//! Tuning coordinator — the Layer-3 service around the paper's identities.
//!
//! Responsibilities:
//! - **Session cache** ([`session`]): the O(N^3) setup (Gram +
//!   eigendecomposition) is keyed by a fingerprint of (inputs, kernel)
//!   and reused across served requests in an LRU store with a byte
//!   budget, so steady-state request cost matches the paper's O(N)
//!   bound.  Clients create sessions explicitly (`create_session`) or
//!   implicitly (an inline `tune` fingerprints its dataset).
//! - **Backend routing**: global search goes through the PJRT
//!   batched-score artifact (one dispatch per swarm generation); Newton
//!   refinement uses the fused artifact or the pure-rust evaluator.
//! - **Serving**: a threaded TCP server (`server.rs`).  Pure-rust jobs
//!   fan out across a worker pool sharing the session store; PJRT jobs
//!   run on a dedicated serial worker that owns the (non-`Send`) PJRT
//!   client.  (tokio is not vendored in this image — DESIGN.md §5.)
//!
//! The wire protocol is documented in `docs/PROTOCOL.md`.
//!
//! # Examples
//!
//! In-process tuning through the [`Coordinator`] (the library-level entry
//! point; the server wraps the same logic):
//!
//! ```
//! use gpml::coordinator::{Coordinator, GlobalStrategy, ObjectiveKind, TuneRequest};
//! use gpml::data::{synthetic, SyntheticSpec};
//!
//! let ds = synthetic(SyntheticSpec { n: 24, p: 2, seed: 1, ..Default::default() }, 1);
//! let mut req = TuneRequest::new(ds.x, ds.ys, SyntheticSpec::default().kernel);
//! req.strategy = GlobalStrategy::Grid { points_per_axis: 5 };
//! req.objective = ObjectiveKind::Evidence;
//!
//! let mut coord = Coordinator::rust_only();
//! let first = coord.tune(&req).unwrap();
//! let second = coord.tune(&req).unwrap(); // same dataset: setup is cached
//! assert!(!first.eigen_cached);
//! assert!(second.eigen_cached);
//! ```

pub mod client;
pub mod protocol;
pub mod server;
pub mod session;

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::kernelfn::{self, Kernel};
use crate::linalg::{Matrix, SymEigen};
use crate::optim::{self, Bounds, NewtonOptions, Objective, PsoOptions};
use crate::runtime::PjrtRuntime;
use crate::spectral::{EigenSystem, HyperParams};

/// Which evaluator backs the objective.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust O(N) loops (always available).
    Rust,
    /// AOT artifacts through PJRT (requires `make artifacts`).
    Pjrt,
}

/// Global-search strategy for the first stage of §1.1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GlobalStrategy {
    Grid { points_per_axis: usize },
    Pso { particles: usize, iterations: usize },
}

impl Default for GlobalStrategy {
    fn default() -> Self {
        GlobalStrategy::Pso { particles: 64, iterations: 25 }
    }
}

/// Which marginal-likelihood objective to minimize.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ObjectiveKind {
    /// The paper's eq. 19 (posterior predictive at the training points).
    /// Note: unbounded below as sigma2 -> 0 (see DESIGN.md); pair it with
    /// bounds that reflect a noise floor.
    #[default]
    PaperScore,
    /// The classical GP evidence -2 log N(y; 0, lambda2 K + sigma2 I) —
    /// same O(N) spectral treatment, interior optimum (extension).
    Evidence,
}

/// A tuning job over one dataset (possibly multi-output).
#[derive(Clone, Debug)]
pub struct TuneRequest {
    pub x: Matrix,
    pub ys: Vec<Vec<f64>>,
    pub kernel: Kernel,
    pub bounds: Bounds,
    pub strategy: GlobalStrategy,
    pub backend: Backend,
    pub objective: ObjectiveKind,
    pub seed: u64,
    /// Pool width for this job's O(N^3) setup and search wavefronts
    /// (DESIGN.md §6): 0 = process default (`--threads` /
    /// `GPML_THREADS` / auto), 1 = exact serial.
    pub threads: usize,
}

impl TuneRequest {
    pub fn new(x: Matrix, ys: Vec<Vec<f64>>, kernel: Kernel) -> Self {
        TuneRequest {
            x,
            ys,
            kernel,
            bounds: Bounds::default(),
            strategy: GlobalStrategy::default(),
            backend: Backend::Rust,
            objective: ObjectiveKind::default(),
            seed: 42,
            threads: 0,
        }
    }
}

/// Per-output tuning outcome.
#[derive(Clone, Copy, Debug)]
pub struct OutputResult {
    pub hp: HyperParams,
    pub score: f64,
    /// Score evaluations in the global stage.
    pub global_evals: usize,
    /// Fused evaluations in the Newton stage.
    pub newton_evals: usize,
    pub converged: bool,
}

/// Whole-job outcome, including stage timings.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub outputs: Vec<OutputResult>,
    /// True if the eigendecomposition came from the cache.
    pub eigen_cached: bool,
    pub gram_seconds: f64,
    pub eigen_seconds: f64,
    pub tune_seconds: f64,
    pub backend: Backend,
}

/// FNV-1a over the little-endian bytes of the inputs + kernel encoding —
/// the eigen-cache key.
pub fn fingerprint(x: &Matrix, kernel: Kernel) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    };
    eat(&(x.rows() as u64).to_le_bytes());
    eat(&(x.cols() as u64).to_le_bytes());
    for v in x.data() {
        eat(&v.to_le_bytes());
    }
    eat(format!("{kernel:?}").as_bytes());
    h
}

/// Cached eigendecomposition for one (dataset, kernel) fingerprint.
struct CacheEntry {
    eigen: SymEigen,
}

/// The coordinator: owns the runtime and the eigen-cache.  Single-threaded
/// by construction (the PJRT client is not `Send`); the server wraps it in
/// a worker thread.
pub struct Coordinator {
    runtime: Option<PjrtRuntime>,
    cache: HashMap<u64, CacheEntry>,
    /// Cache statistics (hits, misses).
    pub cache_hits: usize,
    pub cache_misses: usize,
}

impl Coordinator {
    /// Coordinator with a PJRT runtime (artifact-backed fast paths).
    pub fn with_runtime(runtime: PjrtRuntime) -> Self {
        Coordinator { runtime: Some(runtime), cache: HashMap::new(), cache_hits: 0, cache_misses: 0 }
    }

    /// Pure-rust coordinator (no artifacts needed).
    pub fn rust_only() -> Self {
        Coordinator { runtime: None, cache: HashMap::new(), cache_hits: 0, cache_misses: 0 }
    }

    /// Open the default artifact dir if present, else fall back to rust.
    pub fn auto() -> Self {
        match PjrtRuntime::open(crate::runtime::default_artifact_dir()) {
            Ok(rt) => Coordinator::with_runtime(rt),
            Err(_) => Coordinator::rust_only(),
        }
    }

    pub fn has_runtime(&self) -> bool {
        self.runtime.is_some()
    }

    /// Execute a tuning job.
    pub fn tune(&mut self, req: &TuneRequest) -> Result<TuneResult> {
        if req.ys.is_empty() {
            return Err(anyhow!("no output vectors"));
        }
        for (i, y) in req.ys.iter().enumerate() {
            if y.len() != req.x.rows() {
                return Err(anyhow!("output {i}: length {} != N {}", y.len(), req.x.rows()));
            }
        }
        let backend = match req.backend {
            Backend::Pjrt if self.runtime.is_none() => {
                return Err(anyhow!("PJRT backend requested but no artifacts loaded"))
            }
            b => b,
        };
        // pin this job's pool width for the gram/eigendecomposition and
        // every wavefront issued below (0 = process default)
        crate::util::threadpool::with_threads(req.threads, || self.tune_with_backend(req, backend))
    }

    fn tune_with_backend(&mut self, req: &TuneRequest, backend: Backend) -> Result<TuneResult> {
        // --- O(N^3) overhead: Gram + eigendecomposition, cached ---
        let key = fingerprint(&req.x, req.kernel);
        let t0 = Instant::now();
        let mut gram_seconds = 0.0;
        let mut eigen_seconds = 0.0;
        let eigen_cached = self.cache.contains_key(&key);
        if !eigen_cached {
            self.cache_misses += 1;
            let tg = Instant::now();
            let k = match (&self.runtime, backend) {
                (Some(rt), Backend::Pjrt) if req.kernel.artifact_code().is_some() => {
                    match rt.gram(&req.x, req.kernel) {
                        Ok(k) => k,
                        // dataset larger than any gram bucket: rust fallback
                        Err(_) => kernelfn::gram(req.kernel, &req.x),
                    }
                }
                _ => kernelfn::gram(req.kernel, &req.x),
            };
            gram_seconds = tg.elapsed().as_secs_f64();
            let te = Instant::now();
            let eigen = SymEigen::new(&k).map_err(|e| anyhow!("eigensolver: {e}"))?;
            eigen_seconds = te.elapsed().as_secs_f64();
            self.cache.insert(key, CacheEntry { eigen });
        } else {
            self.cache_hits += 1;
        }
        let eigen = &self.cache.get(&key).unwrap().eigen;

        // --- O(N)-per-iterate tuning per output ---
        let tt = Instant::now();
        let mut outputs = Vec::with_capacity(req.ys.len());
        for y in &req.ys {
            let es = EigenSystem::new(eigen, y);
            let out = match (&self.runtime, backend, req.objective) {
                // the evidence artifacts are not part of the AOT set; the
                // evidence objective always runs on the rust evaluator
                // (its per-iterate cost is the same O(N))
                (Some(rt), Backend::Pjrt, ObjectiveKind::PaperScore) => {
                    let mut ev = rt.evaluator(&es)?;
                    tune_one(&mut ev, req.bounds, req.strategy, req.seed)
                }
                (_, _, ObjectiveKind::Evidence) => {
                    let mut ev = optim::EvidenceObjective(es.clone());
                    tune_one(&mut ev, req.bounds, req.strategy, req.seed)
                }
                _ => {
                    let mut ev = es.clone();
                    tune_one(&mut ev, req.bounds, req.strategy, req.seed)
                }
            };
            outputs.push(out);
        }
        let tune_seconds = tt.elapsed().as_secs_f64();
        let _ = t0;

        Ok(TuneResult {
            outputs,
            eigen_cached,
            gram_seconds,
            eigen_seconds,
            tune_seconds,
            backend,
        })
    }

    /// Look up a cached eigendecomposition (e.g. for prediction after a
    /// tune).
    pub fn cached_eigen(&self, x: &Matrix, kernel: Kernel) -> Option<&SymEigen> {
        self.cache.get(&fingerprint(x, kernel)).map(|e| &e.eigen)
    }

    pub fn runtime(&self) -> Option<&PjrtRuntime> {
        self.runtime.as_ref()
    }
}

/// Global stage + Newton refinement over any objective.  Shared by the
/// coordinator's backend paths and the session subsystem (`session.rs`),
/// so cached-eigenbasis tuning is the *same* computation as a cold tune.
pub(crate) fn tune_one<O: Objective>(
    obj: &mut O,
    bounds: Bounds,
    strategy: GlobalStrategy,
    seed: u64,
) -> OutputResult {
    let global = match strategy {
        GlobalStrategy::Grid { points_per_axis } => {
            optim::grid_search(obj, bounds, points_per_axis, 64)
        }
        GlobalStrategy::Pso { particles, iterations } => optim::pso_search(
            obj,
            bounds,
            PsoOptions { particles, iterations, seed, ..Default::default() },
        ),
    };
    let refined = optim::newton_refine(obj, global.hp, bounds, NewtonOptions::default());
    // Newton should never regress below the global stage's best
    let (hp, score) = if refined.score <= global.score {
        (refined.hp, refined.score)
    } else {
        (global.hp, global.score)
    };
    OutputResult {
        hp,
        score,
        global_evals: global.evals,
        newton_evals: refined.evals,
        converged: refined.converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, SyntheticSpec};

    fn small_request(outputs: usize) -> TuneRequest {
        let spec = SyntheticSpec { n: 60, p: 3, sigma2: 0.1, lambda2: 1.0, seed: 5, ..Default::default() };
        let ds = synthetic(spec, outputs);
        let mut r = TuneRequest::new(ds.x, ds.ys, spec.kernel);
        r.strategy = GlobalStrategy::Grid { points_per_axis: 9 };
        r
    }

    #[test]
    fn tune_evidence_recovers_reasonable_hyperparams() {
        let mut c = Coordinator::rust_only();
        let mut req = small_request(1);
        req.objective = ObjectiveKind::Evidence;
        let res = c.tune(&req).unwrap();
        let out = &res.outputs[0];
        // generating values sigma2=0.1, lambda2=1.0; the evidence has an
        // interior optimum near them
        assert!(out.hp.sigma2 > 1e-3 && out.hp.sigma2 < 10.0, "{:?}", out.hp);
        assert!(out.score.is_finite());
        assert!(!res.eigen_cached);
    }

    #[test]
    fn tune_paper_score_runs_to_noise_floor() {
        // documented pathology of eq. 19 (DESIGN.md): without a noise
        // floor the paper score minimizes at the sigma2 lower bound.
        let mut c = Coordinator::rust_only();
        let req = small_request(1);
        let res = c.tune(&req).unwrap();
        let out = &res.outputs[0];
        assert!(
            out.hp.sigma2 <= req.bounds.sigma2.0 * 1.01,
            "expected boundary solution, got {:?}",
            out.hp
        );
        assert!(out.score.is_finite());
    }

    #[test]
    fn eigen_cache_hits_on_second_job() {
        let mut c = Coordinator::rust_only();
        let req = small_request(1);
        let r1 = c.tune(&req).unwrap();
        let r2 = c.tune(&req).unwrap();
        assert!(!r1.eigen_cached);
        assert!(r2.eigen_cached);
        assert_eq!(c.cache_hits, 1);
        assert_eq!(c.cache_misses, 1);
        // identical results from identical requests
        assert_eq!(r1.outputs[0].hp, r2.outputs[0].hp);
    }

    #[test]
    fn multi_output_shares_decomposition() {
        let mut c = Coordinator::rust_only();
        let res = c.tune(&small_request(3)).unwrap();
        assert_eq!(res.outputs.len(), 3);
        assert_eq!(c.cache_misses, 1);
        for o in &res.outputs {
            assert!(o.score.is_finite());
        }
    }

    #[test]
    fn rejects_mismatched_outputs() {
        let mut c = Coordinator::rust_only();
        let mut req = small_request(1);
        req.ys[0].pop();
        assert!(c.tune(&req).is_err());
        req.ys.clear();
        assert!(c.tune(&req).is_err());
    }

    #[test]
    fn pjrt_backend_without_runtime_errors() {
        let mut c = Coordinator::rust_only();
        let mut req = small_request(1);
        req.backend = Backend::Pjrt;
        assert!(c.tune(&req).is_err());
    }

    #[test]
    fn fingerprint_distinguishes_kernel_and_data() {
        let ds = synthetic(SyntheticSpec { n: 10, p: 2, seed: 1, ..Default::default() }, 1);
        let a = fingerprint(&ds.x, Kernel::Rbf { xi2: 1.0 });
        let b = fingerprint(&ds.x, Kernel::Rbf { xi2: 2.0 });
        let c2 = fingerprint(&ds.x, Kernel::Linear);
        assert_ne!(a, b);
        assert_ne!(a, c2);
        let ds2 = synthetic(SyntheticSpec { n: 10, p: 2, seed: 2, ..Default::default() }, 1);
        assert_ne!(a, fingerprint(&ds2.x, Kernel::Rbf { xi2: 1.0 }));
    }
}

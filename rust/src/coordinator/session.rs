//! Session cache: pay the O(N^3) setup once per dataset, serve every
//! subsequent request in O(N) (DESIGN.md §7).
//!
//! The paper's value proposition is `O(N^3) + k*·O(N)` versus
//! `k*·O(N^3)` — which only materializes in a *server* if the setup
//! survives across requests.  [`SessionStore`] is that survival
//! mechanism: a thread-safe LRU cache of fitted [`SpectralGp`] setups
//! keyed by a fingerprint of (inputs, kernel), bounded by both an entry
//! count and a byte budget, shared by every worker in the server's pool.
//!
//! Three properties the tests pin down:
//!
//! - **Single-flight setup**: concurrent requests for the same dataset
//!   compute the Gram + eigendecomposition exactly once; latecomers
//!   block on a condvar until the first computation publishes.  The
//!   `setups` counter therefore counts O(N^3) work *performed*, not
//!   requests served.
//! - **Numerical identity**: a warm (cached-eigenbasis) tune is the same
//!   computation as a cold one — both run [`EigenSystem`] tuning against
//!   the decomposition produced by the identical `gram` + `SymEigen`
//!   calls — so responses are bitwise identical.
//! - **Bounded memory**: eviction removes least-recently-used sessions
//!   until both budgets hold (the newest session is always retained, so
//!   a budget smaller than one dataset still serves, it just never
//!   caches a second one).
//!
//! [`EigenSystem`]: crate::spectral::EigenSystem
//!
//! # Examples
//!
//! ```
//! use gpml::coordinator::session::SessionStore;
//! use gpml::data::{synthetic, SyntheticSpec};
//!
//! let spec = SyntheticSpec { n: 16, p: 2, seed: 9, ..Default::default() };
//! let ds = synthetic(spec, 1);
//! let store = SessionStore::new(8, 1 << 30);
//!
//! let (sess, cached) = store.create(spec.kernel, ds.x.clone()).unwrap();
//! assert!(!cached);
//! let (again, cached) = store.create(spec.kernel, ds.x).unwrap();
//! assert!(cached);
//! assert_eq!(sess.id, again.id);
//! assert_eq!(store.stats().setups, 1); // O(N^3) paid once
//! ```

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::faults::{hardened_eigen, FaultCounters, FaultPolicy, FaultSnapshot};
use crate::kernelfn::{self, Kernel, ThetaDomain, ThetaDomainVec, ThetaVec, ThetaVecBits};
use crate::linalg::Matrix;
use crate::spectral::{
    EigenSystem, Evaluation, ExtendOutcome, HyperParams, RefitReason, SpectralGp,
};

use super::{
    fingerprint, tune_one, Backend, GlobalStrategy, ObjectiveKind, OutputResult, TuneRequest,
    TuneResult,
};
use crate::optim::{
    self, theta_tune, Bounds, Objective, RefineKind, SetupProvider, ThetaRanges, ThetaSearch,
    TwoStepOptions,
};

/// One cached dataset: the fitted GP handle plus bookkeeping.
pub struct Session {
    /// Server-assigned id; what wire requests reference.
    pub id: u64,
    /// FNV-1a over (inputs, kernel) — see [`fingerprint`].
    pub fingerprint: u64,
    /// The shared O(N^2)-memory setup (cheap-to-clone handle).
    pub gp: SpectralGp,
    /// Approximate heap bytes this session pins (the eviction unit).
    pub bytes: usize,
    /// Wall-clock the one-time setup cost, split by phase.
    pub gram_seconds: f64,
    pub eigen_seconds: f64,
}

/// Point-in-time cache statistics (the wire `stats` op serializes this).
#[derive(Clone, Copy, Debug, Default)]
pub struct StoreStats {
    /// Live sessions.
    pub sessions: usize,
    /// Bytes pinned by live sessions.
    pub bytes: usize,
    /// Entry-count budget.
    pub max_sessions: usize,
    /// Byte budget.
    pub max_bytes: usize,
    /// Requests that found their fingerprint already cached.
    pub hits: u64,
    /// Requests that did not (and so triggered or awaited a setup).
    pub misses: u64,
    /// Sessions removed by LRU/byte-budget pressure (not explicit drops).
    pub evictions: u64,
    /// Gram + eigendecomposition computations actually performed — the
    /// O(N^3) work counter the integration tests assert against.
    pub setups: u64,
    /// Streaming `update_session` requests served (incremental *and*
    /// fallback-refit; a fallback additionally bumps `setups`).
    pub updates: u64,
    /// Live eigen-family cache entries (per-session theta-keyed setups).
    pub theta_entries: usize,
    /// `theta_setup` requests served without building anything: family-
    /// cache hits, the session's own base setup, and single-flight
    /// waiters that woke to find the entry published.
    pub theta_hits: u64,
    /// `theta_setup` requests that triggered a fresh build themselves.
    pub theta_misses: u64,
    /// Family-cache entries removed by cache pressure: shed directly
    /// under the byte budget, or taken along by a session evicted under
    /// either budget.  Explicit `drop_session` and streaming-update
    /// invalidation are not counted.
    pub theta_evictions: u64,
    /// Fault/degradation counters (DESIGN.md §11) — shared with the
    /// server, which accounts sheds/panics/respawns/deadlines on the
    /// same block the store's degradation ladder bumps.
    pub faults: FaultSnapshot,
}

struct Slot {
    sess: Arc<Session>,
    /// Monotonic access tick; smallest = least recently used.
    last_used: u64,
}

/// Family-cache key: (session id, quantized-theta-vector bit patterns).
/// The theta is quantized per component by the engine
/// (`optim::quantize_theta_vec`) before it reaches the store, so the
/// concatenated bit patterns are canonical ([`ThetaVec::bits`]
/// additionally folds `-0.0` to `+0.0`, and the component count is part
/// of the key).
type ThetaKey = (u64, ThetaVecBits);

/// One eigen-family cache entry: the session's kernel family re-fitted
/// at another theta (DESIGN.md §9).
struct ThetaSlot {
    gp: SpectralGp,
    bytes: usize,
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    slots: HashMap<u64, Slot>,
    by_fp: HashMap<u64, u64>,
    /// Fingerprints whose setup is in flight (single-flight guard).
    pending: HashSet<u64>,
    /// Session ids whose streaming update is in flight (updates to one
    /// session serialize; other sessions stay served).
    updating: HashSet<u64>,
    /// Eigen-family cache: per-session setups at other thetas.
    theta: HashMap<ThetaKey, ThetaSlot>,
    /// (session, theta) builds in flight (single-flight guard).
    theta_pending: HashSet<ThetaKey>,
    bytes: usize,
    tick: u64,
    next_id: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    setups: u64,
    updates: u64,
    theta_hits: u64,
    theta_misses: u64,
    theta_evictions: u64,
}

impl Inner {
    /// The fingerprint index's single invariant, both ends: an entry
    /// always points at a live slot, and on collisions (a streaming
    /// update evolving into — or a create racing onto — a fingerprint
    /// another live session already owns) **first-come keeps the
    /// index**.  The loser stays reachable by id until LRU reclaims it.
    ///
    /// Point `fp` at `id` unless another session already owns it.
    fn claim_fp(&mut self, fp: u64, id: u64) {
        let occupied_by_other = matches!(self.by_fp.get(&fp), Some(&other) if other != id);
        if !occupied_by_other {
            self.by_fp.insert(fp, id);
        }
    }

    /// Remove `fp`'s index entry only if `id` owns it (a collision loser
    /// going away must not take the survivor's entry with it).
    fn release_fp(&mut self, fp: u64, id: u64) {
        if self.by_fp.get(&fp) == Some(&id) {
            self.by_fp.remove(&fp);
        }
    }

    /// Remove every eigen-family entry belonging to session `id`,
    /// returning the byte ledger.  `count_evictions` distinguishes
    /// budget-pressure removal (counted) from explicit drops and
    /// streaming-update invalidation (not counted, mirroring how session
    /// drops are accounted).
    fn purge_theta_of(&mut self, id: u64, count_evictions: bool) {
        let keys: Vec<ThetaKey> = self.theta.keys().filter(|k| k.0 == id).copied().collect();
        for key in keys {
            let slot = self.theta.remove(&key).unwrap();
            self.bytes -= slot.bytes;
            if count_evictions {
                self.theta_evictions += 1;
            }
        }
    }
}

/// Thread-safe LRU session cache with a byte budget.  All methods take
/// `&self`; the store is designed to sit in an `Arc` shared by every
/// server worker.
pub struct SessionStore {
    max_sessions: usize,
    max_bytes: usize,
    fault_policy: FaultPolicy,
    faults: Arc<FaultCounters>,
    inner: Mutex<Inner>,
    cv: Condvar,
}

/// Single-flight registration key: which in-flight set holds the claim.
#[derive(Clone, Copy)]
enum PendingKey {
    Fp(u64),
    Theta(ThetaKey),
    Update(u64),
}

/// Drop-guard for a single-flight claim: removes the registration and
/// wakes every condvar waiter on *all* exit paths — success, early
/// `return Err` (the eigensolver-error paths), or a panic unwinding
/// through the builder (the server isolates job panics with
/// `catch_unwind`; without this guard a failed builder would strand
/// every waiter on the condvar forever).
struct PendingGuard<'a> {
    store: &'a SessionStore,
    key: PendingKey,
}

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        let mut g = self.store.guard();
        match self.key {
            PendingKey::Fp(fp) => {
                g.pending.remove(&fp);
            }
            PendingKey::Theta(key) => {
                g.theta_pending.remove(&key);
            }
            PendingKey::Update(id) => {
                g.updating.remove(&id);
            }
        }
        drop(g);
        self.store.cv.notify_all();
    }
}

impl SessionStore {
    /// `max_sessions` entries / `max_bytes` of setup memory; eviction is
    /// LRU and runs when either budget is exceeded.
    pub fn new(max_sessions: usize, max_bytes: usize) -> Self {
        Self::with_faults(
            max_sessions,
            max_bytes,
            FaultPolicy::default(),
            Arc::new(FaultCounters::default()),
        )
    }

    /// [`new`](SessionStore::new) with an explicit degradation-ladder
    /// policy and a (possibly shared) counter block.  The server shares
    /// one [`FaultCounters`] between the store's ladder and its own
    /// shed/panic/deadline accounting, so the wire `stats` op reports a
    /// single fault surface.
    pub fn with_faults(
        max_sessions: usize,
        max_bytes: usize,
        fault_policy: FaultPolicy,
        faults: Arc<FaultCounters>,
    ) -> Self {
        SessionStore {
            max_sessions: max_sessions.max(1),
            max_bytes,
            fault_policy,
            faults,
            inner: Mutex::new(Inner::default()),
            cv: Condvar::new(),
        }
    }

    /// The shared fault-counter block.
    pub fn fault_counters(&self) -> Arc<FaultCounters> {
        self.faults.clone()
    }

    /// Lock the store map, recovering from poison: mutations under this
    /// lock are short and complete (the O(N^3) work runs outside it), so
    /// a panicking job cannot leave `Inner` half-mutated — continuing
    /// with the recovered state is safe, while propagating the poison
    /// would turn one isolated panic into a permanently wedged store.
    fn guard(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Condvar wait with the same poison recovery as [`guard`](Self::guard).
    fn wait_on<'a>(&self, g: MutexGuard<'a, Inner>) -> MutexGuard<'a, Inner> {
        self.cv.wait(g).unwrap_or_else(PoisonError::into_inner)
    }

    /// Get-or-create the session for (kernel, x).  Returns the session
    /// and whether it was already cached.  The O(N^3) setup runs outside
    /// the store lock; concurrent creates of the same dataset are
    /// single-flighted (exactly one computes, the rest wait).
    pub fn create(&self, kernel: Kernel, x: Matrix) -> Result<(Arc<Session>, bool)> {
        // ARD lengthscales are per feature column; a mismatch would
        // silently truncate (or debug-panic) inside the gram kernel
        if let Kernel::RbfArd { xi2 } = kernel {
            if xi2.len() != x.cols() {
                return Err(anyhow!(
                    "rbf-ard kernel has {} lengthscales; data has {} feature columns",
                    xi2.len(),
                    x.cols()
                ));
            }
        }
        let fp = fingerprint(&x, kernel);
        {
            let mut g = self.guard();
            loop {
                if let Some(&id) = g.by_fp.get(&fp) {
                    g.hits += 1;
                    g.tick += 1;
                    let tick = g.tick;
                    let slot = g.slots.get_mut(&id).expect("by_fp points at live slot");
                    slot.last_used = tick;
                    return Ok((slot.sess.clone(), true));
                }
                if g.pending.contains(&fp) {
                    // another worker is computing this setup; wait for it
                    g = self.wait_on(g);
                    continue;
                }
                g.misses += 1;
                g.pending.insert(fp);
                break;
            }
        }
        // claim released + waiters woken on every exit path from here on
        let _claim = PendingGuard { store: self, key: PendingKey::Fp(fp) };

        // --- O(N^3) setup, outside the lock (other sessions stay served) ---
        let tg = Instant::now();
        let k = kernelfn::gram(kernel, &x);
        let gram_seconds = tg.elapsed().as_secs_f64();
        let te = Instant::now();
        let hardened = hardened_eigen(&k, &self.fault_policy, &self.faults);
        let eigen_seconds = te.elapsed().as_secs_f64();
        drop(k);
        // the degradation ladder already walked its jitter/fallback rungs
        // (DESIGN.md §11); an error here is its structured, final end —
        // waiters wake (via `_claim`), retry, and fail the same way
        let eigen = hardened.map_err(|e| anyhow!("eigensolver: {e}"))?.eigen;

        let mut g = self.guard();
        g.setups += 1;
        g.next_id += 1;
        g.tick += 1;
        let (id, tick) = (g.next_id, g.tick);
        let gp = SpectralGp::from_eigen(kernel, x, eigen);
        let bytes = gp.setup_bytes();
        let sess =
            Arc::new(Session { id, fingerprint: fp, gp, bytes, gram_seconds, eigen_seconds });
        g.slots.insert(id, Slot { sess: sess.clone(), last_used: tick });
        // while this setup ran outside the lock, a streaming update may
        // have *evolved* another session to this same fingerprint
        g.claim_fp(fp, id);
        g.bytes += bytes;
        self.evict_over_budget(&mut g, id);
        drop(g);
        self.cv.notify_all();
        Ok((sess, false))
    }

    /// Evict until both budgets hold, never removing `keep_id` (the
    /// session being returned right now) or `keep_theta` (the family
    /// entry being returned right now).
    ///
    /// Under **byte** pressure, LRU eigen-family entries go first: a
    /// family entry is derived state (one decomposition rebuilds it)
    /// while a session is the client-visible product whose id external
    /// callers hold.  Sessions are evicted LRU when the entry budget is
    /// exceeded or when shedding family entries was not enough; an
    /// evicted session takes its whole theta family with it.
    fn evict_over_budget(&self, g: &mut Inner, keep_id: u64) {
        self.evict_with_keeps(g, keep_id, None);
    }

    fn evict_with_keeps(&self, g: &mut Inner, keep_id: u64, keep_theta: Option<ThetaKey>) {
        loop {
            let over_sessions = g.slots.len() > self.max_sessions;
            let over_bytes = g.bytes > self.max_bytes;
            if !over_sessions && !over_bytes {
                break;
            }
            if !over_sessions {
                // byte pressure only: shed LRU family entries first
                let victim = g
                    .theta
                    .iter()
                    .filter(|(&key, _)| Some(key) != keep_theta)
                    .min_by_key(|(_, slot)| slot.last_used)
                    .map(|(&key, _)| key);
                if let Some(key) = victim {
                    let slot = g.theta.remove(&key).unwrap();
                    g.bytes -= slot.bytes;
                    g.theta_evictions += 1;
                    continue;
                }
            }
            let victim = g
                .slots
                .iter()
                .filter(|(&id, _)| id != keep_id)
                .min_by_key(|(_, slot)| slot.last_used)
                .map(|(&id, _)| id);
            let Some(id) = victim else { break };
            let slot = g.slots.remove(&id).unwrap();
            g.release_fp(slot.sess.fingerprint, id);
            g.bytes -= slot.sess.bytes;
            g.evictions += 1;
            g.purge_theta_of(id, true);
        }
    }

    /// Get-or-build the eigendecomposed setup for session `id`'s kernel
    /// family at (engine-quantized) `theta` — the eigen-family cache
    /// read path (DESIGN.md §9).  Returns the setup handle and whether
    /// this call actually built it (`false` = served from the base
    /// session or the family cache).
    ///
    /// Concurrent requests for the same `(session, theta)` are
    /// single-flighted on the store condvar, so a sweep fanned across
    /// the pool — or two clients sweeping the same family — computes
    /// each decomposition exactly once.  The O(N^3) build runs outside
    /// the store lock.  If the session is dropped, evicted, or replaced
    /// by a streaming update while the build is in flight, the setup is
    /// still returned to the caller (the computation is valid against
    /// the dataset it started from) but not cached.
    pub fn theta_setup(&self, id: u64, theta: f64) -> Result<(SpectralGp, bool)> {
        self.theta_setup_vec(id, &ThetaVec::scalar(theta))
    }

    /// Vector form of [`theta_setup`]: the family coordinate is a theta
    /// *vector* (1-component for scalar kernel families), keyed in the
    /// cache by its concatenated quantized bit patterns.
    ///
    /// [`theta_setup`]: SessionStore::theta_setup
    pub fn theta_setup_vec(&self, id: u64, theta: &ThetaVec) -> Result<(SpectralGp, bool)> {
        for i in 0..theta.len() {
            let t = theta.get(i);
            if !(t.is_finite() && t > 0.0) {
                return Err(anyhow!("theta must be positive and finite, got {t}"));
            }
        }
        let key: ThetaKey = (id, theta.bits());
        let base = {
            let mut g = self.guard();
            loop {
                let Some(slot) = g.slots.get(&id) else {
                    return Err(anyhow!("unknown session {id}"));
                };
                let base = slot.sess.gp.clone();
                let dims = base.kernel().theta_dims();
                if dims > 0 && theta.len() != dims {
                    return Err(anyhow!(
                        "theta has {} components; kernel family {:?} has {dims}",
                        theta.len(),
                        base.kernel()
                    ));
                }
                if base.kernel().with_theta_vec(theta) == base.kernel() {
                    // the base session *is* this theta: serve it directly
                    g.theta_hits += 1;
                    g.tick += 1;
                    let tick = g.tick;
                    g.slots.get_mut(&id).unwrap().last_used = tick;
                    return Ok((base, false));
                }
                if let Some(ts) = g.theta.get(&key) {
                    let gp = ts.gp.clone();
                    g.theta_hits += 1;
                    g.tick += 1;
                    let tick = g.tick;
                    g.theta.get_mut(&key).unwrap().last_used = tick;
                    // an active sweep keeps its session warm too
                    g.slots.get_mut(&id).unwrap().last_used = tick;
                    return Ok((gp, false));
                }
                if g.theta_pending.contains(&key) {
                    g = self.wait_on(g);
                    continue;
                }
                g.theta_misses += 1;
                g.theta_pending.insert(key);
                break base;
            }
        };
        // claim released + waiters woken on every exit path from here on
        let _claim = PendingGuard { store: self, key: PendingKey::Theta(key) };

        // --- O(N^3) family build, outside the lock ---
        let kernel = base.kernel().with_theta_vec(theta);
        let k = kernelfn::gram(kernel, base.x());
        let hardened = hardened_eigen(&k, &self.fault_policy, &self.faults);
        drop(k);
        let eigen = hardened.map_err(|e| anyhow!("eigensolver: {e}"))?.eigen;

        let mut g = self.guard();
        g.setups += 1;
        let gp = SpectralGp::from_eigen(kernel, base.x().clone(), eigen);
        // only cache if the session is still live AND still backed by the
        // setup we decomposed — a concurrent streaming update replaces
        // the dataset (and purges the family), and inserting an entry
        // derived from the *old* Gram would poison the warm path
        let still_current =
            g.slots.get(&id).map(|s| s.sess.gp.shares_setup(&base)).unwrap_or(false);
        if !still_current {
            drop(g);
            self.cv.notify_all();
            return Ok((gp, true));
        }
        let bytes = gp.setup_bytes();
        g.tick += 1;
        let tick = g.tick;
        g.theta.insert(key, ThetaSlot { gp: gp.clone(), bytes, last_used: tick });
        g.bytes += bytes;
        self.evict_with_keeps(&mut g, id, Some(key));
        drop(g);
        self.cv.notify_all();
        Ok((gp, true))
    }

    /// Look up a live session by id, refreshing its LRU position.
    pub fn get(&self, id: u64) -> Option<Arc<Session>> {
        let mut g = self.guard();
        g.tick += 1;
        let tick = g.tick;
        let slot = g.slots.get_mut(&id)?;
        slot.last_used = tick;
        Some(slot.sess.clone())
    }

    /// Append observations to a live session — the streaming op
    /// (DESIGN.md §8).  The session keeps its id but its **fingerprint
    /// evolves** to the fingerprint of the grown dataset, so a later
    /// `create_session` with the full (base + appended) inputs is a cache
    /// hit on this same session.  Byte accounting follows the grown
    /// setup (and may evict *other* sessions to restore the budget).
    ///
    /// The O(N^2..N^3) work runs outside the store lock; concurrent
    /// updates to the same id serialize on a per-id in-flight set (each
    /// sees the previous update's result), while other sessions stay
    /// served.  A session dropped or evicted mid-update reports
    /// `unknown session` rather than resurrecting the entry.
    pub fn update(&self, id: u64, x_new: &Matrix) -> Result<UpdateResult> {
        let gp = {
            let mut g = self.guard();
            loop {
                let Some(slot) = g.slots.get(&id) else {
                    return Err(anyhow!("unknown session {id}"));
                };
                let gp = slot.sess.gp.clone();
                if g.updating.contains(&id) {
                    g = self.wait_on(g);
                    continue;
                }
                g.updating.insert(id);
                break gp;
            }
        };
        // claim released + waiters woken on every exit path from here on
        let _claim = PendingGuard { store: self, key: PendingKey::Update(id) };

        // --- the update work, outside the lock ---
        let work = (|| -> Result<(SpectralGp, ExtendOutcome, f64)> {
            if x_new.rows() == 0 {
                return Err(anyhow!("x_new is empty"));
            }
            if x_new.cols() != gp.x().cols() {
                return Err(anyhow!("x_new: {} cols != P {}", x_new.cols(), gp.x().cols()));
            }
            let t0 = Instant::now();
            #[cfg(feature = "fault-inject")]
            let extended = if crate::faults::inject::fire(
                crate::faults::inject::FaultPoint::EigenNoConvergence,
            ) {
                Err(crate::linalg::eigen::NoConvergence { eigenvalue_index: 0 })
            } else {
                gp.extend(x_new)
            };
            #[cfg(not(feature = "fault-inject"))]
            let extended = gp.extend(x_new);
            let (new_gp, outcome) = match extended {
                Ok(v) => v,
                // the incremental eigensolve failed: the ExtendPolicy
                // fallback generalizes into the degradation ladder — a
                // from-scratch refit with jitter/fallback escalation
                Err(_) => self.ladder_refit(&gp, x_new)?,
            };
            Ok((new_gp, outcome, t0.elapsed().as_secs_f64()))
        })();

        let mut g = self.guard();
        let (new_gp, outcome, update_seconds) = match work {
            Ok(v) => v,
            // `g` unlocks before `_claim` releases the claim (reverse
            // declaration order), so the guard's relock cannot deadlock
            Err(e) => return Err(e),
        };
        // the session may have been dropped/evicted while we worked
        let Some(old) = g.slots.get(&id) else {
            return Err(anyhow!("unknown session {id}"));
        };
        let old_sess = old.sess.clone();
        g.updates += 1;
        let refit_reason = match outcome {
            ExtendOutcome::Incremental => None,
            ExtendOutcome::Refit(reason) => {
                g.setups += 1; // the fallback performed real O(N^3) work
                Some(reason.as_str())
            }
        };
        let fp = fingerprint(new_gp.x(), new_gp.kernel());
        let bytes = new_gp.setup_bytes();
        let sess = Arc::new(Session {
            id,
            fingerprint: fp,
            gp: new_gp,
            bytes,
            gram_seconds: old_sess.gram_seconds,
            eigen_seconds: old_sess.eigen_seconds,
        });
        // evolve the fingerprint index (collision policy: see the
        // `Inner` helpers) and the byte ledger
        g.release_fp(old_sess.fingerprint, id);
        g.claim_fp(fp, id);
        g.bytes = g.bytes - old_sess.bytes + bytes;
        g.tick += 1;
        let tick = g.tick;
        g.slots.insert(id, Slot { sess: sess.clone(), last_used: tick });
        // the grown dataset invalidates every family setup derived from
        // the old one (they decompose the *old* Gram at other thetas)
        g.purge_theta_of(id, false);
        self.evict_over_budget(&mut g, id);
        drop(g);
        self.cv.notify_all();
        Ok(UpdateResult { sess, incremental: refit_reason.is_none(), refit_reason, update_seconds })
    }

    /// Full refit of a grown dataset through the degradation ladder —
    /// the streaming path's generalization of the [`ExtendPolicy`]
    /// fallback: when the incremental eigensolve itself fails, rebuild
    /// the grown Gram and decompose it with jitter/fallback escalation
    /// instead of surfacing the raw `NoConvergence`.
    ///
    /// [`ExtendPolicy`]: crate::spectral::ExtendPolicy
    fn ladder_refit(
        &self,
        gp: &SpectralGp,
        x_new: &Matrix,
    ) -> Result<(SpectralGp, ExtendOutcome)> {
        FaultCounters::bump(&self.faults.fallback_refits);
        let p = gp.x().cols();
        let mut data = gp.x().data().to_vec();
        data.extend_from_slice(x_new.data());
        let full_x = Matrix::from_vec(gp.n() + x_new.rows(), p, data);
        let k = kernelfn::gram(gp.kernel(), &full_x);
        let h = hardened_eigen(&k, &self.fault_policy, &self.faults)
            .map_err(|e| anyhow!("eigensolver: {e}"))?;
        Ok((
            SpectralGp::from_eigen(gp.kernel(), full_x, h.eigen),
            ExtendOutcome::Refit(RefitReason::EigenFailure),
        ))
    }

    /// Explicitly drop a session; returns whether it existed.  Freed
    /// bytes are not counted as evictions.
    pub fn drop_session(&self, id: u64) -> bool {
        let mut g = self.guard();
        match g.slots.remove(&id) {
            Some(slot) => {
                g.release_fp(slot.sess.fingerprint, id);
                g.bytes -= slot.sess.bytes;
                g.purge_theta_of(id, false);
                true
            }
            None => false,
        }
    }

    pub fn stats(&self) -> StoreStats {
        let g = self.guard();
        StoreStats {
            sessions: g.slots.len(),
            bytes: g.bytes,
            max_sessions: self.max_sessions,
            max_bytes: self.max_bytes,
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            setups: g.setups,
            updates: g.updates,
            theta_entries: g.theta.len(),
            theta_hits: g.theta_hits,
            theta_misses: g.theta_misses,
            theta_evictions: g.theta_evictions,
            faults: self.faults.snapshot(),
        }
    }
}

/// Outcome of a [`SessionStore::update`]: the replaced session handle
/// plus how the append was served (the wire response serializes this).
pub struct UpdateResult {
    pub sess: Arc<Session>,
    /// True when rank-one corrections served the append (zero O(N^3)).
    pub incremental: bool,
    /// The fallback reason when the policy forced a full refit.
    pub refit_reason: Option<&'static str>,
    /// Wall-clock of the extend (incremental or refit).
    pub update_seconds: f64,
}

/// A tuning job against an existing session: everything a
/// [`TuneRequest`] carries except the dataset (which the session holds).
#[derive(Clone, Debug)]
pub struct SessionTuneRequest {
    pub session_id: u64,
    pub ys: Vec<Vec<f64>>,
    pub bounds: Bounds,
    pub strategy: GlobalStrategy,
    pub objective: ObjectiveKind,
    pub seed: u64,
    /// Pool width for this job's search wavefronts (0 = process default).
    pub threads: usize,
}

impl SessionTuneRequest {
    pub fn new(session_id: u64, ys: Vec<Vec<f64>>) -> Self {
        SessionTuneRequest {
            session_id,
            ys,
            bounds: Bounds::default(),
            strategy: GlobalStrategy::default(),
            objective: ObjectiveKind::default(),
            seed: 42,
            threads: 0,
        }
    }
}

fn validate_outputs(n: usize, ys: &[Vec<f64>]) -> Result<()> {
    if ys.is_empty() {
        return Err(anyhow!("no output vectors"));
    }
    for (i, y) in ys.iter().enumerate() {
        if y.len() != n {
            return Err(anyhow!("output {i}: length {} != N {}", y.len(), n));
        }
    }
    Ok(())
}

/// Per-output global + Newton tuning against a fitted setup — the shared
/// O(N)-per-iterate stage of both the cold and warm paths.
pub(crate) fn run_outputs(
    gp: &SpectralGp,
    ys: &[Vec<f64>],
    objective: ObjectiveKind,
    bounds: Bounds,
    strategy: GlobalStrategy,
    seed: u64,
) -> Vec<OutputResult> {
    ys.iter()
        .map(|y| {
            let es = gp.eigensystem(y);
            match objective {
                ObjectiveKind::Evidence => {
                    let mut ev = optim::EvidenceObjective(es);
                    tune_one(&mut ev, bounds, strategy, seed)
                }
                ObjectiveKind::PaperScore => {
                    let mut ev = es;
                    tune_one(&mut ev, bounds, strategy, seed)
                }
            }
        })
        .collect()
}

/// Execute a session-referencing tune: zero O(N^3) work by construction.
pub fn tune_session(store: &SessionStore, req: &SessionTuneRequest) -> Result<TuneResult> {
    let sess = store
        .get(req.session_id)
        .ok_or_else(|| anyhow!("unknown session {}", req.session_id))?;
    validate_outputs(sess.gp.n(), &req.ys)?;
    crate::util::threadpool::with_threads(req.threads, || {
        let tt = Instant::now();
        let outputs =
            run_outputs(&sess.gp, &req.ys, req.objective, req.bounds, req.strategy, req.seed);
        Ok(TuneResult {
            outputs,
            eigen_cached: true,
            gram_seconds: 0.0,
            eigen_seconds: 0.0,
            tune_seconds: tt.elapsed().as_secs_f64(),
            backend: Backend::Rust,
        })
    })
}

/// Execute an inline (dataset-carrying) tune through the store: the
/// dataset is fingerprinted into an *implicit* session, so repeated
/// inline tunes of the same dataset also skip the setup.  This is the
/// pure-rust server path; PJRT-backed jobs go through [`Coordinator`].
///
/// [`Coordinator`]: super::Coordinator
pub fn tune_via_store(store: &SessionStore, req: &TuneRequest) -> Result<TuneResult> {
    if req.backend == Backend::Pjrt {
        return Err(anyhow!("pjrt-backed jobs run on the coordinator worker, not the pool"));
    }
    validate_outputs(req.x.rows(), &req.ys)?;
    crate::util::threadpool::with_threads(req.threads, || {
        let (sess, cached) = store.create(req.kernel, req.x.clone())?;
        let tt = Instant::now();
        let outputs =
            run_outputs(&sess.gp, &req.ys, req.objective, req.bounds, req.strategy, req.seed);
        Ok(TuneResult {
            outputs,
            eigen_cached: cached,
            gram_seconds: if cached { 0.0 } else { sess.gram_seconds },
            eigen_seconds: if cached { 0.0 } else { sess.eigen_seconds },
            tune_seconds: tt.elapsed().as_secs_f64(),
            backend: Backend::Rust,
        })
    })
}

/// A theta-plane tuning job against an existing session: sweep the
/// session's kernel family over `theta_range`, tuning `(sigma2,
/// lambda2)` at O(N) per iterate inside each probe (Algorithm 1 through
/// the eigen-family cache — DESIGN.md §9).
#[derive(Clone, Debug)]
pub struct ThetaTuneRequest {
    pub session_id: u64,
    pub ys: Vec<Vec<f64>>,
    /// Raw (not log) theta bounds, replicated across every component of
    /// the session's theta vector unless `theta_ranges` is non-empty.
    pub theta_range: (f64, f64),
    /// Per-component raw theta bounds for multi-dimensional (ARD)
    /// families; empty = scalar request (replicate `theta_range`).
    pub theta_ranges: Vec<(f64, f64)>,
    /// Outer evaluation budget (see `TwoStepOptions::outer_iters`).
    pub outer_iters: usize,
    /// Outer search strategy (discrete families sweep regardless).
    pub search: ThetaSearch,
    /// Inner coarse-grid resolution before Newton refinement.
    pub inner_grid: usize,
    /// Whether each outer candidate's inner solve is Newton-polished
    /// (the default) or left at the coarse grid.
    pub refine: RefineKind,
    pub bounds: Bounds,
    pub objective: ObjectiveKind,
    /// Pool width for the outer wavefronts (0 = process default).
    pub threads: usize,
}

impl ThetaTuneRequest {
    pub fn new(session_id: u64, ys: Vec<Vec<f64>>) -> Self {
        ThetaTuneRequest {
            session_id,
            ys,
            theta_range: (1e-2, 1e2),
            theta_ranges: Vec::new(),
            outer_iters: 20,
            search: ThetaSearch::Wavefront { width: 0 },
            inner_grid: 9,
            refine: RefineKind::default(),
            bounds: Bounds::default(),
            objective: ObjectiveKind::default(),
            threads: 0,
        }
    }
}

/// Per-output outcome of a theta-plane tune.
#[derive(Clone, Copy, Debug)]
pub struct ThetaOutput {
    /// Best (quantized) kernel hyperparameter vector found (1-component
    /// for scalar kernel families).
    pub theta: ThetaVec,
    pub hp: HyperParams,
    pub score: f64,
    /// O(N^3) setups actually built for this output (0 on a warm sweep).
    pub outer_evals: usize,
    /// Distinct quantized thetas probed (>= `outer_evals`).
    pub distinct_thetas: usize,
    pub inner_evals: usize,
    /// Newton iterations accepted across the inner refinements (0 when
    /// `refine` is [`RefineKind::None`]).
    pub newton_iters: usize,
    /// O(N) evaluations consumed by Newton refinement alone.
    pub newton_evals: usize,
}

/// Whole-job outcome of [`tune_theta`].
#[derive(Clone, Debug)]
pub struct ThetaTuneResult {
    pub outputs: Vec<ThetaOutput>,
    /// Total setups built across outputs — what the acceptance gate
    /// asserts stays 0 on a warm re-sweep.
    pub setups_built: usize,
    pub tune_seconds: f64,
}

/// The inner objective a [`StoreThetaProvider`] hands the engine: the
/// paper score or the evidence over one output's eigensystem.
enum SessionObjective {
    Paper(EigenSystem),
    Evidence(optim::EvidenceObjective),
}

impl Objective for SessionObjective {
    fn eval(&mut self, hp: HyperParams) -> f64 {
        match self {
            SessionObjective::Paper(es) => es.eval(hp),
            SessionObjective::Evidence(ev) => ev.eval(hp),
        }
    }
    fn eval_batch(&mut self, hps: &[HyperParams]) -> Vec<f64> {
        match self {
            SessionObjective::Paper(es) => es.eval_batch(hps),
            SessionObjective::Evidence(ev) => ev.eval_batch(hps),
        }
    }
    fn eval_full(&mut self, hp: HyperParams) -> Evaluation {
        match self {
            SessionObjective::Paper(es) => es.eval_full(hp),
            SessionObjective::Evidence(ev) => ev.eval_full(hp),
        }
    }
}

/// [`SetupProvider`] over the store's eigen-family cache: `setup(theta)`
/// is [`SessionStore::theta_setup`] + an O(N) `eigensystem` projection
/// of this output.  A warm family means zero builds.
struct StoreThetaProvider<'a> {
    store: &'a SessionStore,
    session_id: u64,
    y: &'a [f64],
    objective: ObjectiveKind,
    domain: ThetaDomainVec,
    built: AtomicUsize,
}

impl SetupProvider for StoreThetaProvider<'_> {
    type Obj = SessionObjective;

    fn domain(&self) -> ThetaDomainVec {
        self.domain
    }

    fn setup(&self, theta: &ThetaVec) -> Result<SessionObjective, String> {
        let (gp, built) =
            self.store.theta_setup_vec(self.session_id, theta).map_err(|e| format!("{e:#}"))?;
        if built {
            self.built.fetch_add(1, Ordering::Relaxed);
        }
        if gp.n() != self.y.len() {
            // a concurrent streaming update grew the session mid-sweep;
            // fail the request cleanly instead of panicking in a worker
            return Err(format!(
                "session {} changed size mid-sweep (N {} != ys length {})",
                self.session_id,
                gp.n(),
                self.y.len()
            ));
        }
        let es = gp.eigensystem(self.y);
        Ok(match self.objective {
            ObjectiveKind::Evidence => SessionObjective::Evidence(optim::EvidenceObjective(es)),
            ObjectiveKind::PaperScore => SessionObjective::Paper(es),
        })
    }

    fn setups_built(&self) -> usize {
        self.built.load(Ordering::Relaxed)
    }
}

/// Execute a theta-plane tune against a live session.  Every probe goes
/// through the eigen-family cache, so outputs after the first — and any
/// repeat request over the same family — reuse the decompositions; a
/// fully warm sweep performs zero O(N^3) work and returns bitwise the
/// same `(theta, hp, score)` as the cold sweep that populated it.
pub fn tune_theta(store: &SessionStore, req: &ThetaTuneRequest) -> Result<ThetaTuneResult> {
    let sess = store
        .get(req.session_id)
        .ok_or_else(|| anyhow!("unknown session {}", req.session_id))?;
    validate_outputs(sess.gp.n(), &req.ys)?;
    let domain = sess.gp.kernel().theta_vec_domain();
    if domain.is_empty() || (0..domain.len()).any(|d| domain.get(d) == ThetaDomain::Fixed) {
        return Err(anyhow!("kernel family {:?} has no tunable theta", sess.gp.kernel()));
    }
    let theta_ranges = if req.theta_ranges.is_empty() {
        ThetaRanges::empty()
    } else {
        ThetaRanges::from_pairs(&req.theta_ranges).map_err(|e| anyhow!(e))?
    };
    let opt = TwoStepOptions {
        theta_range: req.theta_range,
        theta_ranges,
        outer_iters: req.outer_iters,
        search: req.search,
        bounds: req.bounds,
        inner_grid: req.inner_grid,
        refine: req.refine,
        ..Default::default()
    };
    crate::util::threadpool::with_threads(req.threads, || {
        let tt = Instant::now();
        let mut outputs = Vec::with_capacity(req.ys.len());
        let mut setups_built = 0usize;
        for y in &req.ys {
            let provider = StoreThetaProvider {
                store,
                session_id: req.session_id,
                y,
                objective: req.objective,
                domain,
                built: AtomicUsize::new(0),
            };
            let r = theta_tune(&provider, &opt).map_err(|e| anyhow!(e))?;
            setups_built += r.outer_evals;
            outputs.push(ThetaOutput {
                theta: r.theta,
                hp: r.hp,
                score: r.score,
                outer_evals: r.outer_evals,
                distinct_thetas: r.distinct_thetas,
                inner_evals: r.inner_evals,
                newton_iters: r.newton_iters,
                newton_evals: r.newton_evals,
            });
        }
        Ok(ThetaTuneResult { outputs, setups_built, tune_seconds: tt.elapsed().as_secs_f64() })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Coordinator;
    use crate::data::{synthetic, SyntheticSpec};

    fn dataset(n: usize, seed: u64) -> (Kernel, Matrix, Vec<Vec<f64>>) {
        let spec = SyntheticSpec { n, p: 2, seed, ..Default::default() };
        let ds = synthetic(spec, 1);
        (spec.kernel, ds.x, ds.ys)
    }

    #[test]
    fn fingerprint_reuse_returns_same_session() {
        let store = SessionStore::new(8, usize::MAX);
        let (k, x, _) = dataset(20, 1);
        let (a, cached_a) = store.create(k, x.clone()).unwrap();
        let (b, cached_b) = store.create(k, x).unwrap();
        assert!(!cached_a);
        assert!(cached_b);
        assert_eq!(a.id, b.id);
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.setups, s.sessions), (1, 1, 1, 1));
        assert_eq!(s.bytes, a.bytes);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let store = SessionStore::new(2, usize::MAX);
        let (k, xa, _) = dataset(16, 1);
        let (k2, xb, _) = dataset(16, 2);
        let (k3, xc, _) = dataset(16, 3);
        let (a, _) = store.create(k, xa).unwrap();
        let (b, _) = store.create(k2, xb).unwrap();
        // touch A so B becomes the LRU victim
        assert!(store.get(a.id).is_some());
        let (c, _) = store.create(k3, xc).unwrap();
        assert!(store.get(a.id).is_some());
        assert!(store.get(b.id).is_none());
        assert!(store.get(c.id).is_some());
        let s = store.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.sessions, 2);
    }

    #[test]
    fn byte_budget_evicts_but_keeps_newest() {
        let (k, xa, _) = dataset(16, 1);
        let (_, xb, _) = dataset(16, 2);
        // budget below a single session: the newest is still retained
        let one = SpectralGp::fit(k, xa.clone()).unwrap().setup_bytes();
        let store = SessionStore::new(8, one / 2);
        let (a, _) = store.create(k, xa).unwrap();
        assert_eq!(store.stats().sessions, 1, "newest survives an impossible budget");
        let (b, _) = store.create(k, xb).unwrap();
        assert!(store.get(a.id).is_none(), "old session evicted under byte pressure");
        assert!(store.get(b.id).is_some());
        let s = store.stats();
        assert_eq!(s.evictions, 1);
        assert!(s.bytes <= one);
    }

    #[test]
    fn drop_session_frees_bytes_and_fingerprint() {
        let store = SessionStore::new(8, usize::MAX);
        let (k, x, _) = dataset(16, 5);
        let (a, _) = store.create(k, x.clone()).unwrap();
        assert!(store.drop_session(a.id));
        assert!(!store.drop_session(a.id));
        assert_eq!(store.stats().bytes, 0);
        // the fingerprint mapping is gone too: re-create recomputes
        let (_, cached) = store.create(k, x).unwrap();
        assert!(!cached);
        assert_eq!(store.stats().setups, 2);
    }

    #[test]
    fn concurrent_creates_single_flight_the_setup() {
        let store = std::sync::Arc::new(SessionStore::new(8, usize::MAX));
        let (k, x, _) = dataset(48, 7);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let store = store.clone();
                let x = x.clone();
                std::thread::spawn(move || store.create(k, x).unwrap().0.id)
            })
            .collect();
        let ids: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(ids.windows(2).all(|w| w[0] == w[1]), "all threads share one session");
        let s = store.stats();
        assert_eq!(s.setups, 1, "the O(N^3) setup ran exactly once");
        assert_eq!(s.misses + s.hits, 4);
    }

    #[test]
    fn tune_via_store_matches_coordinator_bitwise() {
        let (k, x, ys) = dataset(32, 11);
        let mut req = TuneRequest::new(x, ys, k);
        req.strategy = GlobalStrategy::Grid { points_per_axis: 7 };
        req.objective = ObjectiveKind::Evidence;

        let mut coord = Coordinator::rust_only();
        let cold = coord.tune(&req).unwrap();

        let store = SessionStore::new(8, usize::MAX);
        let via_store = tune_via_store(&store, &req).unwrap();
        let warm = tune_via_store(&store, &req).unwrap();
        assert!(!via_store.eigen_cached);
        assert!(warm.eigen_cached);

        for (a, b) in cold.outputs.iter().zip(&via_store.outputs) {
            assert_eq!(a.hp, b.hp);
            assert_eq!(a.score.to_bits(), b.score.to_bits());
        }
        for (a, b) in via_store.outputs.iter().zip(&warm.outputs) {
            assert_eq!(a.hp, b.hp);
            assert_eq!(a.score.to_bits(), b.score.to_bits());
        }
    }

    #[test]
    fn update_grows_session_and_evolves_fingerprint() {
        let store = SessionStore::new(8, usize::MAX);
        let mut rng = crate::util::rng::Rng::new(51);
        let full = Matrix::from_fn(20, 2, |_, _| rng.normal());
        let base = full.top_left(16, 2);
        let extra = Matrix::from_fn(4, 2, |i, j| full[(16 + i, j)]);
        let k = Kernel::Rbf { xi2: 2.0 };

        let (sess, _) = store.create(k, base).unwrap();
        let before_bytes = store.stats().bytes;
        let res = store.update(sess.id, &extra).unwrap();
        assert!(res.incremental);
        assert_eq!(res.sess.gp.n(), 20);
        assert_eq!(res.sess.id, sess.id);
        let s = store.stats();
        assert_eq!(s.updates, 1);
        assert_eq!(s.setups, 1, "incremental update performed no O(N^3) setup");
        assert!(s.bytes > before_bytes, "byte ledger follows the grown setup");
        assert_eq!(s.bytes, res.sess.bytes);

        // fingerprint evolution: creating the *full* dataset now hits the
        // updated session
        let (again, cached) = store.create(k, full).unwrap();
        assert!(cached);
        assert_eq!(again.id, sess.id);
        // and the old (pre-append) fingerprint is gone: re-creating the
        // base dataset computes a fresh setup
        let (fresh, cached) = store.create(k, res.sess.gp.x().top_left(16, 2)).unwrap();
        assert!(!cached);
        assert_ne!(fresh.id, sess.id);
    }

    #[test]
    fn colliding_fingerprint_evolution_keeps_index_consistent() {
        // two sessions stream the *same* data: the second update's
        // evolved fingerprint collides with the first's — the index must
        // keep exactly one live owner, and dropping either session must
        // not corrupt the survivor's entry
        let store = SessionStore::new(8, usize::MAX);
        let mut rng = crate::util::rng::Rng::new(61);
        let full = Matrix::from_fn(18, 2, |_, _| rng.normal());
        let base = full.top_left(14, 2);
        let extra = Matrix::from_fn(4, 2, |i, j| full[(14 + i, j)]);
        let k = Kernel::Rbf { xi2: 2.0 };

        let (a, _) = store.create(k, base.clone()).unwrap();
        store.update(a.id, &extra).unwrap();
        // second streamer: base fp is free again (A's evolved), so this
        // is a fresh session...
        let (b, cached_b) = store.create(k, base).unwrap();
        assert!(!cached_b);
        assert_ne!(b.id, a.id);
        // ...whose update collides with A's evolved fingerprint
        let res_b = store.update(b.id, &extra).unwrap();
        assert_eq!(res_b.sess.gp.n(), 18);

        // the full dataset resolves to the first owner (first-come keeps)
        let (hit, cached) = store.create(k, full.clone()).unwrap();
        assert!(cached);
        assert_eq!(hit.id, a.id);
        // B stays reachable by id even though it lost the index race
        assert!(store.get(b.id).is_some());

        // dropping the loser must not remove the survivor's entry
        assert!(store.drop_session(b.id));
        let (hit, cached) = store.create(k, full.clone()).unwrap();
        assert!(cached);
        assert_eq!(hit.id, a.id);

        // dropping the owner finally frees the fingerprint
        assert!(store.drop_session(a.id));
        let (_, cached) = store.create(k, full).unwrap();
        assert!(!cached);
    }

    #[test]
    fn update_falls_back_past_budget_and_counts_a_setup() {
        let store = SessionStore::new(8, usize::MAX);
        let (k, x, _) = dataset(16, 31);
        let (sess, _) = store.create(k, x).unwrap();
        let mut rng = crate::util::rng::Rng::new(52);
        // the default policy allows 64 rank-one corrections = 32 appended
        // rows; a 40-row batch must fall back to a refit
        let big = Matrix::from_fn(40, 2, |_, _| rng.normal());
        let res = store.update(sess.id, &big).unwrap();
        assert!(!res.incremental);
        assert_eq!(res.refit_reason, Some("update-budget"));
        assert_eq!(res.sess.gp.n(), 56);
        let s = store.stats();
        assert_eq!(s.updates, 1);
        assert_eq!(s.setups, 2, "the fallback refit is counted as O(N^3) work");
    }

    #[test]
    fn update_rejects_unknown_and_bad_shapes() {
        let store = SessionStore::new(8, usize::MAX);
        let (k, x, _) = dataset(12, 33);
        let (sess, _) = store.create(k, x).unwrap();
        let good = Matrix::from_fn(1, 2, |_, _| 0.5);
        assert!(store.update(999, &good).is_err());
        assert!(store.update(sess.id, &Matrix::zeros(0, 2)).is_err());
        let wrong_p = Matrix::from_fn(1, 3, |_, _| 0.5);
        let err = store.update(sess.id, &wrong_p).unwrap_err();
        assert!(err.to_string().contains("cols"), "{err}");
        // failures leave the session serviceable
        assert!(store.update(sess.id, &good).is_ok());
        assert_eq!(store.stats().updates, 1);
    }

    #[test]
    fn concurrent_updates_to_one_session_serialize() {
        let store = std::sync::Arc::new(SessionStore::new(8, usize::MAX));
        let (k, x, _) = dataset(16, 35);
        let (sess, _) = store.create(k, x).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let store = store.clone();
                let id = sess.id;
                std::thread::spawn(move || {
                    let row = Matrix::from_fn(1, 2, |_, j| (i * 2 + j) as f64 * 0.3);
                    store.update(id, &row).unwrap().sess.gp.n()
                })
            })
            .collect();
        let mut seen: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![17, 18, 19, 20], "each update saw the previous one's result");
        assert_eq!(store.get(sess.id).unwrap().gp.n(), 20);
        assert_eq!(store.stats().updates, 4);
    }

    #[test]
    fn tune_session_rejects_bad_shapes() {
        let store = SessionStore::new(8, usize::MAX);
        let (k, x, ys) = dataset(16, 3);
        let (sess, _) = store.create(k, x).unwrap();
        // unknown id
        assert!(tune_session(&store, &SessionTuneRequest::new(999, ys.clone())).is_err());
        // wrong length
        let mut bad = ys.clone();
        bad[0].pop();
        assert!(tune_session(&store, &SessionTuneRequest::new(sess.id, bad)).is_err());
        // empty
        assert!(tune_session(&store, &SessionTuneRequest::new(sess.id, vec![])).is_err());
        // good
        let mut ok = SessionTuneRequest::new(sess.id, ys);
        ok.strategy = GlobalStrategy::Grid { points_per_axis: 5 };
        let res = tune_session(&store, &ok).unwrap();
        assert!(res.eigen_cached);
        assert_eq!(res.gram_seconds, 0.0);
    }

    #[test]
    fn theta_setup_caches_and_counts() {
        let store = SessionStore::new(8, usize::MAX);
        let (k, x, _) = dataset(16, 41);
        let (sess, _) = store.create(k, x).unwrap();
        let theta = optim::quantize_theta(3.0, ThetaDomain::Continuous);

        let (a, built_a) = store.theta_setup(sess.id, theta).unwrap();
        assert!(built_a);
        assert_eq!(a.kernel(), k.with_theta(theta));
        let (b, built_b) = store.theta_setup(sess.id, theta).unwrap();
        assert!(!built_b);
        assert_eq!(a.eigen().values, b.eigen().values);

        let s = store.stats();
        assert_eq!(s.theta_entries, 1);
        assert_eq!((s.theta_hits, s.theta_misses), (1, 1));
        assert_eq!(s.setups, 2, "base session + one family build");
        assert!(s.bytes > sess.bytes, "family entry joins the byte ledger");

        // the base session's own theta short-circuits without an entry
        let base_theta = k.theta().unwrap();
        let (c, built_c) = store.theta_setup(sess.id, base_theta).unwrap();
        assert!(!built_c);
        assert_eq!(c.kernel(), k);
        assert_eq!(store.stats().theta_entries, 1);

        // invalid thetas and dead sessions are rejected
        assert!(store.theta_setup(sess.id, -1.0).is_err());
        assert!(store.theta_setup(sess.id, f64::NAN).is_err());
        assert!(store.theta_setup(999, theta).is_err());
    }

    #[test]
    fn concurrent_theta_setups_single_flight() {
        let store = std::sync::Arc::new(SessionStore::new(8, usize::MAX));
        let (k, x, _) = dataset(48, 43);
        let (sess, _) = store.create(k, x).unwrap();
        let theta = optim::quantize_theta(0.7, ThetaDomain::Continuous);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let store = store.clone();
                let id = sess.id;
                std::thread::spawn(move || store.theta_setup(id, theta).unwrap().1)
            })
            .collect();
        let builds: usize =
            handles.into_iter().map(|h| usize::from(h.join().unwrap())).sum();
        assert_eq!(builds, 1, "exactly one thread built; the rest were served");
        let s = store.stats();
        assert_eq!(s.theta_entries, 1);
        assert_eq!(s.setups, 2, "base + one single-flighted family build");
    }

    #[test]
    fn byte_pressure_sheds_theta_entries_before_sessions() {
        let (k, xa, _) = dataset(16, 44);
        let one = SpectralGp::fit(k, xa.clone()).unwrap().setup_bytes();
        // room for the session plus roughly one family entry
        let store = SessionStore::new(8, 2 * one + one / 2);
        let (sess, _) = store.create(k, xa).unwrap();
        let t1 = optim::quantize_theta(0.5, ThetaDomain::Continuous);
        let t2 = optim::quantize_theta(5.0, ThetaDomain::Continuous);
        store.theta_setup(sess.id, t1).unwrap();
        store.theta_setup(sess.id, t2).unwrap();
        let s = store.stats();
        assert_eq!(s.sessions, 1, "the session itself survives byte pressure");
        assert_eq!(s.theta_entries, 1, "LRU family entry was shed");
        assert_eq!(s.theta_evictions, 1);
        assert!(s.bytes <= 2 * one + one / 2);
        // the shed theta rebuilds on demand
        let (_, built) = store.theta_setup(sess.id, t1).unwrap();
        assert!(built);
    }

    #[test]
    fn drop_and_update_purge_family_entries() {
        let store = SessionStore::new(8, usize::MAX);
        let mut rng = crate::util::rng::Rng::new(45);
        let base = Matrix::from_fn(16, 2, |_, _| rng.normal());
        let extra = Matrix::from_fn(2, 2, |_, _| rng.normal());
        let k = Kernel::Rbf { xi2: 2.0 };
        let (sess, _) = store.create(k, base).unwrap();
        let theta = optim::quantize_theta(0.9, ThetaDomain::Continuous);
        store.theta_setup(sess.id, theta).unwrap();
        assert_eq!(store.stats().theta_entries, 1);

        // streaming growth invalidates the family (old-Gram decompositions)
        store.update(sess.id, &extra).unwrap();
        let s = store.stats();
        assert_eq!(s.theta_entries, 0);
        assert_eq!(s.theta_evictions, 0, "invalidation is not pressure");
        // rebuilt entries decompose the *grown* dataset
        let (gp, built) = store.theta_setup(sess.id, theta).unwrap();
        assert!(built);
        assert_eq!(gp.n(), 18);

        // explicit drop releases the family's bytes with the session
        assert!(store.drop_session(sess.id));
        let s = store.stats();
        assert_eq!((s.theta_entries, s.bytes), (0, 0));
    }

    #[test]
    fn tune_theta_warm_sweep_is_bitwise_cold() {
        let store = SessionStore::new(8, usize::MAX);
        let (k, x, ys) = dataset(24, 47);
        let (sess, _) = store.create(k, x).unwrap();
        let mut req = ThetaTuneRequest::new(sess.id, ys);
        req.theta_range = (0.2, 10.0);
        req.outer_iters = 12;
        req.inner_grid = 5;
        req.objective = ObjectiveKind::Evidence;

        let cold = tune_theta(&store, &req).unwrap();
        assert!(cold.setups_built > 0);
        let setups_after_cold = store.stats().setups;

        let warm = tune_theta(&store, &req).unwrap();
        assert_eq!(warm.setups_built, 0, "warm sweep builds nothing");
        let s = store.stats();
        assert_eq!(s.setups, setups_after_cold, "setups stay flat");
        assert!(s.theta_hits > 0);
        for (a, b) in cold.outputs.iter().zip(&warm.outputs) {
            assert_eq!(a.theta.bits(), b.theta.bits());
            assert_eq!(a.hp, b.hp);
            assert_eq!(a.score.to_bits(), b.score.to_bits());
            assert_eq!(a.distinct_thetas, b.distinct_thetas);
        }
    }

    #[test]
    fn tune_theta_rejects_bad_requests() {
        let store = SessionStore::new(8, usize::MAX);
        let (k, x, ys) = dataset(12, 49);
        let (sess, _) = store.create(k, x.clone()).unwrap();
        // unknown session
        assert!(tune_theta(&store, &ThetaTuneRequest::new(999, ys.clone())).is_err());
        // output length mismatch
        let mut bad = ys.clone();
        bad[0].pop();
        assert!(tune_theta(&store, &ThetaTuneRequest::new(sess.id, bad)).is_err());
        // inverted range
        let mut req = ThetaTuneRequest::new(sess.id, ys.clone());
        req.theta_range = (10.0, 0.1);
        assert!(tune_theta(&store, &req).is_err());
        // fixed family has no theta
        let (lin, _) = store.create(Kernel::Linear, x).unwrap();
        assert!(tune_theta(&store, &ThetaTuneRequest::new(lin.id, ys)).is_err());
    }

    /// Block `waiters` threads on a single-flight claim, kill the
    /// "builder" by panicking it while it holds only the [`PendingGuard`],
    /// then require every waiter to complete within the deadline — the
    /// regression shape for the condvar-stranding bug this guard fixes.
    fn assert_guard_unblocks<F>(store: &Arc<SessionStore>, key: PendingKey, waiter: F)
    where
        F: Fn() + Send + Sync + 'static,
    {
        use std::sync::mpsc::channel;
        use std::time::Duration;

        // simulate the real paths' claim: register under the lock
        {
            let mut g = store.guard();
            match key {
                PendingKey::Fp(fp) => {
                    g.pending.insert(fp);
                }
                PendingKey::Theta(k) => {
                    g.theta_pending.insert(k);
                }
                PendingKey::Update(id) => {
                    g.updating.insert(id);
                }
            }
        }
        let waiter = Arc::new(waiter);
        let (tx, rx) = channel();
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let waiter = waiter.clone();
                let tx = tx.clone();
                std::thread::spawn(move || {
                    waiter();
                    tx.send(()).unwrap();
                })
            })
            .collect();
        // let the waiters reach the condvar, then fail the builder: its
        // unwind drops the guard, which must release the claim and wake
        std::thread::sleep(Duration::from_millis(50));
        assert!(rx.try_recv().is_err(), "waiters blocked on the in-flight claim");
        let store_for_builder = store.clone();
        let builder = std::thread::spawn(move || {
            let _claim = PendingGuard { store: &store_for_builder, key };
            panic!("builder failed mid-setup");
        });
        assert!(builder.join().is_err());
        for _ in &handles {
            rx.recv_timeout(Duration::from_secs(30))
                .expect("waiter stranded after the building thread failed");
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn failed_create_builder_wakes_waiters() {
        let store = Arc::new(SessionStore::new(8, usize::MAX));
        let (k, x, _) = dataset(16, 77);
        let fp = fingerprint(&x, k);
        let s2 = store.clone();
        assert_guard_unblocks(&store, PendingKey::Fp(fp), move || {
            // a woken waiter finds no claim and builds the setup itself
            s2.create(k, x.clone()).unwrap();
        });
        let s = store.stats();
        assert_eq!(s.setups, 1, "one surviving waiter built; the rest hit");
    }

    #[test]
    fn failed_theta_builder_wakes_waiters() {
        let store = Arc::new(SessionStore::new(8, usize::MAX));
        let (k, x, _) = dataset(16, 78);
        let (sess, _) = store.create(k, x).unwrap();
        let theta = optim::quantize_theta(3.0, ThetaDomain::Continuous);
        let key = (sess.id, ThetaVec::scalar(theta).bits());
        let s2 = store.clone();
        let id = sess.id;
        assert_guard_unblocks(&store, PendingKey::Theta(key), move || {
            s2.theta_setup(id, theta).unwrap();
        });
        assert_eq!(store.stats().theta_entries, 1);
    }

    #[test]
    fn failed_updater_wakes_waiters() {
        let store = Arc::new(SessionStore::new(8, usize::MAX));
        let (k, x, _) = dataset(16, 79);
        let (sess, _) = store.create(k, x).unwrap();
        let s2 = store.clone();
        let id = sess.id;
        assert_guard_unblocks(&store, PendingKey::Update(id), move || {
            let row = Matrix::from_fn(1, 2, |_, j| 0.4 + j as f64 * 0.2);
            s2.update(id, &row).unwrap();
        });
        assert_eq!(store.stats().updates, 3, "every blocked updater was served");
    }
}

//! Wire protocol: newline-delimited JSON over TCP.
//!
//! Requests:
//! ```json
//! {"op": "ping"}
//! {"op": "info"}
//! {"op": "tune", "x": [[...], ...], "ys": [[...], ...],
//!  "kernel": "rbf:2.0", "backend": "rust"|"pjrt",
//!  "strategy": "pso"|"grid", "particles": 64, "iterations": 25,
//!  "grid": 17, "seed": 42}
//! ```
//! Responses: `{"ok": true, ...}` or `{"ok": false, "error": "..."}`.

use crate::coordinator::{Backend, GlobalStrategy, ObjectiveKind, TuneRequest, TuneResult};
use crate::kernelfn;
use crate::linalg::Matrix;
use crate::util::json::{self, Json};

/// Parsed request operations.
#[derive(Debug)]
pub enum Request {
    Ping,
    Info,
    Tune(Box<TuneRequest>),
    Shutdown,
}

fn parse_matrix(v: &Json) -> Result<Matrix, String> {
    let rows = v.as_arr().ok_or("x must be an array of rows")?;
    if rows.is_empty() {
        return Err("x is empty".into());
    }
    let p = rows[0].as_arr().ok_or("x rows must be arrays")?.len();
    let mut data = Vec::with_capacity(rows.len() * p);
    for (i, r) in rows.iter().enumerate() {
        let r = r.as_arr().ok_or("x rows must be arrays")?;
        if r.len() != p {
            return Err(format!("row {i} has {} cols, expected {p}", r.len()));
        }
        for c in r {
            data.push(c.as_f64().ok_or("x entries must be numbers")?);
        }
    }
    Ok(Matrix::from_vec(rows.len(), p, data))
}

fn parse_vec(v: &Json) -> Result<Vec<f64>, String> {
    v.as_arr()
        .ok_or("expected array")?
        .iter()
        .map(|x| x.as_f64().ok_or("expected number".to_string()))
        .collect()
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = json::parse(line)?;
    match v.get("op").and_then(Json::as_str) {
        Some("ping") => Ok(Request::Ping),
        Some("info") => Ok(Request::Info),
        Some("shutdown") => Ok(Request::Shutdown),
        Some("tune") => {
            let x = parse_matrix(v.get("x").ok_or("missing x")?)?;
            let ys_json = v.get("ys").ok_or("missing ys")?;
            let ys: Result<Vec<Vec<f64>>, String> = ys_json
                .as_arr()
                .ok_or("ys must be an array")?
                .iter()
                .map(parse_vec)
                .collect();
            let ys = ys?;
            let kernel =
                kernelfn::parse_kernel(v.get("kernel").and_then(Json::as_str).unwrap_or("rbf:1.0"))?;
            let mut req = TuneRequest::new(x, ys, kernel);
            req.backend = match v.get("backend").and_then(Json::as_str) {
                Some("pjrt") => Backend::Pjrt,
                _ => Backend::Rust,
            };
            req.objective = match v.get("objective").and_then(Json::as_str) {
                Some("evidence") => ObjectiveKind::Evidence,
                _ => ObjectiveKind::PaperScore,
            };
            req.strategy = match v.get("strategy").and_then(Json::as_str) {
                Some("grid") => GlobalStrategy::Grid {
                    points_per_axis: v.get("grid").and_then(Json::as_usize).unwrap_or(17),
                },
                _ => GlobalStrategy::Pso {
                    particles: v.get("particles").and_then(Json::as_usize).unwrap_or(64),
                    iterations: v.get("iterations").and_then(Json::as_usize).unwrap_or(25),
                },
            };
            if let Some(seed) = v.get("seed").and_then(Json::as_f64) {
                req.seed = seed as u64;
            }
            if let Some(threads) = v.get("threads").and_then(Json::as_usize) {
                req.threads = threads;
            }
            Ok(Request::Tune(Box::new(req)))
        }
        other => Err(format!("unknown op {other:?}")),
    }
}

/// Serialize a tune result.
pub fn tune_response(res: &TuneResult) -> String {
    let outputs: Vec<Json> = res
        .outputs
        .iter()
        .map(|o| {
            Json::obj(vec![
                ("sigma2", Json::Num(o.hp.sigma2)),
                ("lambda2", Json::Num(o.hp.lambda2)),
                ("score", Json::Num(o.score)),
                ("global_evals", Json::Num(o.global_evals as f64)),
                ("newton_evals", Json::Num(o.newton_evals as f64)),
                ("converged", Json::Bool(o.converged)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("outputs", Json::Arr(outputs)),
        ("eigen_cached", Json::Bool(res.eigen_cached)),
        ("gram_seconds", Json::Num(res.gram_seconds)),
        ("eigen_seconds", Json::Num(res.eigen_seconds)),
        ("tune_seconds", Json::Num(res.tune_seconds)),
        (
            "backend",
            Json::str(match res.backend {
                Backend::Rust => "rust",
                Backend::Pjrt => "pjrt",
            }),
        ),
    ])
    .to_string()
}

pub fn error_response(msg: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))]).to_string()
}

pub fn pong_response() -> String {
    Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]).to_string()
}

/// Serialize a tune request (client side).
pub fn tune_request_json(req: &TuneRequest) -> String {
    let x_rows: Vec<Json> = (0..req.x.rows()).map(|i| Json::arr_f64(req.x.row(i))).collect();
    let ys: Vec<Json> = req.ys.iter().map(|y| Json::arr_f64(y)).collect();
    let kernel = match req.kernel {
        crate::kernelfn::Kernel::Rbf { xi2 } => format!("rbf:{xi2}"),
        crate::kernelfn::Kernel::Polynomial { degree } => format!("poly:{degree}"),
        crate::kernelfn::Kernel::Linear => "linear".to_string(),
        crate::kernelfn::Kernel::Matern32 { ell } => format!("matern32:{ell}"),
        crate::kernelfn::Kernel::Matern52 { ell } => format!("matern52:{ell}"),
    };
    let mut fields = vec![
        ("op", Json::str("tune")),
        ("x", Json::Arr(x_rows)),
        ("ys", Json::Arr(ys)),
        ("kernel", Json::str(&kernel)),
        (
            "objective",
            Json::str(match req.objective {
                ObjectiveKind::PaperScore => "paper",
                ObjectiveKind::Evidence => "evidence",
            }),
        ),
        (
            "backend",
            Json::str(match req.backend {
                Backend::Rust => "rust",
                Backend::Pjrt => "pjrt",
            }),
        ),
        ("seed", Json::Num(req.seed as f64)),
        ("threads", Json::Num(req.threads as f64)),
    ];
    match req.strategy {
        GlobalStrategy::Grid { points_per_axis } => {
            fields.push(("strategy", Json::str("grid")));
            fields.push(("grid", Json::Num(points_per_axis as f64)));
        }
        GlobalStrategy::Pso { particles, iterations } => {
            fields.push(("strategy", Json::str("pso")));
            fields.push(("particles", Json::Num(particles as f64)));
            fields.push(("iterations", Json::Num(iterations as f64)));
        }
    }
    Json::obj(fields).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::OutputResult;
    use crate::spectral::HyperParams;

    #[test]
    fn ping_and_info_parse() {
        assert!(matches!(parse_request(r#"{"op":"ping"}"#).unwrap(), Request::Ping));
        assert!(matches!(parse_request(r#"{"op":"info"}"#).unwrap(), Request::Info));
        assert!(matches!(parse_request(r#"{"op":"shutdown"}"#).unwrap(), Request::Shutdown));
        assert!(parse_request(r#"{"op":"nope"}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn tune_request_roundtrip() {
        let x = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut req = TuneRequest::new(x, vec![vec![0.5, -0.5]], crate::kernelfn::Kernel::Rbf { xi2: 2.0 });
        req.strategy = GlobalStrategy::Grid { points_per_axis: 9 };
        req.backend = Backend::Rust;
        let line = tune_request_json(&req);
        match parse_request(&line).unwrap() {
            Request::Tune(r) => {
                assert_eq!(r.x.rows(), 2);
                assert_eq!(r.ys[0], vec![0.5, -0.5]);
                assert_eq!(r.kernel, crate::kernelfn::Kernel::Rbf { xi2: 2.0 });
                assert_eq!(r.strategy, GlobalStrategy::Grid { points_per_axis: 9 });
            }
            other => panic!("expected tune, got {other:?}"),
        }
    }

    #[test]
    fn tune_response_shape() {
        let res = TuneResult {
            outputs: vec![OutputResult {
                hp: HyperParams::new(0.5, 2.0),
                score: -12.5,
                global_evals: 100,
                newton_evals: 7,
                converged: true,
            }],
            eigen_cached: true,
            gram_seconds: 0.0,
            eigen_seconds: 0.1,
            tune_seconds: 0.01,
            backend: Backend::Rust,
        };
        let text = tune_response(&res);
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        let outs = v.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs[0].get("sigma2").unwrap().as_f64(), Some(0.5));
        assert_eq!(outs[0].get("converged").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn malformed_tune_requests_rejected() {
        assert!(parse_request(r#"{"op":"tune"}"#).is_err());
        assert!(parse_request(r#"{"op":"tune","x":[[1,2]],"ys":"no"}"#).is_err());
        assert!(parse_request(r#"{"op":"tune","x":[[1],[2,3]],"ys":[[1,2]]}"#).is_err());
        assert!(
            parse_request(r#"{"op":"tune","x":[[1]],"ys":[[1]],"kernel":"bogus"}"#).is_err()
        );
    }
}

//! Wire protocol: newline-delimited JSON over TCP.
//!
//! The complete request/response reference (every op, field defaults,
//! and error shapes) lives in `docs/PROTOCOL.md`.  Summary:
//!
//! ```json
//! {"op": "ping"}
//! {"op": "info"}
//! {"op": "stats"}
//! {"op": "tune", "x": [[...], ...], "ys": [[...], ...],
//!  "kernel": "rbf:2.0", "backend": "rust"|"pjrt",
//!  "strategy": "pso"|"grid", "particles": 64, "iterations": 25,
//!  "grid": 17, "seed": 42, "threads": 0}
//! {"op": "tune", "session_id": 1, "ys": [[...], ...], ...}
//! {"op": "tune_theta", "session_id": 1, "ys": [[...], ...],
//!  "theta_min": 0.05, "theta_max": 50.0, "outer": 20,
//!  "search": "wavefront"|"golden", "wavefront": 8, "inner_grid": 9,
//!  "objective": "paper"|"evidence", "threads": 0}
//! {"op": "create_session", "x": [[...], ...], "kernel": "rbf:2.0"}
//! {"op": "update_session", "session_id": 1, "x_new": [[...], ...]}
//! {"op": "drop_session", "session_id": 1}
//! {"op": "evaluate", "session_id": 1, "y": [...],
//!  "sigma2": 0.1, "lambda2": 1.0, "objective": "paper"|"evidence"}
//! {"op": "predict", "session_id": 1, "y": [...], "xnew": [[...], ...],
//!  "sigma2": 0.1, "lambda2": 1.0}
//! {"op": "shutdown"}
//! ```
//! Responses: `{"ok": true, ...}` or `{"ok": false, "error": "..."}`.

use crate::coordinator::session::{
    SessionTuneRequest, StoreStats, ThetaTuneRequest, ThetaTuneResult,
};
use crate::coordinator::{Backend, GlobalStrategy, ObjectiveKind, TuneRequest, TuneResult};
use crate::kernelfn::{self, Kernel, MAX_THETA_DIMS};
use crate::linalg::Matrix;
use crate::optim::{RefineKind, ThetaSearch};
use crate::spectral::{Evaluation, HyperParams};
use crate::util::json::{self, Json};

/// Parsed request operations.
#[derive(Debug)]
pub enum Request {
    Ping,
    Info,
    /// Session-cache statistics (`session::StoreStats` + worker count).
    Stats,
    /// Inline tune: the dataset rides in the request (and is implicitly
    /// fingerprinted into the session cache on the rust path).
    Tune(Box<TuneRequest>),
    /// Session tune: O(N) against an existing session's eigenbasis.
    TuneSession(Box<SessionTuneRequest>),
    /// Theta-plane tune: sweep the session's kernel family over a theta
    /// range through the eigen-family cache (DESIGN.md §9).
    TuneTheta(Box<ThetaTuneRequest>),
    CreateSession { x: Matrix, kernel: Kernel, threads: usize },
    /// Streaming append: grow a session's dataset by rank-one spectral
    /// refresh (full refit past the fallback policy) — DESIGN.md §8.
    UpdateSession { session_id: u64, x_new: Matrix, threads: usize },
    DropSession { session_id: u64 },
    Evaluate(Box<EvaluateRequest>),
    Predict(Box<PredictRequest>),
    Shutdown,
}

/// Score/Jacobian/Hessian at one hyperparameter point against a session.
#[derive(Clone, Debug)]
pub struct EvaluateRequest {
    pub session_id: u64,
    pub y: Vec<f64>,
    pub hp: HyperParams,
    pub objective: ObjectiveKind,
}

/// Posterior predictive mean + variance at new inputs against a session.
#[derive(Clone, Debug)]
pub struct PredictRequest {
    pub session_id: u64,
    pub y: Vec<f64>,
    pub xnew: Matrix,
    pub hp: HyperParams,
}

fn parse_matrix(v: &Json, field: &str) -> Result<Matrix, String> {
    let rows = v.as_arr().ok_or_else(|| format!("{field} must be an array of rows"))?;
    if rows.is_empty() {
        return Err(format!("{field} is empty"));
    }
    let p = rows[0].as_arr().ok_or_else(|| format!("{field} rows must be arrays"))?.len();
    let mut data = Vec::with_capacity(rows.len() * p);
    for (i, r) in rows.iter().enumerate() {
        let r = r.as_arr().ok_or_else(|| format!("{field} rows must be arrays"))?;
        if r.len() != p {
            return Err(format!("{field} row {i} has {} cols, expected {p}", r.len()));
        }
        for c in r {
            data.push(c.as_f64().ok_or_else(|| format!("{field} entries must be numbers"))?);
        }
    }
    Ok(Matrix::from_vec(rows.len(), p, data))
}

fn parse_vec(v: &Json) -> Result<Vec<f64>, String> {
    v.as_arr()
        .ok_or("expected array")?
        .iter()
        .map(|x| x.as_f64().ok_or("expected number".to_string()))
        .collect()
}

fn parse_ys(v: &Json) -> Result<Vec<Vec<f64>>, String> {
    v.get("ys")
        .ok_or("missing ys")?
        .as_arr()
        .ok_or("ys must be an array")?
        .iter()
        .map(parse_vec)
        .collect()
}

fn parse_session_id(v: &Json) -> Result<u64, String> {
    match v.get("session_id").and_then(Json::as_f64) {
        // reject rather than truncate: a fractional or negative id would
        // silently alias a *different* live session (ids are small
        // sequential integers)
        Some(x) if x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64 => Ok(x as u64),
        Some(x) => Err(format!("session_id must be a non-negative integer, got {x}")),
        None => Err("missing session_id".to_string()),
    }
}

fn parse_objective(v: &Json) -> ObjectiveKind {
    match v.get("objective").and_then(Json::as_str) {
        Some("evidence") => ObjectiveKind::Evidence,
        _ => ObjectiveKind::PaperScore,
    }
}

fn parse_strategy(v: &Json) -> GlobalStrategy {
    match v.get("strategy").and_then(Json::as_str) {
        Some("grid") => GlobalStrategy::Grid {
            points_per_axis: v.get("grid").and_then(Json::as_usize).unwrap_or(17),
        },
        _ => GlobalStrategy::Pso {
            particles: v.get("particles").and_then(Json::as_usize).unwrap_or(64),
            iterations: v.get("iterations").and_then(Json::as_usize).unwrap_or(25),
        },
    }
}

fn parse_hp(v: &Json) -> Result<HyperParams, String> {
    let sigma2 = v.get("sigma2").and_then(Json::as_f64).ok_or("missing sigma2")?;
    let lambda2 = v.get("lambda2").and_then(Json::as_f64).ok_or("missing lambda2")?;
    let hp = HyperParams::new(sigma2, lambda2);
    if !hp.feasible() {
        return Err("sigma2 and lambda2 must be positive and finite".into());
    }
    Ok(hp)
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = json::parse(line)?;
    match v.get("op").and_then(Json::as_str) {
        Some("ping") => Ok(Request::Ping),
        Some("info") => Ok(Request::Info),
        Some("stats") => Ok(Request::Stats),
        Some("shutdown") => Ok(Request::Shutdown),
        Some("tune") if v.get("session_id").is_some() => {
            let mut req = SessionTuneRequest::new(parse_session_id(&v)?, parse_ys(&v)?);
            req.objective = parse_objective(&v);
            req.strategy = parse_strategy(&v);
            if let Some(seed) = v.get("seed").and_then(Json::as_f64) {
                req.seed = seed as u64;
            }
            if let Some(threads) = v.get("threads").and_then(Json::as_usize) {
                req.threads = threads;
            }
            Ok(Request::TuneSession(Box::new(req)))
        }
        Some("tune_theta") => {
            let mut req = ThetaTuneRequest::new(parse_session_id(&v)?, parse_ys(&v)?);
            req.objective = parse_objective(&v);
            // `theta_min`/`theta_max` accept a number (scalar families,
            // the historical form) or equal-length arrays (one range per
            // theta-vector component of an ARD family).  Mixing forms is
            // an error — a half-array request is a client bug, not a
            // broadcast.
            let arr_form = matches!(v.get("theta_min"), Some(Json::Arr(_)))
                || matches!(v.get("theta_max"), Some(Json::Arr(_)));
            if arr_form {
                let comps = |field: &str| -> Result<Vec<f64>, String> {
                    let xs = v.get(field).and_then(Json::as_arr).ok_or_else(|| {
                        "theta_min and theta_max must both be numbers or both arrays".to_string()
                    })?;
                    xs.iter()
                        .map(|x| match x.as_f64() {
                            Some(t) if t.is_finite() && t > 0.0 => Ok(t),
                            _ => Err(format!("{field} must be positive finite numbers")),
                        })
                        .collect()
                };
                let lo = comps("theta_min")?;
                let hi = comps("theta_max")?;
                if lo.len() != hi.len() || lo.is_empty() || lo.len() > MAX_THETA_DIMS {
                    return Err(format!(
                        "theta_min and theta_max must be equal-length arrays of \
                         1..={MAX_THETA_DIMS} components"
                    ));
                }
                for (&l, &h) in lo.iter().zip(&hi) {
                    if l >= h {
                        return Err(format!("theta range must be increasing, got ({l}, {h})"));
                    }
                }
                req.theta_ranges = lo.into_iter().zip(hi).collect();
            } else {
                let bound = |field: &str, default: f64| -> Result<f64, String> {
                    match v.get(field) {
                        None => Ok(default),
                        Some(x) => match x.as_f64() {
                            Some(t) if t.is_finite() && t > 0.0 => Ok(t),
                            _ => Err(format!("{field} must be a positive finite number")),
                        },
                    }
                };
                let lo = bound("theta_min", req.theta_range.0)?;
                let hi = bound("theta_max", req.theta_range.1)?;
                if lo >= hi {
                    return Err(format!("theta range must be increasing, got ({lo}, {hi})"));
                }
                req.theta_range = (lo, hi);
            }
            req.search = match v.get("search").and_then(Json::as_str) {
                None | Some("wavefront") => {
                    let width = match v.get("wavefront") {
                        None => 0,
                        // strict like the sibling fields: a typo must not
                        // silently select a different candidate set
                        Some(w) => match w.as_f64() {
                            Some(x) if x >= 0.0 && x.fract() == 0.0 => x as usize,
                            _ => return Err("wavefront must be a non-negative integer".to_string()),
                        },
                    };
                    ThetaSearch::Wavefront { width }
                }
                Some("golden") => ThetaSearch::Golden,
                Some("nelder-mead") => ThetaSearch::NelderMead,
                Some("pso") => ThetaSearch::Pso,
                Some(other) => {
                    return Err(format!(
                        "unknown search '{other}' (golden|wavefront|nelder-mead|pso)"
                    ))
                }
            };
            req.refine = match v.get("refine") {
                None => RefineKind::Newton,
                Some(r) => match r.as_str() {
                    Some("newton") => RefineKind::Newton,
                    Some("none") => RefineKind::None,
                    Some(other) => return Err(format!("unknown refine '{other}' (newton|none)")),
                    None => return Err("refine must be a string (newton|none)".to_string()),
                },
            };
            if let Some(outer) = v.get("outer") {
                match outer.as_usize() {
                    Some(o) if o >= 2 => req.outer_iters = o,
                    _ => return Err("outer must be an integer >= 2".to_string()),
                }
            }
            if let Some(grid) = v.get("inner_grid") {
                match grid.as_usize() {
                    Some(g) if g >= 2 => req.inner_grid = g,
                    _ => return Err("inner_grid must be an integer >= 2".to_string()),
                }
            }
            if let Some(threads) = v.get("threads").and_then(Json::as_usize) {
                req.threads = threads;
            }
            Ok(Request::TuneTheta(Box::new(req)))
        }
        Some("tune") => {
            let x = parse_matrix(v.get("x").ok_or("missing x")?, "x")?;
            let ys = parse_ys(&v)?;
            let kernel =
                kernelfn::parse_kernel(v.get("kernel").and_then(Json::as_str).unwrap_or("rbf:1.0"))?;
            let mut req = TuneRequest::new(x, ys, kernel);
            req.backend = match v.get("backend").and_then(Json::as_str) {
                Some("pjrt") => Backend::Pjrt,
                _ => Backend::Rust,
            };
            req.objective = parse_objective(&v);
            req.strategy = parse_strategy(&v);
            if let Some(seed) = v.get("seed").and_then(Json::as_f64) {
                req.seed = seed as u64;
            }
            if let Some(threads) = v.get("threads").and_then(Json::as_usize) {
                req.threads = threads;
            }
            Ok(Request::Tune(Box::new(req)))
        }
        Some("create_session") => {
            let x = parse_matrix(v.get("x").ok_or("missing x")?, "x")?;
            let kernel =
                kernelfn::parse_kernel(v.get("kernel").and_then(Json::as_str).unwrap_or("rbf:1.0"))?;
            let threads = v.get("threads").and_then(Json::as_usize).unwrap_or(0);
            Ok(Request::CreateSession { x, kernel, threads })
        }
        Some("update_session") => {
            let x_new = parse_matrix(v.get("x_new").ok_or("missing x_new")?, "x_new")?;
            let threads = v.get("threads").and_then(Json::as_usize).unwrap_or(0);
            Ok(Request::UpdateSession { session_id: parse_session_id(&v)?, x_new, threads })
        }
        Some("drop_session") => Ok(Request::DropSession { session_id: parse_session_id(&v)? }),
        Some("evaluate") => {
            let req = EvaluateRequest {
                session_id: parse_session_id(&v)?,
                y: parse_vec(v.get("y").ok_or("missing y")?)?,
                hp: parse_hp(&v)?,
                objective: parse_objective(&v),
            };
            Ok(Request::Evaluate(Box::new(req)))
        }
        Some("predict") => {
            let req = PredictRequest {
                session_id: parse_session_id(&v)?,
                y: parse_vec(v.get("y").ok_or("missing y")?)?,
                xnew: parse_matrix(v.get("xnew").ok_or("missing xnew")?, "xnew")?,
                hp: parse_hp(&v)?,
            };
            Ok(Request::Predict(Box::new(req)))
        }
        other => Err(format!("unknown op {other:?}")),
    }
}

/// The shared body of a tune response (inline and session variants).
fn tune_response_fields(res: &TuneResult) -> Vec<(&'static str, Json)> {
    let outputs: Vec<Json> = res
        .outputs
        .iter()
        .map(|o| {
            Json::obj(vec![
                ("sigma2", Json::Num(o.hp.sigma2)),
                ("lambda2", Json::Num(o.hp.lambda2)),
                ("score", Json::Num(o.score)),
                ("global_evals", Json::Num(o.global_evals as f64)),
                ("newton_evals", Json::Num(o.newton_evals as f64)),
                ("converged", Json::Bool(o.converged)),
            ])
        })
        .collect();
    vec![
        ("ok", Json::Bool(true)),
        ("outputs", Json::Arr(outputs)),
        ("eigen_cached", Json::Bool(res.eigen_cached)),
        ("gram_seconds", Json::Num(res.gram_seconds)),
        ("eigen_seconds", Json::Num(res.eigen_seconds)),
        ("tune_seconds", Json::Num(res.tune_seconds)),
        (
            "backend",
            Json::str(match res.backend {
                Backend::Rust => "rust",
                Backend::Pjrt => "pjrt",
            }),
        ),
    ]
}

/// Serialize a tune result.
pub fn tune_response(res: &TuneResult) -> String {
    Json::obj(tune_response_fields(res)).to_string()
}

/// Serialize a session-tune result (same shape plus `session_id`).
pub fn session_tune_response(res: &TuneResult, session_id: u64) -> String {
    let mut fields = tune_response_fields(res);
    fields.push(("session_id", Json::Num(session_id as f64)));
    Json::obj(fields).to_string()
}

/// Serialize a `tune_theta` result.  Numbers use shortest-round-trip
/// float formatting and the `outputs` array carries only
/// **run-independent** values (result fields plus the deterministic
/// probe counts), so a warm repeat's `outputs` is byte-identical to the
/// cold run's — an invariant the bench and wire tests assert on the
/// serialized string.  The run-dependent cost counters (`outer_evals`
/// per output, `setups_built`, `tune_seconds`) ride at the top level.
pub fn theta_tune_response(res: &ThetaTuneResult, session_id: u64) -> String {
    let outputs: Vec<Json> = res
        .outputs
        .iter()
        .map(|o| {
            // scalar families keep the historical Num form; ARD
            // families report the full component array
            let theta = if o.theta.len() == 1 {
                Json::Num(o.theta.get(0))
            } else {
                Json::arr_f64(o.theta.as_slice())
            };
            Json::obj(vec![
                ("theta", theta),
                ("sigma2", Json::Num(o.hp.sigma2)),
                ("lambda2", Json::Num(o.hp.lambda2)),
                ("score", Json::Num(o.score)),
                ("distinct_thetas", Json::Num(o.distinct_thetas as f64)),
                ("inner_evals", Json::Num(o.inner_evals as f64)),
                ("newton_iters", Json::Num(o.newton_iters as f64)),
                ("newton_evals", Json::Num(o.newton_evals as f64)),
            ])
        })
        .collect();
    let outer_evals: Vec<Json> =
        res.outputs.iter().map(|o| Json::Num(o.outer_evals as f64)).collect();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("session_id", Json::Num(session_id as f64)),
        ("outputs", Json::Arr(outputs)),
        ("outer_evals", Json::Arr(outer_evals)),
        ("setups_built", Json::Num(res.setups_built as f64)),
        ("tune_seconds", Json::Num(res.tune_seconds)),
    ])
    .to_string()
}

/// Serialize a `create_session` result.
pub fn create_session_response(
    sess: &crate::coordinator::session::Session,
    cached: bool,
) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("session_id", Json::Num(sess.id as f64)),
        ("n", Json::Num(sess.gp.n() as f64)),
        ("p", Json::Num(sess.gp.x().cols() as f64)),
        ("cached", Json::Bool(cached)),
        ("bytes", Json::Num(sess.bytes as f64)),
        ("gram_seconds", Json::Num(if cached { 0.0 } else { sess.gram_seconds })),
        ("eigen_seconds", Json::Num(if cached { 0.0 } else { sess.eigen_seconds })),
    ])
    .to_string()
}

/// Serialize an `update_session` result.  `incremental` says whether the
/// append was served by rank-one corrections (`refit_reason` is present
/// exactly when it was not); `updates_applied` is the session's rank-one
/// correction count since its last full fit.
pub fn update_session_response(res: &crate::coordinator::session::UpdateResult) -> String {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("session_id", Json::Num(res.sess.id as f64)),
        ("n", Json::Num(res.sess.gp.n() as f64)),
        ("p", Json::Num(res.sess.gp.x().cols() as f64)),
        ("bytes", Json::Num(res.sess.bytes as f64)),
        ("incremental", Json::Bool(res.incremental)),
        ("updates_applied", Json::Num(res.sess.gp.updates() as f64)),
        ("update_seconds", Json::Num(res.update_seconds)),
    ];
    if let Some(reason) = res.refit_reason {
        fields.push(("refit_reason", Json::str(reason)));
    }
    Json::obj(fields).to_string()
}

/// Serialize a `drop_session` result.
pub fn drop_session_response(dropped: bool) -> String {
    Json::obj(vec![("ok", Json::Bool(true)), ("dropped", Json::Bool(dropped))]).to_string()
}

/// Serialize the session-cache statistics (`stats` op).
pub fn stats_response(s: &StoreStats, workers: usize) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("sessions", Json::Num(s.sessions as f64)),
        ("bytes", Json::Num(s.bytes as f64)),
        ("max_sessions", Json::Num(s.max_sessions as f64)),
        ("max_bytes", Json::Num(s.max_bytes as f64)),
        ("hits", Json::Num(s.hits as f64)),
        ("misses", Json::Num(s.misses as f64)),
        ("evictions", Json::Num(s.evictions as f64)),
        ("setups", Json::Num(s.setups as f64)),
        ("updates", Json::Num(s.updates as f64)),
        ("theta_entries", Json::Num(s.theta_entries as f64)),
        ("theta_hits", Json::Num(s.theta_hits as f64)),
        ("theta_misses", Json::Num(s.theta_misses as f64)),
        ("theta_evictions", Json::Num(s.theta_evictions as f64)),
        ("sheds", Json::Num(s.faults.sheds as f64)),
        ("panics", Json::Num(s.faults.panics as f64)),
        ("worker_respawns", Json::Num(s.faults.worker_respawns as f64)),
        ("jitter_retries", Json::Num(s.faults.jitter_retries as f64)),
        ("fallback_refits", Json::Num(s.faults.fallback_refits as f64)),
        ("deadline_expired", Json::Num(s.faults.deadline_expired as f64)),
        ("workers", Json::Num(workers as f64)),
    ])
    .to_string()
}

/// Serialize an `evaluate` result (eq. 19/26-28 closed forms).
pub fn evaluate_response(ev: &Evaluation, session_id: u64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("session_id", Json::Num(session_id as f64)),
        ("score", Json::Num(ev.score)),
        ("jac", Json::arr_f64(&ev.jac)),
        (
            "hess",
            Json::Arr(vec![Json::arr_f64(&ev.hess[0]), Json::arr_f64(&ev.hess[1])]),
        ),
    ])
    .to_string()
}

/// Serialize a `predict` result.
pub fn predict_response(mean: &[f64], var: &[f64], session_id: u64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("session_id", Json::Num(session_id as f64)),
        ("mean", Json::arr_f64(mean)),
        ("var", Json::arr_f64(var)),
    ])
    .to_string()
}

pub fn error_response(msg: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))]).to_string()
}

/// Admission-control shed: the job queue is past `--max-queue`, so the
/// server refuses the work instead of queueing unbounded O(N^3).  The
/// `retry_after_ms` hint tells well-behaved clients (see
/// [`crate::coordinator::client::Client`]) when to come back.
pub fn overloaded_response(retry_after_ms: u64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str("overloaded")),
        ("retry_after_ms", Json::Num(retry_after_ms as f64)),
    ])
    .to_string()
}

/// Per-request deadline expiry: the job did not answer within
/// `--request-timeout`.  The connection stays usable; the abandoned
/// job's eventual reply is discarded by the server.
pub fn deadline_response(timeout_ms: u64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str("deadline")),
        ("timeout_ms", Json::Num(timeout_ms as f64)),
    ])
    .to_string()
}

pub fn pong_response() -> String {
    Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]).to_string()
}

/// The CLI encoding of a kernel (`rbf:2.0`, `poly:3`, ... — inverse of
/// `kernelfn::parse_kernel`).
pub fn kernel_string(kernel: Kernel) -> String {
    match kernel {
        Kernel::Rbf { xi2 } => format!("rbf:{xi2}"),
        Kernel::RbfArd { xi2 } => {
            let comps: Vec<String> = xi2.as_slice().iter().map(f64::to_string).collect();
            format!("rbf-ard:{}", comps.join(","))
        }
        Kernel::Polynomial { degree } => format!("poly:{degree}"),
        Kernel::Linear => "linear".to_string(),
        Kernel::Matern32 { ell } => format!("matern32:{ell}"),
        Kernel::Matern52 { ell } => format!("matern52:{ell}"),
    }
}

fn matrix_json(x: &Matrix) -> Json {
    Json::Arr((0..x.rows()).map(|i| Json::arr_f64(x.row(i))).collect())
}

fn strategy_fields(strategy: GlobalStrategy, fields: &mut Vec<(&'static str, Json)>) {
    match strategy {
        GlobalStrategy::Grid { points_per_axis } => {
            fields.push(("strategy", Json::str("grid")));
            fields.push(("grid", Json::Num(points_per_axis as f64)));
        }
        GlobalStrategy::Pso { particles, iterations } => {
            fields.push(("strategy", Json::str("pso")));
            fields.push(("particles", Json::Num(particles as f64)));
            fields.push(("iterations", Json::Num(iterations as f64)));
        }
    }
}

fn objective_str(objective: ObjectiveKind) -> &'static str {
    match objective {
        ObjectiveKind::PaperScore => "paper",
        ObjectiveKind::Evidence => "evidence",
    }
}

/// Serialize a tune request (client side).
pub fn tune_request_json(req: &TuneRequest) -> String {
    let ys: Vec<Json> = req.ys.iter().map(|y| Json::arr_f64(y)).collect();
    let mut fields = vec![
        ("op", Json::str("tune")),
        ("x", matrix_json(&req.x)),
        ("ys", Json::Arr(ys)),
        ("kernel", Json::str(&kernel_string(req.kernel))),
        ("objective", Json::str(objective_str(req.objective))),
        (
            "backend",
            Json::str(match req.backend {
                Backend::Rust => "rust",
                Backend::Pjrt => "pjrt",
            }),
        ),
        ("seed", Json::Num(req.seed as f64)),
        ("threads", Json::Num(req.threads as f64)),
    ];
    strategy_fields(req.strategy, &mut fields);
    Json::obj(fields).to_string()
}

/// Serialize a session-tune request (client side).
pub fn session_tune_json(req: &SessionTuneRequest) -> String {
    let ys: Vec<Json> = req.ys.iter().map(|y| Json::arr_f64(y)).collect();
    let mut fields = vec![
        ("op", Json::str("tune")),
        ("session_id", Json::Num(req.session_id as f64)),
        ("ys", Json::Arr(ys)),
        ("objective", Json::str(objective_str(req.objective))),
        ("seed", Json::Num(req.seed as f64)),
        ("threads", Json::Num(req.threads as f64)),
    ];
    strategy_fields(req.strategy, &mut fields);
    Json::obj(fields).to_string()
}

/// Serialize a `tune_theta` request (client side).
pub fn theta_tune_json(req: &ThetaTuneRequest) -> String {
    let ys: Vec<Json> = req.ys.iter().map(|y| Json::arr_f64(y)).collect();
    let (theta_min, theta_max) = if req.theta_ranges.is_empty() {
        (Json::Num(req.theta_range.0), Json::Num(req.theta_range.1))
    } else {
        let lo: Vec<f64> = req.theta_ranges.iter().map(|r| r.0).collect();
        let hi: Vec<f64> = req.theta_ranges.iter().map(|r| r.1).collect();
        (Json::arr_f64(&lo), Json::arr_f64(&hi))
    };
    let mut fields = vec![
        ("op", Json::str("tune_theta")),
        ("session_id", Json::Num(req.session_id as f64)),
        ("ys", Json::Arr(ys)),
        ("theta_min", theta_min),
        ("theta_max", theta_max),
        ("outer", Json::Num(req.outer_iters as f64)),
        ("inner_grid", Json::Num(req.inner_grid as f64)),
        ("objective", Json::str(objective_str(req.objective))),
        ("threads", Json::Num(req.threads as f64)),
    ];
    match req.search {
        ThetaSearch::Golden => fields.push(("search", Json::str("golden"))),
        ThetaSearch::Wavefront { width } => {
            fields.push(("search", Json::str("wavefront")));
            fields.push(("wavefront", Json::Num(width as f64)));
        }
        ThetaSearch::NelderMead => fields.push(("search", Json::str("nelder-mead"))),
        ThetaSearch::Pso => fields.push(("search", Json::str("pso"))),
    }
    match req.refine {
        RefineKind::Newton => {}
        RefineKind::None => fields.push(("refine", Json::str("none"))),
    }
    Json::obj(fields).to_string()
}

/// Serialize a `create_session` request (client side).
pub fn create_session_json(x: &Matrix, kernel: Kernel, threads: usize) -> String {
    Json::obj(vec![
        ("op", Json::str("create_session")),
        ("x", matrix_json(x)),
        ("kernel", Json::str(&kernel_string(kernel))),
        ("threads", Json::Num(threads as f64)),
    ])
    .to_string()
}

/// Serialize an `update_session` request (client side).
pub fn update_session_json(session_id: u64, x_new: &Matrix, threads: usize) -> String {
    Json::obj(vec![
        ("op", Json::str("update_session")),
        ("session_id", Json::Num(session_id as f64)),
        ("x_new", matrix_json(x_new)),
        ("threads", Json::Num(threads as f64)),
    ])
    .to_string()
}

/// Serialize a `drop_session` request (client side).
pub fn drop_session_json(session_id: u64) -> String {
    Json::obj(vec![
        ("op", Json::str("drop_session")),
        ("session_id", Json::Num(session_id as f64)),
    ])
    .to_string()
}

/// Serialize an `evaluate` request (client side).
pub fn evaluate_json(req: &EvaluateRequest) -> String {
    Json::obj(vec![
        ("op", Json::str("evaluate")),
        ("session_id", Json::Num(req.session_id as f64)),
        ("y", Json::arr_f64(&req.y)),
        ("sigma2", Json::Num(req.hp.sigma2)),
        ("lambda2", Json::Num(req.hp.lambda2)),
        ("objective", Json::str(objective_str(req.objective))),
    ])
    .to_string()
}

/// Serialize a `predict` request (client side).
pub fn predict_json(req: &PredictRequest) -> String {
    Json::obj(vec![
        ("op", Json::str("predict")),
        ("session_id", Json::Num(req.session_id as f64)),
        ("y", Json::arr_f64(&req.y)),
        ("xnew", matrix_json(&req.xnew)),
        ("sigma2", Json::Num(req.hp.sigma2)),
        ("lambda2", Json::Num(req.hp.lambda2)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::OutputResult;
    use crate::spectral::HyperParams;

    #[test]
    fn ping_and_info_parse() {
        assert!(matches!(parse_request(r#"{"op":"ping"}"#).unwrap(), Request::Ping));
        assert!(matches!(parse_request(r#"{"op":"info"}"#).unwrap(), Request::Info));
        assert!(matches!(parse_request(r#"{"op":"shutdown"}"#).unwrap(), Request::Shutdown));
        assert!(parse_request(r#"{"op":"nope"}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn tune_request_roundtrip() {
        let x = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut req = TuneRequest::new(x, vec![vec![0.5, -0.5]], crate::kernelfn::Kernel::Rbf { xi2: 2.0 });
        req.strategy = GlobalStrategy::Grid { points_per_axis: 9 };
        req.backend = Backend::Rust;
        let line = tune_request_json(&req);
        match parse_request(&line).unwrap() {
            Request::Tune(r) => {
                assert_eq!(r.x.rows(), 2);
                assert_eq!(r.ys[0], vec![0.5, -0.5]);
                assert_eq!(r.kernel, crate::kernelfn::Kernel::Rbf { xi2: 2.0 });
                assert_eq!(r.strategy, GlobalStrategy::Grid { points_per_axis: 9 });
            }
            other => panic!("expected tune, got {other:?}"),
        }
    }

    #[test]
    fn tune_response_shape() {
        let res = TuneResult {
            outputs: vec![OutputResult {
                hp: HyperParams::new(0.5, 2.0),
                score: -12.5,
                global_evals: 100,
                newton_evals: 7,
                converged: true,
            }],
            eigen_cached: true,
            gram_seconds: 0.0,
            eigen_seconds: 0.1,
            tune_seconds: 0.01,
            backend: Backend::Rust,
        };
        let text = tune_response(&res);
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        let outs = v.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs[0].get("sigma2").unwrap().as_f64(), Some(0.5));
        assert_eq!(outs[0].get("converged").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn malformed_tune_requests_rejected() {
        assert!(parse_request(r#"{"op":"tune"}"#).is_err());
        assert!(parse_request(r#"{"op":"tune","x":[[1,2]],"ys":"no"}"#).is_err());
        assert!(parse_request(r#"{"op":"tune","x":[[1],[2,3]],"ys":[[1,2]]}"#).is_err());
        assert!(
            parse_request(r#"{"op":"tune","x":[[1]],"ys":[[1]],"kernel":"bogus"}"#).is_err()
        );
    }

    #[test]
    fn session_tune_roundtrip() {
        let mut req = SessionTuneRequest::new(7, vec![vec![0.5, -0.5]]);
        req.strategy = GlobalStrategy::Grid { points_per_axis: 9 };
        req.objective = ObjectiveKind::Evidence;
        req.seed = 5;
        req.threads = 2;
        match parse_request(&session_tune_json(&req)).unwrap() {
            Request::TuneSession(r) => {
                assert_eq!(r.session_id, 7);
                assert_eq!(r.ys[0], vec![0.5, -0.5]);
                assert_eq!(r.strategy, GlobalStrategy::Grid { points_per_axis: 9 });
                assert_eq!(r.objective, ObjectiveKind::Evidence);
                assert_eq!(r.seed, 5);
                assert_eq!(r.threads, 2);
            }
            other => panic!("expected session tune, got {other:?}"),
        }
    }

    #[test]
    fn create_drop_stats_roundtrip() {
        let x = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let line = create_session_json(&x, Kernel::Rbf { xi2: 2.0 }, 3);
        match parse_request(&line).unwrap() {
            Request::CreateSession { x, kernel, threads } => {
                assert_eq!(x.rows(), 2);
                assert_eq!(kernel, Kernel::Rbf { xi2: 2.0 });
                assert_eq!(threads, 3);
            }
            other => panic!("expected create_session, got {other:?}"),
        }
        match parse_request(&drop_session_json(4)).unwrap() {
            Request::DropSession { session_id } => assert_eq!(session_id, 4),
            other => panic!("expected drop_session, got {other:?}"),
        }
        assert!(matches!(parse_request(r#"{"op":"stats"}"#).unwrap(), Request::Stats));
        assert!(parse_request(r#"{"op":"drop_session"}"#).is_err());
        assert!(parse_request(r#"{"op":"create_session"}"#).is_err());
    }

    #[test]
    fn update_session_roundtrip() {
        let x_new = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        match parse_request(&update_session_json(9, &x_new, 2)).unwrap() {
            Request::UpdateSession { session_id, x_new, threads } => {
                assert_eq!(session_id, 9);
                assert_eq!(x_new.rows(), 2);
                assert_eq!(x_new.cols(), 3);
                assert_eq!(x_new[(1, 2)], 6.0);
                assert_eq!(threads, 2);
            }
            other => panic!("expected update_session, got {other:?}"),
        }
        // missing pieces are rejected
        assert!(parse_request(r#"{"op":"update_session","session_id":1}"#).is_err());
        assert!(parse_request(r#"{"op":"update_session","x_new":[[1]]}"#).is_err());
        assert!(parse_request(r#"{"op":"update_session","session_id":1,"x_new":[]}"#).is_err());
        assert!(
            parse_request(r#"{"op":"update_session","session_id":1.5,"x_new":[[1]]}"#).is_err()
        );
    }

    #[test]
    fn stats_response_includes_updates_counter() {
        let s = StoreStats { updates: 7, ..Default::default() };
        let v = json::parse(&stats_response(&s, 2)).unwrap();
        assert_eq!(v.get("updates").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn stats_response_includes_theta_counters() {
        let s = StoreStats {
            theta_entries: 3,
            theta_hits: 40,
            theta_misses: 5,
            theta_evictions: 2,
            ..Default::default()
        };
        let v = json::parse(&stats_response(&s, 1)).unwrap();
        assert_eq!(v.get("theta_entries").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("theta_hits").unwrap().as_usize(), Some(40));
        assert_eq!(v.get("theta_misses").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("theta_evictions").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn stats_response_includes_fault_counters() {
        let s = StoreStats {
            faults: crate::faults::FaultSnapshot {
                sheds: 4,
                panics: 1,
                worker_respawns: 1,
                jitter_retries: 3,
                fallback_refits: 2,
                deadline_expired: 5,
            },
            ..Default::default()
        };
        let v = json::parse(&stats_response(&s, 1)).unwrap();
        assert_eq!(v.get("sheds").unwrap().as_usize(), Some(4));
        assert_eq!(v.get("panics").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("worker_respawns").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("jitter_retries").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("fallback_refits").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("deadline_expired").unwrap().as_usize(), Some(5));
    }

    #[test]
    fn overloaded_and_deadline_shapes() {
        let v = json::parse(&overloaded_response(250)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("error").unwrap().as_str(), Some("overloaded"));
        assert_eq!(v.get("retry_after_ms").unwrap().as_usize(), Some(250));

        let v = json::parse(&deadline_response(30_000)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("error").unwrap().as_str(), Some("deadline"));
        assert_eq!(v.get("timeout_ms").unwrap().as_usize(), Some(30_000));
    }

    #[test]
    fn tune_theta_roundtrip() {
        let mut req = ThetaTuneRequest::new(4, vec![vec![0.5, -0.5]]);
        req.theta_range = (0.05, 50.0);
        req.outer_iters = 16;
        req.search = ThetaSearch::Wavefront { width: 6 };
        req.inner_grid = 7;
        req.objective = ObjectiveKind::Evidence;
        req.threads = 2;
        match parse_request(&theta_tune_json(&req)).unwrap() {
            Request::TuneTheta(r) => {
                assert_eq!(r.session_id, 4);
                assert_eq!(r.ys[0], vec![0.5, -0.5]);
                assert_eq!(r.theta_range, (0.05, 50.0));
                assert_eq!(r.outer_iters, 16);
                assert_eq!(r.search, ThetaSearch::Wavefront { width: 6 });
                assert_eq!(r.inner_grid, 7);
                assert_eq!(r.objective, ObjectiveKind::Evidence);
                assert_eq!(r.threads, 2);
            }
            other => panic!("expected tune_theta, got {other:?}"),
        }
        // golden roundtrips too
        req.search = ThetaSearch::Golden;
        match parse_request(&theta_tune_json(&req)).unwrap() {
            Request::TuneTheta(r) => assert_eq!(r.search, ThetaSearch::Golden),
            other => panic!("expected tune_theta, got {other:?}"),
        }
        // ARD ranges and the refine flag roundtrip
        req.theta_ranges = vec![(0.1, 10.0), (0.2, 20.0)];
        req.refine = RefineKind::None;
        req.search = ThetaSearch::Pso;
        match parse_request(&theta_tune_json(&req)).unwrap() {
            Request::TuneTheta(r) => {
                assert_eq!(r.theta_ranges, req.theta_ranges);
                assert_eq!(r.refine, RefineKind::None);
                assert_eq!(r.search, ThetaSearch::Pso);
            }
            other => panic!("expected tune_theta, got {other:?}"),
        }
    }

    #[test]
    fn tune_theta_defaults_and_strict_validation() {
        // minimal request: defaults fill in
        match parse_request(r#"{"op":"tune_theta","session_id":1,"ys":[[1,2]]}"#).unwrap() {
            Request::TuneTheta(r) => {
                assert_eq!(r.theta_range, (1e-2, 1e2));
                assert_eq!(r.search, ThetaSearch::Wavefront { width: 0 });
                assert_eq!(r.outer_iters, 20);
            }
            other => panic!("expected tune_theta, got {other:?}"),
        }
        // error shapes: each malformed field is rejected, not defaulted
        for bad in [
            r#"{"op":"tune_theta","ys":[[1]]}"#,                                    // no session
            r#"{"op":"tune_theta","session_id":1}"#,                                // no ys
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"theta_min":-1}"#,      // negative
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"theta_min":"x"}"#,     // non-number
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"theta_min":9,"theta_max":1}"#,
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"search":"magic"}"#,    // unknown
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"outer":1}"#,           // < 2
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"inner_grid":1}"#,      // < 2
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"wavefront":"abc"}"#,   // non-number
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"wavefront":-3}"#,      // negative
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"wavefront":3.5}"#,     // fractional
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"refine":"magic"}"#,    // unknown
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"refine":3}"#,          // non-string
        ] {
            assert!(parse_request(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn tune_theta_array_ranges_and_refine() {
        // the ARD form: per-component ranges as equal-length arrays
        let line = r#"{"op":"tune_theta","session_id":1,"ys":[[1,2]],
            "theta_min":[0.1,0.2],"theta_max":[10,20],"refine":"none",
            "search":"nelder-mead"}"#;
        match parse_request(line).unwrap() {
            Request::TuneTheta(r) => {
                assert_eq!(r.theta_ranges, vec![(0.1, 10.0), (0.2, 20.0)]);
                assert_eq!(r.refine, RefineKind::None);
                assert_eq!(r.search, ThetaSearch::NelderMead);
            }
            other => panic!("expected tune_theta, got {other:?}"),
        }
        match parse_request(r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"search":"pso"}"#)
            .unwrap()
        {
            Request::TuneTheta(r) => {
                assert_eq!(r.search, ThetaSearch::Pso);
                assert_eq!(r.refine, RefineKind::Newton, "refine defaults to newton");
                assert!(r.theta_ranges.is_empty(), "scalar form by default");
            }
            other => panic!("expected tune_theta, got {other:?}"),
        }
        // array-form error shapes: half-array, length mismatch, bad
        // elements, non-increasing components, over-capacity
        for bad in [
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"theta_min":[0.1,0.2],"theta_max":10}"#,
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"theta_min":[0.1],"theta_max":[10,20]}"#,
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"theta_min":[],"theta_max":[]}"#,
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"theta_min":[-1,0.1],"theta_max":[10,20]}"#,
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"theta_min":["x",0.1],"theta_max":[10,20]}"#,
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],"theta_min":[5,0.1],"theta_max":[1,20]}"#,
            r#"{"op":"tune_theta","session_id":1,"ys":[[1]],
                "theta_min":[1,1,1,1,1,1,1,1,1],"theta_max":[2,2,2,2,2,2,2,2,2]}"#,
        ] {
            assert!(parse_request(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn theta_tune_response_shape() {
        use crate::coordinator::session::ThetaOutput;
        use crate::kernelfn::ThetaVec;
        let res = ThetaTuneResult {
            outputs: vec![ThetaOutput {
                theta: ThetaVec::scalar(2.5),
                hp: HyperParams::new(0.1, 1.5),
                score: -4.25,
                outer_evals: 14,
                distinct_thetas: 16,
                inner_evals: 900,
                newton_iters: 12,
                newton_evals: 30,
            }],
            setups_built: 14,
            tune_seconds: 0.5,
        };
        let v = json::parse(&theta_tune_response(&res, 7)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("session_id").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("setups_built").unwrap().as_usize(), Some(14));
        let outs = v.get("outputs").unwrap().as_arr().unwrap();
        // a 1-component theta keeps the historical scalar form
        assert_eq!(outs[0].get("theta").unwrap().as_f64(), Some(2.5));
        assert_eq!(outs[0].get("score").unwrap().as_f64(), Some(-4.25));
        assert_eq!(outs[0].get("distinct_thetas").unwrap().as_usize(), Some(16));
        // Newton counters are deterministic, so they live inside the
        // byte-comparable `outputs`
        assert_eq!(outs[0].get("newton_iters").unwrap().as_usize(), Some(12));
        assert_eq!(outs[0].get("newton_evals").unwrap().as_usize(), Some(30));
        // the run-dependent build counter lives OUTSIDE `outputs`, so
        // warm/cold `outputs` strings can be compared byte-for-byte
        assert!(outs[0].get("outer_evals").is_none());
        let builds = v.get("outer_evals").unwrap().as_arr().unwrap();
        assert_eq!(builds[0].as_usize(), Some(14));
    }

    #[test]
    fn theta_tune_response_ard_theta_is_an_array() {
        use crate::kernelfn::ThetaVec;
        let res = ThetaTuneResult {
            outputs: vec![ThetaOutput {
                theta: ThetaVec::from_slice(&[2.5, 0.5]).unwrap(),
                hp: HyperParams::new(0.1, 1.5),
                score: -1.0,
                outer_evals: 10,
                distinct_thetas: 12,
                inner_evals: 500,
                newton_iters: 9,
                newton_evals: 22,
            }],
            setups_built: 10,
            tune_seconds: 0.25,
        };
        let v = json::parse(&theta_tune_response(&res, 3)).unwrap();
        let outs = v.get("outputs").unwrap().as_arr().unwrap();
        let theta = outs[0].get("theta").unwrap().as_arr().unwrap();
        assert_eq!(theta.len(), 2);
        assert_eq!(theta[0].as_f64(), Some(2.5));
        assert_eq!(theta[1].as_f64(), Some(0.5));
    }

    #[test]
    fn non_integer_session_ids_rejected() {
        // truncation would silently alias a different live session
        assert!(parse_request(r#"{"op":"drop_session","session_id":1.9}"#).is_err());
        assert!(parse_request(r#"{"op":"drop_session","session_id":-1}"#).is_err());
        assert!(parse_request(r#"{"op":"drop_session","session_id":"1"}"#).is_err());
        assert!(parse_request(r#"{"op":"drop_session","session_id":2}"#).is_ok());
    }

    #[test]
    fn evaluate_predict_roundtrip() {
        let ereq = EvaluateRequest {
            session_id: 2,
            y: vec![1.0, -1.0],
            hp: HyperParams::new(0.1, 2.0),
            objective: ObjectiveKind::Evidence,
        };
        match parse_request(&evaluate_json(&ereq)).unwrap() {
            Request::Evaluate(r) => {
                assert_eq!(r.session_id, 2);
                assert_eq!(r.y, vec![1.0, -1.0]);
                assert_eq!(r.hp, HyperParams::new(0.1, 2.0));
                assert_eq!(r.objective, ObjectiveKind::Evidence);
            }
            other => panic!("expected evaluate, got {other:?}"),
        }
        let preq = PredictRequest {
            session_id: 3,
            y: vec![1.0, -1.0],
            xnew: Matrix::from_vec(1, 2, vec![0.5, 0.5]),
            hp: HyperParams::new(0.1, 2.0),
        };
        match parse_request(&predict_json(&preq)).unwrap() {
            Request::Predict(r) => {
                assert_eq!(r.session_id, 3);
                assert_eq!(r.xnew.rows(), 1);
            }
            other => panic!("expected predict, got {other:?}"),
        }
        // infeasible hyperparameters are rejected at parse time
        assert!(parse_request(
            r#"{"op":"evaluate","session_id":1,"y":[1],"sigma2":-1,"lambda2":1}"#
        )
        .is_err());
        // missing fields
        assert!(parse_request(r#"{"op":"evaluate","session_id":1,"y":[1],"sigma2":1}"#).is_err());
        assert!(parse_request(
            r#"{"op":"predict","session_id":1,"y":[1],"sigma2":1,"lambda2":1}"#
        )
        .is_err());
    }

    #[test]
    fn response_shapes_parse() {
        let v = json::parse(&stats_response(&StoreStats::default(), 4)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("workers").unwrap().as_usize(), Some(4));
        let v = json::parse(&drop_session_response(true)).unwrap();
        assert_eq!(v.get("dropped").unwrap().as_bool(), Some(true));
        let ev = Evaluation { score: 1.5, jac: [0.1, 0.2], hess: [[1.0, 2.0], [2.0, 3.0]] };
        let v = json::parse(&evaluate_response(&ev, 9)).unwrap();
        assert_eq!(v.get("score").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("session_id").unwrap().as_usize(), Some(9));
        let hess = v.get("hess").unwrap().as_arr().unwrap();
        assert_eq!(hess[1].as_arr().unwrap()[0].as_f64(), Some(2.0));
        let v = json::parse(&predict_response(&[1.0], &[0.5], 9)).unwrap();
        assert_eq!(v.get("mean").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(v.get("var").unwrap().as_arr().unwrap()[0].as_f64(), Some(0.5));
    }

    #[test]
    fn kernel_string_roundtrips_every_family() {
        use crate::kernelfn::ThetaVec;
        for k in [
            Kernel::Rbf { xi2: 1.5 },
            Kernel::RbfArd { xi2: ThetaVec::from_slice(&[0.7, 1.6, 2.5]).unwrap() },
            Kernel::Polynomial { degree: 3 },
            Kernel::Linear,
            Kernel::Matern32 { ell: 0.5 },
            Kernel::Matern52 { ell: 2.0 },
        ] {
            assert_eq!(kernelfn::parse_kernel(&kernel_string(k)).unwrap(), k);
        }
    }
}

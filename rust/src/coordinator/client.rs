//! Blocking client for the coordinator's newline-JSON protocol.
//!
//! One method per wire op (`docs/PROTOCOL.md`); the session workflow is
//! `create_session` -> repeated `tune_session` / `evaluate` / `predict`
//! (all O(N) on the server), with `update_session` appending streaming
//! observations in place -> optional `drop_session`.
//!
//! The client is resilience-aware (DESIGN.md §11): failures come back as
//! a typed [`ClientError`] distinguishing *shed* (`Overloaded`, carrying
//! the server's `retry_after_ms` hint), *timed out* (`Deadline`), and
//! *failed* (`Server` / `Protocol` / `Io`).  Shed requests are retried
//! automatically with capped exponential backoff plus deterministic
//! seeded jitter, honoring the server's hint ([`ClientOptions`]).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::coordinator::protocol::{self, EvaluateRequest, PredictRequest};
use crate::coordinator::session::{SessionTuneRequest, ThetaTuneRequest};
use crate::coordinator::TuneRequest;
use crate::kernelfn::Kernel;
use crate::linalg::Matrix;
use crate::util::json::{self, Json};

/// Typed client-side failure.  `Overloaded` and `Deadline` are the
/// server's structured degradation responses (PROTOCOL.md Conventions);
/// `Server` is any other `"ok": false`; `Protocol` means the response
/// was missing, truncated, or not the documented shape; `Io` is the
/// transport (connect/read/write/timeout).
#[derive(Debug)]
pub enum ClientError {
    /// Admission control shed the request; retry after the hinted delay.
    Overloaded { retry_after_ms: u64 },
    /// The server gave up on the request (`--request-timeout`).
    Deadline { timeout_ms: u64 },
    /// Structured server-side failure.
    Server { message: String },
    /// Malformed or unexpected response shape.
    Protocol { message: String },
    /// Transport-level failure.
    Io(std::io::Error),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Overloaded { retry_after_ms } => {
                write!(f, "server overloaded (retry after {retry_after_ms} ms)")
            }
            ClientError::Deadline { timeout_ms } => {
                write!(f, "server deadline expired ({timeout_ms} ms)")
            }
            ClientError::Server { message } => write!(f, "server error: {message}"),
            ClientError::Protocol { message } => write!(f, "protocol error: {message}"),
            ClientError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for ClientError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ClientError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

/// Connection and retry policy.  Retries apply *only* to `overloaded`
/// sheds — a shed is the one failure the server explicitly invites the
/// client to repeat; deadlines and errors surface immediately.
#[derive(Clone, Copy, Debug)]
pub struct ClientOptions {
    /// TCP connect timeout.
    pub connect_timeout: Duration,
    /// Socket read timeout (None = wait forever; large tunes on a
    /// generously-configured server can legitimately run long).
    pub read_timeout: Option<Duration>,
    /// Extra attempts after a shed (0 = surface `Overloaded` at once).
    pub retries: usize,
    /// Exponential backoff base; attempt k waits `base * 2^k` capped at
    /// `backoff_cap`, never less than the server's `retry_after_ms`.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Seed for the deterministic backoff jitter (de-synchronizes
    /// clients that were shed together without any RNG/clock state).
    pub seed: u64,
}

impl Default for ClientOptions {
    fn default() -> Self {
        ClientOptions {
            connect_timeout: Duration::from_secs(10),
            read_timeout: Some(Duration::from_secs(300)),
            retries: 3,
            backoff_base: Duration::from_millis(100),
            backoff_cap: Duration::from_secs(2),
            seed: 0x9e37_79b9_7f4a_7c15,
        }
    }
}

/// One connection to a running coordinator server.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    opts: ClientOptions,
}

/// Deterministic jitter in `[0, cap]` from (seed, attempt) — xorshift,
/// no RNG or clock state, so retry schedules are reproducible.
fn jitter_ms(seed: u64, attempt: u32, cap: u64) -> u64 {
    if cap == 0 {
        return 0;
    }
    let mut s = seed ^ (0x2545_f491_4f6c_dd1d_u64.wrapping_mul(attempt as u64 + 1));
    s ^= s << 13;
    s ^= s >> 7;
    s ^= s << 17;
    s % (cap + 1)
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client, ClientError> {
        Client::connect_with(addr, ClientOptions::default())
    }

    pub fn connect_with(addr: &str, opts: ClientOptions) -> Result<Client, ClientError> {
        let resolved = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| ClientError::Protocol { message: format!("cannot resolve {addr}") })?;
        let stream = TcpStream::connect_timeout(&resolved, opts.connect_timeout)?;
        stream.set_read_timeout(opts.read_timeout)?;
        stream.set_write_timeout(Some(opts.connect_timeout))?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream, opts })
    }

    /// Send a raw line, read one JSON response line.  No retry, no
    /// `ok` check — the caller sees the response verbatim.
    pub fn raw(&mut self, line: &str) -> Result<Json, ClientError> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut response = String::new();
        self.reader.read_line(&mut response)?;
        if response.is_empty() {
            return Err(ClientError::Protocol { message: "server closed connection".into() });
        }
        json::parse(response.trim())
            .map_err(|e| ClientError::Protocol { message: format!("bad response: {e}") })
    }

    /// Classify an `"ok": false` response into its typed error.
    fn classify(v: Json) -> Result<Json, ClientError> {
        if v.get("ok").and_then(Json::as_bool) == Some(true) {
            return Ok(v);
        }
        match v.get("error").and_then(Json::as_str) {
            Some("overloaded") => Err(ClientError::Overloaded {
                retry_after_ms: v
                    .get("retry_after_ms")
                    .and_then(Json::as_f64)
                    .map(|ms| ms.max(0.0) as u64)
                    .unwrap_or(100),
            }),
            Some("deadline") => Err(ClientError::Deadline {
                timeout_ms: v
                    .get("timeout_ms")
                    .and_then(Json::as_f64)
                    .map(|ms| ms.max(0.0) as u64)
                    .unwrap_or(0),
            }),
            Some(msg) => Err(ClientError::Server { message: msg.to_string() }),
            None => Err(ClientError::Protocol { message: format!("malformed response: {v}") }),
        }
    }

    /// Send a line and require an `"ok": true` response, retrying sheds
    /// with capped exponential backoff + deterministic jitter.
    fn checked(&mut self, line: &str) -> Result<Json, ClientError> {
        let mut attempt: u32 = 0;
        loop {
            match Self::classify(self.raw(line)?) {
                Ok(v) => return Ok(v),
                Err(ClientError::Overloaded { retry_after_ms })
                    if (attempt as usize) < self.opts.retries =>
                {
                    let backoff = self
                        .opts
                        .backoff_base
                        .saturating_mul(1u32 << attempt.min(16))
                        .min(self.opts.backoff_cap)
                        .as_millis() as u64;
                    let base = backoff.max(retry_after_ms);
                    let delay = base + jitter_ms(self.opts.seed, attempt, base / 4);
                    std::thread::sleep(Duration::from_millis(delay));
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    pub fn ping(&mut self) -> Result<bool, ClientError> {
        let v = self.raw(r#"{"op":"ping"}"#)?;
        match v.get("pong").and_then(Json::as_bool) {
            Some(b) => Ok(b),
            None => Err(ClientError::Protocol { message: format!("malformed ping response: {v}") }),
        }
    }

    pub fn info(&mut self) -> Result<Json, ClientError> {
        self.raw(r#"{"op":"info"}"#)
    }

    /// Submit an inline tuning job and return the parsed response.
    pub fn tune(&mut self, req: &TuneRequest) -> Result<Json, ClientError> {
        self.checked(&protocol::tune_request_json(req))
    }

    /// Create (or look up) the server-side session for a dataset; the
    /// server pays the O(N^3) setup at most once per fingerprint.
    /// Returns the session id to reference in subsequent requests.
    pub fn create_session(&mut self, x: &Matrix, kernel: Kernel) -> Result<u64, ClientError> {
        let v = self.checked(&protocol::create_session_json(x, kernel, 0))?;
        v.get("session_id").and_then(Json::as_f64).map(|id| id as u64).ok_or_else(|| {
            ClientError::Protocol { message: "malformed create_session response".into() }
        })
    }

    /// Full create-session response (id, `cached`, setup timings, bytes).
    pub fn create_session_full(
        &mut self,
        x: &Matrix,
        kernel: Kernel,
        threads: usize,
    ) -> Result<Json, ClientError> {
        self.checked(&protocol::create_session_json(x, kernel, threads))
    }

    /// Submit a tuning job against an existing session — O(N) per
    /// iterate on the server, zero setup work.
    pub fn tune_session(&mut self, req: &SessionTuneRequest) -> Result<Json, ClientError> {
        self.checked(&protocol::session_tune_json(req))
    }

    /// Sweep the session's kernel family over a theta range (Algorithm 1
    /// through the server's eigen-family cache): the server evaluates
    /// outer candidates as parallel wavefronts and reuses every
    /// previously-built `(session, theta)` decomposition, so a repeat
    /// sweep over a warm family performs zero O(N^3) work
    /// (`setups_built: 0` in the response) and returns bitwise-identical
    /// results.
    pub fn tune_theta(&mut self, req: &ThetaTuneRequest) -> Result<Json, ClientError> {
        self.checked(&protocol::theta_tune_json(req))
    }

    /// Score/Jacobian/Hessian at one hyperparameter point (O(N)).
    pub fn evaluate(&mut self, req: &EvaluateRequest) -> Result<Json, ClientError> {
        self.checked(&protocol::evaluate_json(req))
    }

    /// Posterior predictive mean + variance at new inputs.
    pub fn predict(&mut self, req: &PredictRequest) -> Result<Json, ClientError> {
        self.checked(&protocol::predict_json(req))
    }

    /// Append observations to a server-side session (streaming update):
    /// the server refreshes the cached eigendecomposition by rank-one
    /// corrections (degradation-ladder refit past its fallback policy)
    /// and evolves the session fingerprint to the grown dataset.
    /// Subsequent requests must send length-N' outputs (`n` in the
    /// response).  `threads` pins the server-side pool width for this
    /// refresh (0 = default).
    pub fn update_session(
        &mut self,
        session_id: u64,
        x_new: &Matrix,
        threads: usize,
    ) -> Result<Json, ClientError> {
        self.checked(&protocol::update_session_json(session_id, x_new, threads))
    }

    /// Drop a session; returns whether it existed.
    pub fn drop_session(&mut self, session_id: u64) -> Result<bool, ClientError> {
        let v = self.checked(&protocol::drop_session_json(session_id))?;
        match v.get("dropped").and_then(Json::as_bool) {
            Some(b) => Ok(b),
            None => Err(ClientError::Protocol {
                message: format!("malformed drop_session response: {v}"),
            }),
        }
    }

    /// Session-cache statistics (hit/miss/eviction/setup counters
    /// plus the fault and degradation counters).
    pub fn stats(&mut self) -> Result<Json, ClientError> {
        self.checked(r#"{"op":"stats"}"#)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        for attempt in 0..8 {
            let a = jitter_ms(42, attempt, 100);
            let b = jitter_ms(42, attempt, 100);
            assert_eq!(a, b);
            assert!(a <= 100);
        }
        assert_eq!(jitter_ms(7, 0, 0), 0);
        // different attempts de-synchronize
        let all: std::collections::HashSet<_> =
            (0..16).map(|k| jitter_ms(9, k, 1_000_000)).collect();
        assert!(all.len() > 8, "jitter collapsed: {all:?}");
    }

    #[test]
    fn classify_separates_shed_deadline_and_failure() {
        let shed =
            json::parse(r#"{"ok":false,"error":"overloaded","retry_after_ms":250}"#).unwrap();
        match Client::classify(shed) {
            Err(ClientError::Overloaded { retry_after_ms: 250 }) => {}
            other => panic!("expected Overloaded(250): {other:?}"),
        }
        let dl = json::parse(r#"{"ok":false,"error":"deadline","timeout_ms":30000}"#).unwrap();
        match Client::classify(dl) {
            Err(ClientError::Deadline { timeout_ms: 30000 }) => {}
            other => panic!("expected Deadline(30000): {other:?}"),
        }
        let err = json::parse(r#"{"ok":false,"error":"unknown session 9"}"#).unwrap();
        match Client::classify(err) {
            Err(ClientError::Server { message }) => assert!(message.contains("unknown session")),
            other => panic!("expected Server: {other:?}"),
        }
        let odd = json::parse(r#"{"what":1}"#).unwrap();
        assert!(matches!(Client::classify(odd), Err(ClientError::Protocol { .. })));
        let ok = json::parse(r#"{"ok":true}"#).unwrap();
        assert!(Client::classify(ok).is_ok());
    }
}

//! Blocking client for the coordinator's newline-JSON protocol.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{anyhow, Result};

use crate::coordinator::{protocol, TuneRequest};
use crate::util::json::{self, Json};

/// One connection to a running coordinator server.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send a raw line, read one JSON response line.
    pub fn raw(&mut self, line: &str) -> Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut response = String::new();
        self.reader.read_line(&mut response)?;
        if response.is_empty() {
            return Err(anyhow!("server closed connection"));
        }
        json::parse(response.trim()).map_err(|e| anyhow!("bad response: {e}"))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let v = self.raw(r#"{"op":"ping"}"#)?;
        Ok(v.get("pong").and_then(Json::as_bool).unwrap_or(false))
    }

    pub fn info(&mut self) -> Result<Json> {
        self.raw(r#"{"op":"info"}"#)
    }

    /// Submit a tuning job and return the parsed response (check `ok`).
    pub fn tune(&mut self, req: &TuneRequest) -> Result<Json> {
        let v = self.raw(&protocol::tune_request_json(req))?;
        if v.get("ok").and_then(Json::as_bool) != Some(true) {
            let msg = v.get("error").and_then(Json::as_str).unwrap_or("unknown error");
            return Err(anyhow!("server error: {msg}"));
        }
        Ok(v)
    }
}

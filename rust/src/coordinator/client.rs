//! Blocking client for the coordinator's newline-JSON protocol.
//!
//! One method per wire op (`docs/PROTOCOL.md`); the session workflow is
//! `create_session` -> repeated `tune_session` / `evaluate` / `predict`
//! (all O(N) on the server), with `update_session` appending streaming
//! observations in place -> optional `drop_session`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{anyhow, Result};

use crate::coordinator::protocol::{self, EvaluateRequest, PredictRequest};
use crate::coordinator::session::{SessionTuneRequest, ThetaTuneRequest};
use crate::coordinator::TuneRequest;
use crate::kernelfn::Kernel;
use crate::linalg::Matrix;
use crate::util::json::{self, Json};

/// One connection to a running coordinator server.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send a raw line, read one JSON response line.
    pub fn raw(&mut self, line: &str) -> Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut response = String::new();
        self.reader.read_line(&mut response)?;
        if response.is_empty() {
            return Err(anyhow!("server closed connection"));
        }
        json::parse(response.trim()).map_err(|e| anyhow!("bad response: {e}"))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let v = self.raw(r#"{"op":"ping"}"#)?;
        Ok(v.get("pong").and_then(Json::as_bool).unwrap_or(false))
    }

    pub fn info(&mut self) -> Result<Json> {
        self.raw(r#"{"op":"info"}"#)
    }

    /// Send a line and require an `"ok": true` response.
    fn checked(&mut self, line: &str) -> Result<Json> {
        let v = self.raw(line)?;
        if v.get("ok").and_then(Json::as_bool) != Some(true) {
            let msg = v.get("error").and_then(Json::as_str).unwrap_or("unknown error");
            return Err(anyhow!("server error: {msg}"));
        }
        Ok(v)
    }

    /// Submit an inline tuning job and return the parsed response.
    pub fn tune(&mut self, req: &TuneRequest) -> Result<Json> {
        self.checked(&protocol::tune_request_json(req))
    }

    /// Create (or look up) the server-side session for a dataset; the
    /// server pays the O(N^3) setup at most once per fingerprint.
    /// Returns the session id to reference in subsequent requests.
    pub fn create_session(&mut self, x: &Matrix, kernel: Kernel) -> Result<u64> {
        let v = self.checked(&protocol::create_session_json(x, kernel, 0))?;
        v.get("session_id")
            .and_then(Json::as_f64)
            .map(|id| id as u64)
            .ok_or_else(|| anyhow!("malformed create_session response"))
    }

    /// Full create-session response (id, `cached`, setup timings, bytes).
    pub fn create_session_full(
        &mut self,
        x: &Matrix,
        kernel: Kernel,
        threads: usize,
    ) -> Result<Json> {
        self.checked(&protocol::create_session_json(x, kernel, threads))
    }

    /// Submit a tuning job against an existing session — O(N) per
    /// iterate on the server, zero setup work.
    pub fn tune_session(&mut self, req: &SessionTuneRequest) -> Result<Json> {
        self.checked(&protocol::session_tune_json(req))
    }

    /// Sweep the session's kernel family over a theta range (Algorithm 1
    /// through the server's eigen-family cache): the server evaluates
    /// outer candidates as parallel wavefronts and reuses every
    /// previously-built `(session, theta)` decomposition, so a repeat
    /// sweep over a warm family performs zero O(N^3) work
    /// (`setups_built: 0` in the response) and returns bitwise-identical
    /// results.
    pub fn tune_theta(&mut self, req: &ThetaTuneRequest) -> Result<Json> {
        self.checked(&protocol::theta_tune_json(req))
    }

    /// Score/Jacobian/Hessian at one hyperparameter point (O(N)).
    pub fn evaluate(&mut self, req: &EvaluateRequest) -> Result<Json> {
        self.checked(&protocol::evaluate_json(req))
    }

    /// Posterior predictive mean + variance at new inputs.
    pub fn predict(&mut self, req: &PredictRequest) -> Result<Json> {
        self.checked(&protocol::predict_json(req))
    }

    /// Append observations to a server-side session (streaming update):
    /// the server refreshes the cached eigendecomposition by rank-one
    /// corrections (full refit past its fallback policy) and evolves the
    /// session fingerprint to the grown dataset.  Subsequent requests
    /// must send length-N' outputs (`n` in the response).  `threads`
    /// pins the server-side pool width for this refresh (0 = default).
    pub fn update_session(
        &mut self,
        session_id: u64,
        x_new: &Matrix,
        threads: usize,
    ) -> Result<Json> {
        self.checked(&protocol::update_session_json(session_id, x_new, threads))
    }

    /// Drop a session; returns whether it existed.
    pub fn drop_session(&mut self, session_id: u64) -> Result<bool> {
        let v = self.checked(&protocol::drop_session_json(session_id))?;
        Ok(v.get("dropped").and_then(Json::as_bool).unwrap_or(false))
    }

    /// Session-cache statistics (hit/miss/eviction/setup counters).
    pub fn stats(&mut self) -> Result<Json> {
        self.checked(r#"{"op":"stats"}"#)
    }
}

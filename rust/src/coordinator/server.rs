//! Threaded TCP server: acceptor threads parse newline-JSON requests and
//! route them to one of two executors (DESIGN.md §7):
//!
//! - a **worker pool** (`--workers`) sharing the [`SessionStore`], for
//!   everything pure-rust — session ops, inline tunes, `evaluate`,
//!   `predict`, `stats`.  The spectral setup is `Send + Sync` behind an
//!   `Arc`, so concurrent clients on different (or the same) sessions
//!   execute in parallel;
//! - a **serial coordinator worker** that owns the [`Coordinator`] (the
//!   PJRT client is not `Send`), for `backend:"pjrt"` tunes and `info`.
//!   Without the `pjrt` feature this thread only answers `info`.
//!
//! Responses travel back on per-job channels.  (tokio is not vendored in
//! this image — DESIGN.md §5.)

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;

use crate::coordinator::session::{self, SessionStore, StoreStats};
use crate::coordinator::{protocol, Backend, Coordinator};
use crate::util::json::Json;

/// A job in flight: the parsed request and the channel to answer on.
enum Job {
    Handle(protocol::Request, Sender<String>),
    Stop,
}

/// Server configuration: pool width and session-cache budgets.
#[derive(Clone, Copy, Debug)]
pub struct ServerOptions {
    /// Worker threads for the pure-rust executor; 0 = auto (the host's
    /// available parallelism, capped at 8).  Each request may still fan
    /// its own O(N^3)/wavefront work across the scoped pool (§6), so the
    /// total thread budget is `workers x pool width` at the extreme.
    pub workers: usize,
    /// Session-cache entry budget.
    pub max_sessions: usize,
    /// Session-cache byte budget (setup memory, not request payloads).
    pub max_bytes: usize,
}

impl ServerOptions {
    /// Default byte budget: 1 GiB of cached setups.
    pub const DEFAULT_MAX_BYTES: usize = 1 << 30;
    /// Default entry budget.
    pub const DEFAULT_MAX_SESSIONS: usize = 64;
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            workers: 0,
            max_sessions: Self::DEFAULT_MAX_SESSIONS,
            max_bytes: Self::DEFAULT_MAX_BYTES,
        }
    }
}

fn resolve_workers(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 8)
    }
}

/// Handles to both executors, shared by every connection thread.
struct Queues {
    coord: Sender<Job>,
    pool: Sender<Job>,
    workers: usize,
}

impl Queues {
    /// Stop both executors (idempotent: extra stops are drained or lost
    /// harmlessly once the workers exit).
    fn stop_all(&self) {
        let _ = self.coord.send(Job::Stop);
        for _ in 0..self.workers {
            let _ = self.pool.send(Job::Stop);
        }
    }
}

/// Server handle: the bound address and a way to stop the loop.
pub struct Server {
    pub addr: std::net::SocketAddr,
    queues: Arc<Queues>,
    stopping: Arc<AtomicBool>,
    accept_handle: Option<thread::JoinHandle<()>>,
    coord_handle: Option<thread::JoinHandle<()>>,
    pool_handles: Vec<thread::JoinHandle<()>>,
    store: Arc<SessionStore>,
}

impl Server {
    /// Bind `addr` with default [`ServerOptions`].  `make_coordinator`
    /// runs *on the coordinator worker thread* (the coordinator is not
    /// `Send`).
    pub fn start<F>(addr: &str, make_coordinator: F) -> std::io::Result<Server>
    where
        F: FnOnce() -> Coordinator + Send + 'static,
    {
        Server::start_with(addr, ServerOptions::default(), make_coordinator)
    }

    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and start
    /// the acceptor, the worker pool, and the coordinator worker.
    pub fn start_with<F>(
        addr: &str,
        opts: ServerOptions,
        make_coordinator: F,
    ) -> std::io::Result<Server>
    where
        F: FnOnce() -> Coordinator + Send + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let workers = resolve_workers(opts.workers);
        let store = Arc::new(SessionStore::new(opts.max_sessions, opts.max_bytes));

        // coordinator worker: owns the (non-Send) coordinator; executes
        // pjrt-backend tunes serially and answers `info`
        let (coord_tx, coord_rx): (Sender<Job>, Receiver<Job>) = channel();
        let coord_store = store.clone();
        let coord_handle = thread::spawn(move || {
            let mut coord = make_coordinator();
            while let Ok(job) = coord_rx.recv() {
                match job {
                    Job::Stop => break,
                    Job::Handle(req, reply) => {
                        let response = dispatch_coord(&mut coord, &coord_store, workers, req);
                        let _ = reply.send(response);
                    }
                }
            }
        });

        // worker pool: all pure-rust work, shared session store.  The
        // receiver is guarded by a mutex; a worker holds it only while
        // blocked in recv, never while executing a job.
        let (pool_tx, pool_rx): (Sender<Job>, Receiver<Job>) = channel();
        let pool_rx = Arc::new(Mutex::new(pool_rx));
        let pool_handles: Vec<_> = (0..workers)
            .map(|_| {
                let rx = pool_rx.clone();
                let store = store.clone();
                thread::spawn(move || loop {
                    let job = match rx.lock().unwrap().recv() {
                        Ok(job) => job,
                        Err(_) => break,
                    };
                    match job {
                        Job::Stop => break,
                        Job::Handle(req, reply) => {
                            let response = dispatch_pool(&store, workers, req);
                            let _ = reply.send(response);
                        }
                    }
                })
            })
            .collect();

        let queues = Arc::new(Queues { coord: coord_tx, pool: pool_tx, workers });

        // acceptor: one thread per connection; exits when `stopping` is
        // set (stop() pokes it with a dummy connection to unblock accept)
        let stopping = Arc::new(AtomicBool::new(false));
        let accept_queues = queues.clone();
        let stop_flag = stopping.clone();
        let accept_handle = thread::spawn(move || {
            for stream in listener.incoming() {
                if stop_flag.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { break };
                let queues = accept_queues.clone();
                thread::spawn(move || {
                    let _ = handle_connection(stream, queues);
                });
            }
        });

        Ok(Server {
            addr: local,
            queues,
            stopping,
            accept_handle: Some(accept_handle),
            coord_handle: Some(coord_handle),
            pool_handles,
            store,
        })
    }

    /// The resolved worker-pool width.
    pub fn workers(&self) -> usize {
        self.queues.workers
    }

    /// The shared session store (tests assert on its counters directly).
    pub fn store(&self) -> &Arc<SessionStore> {
        &self.store
    }

    /// Point-in-time session-cache statistics.
    pub fn session_stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Stop every executor and the acceptor, joining all threads.
    pub fn stop(mut self) {
        self.queues.stop_all();
        if let Some(h) = self.coord_handle.take() {
            let _ = h.join();
        }
        for h in self.pool_handles.drain(..) {
            let _ = h.join();
        }
        // the acceptor blocks in accept(); raise the flag, then poke it
        self.stopping.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// Does this request need the serial coordinator worker?
fn needs_coordinator(req: &protocol::Request) -> bool {
    match req {
        protocol::Request::Tune(r) => r.backend == Backend::Pjrt,
        protocol::Request::Info => true,
        _ => false,
    }
}

/// Coordinator-worker dispatch: pjrt tunes + `info`; anything else that
/// lands here (defensively) runs the pool logic against the shared store.
fn dispatch_coord(
    coord: &mut Coordinator,
    store: &SessionStore,
    workers: usize,
    req: protocol::Request,
) -> String {
    match req {
        protocol::Request::Info => {
            let s = store.stats();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("pjrt", Json::Bool(coord.has_runtime())),
                ("workers", Json::Num(workers as f64)),
                ("sessions", Json::Num(s.sessions as f64)),
                // fingerprint-cache traffic: pool (session store) plus the
                // coordinator's own pjrt-path eigen-cache
                ("cache_hits", Json::Num((s.hits + coord.cache_hits as u64) as f64)),
                ("cache_misses", Json::Num((s.misses + coord.cache_misses as u64) as f64)),
            ])
            .to_string()
        }
        protocol::Request::Tune(req) => match coord.tune(&req) {
            Ok(res) => protocol::tune_response(&res),
            Err(e) => protocol::error_response(&format!("{e:#}")),
        },
        other => dispatch_pool(store, workers, other),
    }
}

/// Pool dispatch: everything pure-rust against the shared session store.
fn dispatch_pool(store: &SessionStore, workers: usize, req: protocol::Request) -> String {
    match req {
        protocol::Request::Ping | protocol::Request::Shutdown => protocol::pong_response(),
        protocol::Request::Stats => protocol::stats_response(&store.stats(), workers),
        protocol::Request::CreateSession { x, kernel, threads } => {
            match crate::util::threadpool::with_threads(threads, || store.create(kernel, x)) {
                Ok((sess, cached)) => protocol::create_session_response(&sess, cached),
                Err(e) => protocol::error_response(&format!("{e:#}")),
            }
        }
        protocol::Request::UpdateSession { session_id, x_new, threads } => {
            let res = crate::util::threadpool::with_threads(threads, || {
                store.update(session_id, &x_new)
            });
            match res {
                Ok(res) => protocol::update_session_response(&res),
                Err(e) => protocol::error_response(&format!("{e:#}")),
            }
        }
        protocol::Request::DropSession { session_id } => {
            protocol::drop_session_response(store.drop_session(session_id))
        }
        protocol::Request::Tune(req) => match session::tune_via_store(store, &req) {
            Ok(res) => protocol::tune_response(&res),
            Err(e) => protocol::error_response(&format!("{e:#}")),
        },
        protocol::Request::TuneSession(req) => match session::tune_session(store, &req) {
            Ok(res) => protocol::session_tune_response(&res, req.session_id),
            Err(e) => protocol::error_response(&format!("{e:#}")),
        },
        protocol::Request::TuneTheta(req) => match session::tune_theta(store, &req) {
            Ok(res) => protocol::theta_tune_response(&res, req.session_id),
            Err(e) => protocol::error_response(&format!("{e:#}")),
        },
        protocol::Request::Evaluate(req) => match store.get(req.session_id) {
            None => protocol::error_response(&format!("unknown session {}", req.session_id)),
            Some(sess) => {
                if req.y.len() != sess.gp.n() {
                    return protocol::error_response(&format!(
                        "y: length {} != N {}",
                        req.y.len(),
                        sess.gp.n()
                    ));
                }
                let es = sess.gp.eigensystem(&req.y);
                let ev = match req.objective {
                    crate::coordinator::ObjectiveKind::Evidence => es.evidence_evaluate(req.hp),
                    crate::coordinator::ObjectiveKind::PaperScore => es.evaluate(req.hp),
                };
                protocol::evaluate_response(&ev, req.session_id)
            }
        },
        protocol::Request::Predict(req) => match store.get(req.session_id) {
            None => protocol::error_response(&format!("unknown session {}", req.session_id)),
            Some(sess) => {
                if req.y.len() != sess.gp.n() {
                    return protocol::error_response(&format!(
                        "y: length {} != N {}",
                        req.y.len(),
                        sess.gp.n()
                    ));
                }
                if req.xnew.cols() != sess.gp.x().cols() {
                    return protocol::error_response(&format!(
                        "xnew: {} cols != P {}",
                        req.xnew.cols(),
                        sess.gp.x().cols()
                    ));
                }
                let (mean, var) = sess.gp.predict(&req.xnew, &req.y, req.hp);
                protocol::predict_response(&mean, &var, req.session_id)
            }
        },
        protocol::Request::Info => protocol::error_response("info runs on the coordinator worker"),
    }
}

fn handle_connection(stream: TcpStream, queues: Arc<Queues>) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line)?;
        if n == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let response = match protocol::parse_request(trimmed) {
            Err(e) => protocol::error_response(&e),
            Ok(protocol::Request::Shutdown) => {
                // acknowledged; the CLI layer decides whether to exit
                queues.stop_all();
                writer.write_all(protocol::pong_response().as_bytes())?;
                writer.write_all(b"\n")?;
                return Ok(());
            }
            Ok(req) => {
                let (reply_tx, reply_rx) = channel();
                let queue = if needs_coordinator(&req) { &queues.coord } else { &queues.pool };
                if queue.send(Job::Handle(req, reply_tx)).is_err() {
                    protocol::error_response("worker stopped")
                } else {
                    reply_rx
                        .recv()
                        .unwrap_or_else(|_| protocol::error_response("worker dropped job"))
                }
            }
        };
        writer.write_all(response.as_bytes())?;
        writer.write_all(b"\n")?;
        let _ = peer;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::client::Client;
    use crate::coordinator::{Coordinator, GlobalStrategy, TuneRequest};
    use crate::data::{synthetic, SyntheticSpec};

    #[test]
    fn ping_info_roundtrip() {
        let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        assert!(client.ping().unwrap());
        let info = client.info().unwrap();
        assert_eq!(info.get("ok").unwrap().as_bool(), Some(true));
        server.stop();
    }

    #[test]
    fn tune_over_the_wire() {
        let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let ds = synthetic(SyntheticSpec { n: 40, p: 2, seed: 3, ..Default::default() }, 2);
        let mut req = TuneRequest::new(ds.x, ds.ys, crate::kernelfn::Kernel::Rbf { xi2: 2.0 });
        req.strategy = GlobalStrategy::Grid { points_per_axis: 7 };
        let res = client.tune(&req).unwrap();
        let outs = res.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs.len(), 2);
        for o in outs {
            assert!(o.get("sigma2").unwrap().as_f64().unwrap() > 0.0);
        }
        // second identical request hits the (implicit) session cache
        let res2 = client.tune(&req).unwrap();
        assert_eq!(res2.get("eigen_cached").unwrap().as_bool(), Some(true));
        assert_eq!(server.session_stats().setups, 1);
        server.stop();
    }

    #[test]
    fn malformed_line_gets_error_response() {
        let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let v = client.raw("this is not json").unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        server.stop();
    }

    #[test]
    fn concurrent_clients_execute_safely() {
        let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
        let addr = server.addr.to_string();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    let ds = synthetic(
                        SyntheticSpec { n: 30, p: 2, seed: i, ..Default::default() },
                        1,
                    );
                    let mut req =
                        TuneRequest::new(ds.x, ds.ys, crate::kernelfn::Kernel::Rbf { xi2: 1.0 });
                    req.strategy = GlobalStrategy::Grid { points_per_axis: 5 };
                    let res = client.tune(&req).unwrap();
                    assert_eq!(res.get("ok").unwrap().as_bool(), Some(true));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.stop();
    }

    #[test]
    fn explicit_worker_count_is_honored() {
        let opts = ServerOptions { workers: 2, ..Default::default() };
        let server = Server::start_with("127.0.0.1:0", opts, Coordinator::rust_only).unwrap();
        assert_eq!(server.workers(), 2);
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let stats = client.stats().unwrap();
        assert_eq!(stats.get("workers").unwrap().as_usize(), Some(2));
        server.stop();
    }
}

//! Threaded TCP server: acceptor threads parse newline-JSON requests and
//! route them to one of two executors (DESIGN.md §7):
//!
//! - a **worker pool** (`--workers`) sharing the [`SessionStore`], for
//!   everything pure-rust — session ops, inline tunes, `evaluate`,
//!   `predict`, `stats`.  The spectral setup is `Send + Sync` behind an
//!   `Arc`, so concurrent clients on different (or the same) sessions
//!   execute in parallel;
//! - a **serial coordinator worker** that owns the [`Coordinator`] (the
//!   PJRT client is not `Send`), for `backend:"pjrt"` tunes and `info`.
//!   Without the `pjrt` feature this thread only answers `info`.
//!
//! Responses travel back on per-job channels.  (tokio is not vendored in
//! this image — DESIGN.md §5.)
//!
//! The serving tier is fault-hardened (DESIGN.md §11): request lines are
//! byte-capped, connections carry socket read/write timeouts, every
//! request has a deadline (`--request-timeout`), the job queues are
//! bounded by admission control (`--max-queue` — excess load is *shed*
//! with a structured `overloaded` response instead of queueing unbounded
//! O(N^3) work), jobs run under per-job `catch_unwind` panic isolation,
//! and a pool worker that loses a panic past the job boundary respawns
//! itself.  Every degradation bumps a [`FaultCounters`] counter that the
//! wire `stats` op reports.

use std::io::{self, BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread;
use std::time::Duration;

use crate::coordinator::session::{self, SessionStore, StoreStats};
use crate::coordinator::{protocol, Backend, Coordinator};
use crate::faults::{FaultCounters, FaultPolicy};
use crate::util::json::Json;

/// A job in flight: the parsed request and the channel to answer on.
enum Job {
    Handle(protocol::Request, Sender<String>),
    Stop,
}

/// Server configuration: pool width, session-cache budgets, and the
/// fault-hardening knobs (deadline, admission control, line cap).
#[derive(Clone, Copy, Debug)]
pub struct ServerOptions {
    /// Worker threads for the pure-rust executor; 0 = auto (the host's
    /// available parallelism, capped at 8).  Each request may still fan
    /// its own O(N^3)/wavefront work across the scoped pool (§6), so the
    /// total thread budget is `workers x pool width` at the extreme.
    pub workers: usize,
    /// Session-cache entry budget.
    pub max_sessions: usize,
    /// Session-cache byte budget (setup memory, not request payloads).
    pub max_bytes: usize,
    /// Per-request deadline: a job that has not answered within this
    /// window gets a structured `deadline` error (the abandoned job's
    /// eventual result is discarded).  Also the socket read/write
    /// timeout — a connection stalled mid-line past this window is a
    /// slow-loris and is answered + closed; an *idle* connection (no
    /// bytes of a next request yet) is never expired.
    pub request_timeout: Duration,
    /// Admission-control bound: jobs waiting in an executor's queue
    /// beyond this are shed with `overloaded` + `retry_after_ms`
    /// instead of queueing more O(N^3) work.
    pub max_queue: usize,
    /// Per-request line cap: a single connection cannot balloon server
    /// memory by streaming an unbounded line.
    pub max_line_bytes: usize,
}

impl ServerOptions {
    /// Default byte budget: 1 GiB of cached setups.
    pub const DEFAULT_MAX_BYTES: usize = 1 << 30;
    /// Default entry budget.
    pub const DEFAULT_MAX_SESSIONS: usize = 64;
    /// Default per-request deadline.
    pub const DEFAULT_REQUEST_TIMEOUT: Duration = Duration::from_secs(30);
    /// Default admission-control queue bound.
    pub const DEFAULT_MAX_QUEUE: usize = 128;
    /// Default request-line cap: 32 MiB comfortably fits an N = 2048,
    /// P = 64 dataset as JSON while still bounding a hostile line.
    pub const DEFAULT_MAX_LINE_BYTES: usize = 32 << 20;
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            workers: 0,
            max_sessions: Self::DEFAULT_MAX_SESSIONS,
            max_bytes: Self::DEFAULT_MAX_BYTES,
            request_timeout: Self::DEFAULT_REQUEST_TIMEOUT,
            max_queue: Self::DEFAULT_MAX_QUEUE,
            max_line_bytes: Self::DEFAULT_MAX_LINE_BYTES,
        }
    }
}

fn resolve_workers(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 8)
    }
}

/// Everything a connection thread needs, shared behind one `Arc`: the
/// executor queues with their depth gauges, the fault counters, the
/// hardening knobs, and the stop flag.
struct Queues {
    coord: Sender<Job>,
    pool: Sender<Job>,
    workers: usize,
    coord_depth: Arc<AtomicUsize>,
    pool_depth: Arc<AtomicUsize>,
    counters: Arc<FaultCounters>,
    opts: ServerOptions,
    stopping: Arc<AtomicBool>,
}

impl Queues {
    /// Stop both executors (idempotent: extra stops are drained or lost
    /// harmlessly once the workers exit).
    fn stop_all(&self) {
        let _ = self.coord.send(Job::Stop);
        for _ in 0..self.workers {
            let _ = self.pool.send(Job::Stop);
        }
    }

    /// Graceful shutdown, phase one: refuse new submissions (connection
    /// threads answer "server stopping"), then enqueue the Stop jobs —
    /// FIFO *behind* every already-accepted job, so in-flight work
    /// drains before the executors exit.
    fn begin_stop(&self) {
        self.stopping.store(true, Ordering::SeqCst);
        self.stop_all();
    }
}

/// Server handle: the bound address and a way to stop the loop.
pub struct Server {
    pub addr: std::net::SocketAddr,
    queues: Arc<Queues>,
    stopping: Arc<AtomicBool>,
    accept_handle: Option<thread::JoinHandle<()>>,
    coord_handle: Option<thread::JoinHandle<()>>,
    pool_handles: Vec<thread::JoinHandle<()>>,
    store: Arc<SessionStore>,
}

impl Server {
    /// Bind `addr` with default [`ServerOptions`].  `make_coordinator`
    /// runs *on the coordinator worker thread* (the coordinator is not
    /// `Send`).
    pub fn start<F>(addr: &str, make_coordinator: F) -> std::io::Result<Server>
    where
        F: FnOnce() -> Coordinator + Send + 'static,
    {
        Server::start_with(addr, ServerOptions::default(), make_coordinator)
    }

    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and start
    /// the acceptor, the worker pool, and the coordinator worker.
    pub fn start_with<F>(
        addr: &str,
        opts: ServerOptions,
        make_coordinator: F,
    ) -> std::io::Result<Server>
    where
        F: FnOnce() -> Coordinator + Send + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let workers = resolve_workers(opts.workers);
        // one counter block shared by the store's degradation ladder and
        // the server's shed/panic/respawn/deadline accounting
        let counters = Arc::new(FaultCounters::default());
        let store = Arc::new(SessionStore::with_faults(
            opts.max_sessions,
            opts.max_bytes,
            FaultPolicy::default(),
            counters.clone(),
        ));

        // coordinator worker: owns the (non-Send) coordinator; executes
        // pjrt-backend tunes serially and answers `info`.  Job panics are
        // isolated per job; the thread itself never dies on one.
        let (coord_tx, coord_rx): (Sender<Job>, Receiver<Job>) = channel();
        let coord_depth = Arc::new(AtomicUsize::new(0));
        let coord_store = store.clone();
        let coord_counters = counters.clone();
        let coord_gauge = coord_depth.clone();
        let coord_handle = thread::spawn(move || {
            let mut coord = make_coordinator();
            while let Ok(job) = coord_rx.recv() {
                match job {
                    Job::Stop => break,
                    Job::Handle(req, reply) => {
                        coord_gauge.fetch_sub(1, Ordering::SeqCst);
                        let response = catch_unwind(AssertUnwindSafe(|| {
                            dispatch_coord(&mut coord, &coord_store, workers, req)
                        }))
                        .unwrap_or_else(|p| {
                            FaultCounters::bump(&coord_counters.panics);
                            panic_response(&p)
                        });
                        let _ = reply.send(response);
                    }
                }
            }
        });

        // worker pool: all pure-rust work, shared session store.  The
        // receiver is guarded by a mutex; a worker holds it only while
        // blocked in recv, never while executing a job.
        let (pool_tx, pool_rx): (Sender<Job>, Receiver<Job>) = channel();
        let pool_rx = Arc::new(Mutex::new(pool_rx));
        let pool_depth = Arc::new(AtomicUsize::new(0));
        let pool_handles: Vec<_> = (0..workers)
            .map(|_| {
                spawn_pool_worker(
                    pool_rx.clone(),
                    store.clone(),
                    pool_depth.clone(),
                    counters.clone(),
                    workers,
                )
            })
            .collect();

        let stopping = Arc::new(AtomicBool::new(false));
        let queues = Arc::new(Queues {
            coord: coord_tx,
            pool: pool_tx,
            workers,
            coord_depth,
            pool_depth,
            counters,
            opts,
            stopping: stopping.clone(),
        });

        // acceptor: one thread per connection; exits when `stopping` is
        // set (stop() pokes it with a dummy connection to unblock accept)
        let accept_queues = queues.clone();
        let stop_flag = stopping.clone();
        let accept_handle = thread::spawn(move || {
            for stream in listener.incoming() {
                if stop_flag.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { break };
                let queues = accept_queues.clone();
                thread::spawn(move || {
                    let _ = handle_connection(stream, queues);
                });
            }
        });

        Ok(Server {
            addr: local,
            queues,
            stopping,
            accept_handle: Some(accept_handle),
            coord_handle: Some(coord_handle),
            pool_handles,
            store,
        })
    }

    /// The resolved worker-pool width.
    pub fn workers(&self) -> usize {
        self.queues.workers
    }

    /// The shared session store (tests assert on its counters directly).
    pub fn store(&self) -> &Arc<SessionStore> {
        &self.store
    }

    /// Point-in-time session-cache statistics (includes the fault and
    /// degradation counters).
    pub fn session_stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Stop every executor and the acceptor, joining all threads.
    /// Graceful: new submissions are refused first, then the executors
    /// drain their already-accepted jobs before exiting.
    pub fn stop(mut self) {
        self.queues.begin_stop();
        if let Some(h) = self.coord_handle.take() {
            let _ = h.join();
        }
        for h in self.pool_handles.drain(..) {
            let _ = h.join();
        }
        // the acceptor blocks in accept(); the flag is up, so poke it
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        debug_assert!(self.stopping.load(Ordering::SeqCst));
    }
}

/// Spawn one pool worker under a supervisor loop: the worker body runs
/// under `catch_unwind`, so a panic that escapes a job boundary (per-job
/// isolation already catches panics *inside* `dispatch_pool`) respawns
/// the loop instead of silently shrinking the pool.
fn spawn_pool_worker(
    rx: Arc<Mutex<Receiver<Job>>>,
    store: Arc<SessionStore>,
    depth: Arc<AtomicUsize>,
    counters: Arc<FaultCounters>,
    workers: usize,
) -> thread::JoinHandle<()> {
    thread::spawn(move || loop {
        let exit = catch_unwind(AssertUnwindSafe(|| {
            pool_worker_loop(&rx, &store, &depth, &counters, workers)
        }));
        match exit {
            Ok(()) => break, // Stop job or closed channel: clean exit
            Err(_) => {
                // self-heal: the worker lost a job to a panic outside the
                // per-job isolation; count it and rejoin the pool
                FaultCounters::bump(&counters.worker_respawns);
            }
        }
    })
}

fn pool_worker_loop(
    rx: &Mutex<Receiver<Job>>,
    store: &SessionStore,
    depth: &AtomicUsize,
    counters: &FaultCounters,
    workers: usize,
) {
    loop {
        // a panicking job cannot poison this mutex (it is released before
        // dispatch), but recover regardless: one poisoned receiver must
        // not wedge the whole pool
        let job = match rx.lock().unwrap_or_else(PoisonError::into_inner).recv() {
            Ok(job) => job,
            Err(_) => return,
        };
        match job {
            Job::Stop => return,
            Job::Handle(req, reply) => {
                depth.fetch_sub(1, Ordering::SeqCst);
                #[cfg(feature = "fault-inject")]
                {
                    use crate::faults::inject;
                    if inject::fire(inject::FaultPoint::WorkerPanic) {
                        // dropping `reply` tells the connection the job
                        // died; the supervisor respawns this worker
                        panic!("injected worker panic");
                    }
                    if inject::fire(inject::FaultPoint::SlowDispatch) {
                        thread::sleep(Duration::from_millis(inject::slow_dispatch_ms()));
                    }
                }
                // per-job panic isolation: a poisoned request kills
                // neither this worker nor the shared receiver
                let response =
                    catch_unwind(AssertUnwindSafe(|| dispatch_pool(store, workers, req)))
                        .unwrap_or_else(|p| {
                            FaultCounters::bump(&counters.panics);
                            panic_response(&p)
                        });
                let _ = reply.send(response);
            }
        }
    }
}

/// Structured error for an isolated job panic.
fn panic_response(payload: &(dyn std::any::Any + Send)) -> String {
    let msg = payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("opaque panic payload");
    protocol::error_response(&format!("internal error: worker panicked: {msg}"))
}

/// Deterministic retry hint for a shed: grows with how far past the cap
/// the queue is, bounded so clients never sleep absurdly long.
fn retry_hint_ms(depth: usize, max_queue: usize) -> u64 {
    let over = depth.saturating_sub(max_queue) as u64;
    (100 + 50 * over).min(5_000)
}

/// Does this request need the serial coordinator worker?
fn needs_coordinator(req: &protocol::Request) -> bool {
    match req {
        protocol::Request::Tune(r) => r.backend == Backend::Pjrt,
        protocol::Request::Info => true,
        _ => false,
    }
}

/// Coordinator-worker dispatch: pjrt tunes + `info`; anything else that
/// lands here (defensively) runs the pool logic against the shared store.
fn dispatch_coord(
    coord: &mut Coordinator,
    store: &SessionStore,
    workers: usize,
    req: protocol::Request,
) -> String {
    match req {
        protocol::Request::Info => {
            let s = store.stats();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("pjrt", Json::Bool(coord.has_runtime())),
                ("workers", Json::Num(workers as f64)),
                ("sessions", Json::Num(s.sessions as f64)),
                // fingerprint-cache traffic: pool (session store) plus the
                // coordinator's own pjrt-path eigen-cache
                ("cache_hits", Json::Num((s.hits + coord.cache_hits as u64) as f64)),
                ("cache_misses", Json::Num((s.misses + coord.cache_misses as u64) as f64)),
            ])
            .to_string()
        }
        protocol::Request::Tune(req) => match coord.tune(&req) {
            Ok(res) => protocol::tune_response(&res),
            Err(e) => protocol::error_response(&format!("{e:#}")),
        },
        other => dispatch_pool(store, workers, other),
    }
}

/// Pool dispatch: everything pure-rust against the shared session store.
fn dispatch_pool(store: &SessionStore, workers: usize, req: protocol::Request) -> String {
    match req {
        protocol::Request::Ping | protocol::Request::Shutdown => protocol::pong_response(),
        protocol::Request::Stats => protocol::stats_response(&store.stats(), workers),
        protocol::Request::CreateSession { x, kernel, threads } => {
            match crate::util::threadpool::with_threads(threads, || store.create(kernel, x)) {
                Ok((sess, cached)) => protocol::create_session_response(&sess, cached),
                Err(e) => protocol::error_response(&format!("{e:#}")),
            }
        }
        protocol::Request::UpdateSession { session_id, x_new, threads } => {
            let res = crate::util::threadpool::with_threads(threads, || {
                store.update(session_id, &x_new)
            });
            match res {
                Ok(res) => protocol::update_session_response(&res),
                Err(e) => protocol::error_response(&format!("{e:#}")),
            }
        }
        protocol::Request::DropSession { session_id } => {
            protocol::drop_session_response(store.drop_session(session_id))
        }
        protocol::Request::Tune(req) => match session::tune_via_store(store, &req) {
            Ok(res) => protocol::tune_response(&res),
            Err(e) => protocol::error_response(&format!("{e:#}")),
        },
        protocol::Request::TuneSession(req) => match session::tune_session(store, &req) {
            Ok(res) => protocol::session_tune_response(&res, req.session_id),
            Err(e) => protocol::error_response(&format!("{e:#}")),
        },
        protocol::Request::TuneTheta(req) => match session::tune_theta(store, &req) {
            Ok(res) => protocol::theta_tune_response(&res, req.session_id),
            Err(e) => protocol::error_response(&format!("{e:#}")),
        },
        protocol::Request::Evaluate(req) => match store.get(req.session_id) {
            None => protocol::error_response(&format!("unknown session {}", req.session_id)),
            Some(sess) => {
                if req.y.len() != sess.gp.n() {
                    return protocol::error_response(&format!(
                        "y: length {} != N {}",
                        req.y.len(),
                        sess.gp.n()
                    ));
                }
                let es = sess.gp.eigensystem(&req.y);
                let ev = match req.objective {
                    crate::coordinator::ObjectiveKind::Evidence => es.evidence_evaluate(req.hp),
                    crate::coordinator::ObjectiveKind::PaperScore => es.evaluate(req.hp),
                };
                protocol::evaluate_response(&ev, req.session_id)
            }
        },
        protocol::Request::Predict(req) => match store.get(req.session_id) {
            None => protocol::error_response(&format!("unknown session {}", req.session_id)),
            Some(sess) => {
                if req.y.len() != sess.gp.n() {
                    return protocol::error_response(&format!(
                        "y: length {} != N {}",
                        req.y.len(),
                        sess.gp.n()
                    ));
                }
                if req.xnew.cols() != sess.gp.x().cols() {
                    return protocol::error_response(&format!(
                        "xnew: {} cols != P {}",
                        req.xnew.cols(),
                        sess.gp.x().cols()
                    ));
                }
                let (mean, var) = sess.gp.predict(&req.xnew, &req.y, req.hp);
                protocol::predict_response(&mean, &var, req.session_id)
            }
        },
        protocol::Request::Info => protocol::error_response("info runs on the coordinator worker"),
    }
}

/// Outcome of one bounded line read.
enum LineRead {
    /// A complete newline-terminated line within the cap.
    Line(String),
    /// Peer closed the connection.
    Eof,
    /// Read timeout with *no* bytes of a next request: an idle
    /// persistent connection, not a fault.
    IdleTimeout,
    /// Read timeout with a half-received line: a slow-loris (or a
    /// wedged peer) holding the connection mid-request.
    Stalled,
    /// The line exceeded the cap (the remainder is unread).
    TooLong,
}

/// Read one newline-terminated line without letting a single connection
/// balloon memory: bytes accumulate up to `max`, and the socket read
/// timeout distinguishes idle connections from mid-line stalls.
fn read_bounded_line(reader: &mut BufReader<TcpStream>, max: usize) -> io::Result<LineRead> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let (consumed, complete) = {
            let chunk = match reader.fill_buf() {
                Ok(c) => c,
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock
                        || e.kind() == io::ErrorKind::TimedOut =>
                {
                    return Ok(if line.is_empty() {
                        LineRead::IdleTimeout
                    } else {
                        LineRead::Stalled
                    });
                }
                Err(e) => return Err(e),
            };
            if chunk.is_empty() {
                return Ok(LineRead::Eof);
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    line.extend_from_slice(&chunk[..pos]);
                    (pos + 1, true)
                }
                None => {
                    line.extend_from_slice(chunk);
                    (chunk.len(), false)
                }
            }
        };
        reader.consume(consumed);
        if line.len() > max {
            return Ok(LineRead::TooLong);
        }
        if complete {
            return Ok(LineRead::Line(String::from_utf8_lossy(&line).into_owned()));
        }
    }
}

fn respond(writer: &mut TcpStream, response: &str) -> io::Result<()> {
    writer.write_all(response.as_bytes())?;
    writer.write_all(b"\n")
}

/// Admission control + submission + deadline for one parsed request.
fn submit(queues: &Queues, req: protocol::Request) -> String {
    if queues.stopping.load(Ordering::SeqCst) {
        return protocol::error_response("server stopping");
    }
    let (queue, depth) = if needs_coordinator(&req) {
        (&queues.coord, &queues.coord_depth)
    } else {
        (&queues.pool, &queues.pool_depth)
    };
    let opts = &queues.opts;
    // shed before queueing: an overloaded server answers cheaply *now*
    // instead of growing a queue of O(N^3) jobs it will never catch up on
    let waiting = depth.load(Ordering::SeqCst);
    if waiting >= opts.max_queue {
        FaultCounters::bump(&queues.counters.sheds);
        return protocol::overloaded_response(retry_hint_ms(waiting, opts.max_queue));
    }
    let (reply_tx, reply_rx) = channel();
    depth.fetch_add(1, Ordering::SeqCst);
    if queue.send(Job::Handle(req, reply_tx)).is_err() {
        depth.fetch_sub(1, Ordering::SeqCst);
        return protocol::error_response("worker stopped");
    }
    match reply_rx.recv_timeout(opts.request_timeout) {
        Ok(response) => response,
        Err(RecvTimeoutError::Timeout) => {
            // the job still runs to completion on its worker; its reply
            // lands in a dropped channel and is discarded
            FaultCounters::bump(&queues.counters.deadline_expired);
            protocol::deadline_response(opts.request_timeout.as_millis() as u64)
        }
        Err(RecvTimeoutError::Disconnected) => {
            protocol::error_response("worker dropped job")
        }
    }
}

fn handle_connection(stream: TcpStream, queues: Arc<Queues>) -> std::io::Result<()> {
    let opts = queues.opts;
    stream.set_read_timeout(Some(opts.request_timeout))?;
    stream.set_write_timeout(Some(opts.request_timeout))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    loop {
        let line = match read_bounded_line(&mut reader, opts.max_line_bytes)? {
            LineRead::Eof => return Ok(()), // client closed
            LineRead::IdleTimeout => continue,
            LineRead::Stalled => {
                FaultCounters::bump(&queues.counters.deadline_expired);
                let _ = respond(
                    &mut writer,
                    &protocol::deadline_response(opts.request_timeout.as_millis() as u64),
                );
                return Ok(());
            }
            LineRead::TooLong => {
                let _ = respond(
                    &mut writer,
                    &protocol::error_response(&format!(
                        "request line exceeds {} bytes",
                        opts.max_line_bytes
                    )),
                );
                return Ok(()); // cannot resync mid-line; hang up
            }
            LineRead::Line(line) => line,
        };
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let response = match protocol::parse_request(trimmed) {
            Err(e) => protocol::error_response(&e),
            Ok(protocol::Request::Shutdown) => {
                // acknowledged; the CLI layer decides whether to exit
                queues.begin_stop();
                respond(&mut writer, &protocol::pong_response())?;
                return Ok(());
            }
            Ok(req) => submit(&queues, req),
        };
        respond(&mut writer, &response)?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::client::Client;
    use crate::coordinator::{Coordinator, GlobalStrategy, TuneRequest};
    use crate::data::{synthetic, SyntheticSpec};
    use crate::util::json;

    #[test]
    fn ping_info_roundtrip() {
        let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        assert!(client.ping().unwrap());
        let info = client.info().unwrap();
        assert_eq!(info.get("ok").unwrap().as_bool(), Some(true));
        server.stop();
    }

    #[test]
    fn tune_over_the_wire() {
        let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let ds = synthetic(SyntheticSpec { n: 40, p: 2, seed: 3, ..Default::default() }, 2);
        let mut req = TuneRequest::new(ds.x, ds.ys, crate::kernelfn::Kernel::Rbf { xi2: 2.0 });
        req.strategy = GlobalStrategy::Grid { points_per_axis: 7 };
        let res = client.tune(&req).unwrap();
        let outs = res.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs.len(), 2);
        for o in outs {
            assert!(o.get("sigma2").unwrap().as_f64().unwrap() > 0.0);
        }
        // second identical request hits the (implicit) session cache
        let res2 = client.tune(&req).unwrap();
        assert_eq!(res2.get("eigen_cached").unwrap().as_bool(), Some(true));
        assert_eq!(server.session_stats().setups, 1);
        server.stop();
    }

    #[test]
    fn malformed_line_gets_error_response() {
        let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let v = client.raw("this is not json").unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        server.stop();
    }

    #[test]
    fn concurrent_clients_execute_safely() {
        let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
        let addr = server.addr.to_string();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    let ds = synthetic(
                        SyntheticSpec { n: 30, p: 2, seed: i, ..Default::default() },
                        1,
                    );
                    let mut req =
                        TuneRequest::new(ds.x, ds.ys, crate::kernelfn::Kernel::Rbf { xi2: 1.0 });
                    req.strategy = GlobalStrategy::Grid { points_per_axis: 5 };
                    let res = client.tune(&req).unwrap();
                    assert_eq!(res.get("ok").unwrap().as_bool(), Some(true));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.stop();
    }

    #[test]
    fn explicit_worker_count_is_honored() {
        let opts = ServerOptions { workers: 2, ..Default::default() };
        let server = Server::start_with("127.0.0.1:0", opts, Coordinator::rust_only).unwrap();
        assert_eq!(server.workers(), 2);
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let stats = client.stats().unwrap();
        assert_eq!(stats.get("workers").unwrap().as_usize(), Some(2));
        server.stop();
    }

    #[test]
    fn zero_queue_sheds_with_structured_retry_hint() {
        // max_queue 0: every submission sheds — the deterministic way to
        // observe the admission-control response shape
        let opts = ServerOptions { workers: 1, max_queue: 0, ..Default::default() };
        let server = Server::start_with("127.0.0.1:0", opts, Coordinator::rust_only).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let v = client.raw(r#"{"op":"ping"}"#).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("error").unwrap().as_str(), Some("overloaded"));
        let hint = v.get("retry_after_ms").unwrap().as_f64().unwrap();
        assert!(hint >= 100.0 && hint <= 5_000.0, "hint in range: {hint}");
        assert!(server.session_stats().faults.sheds >= 1);
        server.stop();
    }

    #[test]
    fn deadline_answers_structurally_and_connection_stays_usable() {
        let opts = ServerOptions {
            workers: 1,
            request_timeout: Duration::from_millis(2),
            ..Default::default()
        };
        let server = Server::start_with("127.0.0.1:0", opts, Coordinator::rust_only).unwrap();
        // manual socket: each request goes out as ONE write so the tiny
        // socket read timeout cannot split a request mid-line
        let mut sock = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let ds = synthetic(SyntheticSpec { n: 300, p: 2, seed: 5, ..Default::default() }, 1);
        let mut req = TuneRequest::new(ds.x, ds.ys, crate::kernelfn::Kernel::Rbf { xi2: 1.0 });
        req.strategy = GlobalStrategy::Grid { points_per_axis: 5 };
        let line = format!("{}\n", protocol::tune_request_json(&req));
        sock.write_all(line.as_bytes()).unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let v = json::parse(resp.trim()).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("error").unwrap().as_str(), Some("deadline"), "{resp}");
        assert!(v.get("timeout_ms").unwrap().as_f64().unwrap() >= 2.0);
        assert!(server.session_stats().faults.deadline_expired >= 1);
        // the same connection stays in protocol sync: once the worker
        // drains the abandoned job, a ping answers inside the deadline
        let mut pinged = false;
        for _ in 0..500 {
            sock.write_all(b"{\"op\":\"ping\"}\n").unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            let v = json::parse(resp.trim()).unwrap();
            if v.get("ok").unwrap().as_bool() == Some(true) {
                pinged = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(pinged, "connection unusable after a deadline response");
        server.stop();
    }

    #[test]
    fn oversized_request_line_is_rejected() {
        let opts = ServerOptions { max_line_bytes: 1024, ..Default::default() };
        let server = Server::start_with("127.0.0.1:0", opts, Coordinator::rust_only).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let big = format!(r#"{{"op":"ping","pad":"{}"}}"#, "x".repeat(4096));
        let v = client.raw(&big).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert!(
            v.get("error").unwrap().as_str().unwrap().contains("exceeds"),
            "names the cap: {v}"
        );
        // the connection closes after an unresyncable oversized line...
        assert!(client.raw(r#"{"op":"ping"}"#).is_err());
        // ...and fresh connections (and normal-size lines) are unaffected
        let mut fresh = Client::connect(&server.addr.to_string()).unwrap();
        assert!(fresh.ping().unwrap());
        server.stop();
    }
}

//! Threaded TCP server: acceptor threads parse newline-JSON requests and
//! forward them over an mpsc channel to the single worker thread that owns
//! the [`Coordinator`] (the PJRT client is not `Send`); responses travel
//! back on per-job channels.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread;

use crate::coordinator::{protocol, Coordinator};
use crate::util::json::Json;

/// A job in flight: the parsed request and the channel to answer on.
enum Job {
    Handle(protocol::Request, Sender<String>),
    Stop,
}

/// Server handle: the bound address and a way to stop the loop.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop_tx: Sender<Job>,
    stopping: Arc<AtomicBool>,
    accept_handle: Option<thread::JoinHandle<()>>,
    worker_handle: Option<thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and start
    /// the acceptor + worker threads.  `make_coordinator` runs *on the
    /// worker thread* (the coordinator is not `Send`).
    pub fn start<F>(addr: &str, make_coordinator: F) -> std::io::Result<Server>
    where
        F: FnOnce() -> Coordinator + Send + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();

        // worker: owns the coordinator, executes jobs serially
        let worker_handle = thread::spawn(move || {
            let mut coord = make_coordinator();
            while let Ok(job) = rx.recv() {
                match job {
                    Job::Stop => break,
                    Job::Handle(req, reply) => {
                        let response = dispatch(&mut coord, req);
                        let _ = reply.send(response);
                    }
                }
            }
        });

        // acceptor: one thread per connection; exits when `stopping` is
        // set (stop() pokes it with a dummy connection to unblock accept)
        let stopping = Arc::new(AtomicBool::new(false));
        let tx_accept = tx.clone();
        let stop_flag = stopping.clone();
        let accept_handle = thread::spawn(move || {
            for stream in listener.incoming() {
                if stop_flag.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { break };
                let tx = tx_accept.clone();
                thread::spawn(move || {
                    let _ = handle_connection(stream, tx);
                });
            }
        });

        Ok(Server {
            addr: local,
            stop_tx: tx,
            stopping,
            accept_handle: Some(accept_handle),
            worker_handle: Some(worker_handle),
        })
    }

    /// Stop the worker and the acceptor, joining both threads.
    pub fn stop(mut self) {
        let _ = self.stop_tx.send(Job::Stop);
        if let Some(h) = self.worker_handle.take() {
            let _ = h.join();
        }
        // the acceptor blocks in accept(); raise the flag, then poke it
        self.stopping.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

fn dispatch(coord: &mut Coordinator, req: protocol::Request) -> String {
    match req {
        protocol::Request::Ping => protocol::pong_response(),
        protocol::Request::Shutdown => protocol::pong_response(),
        protocol::Request::Info => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("pjrt", Json::Bool(coord.has_runtime())),
            ("cache_hits", Json::Num(coord.cache_hits as f64)),
            ("cache_misses", Json::Num(coord.cache_misses as f64)),
        ])
        .to_string(),
        protocol::Request::Tune(req) => match coord.tune(&req) {
            Ok(res) => protocol::tune_response(&res),
            Err(e) => protocol::error_response(&format!("{e:#}")),
        },
    }
}

fn handle_connection(stream: TcpStream, jobs: Sender<Job>) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line)?;
        if n == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let response = match protocol::parse_request(trimmed) {
            Err(e) => protocol::error_response(&e),
            Ok(protocol::Request::Shutdown) => {
                // acknowledged; the CLI layer decides whether to exit
                let _ = jobs.send(Job::Stop);
                writer.write_all(protocol::pong_response().as_bytes())?;
                writer.write_all(b"\n")?;
                return Ok(());
            }
            Ok(req) => {
                let (reply_tx, reply_rx) = channel();
                if jobs.send(Job::Handle(req, reply_tx)).is_err() {
                    protocol::error_response("worker stopped")
                } else {
                    reply_rx
                        .recv()
                        .unwrap_or_else(|_| protocol::error_response("worker dropped job"))
                }
            }
        };
        writer.write_all(response.as_bytes())?;
        writer.write_all(b"\n")?;
        let _ = peer;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::client::Client;
    use crate::coordinator::{Coordinator, GlobalStrategy, TuneRequest};
    use crate::data::{synthetic, SyntheticSpec};

    #[test]
    fn ping_info_roundtrip() {
        let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        assert!(client.ping().unwrap());
        let info = client.info().unwrap();
        assert_eq!(info.get("ok").unwrap().as_bool(), Some(true));
        server.stop();
    }

    #[test]
    fn tune_over_the_wire() {
        let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let ds = synthetic(SyntheticSpec { n: 40, p: 2, seed: 3, ..Default::default() }, 2);
        let mut req = TuneRequest::new(ds.x, ds.ys, crate::kernelfn::Kernel::Rbf { xi2: 2.0 });
        req.strategy = GlobalStrategy::Grid { points_per_axis: 7 };
        let res = client.tune(&req).unwrap();
        let outs = res.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs.len(), 2);
        for o in outs {
            assert!(o.get("sigma2").unwrap().as_f64().unwrap() > 0.0);
        }
        // second identical request hits the eigen cache
        let res2 = client.tune(&req).unwrap();
        assert_eq!(res2.get("eigen_cached").unwrap().as_bool(), Some(true));
        server.stop();
    }

    #[test]
    fn malformed_line_gets_error_response() {
        let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let v = client.raw("this is not json").unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        server.stop();
    }

    #[test]
    fn concurrent_clients_are_serialized_safely() {
        let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
        let addr = server.addr.to_string();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    let ds = synthetic(
                        SyntheticSpec { n: 30, p: 2, seed: i, ..Default::default() },
                        1,
                    );
                    let mut req =
                        TuneRequest::new(ds.x, ds.ys, crate::kernelfn::Kernel::Rbf { xi2: 1.0 });
                    req.strategy = GlobalStrategy::Grid { points_per_axis: 5 };
                    let res = client.tune(&req).unwrap();
                    assert_eq!(res.get("ok").unwrap().as_bool(), Some(true));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.stop();
    }
}

//! Log-space grid search — the simplest global stage of §1.1, and the one
//! whose cost is purely `(grid points) x (score evaluations)`, i.e. the
//! regime where the paper's O(N) identities pay off most directly.
//!
//! Evaluations are issued through [`Objective::eval_batch`] in fixed-size
//! chunks so a PJRT-backed objective can fold each chunk into a single
//! batched-artifact dispatch.

use super::{Bounds, Objective, SearchResult};
use crate::spectral::HyperParams;

/// Evaluate a `g x g` log-spaced grid over `bounds`; returns the best
/// point. `chunk` is the batch size handed to the objective (use the
/// runtime's `b_batch` for the PJRT path).
pub fn grid_search<O: Objective>(
    obj: &mut O,
    bounds: Bounds,
    g: usize,
    chunk: usize,
) -> SearchResult {
    assert!(g >= 2, "grid needs at least 2 points per axis");
    let [ls, ll] = bounds.log();
    let mut points = Vec::with_capacity(g * g);
    for i in 0..g {
        let es = ls.0 + (ls.1 - ls.0) * i as f64 / (g - 1) as f64;
        for j in 0..g {
            let el = ll.0 + (ll.1 - ll.0) * j as f64 / (g - 1) as f64;
            points.push(HyperParams::new(10f64.powf(es), 10f64.powf(el)));
        }
    }
    let mut best = SearchResult {
        hp: points[0],
        score: f64::INFINITY,
        evals: points.len(),
    };
    for ch in points.chunks(chunk.max(1)) {
        let scores = obj.eval_batch(ch);
        for (&hp, &sc) in ch.iter().zip(&scores) {
            if sc < best.score {
                best.score = sc;
                best.hp = hp;
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::Bowl;

    #[test]
    fn finds_bowl_minimum_region() {
        let mut obj = Bowl::new(0.5, 2.0);
        let r = grid_search(&mut obj, Bounds::default(), 33, 16);
        // grid resolution on [-4, 4] with 33 points is 0.25 in log10
        assert!((r.hp.sigma2.log10() - 0.5f64.log10()).abs() < 0.3, "{:?}", r.hp);
        assert!((r.hp.lambda2.log10() - 2.0f64.log10()).abs() < 0.3, "{:?}", r.hp);
        assert_eq!(r.evals, 33 * 33);
        assert_eq!(obj.evals, 33 * 33);
    }

    #[test]
    fn respects_bounds() {
        let mut obj = Bowl::new(1e-8, 1e8); // optimum outside bounds
        let b = Bounds { sigma2: (0.1, 10.0), lambda2: (0.1, 10.0) };
        let r = grid_search(&mut obj, b, 9, 7);
        assert!(b.contains(r.hp));
    }

    #[test]
    fn chunking_does_not_change_result() {
        let r1 = grid_search(&mut Bowl::new(0.7, 0.9), Bounds::default(), 17, 1);
        let r2 = grid_search(&mut Bowl::new(0.7, 0.9), Bounds::default(), 17, 64);
        assert_eq!(r1.hp, r2.hp);
        assert_eq!(r1.score, r2.score);
    }
}

//! Algorithm 1 (paper §2.2): two-step tuning when the kernel itself has a
//! hyperparameter `theta` (RBF bandwidth, Matérn length-scale, ...).
//!
//! The outer loop moves `theta` — each move costs a fresh Gram matrix and
//! eigendecomposition, O(N^3) — while the inner loop tunes `(sigma2,
//! lambda2)` at O(N) per iterate using the spectral identities.  The outer
//! stage here is a golden-section search on log10(theta) (a "conventional
//! line search on the expensive hyperparameter", as the paper puts it).

use super::{newton_refine, Bounds, NewtonOptions, Objective};
use crate::spectral::HyperParams;

#[derive(Clone, Copy, Debug)]
pub struct TwoStepOptions {
    /// log10 bounds for theta.
    pub theta_range: (f64, f64),
    /// Outer golden-section iterations (each costs O(N^3)).
    pub outer_iters: usize,
    /// Inner (sigma2, lambda2) bounds.
    pub bounds: Bounds,
    /// Inner coarse-grid resolution before Newton refinement.
    pub inner_grid: usize,
    pub newton: NewtonOptions,
}

impl Default for TwoStepOptions {
    fn default() -> Self {
        TwoStepOptions {
            theta_range: (1e-2, 1e2),
            outer_iters: 20,
            bounds: Bounds::default(),
            inner_grid: 9,
            newton: NewtonOptions::default(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TwoStepResult {
    pub theta: f64,
    pub hp: HyperParams,
    pub score: f64,
    /// Number of O(N^3) eigendecompositions spent (outer evaluations).
    pub outer_evals: usize,
    /// Total O(N) inner evaluations across all outer points.
    pub inner_evals: usize,
}

/// Inner solve: coarse grid + Newton on a fresh objective.
fn inner_tune<O: Objective>(obj: &mut O, opt: &TwoStepOptions) -> (HyperParams, f64, usize) {
    let coarse = super::grid_search(obj, opt.bounds, opt.inner_grid, 64);
    let refined = newton_refine(obj, coarse.hp, opt.bounds, opt.newton);
    (refined.hp, refined.score, coarse.evals + refined.evals)
}

/// Run Algorithm 1.  `make_objective(theta)` pays the O(N^3) overhead
/// (Gram + eigendecomposition at that kernel hyperparameter) and returns
/// the O(N) objective for the inner loop.
pub fn two_step_tune<O, F>(mut make_objective: F, opt: TwoStepOptions) -> TwoStepResult
where
    O: Objective,
    F: FnMut(f64) -> O,
{
    let inv_phi = (5f64.sqrt() - 1.0) / 2.0;
    let (mut lo, mut hi) = (opt.theta_range.0.log10(), opt.theta_range.1.log10());
    assert!(lo < hi, "theta range must be increasing");

    let mut outer_evals = 0usize;
    let mut inner_evals = 0usize;
    let mut best = TwoStepResult {
        theta: f64::NAN,
        hp: HyperParams::new(1.0, 1.0),
        score: f64::INFINITY,
        outer_evals: 0,
        inner_evals: 0,
    };

    // profile of theta -> best inner score
    let mut eval_theta = |logt: f64, outer: &mut usize, inner: &mut usize, best: &mut TwoStepResult| -> f64 {
        let theta = 10f64.powf(logt);
        let mut obj = make_objective(theta);
        *outer += 1;
        let (hp, score, ev) = inner_tune(&mut obj, &opt);
        *inner += ev;
        if score < best.score {
            best.score = score;
            best.hp = hp;
            best.theta = theta;
        }
        score
    };

    let mut x1 = hi - inv_phi * (hi - lo);
    let mut x2 = lo + inv_phi * (hi - lo);
    let mut f1 = eval_theta(x1, &mut outer_evals, &mut inner_evals, &mut best);
    let mut f2 = eval_theta(x2, &mut outer_evals, &mut inner_evals, &mut best);

    for _ in 0..opt.outer_iters.saturating_sub(2) {
        if f1 < f2 {
            hi = x2;
            x2 = x1;
            f2 = f1;
            x1 = hi - inv_phi * (hi - lo);
            f1 = eval_theta(x1, &mut outer_evals, &mut inner_evals, &mut best);
        } else {
            lo = x1;
            x1 = x2;
            f1 = f2;
            x2 = lo + inv_phi * (hi - lo);
            f2 = eval_theta(x2, &mut outer_evals, &mut inner_evals, &mut best);
        }
        if hi - lo < 1e-4 {
            break;
        }
    }

    best.outer_evals = outer_evals;
    best.inner_evals = inner_evals;
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::Bowl;

    /// Synthetic coupled objective: inner bowl whose depth depends on
    /// theta, with a known best theta at 2.0.
    struct ThetaBowl {
        bowl: Bowl,
        depth: f64,
    }

    impl Objective for ThetaBowl {
        fn eval(&mut self, hp: HyperParams) -> f64 {
            self.bowl.eval(hp) + self.depth
        }
        fn eval_full(&mut self, hp: HyperParams) -> crate::spectral::Evaluation {
            let mut ev = self.bowl.eval_full(hp);
            ev.score += self.depth;
            ev
        }
    }

    #[test]
    fn finds_outer_and_inner_optimum() {
        let make = |theta: f64| ThetaBowl {
            bowl: Bowl::new(0.5, 2.0),
            depth: (theta.ln() - 2f64.ln()).powi(2),
        };
        let r = two_step_tune(
            make,
            TwoStepOptions { outer_iters: 30, ..Default::default() },
        );
        assert!((r.theta.ln() - 2f64.ln()).abs() < 0.02, "theta={}", r.theta);
        assert!((r.hp.sigma2 - 0.5).abs() < 1e-3, "{:?}", r.hp);
        assert!((r.hp.lambda2 - 2.0).abs() < 1e-3, "{:?}", r.hp);
        assert!(r.outer_evals <= 30);
        assert!(r.inner_evals > r.outer_evals, "inner loop should dominate");
    }

    #[test]
    fn outer_budget_respected() {
        let make = |theta: f64| ThetaBowl { bowl: Bowl::new(1.0, 1.0), depth: theta };
        let r = two_step_tune(
            make,
            TwoStepOptions { outer_iters: 5, ..Default::default() },
        );
        assert!(r.outer_evals <= 5);
        assert!(r.score.is_finite());
    }
}

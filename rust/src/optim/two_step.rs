//! Algorithm 1 (paper §2.2) as a **theta-plane tuning engine**: two-step
//! tuning when the kernel itself has a hyperparameter `theta` (RBF
//! bandwidth, Matérn length-scale, polynomial degree, ...).
//!
//! The outer loop moves `theta` — each move costs a fresh Gram matrix and
//! eigendecomposition, O(N^3) — while the inner loop tunes `(sigma2,
//! lambda2)` at O(N) per iterate using the spectral identities.  This
//! module factors that outer loop into three pieces (DESIGN.md §9):
//!
//! - [`SetupProvider`] — *where setups come from*: get-or-build the
//!   eigendecomposed setup at a theta.  [`FnProvider`] builds fresh every
//!   time (the cold path); the coordinator's session store implements the
//!   trait over its eigen-family cache, so a warm sweep builds nothing.
//! - **Theta quantization** ([`quantize_theta`]) — probes are snapped to
//!   a fixed grid (1e-6 decades for continuous families, integers for
//!   discrete ones) *before* the setup is built, so two probes closer
//!   than the grid alias to one setup, cache keys are exact bit
//!   patterns, and warm re-runs replay the identical computation.
//! - [`ThetaSearch`] — *how theta moves*: the legacy serial
//!   golden-section line search, or a **parallel bracketing wavefront**
//!   that evaluates a whole front of candidates concurrently across the
//!   thread pool (each candidate's O(N^3) setup is independent — the
//!   largest un-parallelized wall-clock cost in the repo before this
//!   engine).  Discrete families ([`ThetaDomain::Integer`]) ignore the
//!   requested search and sweep the integer degrees in one wavefront:
//!   a continuous bracket over a rounding family aliases probes to
//!   identical scores and learns nothing between them (see
//!   [`Kernel::with_theta`]).
//!
//! Determinism: the candidate set is a function of `(theta_range,
//! outer_iters, search)` only — wavefront width defaults to a fixed
//! constant, never the pool width — and every candidate's setup is
//! built with the pool width pinned to 1 (the exact serial path), so
//! each setup is *canonical*: results are bit-identical across thread
//! counts and across cold/warm runs, even when a cached entry built
//! under one request width is served to a client using another (the
//! suite in `rust/tests/theta_engine.rs` gates this).  Parallelism
//! comes from evaluating candidates concurrently, not from inside a
//! setup.
//!
//! [`Kernel::with_theta`]: crate::kernelfn::Kernel::with_theta

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::{newton_refine, Bounds, NewtonOptions, Objective};
use crate::kernelfn::ThetaDomain;
use crate::spectral::HyperParams;
use crate::util::threadpool;

/// Quantization grid for continuous thetas: probes are snapped to
/// `1/THETA_QUANTA_PER_DECADE` decades, giving 1e6 distinct setups per
/// decade — far below any optimizer's meaningful resolution, and exact
/// enough that a cache keyed by the quantized value's bit pattern never
/// splits one logical probe across two entries.
pub const THETA_QUANTA_PER_DECADE: f64 = 1e6;

/// Candidates per wavefront round when [`ThetaSearch::Wavefront`] is
/// asked for width 0 ("auto").  Deliberately a constant rather than the
/// pool width: the probe set must not depend on how many threads happen
/// to be available, or cold/warm and cross-width results would diverge.
pub const DEFAULT_WAVEFRONT_WIDTH: usize = 8;

/// Hard cap on candidates in a discrete-family sweep, whatever the
/// requested outer budget: each candidate costs an O(N^3) setup, and
/// both the degree range and the budget arrive over the wire.
pub const MAX_DISCRETE_CANDIDATES: u64 = 4096;

/// Hard cap on [`ThetaSearch::Wavefront`] width (the width rides in a
/// wire request, and the first round is evaluated before any budget
/// check can apply — an unclamped width would size allocations and the
/// O(N^3)-per-candidate fan-out directly from attacker input).
pub const MAX_WAVEFRONT_WIDTH: usize = 64;

/// Snap `theta` to the engine's canonical grid for its domain.  Every
/// probe is quantized before the setup is built, so this function *is*
/// the cache-key contract shared by the engine, [`FnProvider`], and the
/// coordinator's eigen-family cache.
pub fn quantize_theta(theta: f64, domain: ThetaDomain) -> f64 {
    match domain {
        ThetaDomain::Integer => {
            if theta.is_finite() {
                theta.round().max(1.0)
            } else {
                1.0
            }
        }
        _ => {
            let q = THETA_QUANTA_PER_DECADE;
            10f64.powf((theta.log10() * q).round() / q)
        }
    }
}

/// Outer-search strategy over theta (continuous families only; discrete
/// families always sweep — see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThetaSearch {
    /// Serial golden-section line search on log10(theta) — the paper's
    /// "conventional line search on the expensive hyperparameter".
    Golden,
    /// Parallel bracketing wavefronts: each round evaluates `width`
    /// evenly log-spaced candidates across the current bracket
    /// concurrently, then shrinks the bracket to the best candidate's
    /// neighbors.  `width: 0` means [`DEFAULT_WAVEFRONT_WIDTH`]; other
    /// values are clamped to `4..=`[`MAX_WAVEFRONT_WIDTH`] (below 4 the
    /// best-candidate-neighbor bracket cannot shrink — at width 3 an
    /// interior best spans the whole bracket — and the width is
    /// wire-reachable, so the top end is capped too).
    Wavefront { width: usize },
}

impl Default for ThetaSearch {
    fn default() -> Self {
        ThetaSearch::Golden
    }
}

#[derive(Clone, Copy, Debug)]
pub struct TwoStepOptions {
    /// Bounds for theta (raw, not log).
    pub theta_range: (f64, f64),
    /// Outer evaluation budget.  Golden: probe count (legacy iteration
    /// semantics).  Wavefront: total distinct candidates across rounds,
    /// floored at the wavefront width — the first round always completes,
    /// so the effective budget is `max(outer_iters, width)`.
    /// Discrete sweep: maximum degrees probed (evenly thinned past it).
    pub outer_iters: usize,
    /// How the outer stage moves theta.
    pub search: ThetaSearch,
    /// Inner (sigma2, lambda2) bounds.
    pub bounds: Bounds,
    /// Inner coarse-grid resolution before Newton refinement.
    pub inner_grid: usize,
    pub newton: NewtonOptions,
}

impl Default for TwoStepOptions {
    fn default() -> Self {
        TwoStepOptions {
            theta_range: (1e-2, 1e2),
            outer_iters: 20,
            search: ThetaSearch::default(),
            bounds: Bounds::default(),
            inner_grid: 9,
            newton: NewtonOptions::default(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TwoStepResult {
    pub theta: f64,
    pub hp: HyperParams,
    pub score: f64,
    /// O(N^3) setups **actually built** by the provider for this run —
    /// not iterations: probes that aliased to an already-evaluated
    /// quantized theta, and cache hits on a warm provider, do not count.
    pub outer_evals: usize,
    /// Distinct quantized thetas whose inner problem was solved
    /// (>= `outer_evals`; the gap is exactly the cache/memo hits).
    pub distinct_thetas: usize,
    /// Total O(N) inner evaluations across all distinct outer points.
    pub inner_evals: usize,
}

/// Get-or-build the eigendecomposed setup for a (quantized) theta and
/// hand back the O(N) inner objective over it.
///
/// `setup` takes `&self` and must be callable concurrently: the
/// wavefront search fans one call per candidate across the thread pool.
/// Implementations count the setups they *really* built (vs served from
/// a cache) so [`TwoStepResult::outer_evals`] stays truthful.
pub trait SetupProvider: Sync {
    type Obj: Objective + Send;

    /// The theta domain of the family this provider builds (drives the
    /// family-aware search dispatch).
    fn domain(&self) -> ThetaDomain {
        ThetaDomain::Continuous
    }

    /// Build or fetch the setup at `theta` (already quantized by the
    /// engine via [`quantize_theta`]).
    fn setup(&self, theta: f64) -> Result<Self::Obj, String>;

    /// Cumulative count of setups actually built (not cache hits).
    fn setups_built(&self) -> usize;
}

/// [`SetupProvider`] over a plain closure: builds a fresh setup per
/// distinct quantized theta — the cold, cache-less path used by
/// [`two_step_tune`], the benches, and tests.
pub struct FnProvider<F> {
    f: F,
    domain: ThetaDomain,
    built: AtomicUsize,
}

impl<F> FnProvider<F> {
    /// Provider over a continuous theta family.
    pub fn new(f: F) -> Self {
        FnProvider::with_domain(f, ThetaDomain::Continuous)
    }

    /// Provider with an explicit domain (e.g. [`ThetaDomain::Integer`]
    /// for a polynomial-degree sweep).
    pub fn with_domain(f: F, domain: ThetaDomain) -> Self {
        FnProvider { f, domain, built: AtomicUsize::new(0) }
    }
}

impl<O, F> SetupProvider for FnProvider<F>
where
    O: Objective + Send,
    F: Fn(f64) -> O + Sync,
{
    type Obj = O;

    fn domain(&self) -> ThetaDomain {
        self.domain
    }

    fn setup(&self, theta: f64) -> Result<O, String> {
        self.built.fetch_add(1, Ordering::Relaxed);
        Ok((self.f)(theta))
    }

    fn setups_built(&self) -> usize {
        self.built.load(Ordering::Relaxed)
    }
}

/// Inner solve: coarse grid + Newton on a fresh objective (unchanged
/// from the pre-engine implementation, so scores are bit-compatible).
fn inner_tune<O: Objective>(obj: &mut O, opt: &TwoStepOptions) -> (HyperParams, f64, usize) {
    let coarse = super::grid_search(obj, opt.bounds, opt.inner_grid, 64);
    let refined = newton_refine(obj, coarse.hp, opt.bounds, opt.newton);
    (refined.hp, refined.score, coarse.evals + refined.evals)
}

/// Engine state shared by the search strategies: the memo of solved
/// thetas (keyed by quantized bit pattern) and the running best.
struct Engine<'a, P: SetupProvider> {
    provider: &'a P,
    opt: &'a TwoStepOptions,
    /// quantized-theta bits -> (inner hp, inner score)
    memo: HashMap<u64, (HyperParams, f64)>,
    best_theta: f64,
    best_hp: HyperParams,
    best_score: f64,
    inner_evals: usize,
}

impl<'a, P: SetupProvider> Engine<'a, P> {
    fn new(provider: &'a P, opt: &'a TwoStepOptions) -> Self {
        Engine {
            provider,
            opt,
            memo: HashMap::new(),
            best_theta: f64::NAN,
            best_hp: HyperParams::new(1.0, 1.0),
            best_score: f64::INFINITY,
            inner_evals: 0,
        }
    }

    /// The candidates not yet memoized, deduped, in first-seen order —
    /// the single definition of "what a wave will actually evaluate",
    /// shared by [`Engine::eval_wave`] and the wavefront budget check so
    /// the two can never disagree.
    fn fresh_of(&self, thetas: &[f64]) -> Vec<f64> {
        let mut fresh: Vec<f64> = Vec::new();
        for &t in thetas {
            let k = t.to_bits();
            if !self.memo.contains_key(&k) && !fresh.iter().any(|f| f.to_bits() == k) {
                fresh.push(t);
            }
        }
        fresh
    }

    /// Evaluate one wavefront of (already quantized) candidates.  Thetas
    /// already memoized are free; the fresh ones fan out across the pool
    /// — each worker pays the provider's setup (O(N^3) when cold) plus
    /// the O(N)-per-iterate inner tune.  Results merge in candidate
    /// order, so ties and the running best are deterministic regardless
    /// of which worker finished first.
    fn eval_wave(&mut self, thetas: &[f64]) -> Result<(), String> {
        let fresh = self.fresh_of(thetas);
        if fresh.is_empty() {
            return Ok(());
        }
        let (provider, opt) = (self.provider, self.opt);
        let results =
            threadpool::par_map(&fresh, 1, |&t| -> Result<(HyperParams, f64, usize), String> {
                // Pin the build itself to the exact serial path: inside a
                // pool worker nested par_* calls inline anyway, but a
                // 1-candidate wave (every golden probe) runs on the
                // calling thread where the eigensolver would otherwise
                // parallelize at the request width — whose block
                // reductions differ from serial by O(eps).  Pinning makes
                // every setup canonical, so cached entries serve
                // identical bits to clients at any thread count.
                let mut obj = threadpool::with_threads(1, || provider.setup(t))?;
                Ok(inner_tune(&mut obj, opt))
            });
        for (&t, r) in fresh.iter().zip(results) {
            let (hp, score, ev) = r?;
            self.inner_evals += ev;
            self.memo.insert(t.to_bits(), (hp, score));
            if score < self.best_score {
                self.best_score = score;
                self.best_hp = hp;
                self.best_theta = t;
            }
        }
        Ok(())
    }

    fn score_of(&self, theta: f64) -> f64 {
        self.memo[&theta.to_bits()].1
    }

    /// Serial golden-section on log10(theta) — the legacy outer stage,
    /// now memoized: probes that alias to an already-solved quantized
    /// theta re-read the score instead of rebuilding the setup, so the
    /// bracket update can never stall on duplicated work.
    fn golden(&mut self, tmin: f64, tmax: f64) -> Result<(), String> {
        let inv_phi = (5f64.sqrt() - 1.0) / 2.0;
        let (mut lo, mut hi) = (tmin.log10(), tmax.log10());
        let q = |logt: f64| quantize_theta(10f64.powf(logt), ThetaDomain::Continuous);

        let mut x1 = hi - inv_phi * (hi - lo);
        let mut x2 = lo + inv_phi * (hi - lo);
        self.eval_wave(&[q(x1)])?;
        let mut f1 = self.score_of(q(x1));
        self.eval_wave(&[q(x2)])?;
        let mut f2 = self.score_of(q(x2));

        for _ in 0..self.opt.outer_iters.saturating_sub(2) {
            if f1 < f2 {
                hi = x2;
                x2 = x1;
                f2 = f1;
                x1 = hi - inv_phi * (hi - lo);
                self.eval_wave(&[q(x1)])?;
                f1 = self.score_of(q(x1));
            } else {
                lo = x1;
                x1 = x2;
                f1 = f2;
                x2 = lo + inv_phi * (hi - lo);
                self.eval_wave(&[q(x2)])?;
                f2 = self.score_of(q(x2));
            }
            if hi - lo < 1e-4 {
                break;
            }
        }
        Ok(())
    }

    /// Parallel bracketing wavefronts: evaluate `width` evenly log-spaced
    /// candidates over the bracket concurrently, shrink the bracket to
    /// the best candidate's immediate neighbors, repeat.  The bracket
    /// endpoints of round k+1 were candidates of round k, so each round
    /// after the first costs at most `width - 2` fresh setups.  A round
    /// that would push the distinct-candidate count past the outer
    /// budget does not start, so `max(outer_iters, width)` is a hard
    /// cap (the first round always completes — the budget cannot cut a
    /// bracket below one full wave).
    fn wavefront(&mut self, tmin: f64, tmax: f64, width: usize) -> Result<(), String> {
        let width =
            if width == 0 { DEFAULT_WAVEFRONT_WIDTH } else { width.clamp(4, MAX_WAVEFRONT_WIDTH) };
        let budget = self.opt.outer_iters.max(width);
        let (mut lo, mut hi) = (tmin.log10(), tmax.log10());
        loop {
            let logts: Vec<f64> = (0..width)
                .map(|i| lo + (hi - lo) * i as f64 / (width - 1) as f64)
                .collect();
            let thetas: Vec<f64> = logts
                .iter()
                .map(|&lt| quantize_theta(10f64.powf(lt), ThetaDomain::Continuous))
                .collect();
            let fresh = self.fresh_of(&thetas).len();
            if !self.memo.is_empty() && self.memo.len() + fresh > budget {
                break;
            }
            self.eval_wave(&thetas)?;
            // best candidate of this round (first index wins ties —
            // deterministic because scores merge in candidate order)
            let mut bi = 0;
            for (i, &t) in thetas.iter().enumerate().skip(1) {
                if self.score_of(t) < self.score_of(thetas[bi]) {
                    bi = i;
                }
            }
            let nlo = logts[bi.saturating_sub(1)];
            let nhi = logts[(bi + 1).min(width - 1)];
            if nhi - nlo >= hi - lo {
                break; // no shrink possible (degenerate/quantized-out bracket)
            }
            lo = nlo;
            hi = nhi;
            if hi - lo < 1e-4 {
                break;
            }
        }
        Ok(())
    }

    /// Discrete sweep for integer theta families: evaluate every integer
    /// degree in range (evenly thinned down to the outer budget when the
    /// range is huge) as a single parallel wavefront.
    ///
    /// Both ends are clamped against wire-reachable abuse: degrees above
    /// `u32::MAX` are meaningless (`Kernel::with_theta` stores a `u32`),
    /// and the candidate count is hard-capped at
    /// [`MAX_DISCRETE_CANDIDATES`] regardless of the requested outer
    /// budget — each candidate is an O(N^3) setup, so an unbounded cap
    /// would let one request allocate/compute without limit.
    fn discrete(&mut self, tmin: f64, tmax: f64) -> Result<(), String> {
        let lo = tmin.ceil().max(1.0);
        let hi = tmax.floor().min(u32::MAX as f64);
        if hi < lo {
            return Err(format!("theta range ({tmin}, {tmax}) contains no integer degree >= 1"));
        }
        let (lo, hi) = (lo as u64, hi as u64);
        let count = hi - lo + 1;
        let cap = (self.opt.outer_iters.max(2) as u64).min(MAX_DISCRETE_CANDIDATES);
        let mut degs: Vec<u64> = if count <= cap {
            (lo..=hi).collect()
        } else {
            // count <= 2^32 and i < cap <= 4096, so (count-1)*i < 2^44
            (0..cap).map(|i| lo + (count - 1) * i / (cap - 1)).collect()
        };
        degs.dedup();
        let thetas: Vec<f64> = degs.into_iter().map(|d| d as f64).collect();
        self.eval_wave(&thetas)
    }
}

/// Run Algorithm 1 through a [`SetupProvider`]: family-aware dispatch
/// (continuous search vs discrete sweep), quantized memoized probes, and
/// truthful setup accounting.  Errors surface provider failures
/// (eigensolver non-convergence, a dead session) and invalid ranges.
pub fn theta_tune<P: SetupProvider>(
    provider: &P,
    opt: &TwoStepOptions,
) -> Result<TwoStepResult, String> {
    let (tmin, tmax) = opt.theta_range;
    if !(tmin.is_finite() && tmax.is_finite() && tmin > 0.0 && tmin < tmax) {
        return Err(format!("theta range must be positive and increasing, got ({tmin}, {tmax})"));
    }
    let built_before = provider.setups_built();
    let mut eng = Engine::new(provider, opt);
    match provider.domain() {
        ThetaDomain::Fixed => {
            return Err("kernel family has no tunable theta".to_string());
        }
        ThetaDomain::Integer => eng.discrete(tmin, tmax)?,
        ThetaDomain::Continuous => match opt.search {
            ThetaSearch::Golden => eng.golden(tmin, tmax)?,
            ThetaSearch::Wavefront { width } => eng.wavefront(tmin, tmax, width)?,
        },
    }
    Ok(TwoStepResult {
        theta: eng.best_theta,
        hp: eng.best_hp,
        score: eng.best_score,
        outer_evals: provider.setups_built() - built_before,
        distinct_thetas: eng.memo.len(),
        inner_evals: eng.inner_evals,
    })
}

/// Run Algorithm 1 over a closure.  `make_objective(theta)` pays the
/// O(N^3) overhead (Gram + eigendecomposition at that kernel
/// hyperparameter) and returns the O(N) objective for the inner loop.
///
/// Compatibility wrapper over [`theta_tune`] + [`FnProvider`]; the
/// closure must be `Fn + Sync` because a wavefront search calls it from
/// pool workers.  Panics on an invalid `theta_range` (the provider
/// itself cannot fail).
pub fn two_step_tune<O, F>(make_objective: F, opt: TwoStepOptions) -> TwoStepResult
where
    O: Objective + Send,
    F: Fn(f64) -> O + Sync,
{
    let provider = FnProvider::new(make_objective);
    theta_tune(&provider, &opt).expect("two_step_tune: invalid theta range")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::Bowl;

    /// Synthetic coupled objective: inner bowl whose depth depends on
    /// theta, with a known best theta at 2.0.
    struct ThetaBowl {
        bowl: Bowl,
        depth: f64,
    }

    impl Objective for ThetaBowl {
        fn eval(&mut self, hp: HyperParams) -> f64 {
            self.bowl.eval(hp) + self.depth
        }
        fn eval_full(&mut self, hp: HyperParams) -> crate::spectral::Evaluation {
            let mut ev = self.bowl.eval_full(hp);
            ev.score += self.depth;
            ev
        }
    }

    fn theta_bowl(theta: f64) -> ThetaBowl {
        ThetaBowl {
            bowl: Bowl::new(0.5, 2.0),
            depth: (theta.ln() - 2f64.ln()).powi(2),
        }
    }

    #[test]
    fn finds_outer_and_inner_optimum() {
        let r = two_step_tune(
            theta_bowl,
            TwoStepOptions { outer_iters: 30, ..Default::default() },
        );
        assert!((r.theta.ln() - 2f64.ln()).abs() < 0.02, "theta={}", r.theta);
        assert!((r.hp.sigma2 - 0.5).abs() < 1e-3, "{:?}", r.hp);
        assert!((r.hp.lambda2 - 2.0).abs() < 1e-3, "{:?}", r.hp);
        assert!(r.outer_evals <= 30);
        assert_eq!(r.outer_evals, r.distinct_thetas, "cold provider: one build per theta");
        assert!(r.inner_evals > r.outer_evals, "inner loop should dominate");
    }

    #[test]
    fn outer_budget_respected() {
        let make = |theta: f64| ThetaBowl { bowl: Bowl::new(1.0, 1.0), depth: theta };
        let r = two_step_tune(
            make,
            TwoStepOptions { outer_iters: 5, ..Default::default() },
        );
        assert!(r.outer_evals <= 5);
        assert!(r.score.is_finite());
    }

    #[test]
    fn wavefront_matches_golden_optimum() {
        let golden = two_step_tune(
            theta_bowl,
            TwoStepOptions { outer_iters: 24, ..Default::default() },
        );
        let wave = two_step_tune(
            theta_bowl,
            TwoStepOptions {
                outer_iters: 64,
                search: ThetaSearch::Wavefront { width: 0 },
                ..Default::default()
            },
        );
        assert!((wave.theta.ln() - 2f64.ln()).abs() < 0.02, "theta={}", wave.theta);
        assert!(
            wave.score <= golden.score + 1e-6 * golden.score.abs().max(1.0),
            "wavefront {} vs golden {}",
            wave.score,
            golden.score
        );
        assert!(wave.outer_evals <= 64);
    }

    #[test]
    fn wavefront_is_deterministic_across_pool_widths() {
        let opt = TwoStepOptions {
            outer_iters: 30,
            search: ThetaSearch::Wavefront { width: 5 },
            ..Default::default()
        };
        let a = crate::util::threadpool::with_threads(1, || two_step_tune(theta_bowl, opt));
        let b = crate::util::threadpool::with_threads(4, || two_step_tune(theta_bowl, opt));
        assert_eq!(a.theta.to_bits(), b.theta.to_bits());
        assert_eq!(a.score.to_bits(), b.score.to_bits());
        assert_eq!(a.hp, b.hp);
        assert_eq!(a.outer_evals, b.outer_evals);
    }

    #[test]
    fn discrete_domain_sweeps_integer_degrees() {
        // best integer degree is 3 (depth minimized at theta = pi)
        let make = |theta: f64| ThetaBowl {
            bowl: Bowl::new(1.0, 1.0),
            depth: (theta - std::f64::consts::PI).powi(2),
        };
        let provider = FnProvider::with_domain(make, ThetaDomain::Integer);
        let r = theta_tune(
            &provider,
            &TwoStepOptions { theta_range: (1.0, 6.0), outer_iters: 10, ..Default::default() },
        )
        .unwrap();
        assert_eq!(r.theta, 3.0);
        assert_eq!(r.outer_evals, 6, "degrees 1..=6, one setup each");
        assert_eq!(r.distinct_thetas, 6);
    }

    #[test]
    fn discrete_sweep_thins_to_outer_budget() {
        let make = |theta: f64| ThetaBowl { bowl: Bowl::new(1.0, 1.0), depth: theta };
        let provider = FnProvider::with_domain(make, ThetaDomain::Integer);
        let r = theta_tune(
            &provider,
            &TwoStepOptions { theta_range: (1.0, 100.0), outer_iters: 8, ..Default::default() },
        )
        .unwrap();
        assert!(r.outer_evals <= 8, "thinned to the outer budget, got {}", r.outer_evals);
        assert_eq!(r.theta, 1.0, "monotone depth: smallest degree wins");
    }

    #[test]
    fn wavefront_width_is_clamped() {
        // width rides in a wire request; the first round is evaluated
        // before the budget can apply, so it must be hard-capped
        let provider = FnProvider::new(theta_bowl);
        let r = theta_tune(
            &provider,
            &TwoStepOptions {
                outer_iters: 4,
                search: ThetaSearch::Wavefront { width: 1_000_000 },
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            r.distinct_thetas <= MAX_WAVEFRONT_WIDTH,
            "width must clamp to {MAX_WAVEFRONT_WIDTH}, probed {}",
            r.distinct_thetas
        );
        assert!(r.score.is_finite());
    }

    #[test]
    fn aliasing_probes_build_one_setup() {
        // a range so narrow every continuous probe quantizes to ~the same
        // theta: the memo must dedupe instead of rebuilding
        let provider = FnProvider::new(theta_bowl);
        let r = theta_tune(
            &provider,
            &TwoStepOptions {
                theta_range: (2.0, 2.0 * (1.0 + 1e-9)),
                outer_iters: 12,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            r.outer_evals <= 2,
            "12 aliasing probes must not build 12 setups, built {}",
            r.outer_evals
        );
        assert_eq!(r.outer_evals, r.distinct_thetas);
        assert!(r.score.is_finite());
    }

    #[test]
    fn invalid_inputs_error() {
        let provider = FnProvider::new(theta_bowl);
        let bad = TwoStepOptions { theta_range: (5.0, 1.0), ..Default::default() };
        assert!(theta_tune(&provider, &bad).is_err());
        let neg = TwoStepOptions { theta_range: (-1.0, 1.0), ..Default::default() };
        assert!(theta_tune(&provider, &neg).is_err());
        let fixed = FnProvider::with_domain(theta_bowl, ThetaDomain::Fixed);
        assert!(theta_tune(&fixed, &TwoStepOptions::default()).is_err());
        // integer range with no admissible degree
        let int = FnProvider::with_domain(theta_bowl, ThetaDomain::Integer);
        let empty = TwoStepOptions { theta_range: (0.1, 0.9), ..Default::default() };
        assert!(theta_tune(&int, &empty).is_err());
    }

    #[test]
    fn quantize_theta_is_idempotent_and_monotone() {
        for &t in &[1e-3, 0.05, 1.0, 2.0, 3.7, 50.0, 1e4] {
            let q = quantize_theta(t, ThetaDomain::Continuous);
            assert_eq!(q.to_bits(), quantize_theta(q, ThetaDomain::Continuous).to_bits());
            assert!((q / t - 1.0).abs() < 1e-5, "{t} -> {q}");
        }
        assert_eq!(quantize_theta(2.9, ThetaDomain::Integer), 3.0);
        assert_eq!(quantize_theta(0.2, ThetaDomain::Integer), 1.0);
        assert_eq!(quantize_theta(f64::NAN, ThetaDomain::Integer), 1.0);
    }
}

//! Algorithm 1 (paper §2.2) as a **theta-plane tuning engine**: two-step
//! tuning when the kernel itself has hyperparameters `theta` (RBF
//! bandwidth, ARD bandwidth vector, Matérn length-scale, polynomial
//! degree, ...).
//!
//! The outer loop moves `theta` — each move costs a fresh Gram matrix and
//! eigendecomposition, O(N^3) — while the inner loop tunes `(sigma2,
//! lambda2)` at O(N) per iterate using the spectral identities.  This
//! module factors that outer loop into three pieces (DESIGN.md §9–10):
//!
//! - [`SetupProvider`] — *where setups come from*: get-or-build the
//!   eigendecomposed setup at a theta vector.  [`FnProvider`] /
//!   [`VecFnProvider`] build fresh every time (the cold path); the
//!   coordinator's session store implements the trait over its
//!   eigen-family cache, so a warm sweep builds nothing.
//! - **Theta quantization** ([`quantize_theta`] / [`quantize_theta_vec`])
//!   — probes are snapped per component to a fixed grid (1e-6 decades
//!   for continuous families, integers for discrete ones) *before* the
//!   setup is built, so two probes closer than the grid alias to one
//!   setup, cache keys are exact concatenated bit patterns
//!   ([`ThetaVec::bits`], `-0.0` canonicalized), and warm re-runs replay
//!   the identical computation.
//! - [`ThetaSearch`] — *how theta moves*: the serial golden-section line
//!   search, the **parallel bracketing wavefront** (each round evaluates
//!   a whole front of candidates concurrently across the thread pool),
//!   or the derivative-free [`ThetaSearch::NelderMead`] /
//!   [`ThetaSearch::Pso`] comparison backends.  For d > 1 the
//!   golden/wavefront searches run as **coordinate descent**: one
//!   bracketed sweep per component with the other components pinned at
//!   the running best, repeated until a full pass stops improving.
//!   Discrete components ([`ThetaDomain::Integer`]) ignore the requested
//!   search and sweep the integer degrees in one wavefront: a continuous
//!   bracket over a rounding family aliases probes to identical scores
//!   and learns nothing between them (see [`Kernel::with_theta`]).
//!
//! The inner stage is controlled by [`TwoStepOptions::refine`]: after the
//! coarse (sigma2, lambda2) grid, [`RefineKind::Newton`] (the default)
//! polishes with [`newton_refine`] on the paper's exact 2×2 Hessian —
//! each Newton step is one fused O(N) evaluation (Props. 2.1–2.3), so
//! refinement costs O(N) per iterate, never O(N^3).
//! [`TwoStepResult::newton_iters`]/[`newton_evals`] report that work.
//!
//! Determinism: the candidate set is a function of `(theta ranges,
//! outer_iters, search)` only — wavefront width defaults to a fixed
//! constant, never the pool width — and every candidate's setup is
//! built with the pool width pinned to 1 (the exact serial path), so
//! each setup is *canonical*: results are bit-identical across thread
//! counts and across cold/warm runs, even when a cached entry built
//! under one request width is served to a client using another (the
//! suite in `rust/tests/theta_engine.rs` gates this).  Parallelism
//! comes from evaluating candidates concurrently, not from inside a
//! setup.
//!
//! [`Kernel::with_theta`]: crate::kernelfn::Kernel::with_theta
//! [`newton_evals`]: TwoStepResult::newton_evals

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};

use super::{newton_refine, Bounds, NewtonOptions, Objective};
use crate::kernelfn::{ThetaDomain, ThetaDomainVec, ThetaVec, ThetaVecBits, MAX_THETA_DIMS};
use crate::spectral::HyperParams;
use crate::util::threadpool;

/// Quantization grid for continuous thetas: probes are snapped to
/// `1/THETA_QUANTA_PER_DECADE` decades, giving 1e6 distinct setups per
/// decade — far below any optimizer's meaningful resolution, and exact
/// enough that a cache keyed by the quantized value's bit pattern never
/// splits one logical probe across two entries.
pub const THETA_QUANTA_PER_DECADE: f64 = 1e6;

/// Candidates per wavefront round when [`ThetaSearch::Wavefront`] is
/// asked for width 0 ("auto").  Deliberately a constant rather than the
/// pool width: the probe set must not depend on how many threads happen
/// to be available, or cold/warm and cross-width results would diverge.
pub const DEFAULT_WAVEFRONT_WIDTH: usize = 8;

/// Hard cap on candidates in a discrete-family sweep, whatever the
/// requested outer budget: each candidate costs an O(N^3) setup, and
/// both the degree range and the budget arrive over the wire.
pub const MAX_DISCRETE_CANDIDATES: u64 = 4096;

/// Hard cap on [`ThetaSearch::Wavefront`] width (the width rides in a
/// wire request, and the first round is evaluated before any budget
/// check can apply — an unclamped width would size allocations and the
/// O(N^3)-per-candidate fan-out directly from attacker input).
pub const MAX_WAVEFRONT_WIDTH: usize = 64;

/// Snap `theta` to the engine's canonical grid for its domain.  Every
/// probe is quantized before the setup is built, so this function *is*
/// the cache-key contract shared by the engine, the providers, and the
/// coordinator's eigen-family cache.  The result is canonicalized so it
/// can never be `-0.0` (whose bit pattern differs from `+0.0` and would
/// key a duplicate cache entry for the same setup — see
/// [`ThetaVec::bits`], which applies the same canonicalization).
pub fn quantize_theta(theta: f64, domain: ThetaDomain) -> f64 {
    let q = match domain {
        ThetaDomain::Integer => {
            if theta.is_finite() {
                theta.round().max(1.0)
            } else {
                1.0
            }
        }
        _ => {
            let q = THETA_QUANTA_PER_DECADE;
            10f64.powf((theta.log10() * q).round() / q)
        }
    };
    // `-0.0 == 0.0`, so this maps -0.0 (and only -0.0) to +0.0
    if q == 0.0 {
        0.0
    } else {
        q
    }
}

/// Per-component [`quantize_theta`] over a theta vector (`domain` must
/// have the same length).
pub fn quantize_theta_vec(theta: &ThetaVec, domain: &ThetaDomainVec) -> ThetaVec {
    assert_eq!(theta.len(), domain.len(), "theta dims != domain dims");
    let mut out = *theta;
    for d in 0..theta.len() {
        out.set(d, quantize_theta(theta.get(d), domain.get(d)));
    }
    out
}

/// Outer-search strategy over theta (continuous components only;
/// discrete components always sweep — see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThetaSearch {
    /// Serial golden-section line search on log10(theta) — the paper's
    /// "conventional line search on the expensive hyperparameter".  For
    /// d > 1: one golden sweep per component under coordinate descent.
    Golden,
    /// Parallel bracketing wavefronts: each round evaluates `width`
    /// evenly log-spaced candidates across the current bracket
    /// concurrently, then shrinks the bracket to the best candidate's
    /// neighbors.  `width: 0` means [`DEFAULT_WAVEFRONT_WIDTH`]; other
    /// values are clamped to `4..=`[`MAX_WAVEFRONT_WIDTH`] (below 4 the
    /// best-candidate-neighbor bracket cannot shrink — at width 3 an
    /// interior best spans the whole bracket — and the width is
    /// wire-reachable, so the top end is capped too).  For d > 1: one
    /// bracketed wavefront per component under coordinate descent.
    Wavefront { width: usize },
    /// Derivative-free Nelder-Mead simplex over the full log10(theta)
    /// vector (any d) — a comparison backend for the wavefront, probing
    /// through the same quantize/memoize pipeline.
    NelderMead,
    /// Particle-swarm search over the full log10(theta) vector (any d)
    /// with a fixed internal seed — deterministic, like every other
    /// search here.
    Pso,
}

impl Default for ThetaSearch {
    fn default() -> Self {
        ThetaSearch::Golden
    }
}

/// How the inner (sigma2, lambda2) solve finishes at each outer
/// candidate (see [`TwoStepOptions::refine`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RefineKind {
    /// Coarse grid, then [`newton_refine`] on the exact O(N) 2×2
    /// Hessian (the default — and the historical behavior, so scalar
    /// results are bit-compatible with earlier releases).
    #[default]
    Newton,
    /// Coarse grid only (isolates the Newton stage's contribution; the
    /// comparison benches use it).
    None,
}

/// Per-component theta ranges for a multi-dimensional outer search.
/// Empty means "scalar request": [`TwoStepOptions::theta_range`]
/// replicates across every provider dimension.  Fixed capacity keeps
/// [`TwoStepOptions`] `Copy`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ThetaRanges {
    len: usize,
    lo: [f64; MAX_THETA_DIMS],
    hi: [f64; MAX_THETA_DIMS],
}

impl Default for ThetaRanges {
    fn default() -> Self {
        ThetaRanges::empty()
    }
}

impl ThetaRanges {
    /// The scalar-request marker: replicate `theta_range` over dims.
    pub fn empty() -> ThetaRanges {
        ThetaRanges { len: 0, lo: [0.0; MAX_THETA_DIMS], hi: [0.0; MAX_THETA_DIMS] }
    }

    /// Explicit per-component ranges; errors when the length is outside
    /// `1..=MAX_THETA_DIMS` (range *values* are validated by
    /// [`theta_tune`], which owns the error message).
    pub fn from_pairs(pairs: &[(f64, f64)]) -> Result<ThetaRanges, String> {
        if pairs.is_empty() || pairs.len() > MAX_THETA_DIMS {
            return Err(format!(
                "theta ranges have {} components (supported: 1..={MAX_THETA_DIMS})",
                pairs.len()
            ));
        }
        let mut r = ThetaRanges::empty();
        for (i, &(lo, hi)) in pairs.iter().enumerate() {
            r.lo[i] = lo;
            r.hi[i] = hi;
        }
        r.len = pairs.len();
        Ok(r)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn get(&self, i: usize) -> (f64, f64) {
        assert!(i < self.len, "theta range {i} out of 0..{}", self.len);
        (self.lo[i], self.hi[i])
    }
}

#[derive(Clone, Copy, Debug)]
pub struct TwoStepOptions {
    /// Bounds for theta (raw, not log), replicated across every
    /// component unless `theta_ranges` is non-empty.
    pub theta_range: (f64, f64),
    /// Per-component theta bounds (multi-dimensional requests).  Must be
    /// empty or match the provider's dimension count.
    pub theta_ranges: ThetaRanges,
    /// Outer evaluation budget.  Golden: probe count (legacy iteration
    /// semantics).  Wavefront: total distinct candidates across rounds,
    /// floored at the wavefront width — the first round always completes,
    /// so the effective budget is `max(outer_iters, width)`.
    /// Discrete sweep: maximum degrees probed (evenly thinned past it).
    /// For d > 1 the budget applies **per component sweep**, not to the
    /// whole coordinate-descent pass (each sweep is the scalar engine on
    /// one axis).
    pub outer_iters: usize,
    /// How the outer stage moves theta.
    pub search: ThetaSearch,
    /// Inner (sigma2, lambda2) bounds.
    pub bounds: Bounds,
    /// Inner coarse-grid resolution before Newton refinement.
    pub inner_grid: usize,
    /// Whether the inner solve polishes the coarse grid with Newton.
    pub refine: RefineKind,
    pub newton: NewtonOptions,
}

impl Default for TwoStepOptions {
    fn default() -> Self {
        TwoStepOptions {
            theta_range: (1e-2, 1e2),
            theta_ranges: ThetaRanges::empty(),
            outer_iters: 20,
            search: ThetaSearch::default(),
            bounds: Bounds::default(),
            inner_grid: 9,
            refine: RefineKind::default(),
            newton: NewtonOptions::default(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TwoStepResult {
    /// Best quantized theta vector (1-component for scalar families).
    pub theta: ThetaVec,
    pub hp: HyperParams,
    pub score: f64,
    /// O(N^3) setups **actually built** by the provider for this run —
    /// not iterations, and never Newton's O(N) re-evaluations: probes
    /// that aliased to an already-evaluated quantized theta, and cache
    /// hits on a warm provider, do not count.
    pub outer_evals: usize,
    /// Distinct quantized thetas whose inner problem was solved
    /// (>= `outer_evals`; the gap is exactly the cache/memo hits).
    pub distinct_thetas: usize,
    /// Total O(N) inner evaluations across all distinct outer points
    /// (coarse grid + Newton).
    pub inner_evals: usize,
    /// Newton iterations accepted across all distinct outer points (0
    /// when [`RefineKind::None`]).
    pub newton_iters: usize,
    /// Fused O(N) evaluations consumed by Newton refinement alone (a
    /// subset of `inner_evals`).
    pub newton_evals: usize,
}

/// Get-or-build the eigendecomposed setup for a (quantized) theta vector
/// and hand back the O(N) inner objective over it.
///
/// `setup` takes `&self` and must be callable concurrently: the
/// wavefront search fans one call per candidate across the thread pool.
/// Implementations count the setups they *really* built (vs served from
/// a cache) so [`TwoStepResult::outer_evals`] stays truthful.
///
/// The setup does not have to be the exact O(N^3) eigendecomposition:
/// [`crate::sparse::SparseProvider`] satisfies the same contract with an
/// O(N m^2) reduced spectrum, which is how the §2.1 exact-vs-sparse
/// comparison drives both methods through one engine (DESIGN.md §13).
pub trait SetupProvider: Sync {
    type Obj: Objective + Send;

    /// The per-component theta domains of the family this provider
    /// builds (drives the family-aware search dispatch; scalar families
    /// report one component).
    fn domain(&self) -> ThetaDomainVec {
        ThetaDomainVec::scalar(ThetaDomain::Continuous)
    }

    /// Build or fetch the setup at `theta` (already quantized by the
    /// engine via [`quantize_theta_vec`]).
    fn setup(&self, theta: &ThetaVec) -> Result<Self::Obj, String>;

    /// Cumulative count of setups actually built (not cache hits).
    fn setups_built(&self) -> usize;
}

/// [`SetupProvider`] over a plain scalar closure: builds a fresh setup
/// per distinct quantized theta — the cold, cache-less path used by
/// [`two_step_tune`], the benches, and tests.  One-dimensional by
/// construction; use [`VecFnProvider`] for d > 1.
pub struct FnProvider<F> {
    f: F,
    domain: ThetaDomain,
    built: AtomicUsize,
}

impl<F> FnProvider<F> {
    /// Provider over a continuous theta family.
    pub fn new(f: F) -> Self {
        FnProvider::with_domain(f, ThetaDomain::Continuous)
    }

    /// Provider with an explicit domain (e.g. [`ThetaDomain::Integer`]
    /// for a polynomial-degree sweep).
    pub fn with_domain(f: F, domain: ThetaDomain) -> Self {
        FnProvider { f, domain, built: AtomicUsize::new(0) }
    }
}

impl<O, F> SetupProvider for FnProvider<F>
where
    O: Objective + Send,
    F: Fn(f64) -> O + Sync,
{
    type Obj = O;

    fn domain(&self) -> ThetaDomainVec {
        ThetaDomainVec::scalar(self.domain)
    }

    fn setup(&self, theta: &ThetaVec) -> Result<O, String> {
        self.built.fetch_add(1, Ordering::Relaxed);
        Ok((self.f)(theta.get(0)))
    }

    fn setups_built(&self) -> usize {
        self.built.load(Ordering::Relaxed)
    }
}

/// [`SetupProvider`] over a vector closure with an explicit
/// per-component domain — the cold path for multi-dimensional (ARD)
/// families.
pub struct VecFnProvider<F> {
    f: F,
    domain: ThetaDomainVec,
    built: AtomicUsize,
}

impl<F> VecFnProvider<F> {
    pub fn new(f: F, domain: ThetaDomainVec) -> Self {
        VecFnProvider { f, domain, built: AtomicUsize::new(0) }
    }
}

impl<O, F> SetupProvider for VecFnProvider<F>
where
    O: Objective + Send,
    F: Fn(&ThetaVec) -> O + Sync,
{
    type Obj = O;

    fn domain(&self) -> ThetaDomainVec {
        self.domain
    }

    fn setup(&self, theta: &ThetaVec) -> Result<O, String> {
        self.built.fetch_add(1, Ordering::Relaxed);
        Ok((self.f)(theta))
    }

    fn setups_built(&self) -> usize {
        self.built.load(Ordering::Relaxed)
    }
}

/// Outcome of one inner (sigma2, lambda2) solve.
struct InnerOutcome {
    hp: HyperParams,
    score: f64,
    evals: usize,
    newton_iters: usize,
    newton_evals: usize,
}

/// Inner solve: coarse grid, then (by default) Newton on the exact O(N)
/// 2×2 Hessian.  The Newton path is unchanged from the pre-engine
/// implementation, so scalar scores stay bit-compatible; `newton_refine`
/// accepts only strict improvements, so its score can never exceed the
/// coarse-grid score it starts from.
fn inner_tune<O: Objective>(obj: &mut O, opt: &TwoStepOptions) -> InnerOutcome {
    let coarse = super::grid_search(obj, opt.bounds, opt.inner_grid, 64);
    match opt.refine {
        RefineKind::Newton => {
            let refined = newton_refine(obj, coarse.hp, opt.bounds, opt.newton);
            InnerOutcome {
                hp: refined.hp,
                score: refined.score,
                evals: coarse.evals + refined.evals,
                newton_iters: refined.iters,
                newton_evals: refined.evals,
            }
        }
        RefineKind::None => InnerOutcome {
            hp: coarse.hp,
            score: coarse.score,
            evals: coarse.evals,
            newton_iters: 0,
            newton_evals: 0,
        },
    }
}

/// The candidates of `thetas` not yet in `seen`, deduped by bit key, in
/// first-seen order — the single definition of "what a wave will
/// actually evaluate", shared by the evaluation and the budget checks so
/// the two can never disagree.
fn fresh_against(seen: &dyn Fn(&ThetaVecBits) -> bool, thetas: &[ThetaVec]) -> Vec<ThetaVec> {
    let mut fresh: Vec<ThetaVec> = Vec::new();
    for t in thetas {
        let k = t.bits();
        if !seen(&k) && !fresh.iter().any(|f| f.bits() == k) {
            fresh.push(*t);
        }
    }
    fresh
}

/// Engine state shared by the search strategies: the memo of solved
/// thetas (keyed by concatenated quantized bit patterns) and the running
/// best.
struct Engine<'a, P: SetupProvider> {
    provider: &'a P,
    opt: &'a TwoStepOptions,
    dom: ThetaDomainVec,
    /// quantized-theta bits -> (inner hp, inner score)
    memo: HashMap<ThetaVecBits, (HyperParams, f64)>,
    best_theta: ThetaVec,
    best_hp: HyperParams,
    best_score: f64,
    inner_evals: usize,
    newton_iters: usize,
    newton_evals: usize,
}

impl<'a, P: SetupProvider> Engine<'a, P> {
    fn new(provider: &'a P, opt: &'a TwoStepOptions, dom: ThetaDomainVec) -> Self {
        Engine {
            provider,
            opt,
            dom,
            memo: HashMap::new(),
            best_theta: ThetaVec::splat(dom.len().max(1), f64::NAN),
            best_hp: HyperParams::new(1.0, 1.0),
            best_score: f64::INFINITY,
            inner_evals: 0,
            newton_iters: 0,
            newton_evals: 0,
        }
    }

    fn fresh_of(&self, thetas: &[ThetaVec]) -> Vec<ThetaVec> {
        fresh_against(&|k| self.memo.contains_key(k), thetas)
    }

    /// Evaluate one wavefront of (already quantized) candidates.  Thetas
    /// already memoized are free; the fresh ones fan out across the pool
    /// — each worker pays the provider's setup (O(N^3) when cold) plus
    /// the O(N)-per-iterate inner tune.  Results merge in candidate
    /// order, so ties and the running best are deterministic regardless
    /// of which worker finished first.
    fn eval_wave(&mut self, thetas: &[ThetaVec]) -> Result<(), String> {
        let fresh = self.fresh_of(thetas);
        if fresh.is_empty() {
            return Ok(());
        }
        let (provider, opt) = (self.provider, self.opt);
        let results = threadpool::par_map(&fresh, 1, |t| -> Result<InnerOutcome, String> {
            // Pin the build itself to the exact serial path: inside a
            // pool worker nested par_* calls inline anyway, but a
            // 1-candidate wave (every golden probe) runs on the
            // calling thread where the eigensolver would otherwise
            // parallelize at the request width — whose block
            // reductions differ from serial by O(eps).  Pinning makes
            // every setup canonical, so cached entries serve
            // identical bits to clients at any thread count.
            let mut obj = threadpool::with_threads(1, || provider.setup(t))?;
            Ok(inner_tune(&mut obj, opt))
        });
        for (t, r) in fresh.iter().zip(results) {
            let out = r?;
            self.inner_evals += out.evals;
            self.newton_iters += out.newton_iters;
            self.newton_evals += out.newton_evals;
            self.memo.insert(t.bits(), (out.hp, out.score));
            if out.score < self.best_score {
                self.best_score = out.score;
                self.best_hp = out.hp;
                self.best_theta = *t;
            }
        }
        Ok(())
    }

    fn score_of(&self, theta: &ThetaVec) -> f64 {
        self.memo[&theta.bits()].1
    }

    /// Serial golden-section on log10 of component `d` (the other
    /// components pinned at `cur`) — the legacy outer stage, memoized:
    /// probes that alias to an already-solved quantized theta re-read
    /// the score instead of rebuilding the setup, so the bracket update
    /// can never stall on duplicated work.
    fn golden_dim(&mut self, cur: &ThetaVec, d: usize, tmin: f64, tmax: f64) -> Result<(), String> {
        let inv_phi = (5f64.sqrt() - 1.0) / 2.0;
        let (mut lo, mut hi) = (tmin.log10(), tmax.log10());
        let q = |logt: f64| {
            let mut t = *cur;
            t.set(d, quantize_theta(10f64.powf(logt), ThetaDomain::Continuous));
            t
        };

        let mut x1 = hi - inv_phi * (hi - lo);
        let mut x2 = lo + inv_phi * (hi - lo);
        self.eval_wave(&[q(x1)])?;
        let mut f1 = self.score_of(&q(x1));
        self.eval_wave(&[q(x2)])?;
        let mut f2 = self.score_of(&q(x2));

        for _ in 0..self.opt.outer_iters.saturating_sub(2) {
            if f1 < f2 {
                hi = x2;
                x2 = x1;
                f2 = f1;
                x1 = hi - inv_phi * (hi - lo);
                self.eval_wave(&[q(x1)])?;
                f1 = self.score_of(&q(x1));
            } else {
                lo = x1;
                x1 = x2;
                f1 = f2;
                x2 = lo + inv_phi * (hi - lo);
                self.eval_wave(&[q(x2)])?;
                f2 = self.score_of(&q(x2));
            }
            if hi - lo < 1e-4 {
                break;
            }
        }
        Ok(())
    }

    /// Parallel bracketing wavefronts over component `d` (the other
    /// components pinned at `cur`): evaluate `width` evenly log-spaced
    /// candidates over the bracket concurrently, shrink the bracket to
    /// the best candidate's immediate neighbors, repeat.  The bracket
    /// endpoints of round k+1 were candidates of round k, so each round
    /// after the first costs at most `width - 2` fresh setups.  A round
    /// that would push this sweep's distinct-candidate count past the
    /// outer budget does not start, so `max(outer_iters, width)` is a
    /// hard per-sweep cap (the first round always completes — the budget
    /// cannot cut a bracket below one full wave).
    fn wavefront_dim(
        &mut self,
        cur: &ThetaVec,
        d: usize,
        tmin: f64,
        tmax: f64,
        width: usize,
    ) -> Result<(), String> {
        let width =
            if width == 0 { DEFAULT_WAVEFRONT_WIDTH } else { width.clamp(4, MAX_WAVEFRONT_WIDTH) };
        let budget = self.opt.outer_iters.max(width);
        let (mut lo, mut hi) = (tmin.log10(), tmax.log10());
        // this sweep's own candidate ledger: for a 1-D run it coincides
        // with the engine memo (preserving the historical budget
        // semantics bit-for-bit); under coordinate descent it keeps one
        // axis sweep from starving the next
        let mut seen: HashSet<ThetaVecBits> = HashSet::new();
        loop {
            let logts: Vec<f64> = (0..width)
                .map(|i| lo + (hi - lo) * i as f64 / (width - 1) as f64)
                .collect();
            let thetas: Vec<ThetaVec> = logts
                .iter()
                .map(|&lt| {
                    let mut t = *cur;
                    t.set(d, quantize_theta(10f64.powf(lt), ThetaDomain::Continuous));
                    t
                })
                .collect();
            let fresh = fresh_against(&|k| seen.contains(k), &thetas).len();
            if !seen.is_empty() && seen.len() + fresh > budget {
                break;
            }
            self.eval_wave(&thetas)?;
            for t in &thetas {
                seen.insert(t.bits());
            }
            // best candidate of this round (first index wins ties —
            // deterministic because scores merge in candidate order)
            let mut bi = 0;
            for (i, t) in thetas.iter().enumerate().skip(1) {
                if self.score_of(t) < self.score_of(&thetas[bi]) {
                    bi = i;
                }
            }
            let nlo = logts[bi.saturating_sub(1)];
            let nhi = logts[(bi + 1).min(width - 1)];
            if nhi - nlo >= hi - lo {
                break; // no shrink possible (degenerate/quantized-out bracket)
            }
            lo = nlo;
            hi = nhi;
            if hi - lo < 1e-4 {
                break;
            }
        }
        Ok(())
    }

    /// Discrete sweep of component `d` for integer theta families:
    /// evaluate every integer degree in range (evenly thinned down to
    /// the outer budget when the range is huge) as a single parallel
    /// wavefront.
    ///
    /// Both ends are clamped against wire-reachable abuse: degrees above
    /// `u32::MAX` are meaningless (`Kernel::with_theta` stores a `u32`),
    /// and the candidate count is hard-capped at
    /// [`MAX_DISCRETE_CANDIDATES`] regardless of the requested outer
    /// budget — each candidate is an O(N^3) setup, so an unbounded cap
    /// would let one request allocate/compute without limit.
    fn discrete_dim(
        &mut self,
        cur: &ThetaVec,
        d: usize,
        tmin: f64,
        tmax: f64,
    ) -> Result<(), String> {
        let lo = tmin.ceil().max(1.0);
        let hi = tmax.floor().min(u32::MAX as f64);
        if hi < lo {
            return Err(format!("theta range ({tmin}, {tmax}) contains no integer degree >= 1"));
        }
        let (lo, hi) = (lo as u64, hi as u64);
        let count = hi - lo + 1;
        let cap = (self.opt.outer_iters.max(2) as u64).min(MAX_DISCRETE_CANDIDATES);
        let mut degs: Vec<u64> = if count <= cap {
            (lo..=hi).collect()
        } else {
            // count <= 2^32 and i < cap <= 4096, so (count-1)*i < 2^44
            (0..cap).map(|i| lo + (count - 1) * i / (cap - 1)).collect()
        };
        degs.dedup();
        let thetas: Vec<ThetaVec> = degs
            .into_iter()
            .map(|deg| {
                let mut t = *cur;
                t.set(d, deg as f64);
                t
            })
            .collect();
        self.eval_wave(&thetas)
    }

    /// One bracketed sweep of component `d`, dispatched on that
    /// component's domain and the requested search.
    fn sweep_dim(&mut self, cur: &ThetaVec, d: usize, range: (f64, f64)) -> Result<(), String> {
        match self.dom.get(d) {
            ThetaDomain::Integer => self.discrete_dim(cur, d, range.0, range.1),
            _ => match self.opt.search {
                ThetaSearch::Golden => self.golden_dim(cur, d, range.0, range.1),
                ThetaSearch::Wavefront { width } => {
                    self.wavefront_dim(cur, d, range.0, range.1, width)
                }
                // mixed-domain fallback when a full-vector search cannot
                // run: default-width wavefront on the continuous axis
                ThetaSearch::NelderMead | ThetaSearch::Pso => {
                    self.wavefront_dim(cur, d, range.0, range.1, 0)
                }
            },
        }
    }

    /// The quantized geometric midpoint of every component's range — the
    /// starting point that pins off-axis components before their own
    /// sweep has run.
    fn start_point(&self, ranges: &[(f64, f64)]) -> ThetaVec {
        let mut cur = ThetaVec::splat(ranges.len(), 1.0);
        for (d, &(lo, hi)) in ranges.iter().enumerate() {
            let mid = 10f64.powf(0.5 * (lo.log10() + hi.log10()));
            cur.set(d, quantize_theta(mid, self.dom.get(d)));
        }
        cur
    }

    /// Golden/wavefront/discrete dispatch.  d == 1 is exactly one sweep
    /// — the scalar engine, bit-for-bit.  d > 1 runs coordinate descent:
    /// sweep each component in turn with the others pinned at the
    /// running best, until a full pass stops improving (or builds
    /// nothing fresh), with a fixed pass cap as a backstop.
    fn coordinate_descent(&mut self, ranges: &[(f64, f64)]) -> Result<(), String> {
        let dims = ranges.len();
        let mut cur = self.start_point(ranges);
        if dims == 1 {
            return self.sweep_dim(&cur, 0, ranges[0]);
        }
        const MAX_PASSES: usize = 8;
        for _ in 0..MAX_PASSES {
            let score_before = self.best_score;
            let solved_before = self.memo.len();
            for (d, &range) in ranges.iter().enumerate() {
                self.sweep_dim(&cur, d, range)?;
                if self.best_score < f64::INFINITY {
                    cur = self.best_theta;
                }
            }
            let improved = self.best_score < score_before;
            if !improved || self.memo.len() == solved_before {
                break;
            }
        }
        Ok(())
    }

    /// Quantize a log10-space probe point, evaluate it through the memo,
    /// and return its score — the shared probe used by the Nelder-Mead
    /// and PSO backends.  Fresh probes past the outer budget are not
    /// built; they report +inf so the search turns back toward explored
    /// territory (deterministically).
    fn probe(&mut self, logt: &[f64], budget: usize, err: &mut Option<String>) -> f64 {
        let mut t = ThetaVec::splat(logt.len(), 1.0);
        for (d, &lt) in logt.iter().enumerate() {
            t.set(d, quantize_theta(10f64.powf(lt), self.dom.get(d)));
        }
        if let Some(&(_, score)) = self.memo.get(&t.bits()) {
            return score;
        }
        if self.memo.len() >= budget {
            return f64::INFINITY;
        }
        match self.eval_wave(std::slice::from_ref(&t)) {
            Ok(()) => self.score_of(&t),
            Err(e) => {
                if err.is_none() {
                    *err = Some(e);
                }
                f64::INFINITY
            }
        }
    }

    /// Nelder-Mead over the full log10(theta) vector through the
    /// quantize/memoize probe.
    fn nelder_mead_theta(&mut self, ranges: &[(f64, f64)]) -> Result<(), String> {
        let budget = self.opt.outer_iters.max(2);
        let lo: Vec<f64> = ranges.iter().map(|r| r.0.log10()).collect();
        let hi: Vec<f64> = ranges.iter().map(|r| r.1.log10()).collect();
        let start: Vec<f64> = lo.iter().zip(&hi).map(|(&l, &h)| 0.5 * (l + h)).collect();
        let mut err: Option<String> = None;
        {
            let mut f = |p: &[f64]| self.probe(p, budget, &mut err);
            super::neldermead::nelder_mead_vec(&mut f, &start, &lo, &hi, 0.25, 4 * budget, 1e-10);
        }
        match err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// PSO over the full log10(theta) vector through the
    /// quantize/memoize probe (fixed internal seed — deterministic).
    fn pso_theta(&mut self, ranges: &[(f64, f64)]) -> Result<(), String> {
        let budget = self.opt.outer_iters.max(2);
        let lo: Vec<f64> = ranges.iter().map(|r| r.0.log10()).collect();
        let hi: Vec<f64> = ranges.iter().map(|r| r.1.log10()).collect();
        let popt = super::PsoOptions {
            particles: 8,
            iterations: (4 * budget / 8).max(4),
            seed: 0x7e7a_5eed,
            ..Default::default()
        };
        let mut err: Option<String> = None;
        {
            let mut f = |p: &[f64]| self.probe(p, budget, &mut err);
            super::pso::pso_search_vec(&mut f, &lo, &hi, popt);
        }
        match err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Run Algorithm 1 through a [`SetupProvider`]: family-aware dispatch
/// (continuous search vs discrete sweep, scalar sweep vs coordinate
/// descent for d > 1), quantized memoized probes, and truthful setup
/// accounting.  Errors surface provider failures (eigensolver
/// non-convergence, a dead session) and invalid ranges.
pub fn theta_tune<P: SetupProvider>(
    provider: &P,
    opt: &TwoStepOptions,
) -> Result<TwoStepResult, String> {
    // validate the requested ranges first (scalar requests keep the
    // historical error precedence)
    if opt.theta_ranges.is_empty() {
        let (tmin, tmax) = opt.theta_range;
        if !(tmin.is_finite() && tmax.is_finite() && tmin > 0.0 && tmin < tmax) {
            return Err(format!(
                "theta range must be positive and increasing, got ({tmin}, {tmax})"
            ));
        }
    } else {
        for i in 0..opt.theta_ranges.len() {
            let (tmin, tmax) = opt.theta_ranges.get(i);
            if !(tmin.is_finite() && tmax.is_finite() && tmin > 0.0 && tmin < tmax) {
                return Err(format!(
                    "theta range must be positive and increasing, got ({tmin}, {tmax})"
                ));
            }
        }
    }
    let dom = provider.domain();
    let dims = dom.len();
    if dims == 0 || (0..dims).any(|d| dom.get(d) == ThetaDomain::Fixed) {
        return Err("kernel family has no tunable theta".to_string());
    }
    let ranges: Vec<(f64, f64)> = if opt.theta_ranges.is_empty() {
        vec![opt.theta_range; dims]
    } else {
        if opt.theta_ranges.len() != dims {
            return Err(format!(
                "theta ranges have {} components; the kernel family has {dims}",
                opt.theta_ranges.len()
            ));
        }
        (0..dims).map(|i| opt.theta_ranges.get(i)).collect()
    };

    let built_before = provider.setups_built();
    let mut eng = Engine::new(provider, opt, dom);
    let all_continuous = (0..dims).all(|d| dom.get(d) == ThetaDomain::Continuous);
    match opt.search {
        ThetaSearch::NelderMead if all_continuous => eng.nelder_mead_theta(&ranges)?,
        ThetaSearch::Pso if all_continuous => eng.pso_theta(&ranges)?,
        _ => eng.coordinate_descent(&ranges)?,
    }
    Ok(TwoStepResult {
        theta: eng.best_theta,
        hp: eng.best_hp,
        score: eng.best_score,
        outer_evals: provider.setups_built() - built_before,
        distinct_thetas: eng.memo.len(),
        inner_evals: eng.inner_evals,
        newton_iters: eng.newton_iters,
        newton_evals: eng.newton_evals,
    })
}

/// Run Algorithm 1 over a scalar closure.  `make_objective(theta)` pays
/// the O(N^3) overhead (Gram + eigendecomposition at that kernel
/// hyperparameter) and returns the O(N) objective for the inner loop.
///
/// Compatibility wrapper over [`theta_tune`] + [`FnProvider`]; the
/// closure must be `Fn + Sync` because a wavefront search calls it from
/// pool workers.  Panics on an invalid `theta_range` (the provider
/// itself cannot fail).
pub fn two_step_tune<O, F>(make_objective: F, opt: TwoStepOptions) -> TwoStepResult
where
    O: Objective + Send,
    F: Fn(f64) -> O + Sync,
{
    let provider = FnProvider::new(make_objective);
    theta_tune(&provider, &opt).expect("two_step_tune: invalid theta range")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::Bowl;

    /// Synthetic coupled objective: inner bowl whose depth depends on
    /// theta, with a known best theta at 2.0.
    struct ThetaBowl {
        bowl: Bowl,
        depth: f64,
    }

    impl Objective for ThetaBowl {
        fn eval(&mut self, hp: HyperParams) -> f64 {
            self.bowl.eval(hp) + self.depth
        }
        fn eval_full(&mut self, hp: HyperParams) -> crate::spectral::Evaluation {
            let mut ev = self.bowl.eval_full(hp);
            ev.score += self.depth;
            ev
        }
    }

    fn theta_bowl(theta: f64) -> ThetaBowl {
        ThetaBowl {
            bowl: Bowl::new(0.5, 2.0),
            depth: (theta.ln() - 2f64.ln()).powi(2),
        }
    }

    /// 2-D variant with a separable optimum at (2.0, 0.5).
    fn theta_bowl2(theta: &ThetaVec) -> ThetaBowl {
        ThetaBowl {
            bowl: Bowl::new(0.5, 2.0),
            depth: (theta.get(0).ln() - 2f64.ln()).powi(2)
                + (theta.get(1).ln() - 0.5f64.ln()).powi(2),
        }
    }

    #[test]
    fn finds_outer_and_inner_optimum() {
        let r = two_step_tune(
            theta_bowl,
            TwoStepOptions { outer_iters: 30, ..Default::default() },
        );
        assert_eq!(r.theta.len(), 1, "scalar family tunes a 1-vector");
        assert!((r.theta.get(0).ln() - 2f64.ln()).abs() < 0.02, "theta={:?}", r.theta);
        assert!((r.hp.sigma2 - 0.5).abs() < 1e-3, "{:?}", r.hp);
        assert!((r.hp.lambda2 - 2.0).abs() < 1e-3, "{:?}", r.hp);
        assert!(r.outer_evals <= 30);
        assert_eq!(r.outer_evals, r.distinct_thetas, "cold provider: one build per theta");
        assert!(r.inner_evals > r.outer_evals, "inner loop should dominate");
        assert!(r.newton_evals > 0, "default refine runs Newton");
        assert!(r.newton_evals < r.inner_evals, "Newton is a subset of the inner work");
    }

    #[test]
    fn outer_budget_respected() {
        let make = |theta: f64| ThetaBowl { bowl: Bowl::new(1.0, 1.0), depth: theta };
        let r = two_step_tune(
            make,
            TwoStepOptions { outer_iters: 5, ..Default::default() },
        );
        assert!(r.outer_evals <= 5);
        assert!(r.score.is_finite());
    }

    #[test]
    fn wavefront_matches_golden_optimum() {
        let golden = two_step_tune(
            theta_bowl,
            TwoStepOptions { outer_iters: 24, ..Default::default() },
        );
        let wave = two_step_tune(
            theta_bowl,
            TwoStepOptions {
                outer_iters: 64,
                search: ThetaSearch::Wavefront { width: 0 },
                ..Default::default()
            },
        );
        assert!((wave.theta.get(0).ln() - 2f64.ln()).abs() < 0.02, "theta={:?}", wave.theta);
        assert!(
            wave.score <= golden.score + 1e-6 * golden.score.abs().max(1.0),
            "wavefront {} vs golden {}",
            wave.score,
            golden.score
        );
        assert!(wave.outer_evals <= 64);
    }

    #[test]
    fn wavefront_is_deterministic_across_pool_widths() {
        let opt = TwoStepOptions {
            outer_iters: 30,
            search: ThetaSearch::Wavefront { width: 5 },
            ..Default::default()
        };
        let a = crate::util::threadpool::with_threads(1, || two_step_tune(theta_bowl, opt));
        let b = crate::util::threadpool::with_threads(4, || two_step_tune(theta_bowl, opt));
        assert_eq!(a.theta.bits(), b.theta.bits());
        assert_eq!(a.score.to_bits(), b.score.to_bits());
        assert_eq!(a.hp, b.hp);
        assert_eq!(a.outer_evals, b.outer_evals);
        assert_eq!(a.newton_iters, b.newton_iters);
        assert_eq!(a.newton_evals, b.newton_evals);
    }

    #[test]
    fn discrete_domain_sweeps_integer_degrees() {
        // best integer degree is 3 (depth minimized at theta = pi)
        let make = |theta: f64| ThetaBowl {
            bowl: Bowl::new(1.0, 1.0),
            depth: (theta - std::f64::consts::PI).powi(2),
        };
        let provider = FnProvider::with_domain(make, ThetaDomain::Integer);
        let r = theta_tune(
            &provider,
            &TwoStepOptions { theta_range: (1.0, 6.0), outer_iters: 10, ..Default::default() },
        )
        .unwrap();
        assert_eq!(r.theta.get(0), 3.0);
        assert_eq!(r.outer_evals, 6, "degrees 1..=6, one setup each");
        assert_eq!(r.distinct_thetas, 6);
    }

    #[test]
    fn discrete_sweep_thins_to_outer_budget() {
        let make = |theta: f64| ThetaBowl { bowl: Bowl::new(1.0, 1.0), depth: theta };
        let provider = FnProvider::with_domain(make, ThetaDomain::Integer);
        let r = theta_tune(
            &provider,
            &TwoStepOptions { theta_range: (1.0, 100.0), outer_iters: 8, ..Default::default() },
        )
        .unwrap();
        assert!(r.outer_evals <= 8, "thinned to the outer budget, got {}", r.outer_evals);
        assert_eq!(r.theta.get(0), 1.0, "monotone depth: smallest degree wins");
    }

    #[test]
    fn wavefront_width_is_clamped() {
        // width rides in a wire request; the first round is evaluated
        // before the budget can apply, so it must be hard-capped
        let provider = FnProvider::new(theta_bowl);
        let r = theta_tune(
            &provider,
            &TwoStepOptions {
                outer_iters: 4,
                search: ThetaSearch::Wavefront { width: 1_000_000 },
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            r.distinct_thetas <= MAX_WAVEFRONT_WIDTH,
            "width must clamp to {MAX_WAVEFRONT_WIDTH}, probed {}",
            r.distinct_thetas
        );
        assert!(r.score.is_finite());
    }

    #[test]
    fn aliasing_probes_build_one_setup() {
        // a range so narrow every continuous probe quantizes to ~the same
        // theta: the memo must dedupe instead of rebuilding
        let provider = FnProvider::new(theta_bowl);
        let r = theta_tune(
            &provider,
            &TwoStepOptions {
                theta_range: (2.0, 2.0 * (1.0 + 1e-9)),
                outer_iters: 12,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            r.outer_evals <= 2,
            "12 aliasing probes must not build 12 setups, built {}",
            r.outer_evals
        );
        assert_eq!(r.outer_evals, r.distinct_thetas);
        assert!(r.score.is_finite());
    }

    #[test]
    fn invalid_inputs_error() {
        let provider = FnProvider::new(theta_bowl);
        let bad = TwoStepOptions { theta_range: (5.0, 1.0), ..Default::default() };
        assert!(theta_tune(&provider, &bad).is_err());
        let neg = TwoStepOptions { theta_range: (-1.0, 1.0), ..Default::default() };
        assert!(theta_tune(&provider, &neg).is_err());
        let fixed = FnProvider::with_domain(theta_bowl, ThetaDomain::Fixed);
        assert!(theta_tune(&fixed, &TwoStepOptions::default()).is_err());
        // integer range with no admissible degree
        let int = FnProvider::with_domain(theta_bowl, ThetaDomain::Integer);
        let empty = TwoStepOptions { theta_range: (0.1, 0.9), ..Default::default() };
        assert!(theta_tune(&int, &empty).is_err());
        // vector ranges must match the provider's dimensions
        let mismatched = TwoStepOptions {
            theta_ranges: ThetaRanges::from_pairs(&[(0.1, 1.0), (0.1, 1.0)]).unwrap(),
            ..Default::default()
        };
        let err = theta_tune(&provider, &mismatched).unwrap_err();
        assert!(err.contains("2 components"), "{err}");
        // per-component range values are validated like scalar ones
        let badvec = TwoStepOptions {
            theta_ranges: ThetaRanges::from_pairs(&[(5.0, 1.0)]).unwrap(),
            ..Default::default()
        };
        assert!(theta_tune(&provider, &badvec).is_err());
    }

    #[test]
    fn quantize_theta_is_idempotent_and_monotone() {
        for &t in &[1e-3, 0.05, 1.0, 2.0, 3.7, 50.0, 1e4] {
            let q = quantize_theta(t, ThetaDomain::Continuous);
            assert_eq!(q.to_bits(), quantize_theta(q, ThetaDomain::Continuous).to_bits());
            assert!((q / t - 1.0).abs() < 1e-5, "{t} -> {q}");
        }
        assert_eq!(quantize_theta(2.9, ThetaDomain::Integer), 3.0);
        assert_eq!(quantize_theta(0.2, ThetaDomain::Integer), 1.0);
        assert_eq!(quantize_theta(f64::NAN, ThetaDomain::Integer), 1.0);
    }

    #[test]
    fn quantize_canonicalizes_negative_zero() {
        // -0.0 == 0.0 yet their bit patterns differ; before this fix the
        // two keyed distinct eigen-family cache entries for one setup
        assert_ne!((-0.0f64).to_bits(), 0.0f64.to_bits(), "premise");
        let qn = quantize_theta(-0.0, ThetaDomain::Continuous);
        let qp = quantize_theta(0.0, ThetaDomain::Continuous);
        assert_eq!(qn.to_bits(), qp.to_bits());
        assert_eq!(qn.to_bits(), 0.0f64.to_bits(), "canonical form is +0.0");
        // and the vector key applies the same canonicalization
        let dom = ThetaDomainVec::uniform(2, ThetaDomain::Continuous);
        let a = quantize_theta_vec(&ThetaVec::from_slice(&[1.0, -0.0]).unwrap(), &dom);
        let b = quantize_theta_vec(&ThetaVec::from_slice(&[1.0, 0.0]).unwrap(), &dom);
        assert_eq!(a.bits(), b.bits());
    }

    #[test]
    fn vector_coordinate_descent_finds_separable_optimum() {
        let provider =
            VecFnProvider::new(theta_bowl2, ThetaDomainVec::uniform(2, ThetaDomain::Continuous));
        let r = theta_tune(
            &provider,
            &TwoStepOptions {
                theta_range: (0.05, 50.0),
                outer_iters: 24,
                search: ThetaSearch::Wavefront { width: 0 },
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(r.theta.len(), 2);
        assert!((r.theta.get(0).ln() - 2f64.ln()).abs() < 0.05, "theta={:?}", r.theta);
        assert!((r.theta.get(1).ln() - 0.5f64.ln()).abs() < 0.05, "theta={:?}", r.theta);
        assert_eq!(r.outer_evals, r.distinct_thetas, "cold provider: one build per theta");
    }

    #[test]
    fn vector_per_component_ranges_constrain_each_axis() {
        let provider =
            VecFnProvider::new(theta_bowl2, ThetaDomainVec::uniform(2, ThetaDomain::Continuous));
        // clamp component 1 away from its optimum at 0.5
        let r = theta_tune(
            &provider,
            &TwoStepOptions {
                theta_ranges: ThetaRanges::from_pairs(&[(0.05, 50.0), (1.0, 50.0)]).unwrap(),
                outer_iters: 24,
                search: ThetaSearch::Wavefront { width: 0 },
                ..Default::default()
            },
        )
        .unwrap();
        assert!(r.theta.get(1) >= 1.0 - 1e-9, "range violated: {:?}", r.theta);
        assert!((r.theta.get(0).ln() - 2f64.ln()).abs() < 0.05, "theta={:?}", r.theta);
    }

    #[test]
    fn vector_wavefront_is_deterministic_across_pool_widths() {
        let run = || {
            let provider = VecFnProvider::new(
                theta_bowl2,
                ThetaDomainVec::uniform(2, ThetaDomain::Continuous),
            );
            theta_tune(
                &provider,
                &TwoStepOptions {
                    theta_range: (0.05, 50.0),
                    outer_iters: 20,
                    search: ThetaSearch::Wavefront { width: 5 },
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let a = crate::util::threadpool::with_threads(1, run);
        let b = crate::util::threadpool::with_threads(4, run);
        assert_eq!(a.theta.bits(), b.theta.bits());
        assert_eq!(a.score.to_bits(), b.score.to_bits());
        assert_eq!(a.outer_evals, b.outer_evals);
    }

    #[test]
    fn refine_none_skips_newton_and_never_beats_it() {
        let with = two_step_tune(
            theta_bowl,
            TwoStepOptions { outer_iters: 16, ..Default::default() },
        );
        let without = two_step_tune(
            theta_bowl,
            TwoStepOptions { outer_iters: 16, refine: RefineKind::None, ..Default::default() },
        );
        assert_eq!(without.newton_iters, 0);
        assert_eq!(without.newton_evals, 0);
        assert!(with.newton_evals > 0);
        // newton_refine accepts only strict improvements, so on the same
        // candidate set the refined score cannot be worse
        assert!(
            with.score <= without.score,
            "newton {} vs grid-only {}",
            with.score,
            without.score
        );
    }

    #[test]
    fn nelder_mead_and_pso_match_the_wavefront_optimum() {
        let wave = two_step_tune(
            theta_bowl,
            TwoStepOptions {
                outer_iters: 32,
                search: ThetaSearch::Wavefront { width: 0 },
                ..Default::default()
            },
        );
        for search in [ThetaSearch::NelderMead, ThetaSearch::Pso] {
            let r = two_step_tune(
                theta_bowl,
                TwoStepOptions { outer_iters: 32, search, ..Default::default() },
            );
            let slack = 1e-2 * wave.score.abs().max(1.0);
            assert!(
                r.score <= wave.score + slack,
                "{search:?} score {} vs wavefront {}",
                r.score,
                wave.score
            );
            assert!(r.outer_evals <= 32, "{search:?} built {}", r.outer_evals);
        }
    }
}

//! Nelder-Mead simplex in log-hyperparameter space — a derivative-free
//! local polish stage for objectives where only score evaluations are
//! available (e.g. the naive baseline under time budget, or the sparse
//! approximation whose paper-form derivatives we do not implement).

use super::{Bounds, Objective, SearchResult};
use crate::spectral::HyperParams;

/// Standard NM coefficients (reflection 1, expansion 2, contraction 0.5,
/// shrink 0.5) on a 2-simplex.
pub fn nelder_mead<O: Objective>(
    obj: &mut O,
    start: HyperParams,
    bounds: Bounds,
    max_iters: usize,
    tol: f64,
) -> SearchResult {
    let lb = bounds.log();
    let clamp = |p: [f64; 2]| {
        [p[0].clamp(lb[0].0, lb[0].1), p[1].clamp(lb[1].0, lb[1].1)]
    };
    let to_hp = |p: [f64; 2]| HyperParams::new(10f64.powf(p[0]), 10f64.powf(p[1]));

    let p0 = clamp([start.sigma2.log10(), start.lambda2.log10()]);
    let step = 0.25;
    let mut simplex = [
        p0,
        clamp([p0[0] + step, p0[1]]),
        clamp([p0[0], p0[1] + step]),
    ];
    let mut evals = 0usize;
    let mut f = [0.0f64; 3];
    for i in 0..3 {
        f[i] = obj.eval(to_hp(simplex[i]));
        evals += 1;
    }

    for _ in 0..max_iters {
        // order ascending
        let mut order = [0usize, 1, 2];
        order.sort_by(|&a, &b| f[a].partial_cmp(&f[b]).unwrap());
        let (b, m, w) = (order[0], order[1], order[2]);
        if (f[w] - f[b]).abs() < tol * (1.0 + f[b].abs()) {
            break;
        }
        let centroid = [
            0.5 * (simplex[b][0] + simplex[m][0]),
            0.5 * (simplex[b][1] + simplex[m][1]),
        ];
        let refl = clamp([
            centroid[0] + (centroid[0] - simplex[w][0]),
            centroid[1] + (centroid[1] - simplex[w][1]),
        ]);
        let fr = obj.eval(to_hp(refl));
        evals += 1;
        if fr < f[b] {
            // try expansion
            let exp = clamp([
                centroid[0] + 2.0 * (centroid[0] - simplex[w][0]),
                centroid[1] + 2.0 * (centroid[1] - simplex[w][1]),
            ]);
            let fe = obj.eval(to_hp(exp));
            evals += 1;
            if fe < fr {
                simplex[w] = exp;
                f[w] = fe;
            } else {
                simplex[w] = refl;
                f[w] = fr;
            }
        } else if fr < f[m] {
            simplex[w] = refl;
            f[w] = fr;
        } else {
            // contraction
            let con = clamp([
                centroid[0] + 0.5 * (simplex[w][0] - centroid[0]),
                centroid[1] + 0.5 * (simplex[w][1] - centroid[1]),
            ]);
            let fc = obj.eval(to_hp(con));
            evals += 1;
            if fc < f[w] {
                simplex[w] = con;
                f[w] = fc;
            } else {
                // shrink toward best
                for i in [m, w] {
                    simplex[i] = clamp([
                        simplex[b][0] + 0.5 * (simplex[i][0] - simplex[b][0]),
                        simplex[b][1] + 0.5 * (simplex[i][1] - simplex[b][1]),
                    ]);
                    f[i] = obj.eval(to_hp(simplex[i]));
                    evals += 1;
                }
            }
        }
    }

    let mut bi = 0;
    for i in 1..3 {
        if f[i] < f[bi] {
            bi = i;
        }
    }
    SearchResult { hp: to_hp(simplex[bi]), score: f[bi], evals }
}

/// Dimension-generic Nelder-Mead core over a boxed domain — the vector
/// theta search's backend (`ThetaSearch::NelderMead`).  The closure is
/// `FnMut` (serial by design: the theta engine memoizes probes and
/// builds any fresh setup through its own parallel wave); coordinates
/// are whatever space the caller chose (the engine passes log10 theta).
/// Returns `(best_point, best_score, evals)`.
///
/// NaN scores order as equal rather than panicking — the engine reports
/// over-budget probes as +inf, and a pathological objective must not
/// take down the server.
pub fn nelder_mead_vec(
    f: &mut dyn FnMut(&[f64]) -> f64,
    start: &[f64],
    lo: &[f64],
    hi: &[f64],
    step: f64,
    max_iters: usize,
    tol: f64,
) -> (Vec<f64>, f64, usize) {
    let n = start.len();
    assert!(n >= 1 && lo.len() == n && hi.len() == n, "dimension mismatch");
    let clamp = |p: &mut [f64]| {
        for d in 0..n {
            p[d] = p[d].clamp(lo[d], hi[d]);
        }
    };

    // n+1 vertices: start, plus start nudged along each axis
    let mut simplex: Vec<Vec<f64>> = Vec::with_capacity(n + 1);
    let mut p0 = start.to_vec();
    clamp(&mut p0);
    simplex.push(p0.clone());
    for d in 0..n {
        let mut p = p0.clone();
        // nudge inward when the start sits on the upper bound
        p[d] = if p[d] + step <= hi[d] { p[d] + step } else { p[d] - step };
        clamp(&mut p);
        simplex.push(p);
    }
    let mut evals = 0usize;
    let mut fs: Vec<f64> = simplex
        .iter()
        .map(|p| {
            evals += 1;
            f(p)
        })
        .collect();

    let cmp = |a: &f64, b: &f64| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal);
    for _ in 0..max_iters {
        let mut order: Vec<usize> = (0..=n).collect();
        order.sort_by(|&a, &b| cmp(&fs[a], &fs[b]));
        let (b, w) = (order[0], order[n]);
        let second_worst = fs[order[n - 1]];
        if (fs[w] - fs[b]).abs() < tol * (1.0 + fs[b].abs()) {
            break;
        }
        // centroid of all vertices but the worst
        let mut centroid = vec![0.0; n];
        for &i in order.iter().take(n) {
            for d in 0..n {
                centroid[d] += simplex[i][d];
            }
        }
        for c in centroid.iter_mut() {
            *c /= n as f64;
        }
        let along = |scale: f64| {
            let mut p: Vec<f64> =
                (0..n).map(|d| centroid[d] + scale * (centroid[d] - simplex[w][d])).collect();
            clamp(&mut p);
            p
        };
        let refl = along(1.0);
        evals += 1;
        let fr = f(&refl);
        if fr < fs[b] {
            let exp = along(2.0);
            evals += 1;
            let fe = f(&exp);
            if fe < fr {
                simplex[w] = exp;
                fs[w] = fe;
            } else {
                simplex[w] = refl;
                fs[w] = fr;
            }
        } else if fr < second_worst {
            simplex[w] = refl;
            fs[w] = fr;
        } else {
            let con = along(-0.5);
            evals += 1;
            let fc = f(&con);
            if fc < fs[w] {
                simplex[w] = con;
                fs[w] = fc;
            } else {
                // shrink every non-best vertex toward the best
                let best = simplex[b].clone();
                for &i in order.iter().skip(1) {
                    for d in 0..n {
                        simplex[i][d] = best[d] + 0.5 * (simplex[i][d] - best[d]);
                    }
                    evals += 1;
                    fs[i] = f(&simplex[i]);
                }
            }
        }
    }

    let mut bi = 0;
    for i in 1..=n {
        if fs[i] < fs[bi] {
            bi = i;
        }
    }
    (simplex.swap_remove(bi), fs[bi], evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::Bowl;

    #[test]
    fn polishes_to_bowl_minimum() {
        let mut obj = Bowl::new(0.5, 2.0);
        let r = nelder_mead(
            &mut obj,
            HyperParams::new(1.0, 1.0),
            Bounds::default(),
            200,
            1e-12,
        );
        assert!((r.hp.sigma2.ln() - 0.5f64.ln()).abs() < 1e-3, "{:?}", r.hp);
        assert!((r.hp.lambda2.ln() - 2.0f64.ln()).abs() < 1e-3, "{:?}", r.hp);
    }

    #[test]
    fn respects_bounds() {
        let b = Bounds { sigma2: (0.8, 1.2), lambda2: (0.8, 1.2) };
        let r = nelder_mead(&mut Bowl::new(100.0, 100.0), HyperParams::new(1.0, 1.0), b, 100, 1e-10);
        assert!(b.contains(r.hp));
    }

    #[test]
    fn few_iterations_terminates() {
        let r = nelder_mead(
            &mut Bowl::new(1.0, 1.0),
            HyperParams::new(3.0, 0.3),
            Bounds::default(),
            3,
            1e-10,
        );
        assert!(r.evals < 20);
        assert!(r.score.is_finite());
    }

    #[test]
    fn vec_core_minimizes_a_3d_quadratic() {
        let target = [0.3, -0.7, 1.1];
        let mut f = |p: &[f64]| -> f64 {
            p.iter().zip(&target).map(|(x, t)| (x - t) * (x - t)).sum()
        };
        let (best, score, evals) = nelder_mead_vec(
            &mut f,
            &[0.0, 0.0, 0.0],
            &[-2.0, -2.0, -2.0],
            &[2.0, 2.0, 2.0],
            0.25,
            400,
            1e-14,
        );
        for (x, t) in best.iter().zip(&target) {
            assert!((x - t).abs() < 1e-4, "{best:?}");
        }
        assert!(score < 1e-7, "score {score}");
        assert!(evals > 4);
    }

    #[test]
    fn vec_core_respects_bounds_and_nan_scores() {
        // optimum outside the box, plus NaN pockets: must stay in bounds
        // and terminate without panicking
        let mut f = |p: &[f64]| -> f64 {
            if p[0] > 0.9 && p[0] < 0.95 {
                f64::NAN
            } else {
                (p[0] - 5.0).powi(2) + (p[1] + 5.0).powi(2)
            }
        };
        let (best, _, _) =
            nelder_mead_vec(&mut f, &[0.0, 0.0], &[-1.0, -1.0], &[1.0, 1.0], 0.25, 200, 1e-12);
        assert!(best.iter().all(|&x| (-1.0..=1.0).contains(&x)), "{best:?}");
        // the NaN pocket sits at 0.9..0.95, so "past 0.85" demonstrates
        // progress toward the bound without betting on which pocket edge
        // the simplex settles against
        assert!(best[0] > 0.85 && best[1] < -0.85, "should push toward (1, -1): {best:?}");
    }
}

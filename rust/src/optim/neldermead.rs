//! Nelder-Mead simplex in log-hyperparameter space — a derivative-free
//! local polish stage for objectives where only score evaluations are
//! available (e.g. the naive baseline under time budget, or the sparse
//! approximation whose paper-form derivatives we do not implement).

use super::{Bounds, Objective, SearchResult};
use crate::spectral::HyperParams;

/// Standard NM coefficients (reflection 1, expansion 2, contraction 0.5,
/// shrink 0.5) on a 2-simplex.
pub fn nelder_mead<O: Objective>(
    obj: &mut O,
    start: HyperParams,
    bounds: Bounds,
    max_iters: usize,
    tol: f64,
) -> SearchResult {
    let lb = bounds.log();
    let clamp = |p: [f64; 2]| {
        [p[0].clamp(lb[0].0, lb[0].1), p[1].clamp(lb[1].0, lb[1].1)]
    };
    let to_hp = |p: [f64; 2]| HyperParams::new(10f64.powf(p[0]), 10f64.powf(p[1]));

    let p0 = clamp([start.sigma2.log10(), start.lambda2.log10()]);
    let step = 0.25;
    let mut simplex = [
        p0,
        clamp([p0[0] + step, p0[1]]),
        clamp([p0[0], p0[1] + step]),
    ];
    let mut evals = 0usize;
    let mut f = [0.0f64; 3];
    for i in 0..3 {
        f[i] = obj.eval(to_hp(simplex[i]));
        evals += 1;
    }

    for _ in 0..max_iters {
        // order ascending
        let mut order = [0usize, 1, 2];
        order.sort_by(|&a, &b| f[a].partial_cmp(&f[b]).unwrap());
        let (b, m, w) = (order[0], order[1], order[2]);
        if (f[w] - f[b]).abs() < tol * (1.0 + f[b].abs()) {
            break;
        }
        let centroid = [
            0.5 * (simplex[b][0] + simplex[m][0]),
            0.5 * (simplex[b][1] + simplex[m][1]),
        ];
        let refl = clamp([
            centroid[0] + (centroid[0] - simplex[w][0]),
            centroid[1] + (centroid[1] - simplex[w][1]),
        ]);
        let fr = obj.eval(to_hp(refl));
        evals += 1;
        if fr < f[b] {
            // try expansion
            let exp = clamp([
                centroid[0] + 2.0 * (centroid[0] - simplex[w][0]),
                centroid[1] + 2.0 * (centroid[1] - simplex[w][1]),
            ]);
            let fe = obj.eval(to_hp(exp));
            evals += 1;
            if fe < fr {
                simplex[w] = exp;
                f[w] = fe;
            } else {
                simplex[w] = refl;
                f[w] = fr;
            }
        } else if fr < f[m] {
            simplex[w] = refl;
            f[w] = fr;
        } else {
            // contraction
            let con = clamp([
                centroid[0] + 0.5 * (simplex[w][0] - centroid[0]),
                centroid[1] + 0.5 * (simplex[w][1] - centroid[1]),
            ]);
            let fc = obj.eval(to_hp(con));
            evals += 1;
            if fc < f[w] {
                simplex[w] = con;
                f[w] = fc;
            } else {
                // shrink toward best
                for i in [m, w] {
                    simplex[i] = clamp([
                        simplex[b][0] + 0.5 * (simplex[i][0] - simplex[b][0]),
                        simplex[b][1] + 0.5 * (simplex[i][1] - simplex[b][1]),
                    ]);
                    f[i] = obj.eval(to_hp(simplex[i]));
                    evals += 1;
                }
            }
        }
    }

    let mut bi = 0;
    for i in 1..3 {
        if f[i] < f[bi] {
            bi = i;
        }
    }
    SearchResult { hp: to_hp(simplex[bi]), score: f[bi], evals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::Bowl;

    #[test]
    fn polishes_to_bowl_minimum() {
        let mut obj = Bowl::new(0.5, 2.0);
        let r = nelder_mead(
            &mut obj,
            HyperParams::new(1.0, 1.0),
            Bounds::default(),
            200,
            1e-12,
        );
        assert!((r.hp.sigma2.ln() - 0.5f64.ln()).abs() < 1e-3, "{:?}", r.hp);
        assert!((r.hp.lambda2.ln() - 2.0f64.ln()).abs() < 1e-3, "{:?}", r.hp);
    }

    #[test]
    fn respects_bounds() {
        let b = Bounds { sigma2: (0.8, 1.2), lambda2: (0.8, 1.2) };
        let r = nelder_mead(&mut Bowl::new(100.0, 100.0), HyperParams::new(1.0, 1.0), b, 100, 1e-10);
        assert!(b.contains(r.hp));
    }

    #[test]
    fn few_iterations_terminates() {
        let r = nelder_mead(
            &mut Bowl::new(1.0, 1.0),
            HyperParams::new(3.0, 0.3),
            Bounds::default(),
            3,
            1e-10,
        );
        assert!(r.evals < 20);
        assert!(r.score.is_finite());
    }
}

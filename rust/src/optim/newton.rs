//! Newton-Raphson local refinement (paper §1.1's "descent algorithm that
//! exploits the Jacobian and possibly the Hessian").  Uses the full
//! [`Evaluation`] — exactly the quantities Propositions 2.1-2.3 make O(N)
//! — with Levenberg-style Hessian regularization and a backtracking line
//! search that enforces constraint (13).

use super::{Bounds, Objective};
use crate::spectral::{Evaluation, HyperParams};

#[derive(Clone, Copy, Debug)]
pub struct NewtonOptions {
    pub max_iters: usize,
    /// Stop when the gradient inf-norm falls below this.
    pub grad_tol: f64,
    /// Stop when the relative score improvement falls below this.
    pub score_tol: f64,
}

impl Default for NewtonOptions {
    fn default() -> Self {
        NewtonOptions { max_iters: 60, grad_tol: 1e-8, score_tol: 1e-14 }
    }
}

#[derive(Clone, Debug)]
pub struct NewtonResult {
    pub hp: HyperParams,
    pub score: f64,
    pub iters: usize,
    /// Full evaluations consumed (each is one O(N) fused pass).
    pub evals: usize,
    pub converged: bool,
    /// Score trace (one entry per accepted iterate).
    pub trace: Vec<f64>,
}

/// Solve the 2x2 system `(H + tau I) d = -g`, bumping `tau` until the
/// modified Hessian is positive definite (so `d` is a descent direction).
fn descent_direction(ev: &Evaluation) -> [f64; 2] {
    let g = ev.jac;
    let h = ev.hess;
    let mut tau = 0.0;
    let scale = h[0][0].abs().max(h[1][1].abs()).max(1e-12);
    for _ in 0..60 {
        let a = h[0][0] + tau;
        let d = h[1][1] + tau;
        let b = h[0][1];
        let det = a * d - b * b;
        if a > 0.0 && det > 1e-300 {
            let dx = (-g[0] * d + g[1] * b) / det;
            let dy = (-g[1] * a + g[0] * b) / det;
            // confirm descent
            if dx * g[0] + dy * g[1] < 0.0 {
                return [dx, dy];
            }
        }
        tau = if tau == 0.0 { 1e-6 * scale } else { tau * 10.0 };
    }
    // fallback: steepest descent scaled to the Hessian magnitude
    [-g[0] / scale, -g[1] / scale]
}

/// Newton-Raphson with backtracking; `start` should come from a global
/// stage (grid/PSO).  Never leaves `bounds`.
pub fn newton_refine<O: Objective>(
    obj: &mut O,
    start: HyperParams,
    bounds: Bounds,
    opt: NewtonOptions,
) -> NewtonResult {
    let mut hp = bounds.clamp(start);
    let mut ev = obj.eval_full(hp);
    let mut evals = 1usize;
    let mut trace = vec![ev.score];
    let mut converged = false;
    let mut iters = 0;

    for _ in 0..opt.max_iters {
        iters += 1;
        let gnorm = ev.jac[0].abs().max(ev.jac[1].abs());
        if gnorm < opt.grad_tol {
            converged = true;
            break;
        }
        let dir = descent_direction(&ev);
        // backtracking line search with feasibility projection
        let mut step = 1.0;
        let mut accepted = false;
        for _ in 0..40 {
            let cand = bounds.clamp(HyperParams::new(
                hp.sigma2 + step * dir[0],
                hp.lambda2 + step * dir[1],
            ));
            if cand.feasible() && (cand.sigma2 != hp.sigma2 || cand.lambda2 != hp.lambda2) {
                let cev = obj.eval_full(cand);
                evals += 1;
                if cev.score.is_finite() && cev.score < ev.score {
                    let rel = (ev.score - cev.score).abs() / (1.0 + ev.score.abs());
                    hp = cand;
                    ev = cev;
                    trace.push(ev.score);
                    accepted = true;
                    if rel < opt.score_tol {
                        converged = true;
                    }
                    break;
                }
            }
            step *= 0.5;
        }
        if !accepted || converged {
            converged = converged || !accepted && ev.jac[0].abs().max(ev.jac[1].abs()) < 1e-4;
            break;
        }
    }

    NewtonResult { hp, score: ev.score, iters, evals, converged, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::Bowl;
    use crate::optim::Counting;

    #[test]
    fn converges_on_bowl() {
        let mut obj = Counting::new(Bowl::new(0.5, 2.0));
        let r = newton_refine(
            &mut obj,
            HyperParams::new(1.5, 0.8),
            Bounds::default(),
            NewtonOptions::default(),
        );
        assert!(r.converged, "{r:?}");
        assert!((r.hp.sigma2 - 0.5).abs() < 1e-4, "{:?}", r.hp);
        assert!((r.hp.lambda2 - 2.0).abs() < 1e-4, "{:?}", r.hp);
        assert_eq!(obj.full_evals, r.evals);
    }

    #[test]
    fn trace_is_monotone_decreasing() {
        let mut obj = Bowl::new(0.9, 1.3);
        let r = newton_refine(
            &mut obj,
            HyperParams::new(5.0, 0.1),
            Bounds::default(),
            NewtonOptions::default(),
        );
        for w in r.trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "trace not monotone: {:?}", r.trace);
        }
    }

    #[test]
    fn stays_in_bounds() {
        let b = Bounds { sigma2: (0.9, 1.1), lambda2: (0.9, 1.1) };
        let r = newton_refine(
            &mut Bowl::new(100.0, 0.01),
            HyperParams::new(1.0, 1.0),
            b,
            NewtonOptions::default(),
        );
        assert!(b.contains(r.hp));
    }

    #[test]
    fn already_at_minimum_converges_immediately() {
        let mut obj = Bowl::new(1.0, 1.0);
        let r = newton_refine(
            &mut obj,
            HyperParams::new(1.0, 1.0),
            Bounds::default(),
            NewtonOptions::default(),
        );
        assert!(r.converged);
        assert!(r.iters <= 2);
    }

    #[test]
    fn descent_direction_handles_indefinite_hessian() {
        let ev = Evaluation {
            score: 0.0,
            jac: [1.0, -1.0],
            hess: [[-2.0, 0.0], [0.0, 1.0]], // indefinite
        };
        let d = descent_direction(&ev);
        assert!(d[0] * ev.jac[0] + d[1] * ev.jac[1] < 0.0, "must be descent: {d:?}");
    }
}

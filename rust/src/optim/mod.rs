//! Hyperparameter optimization (paper §1.1): global search (grid / PSO /
//! Nelder-Mead) followed by local Newton-Raphson refinement, all driven
//! through the [`Objective`] trait so the same algorithms run against the
//! pure-rust spectral evaluator, the PJRT artifacts, the naive O(N^3)
//! baseline, or the sparse approximation.
//!
//! # Examples
//!
//! The two-stage strategy over an [`EigenSystem`] objective (here built
//! from a synthetic spectrum; [`SpectralGp::eigensystem`] produces the
//! same state from real data):
//!
//! ```
//! use gpml::optim::{self, Bounds, NewtonOptions};
//! use gpml::spectral::EigenSystem;
//!
//! // 8 eigenvalues, squared projected targets, N, y'y
//! let s = vec![8.0, 4.0, 2.0, 1.0, 0.5, 0.25, 0.125, 0.0625];
//! let y2t = vec![1.0; 8];
//! let mut es = EigenSystem::from_parts(s, y2t, 8, 8.0);
//!
//! let bounds = Bounds::default();
//! let coarse = optim::grid_search(&mut es, bounds, 9, 64);
//! let refined = optim::newton_refine(&mut es, coarse.hp, bounds, NewtonOptions::default());
//! // keep whichever stage won (Newton can wander on hard surfaces)
//! let best = if refined.score <= coarse.score { refined.hp } else { coarse.hp };
//! assert!(bounds.contains(best));
//! ```
//!
//! [`EigenSystem`]: crate::spectral::EigenSystem
//! [`SpectralGp::eigensystem`]: crate::spectral::SpectralGp::eigensystem

pub mod grid;
pub mod neldermead;
pub mod newton;
pub mod pso;
pub mod two_step;

pub use grid::grid_search;
pub use neldermead::{nelder_mead, nelder_mead_vec};
pub use newton::{newton_refine, NewtonOptions, NewtonResult};
pub use pso::{pso_search, pso_search_vec, PsoOptions};
pub use two_step::{
    quantize_theta, quantize_theta_vec, theta_tune, two_step_tune, FnProvider, RefineKind,
    SetupProvider, ThetaRanges, ThetaSearch, TwoStepOptions, TwoStepResult, VecFnProvider,
    DEFAULT_WAVEFRONT_WIDTH, MAX_DISCRETE_CANDIDATES, MAX_WAVEFRONT_WIDTH,
};

use crate::spectral::{Evaluation, HyperParams};
use crate::util::threadpool;

/// Grain for global-search wavefronts on the pure-rust path: one score
/// is O(N) flops, so claims of `WAVEFRONT_GRAIN_FLOPS / N` evaluations
/// keep each pool worker busy for well over the ~10 us spawn cost
/// (2^16 element-visits is tens of microseconds of transcendental-heavy
/// score work), and small (batch x N) problems collapse to the serial
/// loop.
const WAVEFRONT_GRAIN_FLOPS: usize = 1 << 16;

fn wavefront_grain(n: usize) -> usize {
    (WAVEFRONT_GRAIN_FLOPS / n.max(1)).max(1)
}

/// Something that can score hyperparameter pairs. `&mut self` so
/// implementations may cache, batch, or count.
pub trait Objective {
    /// Score function L_y (lower is better — eq. 14 minimizes).
    fn eval(&mut self, hp: HyperParams) -> f64;

    /// Batched evaluation. The PJRT-backed objective overrides this to
    /// amortize one dispatch over the whole batch (the global-search
    /// wavefront); the default is a scalar loop.
    fn eval_batch(&mut self, hps: &[HyperParams]) -> Vec<f64> {
        hps.iter().map(|&h| self.eval(h)).collect()
    }

    /// Score + Jacobian + Hessian (for Newton refinement).
    fn eval_full(&mut self, hp: HyperParams) -> Evaluation;
}

impl Objective for crate::spectral::EigenSystem {
    fn eval(&mut self, hp: HyperParams) -> f64 {
        self.score(hp)
    }
    /// Grid/PSO wavefronts fan out across the pool on the pure-rust path
    /// (the batched PJRT objective amortizes the same batch into one
    /// dispatch instead).  Each slot is an independent O(N) score, so the
    /// output is bit-identical to the scalar loop at any thread count.
    fn eval_batch(&mut self, hps: &[HyperParams]) -> Vec<f64> {
        let es: &crate::spectral::EigenSystem = self;
        threadpool::par_map(hps, wavefront_grain(es.s.len()), |&hp| es.score(hp))
    }
    fn eval_full(&mut self, hp: HyperParams) -> Evaluation {
        self.evaluate(hp)
    }
}

/// The classical GP evidence objective over an eigensystem (extension;
/// see `EigenSystem::evidence` for why this exists alongside the paper's
/// eq. 19 score).
pub struct EvidenceObjective(pub crate::spectral::EigenSystem);

impl Objective for EvidenceObjective {
    fn eval(&mut self, hp: HyperParams) -> f64 {
        self.0.evidence(hp)
    }
    /// Parallel wavefront like the paper-score objective above.
    fn eval_batch(&mut self, hps: &[HyperParams]) -> Vec<f64> {
        let es = &self.0;
        threadpool::par_map(hps, wavefront_grain(es.s.len()), |&hp| es.evidence(hp))
    }
    fn eval_full(&mut self, hp: HyperParams) -> Evaluation {
        self.0.evidence_evaluate(hp)
    }
}

/// An [`Objective`] wrapper that counts evaluations (used by benches to
/// report k*, and by tests).
pub struct Counting<O> {
    pub inner: O,
    pub evals: usize,
    pub full_evals: usize,
}

impl<O> Counting<O> {
    pub fn new(inner: O) -> Self {
        Counting { inner, evals: 0, full_evals: 0 }
    }
}

impl<O: Objective> Objective for Counting<O> {
    fn eval(&mut self, hp: HyperParams) -> f64 {
        self.evals += 1;
        self.inner.eval(hp)
    }
    fn eval_batch(&mut self, hps: &[HyperParams]) -> Vec<f64> {
        self.evals += hps.len();
        self.inner.eval_batch(hps)
    }
    fn eval_full(&mut self, hp: HyperParams) -> Evaluation {
        self.full_evals += 1;
        self.inner.eval_full(hp)
    }
}

/// Search-space bounds in raw (sigma2, lambda2) space; global optimizers
/// work on log10 coordinates internally.
#[derive(Clone, Copy, Debug)]
pub struct Bounds {
    pub sigma2: (f64, f64),
    pub lambda2: (f64, f64),
}

impl Default for Bounds {
    fn default() -> Self {
        // generous: 1e-4 .. 1e4 on both axes
        Bounds { sigma2: (1e-4, 1e4), lambda2: (1e-4, 1e4) }
    }
}

impl Bounds {
    pub fn log(&self) -> [(f64, f64); 2] {
        [
            (self.sigma2.0.log10(), self.sigma2.1.log10()),
            (self.lambda2.0.log10(), self.lambda2.1.log10()),
        ]
    }
    pub fn clamp(&self, hp: HyperParams) -> HyperParams {
        HyperParams::new(
            hp.sigma2.clamp(self.sigma2.0, self.sigma2.1),
            hp.lambda2.clamp(self.lambda2.0, self.lambda2.1),
        )
    }
    pub fn contains(&self, hp: HyperParams) -> bool {
        hp.sigma2 >= self.sigma2.0
            && hp.sigma2 <= self.sigma2.1
            && hp.lambda2 >= self.lambda2.0
            && hp.lambda2 <= self.lambda2.1
    }
}

/// Result of a global search stage.
#[derive(Clone, Copy, Debug)]
pub struct SearchResult {
    pub hp: HyperParams,
    pub score: f64,
    pub evals: usize,
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// A smooth synthetic objective with a known unique minimum at
    /// (s*, l*) in log space — used to test optimizers without GP
    /// machinery.
    pub struct Bowl {
        pub opt: HyperParams,
        pub evals: usize,
    }

    impl Bowl {
        pub fn new(sigma2: f64, lambda2: f64) -> Self {
            Bowl { opt: HyperParams::new(sigma2, lambda2), evals: 0 }
        }
    }

    impl Objective for Bowl {
        fn eval(&mut self, hp: HyperParams) -> f64 {
            self.evals += 1;
            let ds = hp.sigma2.ln() - self.opt.sigma2.ln();
            let dl = hp.lambda2.ln() - self.opt.lambda2.ln();
            ds * ds + 0.5 * dl * dl + 0.2 * ds * dl
        }
        fn eval_full(&mut self, hp: HyperParams) -> Evaluation {
            let score = self.eval(hp);
            let ds = hp.sigma2.ln() - self.opt.sigma2.ln();
            let dl = hp.lambda2.ln() - self.opt.lambda2.ln();
            // chain rule: d/dx f(ln x) = f'(ln x)/x
            let (s, l) = (hp.sigma2, hp.lambda2);
            let gs = (2.0 * ds + 0.2 * dl) / s;
            let gl = (dl + 0.2 * ds) / l;
            let hss = (2.0 - (2.0 * ds + 0.2 * dl)) / (s * s);
            let hll = (1.0 - (dl + 0.2 * ds)) / (l * l);
            let hsl = 0.2 / (s * l);
            Evaluation { score, jac: [gs, gl], hess: [[hss, hsl], [hsl, hll]] }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_clamp_and_contains() {
        let b = Bounds::default();
        assert!(b.contains(HyperParams::new(1.0, 1.0)));
        assert!(!b.contains(HyperParams::new(1e9, 1.0)));
        let c = b.clamp(HyperParams::new(1e9, 1e-9));
        assert!(b.contains(c));
    }

    #[test]
    fn counting_wrapper_counts() {
        let mut c = Counting::new(testutil::Bowl::new(1.0, 1.0));
        c.eval(HyperParams::new(1.0, 1.0));
        c.eval_batch(&[HyperParams::new(1.0, 2.0), HyperParams::new(2.0, 1.0)]);
        c.eval_full(HyperParams::new(1.0, 1.0));
        assert_eq!(c.evals, 3);
        assert_eq!(c.full_evals, 1);
    }

    #[test]
    fn bowl_gradient_is_consistent() {
        let mut b = testutil::Bowl::new(0.5, 2.0);
        let hp = HyperParams::new(1.0, 1.0);
        let ev = b.eval_full(hp);
        let h = 1e-7;
        let fs = (b.eval(HyperParams::new(1.0 + h, 1.0)) - b.eval(HyperParams::new(1.0 - h, 1.0)))
            / (2.0 * h);
        let fl = (b.eval(HyperParams::new(1.0, 1.0 + h)) - b.eval(HyperParams::new(1.0, 1.0 - h)))
            / (2.0 * h);
        assert!((ev.jac[0] - fs).abs() < 1e-5);
        assert!((ev.jac[1] - fl).abs() < 1e-5);
    }
}

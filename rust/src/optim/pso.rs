//! Particle Swarm Optimization in log-hyperparameter space — the paper's
//! §1.1 cites PSO (Petelin et al. 2011) as a standard global stage for GP
//! hyperparameter tuning.
//!
//! Each generation evaluates the whole swarm through
//! [`Objective::eval_batch`], which the PJRT objective folds into one
//! batched-artifact dispatch (swarm size == artifact batch B by default).

use super::{Bounds, Objective, SearchResult};
use crate::spectral::HyperParams;
use crate::util::rng::Rng;

/// PSO settings (defaults follow the common w=0.729, c1=c2=1.49 "constriction" values).
#[derive(Clone, Copy, Debug)]
pub struct PsoOptions {
    pub particles: usize,
    pub iterations: usize,
    pub inertia: f64,
    pub cognitive: f64,
    pub social: f64,
    pub seed: u64,
}

impl Default for PsoOptions {
    fn default() -> Self {
        PsoOptions {
            particles: 64,
            iterations: 25,
            inertia: 0.729,
            cognitive: 1.49,
            social: 1.49,
            seed: 0x9505_eed0,
        }
    }
}

/// Run PSO; returns the best point found and the number of evaluations.
pub fn pso_search<O: Objective>(obj: &mut O, bounds: Bounds, opt: PsoOptions) -> SearchResult {
    let mut rng = Rng::new(opt.seed);
    let lb = bounds.log();
    let np = opt.particles.max(2);

    // state in log10 space
    let mut pos: Vec<[f64; 2]> = (0..np)
        .map(|_| {
            [
                rng.uniform_in(lb[0].0, lb[0].1),
                rng.uniform_in(lb[1].0, lb[1].1),
            ]
        })
        .collect();
    let vmax = [(lb[0].1 - lb[0].0) * 0.2, (lb[1].1 - lb[1].0) * 0.2];
    let mut vel: Vec<[f64; 2]> = (0..np)
        .map(|_| {
            [
                rng.uniform_in(-vmax[0], vmax[0]),
                rng.uniform_in(-vmax[1], vmax[1]),
            ]
        })
        .collect();

    let to_hp = |p: &[f64; 2]| HyperParams::new(10f64.powf(p[0]), 10f64.powf(p[1]));

    let mut evals = 0usize;
    let scores = {
        let hps: Vec<HyperParams> = pos.iter().map(to_hp).collect();
        evals += hps.len();
        obj.eval_batch(&hps)
    };
    let mut pbest = pos.clone();
    let mut pbest_score = scores;
    let (mut gbest, mut gbest_score) = {
        let mut bi = 0;
        for i in 1..np {
            if pbest_score[i] < pbest_score[bi] {
                bi = i;
            }
        }
        (pbest[bi], pbest_score[bi])
    };

    for _ in 0..opt.iterations {
        for i in 0..np {
            for d in 0..2 {
                let r1 = rng.uniform();
                let r2 = rng.uniform();
                vel[i][d] = opt.inertia * vel[i][d]
                    + opt.cognitive * r1 * (pbest[i][d] - pos[i][d])
                    + opt.social * r2 * (gbest[d] - pos[i][d]);
                vel[i][d] = vel[i][d].clamp(-vmax[d], vmax[d]);
                pos[i][d] = (pos[i][d] + vel[i][d]).clamp(lb[d].0, lb[d].1);
            }
        }
        let hps: Vec<HyperParams> = pos.iter().map(to_hp).collect();
        evals += hps.len();
        let scores = obj.eval_batch(&hps);
        for i in 0..np {
            if scores[i] < pbest_score[i] {
                pbest_score[i] = scores[i];
                pbest[i] = pos[i];
                if scores[i] < gbest_score {
                    gbest_score = scores[i];
                    gbest = pos[i];
                }
            }
        }
    }

    SearchResult { hp: to_hp(&gbest), score: gbest_score, evals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::Bowl;

    #[test]
    fn converges_to_bowl_minimum() {
        let mut obj = Bowl::new(0.5, 2.0);
        let r = pso_search(&mut obj, Bounds::default(), PsoOptions::default());
        assert!((r.hp.sigma2.ln() - 0.5f64.ln()).abs() < 0.1, "{:?}", r.hp);
        assert!((r.hp.lambda2.ln() - 2.0f64.ln()).abs() < 0.1, "{:?}", r.hp);
        assert!(r.score < 1e-2);
        assert_eq!(r.evals, 64 * 26);
    }

    #[test]
    fn deterministic_given_seed() {
        let o = PsoOptions { seed: 7, ..Default::default() };
        let r1 = pso_search(&mut Bowl::new(1.0, 1.0), Bounds::default(), o);
        let r2 = pso_search(&mut Bowl::new(1.0, 1.0), Bounds::default(), o);
        assert_eq!(r1.hp, r2.hp);
    }

    #[test]
    fn stays_within_bounds() {
        let b = Bounds { sigma2: (0.5, 2.0), lambda2: (0.5, 2.0) };
        let r = pso_search(&mut Bowl::new(1e-6, 1e6), b, PsoOptions::default());
        assert!(b.contains(r.hp), "{:?}", r.hp);
    }

    #[test]
    fn small_swarm_still_works() {
        let o = PsoOptions { particles: 8, iterations: 60, ..Default::default() };
        let r = pso_search(&mut Bowl::new(0.9, 1.1), Bounds::default(), o);
        assert!(r.score < 0.05, "score {}", r.score);
    }
}

//! Particle Swarm Optimization in log-hyperparameter space — the paper's
//! §1.1 cites PSO (Petelin et al. 2011) as a standard global stage for GP
//! hyperparameter tuning.
//!
//! Each generation evaluates the whole swarm through
//! [`Objective::eval_batch`], which the PJRT objective folds into one
//! batched-artifact dispatch (swarm size == artifact batch B by default).

use super::{Bounds, Objective, SearchResult};
use crate::spectral::HyperParams;
use crate::util::rng::Rng;

/// PSO settings (defaults follow the common w=0.729, c1=c2=1.49 "constriction" values).
#[derive(Clone, Copy, Debug)]
pub struct PsoOptions {
    pub particles: usize,
    pub iterations: usize,
    pub inertia: f64,
    pub cognitive: f64,
    pub social: f64,
    pub seed: u64,
}

impl Default for PsoOptions {
    fn default() -> Self {
        PsoOptions {
            particles: 64,
            iterations: 25,
            inertia: 0.729,
            cognitive: 1.49,
            social: 1.49,
            seed: 0x9505_eed0,
        }
    }
}

/// Run PSO; returns the best point found and the number of evaluations.
pub fn pso_search<O: Objective>(obj: &mut O, bounds: Bounds, opt: PsoOptions) -> SearchResult {
    let mut rng = Rng::new(opt.seed);
    let lb = bounds.log();
    let np = opt.particles.max(2);

    // state in log10 space
    let mut pos: Vec<[f64; 2]> = (0..np)
        .map(|_| {
            [
                rng.uniform_in(lb[0].0, lb[0].1),
                rng.uniform_in(lb[1].0, lb[1].1),
            ]
        })
        .collect();
    let vmax = [(lb[0].1 - lb[0].0) * 0.2, (lb[1].1 - lb[1].0) * 0.2];
    let mut vel: Vec<[f64; 2]> = (0..np)
        .map(|_| {
            [
                rng.uniform_in(-vmax[0], vmax[0]),
                rng.uniform_in(-vmax[1], vmax[1]),
            ]
        })
        .collect();

    let to_hp = |p: &[f64; 2]| HyperParams::new(10f64.powf(p[0]), 10f64.powf(p[1]));

    let mut evals = 0usize;
    let scores = {
        let hps: Vec<HyperParams> = pos.iter().map(to_hp).collect();
        evals += hps.len();
        obj.eval_batch(&hps)
    };
    let mut pbest = pos.clone();
    let mut pbest_score = scores;
    let (mut gbest, mut gbest_score) = {
        let mut bi = 0;
        for i in 1..np {
            if pbest_score[i] < pbest_score[bi] {
                bi = i;
            }
        }
        (pbest[bi], pbest_score[bi])
    };

    for _ in 0..opt.iterations {
        for i in 0..np {
            for d in 0..2 {
                let r1 = rng.uniform();
                let r2 = rng.uniform();
                vel[i][d] = opt.inertia * vel[i][d]
                    + opt.cognitive * r1 * (pbest[i][d] - pos[i][d])
                    + opt.social * r2 * (gbest[d] - pos[i][d]);
                vel[i][d] = vel[i][d].clamp(-vmax[d], vmax[d]);
                pos[i][d] = (pos[i][d] + vel[i][d]).clamp(lb[d].0, lb[d].1);
            }
        }
        let hps: Vec<HyperParams> = pos.iter().map(to_hp).collect();
        evals += hps.len();
        let scores = obj.eval_batch(&hps);
        for i in 0..np {
            if scores[i] < pbest_score[i] {
                pbest_score[i] = scores[i];
                pbest[i] = pos[i];
                if scores[i] < gbest_score {
                    gbest_score = scores[i];
                    gbest = pos[i];
                }
            }
        }
    }

    SearchResult { hp: to_hp(&gbest), score: gbest_score, evals }
}

/// Dimension-generic PSO core over a boxed domain — the vector theta
/// search's backend (`ThetaSearch::Pso`).  Serial `FnMut` evaluation by
/// design: the theta engine memoizes probes and parallelizes any fresh
/// setup through its own wave, so batching here would only reorder
/// (and de-determinize) the probe stream.  Deterministic per
/// `opt.seed`.  Returns `(best_point, best_score, evals)`.
pub fn pso_search_vec(
    f: &mut dyn FnMut(&[f64]) -> f64,
    lo: &[f64],
    hi: &[f64],
    opt: PsoOptions,
) -> (Vec<f64>, f64, usize) {
    let n = lo.len();
    assert!(n >= 1 && hi.len() == n, "dimension mismatch");
    let mut rng = Rng::new(opt.seed);
    let np = opt.particles.max(2);

    let mut pos: Vec<Vec<f64>> =
        (0..np).map(|_| (0..n).map(|d| rng.uniform_in(lo[d], hi[d])).collect()).collect();
    let vmax: Vec<f64> = (0..n).map(|d| (hi[d] - lo[d]) * 0.2).collect();
    let mut vel: Vec<Vec<f64>> =
        (0..np).map(|_| (0..n).map(|d| rng.uniform_in(-vmax[d], vmax[d])).collect()).collect();

    let mut evals = 0usize;
    let mut pbest = pos.clone();
    let mut pbest_score: Vec<f64> = pos
        .iter()
        .map(|p| {
            evals += 1;
            f(p)
        })
        .collect();
    let (mut gbest, mut gbest_score) = {
        let mut bi = 0;
        for i in 1..np {
            if pbest_score[i] < pbest_score[bi] {
                bi = i;
            }
        }
        (pbest[bi].clone(), pbest_score[bi])
    };

    for _ in 0..opt.iterations {
        for i in 0..np {
            for d in 0..n {
                let r1 = rng.uniform();
                let r2 = rng.uniform();
                vel[i][d] = opt.inertia * vel[i][d]
                    + opt.cognitive * r1 * (pbest[i][d] - pos[i][d])
                    + opt.social * r2 * (gbest[d] - pos[i][d]);
                vel[i][d] = vel[i][d].clamp(-vmax[d], vmax[d]);
                pos[i][d] = (pos[i][d] + vel[i][d]).clamp(lo[d], hi[d]);
            }
            evals += 1;
            let score = f(&pos[i]);
            if score < pbest_score[i] {
                pbest_score[i] = score;
                pbest[i].copy_from_slice(&pos[i]);
                if score < gbest_score {
                    gbest_score = score;
                    gbest.copy_from_slice(&pos[i]);
                }
            }
        }
    }

    (gbest, gbest_score, evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::Bowl;

    #[test]
    fn converges_to_bowl_minimum() {
        let mut obj = Bowl::new(0.5, 2.0);
        let r = pso_search(&mut obj, Bounds::default(), PsoOptions::default());
        assert!((r.hp.sigma2.ln() - 0.5f64.ln()).abs() < 0.1, "{:?}", r.hp);
        assert!((r.hp.lambda2.ln() - 2.0f64.ln()).abs() < 0.1, "{:?}", r.hp);
        assert!(r.score < 1e-2);
        assert_eq!(r.evals, 64 * 26);
    }

    #[test]
    fn deterministic_given_seed() {
        let o = PsoOptions { seed: 7, ..Default::default() };
        let r1 = pso_search(&mut Bowl::new(1.0, 1.0), Bounds::default(), o);
        let r2 = pso_search(&mut Bowl::new(1.0, 1.0), Bounds::default(), o);
        assert_eq!(r1.hp, r2.hp);
    }

    #[test]
    fn stays_within_bounds() {
        let b = Bounds { sigma2: (0.5, 2.0), lambda2: (0.5, 2.0) };
        let r = pso_search(&mut Bowl::new(1e-6, 1e6), b, PsoOptions::default());
        assert!(b.contains(r.hp), "{:?}", r.hp);
    }

    #[test]
    fn small_swarm_still_works() {
        let o = PsoOptions { particles: 8, iterations: 60, ..Default::default() };
        let r = pso_search(&mut Bowl::new(0.9, 1.1), Bounds::default(), o);
        assert!(r.score < 0.05, "score {}", r.score);
    }

    #[test]
    fn vec_core_minimizes_a_3d_quadratic_within_bounds() {
        let target = [0.3, -0.7, 1.1];
        let mut f = |p: &[f64]| -> f64 {
            p.iter().zip(&target).map(|(x, t)| (x - t) * (x - t)).sum()
        };
        let lo = [-2.0, -2.0, -2.0];
        let hi = [2.0, 2.0, 2.0];
        let o = PsoOptions { particles: 16, iterations: 80, ..Default::default() };
        let (best, score, evals) = pso_search_vec(&mut f, &lo, &hi, o);
        assert!(best.iter().zip(&lo).zip(&hi).all(|((&x, &l), &h)| x >= l && x <= h));
        for (x, t) in best.iter().zip(&target) {
            assert!((x - t).abs() < 0.05, "{best:?}");
        }
        assert!(score < 0.01, "score {score}");
        assert_eq!(evals, 16 + 16 * 80);
    }

    #[test]
    fn vec_core_is_deterministic_per_seed() {
        let run = || {
            let mut f = |p: &[f64]| (p[0] - 1.0).powi(2) + (p[1] + 1.0).powi(2);
            pso_search_vec(&mut f, &[-3.0, -3.0], &[3.0, 3.0], PsoOptions::default())
        };
        let (a, sa, _) = run();
        let (b, sb, _) = run();
        assert_eq!(sa.to_bits(), sb.to_bits());
        assert_eq!(a, b);
    }
}

//! # gpml — Efficient Marginal Likelihood Computation for GP Regression
//!
//! Reproduction of Schirru, Pampuri, De Nicolao & McLoone (2011):
//! after a one-time O(N^3) eigendecomposition of the kernel Gram matrix,
//! the GP marginal-likelihood score (eq. 19), Jacobian (eqs. 20-21) and
//! Hessian (eqs. 26-28) are evaluated in O(N) per hyperparameter iterate
//! with O(N) memory — turning global+local hyperparameter optimization
//! from `k* O(N^3)` into `O(N^3) + k* O(N)`.
//!
//! ## Architecture (three layers; see DESIGN.md)
//!
//! - **Layer 1/2 (build time, python)** — pallas kernels + JAX entry
//!   points AOT-lowered to HLO-text artifacts in `artifacts/`.
//! - **Layer 3 (this crate)** — the [`runtime`] loads the artifacts via
//!   PJRT (behind the `pjrt` cargo feature; a plain checkout compiles the
//!   always-available stub), the [`coordinator`] serves tuning work over
//!   them — its session cache amortizes the O(N^3) setup across requests
//!   and its worker pool executes concurrent pure-rust jobs (the wire
//!   protocol is documented in `docs/PROTOCOL.md`) — and the pure-rust
//!   [`spectral`] evaluator mirrors the same identities for the scalar
//!   fast path.  [`naive`] (O(N^3)) and [`sparse`] (O(N m^2)) are the
//!   paper's comparison baselines; [`optim`] implements §1.1's
//!   global+local strategy and §2.2's Algorithm 1.
//! - **Cross-cutting** — [`verify`] is the differential-verification
//!   harness (DESIGN.md §4): it cross-checks `spectral` against `naive`
//!   and against finite differences over randomized kernels and
//!   hyperparameter grids, and gates every future refactor through
//!   `rust/tests/verify_differential.rs`.
//!
//! ## Quickstart
//!
//! ```no_run
//! use gpml::kernelfn::Kernel;
//! use gpml::optim::{self, Bounds};
//! use gpml::spectral::SpectralGp;
//!
//! # fn main() -> Result<(), Box<dyn std::error::Error>> {
//! let ds = gpml::data::synthetic(gpml::data::SyntheticSpec::default(), 1);
//! let gp = SpectralGp::fit(Kernel::Rbf { xi2: 2.0 }, ds.x.clone())?; // O(N^3), once
//! let mut es = gp.eigensystem(ds.y());                               // O(N) state
//! let coarse = optim::grid_search(&mut es, Bounds::default(), 25, 64);
//! let tuned = optim::newton_refine(&mut es, coarse.hp, Bounds::default(),
//!                                  Default::default());
//! println!("sigma2={:.4} lambda2={:.4}", tuned.hp.sigma2, tuned.hp.lambda2);
//! # Ok(()) }
//! ```
//!
//! To confirm the identities on your own machine (the paper's exactness
//! claim, Props. 2.1-2.3):
//!
//! ```
//! let report = gpml::verify::random_triples_suite(5, 42);
//! assert!(report.ok(), "{}", report.summary());
//! ```

// Dense index-heavy numerical kernels: these style lints fight the
// textbook (i, j, k) transcriptions without making them clearer.
#![allow(clippy::needless_range_loop, clippy::many_single_char_names)]
#![allow(clippy::needless_lifetimes)]

pub mod coordinator;
pub mod data;
pub mod faults;
pub mod kernelfn;
pub mod linalg;
pub mod naive;
pub mod optim;
pub mod runtime;
pub mod sparse;
pub mod spectral;
pub mod util;
pub mod verify;

pub use spectral::{EigenSystem, Evaluation, HyperParams, SpectralGp};

//! Differential-verification subsystem (DESIGN.md §4) — the permanent
//! regression gate for the paper's Propositions 2.1–2.3.
//!
//! The paper's entire contribution is an *exactness* claim: after one
//! O(N^3) eigendecomposition, the O(N) spectral forms of the score
//! (eq. 19), Jacobian (eqs. 20–25) and Hessian (eqs. 26–35) equal the
//! naive O(N^3) quantities — not approximately, identically.  This module
//! turns that claim into an executable contract, cross-checking over
//! randomized kernels, targets and hyperparameter grids:
//!
//! - [`check_against_naive`] — spectral score/Jacobian vs the dense
//!   [`NaiveEvaluator`] (eq. 15 Cholesky form *and* the eq. 16 rewrite).
//! - [`check_against_fd`] — closed-form Jacobian vs finite differences of
//!   the score; closed-form Hessian vs finite differences of the
//!   gradient, including both mixed partials.
//! - [`check_hessian_against_naive_fd`] — spectral Hessian vs finite
//!   differences of the *naive* trace-identity gradient, closing the loop
//!   through the O(N^3) path.
//! - [`check_internal`] — the fused [`EigenSystem::evaluate`] pass vs the
//!   standalone `score`/`grad` paths (machine-precision agreement; they
//!   share per-element helpers) and Hessian symmetry.
//! - [`ard_differential_suite`] — the per-dimension-lengthscale
//!   [`Kernel::RbfArd`] gram vs the isotropic gram on rescaled inputs (an
//!   exact algebraic identity), plus a finite-difference check of the
//!   score's slope along each theta component through both constructions.
//! - [`sparse_differential_suite`] — the §2.1 sparse baselines
//!   (DESIGN.md §13): full-inducing SoR/Nyström collapse to the exact
//!   score, and the compact (m+1)-slot SoR spectrum vs the dense
//!   `C W^{-1} C'` kernel run through the ordinary full-size pipeline.
//!
//! ## Tolerance model
//!
//! Near the constraint-(13) boundary `sigma2 -> 0+` the score subtracts
//! `O(y'y/sigma2)` terms that cancel almost exactly, so "relative error"
//! must be anchored to the *cancellation magnitude*
//! ([`EigenSystem::evaluate_magnitudes`]), and the dense baseline's own
//! backward error grows with `kappa(K + (sigma2/lambda2) I)`.  Every
//! tolerance here is therefore `rtol * |value| + O(N eps) * magnitude`,
//! plus — for dense comparisons — `O(eps kappa) * |value|` and an
//! eigen-representation term `O(eps s_max)` propagated through the
//! per-eigenvalue sensitivities (binding for rank-deficient kernels,
//! where the two paths see different numerical null spaces).  Tight
//! (1e-7 relative) on the well-conditioned interior, honestly widened
//! where f64 itself loses the digits.  Suite grids include the
//! near-boundary region down to `sigma2 = 1e-8`.
//!
//! Every future perf refactor of `spectral`, `naive` or `linalg` is gated
//! on [`differential_suite`] / [`random_triples_suite`] through
//! `rust/tests/verify_differential.rs` (wired into `cargo test`).

pub mod fd;

use crate::kernelfn::{self, Kernel, ThetaVec};
use crate::linalg::{matmul, Matrix, SymEigen};
use crate::naive::NaiveEvaluator;
use crate::spectral::{EigenSystem, Evaluation, HyperParams};
use crate::util::rng::Rng;

/// One failed check: a quantity whose two computations disagree beyond
/// tolerance (or came out non-finite).
#[derive(Clone, Debug)]
pub struct Discrepancy {
    pub quantity: String,
    pub context: String,
    pub got: f64,
    pub want: f64,
    pub tolerance: f64,
    /// |got - want| / max(|got|, |want|).
    pub rel_err: f64,
}

/// Outcome of a verification run: counters plus every discrepancy found.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    pub cases: usize,
    pub checks: usize,
    pub discrepancies: Vec<Discrepancy>,
}

impl VerifyReport {
    pub fn ok(&self) -> bool {
        self.discrepancies.is_empty()
    }

    pub fn merge(&mut self, other: VerifyReport) {
        self.cases += other.cases;
        self.checks += other.checks;
        self.discrepancies.extend(other.discrepancies);
    }

    /// Human-readable digest (counts plus the first discrepancies).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} cases, {} checks, {} discrepancies",
            self.cases,
            self.checks,
            self.discrepancies.len()
        );
        for d in self.discrepancies.iter().take(10) {
            s.push_str(&format!(
                "\n  [{}] {}: got {:.17e} want {:.17e} (|diff| {:.3e} > tol {:.3e}, rel {:.3e})",
                d.context,
                d.quantity,
                d.got,
                d.want,
                (d.got - d.want).abs(),
                d.tolerance,
                d.rel_err
            ));
        }
        if self.discrepancies.len() > 10 {
            s.push_str(&format!("\n  ... and {} more", self.discrepancies.len() - 10));
        }
        s
    }

    /// Record one comparison.  Non-finite values always fail.
    fn check(&mut self, ctx: &str, quantity: &str, got: f64, want: f64, tolerance: f64) {
        self.checks += 1;
        let diff = (got - want).abs();
        let pass = got.is_finite() && want.is_finite() && diff <= tolerance;
        if !pass {
            self.discrepancies.push(Discrepancy {
                quantity: quantity.to_string(),
                context: ctx.to_string(),
                got,
                want,
                tolerance,
                rel_err: diff / got.abs().max(want.abs()).max(f64::MIN_POSITIVE),
            });
        }
    }
}

/// Summation noise floor: `O(N eps)` times the cancellation magnitude of
/// the quantity (see the module docs).  `.abs()` guards against a
/// degenerate magnitude going negative outside the evaluator's domain
/// (e.g. `lambda2 |s_noise| > sigma2` flipping `a` negative) — a
/// tolerance must never be negative.
fn noise_floor(n: usize, magnitude: f64) -> f64 {
    32.0 * (n.max(8) as f64) * f64::EPSILON * magnitude.abs()
}

/// Condition number proxy of the dense path's factorizations:
/// `kappa(K + (sigma2/lambda2) I) ~ 1 + s_max lambda2 / sigma2`.
fn dense_condition(es: &EigenSystem, hp: HyperParams) -> f64 {
    let s_max = es.s.last().copied().unwrap_or(0.0).max(0.0);
    1.0 + s_max * hp.lambda2 / hp.sigma2
}

/// Noise from the two paths seeing *different* numerical representations
/// of K: the spectral side works with eigh(K)'s eigenvalues, the dense
/// side with K itself, and the two agree only to O(eps s_max).  That
/// perturbation propagates through the per-eigenvalue sensitivities
/// `dq/ds_i` — dominated by the null modes, where they reduce to the
/// next-derivative-level magnitudes below (rank-deficient kernels such
/// as linear/polynomial make this the binding term).
struct EigenReprNoise {
    score: f64,
    jac: [f64; 2],
}

fn eigen_repr_noise(es: &EigenSystem, hp: HyperParams, mags: &Evaluation) -> EigenReprNoise {
    let s_max = es.s.last().copied().unwrap_or(0.0).max(0.0);
    let c = 64.0 * f64::EPSILON * s_max;
    EigenReprNoise {
        score: c * hp.lambda2 * mags.jac[0].abs(),
        jac: [
            c * hp.lambda2 * mags.hess[0][0].abs(),
            c * (mags.jac[0].abs() + hp.lambda2 * mags.hess[0][1].abs()),
        ],
    }
}

/// Tolerance for a closed-form vs dense-O(N^3) comparison.
fn naive_tolerance(
    es: &EigenSystem,
    hp: HyperParams,
    rtol: f64,
    scale: f64,
    mag: f64,
    repr_noise: f64,
) -> f64 {
    rtol * scale
        + noise_floor(es.n, mag)
        + 8.0 * f64::EPSILON * dense_condition(es, hp) * scale
        + repr_noise
}

/// Fused-pass vs standalone-path consistency plus Hessian symmetry.
///
/// `grad` and `evaluate` share one per-element transcription and one
/// accumulation order, so their Jacobians agree to the summation noise
/// floor (in practice: bit-identically); the score paths differ only in
/// the reciprocal rewrite and stay within the same floor.
pub fn check_internal(es: &EigenSystem, hp: HyperParams, ctx: &str, report: &mut VerifyReport) {
    let ev = es.evaluate(hp);
    let mags = es.evaluate_magnitudes(hp);
    let sc = es.score(hp);
    let g = es.grad(hp);
    report.check(ctx, "evaluate.score vs score()", ev.score, sc, noise_floor(es.n, mags.score));
    for i in 0..2 {
        let name = ["evaluate.jac[0] vs grad()[0]", "evaluate.jac[1] vs grad()[1]"][i];
        report.check(ctx, name, ev.jac[i], g[i], noise_floor(es.n, mags.jac[i]));
    }
    report.check(ctx, "hess symmetry (stored)", ev.hess[0][1], ev.hess[1][0], 0.0);
}

/// Spectral O(N) score/Jacobian vs the dense O(N^3) evaluator — the
/// paper's central exactness claim (Props. 2.1–2.2).
pub fn check_against_naive(
    es: &EigenSystem,
    naive: &NaiveEvaluator,
    hp: HyperParams,
    rtol: f64,
    ctx: &str,
    report: &mut VerifyReport,
) {
    let mags = es.evaluate_magnitudes(hp);
    let repr = eigen_repr_noise(es, hp, &mags);
    let sc = es.score(hp);
    let g = es.grad(hp);

    let naive_sc = naive.score(hp);
    let scale = naive_sc.abs().max(sc.abs());
    report.check(
        ctx,
        "score: naive eq.15 vs spectral eq.19",
        naive_sc,
        sc,
        naive_tolerance(es, hp, rtol, scale, mags.score, repr.score),
    );

    let (naive_sc16, ng) = naive.score_grad(hp);
    report.check(
        ctx,
        "score: naive eq.16 vs spectral eq.19",
        naive_sc16,
        sc,
        naive_tolerance(es, hp, rtol, naive_sc16.abs().max(sc.abs()), mags.score, repr.score),
    );
    report.check(
        ctx,
        "dL/dsigma2: naive trace vs spectral eq.20",
        ng[0],
        g[0],
        naive_tolerance(es, hp, rtol, ng[0].abs().max(g[0].abs()), mags.jac[0], repr.jac[0]),
    );
    report.check(
        ctx,
        "dL/dlambda2: naive trace vs spectral eq.21",
        ng[1],
        g[1],
        naive_tolerance(es, hp, rtol, ng[1].abs().max(g[1].abs()), mags.jac[1], repr.jac[1]),
    );
}

/// Closed-form Jacobian vs central differences of the score, and
/// closed-form Hessian vs central differences of the gradient (both mixed
/// partials independently), with fd error bounds folded into tolerances.
pub fn check_against_fd(
    es: &EigenSystem,
    hp: HyperParams,
    rtol: f64,
    ctx: &str,
    report: &mut VerifyReport,
) {
    let mags = es.evaluate_magnitudes(hp);
    // The fd oracle's roundoff bound is anchored to N * magnitude: the
    // worst-case rounding error of an N-term sum is (N-1) eps Sum|t_i|
    // (the standard recursive-summation bound), and the observed error
    // of the cancellation-heavy sums here comes within ~6% of it — this
    // is a near-sharp bound, not slack.
    let nf = es.n as f64;
    let g = es.grad(hp);
    let fd_g = fd::grad_of(|h| es.score(h), hp, nf * mags.score);
    for (i, name) in ["dL/dsigma2 vs fd(score)", "dL/dlambda2 vs fd(score)"].iter().enumerate() {
        let tol = rtol * g[i].abs().max(fd_g[i].value.abs())
            + 8.0 * fd_g[i].err
            + noise_floor(es.n, mags.jac[i]);
        report.check(ctx, name, g[i], fd_g[i].value, tol);
    }

    let ev = es.evaluate(hp);
    let fd_h = fd::jac_of(|h| es.grad(h), hp, [nf * mags.jac[0], nf * mags.jac[1]]);
    // fd_h[i][j] approximates d g_j / d theta_i; Hessian H[i][j] = d g_j / d theta_i.
    let pairs = [
        (0usize, 0usize, "d2L/dsigma2^2 vs fd(grad)", mags.hess[0][0]),
        (0, 1, "d2L/dsigma2 dlambda2 vs fd(grad)", mags.hess[0][1]),
        (1, 0, "d2L/dlambda2 dsigma2 vs fd(grad)", mags.hess[1][0]),
        (1, 1, "d2L/dlambda2^2 vs fd(grad)", mags.hess[1][1]),
    ];
    for (i, j, name, mag) in pairs {
        let est = fd_h[i][j];
        let tol = rtol * ev.hess[i][j].abs().max(est.value.abs())
            + 8.0 * est.err
            + noise_floor(es.n, mag);
        report.check(ctx, name, ev.hess[i][j], est.value, tol);
    }
    // the two independent mixed-partial estimates must agree with each other
    let (a, b) = (fd_h[0][1], fd_h[1][0]);
    report.check(
        ctx,
        "fd mixed-partial symmetry",
        a.value,
        b.value,
        rtol * a.value.abs().max(b.value.abs()) + 8.0 * (a.err + b.err),
    );
}

/// Spectral Hessian vs central differences of the *naive* trace-identity
/// gradient: the only check that ties eqs. 26–35 back to the O(N^3) path.
/// The naive gradient's own `O(eps kappa)` backward error is amplified by
/// `1/h`, so this is meaningful only on well-conditioned hyperparameters
/// (the suites restrict to `sigma2 >= 1e-2`, `sigma2/lambda2 >= 1e-3`).
pub fn check_hessian_against_naive_fd(
    es: &EigenSystem,
    naive: &NaiveEvaluator,
    hp: HyperParams,
    rtol: f64,
    ctx: &str,
    report: &mut VerifyReport,
) {
    let ev = es.evaluate(hp);
    let mags = es.evaluate_magnitudes(hp);
    let repr = eigen_repr_noise(es, hp, &mags);
    let nf = es.n as f64;
    let fd_h = fd::jac_of(
        |h| naive.score_grad(h).1,
        hp,
        [nf * mags.jac[0], nf * mags.jac[1]],
    );
    let kappa = dense_condition(es, hp);
    let step = f64::EPSILON.cbrt();
    let pairs = [
        (0usize, 0usize, "d2L/dsigma2^2 vs fd(naive grad)", hp.sigma2),
        (0, 1, "d2L/dsigma2 dlambda2 vs fd(naive grad)", hp.sigma2),
        (1, 0, "d2L/dlambda2 dsigma2 vs fd(naive grad)", hp.lambda2),
        (1, 1, "d2L/dlambda2^2 vs fd(naive grad)", hp.lambda2),
    ];
    for (i, j, name, theta) in pairs {
        let est = fd_h[i][j];
        // extra noise: the dense gradient's backward error (conditioning
        // plus eigen-representation mismatch) amplified over the step
        let dense_noise =
            (8.0 * f64::EPSILON * kappa * nf * mags.jac[j] + repr.jac[j]) / (step * theta);
        let tol = rtol * ev.hess[i][j].abs().max(est.value.abs())
            + 8.0 * est.err
            + dense_noise
            + noise_floor(es.n, mags.hess[i][j]);
        report.check(ctx, name, ev.hess[i][j], est.value, tol);
    }
}

/// Configuration for [`differential_suite`].
#[derive(Clone, Debug)]
pub struct SuiteConfig {
    /// Dataset sizes (the O(N^3) baseline is evaluated at each).
    pub sizes: Vec<usize>,
    /// Independent (x, y) draws per size and kernel.
    pub datasets_per_size: usize,
    pub kernels: Vec<Kernel>,
    /// sigma2 grid; spans eq. (13)'s feasible region including the
    /// near-boundary sigma2 -> 0+ points (fd/internal checks run on all
    /// of it; the dense cross-check is conditioning-gated, see below).
    pub sigma2_grid: Vec<f64>,
    pub lambda2_grid: Vec<f64>,
    /// Base relative tolerance of every comparison (default 1e-7).
    pub rtol: f64,
    pub seed: u64,
    /// Dense O(N^3) cross-checks require `sigma2/lambda2` (the ridge the
    /// dense path factorizes with) at or above this floor — below it the
    /// baseline itself, not the identities, loses the digits.
    pub naive_conditioning_floor: f64,
    /// Hessian-vs-fd(naive grad) checks run only for N up to this size.
    pub hess_naive_fd_max_n: usize,
}

impl Default for SuiteConfig {
    fn default() -> Self {
        SuiteConfig {
            sizes: vec![8, 32, 128],
            datasets_per_size: 2,
            kernels: vec![Kernel::Rbf { xi2: 1.5 }, Kernel::Matern32 { ell: 0.8 }],
            sigma2_grid: vec![1e-8, 1e-6, 1e-4, 1e-2, 0.3, 1.0, 10.0, 1e3],
            lambda2_grid: vec![1e-2, 0.3, 1.0, 10.0],
            rtol: 1e-7,
            seed: 0x5eed_0001,
            naive_conditioning_floor: 1e-6,
            hess_naive_fd_max_n: 32,
        }
    }
}

/// Run the full differential grid: every (size, dataset, kernel,
/// hyperparameter) combination through all applicable checks.
pub fn differential_suite(cfg: &SuiteConfig) -> VerifyReport {
    let mut report = VerifyReport::default();
    let mut rng = Rng::new(cfg.seed);
    for &n in &cfg.sizes {
        for dataset in 0..cfg.datasets_per_size {
            for &kernel in &cfg.kernels {
                let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
                let y = rng.normal_vec(n);
                let k = kernelfn::gram(kernel, &x);
                let eigen = match SymEigen::new(&k) {
                    Ok(e) => e,
                    Err(e) => {
                        report.check(
                            &format!("N={n} kernel={kernel:?} dataset={dataset}"),
                            &format!("eigendecomposition ({e})"),
                            f64::NAN,
                            0.0,
                            0.0,
                        );
                        continue;
                    }
                };
                let es = EigenSystem::new(&eigen, &y);
                let naive = NaiveEvaluator::new(k, y.clone());
                for &s2 in &cfg.sigma2_grid {
                    for &l2 in &cfg.lambda2_grid {
                        let hp = HyperParams::new(s2, l2);
                        let ctx = format!(
                            "N={n} kernel={kernel:?} dataset={dataset} hp=({s2:.1e},{l2:.1e})"
                        );
                        report.cases += 1;
                        check_internal(&es, hp, &ctx, &mut report);
                        check_against_fd(&es, hp, cfg.rtol, &ctx, &mut report);
                        if s2 / l2 >= cfg.naive_conditioning_floor {
                            check_against_naive(&es, &naive, hp, cfg.rtol, &ctx, &mut report);
                            if n <= cfg.hess_naive_fd_max_n && s2 >= 1e-2 && s2 / l2 >= 1e-3 {
                                check_hessian_against_naive_fd(
                                    &es, &naive, hp, cfg.rtol, &ctx, &mut report,
                                );
                            }
                        }
                    }
                }
            }
        }
    }
    report
}

/// Property-style sweep: `count` random (kernel, y, hyperparameter)
/// triples, each cross-checked naive vs spectral, against finite
/// differences, and for Hessian symmetry.
///
/// ```
/// let report = gpml::verify::random_triples_suite(3, 7);
/// assert!(report.ok(), "{}", report.summary());
/// ```
pub fn random_triples_suite(count: usize, seed: u64) -> VerifyReport {
    let kernels = [
        Kernel::Rbf { xi2: 1.0 },
        Kernel::Rbf { xi2: 2.5 },
        Kernel::Matern32 { ell: 0.7 },
        Kernel::Matern52 { ell: 1.2 },
        Kernel::Polynomial { degree: 2 },
        Kernel::Linear,
    ];
    let mut report = VerifyReport::default();
    let mut rng = Rng::new(seed);
    let rtol = 1e-7;
    for i in 0..count {
        let n = 8 + rng.below(41); // 8..=48
        let p = 1 + rng.below(4);
        let kernel = kernels[rng.below(kernels.len())];
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        let y = rng.normal_vec(n);
        let k = kernelfn::gram(kernel, &x);
        let eigen = match SymEigen::new(&k) {
            Ok(e) => e,
            Err(e) => {
                report.check(
                    &format!("triple {i}: N={n} P={p} kernel={kernel:?}"),
                    &format!("eigendecomposition ({e})"),
                    f64::NAN,
                    0.0,
                    0.0,
                );
                continue;
            }
        };
        let es = EigenSystem::new(&eigen, &y);
        let naive = NaiveEvaluator::new(k, y.clone());
        // log-uniform hyperparameters, floored so the dense baseline's
        // ridge sigma2/lambda2 stays within its conditioning range
        let l2 = 10f64.powf(rng.uniform_in(-2.0, 2.0));
        let s2 = 10f64.powf(rng.uniform_in(-5.0, 3.0)).max(1e-6 * l2);
        let hp = HyperParams::new(s2, l2);
        let ctx =
            format!("triple {i}: N={n} P={p} kernel={kernel:?} hp=({s2:.2e},{l2:.2e})");
        report.cases += 1;
        check_internal(&es, hp, &ctx, &mut report);
        check_against_naive(&es, &naive, hp, rtol, &ctx, &mut report);
        check_against_fd(&es, hp, rtol, &ctx, &mut report);
        if n <= 32 && s2 >= 1e-2 && s2 / l2 >= 1e-3 {
            check_hessian_against_naive_fd(&es, &naive, hp, rtol, &ctx, &mut report);
        }
    }
    report
}

/// Score of the ARD family at lengthscales `v`, through either the ARD
/// gram itself (`rescaled = false`) or the isotropic `xi2 = 1` gram on
/// inputs pre-scaled by `1 / sqrt(v_j)` (`rescaled = true`) — two
/// independent constructions of the same mathematical quantity.
fn ard_score_path(
    x: &Matrix,
    y: &[f64],
    v: &[f64],
    hp: HyperParams,
    rescaled: bool,
) -> Result<(f64, EigenSystem), String> {
    let k = if rescaled {
        let xs = Matrix::from_fn(x.rows(), x.cols(), |i, j| x[(i, j)] / v[j].sqrt());
        kernelfn::gram(Kernel::Rbf { xi2: 1.0 }, &xs)
    } else {
        kernelfn::gram(Kernel::RbfArd { xi2: ThetaVec::from_slice(v)? }, x)
    };
    let eigen = SymEigen::new(&k).map_err(|e| e.to_string())?;
    let es = EigenSystem::new(&eigen, y);
    Ok((es.score(hp), es))
}

/// ARD differential gates (the PR 6 vector-theta acceptance): for each
/// `N` in `sizes`, draw random 3-feature data and log-uniform
/// per-dimension lengthscales, then check
///
/// 1. the [`Kernel::RbfArd`] gram equals the isotropic gram on inputs
///    rescaled by `1/sqrt(xi2_d)` to machine precision (the ARD kernel's
///    defining algebraic identity),
/// 2. the eq. 19 score agrees through both gram constructions after the
///    eigendecomposition (eigen-representation tolerance model, as for
///    the dense cross-checks), and
/// 3. the central-difference slope of the score **along each theta
///    component** agrees between the two constructions — the
///    theta-sensitivity contract the vector tuning engine's coordinate
///    sweeps rely on.
pub fn ard_differential_suite(sizes: &[usize], seed: u64) -> VerifyReport {
    let mut report = VerifyReport::default();
    let mut rng = Rng::new(seed);
    let hp = HyperParams::new(0.3, 1.0);
    let d = 3usize;
    for &n in sizes {
        let xi2: Vec<f64> = (0..d).map(|_| 10f64.powf(rng.uniform_in(-0.5, 0.5))).collect();
        let x = Matrix::from_fn(n, d, |_, _| rng.normal());
        let y = rng.normal_vec(n);
        let ctx = format!("ARD N={n} xi2=({:.3},{:.3},{:.3})", xi2[0], xi2[1], xi2[2]);
        report.cases += 1;

        // (1) gram identity: entries are exp(-e) with the exponent summed
        // in different orders, so they agree to a few eps absolutely
        // (e * exp(-e) is bounded); 64 eps is generous and still catches
        // any real per-dimension transcription error
        let tv = ThetaVec::from_slice(&xi2).expect("d <= MAX_THETA_DIMS");
        let k_ard = kernelfn::gram(Kernel::RbfArd { xi2: tv }, &x);
        let xs = Matrix::from_fn(n, d, |i, j| x[(i, j)] / xi2[j].sqrt());
        let k_iso = kernelfn::gram(Kernel::Rbf { xi2: 1.0 }, &xs);
        let mut maxdiff = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                maxdiff = maxdiff.max((k_ard[(i, j)] - k_iso[(i, j)]).abs());
            }
        }
        report.check(
            &ctx,
            "ARD gram vs rescaled isotropic gram",
            maxdiff,
            0.0,
            64.0 * f64::EPSILON,
        );

        // (2) score agreement through the eigendecomposition
        let (sa, es) = match ard_score_path(&x, &y, &xi2, hp, false) {
            Ok(v) => v,
            Err(e) => {
                report.check(&ctx, &format!("eigendecomposition ({e})"), f64::NAN, 0.0, 0.0);
                continue;
            }
        };
        let sb = match ard_score_path(&x, &y, &xi2, hp, true) {
            Ok((s, _)) => s,
            Err(e) => {
                report.check(&ctx, &format!("eigendecomposition ({e})"), f64::NAN, 0.0, 0.0);
                continue;
            }
        };
        let mags = es.evaluate_magnitudes(hp);
        let per_eval = noise_floor(n, mags.score) + eigen_repr_noise(&es, hp, &mags).score;
        report.check(
            &ctx,
            "score: ARD gram vs rescaled isotropic gram",
            sa,
            sb,
            1e-7 * sa.abs().max(sb.abs()) + per_eval,
        );

        // (3) fd slope of the score along each theta component, both
        // constructions: same central stencil on the same mathematical
        // function, so truncation cancels and the tolerance is the
        // per-evaluation noise amplified by 1/h
        let step = f64::EPSILON.cbrt();
        for c in 0..d {
            let h = step * xi2[c];
            let slope = |rescaled: bool| -> Result<f64, String> {
                let mut hi_v = xi2.clone();
                hi_v[c] += h;
                let mut lo_v = xi2.clone();
                lo_v[c] -= h;
                let (f_hi, _) = ard_score_path(&x, &y, &hi_v, hp, rescaled)?;
                let (f_lo, _) = ard_score_path(&x, &y, &lo_v, hp, rescaled)?;
                Ok((f_hi - f_lo) / (2.0 * h))
            };
            match (slope(false), slope(true)) {
                (Ok(ga), Ok(gi)) => {
                    let tol = 1e-7 * ga.abs().max(gi.abs()) + 8.0 * per_eval / h;
                    report.check(
                        &ctx,
                        &format!("fd dscore/dtheta[{c}]: ARD vs rescaled isotropic"),
                        ga,
                        gi,
                        tol,
                    );
                }
                (Err(e), _) | (_, Err(e)) => {
                    report.check(
                        &ctx,
                        &format!("fd eigendecomposition (component {c}: {e})"),
                        f64::NAN,
                        0.0,
                        0.0,
                    );
                }
            }
        }
    }
    report
}

/// Sparse-baseline differential gates (the ISSUE 9 §2.1 subsystem —
/// DESIGN.md §13): for each `N` in `sizes`, draw random 3-feature data
/// and check
///
/// 1. **full-inducing exactness** — with `m = N` both the
///    subset-of-regressors and the Williams–Seeger Nyström compact
///    spectra must reproduce the exact eq. 19 score over a moderate
///    hyperparameter grid (the approximations collapse to the identity
///    there; the only legitimate daylight is the `1e-10 m` inducing-Gram
///    jitter and the eigen-representation noise of running two different
///    eigensolves), and
/// 2. **compact-spectrum fidelity** — at `m = N/2` the SoR score
///    computed from the compact (m+1)-slot spectrum must equal the eq. 19
///    score of the *dense* N x N SoR kernel `K^ = C W^{-1} C'` evaluated
///    through the ordinary full-size pipeline — the differential check
///    that the residual null-slot construction ([`crate::sparse`]) is an
///    identity, not an approximation.
pub fn sparse_differential_suite(sizes: &[usize], seed: u64) -> VerifyReport {
    use crate::linalg::Cholesky;
    use crate::sparse::{even_inducing, SparseGp, SparseMethod};

    let mut report = VerifyReport::default();
    let mut rng = Rng::new(seed);
    let kernel = Kernel::Rbf { xi2: 1.5 };
    let hps = [
        HyperParams::new(1e-2, 0.3),
        HyperParams::new(0.3, 1.0),
        HyperParams::new(1.0, 10.0),
        HyperParams::new(10.0, 0.1),
    ];
    // scale-shift of the spectrum from the 1e-10 m inducing-Gram jitter,
    // propagated like the eigen-representation noise (module docs)
    let jitter_noise = |es: &EigenSystem, hp: HyperParams, mags: &Evaluation| -> f64 {
        1e-10 * es.s.len() as f64 * hp.lambda2 * mags.jac[0].abs()
    };
    for &n in sizes {
        let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
        let y = rng.normal_vec(n);
        let k = kernelfn::gram(kernel, &x);
        let ctx = format!("sparse N={n}");
        report.cases += 1;
        let eigen = match SymEigen::new(&k) {
            Ok(e) => e,
            Err(e) => {
                report.check(&ctx, &format!("eigendecomposition ({e})"), f64::NAN, 0.0, 0.0);
                continue;
            }
        };
        let es = EigenSystem::new(&eigen, &y);

        // (1) m = N: both constructions collapse to the exact method
        let all: Vec<usize> = (0..n).collect();
        for method in [SparseMethod::Sor, SparseMethod::Nystrom] {
            let sp = match SparseGp::new(method, kernel, &x, &y, &all) {
                Ok(sp) => sp,
                Err(e) => {
                    report.check(&ctx, &format!("sparse build ({e})"), f64::NAN, 0.0, 0.0);
                    continue;
                }
            };
            for &hp in &hps {
                let got = sp.score(hp);
                let want = es.score(hp);
                let mags = es.evaluate_magnitudes(hp);
                let repr = eigen_repr_noise(&es, hp, &mags);
                let tol = 1e-5 * want.abs().max(got.abs())
                    + noise_floor(n, mags.score)
                    + 2.0 * repr.score
                    + jitter_noise(&es, hp, &mags);
                report.check(
                    &ctx,
                    &format!("score: {} m=N vs exact eq.19", method.as_str()),
                    got,
                    want,
                    tol,
                );
            }
        }

        // (2) m = N/2: compact SoR spectrum vs the dense SoR kernel
        if n >= 8 {
            let idx = even_inducing(n, n / 2);
            let cols: Vec<usize> = (0..x.cols()).collect();
            let xu = x.select(&idx, &cols);
            let c = kernelfn::cross_gram(kernel, &x, &xu);
            let mut w = kernelfn::gram(kernel, &xu);
            w.add_diag(1e-10 * idx.len() as f64);
            let dense = Cholesky::new(&w)
                .map_err(|e| e.to_string())
                .map(|ch| matmul(&c, &ch.solve_mat(&c.t())))
                .and_then(|khat| SymEigen::new(&khat).map_err(|e| e.to_string()))
                .map(|eig| EigenSystem::new(&eig, &y));
            let sp = SparseGp::new(SparseMethod::Sor, kernel, &x, &y, &idx)
                .map_err(|e| e.to_string());
            match (dense, sp) {
                (Ok(es_hat), Ok(sp)) => {
                    for &hp in &hps {
                        let got = sp.score(hp);
                        let want = es_hat.score(hp);
                        let mags = es_hat.evaluate_magnitudes(hp);
                        let repr = eigen_repr_noise(&es_hat, hp, &mags);
                        let tol = 1e-5 * want.abs().max(got.abs())
                            + noise_floor(n, mags.score)
                            + 2.0 * repr.score;
                        report.check(
                            &ctx,
                            "score: compact SoR spectrum vs dense C W^-1 C' eq.19",
                            got,
                            want,
                            tol,
                        );
                    }
                }
                (Err(e), _) | (_, Err(e)) => {
                    report.check(&ctx, &format!("SoR dense path ({e})"), f64::NAN, 0.0, 0.0);
                }
            }
        }
    }
    report
}

/// Tolerances for [`spectral_gate`].  Every bound is relative to the
/// spectral scale `max(1, max_j |lambda_j|)` of the decomposition under
/// test, so the gate is meaningful for Gram matrices of any magnitude.
#[derive(Clone, Copy, Debug)]
pub struct SpectralGateConfig {
    /// Eigenvalue agreement with the oracle decomposition.
    pub value_rtol: f64,
    /// Elementwise residual bound for `A v_j - lambda_j v_j`.
    pub residual_tol: f64,
    /// Elementwise bound for `Q'Q - I`.
    pub ortho_tol: f64,
}

impl Default for SpectralGateConfig {
    fn default() -> Self {
        SpectralGateConfig { value_rtol: 1e-12, residual_tol: 1e-10, ortho_tol: 1e-10 }
    }
}

/// Oracle-grade acceptance gate for an eigendecomposition of `a`
/// (the test wall the divide-and-conquer solver is shipped behind —
/// `rust/tests/eigen_dac.rs`): ascending finite eigenvalues, the
/// eigenpair residual `A v_j = lambda_j v_j`, eigenvector
/// orthogonality, and — when an `oracle` decomposition (the QL path)
/// is supplied — eigenvalue agreement at `value_rtol`.  Returns the
/// first violated property as an error naming the offending index.
pub fn spectral_gate(
    a: &Matrix,
    eigen: &SymEigen,
    oracle: Option<&SymEigen>,
    cfg: &SpectralGateConfig,
) -> Result<(), String> {
    let n = a.rows();
    if eigen.values.len() != n || eigen.vectors.rows() != n || eigen.vectors.cols() != n {
        return Err(format!(
            "shape mismatch: {} values / {}x{} vectors for an {n}x{n} matrix",
            eigen.values.len(),
            eigen.vectors.rows(),
            eigen.vectors.cols()
        ));
    }
    let scale = eigen.values.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for (j, v) in eigen.values.iter().enumerate() {
        if !v.is_finite() {
            return Err(format!("eigenvalue {j} is not finite: {v}"));
        }
    }
    for (j, w) in eigen.values.windows(2).enumerate() {
        if w[0] > w[1] {
            return Err(format!("eigenvalues not ascending at {j}: {} > {}", w[0], w[1]));
        }
    }
    if let Some(oracle) = oracle {
        if oracle.values.len() != n {
            return Err(format!("oracle has {} values, expected {n}", oracle.values.len()));
        }
        for j in 0..n {
            let (got, want) = (eigen.values[j], oracle.values[j]);
            if (got - want).abs() > cfg.value_rtol * scale {
                return Err(format!(
                    "eigenvalue {j} disagrees with the oracle: {got} vs {want} \
                     (|diff| = {:e} > {:e})",
                    (got - want).abs(),
                    cfg.value_rtol * scale
                ));
            }
        }
    }
    if n == 0 {
        return Ok(());
    }
    // residual: A Q - Q diag(lambda) as one GEMM, then an elementwise scan
    let aq = matmul(a, &eigen.vectors);
    for j in 0..n {
        for i in 0..n {
            let r = (aq[(i, j)] - eigen.values[j] * eigen.vectors[(i, j)]).abs();
            if r > cfg.residual_tol * scale {
                return Err(format!(
                    "eigenpair {j} residual at row {i}: {r:e} > {:e}",
                    cfg.residual_tol * scale
                ));
            }
        }
    }
    // orthogonality: Q'Q vs I
    let qtq = matmul(&eigen.vectors.t(), &eigen.vectors);
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            let drift = (qtq[(i, j)] - want).abs();
            if drift > cfg.ortho_tol {
                return Err(format!(
                    "orthogonality drift at ({i}, {j}): {drift:e} > {:e}",
                    cfg.ortho_tol
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_pair(n: usize, seed: u64) -> (EigenSystem, NaiveEvaluator) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
        let y = rng.normal_vec(n);
        let k = kernelfn::gram(Kernel::Rbf { xi2: 1.5 }, &x);
        let eigen = SymEigen::new(&k).unwrap();
        let es = EigenSystem::new(&eigen, &y);
        (es, NaiveEvaluator::new(k, y))
    }

    #[test]
    fn clean_system_produces_clean_report() {
        let (es, naive) = small_pair(20, 1);
        let mut report = VerifyReport::default();
        for hp in [HyperParams::new(0.5, 1.0), HyperParams::new(2.0, 0.3)] {
            check_internal(&es, hp, "t", &mut report);
            check_against_naive(&es, &naive, hp, 1e-7, "t", &mut report);
            check_against_fd(&es, hp, 1e-7, "t", &mut report);
            check_hessian_against_naive_fd(&es, &naive, hp, 1e-7, "t", &mut report);
        }
        assert!(report.ok(), "{}", report.summary());
        assert!(report.checks >= 30);
    }

    #[test]
    fn harness_detects_a_planted_identity_bug() {
        // Corrupt one squared projection by 0.1% — the kind of silent
        // transcription error the subsystem exists to catch.
        let (es, naive) = small_pair(20, 2);
        let mut broken = es.clone();
        broken.y2t[10] *= 1.001;
        let mut report = VerifyReport::default();
        let hp = HyperParams::new(0.5, 1.0);
        check_against_naive(&broken, &naive, hp, 1e-7, "planted", &mut report);
        assert!(!report.ok(), "planted bug went undetected");
    }

    #[test]
    fn harness_detects_a_planted_constant_term_bug() {
        // Corrupt the y'y closure scalar: shifts score and dL/dsigma2
        // but not dL/dlambda2 — exactly the `- 4 y'y / sigma2` term the
        // ISSUE calls out.
        let (es, naive) = small_pair(16, 3);
        let mut broken = es.clone();
        broken.yy *= 1.0 + 1e-5;
        let mut report = VerifyReport::default();
        let hp = HyperParams::new(0.3, 1.0);
        check_against_naive(&broken, &naive, hp, 1e-7, "planted", &mut report);
        assert!(
            report.discrepancies.iter().any(|d| d.quantity.contains("score")),
            "{}",
            report.summary()
        );
    }

    #[test]
    fn report_summary_lists_discrepancies() {
        let mut report = VerifyReport::default();
        report.check("ctx", "thing", 1.0, 2.0, 1e-9);
        assert!(!report.ok());
        let s = report.summary();
        assert!(s.contains("thing") && s.contains("ctx"), "{s}");
        assert_eq!(report.checks, 1);
    }

    #[test]
    fn non_finite_values_always_fail() {
        let mut report = VerifyReport::default();
        report.check("ctx", "nan", f64::NAN, f64::NAN, f64::INFINITY);
        report.check("ctx", "inf", f64::INFINITY, f64::INFINITY, f64::INFINITY);
        assert_eq!(report.discrepancies.len(), 2);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = VerifyReport::default();
        a.check("c", "q", 1.0, 1.0, 1.0);
        let mut b = VerifyReport::default();
        b.check("c", "q", 1.0, 5.0, 1e-12);
        b.cases = 1;
        a.merge(b);
        assert_eq!(a.checks, 2);
        assert_eq!(a.cases, 1);
        assert!(!a.ok());
    }

    #[test]
    fn ard_suite_is_clean_at_small_sizes() {
        let report = ard_differential_suite(&[8, 16], 0xA4D5_EED);
        assert!(report.ok(), "{}", report.summary());
        assert_eq!(report.cases, 2);
        // per size: gram identity + score agreement + 3 component slopes
        assert_eq!(report.checks, 2 * 5);
    }

    #[test]
    fn ard_suite_detects_a_planted_lengthscale_swap() {
        // Sanity on the gate's teeth: the gram identity must fail when
        // the rescaling uses permuted lengthscales (the aliasing bug a
        // per-dimension transcription error would produce).  We emulate
        // it by comparing the ARD gram against an isotropic gram rescaled
        // with the components reversed.
        let mut rng = Rng::new(11);
        let xi2 = [0.4, 2.5, 1.0];
        let x = Matrix::from_fn(12, 3, |_, _| rng.normal());
        let tv = ThetaVec::from_slice(&xi2).unwrap();
        let k_ard = kernelfn::gram(Kernel::RbfArd { xi2: tv }, &x);
        let xs = Matrix::from_fn(12, 3, |i, j| x[(i, j)] / xi2[2 - j].sqrt());
        let k_bad = kernelfn::gram(Kernel::Rbf { xi2: 1.0 }, &xs);
        let mut maxdiff = 0.0f64;
        for i in 0..12 {
            for j in 0..12 {
                maxdiff = maxdiff.max((k_ard[(i, j)] - k_bad[(i, j)]).abs());
            }
        }
        assert!(maxdiff > 1e-3, "swapped lengthscales went undetected ({maxdiff:.3e})");
    }

    #[test]
    fn sparse_suite_is_clean_at_small_sizes() {
        let report = sparse_differential_suite(&[10, 24], 0x5ba2_5eed);
        assert!(report.ok(), "{}", report.summary());
        assert_eq!(report.cases, 2);
        // per size: 2 methods x 4 hps full-inducing + 4 hps dense SoR
        assert_eq!(report.checks, 2 * 12);
    }

    #[test]
    fn sparse_suite_tolerance_is_discriminative() {
        let mut rng = Rng::new(31);
        let x = Matrix::from_fn(24, 3, |_, _| rng.normal());
        let y = rng.normal_vec(24);
        let kernel = Kernel::Rbf { xi2: 1.5 };
        let k = kernelfn::gram(kernel, &x);
        let es = EigenSystem::new(&SymEigen::new(&k).unwrap(), &y);
        let idx = crate::sparse::even_inducing(24, 12);
        let sp =
            crate::sparse::SparseGp::new(crate::sparse::SparseMethod::Sor, kernel, &x, &y, &idx)
                .unwrap();
        let hp = HyperParams::new(0.3, 1.0);
        // a genuinely reduced m: the sparse score is an approximation,
        // so the *tight* full-inducing tolerance must reject it — the
        // suite's teeth depend on that tolerance being discriminative
        let diff = (sp.score(hp) - es.score(hp)).abs();
        let mags = es.evaluate_magnitudes(hp);
        let tight = 1e-5 * es.score(hp).abs() + noise_floor(24, mags.score);
        assert!(diff > tight, "m=N/2 approximation error {diff:.3e} under tolerance {tight:.3e}");
    }

    #[test]
    fn tiny_differential_suite_is_clean() {
        let cfg = SuiteConfig {
            sizes: vec![8, 16],
            datasets_per_size: 1,
            ..Default::default()
        };
        let report = differential_suite(&cfg);
        assert!(report.ok(), "{}", report.summary());
        assert!(report.cases > 0 && report.checks > report.cases);
    }

    #[test]
    fn spectral_gate_accepts_clean_and_rejects_corrupted() {
        use crate::linalg::EigenSolver;
        let mut rng = Rng::new(21);
        let x = Matrix::from_fn(40, 3, |_, _| rng.normal());
        let k = kernelfn::gram(Kernel::Rbf { xi2: 1.0 }, &x);
        let cfg = SpectralGateConfig::default();
        let dac = SymEigen::new_with(&k, EigenSolver::Dac).unwrap();
        let ql = SymEigen::new_with(&k, EigenSolver::Ql).unwrap();
        spectral_gate(&k, &dac, Some(&ql), &cfg).unwrap();
        spectral_gate(&k, &ql, Some(&dac), &cfg).unwrap();
        // a corrupted eigenvalue must trip the oracle comparison
        let mut bad = dac.clone();
        bad.values[20] += 1e-8 * bad.values.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        assert!(spectral_gate(&k, &bad, Some(&ql), &cfg).is_err());
        // a denormalized eigenvector column must trip orthogonality
        let mut bad = dac.clone();
        for r in 0..40 {
            bad.vectors[(r, 5)] *= 1.0 + 1e-6;
        }
        assert!(spectral_gate(&k, &bad, None, &cfg).is_err());
    }
}

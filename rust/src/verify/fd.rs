//! Centered finite-difference oracles with relative steps and explicit
//! error bounds.
//!
//! Every derivative here is taken with respect to a strictly positive
//! hyperparameter, so steps are *relative* (`h = eps^(1/3) x`) — an
//! absolute step would either vanish against large `x` or cross the
//! feasibility boundary (13) for small `x`.
//!
//! The [`FdEstimate::err`] bound matters as much as the value: near the
//! `sigma2 -> 0` boundary the score's rounding noise scales with the
//! *cancellation magnitude* `~ 4 y'y / sigma2`
//! ([`EigenSystem::evaluate_magnitudes`]), not with the score itself, and
//! a differential check that ignores this either rejects correct code or
//! silently tests nothing.  Callers pass that magnitude in; the bound
//! combines the roundoff term `eps * mag / h` with an `O(h^2)` truncation
//! scale.
//!
//! [`EigenSystem::evaluate_magnitudes`]: crate::spectral::EigenSystem::evaluate_magnitudes

use crate::spectral::HyperParams;

/// A derivative estimate plus a conservative bound on its own error.
#[derive(Clone, Copy, Debug)]
pub struct FdEstimate {
    pub value: f64,
    /// Conservative bound on `|value - true derivative|`.
    pub err: f64,
}

/// Central difference `df/dx` at `x > 0` with step `h = eps^(1/3) x`.
///
/// `mag` is the rounding magnitude of `f` evaluations (pass `|f(x)|` for
/// well-conditioned objectives, or the cancellation magnitude for sums
/// with cancelling terms).
pub fn central<F: Fn(f64) -> f64>(f: F, x: f64, mag: f64) -> FdEstimate {
    debug_assert!(x > 0.0 && x.is_finite());
    let h = f64::EPSILON.cbrt() * x;
    let xp = x + h;
    let xm = x - h;
    let fp = f(xp);
    let fm = f(xm);
    let width = xp - xm; // exact in f64; may differ from 2h in the last ulp
    let value = (fp - fm) / width;
    let round_mag = mag.max(fp.abs()).max(fm.abs());
    let trunc_scale = value.abs().max(round_mag / x);
    let err = 2.0 * f64::EPSILON * round_mag / width
        + 10.0 * f64::EPSILON.powf(2.0 / 3.0) * trunc_scale;
    FdEstimate { value, err }
}

/// Gradient of a scalar objective over `(sigma2, lambda2)`.
/// `mag` is the rounding magnitude of `f` (see [`central`]).
pub fn grad_of<F: Fn(HyperParams) -> f64>(f: F, hp: HyperParams, mag: f64) -> [FdEstimate; 2] {
    [
        central(|s2| f(HyperParams::new(s2, hp.lambda2)), hp.sigma2, mag),
        central(|l2| f(HyperParams::new(hp.sigma2, l2)), hp.lambda2, mag),
    ]
}

/// Jacobian of a 2-vector function (e.g. a closed-form gradient) over
/// `(sigma2, lambda2)`: `out[i][j] = d g_j / d theta_i` with `theta_0 =
/// sigma2`, `theta_1 = lambda2`.  For `g = grad L` this is the Hessian
/// estimate, where `out[0][1]` and `out[1][0]` independently approximate
/// the mixed partial.  `mags[j]` is the rounding magnitude of `g_j`.
pub fn jac_of<G: Fn(HyperParams) -> [f64; 2]>(
    g: G,
    hp: HyperParams,
    mags: [f64; 2],
) -> [[FdEstimate; 2]; 2] {
    let component = |axis: usize, j: usize| -> FdEstimate {
        let f = |t: f64| {
            let p = match axis {
                0 => HyperParams::new(t, hp.lambda2),
                _ => HyperParams::new(hp.sigma2, t),
            };
            g(p)[j]
        };
        let x = if axis == 0 { hp.sigma2 } else { hp.lambda2 };
        central(f, x, mags[j])
    };
    [
        [component(0, 0), component(0, 1)],
        [component(1, 0), component(1, 1)],
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn central_recovers_polynomial_derivative() {
        // f(x) = x^3 - 2x, f'(2) = 10
        let est = central(|x| x * x * x - 2.0 * x, 2.0, 4.0);
        assert!((est.value - 10.0).abs() < 1e-8, "{est:?}");
        assert!((est.value - 10.0).abs() <= est.err, "error bound too tight: {est:?}");
    }

    #[test]
    fn central_error_bound_honest_on_log() {
        for &x in &[1e-8, 1e-3, 1.0, 1e5] {
            let est = central(|t| t.ln(), x, x.ln().abs().max(1.0));
            let truth = 1.0 / x;
            assert!(
                (est.value - truth).abs() <= est.err.max(1e-9 * truth.abs()),
                "x={x}: {est:?} vs {truth}"
            );
        }
    }

    #[test]
    fn grad_of_matches_known_gradient() {
        // f = sigma2^2 * lambda2, df/ds2 = 2 s2 l2, df/dl2 = s2^2
        let hp = HyperParams::new(1.5, 0.7);
        let g = grad_of(|h| h.sigma2 * h.sigma2 * h.lambda2, hp, 2.0);
        assert!((g[0].value - 2.0 * 1.5 * 0.7).abs() < 1e-7, "{:?}", g[0]);
        assert!((g[1].value - 1.5 * 1.5).abs() < 1e-7, "{:?}", g[1]);
    }

    #[test]
    fn jac_of_mixed_partials_symmetric() {
        // g = grad of f = s2^2 l2 + s2 l2^2 (exact closed form)
        let g = |h: HyperParams| {
            [
                2.0 * h.sigma2 * h.lambda2 + h.lambda2 * h.lambda2,
                h.sigma2 * h.sigma2 + 2.0 * h.sigma2 * h.lambda2,
            ]
        };
        let hp = HyperParams::new(0.8, 1.3);
        let m = jac_of(g, hp, [3.0, 3.0]);
        // true mixed partial: 2 s2 + 2 l2
        let truth = 2.0 * hp.sigma2 + 2.0 * hp.lambda2;
        assert!((m[0][1].value - truth).abs() < 1e-6, "{:?}", m[0][1]);
        assert!((m[1][0].value - truth).abs() < 1e-6, "{:?}", m[1][0]);
        assert!((m[0][1].value - m[1][0].value).abs() < 1e-6);
    }
}

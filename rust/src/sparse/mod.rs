//! Sparse (Nyström / subset-of-regressors) approximation baseline — the
//! "state of the art approximations" of paper §2.1, with O(N m^2) cost per
//! score evaluation.
//!
//! The Gram matrix is approximated by `K^ = C W^{-1} C'` with
//! `C = K[:, idx]` (N x m) and `W = K[idx, idx]`.  `K^` has at most m
//! nonzero eigenvalues; the paper's score (eq. 19) then needs only those m
//! eigenpairs plus the residual target mass on the null space (where
//! `d = 1`, `g = 5/sigma2`).
//!
//! Per evaluation the full pipeline (C'C product, m x m eigensolve,
//! projections) is recomputed — matching how sparse GP software behaves
//! inside a hyperparameter sweep where the kernel itself moves, which is
//! precisely the regime the paper's §2.1 comparison assumes.

use crate::kernelfn::Kernel;
use crate::linalg::{gemm, Cholesky, Matrix, SymEigen};
use crate::spectral::HyperParams;

/// Nyström score evaluator over `m` inducing points.
pub struct NystromEvaluator {
    /// N x m cross-Gram.
    c: Matrix,
    /// m x m inducing Gram (jittered).
    w: Matrix,
    y: Vec<f64>,
    yy: f64,
}

impl NystromEvaluator {
    /// Build from explicit inducing indices.
    pub fn new(kernel: Kernel, x: &Matrix, y: &[f64], inducing: &[usize]) -> Self {
        let m = inducing.len();
        assert!(m > 0 && m <= x.rows());
        let all: Vec<usize> = (0..x.rows()).collect();
        let full_cols = Matrix::from_fn(x.rows(), m, |i, j| {
            kernel.eval(x.row(all[i]), x.row(inducing[j]))
        });
        let mut w = Matrix::from_fn(m, m, |i, j| kernel.eval(x.row(inducing[i]), x.row(inducing[j])));
        w.add_diag(1e-10 * m as f64); // jitter for rank safety
        NystromEvaluator {
            c: full_cols,
            w,
            y: y.to_vec(),
            yy: y.iter().map(|v| v * v).sum(),
        }
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }
    pub fn m(&self) -> usize {
        self.w.rows()
    }

    /// The m (at most) nonzero eigenvalues of `K^` and the squared
    /// projections of `y` on their eigenvectors.  O(N m^2).
    fn reduced_spectrum(&self) -> (Vec<f64>, Vec<f64>) {
        // K^ = C W^{-1} C' = (C L^{-T}) (C L^{-T})' with W = L L'.
        // Nonzero eigenvalues of K^ == eigenvalues of B'B (m x m),
        // B = C L^{-T}; eigenvectors u_j = B v_j / sqrt(t_j).
        let ch = Cholesky::new(&self.w).expect("inducing Gram must be SPD");
        let l = ch.l();
        let (n, m) = (self.c.rows(), self.c.cols());
        // B = C L^{-T}: solve L b_row' = c_row' per row (forward subst on L)
        let mut b = Matrix::zeros(n, m);
        for i in 0..n {
            let crow = self.c.row(i);
            let brow = b.row_mut(i);
            for j in 0..m {
                let mut s = crow[j];
                for k in 0..j {
                    s -= l[(j, k)] * brow[k];
                }
                brow[j] = s / l[(j, j)];
            }
        }
        let btb = gemm::ata(&b); // m x m, O(N m^2)
        let eig = SymEigen::new(&btb).expect("B'B eigensolve");
        // y2t_j = (u_j' y)^2 = ((B v_j)' y)^2 / t_j = (v_j' (B' y))^2 / t_j
        let bty = b.matvec_t(&self.y); // m
        let mut t = Vec::with_capacity(m);
        let mut y2t = Vec::with_capacity(m);
        for j in 0..m {
            let tj = eig.values[j].max(0.0);
            let vj = eig.vectors.col(j);
            let proj: f64 = vj.iter().zip(&bty).map(|(a, b)| a * b).sum();
            if tj > 1e-12 {
                t.push(tj);
                y2t.push(proj * proj / tj);
            } else {
                t.push(0.0);
                y2t.push(0.0);
            }
        }
        (t, y2t)
    }

    /// Paper-form score (eq. 19) of the Nyström-approximated model.
    /// O(N m^2) per call.
    pub fn score(&self, hp: HyperParams) -> f64 {
        let (t, y2t) = self.reduced_spectrum();
        let HyperParams { sigma2, lambda2 } = hp;
        let mut acc = 0.0;
        let mut captured = 0.0;
        for (&tj, &y2) in t.iter().zip(&y2t) {
            if tj == 0.0 {
                continue;
            }
            let a = lambda2 * tj + sigma2;
            let b = 2.0 * lambda2 * tj + sigma2;
            let d = b / a;
            let g = (d * d + 4.0) / (sigma2 * d);
            acc += d.ln() + y2 * g;
            captured += y2;
        }
        // null-space directions: d = 1 (log 0), g = 5 / sigma2, and they
        // carry the residual target mass y'y - sum captured projections.
        let residual = (self.yy - captured).max(0.0);
        acc += residual * 5.0 / sigma2;
        self.n() as f64 * sigma2.ln() + acc - 4.0 * self.yy / sigma2
    }
}

/// Pick `m` evenly spread inducing indices (deterministic; benches use a
/// seeded random choice instead where noted).
pub fn even_inducing(n: usize, m: usize) -> Vec<usize> {
    assert!(m >= 1 && m <= n);
    (0..m).map(|j| j * n / m).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spectral::SpectralGp;
    use crate::util::rng::Rng;

    fn setup(n: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(n, 2, |_, _| rng.normal());
        let y = rng.normal_vec(n);
        (x, y)
    }

    #[test]
    fn full_inducing_set_recovers_exact_score() {
        let (x, y) = setup(30, 1);
        let kern = Kernel::Rbf { xi2: 1.0 };
        let all: Vec<usize> = (0..30).collect();
        let ny = NystromEvaluator::new(kern, &x, &y, &all);
        let gp = SpectralGp::fit(kern, x).unwrap();
        let es = gp.eigensystem(&y);
        for hp in [HyperParams::new(0.5, 1.5), HyperParams::new(2.0, 0.3)] {
            let a = ny.score(hp);
            let b = es.score(hp);
            assert!(
                (a - b).abs() < 1e-5 * b.abs().max(1.0),
                "m=n score mismatch: {a} vs {b}"
            );
        }
    }

    #[test]
    fn approximation_improves_with_m() {
        let (x, y) = setup(60, 2);
        let kern = Kernel::Rbf { xi2: 2.0 };
        let gp = SpectralGp::fit(kern, x.clone()).unwrap();
        let es = gp.eigensystem(&y);
        let hp = HyperParams::new(0.7, 1.0);
        let exact = es.score(hp);
        let errs: Vec<f64> = [5, 15, 40, 60]
            .iter()
            .map(|&m| {
                let ny = NystromEvaluator::new(kern, &x, &y, &even_inducing(60, m));
                (ny.score(hp) - exact).abs()
            })
            .collect();
        assert!(
            errs[3] <= errs[0] + 1e-9,
            "error should shrink from m=5 ({}) to m=60 ({})",
            errs[0],
            errs[3]
        );
        assert!(errs[3] < 1e-4 * exact.abs().max(1.0), "m=n err {}", errs[3]);
    }

    #[test]
    fn even_inducing_is_sorted_unique_in_range() {
        let idx = even_inducing(100, 10);
        assert_eq!(idx.len(), 10);
        for w in idx.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(*idx.last().unwrap() < 100);
    }

    #[test]
    fn score_is_finite_for_extreme_hyperparams() {
        let (x, y) = setup(40, 3);
        let ny = NystromEvaluator::new(Kernel::Rbf { xi2: 1.0 }, &x, &y, &even_inducing(40, 8));
        for hp in [
            HyperParams::new(1e-6, 1e3),
            HyperParams::new(1e3, 1e-6),
            HyperParams::new(1e-6, 1e-6),
        ] {
            assert!(ny.score(hp).is_finite(), "hp={hp:?}");
        }
    }
}

//! Sparse approximation baselines — the "state of the art approximations
//! [that] rely on sparse kernel matrices" of paper §2.1, implemented as a
//! real tuning baseline rather than a score-only stub.
//!
//! Two classical low-rank constructions over `m` inducing points share
//! one evaluator ([`SparseGp`]):
//!
//! - [`SparseMethod::Sor`] — subset of regressors: the Gram matrix is
//!   replaced by `K^ = C W^{-1} C'` with `C = K[:, idx]` (N x m) and
//!   `W = K[idx, idx]`, and the score uses the **exact** spectrum of
//!   `K^`: with `W = L L'` and `B = C L^{-T}`, the nonzero eigenvalues
//!   of `K^` are the eigenvalues of `B'B` (m x m) and the eigenvectors
//!   are `u_j = B v_j / sqrt(t_j)`.  O(N m^2) per spectrum.
//! - [`SparseMethod::Nystrom`] — the Williams–Seeger approximation:
//!   eigensolve `W` itself (m x m), scale `t^_j = (N/m) t_j(W)` and lift
//!   `u_j = sqrt(m/N) (1/t_j) C v_j`.  O(m^3 + N m) per spectrum —
//!   cheaper than SoR, but the lifted eigenvectors are only
//!   approximately orthonormal, so the score error is larger at equal m.
//!
//! Either way the result is a **compact** [`EigenSystem`]: the (at most)
//! m nonzero eigenvalues plus one zero-eigenvalue slot carrying the
//! residual target mass `y'y - sum_j (u_j'y)^2`.  Eq. (19) treats a
//! zero eigenvalue as `d = 1, g = 5/sigma2` — exactly the null-space
//! contribution — and the `N log sigma2` / `4 y'y / sigma2` closures use
//! the true N and y'y carried in the struct, so the paper's O(len)
//! score/Jacobian/Hessian code evaluates the sparse model in O(m) with
//! no padding.  That also means the sparse model plugs straight into
//! Newton refinement and the two-step engine ([`SparseProvider`]).
//!
//! Two evaluation regimes, both kept on purpose (DESIGN.md §13):
//!
//! - [`SparseGp::score`] recomputes the reduced spectrum per call —
//!   matching how sparse GP software behaves inside a *kernel*
//!   hyperparameter sweep where `C`/`W` move under theta, which is the
//!   regime the paper's §2.1 crossover argument assumes (k* O(N m^2)
//!   versus the exact method's O(N^3) + k* O(N)).
//! - [`SparseGp::eigensystem`] computes the spectrum **once** and caches
//!   it, so (sigma2, lambda2) probes at a fixed kernel cost O(m) each —
//!   the fair sparse counterpart of the paper's own amortization, and
//!   bitwise identical to the recomputed path at any pool width.
//!
//! The SoR `B = C L^{-T}` solve is row-blocked across the scoped pool
//! with a fixed-shape grain (a function of m only, never the pool
//! width), and `B'B` uses the pooled [`gemm::ata`], so the whole
//! pipeline obeys the repo's bit-determinism policy (DESIGN.md §6;
//! gated in `rust/tests/par_determinism.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::kernelfn::{cross_gram, gram, Kernel, ThetaDomainVec, ThetaVec};
use crate::linalg::{gemm, Cholesky, Matrix, SymEigen};
use crate::optim::SetupProvider;
use crate::spectral::{EigenSystem, HyperParams};
use crate::util::threadpool;

/// Eigenvalues below this are treated as null-space directions (their
/// target mass moves into the residual slot).
const EIGEN_FLOOR: f64 = 1e-12;

/// Flops per row-block of the SoR `B = C L^{-T}` forward substitution
/// (each row costs ~m^2/2): the block shape depends only on m, never on
/// the pool width, so pooled runs are bit-identical to serial.
const B_SOLVE_GRAIN_FLOPS: usize = 1 << 17;

/// Which low-rank construction a [`SparseGp`] evaluates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparseMethod {
    /// Subset of regressors: exact spectrum of `C W^{-1} C'`, O(N m^2).
    Sor,
    /// Williams–Seeger Nyström: scaled m x m spectrum, O(m^3 + N m).
    Nystrom,
}

impl SparseMethod {
    pub fn as_str(&self) -> &'static str {
        match self {
            SparseMethod::Sor => "sor",
            SparseMethod::Nystrom => "nystrom",
        }
    }
}

/// Sparse score evaluator over `m` inducing points (see module docs).
#[derive(Clone)]
pub struct SparseGp {
    method: SparseMethod,
    /// N x m cross-Gram `C = K[:, idx]`.
    c: Matrix,
    /// m x m inducing Gram `W = K[idx, idx]` (jittered).
    w: Matrix,
    y: Vec<f64>,
    yy: f64,
    /// Cached-spectrum fast path (one spectrum per kernel, O(m) probes).
    cached: Option<EigenSystem>,
}

impl SparseGp {
    /// Build from explicit inducing indices.  Errors on an empty or
    /// out-of-range index set (or m > N, which neither construction
    /// supports).
    pub fn new(
        method: SparseMethod,
        kernel: Kernel,
        x: &Matrix,
        y: &[f64],
        inducing: &[usize],
    ) -> Result<SparseGp, String> {
        let (n, m) = (x.rows(), inducing.len());
        if m == 0 || m > n {
            return Err(format!("inducing set has {m} points (need 1..={n})"));
        }
        if let Some(&bad) = inducing.iter().find(|&&i| i >= n) {
            return Err(format!("inducing index {bad} out of range 0..{n}"));
        }
        assert_eq!(y.len(), n, "target length mismatch");
        let cols: Vec<usize> = (0..x.cols()).collect();
        let xu = x.select(inducing, &cols);
        let c = cross_gram(kernel, x, &xu);
        let mut w = gram(kernel, &xu);
        w.add_diag(1e-10 * m as f64); // jitter for rank safety
        Ok(SparseGp {
            method,
            c,
            w,
            y: y.to_vec(),
            yy: y.iter().map(|v| v * v).sum(),
            cached: None,
        })
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }
    pub fn m(&self) -> usize {
        self.w.rows()
    }
    pub fn method(&self) -> SparseMethod {
        self.method
    }

    /// The compact eigensystem of the approximated model: m (at most)
    /// nonzero eigenvalues + one zero slot carrying the residual target
    /// mass.  O(N m^2) for SoR, O(m^3 + N m) for Nyström.
    pub fn reduced_spectrum(&self) -> Result<EigenSystem, String> {
        let (t, y2t) = match self.method {
            SparseMethod::Sor => self.sor_spectrum()?,
            SparseMethod::Nystrom => self.nystrom_spectrum()?,
        };
        let captured: f64 = y2t.iter().sum();
        // Null-space directions share d = 1 (zero log-det contribution)
        // and g = 5/sigma2; eq. (19) is linear in the projected mass, so
        // one aggregate zero-eigenvalue slot carries all of it.  Lifted
        // Nyström eigenvectors are not exactly orthonormal, so clamp.
        let residual = (self.yy - captured).max(0.0);
        let mut s = t;
        let mut y2 = y2t;
        s.push(0.0);
        y2.push(residual);
        Ok(EigenSystem::from_parts(s, y2, self.n(), self.yy))
    }

    /// SoR: exact spectrum of `C W^{-1} C'` through `B = C L^{-T}`.
    fn sor_spectrum(&self) -> Result<(Vec<f64>, Vec<f64>), String> {
        let ch = Cholesky::new(&self.w)
            .map_err(|e| format!("sparse inducing Gram not SPD: {e}"))?;
        let l = ch.l();
        let (n, m) = (self.c.rows(), self.c.cols());
        // B = C L^{-T}: row i solves L b_i' = c_i' (forward substitution).
        // Rows are independent; fan them out in fixed-shape blocks whose
        // size depends only on m, with per-row arithmetic identical to
        // the serial loop — bit-identical at any pool width.
        let rows_per_block = (B_SOLVE_GRAIN_FLOPS / (m * m).max(1)).max(1);
        let mut b = Matrix::zeros(n, m);
        threadpool::par_chunks_mut(b.data_mut(), rows_per_block * m, |ci, chunk| {
            let i0 = ci * rows_per_block;
            for (r, brow) in chunk.chunks_mut(m).enumerate() {
                let crow = self.c.row(i0 + r);
                for j in 0..m {
                    let mut s = crow[j];
                    for k in 0..j {
                        s -= l[(j, k)] * brow[k];
                    }
                    brow[j] = s / l[(j, j)];
                }
            }
        });
        let btb = gemm::ata(&b); // m x m, O(N m^2), pooled
        let eig = SymEigen::new(&btb).map_err(|e| format!("sparse B'B eigensolve: {e}"))?;
        // y2t_j = (u_j'y)^2 = ((B v_j)'y)^2 / t_j = (v_j'(B'y))^2 / t_j
        let bty = b.matvec_t(&self.y); // m
        let mut t = Vec::with_capacity(m);
        let mut y2t = Vec::with_capacity(m);
        for j in 0..m {
            let tj = eig.values[j].max(0.0);
            let vj = eig.vectors.col(j);
            let proj: f64 = vj.iter().zip(&bty).map(|(a, b)| a * b).sum();
            if tj > EIGEN_FLOOR {
                t.push(tj);
                y2t.push(proj * proj / tj);
            } else {
                t.push(0.0);
                y2t.push(0.0);
            }
        }
        Ok((t, y2t))
    }

    /// Williams–Seeger Nyström: eigensolve W itself and lift.
    fn nystrom_spectrum(&self) -> Result<(Vec<f64>, Vec<f64>), String> {
        let (n, m) = (self.c.rows(), self.c.cols());
        let eig = SymEigen::new(&self.w).map_err(|e| format!("sparse W eigensolve: {e}"))?;
        let scale = n as f64 / m as f64;
        // u_j = sqrt(m/N) (1/t_j) C v_j, so
        // (u_j'y)^2 = (m/N) (v_j'(C'y))^2 / t_j^2
        let cty = self.c.matvec_t(&self.y); // m
        let mut t = Vec::with_capacity(m);
        let mut y2t = Vec::with_capacity(m);
        for j in 0..m {
            let wj = eig.values[j].max(0.0);
            let vj = eig.vectors.col(j);
            let proj: f64 = vj.iter().zip(&cty).map(|(a, b)| a * b).sum();
            if wj > EIGEN_FLOOR {
                t.push(scale * wj);
                y2t.push(proj * proj / (scale * wj * wj));
            } else {
                t.push(0.0);
                y2t.push(0.0);
            }
        }
        Ok((t, y2t))
    }

    /// Paper-form score (eq. 19) of the approximated model, spectrum
    /// **recomputed per call** — the paper's §2.1 sweep regime.
    /// O(N m^2) per call for SoR, O(m^3 + N m) for Nyström.
    pub fn score(&self, hp: HyperParams) -> f64 {
        self.reduced_spectrum().expect("sparse reduced spectrum").score(hp)
    }

    /// Cached-spectrum fast path: the reduced spectrum is computed once
    /// and reused, so subsequent (sigma2, lambda2) probes cost O(m).
    /// Bitwise identical to [`score`](Self::score) — both run the same
    /// spectrum pipeline and the same eq. (19) evaluator.
    pub fn eigensystem(&mut self) -> Result<&EigenSystem, String> {
        if self.cached.is_none() {
            self.cached = Some(self.reduced_spectrum()?);
        }
        Ok(self.cached.as_ref().expect("just cached"))
    }

    /// Consume the evaluator into its compact eigensystem (the setup the
    /// two-step engine memoizes per quantized theta).
    pub fn into_eigensystem(mut self) -> Result<EigenSystem, String> {
        self.eigensystem()?;
        Ok(self.cached.expect("just cached"))
    }
}

/// Pick `m` evenly spread inducing indices (deterministic; benches use a
/// seeded random choice instead where noted).
pub fn even_inducing(n: usize, m: usize) -> Vec<usize> {
    assert!(m >= 1 && m <= n);
    (0..m).map(|j| j * n / m).collect()
}

/// [`SetupProvider`] over a sparse baseline: each quantized theta builds
/// the kernel at that theta, assembles `C`/`W`, and returns the compact
/// cached [`EigenSystem`] as the O(m) inner objective — so the existing
/// two-step engine (`optim::theta_tune`) drives sparse sweeps through
/// the same quantize -> memoize pipeline as the exact method, and the
/// engine's `outer_evals` counts sparse O(N m^2) setups exactly like it
/// counts exact O(N^3) ones.
///
/// The engine pins each `setup` call to `with_threads(1)` for canonical
/// bit-identical results across pool widths; direct bench/test callers
/// get the pooled SoR solve.
pub struct SparseProvider {
    method: SparseMethod,
    base: Kernel,
    x: Matrix,
    y: Vec<f64>,
    inducing: Vec<usize>,
    built: AtomicUsize,
}

impl SparseProvider {
    /// Validates the inducing set once up front (the per-theta
    /// [`SparseGp::new`] revalidates cheaply).
    pub fn new(
        method: SparseMethod,
        base: Kernel,
        x: Matrix,
        y: Vec<f64>,
        inducing: Vec<usize>,
    ) -> Result<SparseProvider, String> {
        let n = x.rows();
        if inducing.is_empty() || inducing.len() > n {
            return Err(format!("inducing set has {} points (need 1..={n})", inducing.len()));
        }
        if let Some(&bad) = inducing.iter().find(|&&i| i >= n) {
            return Err(format!("inducing index {bad} out of range 0..{n}"));
        }
        assert_eq!(y.len(), n, "target length mismatch");
        Ok(SparseProvider { method, base, x, y, inducing, built: AtomicUsize::new(0) })
    }

    pub fn method(&self) -> SparseMethod {
        self.method
    }
}

impl SetupProvider for SparseProvider {
    type Obj = EigenSystem;

    fn domain(&self) -> ThetaDomainVec {
        self.base.theta_vec_domain()
    }

    fn setup(&self, theta: &ThetaVec) -> Result<EigenSystem, String> {
        self.built.fetch_add(1, Ordering::Relaxed);
        let kernel = self.base.with_theta_vec(theta);
        SparseGp::new(self.method, kernel, &self.x, &self.y, &self.inducing)?.into_eigensystem()
    }

    fn setups_built(&self) -> usize {
        self.built.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spectral::SpectralGp;
    use crate::util::rng::Rng;

    fn setup(n: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(n, 2, |_, _| rng.normal());
        let y = rng.normal_vec(n);
        (x, y)
    }

    #[test]
    fn full_inducing_set_recovers_exact_score_for_both_methods() {
        let (x, y) = setup(30, 1);
        let kern = Kernel::Rbf { xi2: 1.0 };
        let all: Vec<usize> = (0..30).collect();
        let gp = SpectralGp::fit(kern, x.clone()).unwrap();
        let es = gp.eigensystem(&y);
        for method in [SparseMethod::Sor, SparseMethod::Nystrom] {
            let sp = SparseGp::new(method, kern, &x, &y, &all).unwrap();
            for hp in [HyperParams::new(0.5, 1.5), HyperParams::new(2.0, 0.3)] {
                let a = sp.score(hp);
                let b = es.score(hp);
                assert!(
                    (a - b).abs() < 1e-5 * b.abs().max(1.0),
                    "{} m=n score mismatch: {a} vs {b}",
                    method.as_str()
                );
            }
        }
    }

    #[test]
    fn approximation_improves_with_m() {
        let (x, y) = setup(60, 2);
        let kern = Kernel::Rbf { xi2: 2.0 };
        let gp = SpectralGp::fit(kern, x.clone()).unwrap();
        let es = gp.eigensystem(&y);
        let hp = HyperParams::new(0.7, 1.0);
        let exact = es.score(hp);
        for method in [SparseMethod::Sor, SparseMethod::Nystrom] {
            let errs: Vec<f64> = [5, 15, 40, 60]
                .iter()
                .map(|&m| {
                    let sp = SparseGp::new(method, kern, &x, &y, &even_inducing(60, m)).unwrap();
                    (sp.score(hp) - exact).abs()
                })
                .collect();
            assert!(
                errs[3] <= errs[0] + 1e-9,
                "{}: error should shrink from m=5 ({}) to m=60 ({})",
                method.as_str(),
                errs[0],
                errs[3]
            );
            assert!(
                errs[3] < 1e-4 * exact.abs().max(1.0),
                "{}: m=n err {}",
                method.as_str(),
                errs[3]
            );
        }
    }

    #[test]
    fn cached_eigensystem_matches_recomputed_score_bitwise() {
        let (x, y) = setup(50, 4);
        let kern = Kernel::Rbf { xi2: 1.3 };
        for method in [SparseMethod::Sor, SparseMethod::Nystrom] {
            let mut sp = SparseGp::new(method, kern, &x, &y, &even_inducing(50, 12)).unwrap();
            let cached = sp.eigensystem().unwrap().clone();
            for hp in [
                HyperParams::new(0.5, 1.5),
                HyperParams::new(1.0, 1.0),
                HyperParams::new(3.0, 0.2),
            ] {
                assert_eq!(
                    cached.score(hp).to_bits(),
                    sp.score(hp).to_bits(),
                    "{}: cached vs recomputed drift",
                    method.as_str()
                );
            }
        }
    }

    #[test]
    fn sor_is_at_least_as_accurate_as_nystrom_on_average() {
        // SoR uses the exact spectrum of C W^{-1} C'; Williams–Seeger
        // approximates it.  Averaged over probes the exact-spectrum
        // variant should not lose (small slack for lucky cancellation).
        let (x, y) = setup(60, 5);
        let kern = Kernel::Rbf { xi2: 1.5 };
        let gp = SpectralGp::fit(kern, x.clone()).unwrap();
        let exact = gp.eigensystem(&y);
        let idx = even_inducing(60, 15);
        let sor = SparseGp::new(SparseMethod::Sor, kern, &x, &y, &idx).unwrap();
        let ny = SparseGp::new(SparseMethod::Nystrom, kern, &x, &y, &idx).unwrap();
        let hps = [
            HyperParams::new(0.5, 1.5),
            HyperParams::new(1.0, 1.0),
            HyperParams::new(2.0, 0.5),
        ];
        let avg = |sp: &SparseGp| -> f64 {
            hps.iter().map(|&hp| (sp.score(hp) - exact.score(hp)).abs()).sum::<f64>()
                / hps.len() as f64
        };
        assert!(
            avg(&sor) <= 2.0 * avg(&ny) + 1e-9,
            "SoR err {} vs Nyström err {}",
            avg(&sor),
            avg(&ny)
        );
    }

    #[test]
    fn even_inducing_is_sorted_unique_in_range() {
        let idx = even_inducing(100, 10);
        assert_eq!(idx.len(), 10);
        for w in idx.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(*idx.last().unwrap() < 100);
    }

    #[test]
    fn score_is_finite_for_extreme_hyperparams() {
        let (x, y) = setup(40, 3);
        for method in [SparseMethod::Sor, SparseMethod::Nystrom] {
            let sp = SparseGp::new(method, Kernel::Rbf { xi2: 1.0 }, &x, &y, &even_inducing(40, 8))
                .unwrap();
            for hp in [
                HyperParams::new(1e-6, 1e3),
                HyperParams::new(1e3, 1e-6),
                HyperParams::new(1e-6, 1e-6),
            ] {
                assert!(sp.score(hp).is_finite(), "{} hp={hp:?}", method.as_str());
            }
        }
    }

    #[test]
    fn bad_inducing_sets_error_cleanly() {
        let (x, y) = setup(20, 6);
        let kern = Kernel::Rbf { xi2: 1.0 };
        assert!(SparseGp::new(SparseMethod::Sor, kern, &x, &y, &[]).is_err());
        assert!(SparseGp::new(SparseMethod::Sor, kern, &x, &y, &[20]).is_err());
        let too_many: Vec<usize> = (0..21).map(|i| i % 20).collect();
        assert!(SparseGp::new(SparseMethod::Sor, kern, &x, &y, &too_many).is_err());
    }
}

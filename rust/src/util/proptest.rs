//! Tiny property-testing harness (proptest is not vendored — DESIGN.md §5).
//!
//! [`forall`] runs a property over `cases` randomly generated inputs from a
//! seeded [`Rng`]; on failure it reports the case index and the seed that
//! reproduces it.  No shrinking — generators here are small enough that raw
//! counterexamples are readable.

use crate::util::rng::Rng;

/// Run `prop` over `cases` inputs drawn by `gen`. Panics with a
/// reproducible seed on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {case_seed}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Relative-or-absolute closeness check (mirrors numpy.allclose semantics).
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// Assert-style wrapper producing a useful message for [`forall`] props.
pub fn check_close(what: &str, a: f64, b: f64, rtol: f64, atol: f64) -> Result<(), String> {
    if close(a, b, rtol, atol) {
        Ok(())
    } else {
        Err(format!(
            "{what}: {a:.17e} vs {b:.17e} (|diff|={:.3e}, rtol={rtol:.1e}, atol={atol:.1e})",
            (a - b).abs()
        ))
    }
}

/// Max |a-b| over two slices (convenience for vector comparisons).
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_on_true_property() {
        forall(
            "square nonneg",
            42,
            100,
            |rng| rng.normal(),
            |x| {
                if x * x >= 0.0 { Ok(()) } else { Err("negative square".into()) }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_panics_with_seed_on_failure() {
        forall("always fails", 1, 10, |rng| rng.uniform(), |_| Err("nope".into()));
    }

    #[test]
    fn close_semantics() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, 0.0));
        assert!(!close(1.0, 1.1, 1e-9, 0.0));
        assert!(close(0.0, 1e-15, 0.0, 1e-12));
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
    }
}

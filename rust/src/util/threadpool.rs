//! Scoped work pool — the repo's parallel execution substrate (rayon is
//! not vendored; DESIGN.md §5/§6).
//!
//! Every super-linear hot path (Gram construction, GEMM, the `tred2` /
//! `tql2` eigensolver sweeps, Strassen quadrants, global-search
//! wavefronts) fans out through the three primitives here:
//!
//! - [`par_for`] — dynamic chunked index loop (load-balanced via an
//!   atomic cursor);
//! - [`par_chunks_mut`] — disjoint `&mut` chunks of one slice;
//! - [`par_map`] — map a slice to an owned result vector.
//!
//! Workers are spawned per call on [`std::thread::scope`], so closures
//! borrow freely from the caller's stack and panics propagate when the
//! scope joins (a panicking worker aborts the whole call, exactly like
//! the serial loop would).  There is deliberately no persistent worker
//! state: thread spawn is ~10 µs on Linux, negligible against the ≥ ~1 ms
//! work items the grain thresholds admit, and it keeps the pool
//! re-entrant and fork-safe.
//!
//! ## Thread-count resolution
//!
//! Highest priority first:
//! 1. a thread-local override installed by [`with_threads`] (tests,
//!    per-request plumbing);
//! 2. the process-wide value from [`set_threads`] (`--threads` CLI flag);
//! 3. the `GPML_THREADS` environment variable (read once);
//! 4. `std::thread::available_parallelism()`.
//!
//! `1` means *exact serial fallback*: the primitives run the identical
//! in-order loop on the calling thread — same code path, same FP
//! arithmetic, bit-identical output.
//!
//! ## Determinism policy
//!
//! All call sites partition *writes* disjointly (rows, column blocks,
//! stripes) and keep the per-element arithmetic identical to the serial
//! loop, so results are bit-identical across thread counts, with one
//! exception: block-local partial reductions (e.g. the `tred2`
//! accumulation sweep) re-associate a sum across worker blocks and may
//! differ from serial by O(eps) — the differential-verification suite
//! (DESIGN.md §4) gates those sites.
//!
//! ## Nesting
//!
//! A `par_*` call from inside a pool worker runs serially inline (an
//! `IN_POOL` thread-local guards against exponential spawn storms), so
//! nested parallel structures — Strassen quadrants whose base-case GEMM
//! is itself parallel — cost nothing extra and cannot deadlock.

use std::cell::Cell;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide override from `--threads` (0 = unset → env/auto).
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override from [`with_threads`] (0 = unset).
    static LOCAL_THREADS: Cell<usize> = const { Cell::new(0) };
    /// Set while this thread is executing inside a pool worker.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// `ceil(a / b)` (usize::div_ceil needs rustc 1.73; MSRV here is 1.66).
/// Public: the pooled call sites in linalg/kernelfn share it.
pub fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Hardware parallelism, cached (the benign double-init race recomputes
/// the same value).
fn hardware_threads() -> usize {
    static CACHE: AtomicUsize = AtomicUsize::new(0);
    let cached = CACHE.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    CACHE.store(n, Ordering::Relaxed);
    n
}

/// `GPML_THREADS` / `available_parallelism` default, cached after the
/// first resolution.
fn default_threads() -> usize {
    static CACHE: AtomicUsize = AtomicUsize::new(0);
    let cached = CACHE.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = match std::env::var("GPML_THREADS").ok().and_then(|s| s.parse::<usize>().ok()) {
        Some(n) if n >= 1 => n,
        _ => hardware_threads(),
    };
    CACHE.store(n, Ordering::Relaxed);
    n
}

/// The worker count a `par_*` call issued right now would use.
///
/// Every source (per-request override, `--threads`, `GPML_THREADS`) is
/// clamped to 8x the hardware parallelism: widths are attacker- or
/// typo-reachable (the coordinator protocol carries one per request),
/// and an unclamped width would spawn that many OS threads per `par_*`
/// call — `std::thread::scope` panics if a spawn fails.  Modest
/// oversubscription stays allowed for experiments.
pub fn num_threads() -> usize {
    let cap = 8 * hardware_threads();
    let local = LOCAL_THREADS.with(Cell::get);
    if local != 0 {
        return local.min(cap);
    }
    let global = GLOBAL_THREADS.load(Ordering::Relaxed);
    if global != 0 {
        return global.min(cap);
    }
    default_threads().min(cap)
}

/// Install a process-wide thread count (the `--threads` CLI flag);
/// `0` restores env/auto resolution.
pub fn set_threads(n: usize) {
    GLOBAL_THREADS.store(n, Ordering::Relaxed);
}

/// Run `f` with the calling thread's pool width pinned to `n` (`0` =
/// no-op passthrough).  Scoped and re-entrant: used by tests to compare
/// serial vs pooled output in one process, and by the coordinator to
/// honor a per-request thread count.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    // drop guard so a panicking `f` still restores the previous width
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            LOCAL_THREADS.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(LOCAL_THREADS.with(|c| c.replace(n)));
    f()
}

/// True while executing inside a pool worker (nested `par_*` calls run
/// serially inline).
pub fn in_pool() -> bool {
    IN_POOL.with(Cell::get)
}

/// Worker count a call over `items` units would use when spawning is
/// only worthwhile if each worker gets at least `grain` units: small
/// inputs (and nested calls) collapse to 1, the exact serial path.
/// Public so block-reduction call sites (per-worker partial sums) can
/// size their scratch buffers with the same policy `par_for` applies.
pub fn plan_workers(items: usize, grain: usize) -> usize {
    if items == 0 || in_pool() {
        return 1;
    }
    num_threads().min(div_ceil(items, grain.max(1)))
}

/// Parallel `for i in 0..items { f(i) }`.
///
/// `grain` is both the scheduling quantum (workers claim `grain` indices
/// at a time off an atomic cursor — dynamic, so triangular workloads
/// like Gram rows balance) and the minimum per-worker work unit below
/// which the call degenerates to the serial in-order loop.  `f` must be
/// safe to call concurrently for distinct `i`.
pub fn par_for<F: Fn(usize) + Sync>(items: usize, grain: usize, f: F) {
    let workers = plan_workers(items, grain);
    if workers <= 1 {
        for i in 0..items {
            f(i);
        }
        return;
    }
    let grain = grain.max(1);
    let cursor = AtomicUsize::new(0);
    let run = |f: &F| loop {
        let start = cursor.fetch_add(grain, Ordering::Relaxed);
        if start >= items {
            break;
        }
        for i in start..(start + grain).min(items) {
            f(i);
        }
    };
    // Drop guard, not a trailing store: a panicking worker unwinds
    // through here and the calling thread must not stay marked in-pool.
    struct PoolGuard(bool);
    impl Drop for PoolGuard {
        fn drop(&mut self) {
            IN_POOL.with(|c| c.set(self.0));
        }
    }
    let _guard = PoolGuard(IN_POOL.with(|c| c.replace(true)));
    std::thread::scope(|s| {
        for _ in 1..workers {
            s.spawn(|| {
                IN_POOL.with(|c| c.set(true));
                run(&f);
            });
        }
        run(&f); // the calling thread is worker 0
    });
}

/// Parallel iteration over disjoint `chunk_len`-sized chunks of `data`;
/// `f(chunk_index, chunk)` — `chunk_index * chunk_len` is the chunk's
/// base offset.  One chunk is the scheduling grain.
pub fn par_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk_len: usize,
    f: F,
) {
    let chunk_len = chunk_len.max(1);
    let len = data.len();
    let shared = SharedMut::new(data);
    par_for(div_ceil(len, chunk_len), 1, |ci| {
        let start = ci * chunk_len;
        let end = (start + chunk_len).min(len);
        // Safety: chunk ranges are disjoint across `ci`.
        f(ci, unsafe { shared.slice_mut(start, end) });
    });
}

/// Parallel `items.iter().map(f).collect()`, preserving order.  `grain`
/// as in [`par_for`].
pub fn par_map<T: Sync, R: Send, F: Fn(&T) -> R + Sync>(
    items: &[T],
    grain: usize,
    f: F,
) -> Vec<R> {
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    {
        let shared = SharedMut::new(&mut out[..]);
        // Safety: each index is written by exactly one worker.
        par_for(items.len(), grain, |i| unsafe {
            *shared.get_mut(i) = Some(f(&items[i]));
        });
    }
    out.into_iter().map(|o| o.expect("par_map slot unfilled")).collect()
}

/// Shared-mutable view of a slice for writes that are disjoint by
/// construction but not expressible as `split_at_mut` (interleaved
/// column ranges, scattered rows).  Every access is `unsafe`; the caller
/// contracts that no index is written by two workers concurrently and
/// nothing written by one worker is read by another before the scope
/// joins.
pub struct SharedMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _lifetime: PhantomData<&'a mut [T]>,
}

// Safety: SharedMut only hands out raw access under the documented
// disjointness contract; T: Send suffices because values never move
// between threads, they are only written in place.
unsafe impl<T: Send> Sync for SharedMut<'_, T> {}
unsafe impl<T: Send> Send for SharedMut<'_, T> {}

impl<'a, T> SharedMut<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        SharedMut { ptr: slice.as_mut_ptr(), len: slice.len(), _lifetime: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// # Safety
    /// `i < len`, and no other worker accesses index `i` concurrently.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }

    /// # Safety
    /// `start <= end <= len`, and no other worker accesses
    /// `[start, end)` concurrently.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, end: usize) -> &mut [T] {
        debug_assert!(start <= end && end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), end - start)
    }

    /// Raw read without materializing a reference (so it may target
    /// elements adjacent to another worker's write range).
    ///
    /// # Safety
    /// `i < len`, and no worker writes index `i` concurrently.
    pub unsafe fn read(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        std::ptr::read(self.ptr.add(i))
    }

    /// Raw write without materializing a reference.
    ///
    /// # Safety
    /// `i < len`, and no other worker reads or writes index `i`
    /// concurrently.
    pub unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        std::ptr::write(self.ptr.add(i), value);
    }

    /// Shared view of `[start, end)` for reads.
    ///
    /// # Safety
    /// `start <= end <= len`, and no worker writes inside `[start, end)`
    /// concurrently.
    pub unsafe fn slice_ref(&self, start: usize, end: usize) -> &[T] {
        debug_assert!(start <= end && end <= self.len);
        std::slice::from_raw_parts(self.ptr.add(start), end - start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_empty_input() {
        par_for(0, 1, |_| panic!("must not be called"));
    }

    #[test]
    fn par_for_single_item() {
        let hits = AtomicUsize::new(0);
        par_for(1, 1, |i| {
            assert_eq!(i, 0);
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn par_for_covers_every_index_exactly_once() {
        for threads in [1, 2, 3, 8] {
            with_threads(threads, || {
                let n = 1037;
                let mask = AtomicU64::new(0);
                let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                par_for(n, 1, |i| {
                    counts[i].fetch_add(1, Ordering::Relaxed);
                    mask.fetch_add(1, Ordering::Relaxed);
                });
                assert_eq!(mask.load(Ordering::Relaxed), n as u64);
                assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
            });
        }
    }

    #[test]
    fn par_for_grain_collapses_small_inputs_to_serial() {
        // 8 items at grain 16 -> one worker -> runs on the calling thread
        let caller = std::thread::current().id();
        par_for(8, 16, |_| {
            assert_eq!(std::thread::current().id(), caller);
        });
    }

    #[test]
    fn par_map_preserves_order() {
        with_threads(4, || {
            let xs: Vec<usize> = (0..513).collect();
            let ys = par_map(&xs, 1, |&x| x * 2 + 1);
            assert_eq!(ys, xs.iter().map(|&x| x * 2 + 1).collect::<Vec<_>>());
        });
    }

    #[test]
    fn par_chunks_mut_writes_disjoint_chunks() {
        with_threads(4, || {
            let mut data = vec![0.0f64; 1000];
            par_chunks_mut(&mut data, 64, |ci, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    *x = (ci * 64 + k) as f64;
                }
            });
            for (i, x) in data.iter().enumerate() {
                assert_eq!(*x, i as f64);
            }
        });
    }

    #[test]
    fn nested_par_for_runs_serially_inline() {
        with_threads(4, || {
            let total = AtomicUsize::new(0);
            par_for(8, 1, |_| {
                assert!(in_pool());
                // nested call must not spawn (and must still cover all
                // indices)
                let inner = AtomicUsize::new(0);
                par_for(100, 1, |_| {
                    inner.fetch_add(1, Ordering::Relaxed);
                });
                assert_eq!(inner.load(Ordering::Relaxed), 100);
                total.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(total.load(Ordering::Relaxed), 8);
            assert!(!in_pool());
        });
    }

    #[test]
    fn panic_in_worker_propagates() {
        let result = std::panic::catch_unwind(|| {
            with_threads(4, || {
                par_for(64, 1, |i| {
                    if i == 17 {
                        panic!("worker panic");
                    }
                });
            })
        });
        assert!(result.is_err());
        // the pool must be reusable after a panicked call
        assert!(!in_pool());
        let ok = AtomicUsize::new(0);
        par_for(4, 1, |_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn absurd_widths_are_clamped() {
        // the coordinator protocol carries a per-request width, so a
        // hostile or typoed value must not translate into an OS thread
        // spawn storm
        with_threads(1_000_000, || {
            let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            assert!(num_threads() <= 8 * hw, "width {} escaped the clamp", num_threads());
        });
    }

    #[test]
    fn with_threads_restores_previous_width() {
        let outer = num_threads();
        with_threads(3, || {
            assert_eq!(num_threads(), 3);
            with_threads(1, || assert_eq!(num_threads(), 1));
            assert_eq!(num_threads(), 3);
        });
        assert_eq!(num_threads(), outer);
    }

    #[test]
    fn serial_width_runs_in_order_on_calling_thread() {
        with_threads(1, || {
            let caller = std::thread::current().id();
            let seen = std::sync::Mutex::new(Vec::new());
            // grain 1, 1 thread: must visit 0..n in order, no spawns
            par_for(50, 1, |i| {
                assert_eq!(std::thread::current().id(), caller);
                seen.lock().unwrap().push(i);
            });
            assert_eq!(*seen.lock().unwrap(), (0..50).collect::<Vec<_>>());
        });
    }
}

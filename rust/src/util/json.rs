//! Minimal JSON parser + writer (serde is not vendored — DESIGN.md §5).
//!
//! Supports the full JSON grammar minus `\u` surrogate pairs (enough for
//! the artifact manifest and the coordinator wire protocol, both of which
//! we also author).  Numbers parse as `f64`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// `obj["key"]` access that tolerates missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl fmt::Display for Json {
    /// Compact single-line serialization (wire format).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        write!(f, "{}", *x as i64)
                    } else {
                        // RFC 8785-ish: shortest round-trip via Rust's float fmt
                        write!(f, "{x:?}")
                    }
                } else {
                    // JSON has no inf/nan; encode as null (callers guard)
                    write!(f, "null")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Parse a JSON document. Errors carry a byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected end of input")? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf-8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(parse("-1e3").unwrap(), Json::Num(-1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1], Json::Num(2.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
    }

    #[test]
    fn roundtrip_display_parse() {
        let v = Json::obj(vec![
            ("name", Json::str("fig-1")),
            ("xs", Json::arr_f64(&[1.0, 2.5, -3.0])),
            ("ok", Json::Bool(true)),
            ("nested", Json::obj(vec![("k", Json::Num(42.0))])),
        ]);
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_exponent() {
        assert_eq!(Json::Num(8192.0).to_string(), "8192");
        assert_eq!(Json::Num(0.05).to_string(), "0.05");
    }

    #[test]
    fn errors_on_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nope").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn manifest_shape_parses() {
        let text = r#"{"dtype": "f64", "artifacts": [
            {"name": "score_n32", "file": "score_n32.hlo.txt", "entry": "score", "n": 32}
        ]}"#;
        let v = parse(text).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("n").unwrap().as_usize(), Some(32));
        assert_eq!(a.get("entry").unwrap().as_str(), Some("score"));
    }
}

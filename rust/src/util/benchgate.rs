//! Bench-regression gate: compare a freshly-written `BENCH_<name>.json`
//! against a committed baseline and flag median-latency regressions —
//! the check behind `gpml bench-gate` and CI's `bench-gate` job.
//!
//! The comparison is deliberately narrow: for every series the baseline
//! names, match sweep points by `N` and compare `median_us` values; a
//! point regresses when `current > baseline * tolerance`.  Sweep points
//! the current run did not produce are skipped (CI runs reduced sweeps),
//! but a series with **no** comparable point — or missing entirely —
//! fails the gate, so a bench cannot silently shrink out of coverage.
//!
//! Baselines live in `benches/baselines/`; re-baseline by replacing them
//! with the `BENCH_*.json` artifacts of a representative CI run.  The
//! optional top-level `"note"` string in a baseline is echoed by the CLI
//! (used to mark bootstrap envelopes).

use crate::util::json::Json;

/// One compared sweep point.
#[derive(Clone, Debug)]
pub struct GateRow {
    pub series: String,
    pub n: f64,
    pub baseline_us: f64,
    pub current_us: f64,
    /// `current / baseline`; > tolerance means regressed.
    pub ratio: f64,
    pub regressed: bool,
}

/// Full comparison outcome.
#[derive(Clone, Debug)]
pub struct GateReport {
    pub tolerance: f64,
    pub rows: Vec<GateRow>,
    /// Baseline series with no comparable point in the current run.
    pub missing: Vec<String>,
}

impl GateReport {
    /// True when every compared point is within tolerance and no series
    /// went missing.
    pub fn ok(&self) -> bool {
        self.missing.is_empty() && self.rows.iter().all(|r| !r.regressed)
    }

    pub fn regressions(&self) -> Vec<&GateRow> {
        self.rows.iter().filter(|r| r.regressed).collect()
    }

    /// Human-readable comparison table + verdict lines.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<24} {:>8} {:>14} {:>14} {:>8}  verdict\n",
            "series", "N", "baseline us", "current us", "ratio"
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "{:<24} {:>8} {:>14.1} {:>14.1} {:>7.2}x  {}\n",
                r.series,
                r.n,
                r.baseline_us,
                r.current_us,
                r.ratio,
                if r.regressed { "REGRESSED" } else { "ok" }
            ));
        }
        for m in &self.missing {
            out.push_str(&format!("{m:<24} -- no comparable point in the current run: FAIL\n"));
        }
        out
    }
}

fn f64_arr(v: &Json, what: &str) -> Result<Vec<f64>, String> {
    v.as_arr()
        .ok_or_else(|| format!("{what} must be an array"))?
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| format!("{what} entries must be numbers")))
        .collect()
}

/// Per-series `(n, median_us)` pairs of one bench record.  Accepts the
/// `bench_common::bench_json` shape: top-level `ns` plus
/// `series.<label>.median_us` (parallel arrays).
fn series_points(doc: &Json, label: &str, what: &str) -> Result<Option<Vec<(f64, f64)>>, String> {
    let ns = f64_arr(doc.get("ns").ok_or_else(|| format!("{what}: missing ns"))?, "ns")?;
    let Some(series) = doc.get("series").and_then(|s| s.get(label)) else {
        return Ok(None);
    };
    let med = f64_arr(
        series.get("median_us").ok_or_else(|| format!("{what}: series {label} missing median_us"))?,
        "median_us",
    )?;
    if med.len() != ns.len() {
        return Err(format!("{what}: series {label} has {} medians for {} ns", med.len(), ns.len()));
    }
    Ok(Some(ns.into_iter().zip(med).collect()))
}

/// Compare a current bench record against a baseline record.
/// `tolerance` is the allowed `current / baseline` median ratio (1.25 =
/// fail past +25%).
pub fn compare(current: &Json, baseline: &Json, tolerance: f64) -> Result<GateReport, String> {
    if !(tolerance.is_finite() && tolerance > 0.0) {
        return Err(format!("bad tolerance {tolerance}"));
    }
    let labels: Vec<String> = baseline
        .get("series")
        .and_then(Json::as_obj)
        .ok_or("baseline: missing series object")?
        .keys()
        .cloned()
        .collect();
    if labels.is_empty() {
        return Err("baseline names no series".into());
    }
    let mut rows = Vec::new();
    let mut missing = Vec::new();
    for label in labels {
        let base = series_points(baseline, &label, "baseline")?
            .ok_or_else(|| format!("baseline: series {label} vanished mid-parse"))?;
        let cur = match series_points(current, &label, "current")? {
            Some(points) => points,
            None => {
                missing.push(label);
                continue;
            }
        };
        let mut matched = 0usize;
        for &(n, baseline_us) in &base {
            // sweep Ns are small integers serialized exactly: match by value
            let Some(&(_, current_us)) = cur.iter().find(|(cn, _)| *cn == n) else {
                continue; // reduced sweep: point not produced, skip
            };
            matched += 1;
            let ratio = current_us / baseline_us;
            rows.push(GateRow {
                series: label.clone(),
                n,
                baseline_us,
                current_us,
                ratio,
                regressed: ratio > tolerance,
            });
        }
        if matched == 0 {
            missing.push(label);
        }
    }
    Ok(GateReport { tolerance, rows, missing })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(ns: &[f64], series: Vec<(&str, Vec<f64>)>) -> Json {
        Json::obj(vec![
            ("ns", Json::arr_f64(ns)),
            (
                "series",
                Json::Obj(
                    series
                        .into_iter()
                        .map(|(label, med)| {
                            (
                                label.to_string(),
                                Json::obj(vec![("median_us", Json::arr_f64(&med))]),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    #[test]
    fn within_tolerance_passes() {
        let baseline = record(&[64.0, 128.0], vec![("a", vec![100.0, 200.0])]);
        let current = record(&[64.0, 128.0], vec![("a", vec![110.0, 240.0])]);
        let rep = compare(&current, &baseline, 1.25).unwrap();
        assert!(rep.ok(), "{}", rep.summary());
        assert_eq!(rep.rows.len(), 2);
    }

    #[test]
    fn regression_past_tolerance_fails() {
        let baseline = record(&[64.0], vec![("a", vec![100.0])]);
        let current = record(&[64.0], vec![("a", vec![126.0])]);
        let rep = compare(&current, &baseline, 1.25).unwrap();
        assert!(!rep.ok());
        assert_eq!(rep.regressions().len(), 1);
        assert!(rep.summary().contains("REGRESSED"));
    }

    #[test]
    fn faster_is_always_fine() {
        let baseline = record(&[64.0], vec![("a", vec![100.0])]);
        let current = record(&[64.0], vec![("a", vec![10.0])]);
        assert!(compare(&current, &baseline, 1.25).unwrap().ok());
    }

    #[test]
    fn missing_series_fails() {
        let baseline = record(&[64.0], vec![("a", vec![100.0]), ("b", vec![50.0])]);
        let current = record(&[64.0], vec![("a", vec![100.0])]);
        let rep = compare(&current, &baseline, 1.25).unwrap();
        assert!(!rep.ok());
        assert_eq!(rep.missing, vec!["b".to_string()]);
    }

    #[test]
    fn reduced_sweep_skips_but_requires_overlap() {
        // current ran only N=64 of a {64, 512} baseline: 512 skipped
        let baseline = record(&[64.0, 512.0], vec![("a", vec![100.0, 800.0])]);
        let current = record(&[64.0], vec![("a", vec![90.0])]);
        let rep = compare(&current, &baseline, 1.25).unwrap();
        assert!(rep.ok());
        assert_eq!(rep.rows.len(), 1);
        // zero overlap is a failure, not a silent pass
        let disjoint = record(&[32.0], vec![("a", vec![90.0])]);
        let rep = compare(&disjoint, &baseline, 1.25).unwrap();
        assert!(!rep.ok());
        assert_eq!(rep.missing, vec!["a".to_string()]);
    }

    #[test]
    fn malformed_inputs_error() {
        let good = record(&[64.0], vec![("a", vec![100.0])]);
        assert!(compare(&good, &Json::obj(vec![]), 1.25).is_err());
        assert!(compare(&good, &good, 0.0).is_err());
        let bad_len = record(&[64.0, 128.0], vec![("a", vec![100.0])]);
        assert!(compare(&good, &bad_len, 1.25).is_err());
    }
}

//! In-repo substrates for functionality that is normally pulled from
//! crates.io but is unavailable in this offline image (DESIGN.md §5):
//! deterministic RNG, JSON, CLI parsing, bench timing, the
//! bench-regression gate, property testing, and the scoped thread pool
//! (DESIGN.md §6).

pub mod benchgate;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod threadpool;
pub mod timing;

//! In-repo substrates for functionality that is normally pulled from
//! crates.io but is unavailable in this offline image (DESIGN.md §5):
//! deterministic RNG, JSON, CLI parsing, bench timing, property testing.

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod timing;

//! Minimal command-line parsing (clap is not vendored — DESIGN.md §5).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters and a generated usage string.

use std::collections::BTreeMap;

/// Boolean flags (no value follows).  Everything else starting with `--`
/// is a key-value option.  Keeping this list explicit resolves the
/// `--flag positional` ambiguity without clap-style per-command specs.
const KNOWN_FLAGS: &[&str] = &[
    "predict", "verbose", "quiet", "no-pjrt", "help", "evidence", "paper-score", "json", "stats",
    "session",
];

/// Parsed arguments: flags, key-value options, and positionals, in the
/// order conventions of `gpml <subcommand> [options]`.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (tests) .
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest is positional
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if KNOWN_FLAGS.contains(&body) {
                    out.flags.push(body.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(body.to_string(), v);
                } else {
                    // unknown name with no value: treat as a flag
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments (after the binary name).
    pub fn from_env() -> Result<Args, String> {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{name}: bad float '{s}'")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{name}: bad integer '{s}'")),
        }
    }

    /// Byte-size option with an optional binary-unit suffix:
    /// `--cache-bytes 1048576`, `512k`, `256m`, `2g` (also `kb`/`mb`/`gb`;
    /// fractional values like `1.5g` are allowed).
    pub fn get_bytes(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => parse_bytes(s).ok_or_else(|| format!("--{name}: bad size '{s}'")),
        }
    }

    /// Comma-separated usize list, e.g. `--sizes 32,64,128`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|t| t.trim().parse().map_err(|_| format!("--{name}: bad list '{s}'")))
                .collect(),
        }
    }
}

/// Parse a byte size: a plain number of bytes, or a number with a binary
/// `k`/`m`/`g` suffix (optionally followed by `b`), case-insensitive.
pub fn parse_bytes(s: &str) -> Option<usize> {
    let t = s.trim().to_ascii_lowercase();
    let t = t.strip_suffix('b').unwrap_or(&t);
    let (digits, mult) = match t.chars().last()? {
        'k' => (&t[..t.len() - 1], 1usize << 10),
        'm' => (&t[..t.len() - 1], 1 << 20),
        'g' => (&t[..t.len() - 1], 1 << 30),
        _ => (t, 1),
    };
    let v: f64 = digits.trim().parse().ok()?;
    if !v.is_finite() || v < 0.0 {
        return None;
    }
    Some((v * mult as f64) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["tune", "--n", "128", "--kernel=rbf", "--verbose", "data.csv"]);
        assert_eq!(a.positional, vec!["tune", "data.csv"]);
        assert_eq!(a.get("n"), Some("128"));
        assert_eq!(a.get("kernel"), Some("rbf"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--x", "2.5", "--n", "42", "--sizes", "32,64,128"]);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert_eq!(a.get_usize_list("sizes", &[]).unwrap(), vec![32, 64, 128]);
        assert_eq!(a.get_f64("missing", 7.0).unwrap(), 7.0);
    }

    #[test]
    fn bad_values_error() {
        let a = parse(&["--x", "abc"]);
        assert!(a.get_f64("x", 0.0).is_err());
        assert!(a.get_usize("x", 0).is_err());
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["--k", "v", "--", "--not-an-option"]);
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--verbose"]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn byte_sizes() {
        assert_eq!(parse_bytes("1048576"), Some(1 << 20));
        assert_eq!(parse_bytes("512k"), Some(512 << 10));
        assert_eq!(parse_bytes("256M"), Some(256 << 20));
        assert_eq!(parse_bytes("2g"), Some(2 << 30));
        assert_eq!(parse_bytes("2GB"), Some(2 << 30));
        assert_eq!(parse_bytes("1.5g"), Some(3 << 29));
        assert_eq!(parse_bytes("0"), Some(0));
        assert_eq!(parse_bytes("nope"), None);
        assert_eq!(parse_bytes("-1k"), None);
        assert_eq!(parse_bytes(""), None);
        let a = parse(&["--cache-bytes", "64m"]);
        assert_eq!(a.get_bytes("cache-bytes", 0).unwrap(), 64 << 20);
        assert_eq!(a.get_bytes("missing", 7).unwrap(), 7);
        assert!(parse(&["--cache-bytes", "x"]).get_bytes("cache-bytes", 0).is_err());
    }
}

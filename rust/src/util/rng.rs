//! Deterministic pseudo-random generation (the `rand` crate is not
//! vendored in this image — DESIGN.md §5).
//!
//! [`Rng`] is Xoshiro256** seeded through SplitMix64, with uniform /
//! normal / permutation helpers.  Everything in the repo that needs
//! randomness (synthetic data, PSO, property tests) goes through this type
//! so that every run is reproducible from a single `u64` seed.

/// SplitMix64 step — used to expand a single `u64` seed into the 256-bit
/// Xoshiro state (the construction recommended by the xoshiro authors).
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box-Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Deterministic generator from a single seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (caches the paired variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u in (0,1] to keep ln finite
        let u = 1.0 - self.uniform();
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (sin_t, cos_t) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.spare_normal = Some(r * sin_t);
        r * cos_t
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of uniforms in `[lo, hi)`.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices sampled from `0..n` (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fork an independent stream (for per-thread / per-particle use).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs = r.normal_vec(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 1e-2, "mean {mean}");
        assert!((var - 1.0).abs() < 2e-2, "var {var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30, "indices distinct");
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::new(17);
        let mut b = a.fork();
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}

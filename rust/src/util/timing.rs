//! Measurement helpers for the bench harness (criterion is not vendored —
//! DESIGN.md §5): warmup + repetition loops, trimmed statistics, and the
//! least-squares linear fit `tau(N) = a + b N` that the paper reports for
//! Figures 1-3.

use std::time::Instant;

/// Summary statistics over a sample of per-iteration times (microseconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub mean_us: f64,
    pub median_us: f64,
    pub p10_us: f64,
    pub p90_us: f64,
    pub min_us: f64,
    pub iters: usize,
}

impl Stats {
    /// Summarize a raw sample vector (per-repetition times in
    /// microseconds) — the shared percentile computation behind
    /// [`measure`] and the bench binaries that time repetitions
    /// themselves.
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        stats_of(&mut samples)
    }

    /// JSON object for the machine-readable bench trajectory
    /// (`BENCH_<name>.json`; written via the in-repo `util::json`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("mean_us", Json::Num(self.mean_us)),
            ("median_us", Json::Num(self.median_us)),
            ("p10_us", Json::Num(self.p10_us)),
            ("p90_us", Json::Num(self.p90_us)),
            ("min_us", Json::Num(self.min_us)),
            ("iters", Json::Num(self.iters as f64)),
        ])
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured ones.
/// Returns per-iteration stats; each iteration is timed individually so the
/// distribution (not just the mean) is available.
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    stats_of(&mut samples)
}

/// Time `f` in one block of `iters` calls (lower timer overhead; use when a
/// single call is sub-microsecond). Returns mean time per call in us.
pub fn measure_block<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e6 / iters as f64
}

/// Repeat [`measure_block`] `reps` times and return the distribution of
/// the block means: per-iteration percentiles are meaningless when one
/// call is sub-microsecond, but the bench JSON wants a spread.
///
/// Note the `Stats::iters` semantics: it is always the number of timed
/// samples behind the percentiles — individual iterations for
/// [`measure`], block *means* (each averaging `iters` calls) here.
pub fn measure_block_stats<F: FnMut()>(
    warmup: usize,
    iters: usize,
    reps: usize,
    mut f: F,
) -> Stats {
    let mut samples: Vec<f64> = Vec::with_capacity(reps.max(1));
    for r in 0..reps.max(1) {
        samples.push(measure_block(if r == 0 { warmup } else { 0 }, iters, &mut f));
    }
    stats_of(&mut samples)
}

fn stats_of(samples: &mut [f64]) -> Stats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let pct = |p: f64| samples[((n as f64 * p) as usize).min(n - 1)];
    Stats {
        mean_us: samples.iter().sum::<f64>() / n as f64,
        median_us: pct(0.5),
        p10_us: pct(0.1),
        p90_us: pct(0.9),
        min_us: samples[0],
        iters: n,
    }
}

/// Ordinary least squares fit `y = a + b x`. Returns `(a, b, r2)`.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a + b * x);
            e * e
        })
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, b, r2)
}

/// Markdown-ish aligned table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }
    pub fn print(&self) {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:>width$} |", c, width = w[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let sep: Vec<String> = w.iter().map(|n| "-".repeat(*n)).collect();
        println!("{}", line(&sep));
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_fit_exact_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 0.5 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-12);
        assert!((b - 0.5).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_noisy_line() {
        let mut rng = crate::util::rng::Rng::new(1);
        let xs: Vec<f64> = (0..200).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 10.0 + 2.0 * x + rng.normal()).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 10.0).abs() < 0.5, "a={a}");
        assert!((b - 2.0).abs() < 0.01, "b={b}");
        assert!(r2 > 0.999);
    }

    #[test]
    fn measure_returns_positive_times() {
        let mut acc = 0u64;
        let st = measure(2, 10, || {
            acc = acc.wrapping_add(1);
            std::hint::black_box(acc);
        });
        assert_eq!(st.iters, 10);
        assert!(st.mean_us >= 0.0);
        assert!(st.p10_us <= st.p90_us);
        assert!(st.min_us <= st.median_us);
    }

    #[test]
    fn measure_block_scales() {
        let mut acc = 0.0f64;
        let t = measure_block(1, 1000, || {
            acc += 1.0;
            std::hint::black_box(acc);
        });
        assert!(t >= 0.0 && t < 1000.0);
    }

    #[test]
    fn block_stats_distribution_and_json() {
        let mut acc = 0.0f64;
        let st = measure_block_stats(1, 100, 5, || {
            acc += 1.0;
            std::hint::black_box(acc);
        });
        assert_eq!(st.iters, 5);
        assert!(st.p10_us <= st.p90_us);
        assert!(st.min_us <= st.median_us);
        let j = st.to_json();
        assert!(j.get("median_us").and_then(|v| v.as_f64()).is_some());
        assert_eq!(j.get("iters").and_then(|v| v.as_usize()), Some(5));
        // round-trips through the in-repo parser
        assert!(crate::util::json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["N", "mean_us"]);
        t.row(&["32".into(), "1.5".into()]);
        t.row(&["8192".into(), "410.2".into()]);
        t.print();
    }
}

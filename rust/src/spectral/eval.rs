//! Pure-rust O(N) evaluator of the paper's spectral identities
//! (Propositions 2.1-2.3) — the mirror of the Layer-1 pallas kernel.
//!
//! Serves three roles: (i) the scalar fast path used inside Newton
//! refinement where a PJRT dispatch per iterate would dominate; (ii) the
//! correctness cross-check for the AOT artifacts; (iii) the
//! "proposed identities on the authors' own terms" implementation measured
//! by the Figure 1-3 benches.

use crate::linalg::SymEigen;

/// Hyperparameter pair of the optimization problem (eq. 12).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HyperParams {
    pub sigma2: f64,
    pub lambda2: f64,
}

impl HyperParams {
    pub fn new(sigma2: f64, lambda2: f64) -> Self {
        HyperParams { sigma2, lambda2 }
    }
    /// Feasibility constraint (13).
    pub fn feasible(&self) -> bool {
        self.sigma2 > 0.0 && self.lambda2 > 0.0 && self.sigma2.is_finite() && self.lambda2.is_finite()
    }
}

/// Score + Jacobian + Hessian at one hyperparameter point.
#[derive(Clone, Copy, Debug)]
pub struct Evaluation {
    pub score: f64,
    /// [dL/dsigma2, dL/dlambda2]
    pub jac: [f64; 2],
    /// [[d2ss, d2sl], [d2sl, d2ll]]
    pub hess: [[f64; 2]; 2],
}

/// Powers of the hyperparameters shared by the derivative closed forms
/// (computed once per evaluation, not per eigenvalue).
#[derive(Clone, Copy)]
struct HpPowers {
    sigma2: f64,
    lambda2: f64,
    /// 1 / sigma2^2
    inv_s4: f64,
    /// 1 / sigma2^3
    inv_s6: f64,
}

impl HpPowers {
    #[inline]
    fn new(hp: HyperParams) -> Self {
        let HyperParams { sigma2, lambda2 } = hp;
        let inv_s2 = 1.0 / sigma2;
        let inv_s4 = inv_s2 * inv_s2;
        HpPowers { sigma2, lambda2, inv_s4, inv_s6: inv_s4 * inv_s2 }
    }
}

/// Per-eigenvalue first-derivative terms (eqs. 22-25).
#[derive(Clone, Copy)]
struct FirstOrder {
    dlogd_ds: f64,
    dlogd_dl: f64,
    dg_ds: f64,
    dg_dl: f64,
}

/// One shared transcription of eqs. (22)-(25), used verbatim by both
/// [`EigenSystem::grad`] and [`EigenSystem::evaluate`] so the two paths
/// cannot drift apart structurally (the seed carried two hand-expanded
/// variants whose roundings diverged under cancellation).
///
/// The powers of `sigma^2` and `lambda^2 s` are folded into the bounded
/// ratios `u = sigma2/(ab)` and `v = lambda2 s/(ab)` so no intermediate
/// overflows before the result does: the seed's expanded
/// `(sigma^8 - 2 lambda^4 s^2 sigma^4)/sigma^4` form hit `inf` (NaN
/// after the subtraction) from sigma2 ~ 1e77 even though constraint (13)
/// only requires sigma2 > 0.  With the `u`/`v` forms the closed forms
/// stay finite wherever their true values are representable in f64
/// (the hard limits are the genuine `4/sigma^4`, `8/sigma^6` terms).
#[inline(always)]
fn first_order(p: &HpPowers, s: f64, inv_a: f64, inv_b: f64) -> FirstOrder {
    let (ia2, ib2) = (inv_a * inv_a, inv_b * inv_b);
    let iab = inv_a * inv_b;
    let u = p.sigma2 * iab;
    let v = p.lambda2 * s * iab;
    FirstOrder {
        dlogd_ds: inv_b - inv_a,
        dlogd_dl: s * u,
        dg_ds: 2.0 * v * v - u * u - 4.0 * p.inv_s4,
        dg_dl: s * (ia2 - 4.0 * ib2),
    }
}

/// Per-eigenvalue second-derivative terms (eqs. 30-35).
#[derive(Clone, Copy)]
struct SecondOrder {
    d2logd_ss: f64,
    d2logd_sl: f64,
    d2logd_ll: f64,
    d2g_ss: f64,
    d2g_sl: f64,
    d2g_ll: f64,
}

/// Eqs. (30)-(35) in the bounded-ratio form of [`first_order`]:
/// `sigma^12 ia^3 ib^3 == u^3`, `lambda^6 s^3 ia^3 ib^3 == v^3`, so the
/// seed's `sigma^12` intermediate (overflowed from sigma2 ~ 1e51) never
/// materializes.
#[inline(always)]
fn second_order(p: &HpPowers, s: f64, inv_a: f64, inv_b: f64) -> SecondOrder {
    let (ia2, ib2) = (inv_a * inv_a, inv_b * inv_b);
    let (ia3, ib3) = (ia2 * inv_a, ib2 * inv_b);
    let iab = inv_a * inv_b;
    let u = p.sigma2 * iab;
    let v = p.lambda2 * s * iab;
    let s2 = s * s;
    SecondOrder {
        d2logd_ss: ia2 - ib2,
        d2logd_sl: s * (ia2 - 2.0 * ib2),
        d2logd_ll: s2 * (ia2 - 4.0 * ib2),
        d2g_ss: 8.0 * p.inv_s6 + 2.0 * (u * u * u) - 12.0 * v * v * (v + u),
        d2g_sl: s * (8.0 * ib3 - 2.0 * ia3),
        d2g_ll: s2 * (16.0 * ib3 - 2.0 * ia3),
    }
}

/// Rounding-magnitude counterpart of [`first_order`]: every difference
/// replaced by the sum of its constituents' absolute values.  `dg_dl`,
/// for example, is `s (1/a^2 - 4/b^2)` whose two parts agree to
/// O(sigma2 / lambda2 s) near the sigma2 -> 0 boundary — the rounding
/// noise of an evaluation scales with the *uncancelled* parts, which is
/// what [`EigenSystem::evaluate_magnitudes`] must accumulate.
#[inline(always)]
fn first_order_mag(p: &HpPowers, s: f64, inv_a: f64, inv_b: f64) -> FirstOrder {
    // rank-deficient spectra carry numerically-negative eigenvalues; a
    // magnitude must not inherit their sign (nor the sign of inv_a /
    // inv_b, which can flip when lambda2 |s| exceeds sigma2)
    let s = s.abs();
    let (inv_a, inv_b) = (inv_a.abs(), inv_b.abs());
    let (ia2, ib2) = (inv_a * inv_a, inv_b * inv_b);
    let iab = inv_a * inv_b;
    let u = p.sigma2 * iab;
    let v = p.lambda2 * s * iab;
    FirstOrder {
        dlogd_ds: inv_b + inv_a,
        dlogd_dl: s * u,
        dg_ds: 2.0 * v * v + u * u + 4.0 * p.inv_s4,
        dg_dl: s * (ia2 + 4.0 * ib2),
    }
}

/// Rounding-magnitude counterpart of [`second_order`] (see
/// [`first_order_mag`]).
#[inline(always)]
fn second_order_mag(p: &HpPowers, s: f64, inv_a: f64, inv_b: f64) -> SecondOrder {
    // see first_order_mag
    let s = s.abs();
    let (inv_a, inv_b) = (inv_a.abs(), inv_b.abs());
    let (ia2, ib2) = (inv_a * inv_a, inv_b * inv_b);
    let (ia3, ib3) = (ia2 * inv_a, ib2 * inv_b);
    let iab = inv_a * inv_b;
    let u = p.sigma2 * iab;
    let v = p.lambda2 * s * iab;
    let s2 = s * s;
    SecondOrder {
        d2logd_ss: ia2 + ib2,
        d2logd_sl: s * (ia2 + 2.0 * ib2),
        d2logd_ll: s2 * (ia2 + 4.0 * ib2),
        d2g_ss: 8.0 * p.inv_s6 + 2.0 * (u * u * u) + 12.0 * v * v * (v + u),
        d2g_sl: s * (8.0 * ib3 + 2.0 * ia3),
        d2g_ll: s2 * (16.0 * ib3 + 2.0 * ia3),
    }
}

/// The O(N) state the paper's identities need: eigenvalues, squared
/// projected targets, true N, and y'y.  This is the *entire* per-dataset
/// memory footprint after the O(N^3) overhead (paper §2.1: O(N) storage).
#[derive(Clone, Debug)]
pub struct EigenSystem {
    /// Eigenvalues of K (ascending, possibly with near-zero entries for
    /// rank-deficient kernels — the identities stay valid, paper §2).
    pub s: Vec<f64>,
    /// (U'y)_i^2.
    pub y2t: Vec<f64>,
    /// True number of examples.
    pub n: usize,
    /// y'y (= y~'y~ by orthogonality).
    pub yy: f64,
}

impl EigenSystem {
    /// Assemble from a decomposed Gram matrix and targets.
    pub fn new(eigen: &SymEigen, y: &[f64]) -> Self {
        let yt = eigen.project(y);
        EigenSystem {
            s: eigen.values.clone(),
            y2t: yt.iter().map(|v| v * v).collect(),
            n: y.len(),
            yy: y.iter().map(|v| v * v).sum(),
        }
    }

    /// Build directly from raw parts (used by the runtime padding path and
    /// by tests).
    pub fn from_parts(s: Vec<f64>, y2t: Vec<f64>, n: usize, yy: f64) -> Self {
        assert_eq!(s.len(), y2t.len());
        EigenSystem { s, y2t, n, yy }
    }

    /// Proposition 2.1 — eq. (19). O(N).
    ///
    /// Perf (EXPERIMENTS.md §Perf): the naive loop spends most of its
    /// cycles in one `ln` per eigenvalue.  Since `d_i = b/a in (1, 2]`,
    /// `sum ln d_i` is accumulated as `ln` of running products of up to
    /// 512 terms (2^512 < f64::MAX, no overflow), cutting `ln` calls by
    /// ~500x; `g_i` is rewritten as `(b^2 + 4a^2) / (sigma2 * a * b)` so
    /// each element costs a single division.
    pub fn score(&self, hp: HyperParams) -> f64 {
        let HyperParams { sigma2, lambda2 } = hp;
        let inv_sigma2 = 1.0 / sigma2;
        let mut acc = 0.0;
        let mut log_acc = 0.0;
        let mut prod_d = 1.0f64; // prod d_i over the open chunk, d in (1, 2]
        for (chunk_s, chunk_y2) in self.s.chunks(512).zip(self.y2t.chunks(512)) {
            for (&s, &y2) in chunk_s.iter().zip(chunk_y2) {
                let ls = lambda2 * s;
                let a = ls + sigma2;
                let b = ls + ls + sigma2;
                let t = 1.0 / (a * b); // one division per element
                let b2 = b * b;
                prod_d *= b2 * t; // d = b/a = b^2/(ab)
                // g = (d^2 + 4)/(sigma2 d)  ==  (b^2 + 4a^2)/(sigma2 a b)
                acc += y2 * ((b2 + 4.0 * a * a) * t);
            }
            log_acc += prod_d.ln();
            prod_d = 1.0;
        }
        self.n as f64 * sigma2.ln() + log_acc + acc * inv_sigma2
            - 4.0 * self.yy * inv_sigma2
    }

    /// Proposition 2.2 — eqs. (20)-(25). O(N).
    ///
    /// Per-element closed forms come from the [`first_order`] helper that
    /// [`evaluate`](Self::evaluate) also uses, and the accumulation order
    /// matches its fused loop, so the two Jacobian paths agree to machine
    /// precision (property-tested, including across chunk boundaries).
    pub fn grad(&self, hp: HyperParams) -> [f64; 2] {
        let p = HpPowers::new(hp);
        // same `n * (1/sigma2)` form as `evaluate` (an `n / sigma2`
        // division here would differ in the last ulp and break the
        // machine-precision agreement between the two paths)
        let inv_s2 = 1.0 / p.sigma2;
        let (mut gs, mut gl) = (0.0, 0.0);
        for (&s, &y2) in self.s.iter().zip(&self.y2t) {
            let ls = p.lambda2 * s;
            let a = p.sigma2 + ls;
            let b = p.sigma2 + ls + ls;
            let inv_a = 1.0 / a;
            let inv_b = 1.0 / b;
            let fo = first_order(&p, s, inv_a, inv_b);
            gs += fo.dlogd_ds + y2 * fo.dg_ds;
            gl += fo.dlogd_dl + y2 * fo.dg_dl;
        }
        [self.n as f64 * inv_s2 + 4.0 * self.yy * p.inv_s4 + gs, gl]
    }

    /// Propositions 2.1-2.3 in one pass. O(N).
    ///
    /// Perf (EXPERIMENTS.md §Perf): the textbook transcription costs ~15
    /// divisions + one `ln` per eigenvalue; here each element pays two
    /// reciprocals (`1/a`, `1/b`) with every closed form rewritten in
    /// non-negative powers of them, and `sum ln d_i` uses the same
    /// chunked-product trick as [`score`].
    pub fn evaluate(&self, hp: HyperParams) -> Evaluation {
        let p = HpPowers::new(hp);
        let inv_s2 = 1.0 / p.sigma2;
        let nf = self.n as f64;
        let (mut c0, mut c1, mut c2, mut c3, mut c4, mut c5) = (0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
        let mut log_acc = 0.0;
        let mut prod_d = 1.0f64;
        for (chunk_s, chunk_y2) in self.s.chunks(512).zip(self.y2t.chunks(512)) {
            for (&s, &y2) in chunk_s.iter().zip(chunk_y2) {
                let ls = p.lambda2 * s;
                let a = p.sigma2 + ls;
                let b = p.sigma2 + ls + ls;
                // two independent divisions pipeline better than the
                // 1/(ab) trick (measured; EXPERIMENTS.md §Perf)
                let inv_a = 1.0 / a;
                let inv_b = 1.0 / b;

                // score terms: d = b/a in (1,2]; g = (b^2+4a^2)/(sigma2 a b)
                prod_d *= b * inv_a;
                c0 += y2 * ((b * b + 4.0 * a * a) * inv_a * inv_b);

                // first derivatives (eqs. 22-25): the same helper `grad`
                // uses, so the fused and standalone Jacobians cannot
                // diverge structurally.
                let fo = first_order(&p, s, inv_a, inv_b);
                c1 += fo.dlogd_ds + y2 * fo.dg_ds;
                c2 += fo.dlogd_dl + y2 * fo.dg_dl;

                // second derivatives (eqs. 30-35)
                let so = second_order(&p, s, inv_a, inv_b);
                c3 += so.d2logd_ss + y2 * so.d2g_ss;
                c4 += so.d2logd_sl + y2 * so.d2g_sl;
                c5 += so.d2logd_ll + y2 * so.d2g_ll;
            }
            log_acc += prod_d.ln();
            prod_d = 1.0;
        }
        let score = nf * p.sigma2.ln() + log_acc + c0 * inv_s2 - 4.0 * self.yy * inv_s2;
        let j_s = nf * inv_s2 + 4.0 * self.yy * p.inv_s4 + c1;
        let j_l = c2;
        let h_ss = -nf * p.inv_s4 - 8.0 * self.yy * p.inv_s6 + c3;
        Evaluation { score, jac: [j_s, j_l], hess: [[h_ss, c4], [c4, c5]] }
    }

    /// The cancellation noise floor of [`evaluate`](Self::evaluate): the
    /// same sums with every summand — including the `4 y'y / sigma^2`
    /// family of closure constants — replaced by the sum of its
    /// *constituent* magnitudes (differences like `1/a^2 - 4/b^2` count
    /// as `1/a^2 + 4/b^2`; see [`first_order_mag`]).
    ///
    /// The output is *not* a derivative.  It is the magnitude each
    /// quantity's rounding error scales with: the score and dL/dsigma2
    /// subtract O(y'y/sigma^2) terms that cancel almost exactly near the
    /// sigma2 -> 0 boundary, so a relative comparison of two evaluators
    /// must be anchored to these magnitudes rather than to the (much
    /// smaller) final values.  Used by [`crate::verify`].
    pub fn evaluate_magnitudes(&self, hp: HyperParams) -> Evaluation {
        let p = HpPowers::new(hp);
        let inv_s2 = 1.0 / p.sigma2;
        let nf = self.n as f64;
        let (mut c0, mut c1, mut c2, mut c3, mut c4, mut c5) = (0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
        let mut log_acc = 0.0;
        for (&s, &y2) in self.s.iter().zip(&self.y2t) {
            let ls = p.lambda2 * s;
            let a = p.sigma2 + ls;
            let b = p.sigma2 + ls + ls;
            let inv_a = 1.0 / a;
            let inv_b = 1.0 / b;
            log_acc += (b * inv_a).ln().abs();
            c0 += y2 * ((b * b + 4.0 * a * a) * inv_a * inv_b);
            let fo = first_order_mag(&p, s, inv_a, inv_b);
            c1 += fo.dlogd_ds + y2 * fo.dg_ds;
            c2 += fo.dlogd_dl + y2 * fo.dg_dl;
            let so = second_order_mag(&p, s, inv_a, inv_b);
            c3 += so.d2logd_ss + y2 * so.d2g_ss;
            c4 += so.d2logd_sl + y2 * so.d2g_sl;
            c5 += so.d2logd_ll + y2 * so.d2g_ll;
        }
        Evaluation {
            score: nf * p.sigma2.ln().abs() + log_acc + c0 * inv_s2 + 4.0 * self.yy * inv_s2,
            jac: [nf * inv_s2 + 4.0 * self.yy * p.inv_s4 + c1, c2],
            hess: [
                [nf * p.inv_s4 + 8.0 * self.yy * p.inv_s6 + c3, c4],
                [c4, c5],
            ],
        }
    }

    /// Merge the six raw kernel sums (the PJRT `fused` artifact output is
    /// exactly `[score, j_s, j_l, h_ss, h_sl, h_ll]` with closures already
    /// applied) into an [`Evaluation`].
    pub fn evaluation_from_fused(out: &[f64]) -> Evaluation {
        assert!(out.len() >= 6);
        Evaluation {
            score: out[0],
            jac: [out[1], out[2]],
            hess: [[out[3], out[4]], [out[4], out[5]]],
        }
    }

    // ------------------------------------------------------------------
    // Evidence objective (extension; see DESIGN.md §"Score pathology").
    //
    // The paper's L_y (eq. 19) is the posterior predictive at the
    // training points and is *unbounded below* as sigma2 -> 0: the
    // 4 y'Sigma_y^{-1} y and -4 y'y/sigma2 terms cancel exactly (because
    // y~'y~ = y'y) leaving N log sigma2 -> -inf.  The classical GP
    // evidence -2 log N(y; 0, lambda2 K + sigma2 I) has an interior
    // optimum and enjoys exactly the same O(N) spectral treatment:
    //   L_e = sum_i [ log(lambda2 s_i + sigma2) + y~_i^2/(lambda2 s_i + sigma2) ]
    // (up to the N log 2pi constant).
    // ------------------------------------------------------------------

    /// Evidence score `-2 log p(y | 0, lambda2 K + sigma2 I)` up to a
    /// constant.  O(N).
    pub fn evidence(&self, hp: HyperParams) -> f64 {
        let HyperParams { sigma2, lambda2 } = hp;
        let mut acc = 0.0;
        for (&s, &y2) in self.s.iter().zip(&self.y2t) {
            let a = lambda2 * s + sigma2;
            acc += a.ln() + y2 / a;
        }
        acc
    }

    /// Evidence score + Jacobian + Hessian in one O(N) pass.
    pub fn evidence_evaluate(&self, hp: HyperParams) -> Evaluation {
        let HyperParams { sigma2, lambda2 } = hp;
        let (mut e, mut gs, mut gl, mut hss, mut hsl, mut hll) = (0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
        for (&s, &y2) in self.s.iter().zip(&self.y2t) {
            let a = lambda2 * s + sigma2;
            let a2 = a * a;
            let a3 = a2 * a;
            e += a.ln() + y2 / a;
            gs += 1.0 / a - y2 / a2;
            gl += s / a - s * y2 / a2;
            hss += -1.0 / a2 + 2.0 * y2 / a3;
            hsl += -s / a2 + 2.0 * s * y2 / a3;
            hll += -s * s / a2 + 2.0 * s * s * y2 / a3;
        }
        Evaluation { score: e, jac: [gs, gl], hess: [[hss, hsl], [hsl, hll]] }
    }

    /// Proposition 2.4 eigencoefficients: `q_i = sigma2 lam2 / ((lam2 s_i +
    /// sigma2) s_i)`; zero-guarded for rank-deficient spectra.
    pub fn posterior_var_coeffs(&self, hp: HyperParams) -> Vec<f64> {
        self.s
            .iter()
            .map(|&s| {
                if s > 1e-300 {
                    hp.sigma2 * hp.lambda2 / ((hp.lambda2 * s + hp.sigma2) * s)
                } else {
                    0.0
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check_close, forall};

    /// Finite-difference oracle over the closed-form score.
    fn fd_grad(es: &EigenSystem, hp: HyperParams) -> [f64; 2] {
        let h = 1e-6;
        let f = |s: f64, l: f64| es.score(HyperParams::new(s, l));
        [
            (f(hp.sigma2 + h, hp.lambda2) - f(hp.sigma2 - h, hp.lambda2)) / (2.0 * h),
            (f(hp.sigma2, hp.lambda2 + h) - f(hp.sigma2, hp.lambda2 - h)) / (2.0 * h),
        ]
    }

    fn fd_hess(es: &EigenSystem, hp: HyperParams) -> [[f64; 2]; 2] {
        let h = 1e-5;
        let g = |s: f64, l: f64| es.grad(HyperParams::new(s, l));
        let gs_p = g(hp.sigma2 + h, hp.lambda2);
        let gs_m = g(hp.sigma2 - h, hp.lambda2);
        let gl_p = g(hp.sigma2, hp.lambda2 + h);
        let gl_m = g(hp.sigma2, hp.lambda2 - h);
        [
            [(gs_p[0] - gs_m[0]) / (2.0 * h), (gl_p[0] - gl_m[0]) / (2.0 * h)],
            [(gs_p[1] - gs_m[1]) / (2.0 * h), (gl_p[1] - gl_m[1]) / (2.0 * h)],
        ]
    }

    fn sample_system(rng: &mut crate::util::rng::Rng, n: usize) -> EigenSystem {
        let s: Vec<f64> = (0..n).map(|_| rng.uniform_in(1e-3, 10.0)).collect();
        let yt: Vec<f64> = rng.normal_vec(n);
        let yy = yt.iter().map(|v| v * v).sum();
        EigenSystem::from_parts(s, yt.iter().map(|v| v * v).collect(), n, yy)
    }

    #[test]
    fn grad_matches_finite_differences() {
        forall(
            "spectral grad == fd",
            31,
            20,
            |r| {
                let n = 16 + r.below(64);
                let es = sample_system(r, n);
                let hp = HyperParams::new(r.uniform_in(0.3, 3.0), r.uniform_in(0.3, 3.0));
                (es, hp)
            },
            |(es, hp)| {
                let got = es.grad(*hp);
                let want = fd_grad(es, *hp);
                check_close("dL/dsigma2", got[0], want[0], 1e-4, 1e-6)?;
                check_close("dL/dlambda2", got[1], want[1], 1e-4, 1e-6)
            },
        );
    }

    #[test]
    fn hess_matches_finite_differences() {
        forall(
            "spectral hess == fd",
            37,
            15,
            |r| {
                let n = 16 + r.below(32);
                let es = sample_system(r, n);
                let hp = HyperParams::new(r.uniform_in(0.5, 2.0), r.uniform_in(0.5, 2.0));
                (es, hp)
            },
            |(es, hp)| {
                let ev = es.evaluate(*hp);
                let want = fd_hess(es, *hp);
                check_close("h_ss", ev.hess[0][0], want[0][0], 1e-3, 1e-4)?;
                check_close("h_sl", ev.hess[0][1], want[0][1], 1e-3, 1e-4)?;
                check_close("h_ll", ev.hess[1][1], want[1][1], 1e-3, 1e-4)?;
                // symmetry of mixed partials (also checks eq. 27 against fd
                // computed the other way)
                check_close("h_sl symm", want[0][1], want[1][0], 1e-3, 1e-4)
            },
        );
    }

    #[test]
    fn evaluate_consistent_with_score_and_grad() {
        let mut rng = crate::util::rng::Rng::new(5);
        let es = sample_system(&mut rng, 50);
        let hp = HyperParams::new(0.8, 1.4);
        let ev = es.evaluate(hp);
        assert!((ev.score - es.score(hp)).abs() < 1e-10);
        let g = es.grad(hp);
        assert!((ev.jac[0] - g[0]).abs() < 1e-10);
        assert!((ev.jac[1] - g[1]).abs() < 1e-10);
        assert_eq!(ev.hess[0][1], ev.hess[1][0]);
    }

    #[test]
    fn zero_padding_neutrality() {
        let mut rng = crate::util::rng::Rng::new(6);
        let es = sample_system(&mut rng, 40);
        let mut padded = es.clone();
        padded.s.extend(vec![0.0; 24]);
        padded.y2t.extend(vec![0.0; 24]);
        let hp = HyperParams::new(0.9, 2.0);
        let a = es.evaluate(hp);
        let b = padded.evaluate(hp);
        assert!((a.score - b.score).abs() < 1e-12);
        assert!((a.jac[0] - b.jac[0]).abs() < 1e-12);
        assert!((a.jac[1] - b.jac[1]).abs() < 1e-12);
        assert!((a.hess[0][0] - b.hess[0][0]).abs() < 1e-12);
        assert!((a.hess[1][1] - b.hess[1][1]).abs() < 1e-12);
    }

    /// Literal, unoptimized transcription of eq. (19) — the regression
    /// oracle for the chunked-ln / reciprocal-rewrite optimizations.
    fn score_textbook(es: &EigenSystem, hp: HyperParams) -> f64 {
        let HyperParams { sigma2, lambda2 } = hp;
        let mut acc = 0.0;
        for (&s, &y2) in es.s.iter().zip(&es.y2t) {
            let a = lambda2 * s + sigma2;
            let b = 2.0 * lambda2 * s + sigma2;
            let d = b / a;
            let g = (d * d + 4.0) / (sigma2 * d);
            acc += d.ln() + y2 * g;
        }
        es.n as f64 * sigma2.ln() + acc - 4.0 * es.yy / sigma2
    }

    #[test]
    fn optimized_score_matches_textbook_transcription() {
        forall(
            "optimized score == textbook",
            61,
            20,
            |r| {
                // sizes straddling the 512-element ln-chunk boundary
                let n = [5, 511, 512, 513, 1500][r.below(5)];
                let es = sample_system(r, n);
                let hp = HyperParams::new(
                    10f64.powf(r.uniform_in(-3.0, 3.0)),
                    10f64.powf(r.uniform_in(-3.0, 3.0)),
                );
                (es, hp)
            },
            |(es, hp)| {
                check_close("score", es.score(*hp), score_textbook(es, *hp), 1e-11, 1e-11)?;
                let ev = es.evaluate(*hp);
                check_close("fused score", ev.score, score_textbook(es, *hp), 1e-11, 1e-11)
            },
        );
    }

    #[test]
    fn evidence_matches_finite_differences() {
        forall(
            "evidence grad/hess == fd",
            53,
            15,
            |r| {
                let n = 16 + r.below(48);
                let es = sample_system(r, n);
                let hp = HyperParams::new(r.uniform_in(0.3, 3.0), r.uniform_in(0.3, 3.0));
                (es, hp)
            },
            |(es, hp)| {
                let ev = es.evidence_evaluate(*hp);
                check_close("evidence score", ev.score, es.evidence(*hp), 1e-12, 1e-12)?;
                let h = 1e-6;
                let f = |s: f64, l: f64| es.evidence(HyperParams::new(s, l));
                let fd_s = (f(hp.sigma2 + h, hp.lambda2) - f(hp.sigma2 - h, hp.lambda2)) / (2.0 * h);
                let fd_l = (f(hp.sigma2, hp.lambda2 + h) - f(hp.sigma2, hp.lambda2 - h)) / (2.0 * h);
                check_close("d/dsigma2", ev.jac[0], fd_s, 1e-4, 1e-6)?;
                check_close("d/dlambda2", ev.jac[1], fd_l, 1e-4, 1e-6)?;
                // hessian from central differences of the closed-form
                // gradient (second differences of f are cancellation-noisy)
                let h2 = 1e-5;
                let gp = es.evidence_evaluate(HyperParams::new(hp.sigma2 + h2, hp.lambda2));
                let gm = es.evidence_evaluate(HyperParams::new(hp.sigma2 - h2, hp.lambda2));
                let fd_ss = (gp.jac[0] - gm.jac[0]) / (2.0 * h2);
                let fd_sl = (gp.jac[1] - gm.jac[1]) / (2.0 * h2);
                check_close("d2/dsigma2^2", ev.hess[0][0], fd_ss, 1e-4, 1e-6)?;
                check_close("d2/dsigma2 dlambda2", ev.hess[0][1], fd_sl, 1e-4, 1e-6)
            },
        );
    }

    #[test]
    fn evidence_has_interior_minimum_where_paper_score_runs_to_boundary() {
        // The documented pathology (DESIGN.md): on a spectrum bounded away
        // from zero, L_y(eq.19) decreases without bound as sigma2 -> 0
        // (the 5 y2/sigma2 "null-mode" penalty never activates and
        // N log sigma2 dominates); the evidence turns back up whenever
        // near-zero eigenvalues carry target mass, which real Gram
        // spectra always have.
        let mut rng = crate::util::rng::Rng::new(77);
        let lam = 1.0;

        // (a) uniform spectrum (all s >= 1e-3): paper score is unbounded below
        let es_flat = sample_system(&mut rng, 60);
        let tiny = es_flat.score(HyperParams::new(1e-10, lam));
        let small = es_flat.score(HyperParams::new(1e-4, lam));
        let mid = es_flat.score(HyperParams::new(1.0, lam));
        assert!(
            tiny < small && small < mid,
            "paper score must decrease toward sigma2->0 on a flat spectrum: {tiny} {small} {mid}"
        );

        // (b) decaying (kernel-like) spectrum: evidence blows up at sigma2->0
        let n = 60;
        let s: Vec<f64> = (0..n).map(|i| 10.0 * 0.7f64.powi(i as i32)).collect();
        let yt: Vec<f64> = rng.normal_vec(n);
        let yy: f64 = yt.iter().map(|v| v * v).sum();
        let es_decay = EigenSystem::from_parts(s, yt.iter().map(|v| v * v).collect(), n, yy);
        let e_tiny = es_decay.evidence(HyperParams::new(1e-10, lam));
        let e_mid = es_decay.evidence(HyperParams::new(1.0, lam));
        assert!(e_tiny > e_mid, "evidence must blow up at sigma2->0: {e_tiny} vs {e_mid}");
    }

    #[test]
    fn evidence_zero_padding_neutral() {
        // evidence padding is NOT neutral without the closure correction;
        // the rust evaluator never pads, but assert the raw behaviour so
        // the artifact-side closure (which subtracts (Npad-n) log sigma2)
        // is kept honest.
        let mut rng = crate::util::rng::Rng::new(8);
        let es = sample_system(&mut rng, 30);
        let mut padded = es.clone();
        padded.s.extend(vec![0.0; 10]);
        padded.y2t.extend(vec![0.0; 10]);
        let hp = HyperParams::new(0.7, 1.3);
        let raw = padded.evidence(hp);
        let corrected = raw - 10.0 * hp.sigma2.ln();
        assert!((corrected - es.evidence(hp)).abs() < 1e-10);
    }

    /// ulp distance between two finite f64s (0 == bitwise identical).
    fn ulp_distance(a: f64, b: f64) -> u64 {
        let to_ordered = |x: f64| {
            let bits = x.to_bits() as i64;
            if bits < 0 {
                i64::MIN.wrapping_sub(bits)
            } else {
                bits
            }
        };
        to_ordered(a).abs_diff(to_ordered(b))
    }

    #[test]
    fn grad_and_evaluate_jacobians_agree_to_machine_precision() {
        // `grad` and `evaluate` share the `first_order` helper and the
        // same accumulation order, so their Jacobians must agree to a few
        // ulps even under cancellation-heavy hyperparameters and across
        // the 512-element chunk boundary.  (The seed carried two
        // hand-expanded variants that drifted ~1e-10 relative apart.)
        forall(
            "evaluate jac == grad (ulps)",
            71,
            20,
            |r| {
                let n = [5, 511, 512, 513, 1500][r.below(5)];
                let es = sample_system(r, n);
                let hp = HyperParams::new(
                    10f64.powf(r.uniform_in(-3.0, 3.0)),
                    10f64.powf(r.uniform_in(-3.0, 3.0)),
                );
                (es, hp)
            },
            |(es, hp)| {
                let ev = es.evaluate(*hp);
                let g = es.grad(*hp);
                for i in 0..2 {
                    let d = ulp_distance(ev.jac[i], g[i]);
                    if d > 4 {
                        return Err(format!(
                            "jac[{i}]: {:.17e} vs {:.17e} ({d} ulps apart)",
                            ev.jac[i], g[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn derivatives_finite_for_extreme_but_feasible_hyperparams() {
        // Regression for the seed's sigma^8 (grad) and sigma^12 (Hessian)
        // intermediates, which overflowed to inf — NaN after the
        // subtraction — for sigma2 >~ 1e77 / 1e51 respectively, even
        // though constraint (13) allows any sigma2 > 0.  The bounded
        // u/v rewrites stay finite wherever the true values (and the
        // score's b^2 chunk trick, good to sigma2/lambda2*s ~ 1e154)
        // are representable in f64.
        let mut rng = crate::util::rng::Rng::new(90);
        let es = sample_system(&mut rng, 32);
        for &s2 in &[1e-100, 1e-30, 1e-6, 1.0, 1e40, 1e80, 1e100, 1e150] {
            for &l2 in &[1e-30, 1.0, 1e30] {
                let hp = HyperParams::new(s2, l2);
                assert!(hp.feasible());
                let g = es.grad(hp);
                assert!(
                    g[0].is_finite() && g[1].is_finite(),
                    "grad not finite at sigma2={s2:e} lambda2={l2:e}: {g:?}"
                );
                let ev = es.evaluate(hp);
                assert!(ev.score.is_finite(), "score at sigma2={s2:e} lambda2={l2:e}");
                for i in 0..2 {
                    assert!(
                        ev.jac[i].is_finite(),
                        "jac[{i}] at sigma2={s2:e} lambda2={l2:e}: {:?}",
                        ev.jac
                    );
                    for j in 0..2 {
                        assert!(
                            ev.hess[i][j].is_finite(),
                            "hess[{i}][{j}] at sigma2={s2:e} lambda2={l2:e}: {:?}",
                            ev.hess
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn feasibility() {
        assert!(HyperParams::new(0.1, 0.1).feasible());
        assert!(!HyperParams::new(-0.1, 1.0).feasible());
        assert!(!HyperParams::new(1.0, 0.0).feasible());
        assert!(!HyperParams::new(f64::NAN, 1.0).feasible());
    }

    #[test]
    fn posterior_var_coeffs_guarded() {
        let es = EigenSystem::from_parts(vec![0.0, 1.0], vec![0.0, 1.0], 2, 1.0);
        let q = es.posterior_var_coeffs(HyperParams::new(0.5, 2.0));
        assert_eq!(q[0], 0.0);
        assert!((q[1] - 0.5 * 2.0 / ((2.0 + 0.5) * 1.0)).abs() < 1e-14);
    }
}

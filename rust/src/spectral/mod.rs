//! The paper's system: spectral (eigendecomposition-based) Gaussian
//! process regression with O(N)-per-iterate hyperparameter tuning.
//!
//! [`SpectralGp`] pays the O(N^3) eigendecomposition once per (dataset,
//! kernel) pair; everything downstream — score/Jacobian/Hessian
//! evaluations ([`EigenSystem`]), posterior moments, Prop. 2.4 variance —
//! is O(N) or O(N^2).  Multi-output datasets share the decomposition
//! (paper §2.1: "the eigendecomposition need only be computed once").
//!
//! A `SpectralGp` is a cheap-to-clone *handle*: the O(N^2) setup (inputs
//! + eigendecomposition) lives behind an [`std::sync::Arc`], so the
//! coordinator's session cache and its worker pool can hand the same
//! fitted state to many concurrent requests without copying it
//! (DESIGN.md §7).
//!
//! # Examples
//!
//! ```
//! use gpml::kernelfn::Kernel;
//! use gpml::spectral::{HyperParams, SpectralGp};
//!
//! let ds = gpml::data::synthetic(
//!     gpml::data::SyntheticSpec { n: 24, p: 2, seed: 7, ..Default::default() }, 1);
//! let gp = SpectralGp::fit(Kernel::Rbf { xi2: 2.0 }, ds.x.clone()).unwrap();
//!
//! // O(N) tuning state; clones of `gp` share the same decomposition.
//! let es = gp.eigensystem(ds.y());
//! assert!(es.score(HyperParams::new(0.1, 1.0)).is_finite());
//!
//! let mu = gp.posterior_mean_train(ds.y(), HyperParams::new(0.1, 1.0));
//! assert_eq!(mu.len(), gp.n());
//! ```

pub mod eval;

pub use eval::{EigenSystem, Evaluation, HyperParams};

use std::sync::Arc;

use crate::kernelfn::{self, Kernel};
use crate::linalg::{rankone, strassen, Matrix, SymEigen};

/// The shared one-time setup: training inputs + eigendecomposition.
struct Setup {
    x: Matrix,
    eigen: SymEigen,
    /// Rank-one corrections applied since the last full fit (two per
    /// appended observation) — the streaming-update budget counter
    /// (DESIGN.md §8).
    updates: usize,
}

/// Fallback policy for the streaming [`SpectralGp::extend`] path: when
/// either limit is crossed the append is served by a full O(N^3) refit
/// instead of rank-one corrections (DESIGN.md §8).
#[derive(Clone, Copy, Debug)]
pub struct ExtendPolicy {
    /// Maximum rank-one corrections since the last full fit (each
    /// appended observation costs two).  Bounds the accumulated
    /// O(eps * ||K||) per-update error.
    pub max_updates: usize,
    /// Maximum tolerated [`rankone::ortho_drift`] of the updated
    /// eigenbasis — the conditioning estimate.
    pub ortho_tol: f64,
}

impl Default for ExtendPolicy {
    fn default() -> Self {
        ExtendPolicy { max_updates: 64, ortho_tol: 1e-8 }
    }
}

/// How an [`SpectralGp::extend`] call was served.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExtendOutcome {
    /// Rank-one corrections only — no O(N^3) work.
    Incremental,
    /// The policy forced a full refit.
    Refit(RefitReason),
}

/// Why an extend fell back to a full refit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefitReason {
    /// `updates + 2m` would exceed [`ExtendPolicy::max_updates`].
    UpdateBudget,
    /// The updated eigenbasis drifted past [`ExtendPolicy::ortho_tol`].
    Conditioning,
    /// The incremental path's eigensolve failed and the degradation
    /// ladder ([`crate::faults::hardened_eigen`]) refitted from scratch.
    EigenFailure,
}

impl RefitReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            RefitReason::UpdateBudget => "update-budget",
            RefitReason::Conditioning => "conditioning",
            RefitReason::EigenFailure => "eigen-failure",
        }
    }
}

/// A fitted spectral GP: kernel + training inputs + eigendecomposition.
///
/// Cloning is O(1) (an `Arc` bump): every clone reads the same
/// setup, which is what lets the coordinator serve many concurrent
/// requests against one cached decomposition.
#[derive(Clone)]
pub struct SpectralGp {
    kernel: Kernel,
    setup: Arc<Setup>,
}

impl SpectralGp {
    /// Build the Gram matrix and eigendecompose it — the one-time O(N^3)
    /// overhead (eq. 17).
    pub fn fit(kernel: Kernel, x: Matrix) -> Result<Self, crate::linalg::eigen::NoConvergence> {
        let k = kernelfn::gram(kernel, &x);
        let eigen = SymEigen::new(&k)?;
        Ok(SpectralGp::from_eigen(kernel, x, eigen))
    }

    /// Build from a precomputed Gram matrix (e.g. the PJRT gram artifact).
    pub fn fit_from_gram(
        kernel: Kernel,
        x: Matrix,
        k: &Matrix,
    ) -> Result<Self, crate::linalg::eigen::NoConvergence> {
        let eigen = SymEigen::new(k)?;
        Ok(SpectralGp::from_eigen(kernel, x, eigen))
    }

    /// Wrap an already-computed eigendecomposition (used by the session
    /// cache, which times the gram and eigen phases separately).
    pub fn from_eigen(kernel: Kernel, x: Matrix, eigen: SymEigen) -> Self {
        SpectralGp { kernel, setup: Arc::new(Setup { x, eigen, updates: 0 }) }
    }

    /// Append observations with the default [`ExtendPolicy`].
    pub fn extend(
        &self,
        x_new: &Matrix,
    ) -> Result<(SpectralGp, ExtendOutcome), crate::linalg::eigen::NoConvergence> {
        self.extend_with(x_new, ExtendPolicy::default())
    }

    /// Append the rows of `x_new` to the training inputs and refresh the
    /// eigendecomposition *incrementally*: each appended observation
    /// borders the Gram matrix —
    /// `K' = [[K, c], [c', kappa]] = diag(K, kappa) + e v' + v e'`
    /// with `v = [c; 0]` — and the bordering splits into exactly two
    /// symmetric rank-one corrections
    /// `e v' + v e' = p p' - m m'`, `p = (v + e)/sqrt(2)`,
    /// `m = (v - e)/sqrt(2)`, each applied by
    /// [`rankone::rank_one_update`] in O(N^2 + N k^2) instead of the
    /// O(N^3) refit (DESIGN.md §8).
    ///
    /// Falls back to a full refit (and resets the update budget) when the
    /// policy's update budget or conditioning estimate is crossed; the
    /// outcome reports which path served the append.  The returned handle
    /// is a fresh `Arc` — existing clones keep serving the old setup.
    pub fn extend_with(
        &self,
        x_new: &Matrix,
        policy: ExtendPolicy,
    ) -> Result<(SpectralGp, ExtendOutcome), crate::linalg::eigen::NoConvergence> {
        assert_eq!(x_new.cols(), self.setup.x.cols(), "appended rows: feature dim mismatch");
        let m = x_new.rows();
        if m == 0 {
            return Ok((self.clone(), ExtendOutcome::Incremental));
        }
        let p_dim = self.setup.x.cols();
        let full_x = {
            let mut data = self.setup.x.data().to_vec();
            data.extend_from_slice(x_new.data());
            Matrix::from_vec(self.n() + m, p_dim, data)
        };
        if self.setup.updates + 2 * m > policy.max_updates {
            let gp = SpectralGp::fit(self.kernel, full_x)?;
            return Ok((gp, ExtendOutcome::Refit(RefitReason::UpdateBudget)));
        }

        let mut eigen = self.setup.eigen.clone();
        for t in 0..m {
            let n_cur = self.n() + t;
            let new_row = full_x.row(n_cur);
            // cross-kernel column against everything accepted so far
            let cur_x = full_x.top_left(n_cur, p_dim);
            let row_mat = Matrix::from_vec(1, p_dim, new_row.to_vec());
            let c = kernelfn::cross_gram(self.kernel, &cur_x, &row_mat);
            let kappa = self.kernel.eval(new_row, new_row);

            let embedded = embed_bordered(&eigen, kappa);
            let inv_sqrt2 = std::f64::consts::FRAC_1_SQRT_2;
            let mut p_vec = vec![0.0f64; n_cur + 1];
            let mut m_vec = vec![0.0f64; n_cur + 1];
            for i in 0..n_cur {
                let ci = c[(i, 0)] * inv_sqrt2;
                p_vec[i] = ci;
                m_vec[i] = ci;
            }
            p_vec[n_cur] = inv_sqrt2;
            m_vec[n_cur] = -inv_sqrt2;
            let plus = rankone::rank_one_update(&embedded, &p_vec, 1.0);
            eigen = rankone::rank_one_update(&plus, &m_vec, -1.0);
        }

        if rankone::ortho_drift(&eigen, 8) > policy.ortho_tol {
            let gp = SpectralGp::fit(self.kernel, full_x)?;
            return Ok((gp, ExtendOutcome::Refit(RefitReason::Conditioning)));
        }
        let setup = Setup { x: full_x, eigen, updates: self.setup.updates + 2 * m };
        Ok((SpectralGp { kernel: self.kernel, setup: Arc::new(setup) }, ExtendOutcome::Incremental))
    }

    /// Rank-one corrections applied since the last full fit.
    pub fn updates(&self) -> usize {
        self.setup.updates
    }

    /// True when both handles read the *same* shared setup (`Arc`
    /// identity, not value equality).  The session store uses this to
    /// detect that a session's dataset was replaced (streaming update /
    /// drop + recreate) while a derived computation ran outside its
    /// lock.
    pub fn shares_setup(&self, other: &SpectralGp) -> bool {
        Arc::ptr_eq(&self.setup, &other.setup)
    }

    pub fn n(&self) -> usize {
        self.setup.x.rows()
    }
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }
    pub fn eigen(&self) -> &SymEigen {
        &self.setup.eigen
    }
    pub fn x(&self) -> &Matrix {
        &self.setup.x
    }

    /// Approximate heap footprint of the shared setup in bytes (inputs +
    /// eigenvectors + eigenvalues) — the session cache's accounting unit.
    pub fn setup_bytes(&self) -> usize {
        let n = self.n();
        (self.setup.x.data().len() + n * n + n) * std::mem::size_of::<f64>()
    }

    /// O(N) tuning state for one output vector. For an M-output dataset
    /// call this M times — the decomposition is shared, which is the
    /// multi-output advantage of §2.1.
    pub fn eigensystem(&self, y: &[f64]) -> EigenSystem {
        assert_eq!(y.len(), self.n(), "target length != training size");
        EigenSystem::new(&self.setup.eigen, y)
    }

    /// Posterior mean of the coefficient vector:
    /// `mu_c = (K + sigma2/lambda2 I)^{-1} y = U (S + r I)^{-1} U' y` (eq. 8).
    pub fn posterior_mean_coef(&self, y: &[f64], hp: HyperParams) -> Vec<f64> {
        let r = hp.sigma2 / hp.lambda2;
        let mut yt = self.setup.eigen.project(y);
        for (v, &s) in yt.iter_mut().zip(&self.setup.eigen.values) {
            *v /= s + r;
        }
        self.setup.eigen.back_project(&yt)
    }

    /// Training-point posterior predictive mean `mu_y = K mu_c` (eq. 10),
    /// computed in the eigenbasis in O(N^2).
    pub fn posterior_mean_train(&self, y: &[f64], hp: HyperParams) -> Vec<f64> {
        let r = hp.sigma2 / hp.lambda2;
        let mut yt = self.setup.eigen.project(y);
        for (v, &s) in yt.iter_mut().zip(&self.setup.eigen.values) {
            *v *= s / (s + r);
        }
        self.setup.eigen.back_project(&yt)
    }

    /// Predictive mean at new inputs: `k_x~ mu_c` (eq. 4).
    pub fn predict_mean(&self, xnew: &Matrix, y: &[f64], hp: HyperParams) -> Vec<f64> {
        let mu_c = self.posterior_mean_coef(y, hp);
        let kx = kernelfn::cross_gram(self.kernel, xnew, &self.setup.x);
        kx.matvec(&mu_c)
    }

    /// Predictive variance at new inputs:
    /// `k_x~ Sigma_c k_x~' + sigma2` with `Sigma_c = U Q U'` (Prop. 2.4).
    pub fn predict_var(&self, xnew: &Matrix, hp: HyperParams) -> Vec<f64> {
        let kx = kernelfn::cross_gram(self.kernel, xnew, &self.setup.x);
        self.var_from_cross_gram(&kx, hp)
    }

    /// Predictive mean *and* variance at new inputs, sharing one
    /// cross-Gram computation — the serving layer's `predict` op (the
    /// kernel evaluations dominate, so computing `k_x~` once halves the
    /// request cost versus `predict_mean` + `predict_var`).
    pub fn predict(&self, xnew: &Matrix, y: &[f64], hp: HyperParams) -> (Vec<f64>, Vec<f64>) {
        let kx = kernelfn::cross_gram(self.kernel, xnew, &self.setup.x);
        let mean = kx.matvec(&self.posterior_mean_coef(y, hp));
        let var = self.var_from_cross_gram(&kx, hp);
        (mean, var)
    }

    fn var_from_cross_gram(&self, kx: &Matrix, hp: HyperParams) -> Vec<f64> {
        let q = self.posterior_var_coeffs(hp);
        // v = U' k_x~'; var = sum_j q_j v_j^2 + sigma2
        (0..kx.rows())
            .map(|i| {
                let v = self.setup.eigen.project(kx.row(i));
                v.iter().zip(&q).map(|(vj, qj)| vj * vj * qj).sum::<f64>() + hp.sigma2
            })
            .collect()
    }

    /// Prop. 2.4: the diagonal of `Sigma_c` in O(N) per element.
    pub fn posterior_var_diag(&self, hp: HyperParams) -> Vec<f64> {
        let q = self.posterior_var_coeffs(hp);
        let u = &self.setup.eigen.vectors;
        (0..self.n())
            .map(|i| u.row(i).iter().zip(&q).map(|(uij, qj)| uij * uij * qj).sum())
            .collect()
    }

    /// Prop. 2.4: the full `Sigma_c = U Q U'` via Strassen multiplication
    /// (O(N^2.807) instead of two O(N^3) inversions of eq. 36).
    pub fn posterior_var_full(&self, hp: HyperParams) -> Matrix {
        let q = self.posterior_var_coeffs(hp);
        let u = &self.setup.eigen.vectors;
        let n = self.n();
        // (U Q) then Strassen (U Q) U'
        let mut uq = u.clone();
        for i in 0..n {
            for j in 0..n {
                uq[(i, j)] *= q[j];
            }
        }
        strassen::strassen(&uq, &u.t())
    }

    fn posterior_var_coeffs(&self, hp: HyperParams) -> Vec<f64> {
        self.setup.eigen
            .values
            .iter()
            .map(|&s| {
                if s > 1e-12 {
                    hp.sigma2 * hp.lambda2 / ((hp.lambda2 * s + hp.sigma2) * s)
                } else {
                    0.0 // rank-deficient direction: prior precision infinite
                }
            })
            .collect()
    }
}

/// Eigendecomposition of `diag(K, kappa)` from the decomposition of `K`:
/// the appended coordinate is its own eigenpair (`e_{n}`, `kappa`),
/// spliced into the ascending order.  This is the exact starting point of
/// the bordering identity in [`SpectralGp::extend_with`].
fn embed_bordered(eigen: &SymEigen, kappa: f64) -> SymEigen {
    let n = eigen.values.len();
    let pos = eigen.values.partition_point(|&s| s < kappa);
    let mut values = Vec::with_capacity(n + 1);
    values.extend_from_slice(&eigen.values[..pos]);
    values.push(kappa);
    values.extend_from_slice(&eigen.values[pos..]);
    let mut vectors = Matrix::zeros(n + 1, n + 1);
    for i in 0..n {
        for j in 0..n {
            let col = if j < pos { j } else { j + 1 };
            vectors[(i, col)] = eigen.vectors[(i, j)];
        }
    }
    vectors[(n, pos)] = 1.0;
    SymEigen { values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Cholesky;
    use crate::util::rng::Rng;

    fn setup(n: usize, seed: u64) -> (SpectralGp, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
        let y = rng.normal_vec(n);
        (SpectralGp::fit(Kernel::Rbf { xi2: 1.5 }, x).unwrap(), y)
    }

    /// Dense eq. (8) oracle for mu_c.
    fn dense_mu_c(gp: &SpectralGp, y: &[f64], hp: HyperParams) -> Vec<f64> {
        let mut m = kernelfn::gram(gp.kernel(), gp.x());
        m.add_diag(hp.sigma2 / hp.lambda2);
        Cholesky::new(&m).unwrap().solve(y)
    }

    #[test]
    fn posterior_mean_coef_matches_dense() {
        let (gp, y) = setup(40, 1);
        let hp = HyperParams::new(0.5, 2.0);
        let got = gp.posterior_mean_coef(&y, hp);
        let want = dense_mu_c(&gp, &y, hp);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn posterior_mean_train_matches_k_mu_c() {
        let (gp, y) = setup(30, 2);
        let hp = HyperParams::new(0.7, 1.1);
        let k = kernelfn::gram(gp.kernel(), gp.x());
        let want = k.matvec(&gp.posterior_mean_coef(&y, hp));
        let got = gp.posterior_mean_train(&y, hp);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn predict_mean_at_training_points_matches_mu_y() {
        let (gp, y) = setup(25, 3);
        let hp = HyperParams::new(0.4, 1.5);
        let got = gp.predict_mean(&gp.x().clone(), &y, hp);
        let want = gp.posterior_mean_train(&y, hp);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    /// Dense eq. (36) oracle for Sigma_c.
    fn dense_sigma_c(gp: &SpectralGp, hp: HyperParams) -> Matrix {
        let k = kernelfn::gram(gp.kernel(), gp.x());
        let mut m = k.clone();
        m.add_diag(hp.sigma2 / hp.lambda2);
        let minv = Cholesky::new(&m).unwrap().inverse();
        // K^{-1} via eigen to tolerate conditioning
        let kinv = {
            let mut kk = k.clone();
            kk.add_diag(1e-10);
            Cholesky::new(&kk).unwrap().inverse()
        };
        let mut out = crate::linalg::gemm::matmul(&minv, &kinv);
        out.scale(hp.sigma2);
        out
    }

    #[test]
    fn posterior_var_diag_matches_dense_eq36() {
        let (gp, _) = setup(30, 4);
        let hp = HyperParams::new(0.6, 1.8);
        let got = gp.posterior_var_diag(hp);
        let want = dense_sigma_c(&gp, hp);
        for i in 0..30 {
            assert!(
                (got[i] - want[(i, i)]).abs() < 1e-5 * want[(i, i)].abs().max(1.0),
                "i={i}: {} vs {}",
                got[i],
                want[(i, i)]
            );
        }
    }

    #[test]
    fn posterior_var_full_matches_diag() {
        let (gp, _) = setup(20, 5);
        let hp = HyperParams::new(0.9, 0.7);
        let full = gp.posterior_var_full(hp);
        let diag = gp.posterior_var_diag(hp);
        for i in 0..20 {
            assert!((full[(i, i)] - diag[i]).abs() < 1e-9);
        }
        // symmetry
        assert!(full.max_abs_diff(&full.t()) < 1e-9);
    }

    #[test]
    fn combined_predict_matches_separate_paths() {
        let (gp, y) = setup(25, 10);
        let hp = HyperParams::new(0.3, 1.2);
        let mut rng = Rng::new(11);
        let xnew = Matrix::from_fn(8, 3, |_, _| rng.normal());
        let (mean, var) = gp.predict(&xnew, &y, hp);
        let mean2 = gp.predict_mean(&xnew, &y, hp);
        let var2 = gp.predict_var(&xnew, hp);
        assert_eq!(mean, mean2);
        assert_eq!(var, var2);
    }

    #[test]
    fn predict_var_positive_and_at_least_noise() {
        let (gp, _) = setup(30, 6);
        let hp = HyperParams::new(0.5, 2.0);
        let mut rng = Rng::new(7);
        let xnew = Matrix::from_fn(10, 3, |_, _| rng.normal());
        for v in gp.predict_var(&xnew, hp) {
            assert!(v >= hp.sigma2 - 1e-12, "variance {v} below noise floor");
        }
    }

    #[test]
    fn multi_output_shares_decomposition() {
        let (gp, y1) = setup(30, 8);
        let mut rng = Rng::new(9);
        let y2 = rng.normal_vec(30);
        let es1 = gp.eigensystem(&y1);
        let es2 = gp.eigensystem(&y2);
        assert_eq!(es1.s, es2.s); // same spectrum object content
        assert!(es1.score(HyperParams::new(1.0, 1.0)).is_finite());
        assert!(es2.score(HyperParams::new(1.0, 1.0)).is_finite());
    }

    #[test]
    fn extend_matches_full_refit_spectrally() {
        let mut rng = Rng::new(21);
        let x_full = Matrix::from_fn(28, 3, |_, _| rng.normal());
        let x_base = x_full.top_left(24, 3);
        let x_new = Matrix::from_fn(4, 3, |i, j| x_full[(24 + i, j)]);
        let kernel = Kernel::Rbf { xi2: 1.5 };
        let base = SpectralGp::fit(kernel, x_base).unwrap();
        let (ext, outcome) = base.extend(&x_new).unwrap();
        assert_eq!(outcome, ExtendOutcome::Incremental);
        assert_eq!(ext.n(), 28);
        assert_eq!(ext.updates(), 8);
        // the updated decomposition reconstructs the bordered Gram matrix
        let k_full = kernelfn::gram(kernel, &x_full);
        let err = ext.eigen().reconstruct().max_abs_diff(&k_full);
        assert!(err < 1e-9 * (1.0 + k_full.fro_norm()), "reconstruction {err}");
        // and the base handle is untouched (fresh Arc)
        assert_eq!(base.n(), 24);
        assert_eq!(base.updates(), 0);
    }

    #[test]
    fn extend_falls_back_on_update_budget() {
        let mut rng = Rng::new(22);
        let x = Matrix::from_fn(16, 2, |_, _| rng.normal());
        let x_new = Matrix::from_fn(3, 2, |_, _| rng.normal());
        let gp = SpectralGp::fit(Kernel::Rbf { xi2: 1.0 }, x).unwrap();
        let policy = ExtendPolicy { max_updates: 4, ..Default::default() };
        // 3 appends = 6 updates > 4: full refit
        let (refit, outcome) = gp.extend_with(&x_new, policy).unwrap();
        assert_eq!(outcome, ExtendOutcome::Refit(RefitReason::UpdateBudget));
        assert_eq!(refit.n(), 19);
        assert_eq!(refit.updates(), 0, "refit resets the budget");
        // 2 appends = 4 updates <= 4: incremental
        let two = Matrix::from_fn(2, 2, |i, j| x_new[(i, j)]);
        let (inc, outcome) = gp.extend_with(&two, policy).unwrap();
        assert_eq!(outcome, ExtendOutcome::Incremental);
        assert_eq!(inc.updates(), 4);
    }

    #[test]
    fn shares_setup_tracks_arc_identity() {
        let (gp, _) = setup(10, 29);
        let clone = gp.clone();
        assert!(gp.shares_setup(&clone), "clones share the setup");
        let (grown, _) = gp.extend(&Matrix::from_fn(1, 3, |_, _| 0.1)).unwrap();
        assert!(!gp.shares_setup(&grown), "extend produces a fresh setup");
        let refit = SpectralGp::fit(gp.kernel(), gp.x().clone()).unwrap();
        assert!(!gp.shares_setup(&refit), "identical values, different setup");
    }

    #[test]
    fn extend_empty_append_is_identity() {
        let (gp, _) = setup(10, 30);
        let none = Matrix::zeros(0, 3);
        let (same, outcome) = gp.extend(&none).unwrap();
        assert_eq!(outcome, ExtendOutcome::Incremental);
        assert_eq!(same.n(), 10);
    }

    #[test]
    fn interpolation_quality_on_smooth_function() {
        // y = sin(x) on a grid; GP with good hyperparameters should
        // interpolate much better than the data std
        let n = 60;
        let x = Matrix::from_fn(n, 1, |i, _| i as f64 / n as f64 * 6.0);
        let y: Vec<f64> = (0..n).map(|i| (i as f64 / n as f64 * 6.0).sin()).collect();
        let gp = SpectralGp::fit(Kernel::Rbf { xi2: 0.5 }, x).unwrap();
        let hp = HyperParams::new(1e-4, 1.0);
        let xt = Matrix::from_fn(20, 1, |i, _| 0.15 + i as f64 * 0.3);
        let pred = gp.predict_mean(&xt, &y, hp);
        for (i, p) in pred.iter().enumerate() {
            let truth = (0.15 + i as f64 * 0.3).sin();
            assert!((p - truth).abs() < 0.05, "at {i}: {p} vs {truth}");
        }
    }
}

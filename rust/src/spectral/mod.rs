//! The paper's system: spectral (eigendecomposition-based) Gaussian
//! process regression with O(N)-per-iterate hyperparameter tuning.
//!
//! [`SpectralGp`] pays the O(N^3) eigendecomposition once per (dataset,
//! kernel) pair; everything downstream — score/Jacobian/Hessian
//! evaluations ([`EigenSystem`]), posterior moments, Prop. 2.4 variance —
//! is O(N) or O(N^2).  Multi-output datasets share the decomposition
//! (paper §2.1: "the eigendecomposition need only be computed once").
//!
//! A `SpectralGp` is a cheap-to-clone *handle*: the O(N^2) setup (inputs
//! + eigendecomposition) lives behind an [`std::sync::Arc`], so the
//! coordinator's session cache and its worker pool can hand the same
//! fitted state to many concurrent requests without copying it
//! (DESIGN.md §7).
//!
//! # Examples
//!
//! ```
//! use gpml::kernelfn::Kernel;
//! use gpml::spectral::{HyperParams, SpectralGp};
//!
//! let ds = gpml::data::synthetic(
//!     gpml::data::SyntheticSpec { n: 24, p: 2, seed: 7, ..Default::default() }, 1);
//! let gp = SpectralGp::fit(Kernel::Rbf { xi2: 2.0 }, ds.x.clone()).unwrap();
//!
//! // O(N) tuning state; clones of `gp` share the same decomposition.
//! let es = gp.eigensystem(ds.y());
//! assert!(es.score(HyperParams::new(0.1, 1.0)).is_finite());
//!
//! let mu = gp.posterior_mean_train(ds.y(), HyperParams::new(0.1, 1.0));
//! assert_eq!(mu.len(), gp.n());
//! ```

pub mod eval;

pub use eval::{EigenSystem, Evaluation, HyperParams};

use std::sync::Arc;

use crate::kernelfn::{self, Kernel};
use crate::linalg::{strassen, Matrix, SymEigen};

/// The shared one-time setup: training inputs + eigendecomposition.
struct Setup {
    x: Matrix,
    eigen: SymEigen,
}

/// A fitted spectral GP: kernel + training inputs + eigendecomposition.
///
/// Cloning is O(1) (an `Arc` bump): every clone reads the same
/// setup, which is what lets the coordinator serve many concurrent
/// requests against one cached decomposition.
#[derive(Clone)]
pub struct SpectralGp {
    kernel: Kernel,
    setup: Arc<Setup>,
}

impl SpectralGp {
    /// Build the Gram matrix and eigendecompose it — the one-time O(N^3)
    /// overhead (eq. 17).
    pub fn fit(kernel: Kernel, x: Matrix) -> Result<Self, crate::linalg::eigen::NoConvergence> {
        let k = kernelfn::gram(kernel, &x);
        let eigen = SymEigen::new(&k)?;
        Ok(SpectralGp::from_eigen(kernel, x, eigen))
    }

    /// Build from a precomputed Gram matrix (e.g. the PJRT gram artifact).
    pub fn fit_from_gram(
        kernel: Kernel,
        x: Matrix,
        k: &Matrix,
    ) -> Result<Self, crate::linalg::eigen::NoConvergence> {
        let eigen = SymEigen::new(k)?;
        Ok(SpectralGp::from_eigen(kernel, x, eigen))
    }

    /// Wrap an already-computed eigendecomposition (used by the session
    /// cache, which times the gram and eigen phases separately).
    pub fn from_eigen(kernel: Kernel, x: Matrix, eigen: SymEigen) -> Self {
        SpectralGp { kernel, setup: Arc::new(Setup { x, eigen }) }
    }

    pub fn n(&self) -> usize {
        self.setup.x.rows()
    }
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }
    pub fn eigen(&self) -> &SymEigen {
        &self.setup.eigen
    }
    pub fn x(&self) -> &Matrix {
        &self.setup.x
    }

    /// Approximate heap footprint of the shared setup in bytes (inputs +
    /// eigenvectors + eigenvalues) — the session cache's accounting unit.
    pub fn setup_bytes(&self) -> usize {
        let n = self.n();
        (self.setup.x.data().len() + n * n + n) * std::mem::size_of::<f64>()
    }

    /// O(N) tuning state for one output vector. For an M-output dataset
    /// call this M times — the decomposition is shared, which is the
    /// multi-output advantage of §2.1.
    pub fn eigensystem(&self, y: &[f64]) -> EigenSystem {
        assert_eq!(y.len(), self.n(), "target length != training size");
        EigenSystem::new(&self.setup.eigen, y)
    }

    /// Posterior mean of the coefficient vector:
    /// `mu_c = (K + sigma2/lambda2 I)^{-1} y = U (S + r I)^{-1} U' y` (eq. 8).
    pub fn posterior_mean_coef(&self, y: &[f64], hp: HyperParams) -> Vec<f64> {
        let r = hp.sigma2 / hp.lambda2;
        let mut yt = self.setup.eigen.project(y);
        for (v, &s) in yt.iter_mut().zip(&self.setup.eigen.values) {
            *v /= s + r;
        }
        self.setup.eigen.back_project(&yt)
    }

    /// Training-point posterior predictive mean `mu_y = K mu_c` (eq. 10),
    /// computed in the eigenbasis in O(N^2).
    pub fn posterior_mean_train(&self, y: &[f64], hp: HyperParams) -> Vec<f64> {
        let r = hp.sigma2 / hp.lambda2;
        let mut yt = self.setup.eigen.project(y);
        for (v, &s) in yt.iter_mut().zip(&self.setup.eigen.values) {
            *v *= s / (s + r);
        }
        self.setup.eigen.back_project(&yt)
    }

    /// Predictive mean at new inputs: `k_x~ mu_c` (eq. 4).
    pub fn predict_mean(&self, xnew: &Matrix, y: &[f64], hp: HyperParams) -> Vec<f64> {
        let mu_c = self.posterior_mean_coef(y, hp);
        let kx = kernelfn::cross_gram(self.kernel, xnew, &self.setup.x);
        kx.matvec(&mu_c)
    }

    /// Predictive variance at new inputs:
    /// `k_x~ Sigma_c k_x~' + sigma2` with `Sigma_c = U Q U'` (Prop. 2.4).
    pub fn predict_var(&self, xnew: &Matrix, hp: HyperParams) -> Vec<f64> {
        let kx = kernelfn::cross_gram(self.kernel, xnew, &self.setup.x);
        self.var_from_cross_gram(&kx, hp)
    }

    /// Predictive mean *and* variance at new inputs, sharing one
    /// cross-Gram computation — the serving layer's `predict` op (the
    /// kernel evaluations dominate, so computing `k_x~` once halves the
    /// request cost versus `predict_mean` + `predict_var`).
    pub fn predict(&self, xnew: &Matrix, y: &[f64], hp: HyperParams) -> (Vec<f64>, Vec<f64>) {
        let kx = kernelfn::cross_gram(self.kernel, xnew, &self.setup.x);
        let mean = kx.matvec(&self.posterior_mean_coef(y, hp));
        let var = self.var_from_cross_gram(&kx, hp);
        (mean, var)
    }

    fn var_from_cross_gram(&self, kx: &Matrix, hp: HyperParams) -> Vec<f64> {
        let q = self.posterior_var_coeffs(hp);
        // v = U' k_x~'; var = sum_j q_j v_j^2 + sigma2
        (0..kx.rows())
            .map(|i| {
                let v = self.setup.eigen.project(kx.row(i));
                v.iter().zip(&q).map(|(vj, qj)| vj * vj * qj).sum::<f64>() + hp.sigma2
            })
            .collect()
    }

    /// Prop. 2.4: the diagonal of `Sigma_c` in O(N) per element.
    pub fn posterior_var_diag(&self, hp: HyperParams) -> Vec<f64> {
        let q = self.posterior_var_coeffs(hp);
        let u = &self.setup.eigen.vectors;
        (0..self.n())
            .map(|i| u.row(i).iter().zip(&q).map(|(uij, qj)| uij * uij * qj).sum())
            .collect()
    }

    /// Prop. 2.4: the full `Sigma_c = U Q U'` via Strassen multiplication
    /// (O(N^2.807) instead of two O(N^3) inversions of eq. 36).
    pub fn posterior_var_full(&self, hp: HyperParams) -> Matrix {
        let q = self.posterior_var_coeffs(hp);
        let u = &self.setup.eigen.vectors;
        let n = self.n();
        // (U Q) then Strassen (U Q) U'
        let mut uq = u.clone();
        for i in 0..n {
            for j in 0..n {
                uq[(i, j)] *= q[j];
            }
        }
        strassen::strassen(&uq, &u.t())
    }

    fn posterior_var_coeffs(&self, hp: HyperParams) -> Vec<f64> {
        self.setup.eigen
            .values
            .iter()
            .map(|&s| {
                if s > 1e-12 {
                    hp.sigma2 * hp.lambda2 / ((hp.lambda2 * s + hp.sigma2) * s)
                } else {
                    0.0 // rank-deficient direction: prior precision infinite
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Cholesky;
    use crate::util::rng::Rng;

    fn setup(n: usize, seed: u64) -> (SpectralGp, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
        let y = rng.normal_vec(n);
        (SpectralGp::fit(Kernel::Rbf { xi2: 1.5 }, x).unwrap(), y)
    }

    /// Dense eq. (8) oracle for mu_c.
    fn dense_mu_c(gp: &SpectralGp, y: &[f64], hp: HyperParams) -> Vec<f64> {
        let mut m = kernelfn::gram(gp.kernel(), gp.x());
        m.add_diag(hp.sigma2 / hp.lambda2);
        Cholesky::new(&m).unwrap().solve(y)
    }

    #[test]
    fn posterior_mean_coef_matches_dense() {
        let (gp, y) = setup(40, 1);
        let hp = HyperParams::new(0.5, 2.0);
        let got = gp.posterior_mean_coef(&y, hp);
        let want = dense_mu_c(&gp, &y, hp);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn posterior_mean_train_matches_k_mu_c() {
        let (gp, y) = setup(30, 2);
        let hp = HyperParams::new(0.7, 1.1);
        let k = kernelfn::gram(gp.kernel(), gp.x());
        let want = k.matvec(&gp.posterior_mean_coef(&y, hp));
        let got = gp.posterior_mean_train(&y, hp);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn predict_mean_at_training_points_matches_mu_y() {
        let (gp, y) = setup(25, 3);
        let hp = HyperParams::new(0.4, 1.5);
        let got = gp.predict_mean(&gp.x().clone(), &y, hp);
        let want = gp.posterior_mean_train(&y, hp);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    /// Dense eq. (36) oracle for Sigma_c.
    fn dense_sigma_c(gp: &SpectralGp, hp: HyperParams) -> Matrix {
        let k = kernelfn::gram(gp.kernel(), gp.x());
        let mut m = k.clone();
        m.add_diag(hp.sigma2 / hp.lambda2);
        let minv = Cholesky::new(&m).unwrap().inverse();
        // K^{-1} via eigen to tolerate conditioning
        let kinv = {
            let mut kk = k.clone();
            kk.add_diag(1e-10);
            Cholesky::new(&kk).unwrap().inverse()
        };
        let mut out = crate::linalg::gemm::matmul(&minv, &kinv);
        out.scale(hp.sigma2);
        out
    }

    #[test]
    fn posterior_var_diag_matches_dense_eq36() {
        let (gp, _) = setup(30, 4);
        let hp = HyperParams::new(0.6, 1.8);
        let got = gp.posterior_var_diag(hp);
        let want = dense_sigma_c(&gp, hp);
        for i in 0..30 {
            assert!(
                (got[i] - want[(i, i)]).abs() < 1e-5 * want[(i, i)].abs().max(1.0),
                "i={i}: {} vs {}",
                got[i],
                want[(i, i)]
            );
        }
    }

    #[test]
    fn posterior_var_full_matches_diag() {
        let (gp, _) = setup(20, 5);
        let hp = HyperParams::new(0.9, 0.7);
        let full = gp.posterior_var_full(hp);
        let diag = gp.posterior_var_diag(hp);
        for i in 0..20 {
            assert!((full[(i, i)] - diag[i]).abs() < 1e-9);
        }
        // symmetry
        assert!(full.max_abs_diff(&full.t()) < 1e-9);
    }

    #[test]
    fn combined_predict_matches_separate_paths() {
        let (gp, y) = setup(25, 10);
        let hp = HyperParams::new(0.3, 1.2);
        let mut rng = Rng::new(11);
        let xnew = Matrix::from_fn(8, 3, |_, _| rng.normal());
        let (mean, var) = gp.predict(&xnew, &y, hp);
        let mean2 = gp.predict_mean(&xnew, &y, hp);
        let var2 = gp.predict_var(&xnew, hp);
        assert_eq!(mean, mean2);
        assert_eq!(var, var2);
    }

    #[test]
    fn predict_var_positive_and_at_least_noise() {
        let (gp, _) = setup(30, 6);
        let hp = HyperParams::new(0.5, 2.0);
        let mut rng = Rng::new(7);
        let xnew = Matrix::from_fn(10, 3, |_, _| rng.normal());
        for v in gp.predict_var(&xnew, hp) {
            assert!(v >= hp.sigma2 - 1e-12, "variance {v} below noise floor");
        }
    }

    #[test]
    fn multi_output_shares_decomposition() {
        let (gp, y1) = setup(30, 8);
        let mut rng = Rng::new(9);
        let y2 = rng.normal_vec(30);
        let es1 = gp.eigensystem(&y1);
        let es2 = gp.eigensystem(&y2);
        assert_eq!(es1.s, es2.s); // same spectrum object content
        assert!(es1.score(HyperParams::new(1.0, 1.0)).is_finite());
        assert!(es2.score(HyperParams::new(1.0, 1.0)).is_finite());
    }

    #[test]
    fn interpolation_quality_on_smooth_function() {
        // y = sin(x) on a grid; GP with good hyperparameters should
        // interpolate much better than the data std
        let n = 60;
        let x = Matrix::from_fn(n, 1, |i, _| i as f64 / n as f64 * 6.0);
        let y: Vec<f64> = (0..n).map(|i| (i as f64 / n as f64 * 6.0).sin()).collect();
        let gp = SpectralGp::fit(Kernel::Rbf { xi2: 0.5 }, x).unwrap();
        let hp = HyperParams::new(1e-4, 1.0);
        let xt = Matrix::from_fn(20, 1, |i, _| 0.15 + i as f64 * 0.3);
        let pred = gp.predict_mean(&xt, &y, hp);
        for (i, p) in pred.iter().enumerate() {
            let truth = (0.15 + i as f64 * 0.3).sin();
            assert!((p - truth).abs() < 0.05, "at {i}: {p} vs {truth}");
        }
    }
}

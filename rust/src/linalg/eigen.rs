//! Symmetric eigendecomposition — the paper's one-time O(N^3) overhead
//! (eq. 17: `K = U S U'`).
//!
//! Classic two-phase dense solver, implemented from scratch:
//!  1. Householder tridiagonalization with accumulation of the orthogonal
//!     transform (EISPACK `tred2`).
//!  2. A tridiagonal eigensolver: by default the Cuppen
//!     divide-and-conquer solver (`linalg/dac.rs`, DESIGN.md §12) whose
//!     eigenvector accumulation is one blocked GEMM against the
//!     `tred2` transform; setting the environment variable
//!     `GPML_EIGEN=ql` (or calling [`with_solver`]) falls back to the
//!     implicit-shift QL iteration (EISPACK `tql2`), which doubles as
//!     the in-repo oracle for the differential suite.
//!
//! Output convention matches the paper: ascending eigenvalues `s` and an
//! orthogonal `U` whose *columns* are eigenvectors, `K = U diag(s) U'`.

use super::matrix::Matrix;
use super::microkernel;
use crate::util::threadpool::{self, div_ceil, SharedMut};
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Minimum per-worker work (multiply-add units) before a tred2/tql2
/// sweep fans out through the pool — below this the per-step scope
/// spawn (~30 us at 4 workers) beats the win, and `par_for`'s plan
/// collapses to the exact serial loop.  2^16 madds ≈ 30 us: fan-out
/// starts around step size i ≈ 256, which keeps total spawn overhead
/// under ~3% of the O(N^3) work at N = 2048.
const PAR_GRAIN: usize = 1 << 16;

/// Upper bound on the number of partial-sum blocks in the `tred2`
/// transform-accumulation phase.  The block layout must stay a function
/// of the step size only (the determinism policy), so the cap widens
/// each block rather than shrinking the fan-out below the pool width:
/// 64 blocks keeps every hosted-runner width saturated while bounding
/// the reusable partials buffer at `64 * N` doubles (~4 MB at N = 8192,
/// versus ~67 MB per step for the uncapped layout).
const MAX_PARTIAL_BLOCKS: usize = 64;

/// Eigendecomposition `A = U diag(s) U'` of a symmetric matrix.
#[derive(Clone, Debug)]
pub struct SymEigen {
    /// Ascending eigenvalues.
    pub values: Vec<f64>,
    /// Orthogonal matrix; column `j` is the eigenvector of `values[j]`.
    pub vectors: Matrix,
}

/// QL failed to converge (pathological input; never observed on Gram
/// matrices).
#[derive(Debug)]
pub struct NoConvergence {
    pub eigenvalue_index: usize,
}

impl std::fmt::Display for NoConvergence {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "QL iteration failed to converge for eigenvalue {}", self.eigenvalue_index)
    }
}
impl std::error::Error for NoConvergence {}

/// Which solver handles the tridiagonal stage of [`SymEigen::new`].
///
/// The default is resolved once per process from the `GPML_EIGEN`
/// environment variable (`ql` selects [`EigenSolver::Ql`], anything
/// else — including unset — selects [`EigenSolver::Dac`]) and can be
/// overridden per call tree with [`with_solver`].  Both produce the
/// same convention (ascending eigenvalues, orthogonal columns); the QL
/// path is the in-repo oracle the differential suite gates D&C against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EigenSolver {
    /// Cuppen divide-and-conquer over the shared secular merge
    /// machinery (`linalg/dac.rs`) — the default.
    Dac,
    /// Sequential implicit-shift QL iteration (EISPACK `tql2`) — the
    /// escape hatch (`GPML_EIGEN=ql`) and oracle.
    Ql,
}

impl EigenSolver {
    /// Stable label, matching the accepted `GPML_EIGEN` values.
    pub fn as_str(self) -> &'static str {
        match self {
            EigenSolver::Dac => "dac",
            EigenSolver::Ql => "ql",
        }
    }
}

// Encoding shared by the env cache and the thread-local override:
// 0 = unset, 1 = Dac, 2 = Ql.
const SOLVER_UNSET: usize = 0;
const SOLVER_DAC: usize = 1;
const SOLVER_QL: usize = 2;

fn env_solver() -> EigenSolver {
    static CACHE: AtomicUsize = AtomicUsize::new(SOLVER_UNSET);
    match CACHE.load(Ordering::Relaxed) {
        SOLVER_DAC => return EigenSolver::Dac,
        SOLVER_QL => return EigenSolver::Ql,
        _ => {}
    }
    let solver = match std::env::var("GPML_EIGEN") {
        Ok(v) if v.eq_ignore_ascii_case("ql") => EigenSolver::Ql,
        _ => EigenSolver::Dac,
    };
    let code = if solver == EigenSolver::Ql { SOLVER_QL } else { SOLVER_DAC };
    CACHE.store(code, Ordering::Relaxed);
    solver
}

thread_local! {
    static LOCAL_SOLVER: Cell<usize> = const { Cell::new(SOLVER_UNSET) };
}

/// The solver [`SymEigen::new`] will dispatch to on this thread: the
/// innermost [`with_solver`] override if one is active, else the
/// process-wide `GPML_EIGEN` choice (default [`EigenSolver::Dac`]).
pub fn default_solver() -> EigenSolver {
    match LOCAL_SOLVER.with(Cell::get) {
        SOLVER_DAC => EigenSolver::Dac,
        SOLVER_QL => EigenSolver::Ql,
        _ => env_solver(),
    }
}

/// Run `f` with every [`SymEigen::new`] on this thread dispatched to
/// `solver`, restoring the previous choice on exit (panic-safe; nests).
/// Thread-local: work handed to other threads inside `f` still sees
/// their own default.
pub fn with_solver<R>(solver: EigenSolver, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            LOCAL_SOLVER.with(|c| c.set(self.0));
        }
    }
    let code = if solver == EigenSolver::Ql { SOLVER_QL } else { SOLVER_DAC };
    let _restore = Restore(LOCAL_SOLVER.with(|c| c.replace(code)));
    f()
}

impl SymEigen {
    /// Decompose a symmetric matrix (only the lower triangle is read; the
    /// input is copied) with the ambient solver — see [`default_solver`].
    pub fn new(a: &Matrix) -> Result<SymEigen, NoConvergence> {
        SymEigen::new_with(a, default_solver())
    }

    /// Decompose with an explicit tridiagonal-stage solver.
    pub fn new_with(a: &Matrix, solver: EigenSolver) -> Result<SymEigen, NoConvergence> {
        assert!(a.is_square(), "eigendecomposition needs a square matrix");
        let n = a.rows();
        if n == 0 {
            return Ok(SymEigen { values: vec![], vectors: Matrix::zeros(0, 0) });
        }
        let mut z = a.clone();
        z.symmetrize();
        let mut d = vec![0.0; n]; // diagonal
        let mut e = vec![0.0; n]; // sub-diagonal
        tred2(&mut z, &mut d, &mut e);
        // At or below the D&C leaf crossover the two solvers are the
        // same QL code path (a single leaf) — run it on the accumulated
        // transform directly instead of paying a wasted n x n GEMM.
        if solver == EigenSolver::Ql || n <= super::dac::CROSSOVER {
            tql2(&mut z, &mut d, &mut e)?;
            return Ok(SymEigen { values: d, vectors: z });
        }
        let tri = super::dac::solve_tridiag(&d, &e[1..])?;
        // back-multiply the tred2 transform: U = Z * Q_tri, one blocked GEMM
        let vectors = crate::linalg::gemm::matmul(&z, &tri.vectors);
        Ok(SymEigen { values: tri.values, vectors })
    }

    /// `U' y` — projection of targets onto the eigenbasis (eq. 18).
    pub fn project(&self, y: &[f64]) -> Vec<f64> {
        self.vectors.matvec_t(y)
    }

    /// `U x` — back-projection.
    pub fn back_project(&self, x: &[f64]) -> Vec<f64> {
        self.vectors.matvec(x)
    }

    /// Reconstruct `U diag(s) U'` (test/diagnostic helper).
    pub fn reconstruct(&self) -> Matrix {
        let n = self.values.len();
        let mut scaled = self.vectors.clone(); // columns scaled by eigenvalue
        for i in 0..n {
            for j in 0..n {
                scaled[(i, j)] *= self.values[j];
            }
        }
        crate::linalg::gemm::matmul_bt(&scaled, &self.vectors)
    }
}

/// Householder tridiagonalization alone (the `tred2` phase of
/// [`SymEigen::new`]), exposed for the kernel-ablation bench: returns
/// the accumulated transform, the diagonal, and the sub-diagonal
/// (`e[1..]`).
pub fn tridiagonalize(a: &Matrix) -> (Matrix, Vec<f64>, Vec<f64>) {
    assert!(a.is_square(), "tridiagonalization needs a square matrix");
    let n = a.rows();
    let mut z = a.clone();
    z.symmetrize();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    if n > 0 {
        tred2(&mut z, &mut d, &mut e);
    }
    (z, d, e)
}

/// Householder reduction to tridiagonal form, accumulating the transform.
/// On exit `z` holds the orthogonal matrix, `d` the diagonal, `e[1..]` the
/// sub-diagonal. (Port of EISPACK tred2 as given in Numerical Recipes §11.2.)
///
/// Both O(N^3) phases fan out through the scoped pool (DESIGN.md §6):
/// the reduction's symmetric-matvec and rank-2-update sweeps are
/// parallel over their disjoint target rows (bit-identical across
/// thread counts — the per-element arithmetic is the serial one), and
/// the transform accumulation splits its row-streaming sum into
/// fixed-shape k-blocks (a function of the step size only, never the
/// pool width) whose private partials are reduced serially in block
/// order — so the accumulated transform, and with it the whole solve,
/// is bit-identical at any `GPML_THREADS` (DESIGN.md §12's determinism
/// policy; a single block collapses to the pre-pool serial sweep).
///
/// The inner arithmetic runs on the fixed-lane microkernels
/// (DESIGN.md §14): the symmetric matvec's row part is the 8-lane dot,
/// the rank-2 and rank-1 row updates and the accumulation sweeps are the
/// broadcast-FMA axpy — so the whole reduction is additionally
/// bit-identical across `GPML_KERNEL` backends.  The backend is resolved
/// once here, on the calling thread, and captured into the pool closures
/// (pool workers don't inherit thread-locals).
fn tred2(z: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    let kb = microkernel::default_kernel_backend();
    // Step-local scratch, hoisted: `vbuf` holds the read-only copy of
    // row i (the Householder vector / transform row) each step, and
    // `partials` the per-block partial sums of the accumulation phase.
    // At N = 8192 the seed allocated these fresh every step — ~67 MB of
    // partials per step alone; reusing (and block-capping) them keeps
    // the large-N sweep allocation-flat without changing any arithmetic
    // within a block.
    let mut vbuf = vec![0.0f64; n];
    let mut partials: Vec<f64> = Vec::new();
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let scale: f64 = (0..=l).map(|k| z[(i, k)].abs()).sum();
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                // Row i (the Householder vector, scaled) is read-only for
                // the rest of this step; a copy keeps the borrows simple.
                vbuf[..=l].copy_from_slice(&z.row(i)[..=l]);
                let zi = &vbuf[..=l];
                let grain = (PAR_GRAIN / i).max(1);
                {
                    // e[j] = (A v)_j / h over the leading (l+1) x (l+1)
                    // symmetric block (stored in the lower triangle), and
                    // z[(j, i)] = v_j / h.  Worker j writes only e[j] and
                    // the column-i slot of row j; it reads row j's lower
                    // triangle and column j below the diagonal, none of
                    // which is written here.
                    let zs = SharedMut::new(z.data_mut());
                    let es = SharedMut::new(e);
                    threadpool::par_for(l + 1, grain, |j| unsafe {
                        zs.write(j * n + i, zi[j] / h);
                        // contiguous row part: the fixed 8-lane dot;
                        // strided column part: the same scalar FMA chain
                        // on either backend
                        let zrow_j = zs.slice_ref(j * n, j * n + j + 1);
                        let mut g = microkernel::dot_with(kb, zrow_j, &zi[..=j]);
                        for k in (j + 1)..=l {
                            g = zs.read(k * n + j).mul_add(zi[k], g);
                        }
                        es.write(j, g / h);
                    });
                }
                // f = v' A v / h, accumulated in the serial j order
                let mut f = 0.0;
                for j in 0..=l {
                    f += e[j] * zi[j];
                }
                let hh = f / (h + h);
                for (ej, &zij) in e[..=l].iter_mut().zip(zi) {
                    *ej -= hh * zij;
                }
                // Rank-2 update of the leading block: row j gets
                // z[(j, k)] -= v_j e[k] + e_j v_k for k <= j.  Rows are
                // disjoint chunks; e and zi are read-only by now.
                let rows_per_chunk = (PAR_GRAIN / i).max(1);
                let (lower, _rest) = z.data_mut().split_at_mut(i * n);
                let e_ro: &[f64] = e;
                threadpool::par_chunks_mut(lower, rows_per_chunk * n, |ci, chunk| {
                    let j0 = ci * rows_per_chunk;
                    for (r, row) in chunk.chunks_mut(n).enumerate() {
                        let j = j0 + r;
                        let fj = zi[j];
                        let gj = e_ro[j];
                        microkernel::rank2_sub_with(
                            kb,
                            &mut row[..=j],
                            fj,
                            &e_ro[..=j],
                            gj,
                            &zi[..=j],
                        );
                    }
                });
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    // Transform accumulation, restructured from per-column dot products
    // (stride-N accesses) into two row-streaming sweeps over the leading
    // i x i block (EXPERIMENTS.md §Perf): first g[j] = sum_k z[i][k] z[k][j]
    // accumulated row-by-row, then the rank-1 update z[k][j] -= g[j] z[k][i]
    // applied row-by-row.
    let mut gbuf = vec![0.0f64; n];
    for i in 0..n {
        if d[i] != 0.0 {
            vbuf[..i].copy_from_slice(&z.row(i)[..i]);
            let zi = &vbuf[..i];
            // fixed-shape k-blocks of grain_rows rows: the block layout
            // depends only on the step size i, never on the pool width,
            // so the block-order reduction below is bit-identical at any
            // GPML_THREADS (width 1 walks the same blocks serially).
            // MAX_PARTIAL_BLOCKS caps the partial-sum footprint at large
            // i (the seed's uncapped layout hit blocks ~ i^2/PAR_GRAIN,
            // ~67 MB of partials per step at i = 8192) while staying far
            // above any realistic pool width.
            let grain_rows =
                (PAR_GRAIN / i.max(1)).max(1).max(div_ceil(i.max(1), MAX_PARTIAL_BLOCKS));
            let blocks = div_ceil(i.max(1), grain_rows);
            if blocks <= 1 {
                // one block == the pre-pool serial sweep, bit for bit
                for gj in gbuf[..i].iter_mut() {
                    *gj = 0.0;
                }
                for k in 0..i {
                    let row = &z.data()[k * n..k * n + i];
                    microkernel::fma_axpy_with(kb, &mut gbuf[..i], zi[k], row);
                }
            } else {
                // contiguous k-blocks accumulate private partials (each
                // block row-streams exactly like the serial sweep), then
                // a serial block-order reduction; the hoisted buffer is
                // re-zeroed per block before accumulating
                let plen = blocks * i;
                if partials.len() < plen {
                    partials.resize(plen, 0.0);
                }
                let zd = z.data();
                threadpool::par_chunks_mut(&mut partials[..plen], i, |b, part| {
                    part.fill(0.0);
                    let k0 = b * grain_rows;
                    let k1 = (k0 + grain_rows).min(i);
                    for k in k0..k1 {
                        let row = &zd[k * n..k * n + i];
                        microkernel::fma_axpy_with(kb, part, zi[k], row);
                    }
                });
                for gj in gbuf[..i].iter_mut() {
                    *gj = 0.0;
                }
                for b in 0..blocks {
                    for (gj, &p) in gbuf[..i].iter_mut().zip(&partials[b * i..b * i + i]) {
                        *gj += p;
                    }
                }
            }
            // rank-1 update over disjoint row chunks
            let gb: &[f64] = &gbuf;
            threadpool::par_chunks_mut(&mut z.data_mut()[..i * n], grain_rows * n, |_, chunk| {
                for row in chunk.chunks_mut(n) {
                    let zki = row[i];
                    microkernel::fma_axpy_with(kb, &mut row[..i], -zki, &gb[..i]);
                }
            });
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
}

/// Implicit-shift QL on the tridiagonal (d, e), rotating `z` into the
/// eigenvector matrix; sorts ascending. (Port of EISPACK tql2.)
///
/// Perf (EXPERIMENTS.md §Perf): the Givens rotations update eigenvector
/// *columns*; on the row-major [`Matrix`] that is a stride-N access
/// pattern which dominated the O(N^3) overhead.  The rotations therefore
/// run on a transposed copy (`zt`, one eigenvector per contiguous row) and
/// the result is transposed back — two O(N^2) copies buy cache-linear
/// O(N^3) inner loops (~8x at N=1024).
///
/// Parallelism (DESIGN.md §6): the scalar (d, e, s, c) recurrence never
/// reads `zt`, so each QL sweep records its rotation sequence and applies
/// it afterwards, column-chunked across the pool — every element of `zt`
/// sees the identical rotation order and arithmetic, so the result is
/// bit-identical to the serial interleaved application at any thread
/// count.  The documented cache-linear layout is preserved: workers walk
/// contiguous column segments of the two affected rows per rotation.
pub(crate) fn tql2(z: &mut Matrix, d: &mut [f64], e: &mut [f64]) -> Result<(), NoConvergence> {
    let n = d.len();
    if n == 1 {
        return Ok(());
    }
    // zt[i] (row) == eigenvector i == column i of z
    let mut zt = vec![0.0f64; n * n];
    for r in 0..n {
        for c in 0..n {
            zt[c * n + r] = z[(r, c)];
        }
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    // Absolute deflation floor scaled to the matrix norm: Gram matrices
    // have large clusters of numerically-zero eigenvalues where the
    // relative test (|e| <= eps * (|d_m| + |d_m+1|)) never fires because
    // the cluster's d values are themselves ~eps * ||A||.
    let anorm = d
        .iter()
        .zip(e.iter())
        .map(|(a, b)| a.abs() + b.abs())
        .fold(0.0f64, f64::max)
        .max(f64::MIN_POSITIVE);

    // rotation sequence of one QL sweep, recorded then batch-applied
    let mut rots: Vec<(f64, f64)> = Vec::with_capacity(n);
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find small sub-diagonal element
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                // deflating at |e| <= eps*(dd + anorm) perturbs eigenvalues
                // by at most eps*||A|| (Weyl), the same bound LAPACK's
                // absolute criterion accepts.
                if e[m].abs() <= f64::EPSILON * (dd + anorm) {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                return Err(NoConvergence { eigenvalue_index: l });
            }
            // form shift
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            let mut underflow = false; // NR's `r == 0.0 && i >= l` early break
            rots.clear();
            for i in (l..m).rev() {
                let f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // rotation t acts on zt rows (m-1-t, m-t); recorded here,
                // applied column-chunked below
                rots.push((s, c));
            }
            apply_rotations(&mut zt, n, m, &rots);
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }

    // sort ascending, permuting eigenvector rows of zt
    for i in 0..n - 1 {
        let mut k = i;
        for j in (i + 1)..n {
            if d[j] < d[k] {
                k = j;
            }
        }
        if k != i {
            d.swap(i, k);
            for c in 0..n {
                zt.swap(i * n + c, k * n + c);
            }
        }
    }
    // write back transposed: z column i = zt row i
    for r in 0..n {
        for c in 0..n {
            z[(r, c)] = zt[c * n + r];
        }
    }
    Ok(())
}

/// Apply one QL sweep's rotation sequence to `zt` (row-major, one
/// eigenvector per row): rotation `t` mixes rows `m - 1 - t` and
/// `m - t`.  Columns are independent, so workers own disjoint column
/// ranges and each applies the full sequence in order — identical
/// arithmetic per element, bit-identical across thread counts.
fn apply_rotations(zt: &mut [f64], n: usize, m: usize, rots: &[(f64, f64)]) {
    if rots.is_empty() {
        return;
    }
    // per-column cost is rots.len() rotations; size chunks so one chunk
    // clears the spawn threshold, which also collapses short deflated
    // sweeps to the serial path
    let cols_per_chunk = div_ceil(PAR_GRAIN, rots.len()).min(n).max(1);
    let shared = SharedMut::new(zt);
    threadpool::par_for(div_ceil(n, cols_per_chunk), 1, |ci| {
        let c0 = ci * cols_per_chunk;
        let c1 = (c0 + cols_per_chunk).min(n);
        for (t, &(s, c)) in rots.iter().enumerate() {
            let ri = (m - 1 - t) * n;
            let ri1 = ri + n;
            for col in c0..c1 {
                // Safety: this worker owns columns [c0, c1) of every row.
                unsafe {
                    let zi = shared.read(ri + col);
                    let f = shared.read(ri1 + col);
                    shared.write(ri1 + col, s * zi + c * f);
                    shared.write(ri + col, c * zi - s * f);
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_bt};
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    fn random_sym(rng: &mut Rng, n: usize) -> Matrix {
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.add(&b.t());
        a.scale(0.5);
        a
    }

    fn random_spd(rng: &mut Rng, n: usize) -> Matrix {
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        matmul_bt(&b, &b)
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let a = Matrix::diag(&[3.0, 1.0, 2.0]);
        let eg = SymEigen::new(&a).unwrap();
        assert!((eg.values[0] - 1.0).abs() < 1e-12);
        assert!((eg.values[1] - 2.0).abs() < 1e-12);
        assert!((eg.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let eg = SymEigen::new(&a).unwrap();
        assert!((eg.values[0] - 1.0).abs() < 1e-12);
        assert!((eg.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        let mut rng = Rng::new(8);
        for &n in &[1usize, 2, 3, 10, 33, 64] {
            let a = random_sym(&mut rng, n);
            let eg = SymEigen::new(&a).unwrap();
            assert!(eg.reconstruct().max_abs_diff(&a) < 1e-9, "reconstruct n={n}");
            let utu = matmul(&eg.vectors.t(), &eg.vectors);
            assert!(utu.max_abs_diff(&Matrix::eye(n)) < 1e-10, "orthogonal n={n}");
        }
    }

    #[test]
    fn eigenvalues_ascending() {
        let mut rng = Rng::new(9);
        let a = random_sym(&mut rng, 40);
        let eg = SymEigen::new(&a).unwrap();
        for w in eg.values.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn spd_eigenvalues_nonnegative() {
        let mut rng = Rng::new(10);
        let a = random_spd(&mut rng, 25);
        let eg = SymEigen::new(&a).unwrap();
        assert!(eg.values[0] > -1e-9, "smallest {}", eg.values[0]);
    }

    #[test]
    fn trace_and_det_invariants() {
        let mut rng = Rng::new(11);
        let a = random_spd(&mut rng, 15);
        let eg = SymEigen::new(&a).unwrap();
        let tr: f64 = eg.values.iter().sum();
        assert!((tr - a.trace()).abs() < 1e-9);
        // det via cholesky logdet vs sum of log eigenvalues
        let ld: f64 = eg.values.iter().map(|v| v.ln()).sum();
        let ch = crate::linalg::chol::Cholesky::new(&a).unwrap();
        assert!((ld - ch.logdet()).abs() < 1e-8);
    }

    #[test]
    fn project_roundtrip() {
        let mut rng = Rng::new(12);
        let a = random_sym(&mut rng, 20);
        let eg = SymEigen::new(&a).unwrap();
        let y = rng.normal_vec(20);
        let yt = eg.project(&y);
        let back = eg.back_project(&yt);
        let err: f64 = back.iter().zip(&y).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
        assert!(err < 1e-10);
        // norm preservation (SVD property the paper uses: y~'y~ = y'y)
        let n1: f64 = y.iter().map(|v| v * v).sum();
        let n2: f64 = yt.iter().map(|v| v * v).sum();
        assert!((n1 - n2).abs() < 1e-9);
    }

    #[test]
    fn repeated_eigenvalues_identity() {
        let eg = SymEigen::new(&Matrix::eye(8)).unwrap();
        for v in &eg.values {
            assert!((v - 1.0).abs() < 1e-12);
        }
        assert!(eg.reconstruct().max_abs_diff(&Matrix::eye(8)) < 1e-10);
    }

    #[test]
    fn rank_deficient_matrix() {
        // rank-1: outer product
        let u = [1.0, 2.0, 3.0, 4.0];
        let a = Matrix::from_fn(4, 4, |i, j| u[i] * u[j]);
        let eg = SymEigen::new(&a).unwrap();
        let total: f64 = u.iter().map(|x| x * x).sum();
        assert!((eg.values[3] - total).abs() < 1e-9);
        for v in &eg.values[..3] {
            assert!(v.abs() < 1e-9);
        }
    }

    #[test]
    fn zero_and_one_dimensional_matrices() {
        for solver in [EigenSolver::Dac, EigenSolver::Ql] {
            let eg = SymEigen::new_with(&Matrix::zeros(0, 0), solver).unwrap();
            assert!(eg.values.is_empty());
            assert_eq!(eg.vectors.rows(), 0);
            assert!(eg.project(&[]).is_empty());
            let eg = SymEigen::new_with(&Matrix::diag(&[-3.5]), solver).unwrap();
            assert_eq!(eg.values, vec![-3.5]);
            assert_eq!(eg.vectors[(0, 0)].abs(), 1.0);
        }
    }

    #[test]
    fn already_tridiagonal_input() {
        // tred2 must pass a tridiagonal matrix through (scale == 0 in
        // every Householder step) and both solvers must still decompose it
        for &n in &[2usize, 3, 8, 33, 64] {
            let a = Matrix::from_fn(n, n, |i, j| {
                if i == j {
                    1.0 + 0.3 * i as f64
                } else if i.abs_diff(j) == 1 {
                    0.4 + 0.01 * i.min(j) as f64
                } else {
                    0.0
                }
            });
            for solver in [EigenSolver::Dac, EigenSolver::Ql] {
                let eg = SymEigen::new_with(&a, solver).unwrap();
                assert!(
                    eg.reconstruct().max_abs_diff(&a) < 1e-11,
                    "tridiagonal n={n} {}",
                    solver.as_str()
                );
            }
        }
    }

    #[test]
    fn with_solver_overrides_and_restores() {
        let mut rng = Rng::new(77);
        let a = random_sym(&mut rng, 40);
        let dac = with_solver(EigenSolver::Dac, || SymEigen::new(&a)).unwrap();
        let ql = with_solver(EigenSolver::Ql, || SymEigen::new(&a)).unwrap();
        assert_eq!(dac.values, SymEigen::new_with(&a, EigenSolver::Dac).unwrap().values);
        assert_eq!(ql.values, SymEigen::new_with(&a, EigenSolver::Ql).unwrap().values);
        // nesting restores the outer override
        with_solver(EigenSolver::Ql, || {
            with_solver(EigenSolver::Dac, || {
                assert_eq!(default_solver(), EigenSolver::Dac);
            });
            assert_eq!(default_solver(), EigenSolver::Ql);
        });
        // both agree on the spectrum to oracle accuracy
        let scale = ql.values.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        for (d, q) in dac.values.iter().zip(&ql.values) {
            assert!((d - q).abs() < 1e-12 * scale, "{d} vs {q}");
        }
    }

    #[test]
    fn property_eigen_residual() {
        forall(
            "A u = s u",
            21,
            10,
            |r| {
                let n = 2 + r.below(25);
                random_sym(r, n)
            },
            |a| {
                let n = a.rows();
                let eg = SymEigen::new(a).map_err(|e| e.to_string())?;
                for j in 0..n {
                    let u = eg.vectors.col(j);
                    let au = a.matvec(&u);
                    for i in 0..n {
                        let want = eg.values[j] * u[i];
                        if (au[i] - want).abs() > 1e-8 {
                            return Err(format!(
                                "residual at eigpair {j}, row {i}: {} vs {}",
                                au[i], want
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}

//! Cholesky decomposition and SPD solves — the engine of the naive O(N^3)
//! baseline (paper §1.1): every score evaluation without the spectral
//! identities costs one factorization per hyperparameter iterate.

use super::matrix::Matrix;

/// Lower-triangular Cholesky factor `L` with `L L' = A`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Matrix,
}

/// Errors from the factorization.
#[derive(Debug, PartialEq)]
pub enum CholError {
    NotSquare,
    NotPositiveDefinite { pivot: usize, value: f64 },
}

impl std::fmt::Display for CholError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholError::NotSquare => write!(f, "matrix is not square"),
            CholError::NotPositiveDefinite { pivot, value } => {
                write!(f, "matrix not positive definite (pivot {pivot}: {value:.3e})")
            }
        }
    }
}

impl std::error::Error for CholError {}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix (reads the lower
    /// triangle only).
    pub fn new(a: &Matrix) -> Result<Cholesky, CholError> {
        if !a.is_square() {
            return Err(CholError::NotSquare);
        }
        let n = a.rows();
        let mut l = Matrix::zeros(n, n);
        for j in 0..n {
            // diagonal
            let mut d = a[(j, j)];
            for k in 0..j {
                d -= l[(j, k)] * l[(j, k)];
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(CholError::NotPositiveDefinite { pivot: j, value: d });
            }
            let dj = d.sqrt();
            l[(j, j)] = dj;
            // column below the diagonal
            for i in (j + 1)..n {
                let mut s = a[(i, j)];
                let (ri, rj) = (l.row(i), l.row(j));
                s -= ri[..j].iter().zip(&rj[..j]).map(|(x, y)| x * y).sum::<f64>();
                l[(i, j)] = s / dj;
            }
        }
        Ok(Cholesky { l })
    }

    pub fn l(&self) -> &Matrix {
        &self.l
    }

    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// `log |A| = 2 sum_i log L_ii`.
    pub fn logdet(&self) -> f64 {
        (0..self.n()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// Solve `A x = b` in place (forward then backward substitution).
    pub fn solve_in_place(&self, x: &mut [f64]) {
        let n = self.n();
        assert_eq!(x.len(), n);
        // L y = b
        for i in 0..n {
            let row = self.l.row(i);
            let s: f64 = row[..i].iter().zip(x[..i].iter()).map(|(a, b)| a * b).sum();
            x[i] = (x[i] - s) / row[i];
        }
        // L' x = y
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * x[k];
            }
            x[i] = s / self.l[(i, i)];
        }
    }

    /// Solve `A X = B` column-by-column.
    pub fn solve_mat(&self, b: &Matrix) -> Matrix {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let mut out = Matrix::zeros(n, b.cols());
        for j in 0..b.cols() {
            let mut col = b.col(j);
            self.solve_in_place(&mut col);
            for i in 0..n {
                out[(i, j)] = col[i];
            }
        }
        out
    }

    /// Explicit inverse (used by the naive baseline where the paper's
    /// procedure stores `Sigma_y^{-1}`).
    pub fn inverse(&self) -> Matrix {
        self.solve_mat(&Matrix::eye(self.n()))
    }

    /// Quadratic form `b' A^{-1} b` without materializing the inverse.
    pub fn quad_form(&self, b: &[f64]) -> f64 {
        let n = self.n();
        assert_eq!(b.len(), n);
        // y = L^{-1} b, result = y'y
        let mut y = b.to_vec();
        for i in 0..n {
            let row = self.l.row(i);
            let s: f64 = row[..i].iter().zip(y[..i].iter()).map(|(a, b)| a * b).sum();
            y[i] = (y[i] - s) / row[i];
        }
        y.iter().map(|v| v * v).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_bt};
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    /// Random SPD matrix `B B' + eps I`.
    fn spd(rng: &mut Rng, n: usize) -> Matrix {
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = matmul_bt(&b, &b);
        a.add_diag(0.5);
        a
    }

    #[test]
    fn reconstructs_matrix() {
        let mut rng = Rng::new(1);
        let a = spd(&mut rng, 24);
        let ch = Cholesky::new(&a).unwrap();
        let rec = matmul_bt(ch.l(), ch.l());
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn solve_matches_direct() {
        let mut rng = Rng::new(2);
        let a = spd(&mut rng, 16);
        let x_true = rng.normal_vec(16);
        let b = a.matvec(&x_true);
        let ch = Cholesky::new(&a).unwrap();
        let x = ch.solve(&b);
        let err: f64 = x.iter().zip(&x_true).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
        assert!(err < 1e-9, "err={err}");
    }

    #[test]
    fn logdet_matches_2x2() {
        // A = [[4, 2], [2, 3]] => det = 8
        let a = Matrix::from_vec(2, 2, vec![4.0, 2.0, 2.0, 3.0]);
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.logdet() - 8f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn logdet_of_diag() {
        let a = Matrix::diag(&[1.0, 2.0, 4.0, 8.0]);
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.logdet() - 64f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn quad_form_matches_solve() {
        let mut rng = Rng::new(3);
        let a = spd(&mut rng, 12);
        let b = rng.normal_vec(12);
        let ch = Cholesky::new(&a).unwrap();
        let direct: f64 = b.iter().zip(ch.solve(&b)).map(|(u, v)| u * v).sum();
        assert!((ch.quad_form(&b) - direct).abs() < 1e-9);
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let mut rng = Rng::new(4);
        let a = spd(&mut rng, 10);
        let inv = Cholesky::new(&a).unwrap().inverse();
        assert!(matmul(&a, &inv).max_abs_diff(&Matrix::eye(10)) < 1e-9);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        match Cholesky::new(&a) {
            Err(CholError::NotPositiveDefinite { .. }) => {}
            other => panic!("expected NotPositiveDefinite, got {other:?}"),
        }
    }

    #[test]
    fn rejects_non_square() {
        assert_eq!(Cholesky::new(&Matrix::zeros(2, 3)).unwrap_err(), CholError::NotSquare);
    }

    #[test]
    fn property_solve_residual_small() {
        forall(
            "chol solve residual",
            11,
            15,
            |r| {
                let n = 2 + r.below(30);
                let a = spd(r, n);
                let b = r.normal_vec(n);
                (a, b)
            },
            |(a, b)| {
                let ch = Cholesky::new(a).map_err(|e| e.to_string())?;
                let x = ch.solve(b);
                let r = a.matvec(&x);
                let res: f64 =
                    r.iter().zip(b).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
                if res < 1e-8 {
                    Ok(())
                } else {
                    Err(format!("residual {res}"))
                }
            },
        );
    }
}

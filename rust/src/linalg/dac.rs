//! Cuppen-style divide-and-conquer symmetric tridiagonal eigensolver
//! (DESIGN.md §12) — the post-`tred2` stage that replaces the
//! sequential QL iteration as the default solver.
//!
//! The tridiagonal `T` is torn at its midpoint by a rank-one
//! correction:
//!
//! ```text
//! T = [ T1~  0  ]  +  beta w w',   w = e_{k-1} + e_k,  beta = T[k, k-1]
//!     [ 0   T2~ ]
//! ```
//!
//! where `T1~`/`T2~` are the two halves with `beta` subtracted from
//! their facing diagonal entries.  Each half is solved recursively
//! (leaves at or below [`CROSSOVER`] use the in-repo QL iteration,
//! `eigen::tql2`), and the halves are recombined by projecting `w` into
//! the children's eigenbases — `z = [last row of Q1; first row of Q2]`
//! — which turns the merge into exactly the `diag(d) + beta z z'`
//! problem the shared [`secular`](crate::linalg::secular) machinery
//! already solves for streaming rank-one updates: deflation, pooled
//! per-interval secular bisection, Gu–Eisenstat z-hat, and blocked-GEMM
//! eigenvector back-multiplication.
//!
//! Determinism (DESIGN.md §6, §12): the recursion tree is a pure
//! function of `n` (fixed midpoint split, fixed crossover), children
//! are solved in a fixed order, and every merge fan-out partitions by
//! shape-only grain sizes — results are bit-identical across
//! `GPML_THREADS`, with width 1 running the exact serial path.
//!
//! `tql2` stays available as the full-size solver behind the
//! `GPML_EIGEN=ql` escape hatch and serves as the in-repo oracle for
//! the differential suite (`rust/tests/eigen_dac.rs`).

use super::eigen::{self, NoConvergence, SymEigen};
use super::matrix::Matrix;
use super::secular;

/// Leaf crossover: subproblems at or below this size are solved by one
/// QL iteration instead of recursing.  The value is fixed — never
/// width-, env- or hardware-dependent — so the recursion shape (and
/// therefore the floating-point arithmetic) is identical everywhere.
/// Below it the O(n^3) QL cost is small and the merge bookkeeping
/// dominates; 32 keeps leaves inside one cache tile.
pub(crate) const CROSSOVER: usize = 32;

/// Eigendecomposition of the symmetric tridiagonal `(d, sub)` where
/// `d` is the diagonal (length n) and `sub` the sub-diagonal (length
/// n-1, `sub[i] = T[i+1, i]`).  Returns the [`SymEigen`] convention:
/// ascending eigenvalues, orthogonal columns.
pub(crate) fn solve_tridiag(d: &[f64], sub: &[f64]) -> Result<SymEigen, NoConvergence> {
    debug_assert_eq!(sub.len(), d.len().saturating_sub(1), "sub-diagonal length");
    solve_rec(d, sub, 0)
}

/// `base` is the offset of this subproblem within the original matrix,
/// used only to report a meaningful index on `NoConvergence`.
fn solve_rec(d: &[f64], sub: &[f64], base: usize) -> Result<SymEigen, NoConvergence> {
    let n = d.len();
    if n <= CROSSOVER {
        return ql_leaf(d, sub, base);
    }
    let k = n / 2;
    let beta = sub[k - 1];
    // rank-one tear: subtract beta from the two facing diagonal entries
    // so T = diag(T1~, T2~) + beta w w' exactly, for beta of any sign
    let mut d1 = d[..k].to_vec();
    let mut d2 = d[k..].to_vec();
    d1[k - 1] -= beta;
    d2[0] -= beta;
    // children in fixed order; parallelism comes from each merge's
    // pooled fan-outs, not from racing the two subtrees (DESIGN.md §12)
    let left = solve_rec(&d1, &sub[..k - 1], base)?;
    let right = solve_rec(&d2, &sub[k..], base + k)?;
    #[cfg(feature = "fault-inject")]
    if crate::faults::inject::fire(crate::faults::inject::FaultPoint::DacMergeNoConvergence) {
        return Err(NoConvergence { eigenvalue_index: base + k });
    }
    Ok(merge(&left, &right, beta))
}

/// Recombine two child decompositions across the rank-one tear.
///
/// In the permuted basis `Q = diag(Q1, Q2) P` (columns sorted so the
/// merged child spectrum ascends; ties take the left child first — a
/// fixed, width-independent order) the torn matrix is
/// `diag(dm) + beta zm zm'` with `zm` drawn from the last row of `Q1`
/// and the first row of `Q2`.  `beta = 0` (a decoupled tridiagonal)
/// short-circuits inside `merge_spectrum`: the sorted union of the
/// child spectra with the permuted block-diagonal basis is already the
/// exact answer.
fn merge(left: &SymEigen, right: &SymEigen, beta: f64) -> SymEigen {
    let k = left.values.len();
    let m = right.values.len();
    let n = k + m;
    // two-pointer merge of the two ascending spectra
    let mut perm: Vec<usize> = Vec::with_capacity(n);
    let (mut i, mut j) = (0, 0);
    while i < k && j < m {
        if left.values[i] <= right.values[j] {
            perm.push(i);
            i += 1;
        } else {
            perm.push(k + j);
            j += 1;
        }
    }
    while i < k {
        perm.push(i);
        i += 1;
    }
    while j < m {
        perm.push(k + j);
        j += 1;
    }

    let mut dm = Vec::with_capacity(n);
    let mut zm = Vec::with_capacity(n);
    let mut basis = Matrix::zeros(n, n);
    for (col, &src) in perm.iter().enumerate() {
        if src < k {
            dm.push(left.values[src]);
            zm.push(left.vectors[(k - 1, src)]);
            for r in 0..k {
                basis[(r, col)] = left.vectors[(r, src)];
            }
        } else {
            let s = src - k;
            dm.push(right.values[s]);
            zm.push(right.vectors[(0, s)]);
            for r in 0..m {
                basis[(k + r, col)] = right.vectors[(r, s)];
            }
        }
    }
    secular::merge_spectrum(&dm, zm, beta, basis)
}

/// Solve a leaf with the QL iteration on an identity accumulator.
fn ql_leaf(d: &[f64], sub: &[f64], base: usize) -> Result<SymEigen, NoConvergence> {
    let n = d.len();
    if n == 0 {
        return Ok(SymEigen { values: vec![], vectors: Matrix::zeros(0, 0) });
    }
    let mut dd = d.to_vec();
    // tql2 reads the sub-diagonal from e[1..] (tred2's layout)
    let mut e = vec![0.0; n];
    e[1..].copy_from_slice(sub);
    let mut z = Matrix::eye(n);
    eigen::tql2(&mut z, &mut dd, &mut e)
        .map_err(|err| NoConvergence { eigenvalue_index: base + err.eigenvalue_index })?;
    Ok(SymEigen { values: dd, vectors: z })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;

    /// Dense tridiagonal for reference checks.
    fn dense_tridiag(d: &[f64], sub: &[f64]) -> Matrix {
        let n = d.len();
        Matrix::from_fn(n, n, |i, j| {
            if i == j {
                d[i]
            } else if i == j + 1 {
                sub[j]
            } else if j == i + 1 {
                sub[i]
            } else {
                0.0
            }
        })
    }

    /// Deterministic wiggly tridiagonal (no RNG needed).
    fn test_problem(n: usize) -> (Vec<f64>, Vec<f64>) {
        let d: Vec<f64> =
            (0..n).map(|i| (i as f64 * 0.7).sin() * 2.0 + 0.1 * i as f64).collect();
        let sub: Vec<f64> =
            (0..n.saturating_sub(1)).map(|i| (i as f64 * 1.3).cos() * 0.8 + 0.05).collect();
        (d, sub)
    }

    fn assert_solves(n: usize) {
        let (d, sub) = test_problem(n);
        let a = dense_tridiag(&d, &sub);
        let got = solve_tridiag(&d, &sub).unwrap();
        let want = ql_leaf(&d, &sub, 0).unwrap();
        let scale = got.values.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        for (g, w) in got.values.iter().zip(&want.values) {
            assert!((g - w).abs() < 1e-12 * scale, "n={n}: {g} vs {w}");
        }
        assert!(got.reconstruct().max_abs_diff(&a) < 1e-11 * scale, "n={n} reconstruct");
        let utu = matmul(&got.vectors.t(), &got.vectors);
        assert!(utu.max_abs_diff(&Matrix::eye(n)) < 1e-11, "n={n} orthogonality");
    }

    #[test]
    fn matches_ql_around_the_crossover() {
        for n in [1, 2, 3, 31, 32, 33, 48, 64, 65] {
            assert_solves(n);
        }
    }

    #[test]
    fn at_or_below_crossover_is_the_ql_path_bitwise() {
        let (d, sub) = test_problem(CROSSOVER);
        let dac = solve_tridiag(&d, &sub).unwrap();
        let ql = ql_leaf(&d, &sub, 0).unwrap();
        assert_eq!(dac.values, ql.values);
        assert_eq!(dac.vectors.data(), ql.vectors.data());
    }

    #[test]
    fn zero_coupling_at_the_split_point() {
        // sub[k-1] = 0: the tear is a no-op (beta = 0) and the merge
        // must return the exact sorted union of the decoupled blocks
        let n = 2 * CROSSOVER;
        let (d, mut sub) = test_problem(n);
        sub[n / 2 - 1] = 0.0;
        let a = dense_tridiag(&d, &sub);
        let got = solve_tridiag(&d, &sub).unwrap();
        let scale = got.values.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        assert!(got.reconstruct().max_abs_diff(&a) < 1e-11 * scale);
        for w in got.values.windows(2) {
            assert!(w[0] <= w[1], "not ascending across decoupled blocks");
        }
    }

    #[test]
    fn empty_problem() {
        let eg = solve_tridiag(&[], &[]).unwrap();
        assert!(eg.values.is_empty());
        assert_eq!(eg.vectors.rows(), 0);
    }
}

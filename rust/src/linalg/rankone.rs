//! Symmetric rank-one eigendecomposition update — the streaming
//! counterpart of the paper's one-time O(N^3) overhead (DESIGN.md §8).
//!
//! Given `A = U diag(d) U'` and a correction `A + rho v v'`, the updated
//! decomposition is recovered without re-tridiagonalizing: project
//! `z = U' v` (the update in the eigenbasis, O(N^2)), then hand the
//! resulting `diag(d) + rho z z'` problem to the shared
//! [`secular`](crate::linalg::secular) merge machinery — amplitude and
//! cluster deflation, per-interval secular bisection fanned across the
//! scoped pool, Gu–Eisenstat z-hat, and the surviving-columns basis
//! rotation as one blocked GEMM (O(N k^2) with k typically far below N
//! after deflation; kernel Gram matrices' numerically-zero eigenvalue
//! clusters deflate almost entirely).  The same machinery drives the
//! divide-and-conquer tridiagonal solver's merge step (`linalg/dac.rs`).
//!
//! The result is ascending-sorted like [`SymEigen::new`].  Accuracy is
//! O(eps * ||A|| + |rho| ||v||^2) per update; callers that chain many
//! updates (the streaming `extend` path) monitor [`ortho_drift`] and
//! fall back to a full refit when it crosses their tolerance
//! (DESIGN.md §8's fallback policy).

use super::eigen::SymEigen;
use super::secular;

/// Eigendecomposition of `A + rho v v'` from the decomposition of `A`.
///
/// `v` must have length `eigen.values.len()`.  `rho = 0` (or `v = 0`)
/// returns a clone.  Output follows the [`SymEigen`] convention:
/// ascending eigenvalues, orthogonal columns.
pub fn rank_one_update(eigen: &SymEigen, v: &[f64], rho: f64) -> SymEigen {
    let n = eigen.values.len();
    assert_eq!(v.len(), n, "update vector length != decomposition size");
    if n == 0 {
        return eigen.clone();
    }
    let z = eigen.project(v);
    let zz: f64 = z.iter().map(|x| x * x).sum();
    if rho == 0.0 || zz == 0.0 {
        return eigen.clone();
    }
    secular::merge_spectrum(&eigen.values, z, rho, eigen.vectors.clone())
}

/// Cheap orthogonality probe: max over a deterministic sample of column
/// pairs of `|u_i . u_j|` (off-diagonal) and `|1 - u_i . u_i|`
/// (normalization).  An exact decomposition scores ~N*eps; streaming
/// callers refit when this drifts past their tolerance (DESIGN.md §8).
pub fn ortho_drift(eigen: &SymEigen, samples: usize) -> f64 {
    let n = eigen.values.len();
    if n == 0 {
        return 0.0;
    }
    let samples = samples.clamp(1, n);
    let stride = (n / samples).max(1);
    let u = &eigen.vectors;
    let mut worst = 0.0f64;
    let mut c = 0;
    while c < n {
        let mut dot_self = 0.0;
        let mut dot_next = 0.0;
        let next = (c + stride) % n;
        for r in 0..n {
            let a = u[(r, c)];
            dot_self += a * a;
            if next != c {
                dot_next += a * u[(r, next)];
            }
        }
        worst = worst.max((1.0 - dot_self).abs()).max(dot_next.abs());
        c += stride;
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;
    use crate::linalg::gemm::matmul_bt;
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Rng;
    use crate::util::threadpool::with_threads;

    fn random_sym(rng: &mut Rng, n: usize) -> Matrix {
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.add(&b.t());
        a.scale(0.5);
        a
    }

    /// `A + rho v v'` densely.
    fn updated_dense(a: &Matrix, v: &[f64], rho: f64) -> Matrix {
        let n = a.rows();
        Matrix::from_fn(n, n, |i, j| a[(i, j)] + rho * v[i] * v[j])
    }

    fn assert_is_eigen_of(eg: &SymEigen, a: &Matrix, tol: f64) {
        let n = a.rows();
        // ascending
        for w in eg.values.windows(2) {
            assert!(w[0] <= w[1] + tol, "not ascending: {} > {}", w[0], w[1]);
        }
        // reconstruction
        assert!(
            eg.reconstruct().max_abs_diff(a) < tol,
            "reconstruction off by {} (n={n})",
            eg.reconstruct().max_abs_diff(a)
        );
        // orthogonality
        let utu = gemm::matmul(&eg.vectors.t(), &eg.vectors);
        assert!(
            utu.max_abs_diff(&Matrix::eye(n)) < tol,
            "orthogonality off by {}",
            utu.max_abs_diff(&Matrix::eye(n))
        );
    }

    #[test]
    fn matches_dense_reference_both_signs() {
        let mut rng = Rng::new(41);
        for &n in &[2usize, 3, 5, 16, 40] {
            for &rho in &[1.0, -1.0, 0.35, -2.5] {
                let a = random_sym(&mut rng, n);
                let v = rng.normal_vec(n);
                let eg = SymEigen::new(&a).unwrap();
                let up = rank_one_update(&eg, &v, rho);
                let dense = updated_dense(&a, &v, rho);
                let scale = 1.0 + dense.fro_norm();
                assert_is_eigen_of(&up, &dense, 1e-9 * scale);
                // eigenvalues agree with a from-scratch decomposition
                let want = SymEigen::new(&dense).unwrap();
                for (got, want) in up.values.iter().zip(&want.values) {
                    assert!(
                        (got - want).abs() < 1e-9 * scale,
                        "n={n} rho={rho}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn zero_rho_and_zero_vector_are_identity() {
        let mut rng = Rng::new(5);
        let a = random_sym(&mut rng, 10);
        let eg = SymEigen::new(&a).unwrap();
        let v = rng.normal_vec(10);
        let same = rank_one_update(&eg, &v, 0.0);
        assert_eq!(same.values, eg.values);
        let same = rank_one_update(&eg, &vec![0.0; 10], 1.0);
        assert_eq!(same.values, eg.values);
    }

    #[test]
    fn identity_plus_outer_product() {
        // I + rho vv' has eigenvalue 1 (n-1 fold) and 1 + rho ||v||^2:
        // exercises the repeated-eigenvalue cluster deflation
        let n = 12;
        let mut rng = Rng::new(7);
        let v = rng.normal_vec(n);
        let vv: f64 = v.iter().map(|x| x * x).sum();
        let eg = SymEigen::new(&Matrix::eye(n)).unwrap();
        let up = rank_one_update(&eg, &v, 0.5);
        for val in &up.values[..n - 1] {
            assert!((val - 1.0).abs() < 1e-10, "cluster eigenvalue {val}");
        }
        assert!((up.values[n - 1] - (1.0 + 0.5 * vv)).abs() < 1e-10);
        assert_is_eigen_of(&up, &updated_dense(&Matrix::eye(n), &v, 0.5), 1e-10);
    }

    #[test]
    fn sparse_update_vector_deflates() {
        // v aligned with few eigenvectors: most components deflate and
        // the untouched eigenpairs pass through bitwise
        let mut rng = Rng::new(9);
        let a = Matrix::diag(&(0..20).map(|i| i as f64).collect::<Vec<_>>());
        let eg = SymEigen::new(&a).unwrap();
        let mut v = vec![0.0; 20];
        v[3] = rng.normal();
        v[11] = rng.normal();
        let up = rank_one_update(&eg, &v, 1.0);
        assert_is_eigen_of(&up, &updated_dense(&a, &v, 1.0), 1e-10 * 20.0);
    }

    #[test]
    fn interlacing_holds() {
        let mut rng = Rng::new(13);
        let a = random_sym(&mut rng, 25);
        let eg = SymEigen::new(&a).unwrap();
        let v = rng.normal_vec(25);
        let up = rank_one_update(&eg, &v, 1.0);
        // rho > 0: d_i <= s_i <= d_{i+1} (up to deflation tolerance)
        let slack = 1e-9 * (1.0 + a.fro_norm());
        for i in 0..25 {
            assert!(up.values[i] >= eg.values[i] - slack);
            if i + 1 < 25 {
                assert!(up.values[i] <= eg.values[i + 1] + slack);
            }
        }
        // trace identity: tr(A + vv') = tr A + v'v
        let vv: f64 = v.iter().map(|x| x * x).sum();
        let tr_up: f64 = up.values.iter().sum();
        let tr: f64 = eg.values.iter().sum();
        assert!((tr_up - tr - vv).abs() < 1e-9 * (1.0 + tr.abs() + vv));
    }

    #[test]
    fn gram_like_spectrum_update() {
        // the streaming regime: PSD matrix with a decaying spectrum and
        // a numerically rank-deficient tail
        let mut rng = Rng::new(17);
        let n = 30;
        let b = Matrix::from_fn(n, 8, |_, _| rng.normal());
        let a = matmul_bt(&b, &b); // rank 8
        let eg = SymEigen::new(&a).unwrap();
        let v = rng.normal_vec(n);
        let up = rank_one_update(&eg, &v, 1.0);
        let dense = updated_dense(&a, &v, 1.0);
        assert_is_eigen_of(&up, &dense, 1e-8 * (1.0 + dense.fro_norm()));
    }

    #[test]
    fn bit_identical_across_pool_widths() {
        let mut rng = Rng::new(23);
        let a = random_sym(&mut rng, 33);
        let eg = SymEigen::new(&a).unwrap();
        let v = rng.normal_vec(33);
        let serial = with_threads(1, || rank_one_update(&eg, &v, 1.0));
        let pooled = with_threads(4, || rank_one_update(&eg, &v, 1.0));
        assert_eq!(serial.values, pooled.values);
        assert_eq!(serial.vectors.data(), pooled.vectors.data());
    }

    #[test]
    fn ortho_drift_small_for_exact_and_large_for_broken() {
        let mut rng = Rng::new(29);
        let a = random_sym(&mut rng, 20);
        let eg = SymEigen::new(&a).unwrap();
        assert!(ortho_drift(&eg, 8) < 1e-12);
        let mut broken = eg.clone();
        for r in 0..20 {
            broken.vectors[(r, 3)] *= 1.5;
        }
        assert!(ortho_drift(&broken, 20) > 0.1);
    }

    #[test]
    fn one_by_one_matrix() {
        let eg = SymEigen::new(&Matrix::diag(&[2.0])).unwrap();
        let up = rank_one_update(&eg, &[3.0], 1.0);
        assert!((up.values[0] - 11.0).abs() < 1e-12);
    }
}

//! Symmetric rank-one eigendecomposition update — the streaming
//! counterpart of the paper's one-time O(N^3) overhead (DESIGN.md §8).
//!
//! Given `A = U diag(d) U'` and a correction `A + rho v v'`, the updated
//! decomposition is recovered without re-tridiagonalizing:
//!
//! 1. project `z = U' v` (the update in the eigenbasis), O(N^2);
//! 2. **deflate**: components with negligible `|z_i|` keep their
//!    eigenpair verbatim, and (near-)equal eigenvalues are merged by
//!    Givens rotations that concentrate their `z` mass into one
//!    representative per cluster (the rotated-out partners deflate) —
//!    this is what makes streaming updates cheap on kernel Gram
//!    matrices, whose numerically-zero eigenvalue clusters deflate
//!    almost entirely;
//! 3. solve the **secular equation** `1 + rho * sum_i z_i^2/(d_i - s) = 0`
//!    once per surviving interval (monotone in each interval, so a
//!    safeguarded bisection in pole-relative coordinates cannot miss),
//!    intervals fanned out across the scoped pool;
//! 4. recompute the update vector a la Gu–Eisenstat from the solved
//!    eigenvalues (`z_hat`), which restores numerical orthogonality of
//!    the new eigenvectors even for tightly-spaced spectra;
//! 5. rotate: each new eigenvector is `U_k w_j` with
//!    `w_j(i) = z_hat_i / (d_i - s_j)`, assembled for all survivors as
//!    one blocked [`gemm`] product over the k surviving columns —
//!    O(N k^2), with k typically far below N after step 2.
//!
//! The result is ascending-sorted like [`SymEigen::new`].  Accuracy is
//! O(eps * ||A|| + |rho| ||v||^2) per update; callers that chain many
//! updates (the streaming `extend` path) monitor [`ortho_drift`] and
//! fall back to a full refit when it crosses their tolerance
//! (DESIGN.md §8's fallback policy).

use super::eigen::SymEigen;
use super::matrix::Matrix;
use crate::linalg::gemm;
use crate::util::threadpool::{self, SharedMut};

/// Minimum per-worker multiply-add units before the secular solves /
/// z-hat recomputations fan out (same policy as `linalg/eigen`).
const PAR_GRAIN: usize = 1 << 14;

/// One solved secular root, kept in pole-relative form: the eigenvalue is
/// `d[base] + offset` where `d[base]` is the closest pole.  Differences
/// `d_i - lambda` are then computed as `(d_i - d[base]) - offset`, which
/// never cancels catastrophically — the two addends are exact data.
#[derive(Clone, Copy, Debug)]
struct Root {
    base: usize,
    offset: f64,
}

impl Root {
    #[inline]
    fn value(&self, d: &[f64]) -> f64 {
        d[self.base] + self.offset
    }
    /// `d[i] - lambda`, cancellation-safe.
    #[inline]
    fn pole_gap(&self, d: &[f64], i: usize) -> f64 {
        if i == self.base {
            -self.offset
        } else {
            (d[i] - d[self.base]) - self.offset
        }
    }
}

/// Eigendecomposition of `A + rho v v'` from the decomposition of `A`.
///
/// `v` must have length `eigen.values.len()`.  `rho = 0` (or `v = 0`)
/// returns a clone.  Output follows the [`SymEigen`] convention:
/// ascending eigenvalues, orthogonal columns.
pub fn rank_one_update(eigen: &SymEigen, v: &[f64], rho: f64) -> SymEigen {
    let n = eigen.values.len();
    assert_eq!(v.len(), n, "update vector length != decomposition size");
    if n == 0 {
        return eigen.clone();
    }
    let z = eigen.project(v);
    let zz: f64 = z.iter().map(|x| x * x).sum();
    if rho == 0.0 || zz == 0.0 {
        return eigen.clone();
    }

    let d = &eigen.values;
    // Perturbation scale: deflating a component of size z_i perturbs the
    // matrix by at most 2|rho||z_i|sqrt(zz); dropping a cluster's
    // off-diagonal perturbs by at most the cluster gap.  Both thresholds
    // come from the same norm estimate (Weyl).
    let anorm = d
        .iter()
        .fold(0.0f64, |m, x| m.max(x.abs()))
        .max(rho.abs() * zz)
        .max(f64::MIN_POSITIVE);
    let tol = 8.0 * f64::EPSILON * anorm;

    // --- step 2: deflation ---------------------------------------------
    // Rotations mutate working copies; the original eigen is only read.
    let mut zw = z;
    let mut vectors = eigen.vectors.clone();
    let z_floor = tol / (2.0 * rho.abs() * zz.sqrt());
    let mut survivors: Vec<usize> = (0..n).filter(|&i| zw[i].abs() > z_floor).collect();

    // cluster deflation: adjacent surviving poles closer than tol are
    // merged — rotate the earlier component's mass into the later one
    // (exact when the eigenvalues are equal, O(tol) otherwise)
    if survivors.len() >= 2 {
        let mut merged: Vec<usize> = Vec::with_capacity(survivors.len());
        let mut head = survivors[0];
        for &next in &survivors[1..] {
            if d[next] - d[head] <= tol {
                let (zh, zn) = (zw[head], zw[next]);
                let r = zh.hypot(zn);
                let (c, s) = (zn / r, zh / r);
                zw[head] = 0.0;
                zw[next] = r;
                rotate_columns(&mut vectors, head, next, c, s);
                // `head` deflates with its eigenvalue unchanged
            } else {
                merged.push(head);
            }
            head = next;
        }
        merged.push(head);
        survivors = merged;
    }

    let k = survivors.len();
    if k == 0 {
        // the update was numerically invisible
        return SymEigen { values: d.clone(), vectors };
    }

    let ds: Vec<f64> = survivors.iter().map(|&i| d[i]).collect();
    let zs: Vec<f64> = survivors.iter().map(|&i| zw[i]).collect();
    let zzs: f64 = zs.iter().map(|x| x * x).sum();

    // --- step 3: secular roots ------------------------------------------
    let roots = if k == 1 {
        vec![Root { base: 0, offset: rho * zzs }]
    } else if rho > 0.0 {
        solve_secular(&ds, &zs, rho)
    } else {
        // eig(A + rho vv') = -eig(-A + (-rho) vv'): flip sign and order,
        // solve the positive problem, map the roots back
        let df: Vec<f64> = ds.iter().rev().map(|x| -x).collect();
        let zf: Vec<f64> = zs.iter().rev().copied().collect();
        let flipped = solve_secular(&df, &zf, -rho);
        (0..k)
            .map(|j| {
                let r = flipped[k - 1 - j];
                Root { base: k - 1 - r.base, offset: -r.offset }
            })
            .collect()
    };

    // --- step 4: Gu–Eisenstat z-hat --------------------------------------
    // |z_hat_i|^2 = prod_j (s_j - d_i) / (rho * prod_{j != i} (d_j - d_i));
    // the ratio is positive by interlacing, so it is accumulated in log
    // magnitude (products of k factors of wildly varying scale would
    // otherwise over/underflow) and signed from the original z.
    let ln_rho = rho.abs().ln();
    let zhat: Vec<f64> = threadpool::par_map(
        &(0..k).collect::<Vec<usize>>(),
        (PAR_GRAIN / (2 * k).max(1)).max(1),
        |&i| {
            let mut acc = -ln_rho;
            for (j, r) in roots.iter().enumerate() {
                acc += r.pole_gap(&ds, i).abs().ln();
                if j != i {
                    acc -= (ds[j] - ds[i]).abs().ln();
                }
            }
            (0.5 * acc).exp().copysign(zs[i])
        },
    );

    // --- step 5: eigenvectors --------------------------------------------
    // w_j(i) = z_hat_i / (d_i - s_j), normalized; survivors-only basis
    // rotation Q = U_k W as one blocked GEMM (N x k by k x k).
    let mut w = Matrix::zeros(k, k);
    {
        let shared = SharedMut::new(w.data_mut());
        threadpool::par_for(k, (PAR_GRAIN / (2 * k).max(1)).max(1), |j| {
            let r = &roots[j];
            let mut col = vec![0.0f64; k];
            let mut norm2 = 0.0;
            for i in 0..k {
                let wi = zhat[i] / r.pole_gap(&ds, i);
                norm2 += wi * wi;
                col[i] = wi;
            }
            let inv = 1.0 / norm2.sqrt();
            for (i, wi) in col.into_iter().enumerate() {
                // Safety: worker j writes only column j.
                unsafe { shared.write(i * k + j, wi * inv) };
            }
        });
    }
    let mut u_sub = Matrix::zeros(n, k);
    for (jj, &col) in survivors.iter().enumerate() {
        for i in 0..n {
            u_sub[(i, jj)] = vectors[(i, col)];
        }
    }
    let q = gemm::matmul(&u_sub, &w);

    // --- assemble + sort ascending ---------------------------------------
    // pair each output eigenvalue with its column source: deflated
    // columns pass through (possibly cluster-rotated), survivors take the
    // rotated columns of q
    enum Src {
        Old(usize),
        New(usize),
    }
    let mut pairs: Vec<(f64, Src)> = Vec::with_capacity(n);
    let survivor_set: Vec<bool> = {
        let mut m = vec![false; n];
        for &i in &survivors {
            m[i] = true;
        }
        m
    };
    for i in 0..n {
        if !survivor_set[i] {
            pairs.push((d[i], Src::Old(i)));
        }
    }
    for (j, r) in roots.iter().enumerate() {
        pairs.push((r.value(&ds), Src::New(j)));
    }
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    let mut values = Vec::with_capacity(n);
    let mut out = Matrix::zeros(n, n);
    for (col, (val, src)) in pairs.into_iter().enumerate() {
        values.push(val);
        match src {
            Src::Old(c) => {
                for i in 0..n {
                    out[(i, col)] = vectors[(i, c)];
                }
            }
            Src::New(j) => {
                for i in 0..n {
                    out[(i, col)] = q[(i, j)];
                }
            }
        }
    }
    SymEigen { values, vectors: out }
}

/// Givens rotation of eigenvector columns `i` and `j`:
/// `u_i <- c u_i - s u_j`, `u_j <- s u_i + c u_j`.
fn rotate_columns(u: &mut Matrix, i: usize, j: usize, c: f64, s: f64) {
    let n = u.rows();
    for r in 0..n {
        let (a, b) = (u[(r, i)], u[(r, j)]);
        u[(r, i)] = c * a - s * b;
        u[(r, j)] = s * a + c * b;
    }
}

/// Roots of `1 + rho * sum_i z_i^2 / (d_i - s) = 0` for `rho > 0`,
/// `d` strictly ascending (post-deflation), all `z_i != 0`, `k >= 2`.
/// Root `j` lies in `(d_j, d_{j+1})` (last: `(d_{k-1}, d_{k-1} + rho z'z)`).
///
/// Each interval solve picks the closer pole as origin (decided by the
/// secular function's sign at the midpoint) and bisects in pole-relative
/// coordinates — the function is strictly increasing on the interval, so
/// bisection converges unconditionally to f64 fixpoint.  Intervals are
/// independent and fan out across the pool with serial-identical
/// arithmetic (bit-identical across widths).
fn solve_secular(d: &[f64], z: &[f64], rho: f64) -> Vec<Root> {
    let k = d.len();
    let zz: f64 = z.iter().map(|x| x * x).sum();
    let js: Vec<usize> = (0..k).collect();
    // ~60-120 g() evaluations of O(k) each per interval
    let grain = (PAR_GRAIN / (128 * k)).max(1);
    threadpool::par_map(&js, grain, |&j| {
        // g(t) = 1 + rho sum_i z_i^2 / (delta_i - t), origin-relative
        let g = |origin: usize, t: f64| -> f64 {
            let mut acc = 1.0;
            for i in 0..k {
                let delta = if i == origin { 0.0 } else { d[i] - d[origin] };
                acc += rho * z[i] * z[i] / (delta - t);
            }
            acc
        };
        let (origin, mut lo, mut hi) = if j + 1 < k {
            let gap = d[j + 1] - d[j];
            // g just right of d_j is -inf, just left of d_{j+1} is +inf;
            // the midpoint sign picks the closer pole as origin
            if g(j, 0.5 * gap) >= 0.0 {
                (j, 0.0, 0.5 * gap)
            } else {
                (j + 1, -0.5 * gap, 0.0)
            }
        } else {
            // last interval: upper bound d_{k-1} + rho z'z is not a pole
            (j, 0.0, rho * zz)
        };
        // invariant: g(lo) < 0 <= g(hi) (limits at the open endpoints)
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if mid == lo || mid == hi {
                break;
            }
            if g(origin, mid) < 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        // return the side strictly inside the interval, so the offset is
        // never exactly 0 (which would alias the pole in step 5)
        let t = if origin == j && lo == 0.0 {
            hi
        } else if origin == j + 1 && hi == 0.0 {
            lo
        } else {
            0.5 * (lo + hi)
        };
        Root { base: origin, offset: t }
    })
}

/// Cheap orthogonality probe: max over a deterministic sample of column
/// pairs of `|u_i . u_j|` (off-diagonal) and `|1 - u_i . u_i|`
/// (normalization).  An exact decomposition scores ~N*eps; streaming
/// callers refit when this drifts past their tolerance (DESIGN.md §8).
pub fn ortho_drift(eigen: &SymEigen, samples: usize) -> f64 {
    let n = eigen.values.len();
    if n == 0 {
        return 0.0;
    }
    let samples = samples.clamp(1, n);
    let stride = (n / samples).max(1);
    let u = &eigen.vectors;
    let mut worst = 0.0f64;
    let mut c = 0;
    while c < n {
        let mut dot_self = 0.0;
        let mut dot_next = 0.0;
        let next = (c + stride) % n;
        for r in 0..n {
            let a = u[(r, c)];
            dot_self += a * a;
            if next != c {
                dot_next += a * u[(r, next)];
            }
        }
        worst = worst.max((1.0 - dot_self).abs()).max(dot_next.abs());
        c += stride;
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_bt;
    use crate::util::rng::Rng;
    use crate::util::threadpool::with_threads;

    fn random_sym(rng: &mut Rng, n: usize) -> Matrix {
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.add(&b.t());
        a.scale(0.5);
        a
    }

    /// `A + rho v v'` densely.
    fn updated_dense(a: &Matrix, v: &[f64], rho: f64) -> Matrix {
        let n = a.rows();
        Matrix::from_fn(n, n, |i, j| a[(i, j)] + rho * v[i] * v[j])
    }

    fn assert_is_eigen_of(eg: &SymEigen, a: &Matrix, tol: f64) {
        let n = a.rows();
        // ascending
        for w in eg.values.windows(2) {
            assert!(w[0] <= w[1] + tol, "not ascending: {} > {}", w[0], w[1]);
        }
        // reconstruction
        assert!(
            eg.reconstruct().max_abs_diff(a) < tol,
            "reconstruction off by {} (n={n})",
            eg.reconstruct().max_abs_diff(a)
        );
        // orthogonality
        let utu = gemm::matmul(&eg.vectors.t(), &eg.vectors);
        assert!(
            utu.max_abs_diff(&Matrix::eye(n)) < tol,
            "orthogonality off by {}",
            utu.max_abs_diff(&Matrix::eye(n))
        );
    }

    #[test]
    fn matches_dense_reference_both_signs() {
        let mut rng = Rng::new(41);
        for &n in &[2usize, 3, 5, 16, 40] {
            for &rho in &[1.0, -1.0, 0.35, -2.5] {
                let a = random_sym(&mut rng, n);
                let v = rng.normal_vec(n);
                let eg = SymEigen::new(&a).unwrap();
                let up = rank_one_update(&eg, &v, rho);
                let dense = updated_dense(&a, &v, rho);
                let scale = 1.0 + dense.fro_norm();
                assert_is_eigen_of(&up, &dense, 1e-9 * scale);
                // eigenvalues agree with a from-scratch decomposition
                let want = SymEigen::new(&dense).unwrap();
                for (got, want) in up.values.iter().zip(&want.values) {
                    assert!(
                        (got - want).abs() < 1e-9 * scale,
                        "n={n} rho={rho}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn zero_rho_and_zero_vector_are_identity() {
        let mut rng = Rng::new(5);
        let a = random_sym(&mut rng, 10);
        let eg = SymEigen::new(&a).unwrap();
        let v = rng.normal_vec(10);
        let same = rank_one_update(&eg, &v, 0.0);
        assert_eq!(same.values, eg.values);
        let same = rank_one_update(&eg, &vec![0.0; 10], 1.0);
        assert_eq!(same.values, eg.values);
    }

    #[test]
    fn identity_plus_outer_product() {
        // I + rho vv' has eigenvalue 1 (n-1 fold) and 1 + rho ||v||^2:
        // exercises the repeated-eigenvalue cluster deflation
        let n = 12;
        let mut rng = Rng::new(7);
        let v = rng.normal_vec(n);
        let vv: f64 = v.iter().map(|x| x * x).sum();
        let eg = SymEigen::new(&Matrix::eye(n)).unwrap();
        let up = rank_one_update(&eg, &v, 0.5);
        for val in &up.values[..n - 1] {
            assert!((val - 1.0).abs() < 1e-10, "cluster eigenvalue {val}");
        }
        assert!((up.values[n - 1] - (1.0 + 0.5 * vv)).abs() < 1e-10);
        assert_is_eigen_of(&up, &updated_dense(&Matrix::eye(n), &v, 0.5), 1e-10);
    }

    #[test]
    fn sparse_update_vector_deflates() {
        // v aligned with few eigenvectors: most components deflate and
        // the untouched eigenpairs pass through bitwise
        let mut rng = Rng::new(9);
        let a = Matrix::diag(&(0..20).map(|i| i as f64).collect::<Vec<_>>());
        let eg = SymEigen::new(&a).unwrap();
        let mut v = vec![0.0; 20];
        v[3] = rng.normal();
        v[11] = rng.normal();
        let up = rank_one_update(&eg, &v, 1.0);
        assert_is_eigen_of(&up, &updated_dense(&a, &v, 1.0), 1e-10 * 20.0);
    }

    #[test]
    fn interlacing_holds() {
        let mut rng = Rng::new(13);
        let a = random_sym(&mut rng, 25);
        let eg = SymEigen::new(&a).unwrap();
        let v = rng.normal_vec(25);
        let up = rank_one_update(&eg, &v, 1.0);
        // rho > 0: d_i <= s_i <= d_{i+1} (up to deflation tolerance)
        let slack = 1e-9 * (1.0 + a.fro_norm());
        for i in 0..25 {
            assert!(up.values[i] >= eg.values[i] - slack);
            if i + 1 < 25 {
                assert!(up.values[i] <= eg.values[i + 1] + slack);
            }
        }
        // trace identity: tr(A + vv') = tr A + v'v
        let vv: f64 = v.iter().map(|x| x * x).sum();
        let tr_up: f64 = up.values.iter().sum();
        let tr: f64 = eg.values.iter().sum();
        assert!((tr_up - tr - vv).abs() < 1e-9 * (1.0 + tr.abs() + vv));
    }

    #[test]
    fn gram_like_spectrum_update() {
        // the streaming regime: PSD matrix with a decaying spectrum and
        // a numerically rank-deficient tail
        let mut rng = Rng::new(17);
        let n = 30;
        let b = Matrix::from_fn(n, 8, |_, _| rng.normal());
        let a = matmul_bt(&b, &b); // rank 8
        let eg = SymEigen::new(&a).unwrap();
        let v = rng.normal_vec(n);
        let up = rank_one_update(&eg, &v, 1.0);
        let dense = updated_dense(&a, &v, 1.0);
        assert_is_eigen_of(&up, &dense, 1e-8 * (1.0 + dense.fro_norm()));
    }

    #[test]
    fn bit_identical_across_pool_widths() {
        let mut rng = Rng::new(23);
        let a = random_sym(&mut rng, 33);
        let eg = SymEigen::new(&a).unwrap();
        let v = rng.normal_vec(33);
        let serial = with_threads(1, || rank_one_update(&eg, &v, 1.0));
        let pooled = with_threads(4, || rank_one_update(&eg, &v, 1.0));
        assert_eq!(serial.values, pooled.values);
        assert_eq!(serial.vectors.data(), pooled.vectors.data());
    }

    #[test]
    fn ortho_drift_small_for_exact_and_large_for_broken() {
        let mut rng = Rng::new(29);
        let a = random_sym(&mut rng, 20);
        let eg = SymEigen::new(&a).unwrap();
        assert!(ortho_drift(&eg, 8) < 1e-12);
        let mut broken = eg.clone();
        for r in 0..20 {
            broken.vectors[(r, 3)] *= 1.5;
        }
        assert!(ortho_drift(&broken, 20) > 0.1);
    }

    #[test]
    fn one_by_one_matrix() {
        let eg = SymEigen::new(&Matrix::diag(&[2.0])).unwrap();
        let up = rank_one_update(&eg, &[3.0], 1.0);
        assert!((up.values[0] - 11.0).abs() < 1e-12);
    }
}

//! Dense row-major `f64` matrix — the storage type every substrate in the
//! repo builds on (no external linear-algebra crates in this image).

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { rows, cols, data }
    }

    /// Diagonal matrix from a vector.
    pub fn diag(d: &[f64]) -> Self {
        let mut m = Matrix::zeros(d.len(), d.len());
        for (i, &x) in d.iter().enumerate() {
            m[(i, i)] = x;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }
    pub fn data(&self) -> &[f64] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column `j` as an owned vector (strided copy).
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transpose (copy).
    pub fn t(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Main diagonal.
    pub fn diagonal(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).collect()
    }

    /// Trace.
    pub fn trace(&self) -> f64 {
        self.diagonal().iter().sum()
    }

    /// Matrix-vector product `A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec dimension mismatch");
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Transposed matrix-vector product `A' x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "matvec_t dimension mismatch");
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += a * xi;
            }
        }
        out
    }

    /// `self + alpha * I` in place.
    pub fn add_diag(&mut self, alpha: f64) {
        assert!(self.is_square());
        for i in 0..self.rows {
            self[(i, i)] += alpha;
        }
    }

    /// Elementwise scale in place.
    pub fn scale(&mut self, alpha: f64) {
        for x in &mut self.data {
            *x *= alpha;
        }
    }

    /// `self + other` (allocating).
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// `self - other` (allocating).
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Symmetrize in place: `(A + A')/2` (guards eigensolver inputs against
    /// accumulation asymmetry).
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    /// Top-left `r x c` block (for un-padding bucketed artifact outputs).
    pub fn top_left(&self, r: usize, c: usize) -> Matrix {
        assert!(r <= self.rows && c <= self.cols);
        Matrix::from_fn(r, c, |i, j| self[(i, j)])
    }

    /// Row/column selection (for Nystrom inducing subsets).
    pub fn select(&self, row_idx: &[usize], col_idx: &[usize]) -> Matrix {
        Matrix::from_fn(row_idx.len(), col_idx.len(), |i, j| self[(row_idx[i], col_idx[j])])
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(6);
        for i in 0..show {
            let cols = self.cols.min(8);
            let cells: Vec<String> =
                (0..cols).map(|j| format!("{:>10.4}", self[(i, j)])).collect();
            writeln!(
                f,
                "  {}{}",
                cells.join(" "),
                if self.cols > 8 { " ..." } else { "" }
            )?;
        }
        if self.rows > show {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

/// Dot product of two slices.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `y += alpha * x`.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut m = Matrix::zeros(3, 4);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0, 0.0]);
        assert_eq!(m.col(2), vec![0.0, 5.0, 0.0]);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_fn(3, 5, |i, j| (i * 7 + j) as f64);
        assert_eq!(m.t().t(), m);
        assert_eq!(m.t()[(4, 2)], m[(2, 4)]);
    }

    #[test]
    fn matvec_identity() {
        let m = Matrix::eye(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(m.matvec(&x), x);
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let m = Matrix::from_fn(3, 5, |i, j| (i + 2 * j) as f64);
        let x = vec![1.0, -1.0, 2.0];
        assert_eq!(m.matvec_t(&x), m.t().matvec(&x));
    }

    #[test]
    fn trace_and_diag() {
        let m = Matrix::diag(&[1.0, 2.0, 3.0]);
        assert_eq!(m.trace(), 6.0);
        assert_eq!(m.diagonal(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn add_sub_fro() {
        let a = Matrix::from_fn(2, 2, |i, j| (i + j) as f64);
        let b = Matrix::eye(2);
        let c = a.add(&b).sub(&a);
        assert!((c.max_abs_diff(&b)) < 1e-15);
        assert!((Matrix::eye(3).fro_norm() - 3f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn symmetrize_makes_symmetric() {
        let mut m = Matrix::from_fn(4, 4, |i, j| (3 * i + j) as f64);
        m.symmetrize();
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(m[(i, j)], m[(j, i)]);
            }
        }
    }

    #[test]
    fn select_block() {
        let m = Matrix::from_fn(5, 5, |i, j| (10 * i + j) as f64);
        let s = m.select(&[1, 3], &[0, 4]);
        assert_eq!(s[(0, 0)], 10.0);
        assert_eq!(s[(1, 1)], 34.0);
        let tl = m.top_left(2, 3);
        assert_eq!(tl[(1, 2)], 12.0);
    }

    #[test]
    #[should_panic(expected = "matvec dimension mismatch")]
    fn matvec_dim_check() {
        Matrix::zeros(2, 3).matvec(&[1.0, 2.0]);
    }
}

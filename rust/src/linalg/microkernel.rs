//! Fixed-lane SIMD microkernel layer for the O(N^3) setup path
//! (DESIGN.md §14).
//!
//! Every super-linear setup kernel — packed-panel GEMM, the fixed 8-lane
//! dot, the broadcast-FMA axpy/rank-2 sweeps, and the RBF
//! squared-distance + exp row kernel — is implemented twice: an AVX2/FMA
//! path (`std::arch::x86_64`, behind runtime feature detection) and a
//! portable scalar path.  Both execute the **identical per-element
//! floating-point op sequence**:
//!
//!  * every multiply-add is a single correctly-rounded fused op —
//!    `_mm256_fmadd_pd` on the SIMD path, [`f64::mul_add`] on the scalar
//!    path (IEEE 754 `fusedMultiplyAdd`; one rounding in both);
//!  * every reduction runs through the same fixed 8-lane accumulator
//!    tree: element `i` lands in lane `i mod 8` and the lanes collapse
//!    in the fixed shape `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`,
//!    regardless of vector width, tail length, or backend;
//!  * the GEMM microkernel keeps each output element a pure FMA chain
//!    over `k` in ascending order (the 4x8 register tile reorders rows
//!    and columns, never the `k` reduction), so its canonical semantics
//!    are exactly the naive `mul_add` triple loop.
//!
//! Results are therefore **bitwise identical** across backends — the
//! extension of the repo's determinism policy (DESIGN.md §6) from
//! "independent of pool width" to "independent of ISA".  Backend
//! selection mirrors `GPML_EIGEN`: the `GPML_KERNEL` environment
//! variable (`auto`/`simd`/`scalar`, resolved once per process) plus the
//! scoped thread-local override [`with_kernel_backend`].  Entry points
//! in `gemm`/`kernelfn`/`eigen` resolve the backend **once on the
//! calling thread** and capture it into their pool closures, so the
//! override survives the fan-out.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Rows per GEMM register tile (broadcast operand).
pub const MR: usize = 4;
/// Columns per GEMM register tile / packed B panel width (two 4-wide
/// vector registers).
pub const NR: usize = 8;
/// Lanes in the fixed accumulator tree of [`dot`].
pub const LANES: usize = 8;
/// `k`-depth of one packed slab (A tile: 8 KiB, L1-resident).
const KC: usize = 256;
/// Column width of one packed B slab (`KC x NC` = 1 MiB, L2-resident).
const NC: usize = 512;

// ---------------------------------------------------------------------
// Backend dispatch (the GPML_EIGEN pattern: env cache + scoped override)
// ---------------------------------------------------------------------

/// Which implementation the microkernels execute.  Both produce bitwise
/// identical results (see the module docs); the choice is purely a
/// throughput matter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelBackend {
    /// Runtime-detected AVX2+FMA vector path (x86-64 only).
    Simd,
    /// Portable scalar path (`f64::mul_add` everywhere the SIMD path
    /// fuses).
    Scalar,
}

impl KernelBackend {
    /// Stable label, matching the accepted `GPML_KERNEL` values.
    pub fn as_str(self) -> &'static str {
        match self {
            KernelBackend::Simd => "simd",
            KernelBackend::Scalar => "scalar",
        }
    }
}

/// Whether the SIMD backend can actually run here (x86-64 with AVX2 and
/// FMA detected at runtime).  When this is `false`, requesting
/// [`KernelBackend::Simd`] — via env or override — resolves to the
/// scalar path, which computes the same bits.
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

// Encoding shared by the env cache and the thread-local override:
// 0 = unset, 1 = Simd, 2 = Scalar.
const BACKEND_UNSET: usize = 0;
const BACKEND_SIMD: usize = 1;
const BACKEND_SCALAR: usize = 2;

fn env_backend() -> KernelBackend {
    static CACHE: AtomicUsize = AtomicUsize::new(BACKEND_UNSET);
    match CACHE.load(Ordering::Relaxed) {
        BACKEND_SIMD => return KernelBackend::Simd,
        BACKEND_SCALAR => return KernelBackend::Scalar,
        _ => {}
    }
    let backend = match std::env::var("GPML_KERNEL") {
        Ok(v) if v.eq_ignore_ascii_case("scalar") => KernelBackend::Scalar,
        // "simd", "auto", anything else, unset: vectorize when the
        // hardware can — the two backends are bitwise identical, so
        // auto-selection never changes results.
        _ if simd_available() => KernelBackend::Simd,
        _ => KernelBackend::Scalar,
    };
    let code = if backend == KernelBackend::Simd { BACKEND_SIMD } else { BACKEND_SCALAR };
    CACHE.store(code, Ordering::Relaxed);
    backend
}

thread_local! {
    static LOCAL_BACKEND: Cell<usize> = const { Cell::new(BACKEND_UNSET) };
}

/// The backend microkernel entry points on this thread will execute: the
/// innermost [`with_kernel_backend`] override if one is active, else the
/// process-wide `GPML_KERNEL` choice (default: SIMD when available).
/// Never returns [`KernelBackend::Simd`] on hardware that cannot run it.
pub fn default_kernel_backend() -> KernelBackend {
    let requested = match LOCAL_BACKEND.with(Cell::get) {
        BACKEND_SIMD => KernelBackend::Simd,
        BACKEND_SCALAR => KernelBackend::Scalar,
        _ => env_backend(),
    };
    if requested == KernelBackend::Simd && !simd_available() {
        KernelBackend::Scalar
    } else {
        requested
    }
}

/// Run `f` with every microkernel dispatch on this thread pinned to
/// `backend`, restoring the previous choice on exit (panic-safe; nests).
/// Thread-local, like [`crate::linalg::eigen::with_solver`]: the
/// `gemm`/`kernelfn`/`eigen` entry points resolve the backend on the
/// calling thread *before* fanning out, so pooled work dispatched inside
/// `f` stays pinned; work handed to other threads that dispatches
/// independently sees the env default.
pub fn with_kernel_backend<R>(backend: KernelBackend, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            LOCAL_BACKEND.with(|c| c.set(self.0));
        }
    }
    let code = if backend == KernelBackend::Simd { BACKEND_SIMD } else { BACKEND_SCALAR };
    let _restore = Restore(LOCAL_BACKEND.with(|c| c.replace(code)));
    f()
}

// ---------------------------------------------------------------------
// Canonical scalar kernels: the op-sequence contract both backends meet
// ---------------------------------------------------------------------

/// Collapse the 8-lane accumulators after folding any tail (< 8
/// elements; element `t` of the tail continues lane `t`'s chain) —
/// the one fixed reduction tree every dot product in the repo reduces
/// through, shared verbatim by both backends.
#[inline(always)]
fn lanes_finish(mut acc: [f64; LANES], xt: &[f64], yt: &[f64]) -> f64 {
    for (l, (&xv, &yv)) in xt.iter().zip(yt).enumerate() {
        acc[l] = xv.mul_add(yv, acc[l]);
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

#[inline(always)]
fn dot_scalar(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n8 = x.len() - x.len() % LANES;
    let mut acc = [0.0f64; LANES];
    let mut i = 0;
    while i < n8 {
        for (l, a) in acc.iter_mut().enumerate() {
            *a = x[i + l].mul_add(y[i + l], *a);
        }
        i += LANES;
    }
    lanes_finish(acc, &x[n8..], &y[n8..])
}

#[inline(always)]
fn axpy_scalar(dst: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(dst.len(), x.len());
    for (d, &xv) in dst.iter_mut().zip(x) {
        *d = a.mul_add(xv, *d);
    }
}

/// `dst[i] -= f * e[i] + g * z[i]`, canonically
/// `dst[i] = fma(-f, e[i], fma(-g, z[i], dst[i]))` (tred2's rank-2 row
/// update).
#[inline(always)]
fn rank2_scalar(dst: &mut [f64], f: f64, e: &[f64], g: f64, z: &[f64]) {
    debug_assert_eq!(dst.len(), e.len());
    debug_assert_eq!(dst.len(), z.len());
    let (nf, ng) = (-f, -g);
    for (i, d) in dst.iter_mut().enumerate() {
        *d = nf.mul_add(e[i], ng.mul_add(z[i], *d));
    }
}

// --- fixed-sequence exp -----------------------------------------------

/// Cutoffs: below `EXP_LO` the result underflows past the smallest
/// normal scale the bit-built `2^n` can represent, so both backends
/// return exactly `0.0`; at or above `EXP_HI` they return `+inf`.
const EXP_LO: f64 = -708.0;
const EXP_HI: f64 = 709.0;
/// `1.5 * 2^52`: adding it pushes the integer part of `x * log2(e)`
/// into the mantissa's low bits, rounding ties-to-even in the process —
/// the round-to-nearest trick shared by both backends (valid for
/// |value| < 2^51, far beyond the cutoffs above).
const EXP_MAGIC: f64 = 6755399441055744.0;
const EXP_MAGIC_BITS: i64 = 0x4338000000000000;
/// ln(2) split: the high part's low mantissa bits are zero, so
/// `n * LN2_HI` is exact for the `|n| <= 1075` range in play.
#[allow(clippy::excessive_precision)]
const EXP_LN2_HI: f64 = 6.93147180369123816490e-1; // 0x3FE62E42FEE00000
#[allow(clippy::excessive_precision)]
const EXP_LN2_LO: f64 = 1.90821492927058770002e-10; // 0x3DEA39EF35793C76
/// Taylor coefficients 1/12! .. 1/0! (Horner order).  Over the reduced
/// range |r| <= ln(2)/2 the truncation error is ~r^13/13! < 2e-16
/// relative — a correctly-rounded-to-~1-ulp exp, and (the property that
/// matters here) the *same* ~1-ulp value from both backends.
const EXP_POLY: [f64; 13] = [
    1.0 / 479001600.0,
    1.0 / 39916800.0,
    1.0 / 3628800.0,
    1.0 / 362880.0,
    1.0 / 40320.0,
    1.0 / 5040.0,
    1.0 / 720.0,
    1.0 / 120.0,
    1.0 / 24.0,
    1.0 / 6.0,
    0.5,
    1.0,
    1.0,
];

#[inline(always)]
fn exp_scalar(x: f64) -> f64 {
    if x < EXP_LO {
        return 0.0;
    }
    if x >= EXP_HI {
        return f64::INFINITY;
    }
    let t = x.mul_add(std::f64::consts::LOG2_E, EXP_MAGIC);
    let n = t - EXP_MAGIC;
    let n_i = (t.to_bits() as i64).wrapping_sub(EXP_MAGIC_BITS);
    let mut r = (-n).mul_add(EXP_LN2_HI, x);
    r = (-n).mul_add(EXP_LN2_LO, r);
    let mut q = EXP_POLY[0];
    for &c in &EXP_POLY[1..] {
        q = q.mul_add(r, c);
    }
    // 2^n assembled directly in the exponent field (n is in [-1021, 1023]
    // between the cutoffs, so the biased exponent stays normal)
    let scale = f64::from_bits(((n_i + 1023) << 52) as u64);
    q * scale
}

/// The deterministic exponential the RBF gram fast path applies —
/// `exp(x)` to ~1 ulp over `x <= 0` (the gram feeds only non-positive
/// arguments; the full supported domain is `[-inf, 709)` with underflow
/// to exactly `0.0` below -708).  `exp_fixed(0.0) == 1.0` exactly, and
/// `exp_fixed(x) <= 1.0` for every `x <= 0` — the Gram diagonal/bound
/// invariants hold by construction.  Bitwise identical on both backends;
/// exposed so the determinism gates can build references against it.
pub fn exp_fixed(x: f64) -> f64 {
    exp_scalar(x)
}

/// Squared-norm FMA chain `sum_d x[d]^2`, accumulated element by element
/// — deliberately *not* the 8-lane tree: it matches the per-element
/// ascending-`d` chain the gram fast path builds its inner products
/// with (rank-p [`fma_axpy_with`] over the transposed inputs), so
/// the diagonal `d2(i,i) = (sq_i + sq_i) - 2 t_ii` cancels to exactly
/// `0.0` and the gram diagonal is exactly `1.0`.
#[inline]
pub fn sq_chain(x: &[f64]) -> f64 {
    let mut s = 0.0f64;
    for &v in x {
        s = v.mul_add(v, s);
    }
    s
}

/// `t[j] = exp(((sq_i + sq[j]) - 2 t[j]).max(0) * neg_inv)` — the
/// combine + exp pass that turns accumulated inner products into RBF
/// kernel values.  The clamp guards the expansion's cancellation (d2 is
/// mathematically >= 0) so `k <= 1` survives; `neg_inv = -1/(2 xi^2)` is
/// computed once by the caller.
#[inline(always)]
fn rbf_finish_scalar(t: &mut [f64], sqi: f64, sq: &[f64], neg_inv: f64) {
    debug_assert_eq!(t.len(), sq.len());
    for (tj, &sqj) in t.iter_mut().zip(sq) {
        let d2 = (-2.0f64).mul_add(*tj, sqi + sqj);
        let d2 = if d2 > 0.0 { d2 } else { 0.0 };
        *tj = exp_scalar(d2 * neg_inv);
    }
}

// --- GEMM: packing + the canonical tile kernel -------------------------

/// Pack an up-to-MR-row sliver of A for one `k` slab: `apack[kk*MR + r]`
/// holds `A[row0 + r][k0 + kk]`, rows past `mrb` zero-filled (the tile
/// kernels never read them; the zeros are defensive).
#[inline]
fn pack_a(apack: &mut [f64], ad: &[f64], k: usize, row0: usize, mrb: usize, k0: usize, kcb: usize) {
    for kk in 0..kcb {
        let dst = &mut apack[kk * MR..kk * MR + MR];
        for (r, slot) in dst.iter_mut().enumerate() {
            *slot = if r < mrb { ad[(row0 + r) * k + k0 + kk] } else { 0.0 };
        }
    }
}

/// Pack a `kcb x ncb` slab of B into NR-wide panels: panel `p` occupies
/// `bpack[p*kcb*NR ..][.. kcb*NR]` with layout `kk*NR + j` — the
/// microkernel streams it linearly.  Tail columns zero-fill.
#[inline]
fn pack_b(bpack: &mut [f64], bd: &[f64], n: usize, k0: usize, kcb: usize, jc: usize, ncb: usize) {
    let npanels = crate::util::threadpool::div_ceil(ncb, NR);
    for p in 0..npanels {
        let j0 = jc + p * NR;
        let nrb = NR.min(jc + ncb - j0);
        let panel = &mut bpack[p * kcb * NR..(p + 1) * kcb * NR];
        for kk in 0..kcb {
            let src = &bd[(k0 + kk) * n + j0..(k0 + kk) * n + j0 + nrb];
            let dst = &mut panel[kk * NR..kk * NR + NR];
            dst[..nrb].copy_from_slice(src);
            dst[nrb..].fill(0.0);
        }
    }
}

/// The canonical tile kernel: `C[r0+r][c0+j] +=` the ascending-`kk` FMA
/// chain over the packed slab, for `r < mrb`, `j < nrb`.  Independent
/// per-element chains (interleaved across `j` for ILP, which cannot
/// change any chain's rounding).  The SIMD 4x8 kernel computes exactly
/// this for full tiles; this function handles both backends' edge tiles
/// and the whole scalar backend.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn tile_scalar(
    apack: &[f64],
    bpanel: &[f64],
    kcb: usize,
    c: &mut [f64],
    r0: usize,
    c0: usize,
    n: usize,
    mrb: usize,
    nrb: usize,
) {
    for r in 0..mrb {
        let crow = &mut c[(r0 + r) * n + c0..(r0 + r) * n + c0 + nrb];
        let mut acc = [0.0f64; NR];
        acc[..nrb].copy_from_slice(crow);
        for kk in 0..kcb {
            let a = apack[kk * MR + r];
            let brow = &bpanel[kk * NR..kk * NR + NR];
            for (j, slot) in acc.iter_mut().enumerate() {
                *slot = a.mul_add(brow[j], *slot);
            }
        }
        crow.copy_from_slice(&acc[..nrb]);
    }
}

// ---------------------------------------------------------------------
// AVX2/FMA backend.  Every function computes the canonical op sequence
// above with 4-wide vector ops: vfmadd213pd lane l == mul_add on the
// same operands, so equality is per-op IEEE semantics, not scheduling
// luck.  Scalar tails run *inside* the target_feature fns (mul_add
// inlines to vfmadd) and are the same code both backends run.
// ---------------------------------------------------------------------
#[cfg(target_arch = "x86_64")]
mod simd {
    use super::*;
    use core::arch::x86_64::*;

    /// Safety: caller must have verified AVX2+FMA (all call sites
    /// dispatch through `default_kernel_backend`, which only yields
    /// `Simd` when `simd_available()`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(x: &[f64], y: &[f64]) -> f64 {
        let n8 = x.len() - x.len() % LANES;
        let mut a0 = _mm256_setzero_pd();
        let mut a1 = _mm256_setzero_pd();
        let (xp, yp) = (x.as_ptr(), y.as_ptr());
        let mut i = 0;
        while i < n8 {
            a0 = _mm256_fmadd_pd(_mm256_loadu_pd(xp.add(i)), _mm256_loadu_pd(yp.add(i)), a0);
            a1 = _mm256_fmadd_pd(
                _mm256_loadu_pd(xp.add(i + 4)),
                _mm256_loadu_pd(yp.add(i + 4)),
                a1,
            );
            i += LANES;
        }
        let mut acc = [0.0f64; LANES];
        _mm256_storeu_pd(acc.as_mut_ptr(), a0);
        _mm256_storeu_pd(acc.as_mut_ptr().add(4), a1);
        lanes_finish(acc, &x[n8..], &y[n8..])
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(dst: &mut [f64], a: f64, x: &[f64]) {
        let n4 = dst.len() - dst.len() % 4;
        let av = _mm256_set1_pd(a);
        let (dp, xp) = (dst.as_mut_ptr(), x.as_ptr());
        let mut i = 0;
        while i < n4 {
            let d = _mm256_loadu_pd(dp.add(i));
            let xv = _mm256_loadu_pd(xp.add(i));
            _mm256_storeu_pd(dp.add(i), _mm256_fmadd_pd(av, xv, d));
            i += 4;
        }
        axpy_scalar(&mut dst[n4..], a, &x[n4..]);
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn rank2(dst: &mut [f64], f: f64, e: &[f64], g: f64, z: &[f64]) {
        let n4 = dst.len() - dst.len() % 4;
        let nf = _mm256_set1_pd(-f);
        let ng = _mm256_set1_pd(-g);
        let (dp, ep, zp) = (dst.as_mut_ptr(), e.as_ptr(), z.as_ptr());
        let mut i = 0;
        while i < n4 {
            let d = _mm256_loadu_pd(dp.add(i));
            let inner = _mm256_fmadd_pd(ng, _mm256_loadu_pd(zp.add(i)), d);
            _mm256_storeu_pd(dp.add(i), _mm256_fmadd_pd(nf, _mm256_loadu_pd(ep.add(i)), inner));
            i += 4;
        }
        rank2_scalar(&mut dst[n4..], f, &e[n4..], g, &z[n4..]);
    }

    /// 4-lane exp, op-for-op the sequence of `exp_scalar`.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp4(x: __m256d) -> __m256d {
        let lo = _mm256_cmp_pd::<_CMP_LT_OQ>(x, _mm256_set1_pd(EXP_LO));
        let hi = _mm256_cmp_pd::<_CMP_GE_OQ>(x, _mm256_set1_pd(EXP_HI));
        let log2e = _mm256_set1_pd(std::f64::consts::LOG2_E);
        let t = _mm256_fmadd_pd(x, log2e, _mm256_set1_pd(EXP_MAGIC));
        let n = _mm256_sub_pd(t, _mm256_set1_pd(EXP_MAGIC));
        let n_i = _mm256_sub_epi64(_mm256_castpd_si256(t), _mm256_set1_epi64x(EXP_MAGIC_BITS));
        // -n is an exact sign flip, matching the scalar unary negation
        let nn = _mm256_xor_pd(n, _mm256_set1_pd(-0.0));
        let mut r = _mm256_fmadd_pd(nn, _mm256_set1_pd(EXP_LN2_HI), x);
        r = _mm256_fmadd_pd(nn, _mm256_set1_pd(EXP_LN2_LO), r);
        let mut q = _mm256_set1_pd(EXP_POLY[0]);
        for &c in &EXP_POLY[1..] {
            q = _mm256_fmadd_pd(q, r, _mm256_set1_pd(c));
        }
        let scale_bits =
            _mm256_slli_epi64::<52>(_mm256_add_epi64(n_i, _mm256_set1_epi64x(1023)));
        let res = _mm256_mul_pd(q, _mm256_castsi256_pd(scale_bits));
        let res = _mm256_blendv_pd(res, _mm256_set1_pd(f64::INFINITY), hi);
        _mm256_andnot_pd(lo, res)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn rbf_finish(t: &mut [f64], sqi: f64, sq: &[f64], neg_inv: f64) {
        let n4 = t.len() - t.len() % 4;
        let sqi_v = _mm256_set1_pd(sqi);
        let m2 = _mm256_set1_pd(-2.0);
        let ni = _mm256_set1_pd(neg_inv);
        let zero = _mm256_setzero_pd();
        let (tp, sp) = (t.as_mut_ptr(), sq.as_ptr());
        let mut i = 0;
        while i < n4 {
            let tv = _mm256_loadu_pd(tp.add(i));
            let s = _mm256_add_pd(sqi_v, _mm256_loadu_pd(sp.add(i)));
            let d2 = _mm256_fmadd_pd(m2, tv, s);
            // max(d2, 0): maxpd returns the second operand on NaN, same
            // as the scalar `if d2 > 0.0 { d2 } else { 0.0 }`
            let d2 = _mm256_max_pd(d2, zero);
            _mm256_storeu_pd(tp.add(i), exp4(_mm256_mul_pd(d2, ni)));
            i += 4;
        }
        rbf_finish_scalar(&mut t[n4..], sqi, &sq[n4..], neg_inv);
    }

    /// Full 4x8 register tile: 8 accumulator registers loaded from C,
    /// one FMA chain over the packed slab in ascending `kk`, stored
    /// back.  Same per-element chain as `tile_scalar` with mrb=4, nrb=8.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn tile_4x8(
        apack: *const f64,
        bpanel: *const f64,
        kcb: usize,
        c: *mut f64,
        ldc: usize,
    ) {
        let mut c0l = _mm256_loadu_pd(c);
        let mut c0h = _mm256_loadu_pd(c.add(4));
        let mut c1l = _mm256_loadu_pd(c.add(ldc));
        let mut c1h = _mm256_loadu_pd(c.add(ldc + 4));
        let mut c2l = _mm256_loadu_pd(c.add(2 * ldc));
        let mut c2h = _mm256_loadu_pd(c.add(2 * ldc + 4));
        let mut c3l = _mm256_loadu_pd(c.add(3 * ldc));
        let mut c3h = _mm256_loadu_pd(c.add(3 * ldc + 4));
        for kk in 0..kcb {
            let bl = _mm256_loadu_pd(bpanel.add(kk * NR));
            let bh = _mm256_loadu_pd(bpanel.add(kk * NR + 4));
            let a0 = _mm256_set1_pd(*apack.add(kk * MR));
            c0l = _mm256_fmadd_pd(a0, bl, c0l);
            c0h = _mm256_fmadd_pd(a0, bh, c0h);
            let a1 = _mm256_set1_pd(*apack.add(kk * MR + 1));
            c1l = _mm256_fmadd_pd(a1, bl, c1l);
            c1h = _mm256_fmadd_pd(a1, bh, c1h);
            let a2 = _mm256_set1_pd(*apack.add(kk * MR + 2));
            c2l = _mm256_fmadd_pd(a2, bl, c2l);
            c2h = _mm256_fmadd_pd(a2, bh, c2h);
            let a3 = _mm256_set1_pd(*apack.add(kk * MR + 3));
            c3l = _mm256_fmadd_pd(a3, bl, c3l);
            c3h = _mm256_fmadd_pd(a3, bh, c3h);
        }
        _mm256_storeu_pd(c, c0l);
        _mm256_storeu_pd(c.add(4), c0h);
        _mm256_storeu_pd(c.add(ldc), c1l);
        _mm256_storeu_pd(c.add(ldc + 4), c1h);
        _mm256_storeu_pd(c.add(2 * ldc), c2l);
        _mm256_storeu_pd(c.add(2 * ldc + 4), c2h);
        _mm256_storeu_pd(c.add(3 * ldc), c3l);
        _mm256_storeu_pd(c.add(3 * ldc + 4), c3h);
    }

    /// Edge tiles on the SIMD backend: the canonical scalar kernel, but
    /// compiled under the target features so `mul_add` inlines to
    /// hardware FMA.  Same ops, same bits.
    #[target_feature(enable = "avx2,fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn tile_edge(
        apack: &[f64],
        bpanel: &[f64],
        kcb: usize,
        c: &mut [f64],
        r0: usize,
        c0: usize,
        n: usize,
        mrb: usize,
        nrb: usize,
    ) {
        tile_scalar(apack, bpanel, kcb, c, r0, c0, n, mrb, nrb);
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn sq_chain_tf(x: &[f64]) -> f64 {
        super::sq_chain(x)
    }
}

// ---------------------------------------------------------------------
// Dispatching entry points
// ---------------------------------------------------------------------

/// Fixed-8-lane dot product: element `i` accumulates into lane
/// `i mod 8` (FMA), lanes collapse through the fixed pairwise tree.
/// Bitwise identical on both backends and for any slicing of the call
/// across threads (it is a pure function of its inputs).
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    dot_with(default_kernel_backend(), x, y)
}

/// [`dot`] with an explicit backend (entry points resolve once and pass
/// it down so scoped overrides survive pool fan-out).
pub fn dot_with(backend: KernelBackend, x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "dot length mismatch");
    match backend {
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Simd => unsafe { simd::dot(x, y) },
        _ => dot_scalar(x, y),
    }
}

/// `dst[i] = fma(a, x[i], dst[i])` — the broadcast-FMA axpy all rank-1
/// accumulation sweeps (ata, tred2 transform accumulation, RBF distance
/// build) run on.
pub fn fma_axpy_with(backend: KernelBackend, dst: &mut [f64], a: f64, x: &[f64]) {
    assert_eq!(dst.len(), x.len(), "axpy length mismatch");
    match backend {
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Simd => unsafe { simd::axpy(dst, a, x) },
        _ => axpy_scalar(dst, a, x),
    }
}

/// `dst[i] = fma(-f, e[i], fma(-g, z[i], dst[i]))` — tred2's rank-2 row
/// update.
pub fn rank2_sub_with(
    backend: KernelBackend,
    dst: &mut [f64],
    f: f64,
    e: &[f64],
    g: f64,
    z: &[f64],
) {
    assert_eq!(dst.len(), e.len(), "rank2 length mismatch");
    assert_eq!(dst.len(), z.len(), "rank2 length mismatch");
    match backend {
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Simd => unsafe { simd::rank2(dst, f, e, g, z) },
        _ => rank2_scalar(dst, f, e, g, z),
    }
}

/// `sq_chain` under the ambient-backend target features (bits are
/// backend-independent; the SIMD wrapper only buys inlined FMA).
pub fn sq_chain_with(backend: KernelBackend, x: &[f64]) -> f64 {
    match backend {
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Simd => unsafe { simd::sq_chain_tf(x) },
        _ => sq_chain(x),
    }
}

/// One row segment of an RBF/ARD gram: `out` must arrive holding the
/// accumulated inner products `t[j] = <x_i, x_j>` (built with
/// [`fma_axpy_with`] over the transposed inputs); this combines them
/// with the squared norms and applies the fixed exp —
/// `out[j] = exp(max((sq_i + sq[j]) - 2 t[j], 0) * neg_inv)`.
pub fn rbf_finish_with(
    backend: KernelBackend,
    out: &mut [f64],
    sqi: f64,
    sq: &[f64],
    neg_inv: f64,
) {
    assert_eq!(out.len(), sq.len(), "rbf_finish length mismatch");
    match backend {
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Simd => unsafe { simd::rbf_finish(out, sqi, sq, neg_inv) },
        _ => rbf_finish_scalar(out, sqi, sq, neg_inv),
    }
}

/// Packed-panel GEMM over one stripe of C rows: `C[i0..i0+rows] += A
/// [i0..i0+rows] * B` with `A` m x k, `B` k x n, both row-major, `cstripe`
/// the stripe's rows of C.  B is packed into `KC x NC` slabs of NR-wide
/// panels and A into MR-row slivers; full 4x8 tiles run the register
/// kernel, edges the canonical scalar kernel.  Each C element is an
/// ascending-`k` FMA chain — bitwise equal to the naive `mul_add` triple
/// loop on both backends, and independent of the stripe partition.
pub fn gemm_stripe(
    backend: KernelBackend,
    ad: &[f64],
    bd: &[f64],
    cstripe: &mut [f64],
    i0: usize,
    k: usize,
    n: usize,
) {
    if n == 0 || cstripe.is_empty() {
        return;
    }
    let rows = cstripe.len() / n;
    if k == 0 || rows == 0 {
        return;
    }
    let kc_max = KC.min(k);
    let npanels_max = crate::util::threadpool::div_ceil(NC.min(n), NR);
    let mut bpack = vec![0.0f64; kc_max * npanels_max * NR];
    let mut apack = vec![0.0f64; kc_max * MR];
    let mut jc = 0;
    while jc < n {
        let ncb = NC.min(n - jc);
        let npanels = crate::util::threadpool::div_ceil(ncb, NR);
        let mut k0 = 0;
        while k0 < k {
            let kcb = KC.min(k - k0);
            pack_b(&mut bpack, bd, n, k0, kcb, jc, ncb);
            let mut r0 = 0;
            while r0 < rows {
                let mrb = MR.min(rows - r0);
                pack_a(&mut apack, ad, k, i0 + r0, mrb, k0, kcb);
                for p in 0..npanels {
                    let c0 = jc + p * NR;
                    let nrb = NR.min(jc + ncb - c0);
                    let bpanel = &bpack[p * kcb * NR..(p + 1) * kcb * NR];
                    match backend {
                        #[cfg(target_arch = "x86_64")]
                        KernelBackend::Simd if mrb == MR && nrb == NR => unsafe {
                            simd::tile_4x8(
                                apack.as_ptr(),
                                bpanel.as_ptr(),
                                kcb,
                                cstripe.as_mut_ptr().add(r0 * n + c0),
                                n,
                            );
                        },
                        #[cfg(target_arch = "x86_64")]
                        KernelBackend::Simd => unsafe {
                            simd::tile_edge(&apack, bpanel, kcb, cstripe, r0, c0, n, mrb, nrb);
                        },
                        _ => tile_scalar(&apack, bpanel, kcb, cstripe, r0, c0, n, mrb, nrb),
                    }
                }
                r0 += MR;
            }
            k0 += KC;
        }
        jc += NC;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The canonical semantics: naive triple loop, ascending-k mul_add
    /// chain per element.
    fn naive_fma_gemm(ad: &[f64], bd: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for kk in 0..k {
                    acc = ad[i * k + kk].mul_add(bd[kk * n + j], acc);
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn backends() -> Vec<KernelBackend> {
        let mut v = vec![KernelBackend::Scalar];
        if simd_available() {
            v.push(KernelBackend::Simd);
        }
        v
    }

    #[test]
    fn gemm_panel_tails_match_naive() {
        // the ISSUE 10 satellite grid: every dimension crosses the
        // packing boundaries (MR/NR/KC tails) and the cache-block edge
        let dims = [1usize, 3, 63, 64, 65, 100];
        let mut rng = Rng::new(101);
        for &m in &dims {
            for &k in &dims {
                for &n in &dims {
                    let ad: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
                    let bd: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
                    let want = naive_fma_gemm(&ad, &bd, m, k, n);
                    for backend in backends() {
                        let mut c = vec![0.0f64; m * n];
                        gemm_stripe(backend, &ad, &bd, &mut c, 0, k, n);
                        assert!(
                            c == want,
                            "gemm ({m},{k},{n}) {} differs from the naive FMA chain",
                            backend.as_str()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dot_matches_the_eight_lane_reference_bitwise() {
        let mut rng = Rng::new(102);
        for len in [0usize, 1, 3, 7, 8, 9, 16, 31, 100, 1000] {
            let x: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            // independent 8-lane reference
            let mut lanes = [0.0f64; 8];
            for (i, (&a, &b)) in x.iter().zip(&y).enumerate() {
                lanes[i % 8] = a.mul_add(b, lanes[i % 8]);
            }
            let want = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
                + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
            for backend in backends() {
                let got = dot_with(backend, &x, &y);
                assert!(
                    got.to_bits() == want.to_bits(),
                    "dot len {len} {}: {got:e} vs {want:e}",
                    backend.as_str()
                );
            }
        }
    }

    #[test]
    fn axpy_and_rank2_match_scalar_bitwise() {
        let mut rng = Rng::new(103);
        for len in [1usize, 4, 5, 31, 64, 257] {
            let x: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let e: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let base: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let (a, f, g) = (rng.normal(), rng.normal(), rng.normal());
            let mut want = base.clone();
            axpy_scalar(&mut want, a, &x);
            rank2_scalar(&mut want, f, &e, g, &x);
            for backend in backends() {
                let mut got = base.clone();
                fma_axpy_with(backend, &mut got, a, &x);
                rank2_sub_with(backend, &mut got, f, &e, g, &x);
                assert!(got == want, "axpy/rank2 len {len} {}", backend.as_str());
            }
        }
    }

    #[test]
    fn exp_fixed_accuracy_and_invariants() {
        // ~1 ulp against std exp across the gram's operating range
        let mut worst = 0.0f64;
        let mut x = -700.0f64;
        while x <= 0.0 {
            let got = exp_fixed(x);
            let want = x.exp();
            if want > 0.0 {
                let rel = ((got - want) / want).abs();
                worst = worst.max(rel);
            }
            assert!(got <= 1.0, "exp_fixed({x}) = {got} > 1");
            assert!(got >= 0.0, "exp_fixed({x}) = {got} < 0");
            x += 0.37;
        }
        assert!(worst < 1e-15, "exp_fixed worst relative error {worst:e}");
        // exact endpoints and edge cases
        assert_eq!(exp_fixed(0.0), 1.0);
        assert_eq!(exp_fixed(-0.0), 1.0);
        assert_eq!(exp_fixed(-800.0), 0.0);
        assert_eq!(exp_fixed(f64::NEG_INFINITY), 0.0);
        assert_eq!(exp_fixed(800.0), f64::INFINITY);
        assert!(exp_fixed(f64::NAN).is_nan());
        // positive range still ~1 ulp (used by nothing hot, but exposed)
        for &x in &[0.5, 1.0, 10.0, 100.0, 700.0] {
            let rel = ((exp_fixed(x) - x.exp()) / x.exp()).abs();
            assert!(rel < 1e-15, "exp_fixed({x}) rel err {rel:e}");
        }
    }

    #[test]
    fn rbf_finish_diag_is_exactly_one_and_backends_agree() {
        let mut rng = Rng::new(104);
        let p = 5;
        let xi: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        let sqi = sq_chain(&xi);
        // t accumulated the same way the gram row kernel does
        let cols = 11usize;
        let xt: Vec<Vec<f64>> = (0..p)
            .map(|_| (0..cols).map(|_| rng.normal()).collect())
            .collect();
        let mut t = vec![0.0f64; cols];
        for (d, row) in xt.iter().enumerate() {
            fma_axpy_with(KernelBackend::Scalar, &mut t, xi[d], row);
        }
        let sq: Vec<f64> = (0..cols)
            .map(|j| sq_chain(&xt.iter().map(|r| r[j]).collect::<Vec<_>>()))
            .collect();
        // plant the self-column: t[0] = <xi, xi> accumulated per-d
        let mut t0 = t.clone();
        t0[0] = sq_chain(&xi);
        let mut sq0 = sq.clone();
        sq0[0] = sqi;
        let mut want = t0.clone();
        rbf_finish_scalar(&mut want, sqi, &sq0, -0.5);
        assert_eq!(want[0], 1.0, "diagonal must be exactly 1.0");
        for backend in backends() {
            let mut got = t0.clone();
            rbf_finish_with(backend, &mut got, sqi, &sq0, -0.5);
            assert!(got == want, "rbf_finish {} drifts", backend.as_str());
            assert!(got.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn backend_dispatch_override_nests_and_restores() {
        with_kernel_backend(KernelBackend::Scalar, || {
            assert_eq!(default_kernel_backend(), KernelBackend::Scalar);
            if simd_available() {
                with_kernel_backend(KernelBackend::Simd, || {
                    assert_eq!(default_kernel_backend(), KernelBackend::Simd);
                });
            }
            assert_eq!(default_kernel_backend(), KernelBackend::Scalar);
        });
        // forcing simd on hardware without it resolves to scalar
        if !simd_available() {
            with_kernel_backend(KernelBackend::Simd, || {
                assert_eq!(default_kernel_backend(), KernelBackend::Scalar);
            });
        }
        assert_eq!(KernelBackend::Simd.as_str(), "simd");
        assert_eq!(KernelBackend::Scalar.as_str(), "scalar");
    }

    #[test]
    fn gemm_stripe_accumulates_into_existing_c() {
        let mut rng = Rng::new(105);
        let (m, k, n) = (7usize, 9usize, 13usize);
        let ad: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        let bd: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
        let init: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
        let mut want = init.clone();
        for i in 0..m {
            for j in 0..n {
                let mut acc = want[i * n + j];
                for kk in 0..k {
                    acc = ad[i * k + kk].mul_add(bd[kk * n + j], acc);
                }
                want[i * n + j] = acc;
            }
        }
        for backend in backends() {
            let mut c = init.clone();
            gemm_stripe(backend, &ad, &bd, &mut c, 0, k, n);
            assert!(c == want, "accumulating gemm {} drifts", backend.as_str());
        }
    }
}

//! Dense linear-algebra substrate, implemented from scratch (no external
//! linalg crates in this image): row-major [`Matrix`], blocked GEMM,
//! Cholesky (naive-baseline engine), the symmetric eigensolver (the
//! paper's O(N^3) overhead), rank-one eigendecomposition updates (the
//! streaming path, DESIGN.md §8), and Strassen multiplication (Prop. 2.4).

pub mod chol;
pub mod eigen;
pub mod gemm;
pub mod matrix;
pub mod rankone;
pub mod strassen;

pub use chol::{CholError, Cholesky};
pub use eigen::SymEigen;
pub use gemm::{matmul, matmul_bt};
pub use matrix::{axpy, dot, norm2, Matrix};
pub use rankone::{ortho_drift, rank_one_update};
pub use strassen::strassen;

//! Dense linear-algebra substrate, implemented from scratch (no external
//! linalg crates in this image): row-major [`Matrix`], the fixed-lane
//! SIMD/scalar microkernel layer (`microkernel`, DESIGN.md §14) and the
//! blocked GEMM on top of it,
//! Cholesky (naive-baseline engine), the symmetric eigensolver (the
//! paper's O(N^3) overhead; divide-and-conquer tridiagonal stage in
//! `dac` over the shared `secular` merge machinery, with the QL
//! iteration behind the `GPML_EIGEN=ql` escape hatch), rank-one
//! eigendecomposition updates (the streaming path, DESIGN.md §8), and
//! Strassen multiplication (Prop. 2.4).

pub mod chol;
pub(crate) mod dac;
pub mod eigen;
pub mod gemm;
pub mod matrix;
pub mod microkernel;
pub mod rankone;
pub(crate) mod secular;
pub mod strassen;

pub use chol::{CholError, Cholesky};
pub use eigen::{with_solver, EigenSolver, SymEigen};
pub use gemm::{matmul, matmul_bt};
pub use microkernel::{
    default_kernel_backend, simd_available, with_kernel_backend, KernelBackend,
};
pub use matrix::{axpy, dot, norm2, Matrix};
pub use rankone::{ortho_drift, rank_one_update};
pub use strassen::strassen;

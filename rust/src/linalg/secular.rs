//! Shared secular-equation merge machinery (DESIGN.md §8, §12).
//!
//! Both eigen-update paths in this crate reduce to the same core
//! problem: given `D + rho z z'` with `D = diag(d)` ascending and the
//! current eigenbasis expressed as the columns of `vectors`, produce
//! the updated decomposition.  [`rank_one_update`] reaches it from a
//! streaming correction `A + rho v v'`; the divide-and-conquer
//! tridiagonal solver (`linalg/dac.rs`) reaches it once per merge of
//! two child spectra after a rank-one tear.  The pipeline lives here so
//! both callers share one implementation, bit for bit:
//!
//! 1. **deflate** by amplitude (negligible `|z_i|` keeps its eigenpair
//!    verbatim) and by cluster (near-equal poles merged via Givens
//!    rotations that concentrate their `z` mass into one survivor);
//! 2. solve the **secular equation**
//!    `1 + rho * sum_i z_i^2 / (d_i - s) = 0` once per surviving
//!    interval, fanned across the scoped pool in pole-relative
//!    coordinates (safeguarded bisection cannot miss);
//! 3. recompute the update vector a la Gu–Eisenstat from the solved
//!    roots (`z_hat`), restoring numerical orthogonality even for
//!    tightly-spaced spectra;
//! 4. rotate the surviving basis columns by the `k x k` solution matrix
//!    `W` as one blocked [`gemm`] product, then re-assemble deflated
//!    and updated columns ascending-sorted.
//!
//! Determinism (DESIGN.md §6): every fan-out below partitions by fixed
//! grain sizes that depend only on the problem shape `k`, never on the
//! pool width, and each unit of work is self-contained — results are
//! bit-identical across `GPML_THREADS`, with width 1 running the exact
//! serial loop.
//!
//! [`rank_one_update`]: crate::linalg::rankone::rank_one_update

use super::eigen::SymEigen;
use super::matrix::Matrix;
use crate::linalg::gemm;
use crate::util::threadpool::{self, SharedMut};

/// Minimum per-worker multiply-add units before the secular solves /
/// z-hat recomputations fan out (same policy as `linalg/eigen`).
const PAR_GRAIN: usize = 1 << 14;

/// One solved secular root, kept in pole-relative form: the eigenvalue is
/// `d[base] + offset` where `d[base]` is the closest pole.  Differences
/// `d_i - lambda` are then computed as `(d_i - d[base]) - offset`, which
/// never cancels catastrophically — the two addends are exact data.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Root {
    pub(crate) base: usize,
    pub(crate) offset: f64,
}

impl Root {
    #[inline]
    pub(crate) fn value(&self, d: &[f64]) -> f64 {
        d[self.base] + self.offset
    }
    /// `d[i] - lambda`, cancellation-safe.
    #[inline]
    pub(crate) fn pole_gap(&self, d: &[f64], i: usize) -> f64 {
        if i == self.base {
            -self.offset
        } else {
            (d[i] - d[self.base]) - self.offset
        }
    }
}

/// Eigendecomposition of `basis * (diag(d) + rho z z') * basis'` given
/// `d` ascending and an orthogonal `basis` whose column `i` carries
/// pole `d[i]`.
///
/// `z` and `vectors` are consumed as working storage (deflation rotates
/// basis columns in place).  `rho = 0` or `z = 0` returns the input
/// decomposition unchanged — for the divide-and-conquer caller that is
/// the exact decoupled-blocks answer (the merged spectrum is already
/// sorted and the basis already block-diagonal).
pub(crate) fn merge_spectrum(d: &[f64], z: Vec<f64>, rho: f64, vectors: Matrix) -> SymEigen {
    let n = d.len();
    debug_assert_eq!(z.len(), n, "z length != spectrum size");
    debug_assert_eq!(vectors.cols(), n, "basis columns != spectrum size");
    let zz: f64 = z.iter().map(|x| x * x).sum();
    if n == 0 || rho == 0.0 || zz == 0.0 {
        return SymEigen { values: d.to_vec(), vectors };
    }

    // Perturbation scale: deflating a component of size z_i perturbs the
    // matrix by at most 2|rho||z_i|sqrt(zz); dropping a cluster's
    // off-diagonal perturbs by at most the cluster gap.  Both thresholds
    // come from the same norm estimate (Weyl).
    let anorm = d
        .iter()
        .fold(0.0f64, |m, x| m.max(x.abs()))
        .max(rho.abs() * zz)
        .max(f64::MIN_POSITIVE);
    let tol = 8.0 * f64::EPSILON * anorm;

    // --- step 1: deflation ---------------------------------------------
    // Rotations mutate the working copies owned by this call.
    let mut zw = z;
    let mut vectors = vectors;
    let z_floor = tol / (2.0 * rho.abs() * zz.sqrt());
    let mut survivors: Vec<usize> = (0..n).filter(|&i| zw[i].abs() > z_floor).collect();

    // cluster deflation: adjacent surviving poles closer than tol are
    // merged — rotate the earlier component's mass into the later one
    // (exact when the eigenvalues are equal, O(tol) otherwise)
    if survivors.len() >= 2 {
        let mut merged: Vec<usize> = Vec::with_capacity(survivors.len());
        let mut head = survivors[0];
        for &next in &survivors[1..] {
            if d[next] - d[head] <= tol {
                let (zh, zn) = (zw[head], zw[next]);
                let r = zh.hypot(zn);
                let (c, s) = (zn / r, zh / r);
                zw[head] = 0.0;
                zw[next] = r;
                rotate_columns(&mut vectors, head, next, c, s);
                // `head` deflates with its eigenvalue unchanged
            } else {
                merged.push(head);
            }
            head = next;
        }
        merged.push(head);
        survivors = merged;
    }

    let k = survivors.len();
    if k == 0 {
        // the update was numerically invisible
        return SymEigen { values: d.to_vec(), vectors };
    }

    let ds: Vec<f64> = survivors.iter().map(|&i| d[i]).collect();
    let zs: Vec<f64> = survivors.iter().map(|&i| zw[i]).collect();
    let zzs: f64 = zs.iter().map(|x| x * x).sum();

    // --- step 2: secular roots ------------------------------------------
    let roots = if k == 1 {
        vec![Root { base: 0, offset: rho * zzs }]
    } else if rho > 0.0 {
        solve_secular(&ds, &zs, rho)
    } else {
        // eig(A + rho vv') = -eig(-A + (-rho) vv'): flip sign and order,
        // solve the positive problem, map the roots back
        let df: Vec<f64> = ds.iter().rev().map(|x| -x).collect();
        let zf: Vec<f64> = zs.iter().rev().copied().collect();
        let flipped = solve_secular(&df, &zf, -rho);
        (0..k)
            .map(|j| {
                let r = flipped[k - 1 - j];
                Root { base: k - 1 - r.base, offset: -r.offset }
            })
            .collect()
    };

    // --- step 3: Gu–Eisenstat z-hat --------------------------------------
    // |z_hat_i|^2 = prod_j (s_j - d_i) / (rho * prod_{j != i} (d_j - d_i));
    // the ratio is positive by interlacing, so it is accumulated in log
    // magnitude (products of k factors of wildly varying scale would
    // otherwise over/underflow) and signed from the original z.
    let ln_rho = rho.abs().ln();
    let zhat: Vec<f64> = threadpool::par_map(
        &(0..k).collect::<Vec<usize>>(),
        (PAR_GRAIN / (2 * k).max(1)).max(1),
        |&i| {
            let mut acc = -ln_rho;
            for (j, r) in roots.iter().enumerate() {
                acc += r.pole_gap(&ds, i).abs().ln();
                if j != i {
                    acc -= (ds[j] - ds[i]).abs().ln();
                }
            }
            (0.5 * acc).exp().copysign(zs[i])
        },
    );

    // --- step 4: eigenvectors --------------------------------------------
    // w_j(i) = z_hat_i / (d_i - s_j), normalized; survivors-only basis
    // rotation Q = U_k W as one blocked GEMM (N x k by k x k).
    let mut w = Matrix::zeros(k, k);
    {
        let shared = SharedMut::new(w.data_mut());
        threadpool::par_for(k, (PAR_GRAIN / (2 * k).max(1)).max(1), |j| {
            let r = &roots[j];
            let mut col = vec![0.0f64; k];
            let mut norm2 = 0.0;
            for i in 0..k {
                let wi = zhat[i] / r.pole_gap(&ds, i);
                norm2 += wi * wi;
                col[i] = wi;
            }
            let inv = 1.0 / norm2.sqrt();
            for (i, wi) in col.into_iter().enumerate() {
                // Safety: worker j writes only column j.
                unsafe { shared.write(i * k + j, wi * inv) };
            }
        });
    }
    let mut u_sub = Matrix::zeros(n, k);
    for (jj, &col) in survivors.iter().enumerate() {
        for i in 0..n {
            u_sub[(i, jj)] = vectors[(i, col)];
        }
    }
    let q = gemm::matmul(&u_sub, &w);

    // --- assemble + sort ascending ---------------------------------------
    // pair each output eigenvalue with its column source: deflated
    // columns pass through (possibly cluster-rotated), survivors take the
    // rotated columns of q
    enum Src {
        Old(usize),
        New(usize),
    }
    let mut pairs: Vec<(f64, Src)> = Vec::with_capacity(n);
    let survivor_set: Vec<bool> = {
        let mut m = vec![false; n];
        for &i in &survivors {
            m[i] = true;
        }
        m
    };
    for i in 0..n {
        if !survivor_set[i] {
            pairs.push((d[i], Src::Old(i)));
        }
    }
    for (j, r) in roots.iter().enumerate() {
        pairs.push((r.value(&ds), Src::New(j)));
    }
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    let mut values = Vec::with_capacity(n);
    let mut out = Matrix::zeros(n, n);
    for (col, (val, src)) in pairs.into_iter().enumerate() {
        values.push(val);
        match src {
            Src::Old(c) => {
                for i in 0..n {
                    out[(i, col)] = vectors[(i, c)];
                }
            }
            Src::New(j) => {
                for i in 0..n {
                    out[(i, col)] = q[(i, j)];
                }
            }
        }
    }
    SymEigen { values, vectors: out }
}

/// Givens rotation of eigenvector columns `i` and `j`:
/// `u_i <- c u_i - s u_j`, `u_j <- s u_i + c u_j`.
fn rotate_columns(u: &mut Matrix, i: usize, j: usize, c: f64, s: f64) {
    let n = u.rows();
    for r in 0..n {
        let (a, b) = (u[(r, i)], u[(r, j)]);
        u[(r, i)] = c * a - s * b;
        u[(r, j)] = s * a + c * b;
    }
}

/// Roots of `1 + rho * sum_i z_i^2 / (d_i - s) = 0` for `rho > 0`,
/// `d` strictly ascending (post-deflation), all `z_i != 0`, `k >= 2`.
/// Root `j` lies in `(d_j, d_{j+1})` (last: `(d_{k-1}, d_{k-1} + rho z'z)`).
///
/// Each interval solve picks the closer pole as origin (decided by the
/// secular function's sign at the midpoint) and bisects in pole-relative
/// coordinates — the function is strictly increasing on the interval, so
/// bisection converges unconditionally to f64 fixpoint.  Intervals are
/// independent and fan out across the pool with serial-identical
/// arithmetic (bit-identical across widths).
pub(crate) fn solve_secular(d: &[f64], z: &[f64], rho: f64) -> Vec<Root> {
    let k = d.len();
    let zz: f64 = z.iter().map(|x| x * x).sum();
    let js: Vec<usize> = (0..k).collect();
    // ~60-120 g() evaluations of O(k) each per interval
    let grain = (PAR_GRAIN / (128 * k)).max(1);
    threadpool::par_map(&js, grain, |&j| {
        // g(t) = 1 + rho sum_i z_i^2 / (delta_i - t), origin-relative
        let g = |origin: usize, t: f64| -> f64 {
            let mut acc = 1.0;
            for i in 0..k {
                let delta = if i == origin { 0.0 } else { d[i] - d[origin] };
                acc += rho * z[i] * z[i] / (delta - t);
            }
            acc
        };
        let (origin, mut lo, mut hi) = if j + 1 < k {
            let gap = d[j + 1] - d[j];
            // g just right of d_j is -inf, just left of d_{j+1} is +inf;
            // the midpoint sign picks the closer pole as origin
            if g(j, 0.5 * gap) >= 0.0 {
                (j, 0.0, 0.5 * gap)
            } else {
                (j + 1, -0.5 * gap, 0.0)
            }
        } else {
            // last interval: upper bound d_{k-1} + rho z'z is not a pole
            (j, 0.0, rho * zz)
        };
        // invariant: g(lo) < 0 <= g(hi) (limits at the open endpoints)
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if mid == lo || mid == hi {
                break;
            }
            if g(origin, mid) < 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        // return the side strictly inside the interval, so the offset is
        // never exactly 0 (which would alias the pole in step 4)
        let t = if origin == j && lo == 0.0 {
            hi
        } else if origin == j + 1 && hi == 0.0 {
            lo
        } else {
            0.5 * (lo + hi)
        };
        Root { base: origin, offset: t }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;

    /// Dense `basis (diag(d) + rho z z') basis'` for reference checks.
    fn dense(d: &[f64], z: &[f64], rho: f64, basis: &Matrix) -> Matrix {
        let n = d.len();
        let inner = Matrix::from_fn(n, n, |i, j| {
            let diag = if i == j { d[i] } else { 0.0 };
            diag + rho * z[i] * z[j]
        });
        matmul(&matmul(basis, &inner), &basis.t())
    }

    #[test]
    fn merges_diagonal_plus_rank_one_both_signs() {
        let d = [-1.5, -0.25, 0.0, 0.75, 2.0];
        let z = [0.6, -0.3, 0.8, 0.2, -0.5];
        for &rho in &[1.0, -1.0, 0.4] {
            let eg = merge_spectrum(&d, z.to_vec(), rho, Matrix::eye(5));
            let a = dense(&d, &z, rho, &Matrix::eye(5));
            assert!(eg.reconstruct().max_abs_diff(&a) < 1e-10, "rho={rho}");
            let utu = matmul(&eg.vectors.t(), &eg.vectors);
            assert!(utu.max_abs_diff(&Matrix::eye(5)) < 1e-12, "rho={rho}");
            for w in eg.values.windows(2) {
                assert!(w[0] <= w[1], "rho={rho}: not ascending");
            }
        }
    }

    #[test]
    fn zero_rho_or_zero_z_is_identity() {
        let d = [0.5, 1.0, 3.0];
        let eg = merge_spectrum(&d, vec![1.0, -2.0, 0.5], 0.0, Matrix::eye(3));
        assert_eq!(eg.values, d.to_vec());
        assert_eq!(eg.vectors.data(), Matrix::eye(3).data());
        let eg = merge_spectrum(&d, vec![0.0; 3], 2.0, Matrix::eye(3));
        assert_eq!(eg.values, d.to_vec());
    }

    #[test]
    fn secular_roots_interlace() {
        let d = [0.0, 1.0, 2.5, 4.0];
        let z = [0.5, 0.5, 0.5, 0.5];
        let zz: f64 = z.iter().map(|x| x * x).sum();
        let roots = solve_secular(&d, &z, 1.0);
        for (j, r) in roots.iter().enumerate() {
            let s = r.value(&d);
            assert!(s > d[j], "root {j} below its pole");
            let hi = if j + 1 < 4 { d[j + 1] } else { d[3] + zz };
            assert!(s <= hi, "root {j} above its interval");
        }
    }
}

//! Blocked dense matrix multiplication on the microkernel layer.
//!
//! All three entry points drive disjoint output stripes through the
//! scoped pool (DESIGN.md §6) and do their per-element arithmetic in the
//! fixed-lane microkernels of [`super::microkernel`] (DESIGN.md §14):
//! `matmul`/`matmul_into` run the packed-panel 4x8 register-tile GEMM,
//! `matmul_bt` the fixed 8-lane dot, and `ata` the broadcast-FMA axpy.
//! The per-element accumulation order never depends on the thread count
//! *or* the backend, so results are bit-identical serial vs pooled and
//! `GPML_KERNEL=simd` vs `scalar`.  The backend is resolved once per
//! call on the calling thread (before the fan-out), so the scoped
//! [`super::microkernel::with_kernel_backend`] override applies to
//! pooled work too.

use super::matrix::Matrix;
use super::microkernel;
use crate::util::threadpool::{self, div_ceil};

/// Cache block edge (in elements) for the `matmul_bt` (j, k) tiling and
/// the stripe-height quantum. 64x64 f64 tiles = 32 KiB per operand pair.
const BLOCK: usize = 64;

/// Minimum multiply-add count per pool worker before a GEMM fans out
/// (thread spawn is ~10 us; 2^20 flops is ~0.3 ms of work).
const PAR_GRAIN_FLOPS: usize = 1 << 20;

/// Stripe height (rows of C per pool chunk): at least one cache block,
/// scaled up until a stripe carries `PAR_GRAIN_FLOPS` work so small
/// problems collapse to the serial path inside `par_chunks_mut`.
fn stripe_rows(k: usize, n: usize) -> usize {
    let per_row = (k * n).max(1);
    BLOCK * div_ceil(PAR_GRAIN_FLOPS, BLOCK * per_row).max(1)
}

/// `C = A * B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul dimension mismatch");
    let (m, n) = (a.rows(), b.cols());
    let mut c = Matrix::zeros(m, n);
    matmul_into(a, b, &mut c);
    c
}

/// `C += A * B` over an existing (zeroed or accumulating) output,
/// parallel over i-stripes of C.  Each stripe runs the packed-panel
/// microkernel GEMM; every C element is an ascending-k FMA chain, so the
/// result is independent of the stripe partition and the backend.
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(c.rows(), m);
    assert_eq!(c.cols(), n);
    if m == 0 || n == 0 {
        return;
    }
    let ad = a.data();
    let bd = b.data();
    let kb = microkernel::default_kernel_backend();
    let rows = stripe_rows(k, n);
    threadpool::par_chunks_mut(c.data_mut(), rows * n, |si, cstripe| {
        microkernel::gemm_stripe(kb, ad, bd, cstripe, si * rows, k, n);
    });
}

/// `A * B'` without materializing the transpose — blocked over (j, k)
/// tiles with the fixed 8-lane dot as the inner kernel, parallel over
/// i-stripes of C.
pub fn matmul_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_bt dimension mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    let ad = a.data();
    let bd = b.data();
    let kb = microkernel::default_kernel_backend();
    let rows = stripe_rows(k, n);
    threadpool::par_chunks_mut(c.data_mut(), rows * n, |si, cstripe| {
        let i0 = si * rows;
        let srows = cstripe.len() / n;
        // (j0, k0) tiles keep a BLOCK x BLOCK window of B rows hot while
        // the stripe's A rows stream over it; C[i][j] accumulates one
        // 8-lane dot per k block, in ascending k order.
        for j0 in (0..n).step_by(BLOCK) {
            let j1 = (j0 + BLOCK).min(n);
            for k0 in (0..k).step_by(BLOCK) {
                let k1 = (k0 + BLOCK).min(k);
                for r in 0..srows {
                    let aseg = &ad[(i0 + r) * k + k0..(i0 + r) * k + k1];
                    let crow = &mut cstripe[r * n..(r + 1) * n];
                    for j in j0..j1 {
                        let bseg = &bd[j * k + k0..j * k + k1];
                        crow[j] += microkernel::dot_with(kb, aseg, bseg);
                    }
                }
            }
        }
    });
    c
}

/// Column-block edges for `ata`: block `b` covers columns
/// `[edges[b], edges[b+1])` of the upper triangle.  A block's work is
/// the triangle strip area `m * (c1^2 - c0^2) / 2`, so equal-work edges
/// follow `edges[b] ~ n * sqrt(b / nblocks)` — the fix for the old
/// `PAR_GRAIN_FLOPS / m` sizing, which measured a rectangle and let the
/// late (wide, shallow-triangle) blocks undershoot the spawn grain.
/// Deterministic in (m, n) alone, and since column partitioning never
/// reorders a C element's over-rows accumulation, any edge set gives the
/// same bits.
fn ata_col_edges(m: usize, n: usize) -> Vec<usize> {
    let total = m.max(1) * (n * (n + 1) / 2);
    let nblocks = div_ceil(total, PAR_GRAIN_FLOPS).clamp(1, n);
    let mut edges = Vec::with_capacity(nblocks + 1);
    edges.push(0usize);
    for b in 1..=nblocks {
        let frac = b as f64 / nblocks as f64;
        let ideal = (n as f64 * frac.sqrt()).round() as usize;
        let prev = *edges.last().unwrap();
        // strictly increasing, and leave >= 1 column for each remaining
        // block (always feasible: nblocks <= n)
        edges.push(ideal.clamp(prev + 1, n - (nblocks - b)));
    }
    edges
}

/// `A' * A` (Gram of columns), exploiting symmetry — row-streaming
/// rank-1 accumulation through the broadcast-FMA axpy microkernel,
/// parallel over equal-triangle-area column blocks of C (each worker
/// streams all of A but owns a disjoint set of output columns, so the
/// per-element accumulation order over rows is unchanged).
pub fn ata(a: &Matrix) -> Matrix {
    let (m, n) = (a.rows(), a.cols());
    let mut c = Matrix::zeros(n, n);
    if n == 0 {
        return c;
    }
    let edges = ata_col_edges(m, n);
    let nblocks = edges.len() - 1;
    let ad = a.data();
    let kb = microkernel::default_kernel_backend();
    {
        let shared = threadpool::SharedMut::new(c.data_mut());
        threadpool::par_for(nblocks, 1, |bi| {
            let c0 = edges[bi];
            let c1 = edges[bi + 1];
            for r in 0..m {
                let row = &ad[r * n..(r + 1) * n];
                for i in 0..c1 {
                    let j0 = i.max(c0);
                    // Safety: this worker owns columns [c0, c1) of C's
                    // upper triangle; writes from other workers land in
                    // disjoint columns.
                    let crow = unsafe { shared.slice_mut(i * n + j0, i * n + c1) };
                    microkernel::fma_axpy_with(kb, crow, row[i], &row[j0..c1]);
                }
            }
        });
    }
    for i in 0..n {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::microkernel::KernelBackend;
    use crate::util::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        Matrix::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|k| a[(i, k)] * b[(k, j)]).sum()
        })
    }

    fn random(rng: &mut Rng, m: usize, n: usize) -> Matrix {
        Matrix::from_fn(m, n, |_, _| rng.normal())
    }

    #[test]
    fn matches_naive_on_odd_shapes() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (65, 64, 63), (100, 17, 130)] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-10, "({m},{k},{n})");
        }
    }

    #[test]
    fn backends_agree_bitwise_through_the_public_entry_points() {
        let mut rng = Rng::new(7);
        let a = random(&mut rng, 37, 29);
        let b = random(&mut rng, 29, 41);
        let with = |kb| {
            microkernel::with_kernel_backend(kb, || {
                (matmul(&a, &b), matmul_bt(&a, &b.t()), ata(&a))
            })
        };
        let (m1, bt1, g1) = with(KernelBackend::Scalar);
        let (m2, bt2, g2) = with(KernelBackend::Simd); // resolves to scalar off-AVX2
        assert!(m1.data() == m2.data(), "matmul backend drift");
        assert!(bt1.data() == bt2.data(), "matmul_bt backend drift");
        assert!(g1.data() == g2.data(), "ata backend drift");
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = random(&mut rng, 20, 20);
        assert!(matmul(&a, &Matrix::eye(20)).max_abs_diff(&a) < 1e-14);
        assert!(matmul(&Matrix::eye(20), &a).max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn bt_matches_explicit_transpose() {
        let mut rng = Rng::new(4);
        let a = random(&mut rng, 13, 9);
        let b = random(&mut rng, 21, 9);
        assert!(matmul_bt(&a, &b).max_abs_diff(&matmul(&a, &b.t())) < 1e-12);
    }

    #[test]
    fn ata_matches_explicit() {
        let mut rng = Rng::new(5);
        let a = random(&mut rng, 31, 8);
        assert!(ata(&a).max_abs_diff(&matmul(&a.t(), &a)) < 1e-12);
    }

    #[test]
    fn ata_col_edges_cover_and_grow() {
        for &(m, n) in &[(1usize, 1usize), (4, 7), (1000, 100), (4096, 4096), (100000, 3)] {
            let edges = ata_col_edges(m, n);
            assert_eq!(*edges.first().unwrap(), 0);
            assert_eq!(*edges.last().unwrap(), n);
            assert!(edges.windows(2).all(|w| w[0] < w[1]), "({m},{n}): {edges:?}");
            // equal-area sizing: blocks narrow as columns (and triangle
            // depth) grow
            let widths: Vec<usize> = edges.windows(2).map(|w| w[1] - w[0]).collect();
            if widths.len() > 2 {
                assert!(
                    widths.first().unwrap() >= widths.last().unwrap(),
                    "({m},{n}): early blocks should be widest: {widths:?}"
                );
            }
        }
    }

    #[test]
    fn associativity_property() {
        let rng = Rng::new(6);
        crate::util::proptest::forall(
            "(AB)C == A(BC)",
            7,
            10,
            |r| {
                let m = 2 + r.below(12);
                let k = 2 + r.below(12);
                let n = 2 + r.below(12);
                let p = 2 + r.below(12);
                (random(r, m, k), random(r, k, n), random(r, n, p))
            },
            |(a, b, c)| {
                let left = matmul(&matmul(a, b), c);
                let right = matmul(a, &matmul(b, c));
                if left.max_abs_diff(&right) < 1e-9 {
                    Ok(())
                } else {
                    Err("associativity violated".into())
                }
            },
        );
        let _ = rng;
    }
}

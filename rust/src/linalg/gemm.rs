//! Blocked dense matrix multiplication.
//!
//! A cache-blocked ikj-order GEMM with a small unrolled inner loop — not
//! MKL, but within a small factor of peak for the N <= 8192 sizes the
//! naive-baseline benches need, and entirely self-contained.

use super::matrix::Matrix;

/// Cache block edge (in elements). 64x64 f64 tiles = 32 KiB per operand
/// pair, sized for L1/L2 residency.
const BLOCK: usize = 64;

/// `C = A * B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul dimension mismatch");
    let (m, n) = (a.rows(), b.cols());
    let mut c = Matrix::zeros(m, n);
    matmul_into(a, b, &mut c);
    c
}

/// `C += A * B` over an existing (zeroed or accumulating) output.
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(c.rows(), m);
    assert_eq!(c.cols(), n);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for j0 in (0..n).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(n);
                for i in i0..i1 {
                    let arow = &ad[i * k..(i + 1) * k];
                    let crow = &mut cd[i * n..(i + 1) * n];
                    for kk in k0..k1 {
                        let aik = arow[kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &bd[kk * n..(kk + 1) * n];
                        // unrolled-by-4 axpy over the j tile
                        let (mut j, end) = (j0, j1);
                        while j + 4 <= end {
                            crow[j] += aik * brow[j];
                            crow[j + 1] += aik * brow[j + 1];
                            crow[j + 2] += aik * brow[j + 2];
                            crow[j + 3] += aik * brow[j + 3];
                            j += 4;
                        }
                        while j < end {
                            crow[j] += aik * brow[j];
                            j += 1;
                        }
                    }
                }
            }
        }
    }
}

/// `A * B'` without materializing the transpose.
pub fn matmul_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_bt dimension mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut c = Matrix::zeros(m, n);
    let ad = a.data();
    let bd = b.data();
    let cd = c.data_mut();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            cd[i * n + j] = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
        }
    }
    c
}

/// `A' * A` (Gram of columns), exploiting symmetry.
pub fn ata(a: &Matrix) -> Matrix {
    let (m, n) = (a.rows(), a.cols());
    let mut c = Matrix::zeros(n, n);
    for r in 0..m {
        let row = a.row(r);
        for i in 0..n {
            let ri = row[i];
            if ri == 0.0 {
                continue;
            }
            for j in i..n {
                c[(i, j)] += ri * row[j];
            }
        }
    }
    for i in 0..n {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        Matrix::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|k| a[(i, k)] * b[(k, j)]).sum()
        })
    }

    fn random(rng: &mut Rng, m: usize, n: usize) -> Matrix {
        Matrix::from_fn(m, n, |_, _| rng.normal())
    }

    #[test]
    fn matches_naive_on_odd_shapes() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (65, 64, 63), (100, 17, 130)] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-10, "({m},{k},{n})");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = random(&mut rng, 20, 20);
        assert!(matmul(&a, &Matrix::eye(20)).max_abs_diff(&a) < 1e-14);
        assert!(matmul(&Matrix::eye(20), &a).max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn bt_matches_explicit_transpose() {
        let mut rng = Rng::new(4);
        let a = random(&mut rng, 13, 9);
        let b = random(&mut rng, 21, 9);
        assert!(matmul_bt(&a, &b).max_abs_diff(&matmul(&a, &b.t())) < 1e-12);
    }

    #[test]
    fn ata_matches_explicit() {
        let mut rng = Rng::new(5);
        let a = random(&mut rng, 31, 8);
        assert!(ata(&a).max_abs_diff(&matmul(&a.t(), &a)) < 1e-12);
    }

    #[test]
    fn associativity_property() {
        let rng = Rng::new(6);
        crate::util::proptest::forall(
            "(AB)C == A(BC)",
            7,
            10,
            |r| {
                let m = 2 + r.below(12);
                let k = 2 + r.below(12);
                let n = 2 + r.below(12);
                let p = 2 + r.below(12);
                (random(r, m, k), random(r, k, n), random(r, n, p))
            },
            |(a, b, c)| {
                let left = matmul(&matmul(a, b), c);
                let right = matmul(a, &matmul(b, c));
                if left.max_abs_diff(&right) < 1e-9 {
                    Ok(())
                } else {
                    Err("associativity violated".into())
                }
            },
        );
        let _ = rng;
    }
}

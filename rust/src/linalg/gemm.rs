//! Blocked dense matrix multiplication.
//!
//! A cache-blocked ikj-order GEMM with a small unrolled inner loop — not
//! MKL, but within a small factor of peak for the N <= 8192 sizes the
//! naive-baseline benches need, and entirely self-contained.  All three
//! entry points drive disjoint output stripes through the scoped pool
//! (DESIGN.md §6); the per-element accumulation order never depends on
//! the thread count, so results are bit-identical serial vs pooled.

use super::matrix::Matrix;
use crate::util::threadpool::{self, div_ceil};

/// Cache block edge (in elements). 64x64 f64 tiles = 32 KiB per operand
/// pair, sized for L1/L2 residency.
const BLOCK: usize = 64;

/// Minimum multiply-add count per pool worker before a GEMM fans out
/// (thread spawn is ~10 us; 2^20 flops is ~0.3 ms of work).
const PAR_GRAIN_FLOPS: usize = 1 << 20;

/// Stripe height (rows of C per pool chunk): at least one cache block,
/// scaled up until a stripe carries `PAR_GRAIN_FLOPS` work so small
/// problems collapse to the serial path inside `par_chunks_mut`.
fn stripe_rows(k: usize, n: usize) -> usize {
    let per_row = (k * n).max(1);
    BLOCK * div_ceil(PAR_GRAIN_FLOPS, BLOCK * per_row).max(1)
}

/// `C = A * B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul dimension mismatch");
    let (m, n) = (a.rows(), b.cols());
    let mut c = Matrix::zeros(m, n);
    matmul_into(a, b, &mut c);
    c
}

/// `C += A * B` over an existing (zeroed or accumulating) output,
/// parallel over i-stripes of C.
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(c.rows(), m);
    assert_eq!(c.cols(), n);
    if m == 0 || n == 0 {
        return;
    }
    let ad = a.data();
    let bd = b.data();
    let rows = stripe_rows(k, n);
    threadpool::par_chunks_mut(c.data_mut(), rows * n, |si, cstripe| {
        matmul_stripe(ad, bd, cstripe, si * rows, k, n);
    });
}

/// The blocked ikj kernel over C rows `[i0, i0 + cstripe.len()/n)`.
fn matmul_stripe(ad: &[f64], bd: &[f64], cstripe: &mut [f64], i0: usize, k: usize, n: usize) {
    let rows = cstripe.len() / n;
    for b0 in (0..rows).step_by(BLOCK) {
        let b1 = (b0 + BLOCK).min(rows);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for j0 in (0..n).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(n);
                for r in b0..b1 {
                    let i = i0 + r;
                    let arow = &ad[i * k..(i + 1) * k];
                    let crow = &mut cstripe[r * n..(r + 1) * n];
                    for kk in k0..k1 {
                        let aik = arow[kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &bd[kk * n..(kk + 1) * n];
                        // unrolled-by-4 axpy over the j tile
                        let (mut j, end) = (j0, j1);
                        while j + 4 <= end {
                            crow[j] += aik * brow[j];
                            crow[j + 1] += aik * brow[j + 1];
                            crow[j + 2] += aik * brow[j + 2];
                            crow[j + 3] += aik * brow[j + 3];
                            j += 4;
                        }
                        while j < end {
                            crow[j] += aik * brow[j];
                            j += 1;
                        }
                    }
                }
            }
        }
    }
}

/// `A * B'` without materializing the transpose — blocked over (j, k)
/// tiles with a four-accumulator unrolled dot kernel (parity with
/// `matmul`'s treatment), parallel over i-stripes of C.
pub fn matmul_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_bt dimension mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    let ad = a.data();
    let bd = b.data();
    let rows = stripe_rows(k, n);
    threadpool::par_chunks_mut(c.data_mut(), rows * n, |si, cstripe| {
        let i0 = si * rows;
        let srows = cstripe.len() / n;
        // (j0, k0) tiles keep a BLOCK x BLOCK window of B rows hot while
        // the stripe's A rows stream over it.
        for j0 in (0..n).step_by(BLOCK) {
            let j1 = (j0 + BLOCK).min(n);
            for k0 in (0..k).step_by(BLOCK) {
                let k1 = (k0 + BLOCK).min(k);
                for r in 0..srows {
                    let aseg = &ad[(i0 + r) * k + k0..(i0 + r) * k + k1];
                    let crow = &mut cstripe[r * n..(r + 1) * n];
                    for j in j0..j1 {
                        let bseg = &bd[j * k + k0..j * k + k1];
                        crow[j] += dot_unrolled(aseg, bseg);
                    }
                }
            }
        }
    });
    c
}

/// Four-accumulator unrolled dot product (the inner kernel `matmul_bt`
/// and `ata` share).
#[inline]
fn dot_unrolled(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let len = x.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut i = 0;
    while i + 4 <= len {
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < len {
        s += x[i] * y[i];
        i += 1;
    }
    s
}

/// `A' * A` (Gram of columns), exploiting symmetry — row-streaming
/// rank-1 accumulation with an unrolled-by-4 inner axpy (parity with
/// `matmul`), parallel over column blocks of C (each worker streams all
/// of A but owns a disjoint set of output columns, so the per-element
/// accumulation order over rows is unchanged).
pub fn ata(a: &Matrix) -> Matrix {
    let (m, n) = (a.rows(), a.cols());
    let mut c = Matrix::zeros(n, n);
    if n == 0 {
        return c;
    }
    // column block sized so each worker's share (m rows x block columns)
    // clears the spawn threshold
    let bcols = div_ceil(PAR_GRAIN_FLOPS, m.max(1)).max(BLOCK).min(n);
    let nblocks = div_ceil(n, bcols);
    let ad = a.data();
    {
        let shared = threadpool::SharedMut::new(c.data_mut());
        threadpool::par_for(nblocks, 1, |bi| {
            let c0 = bi * bcols;
            let c1 = (c0 + bcols).min(n);
            for r in 0..m {
                let row = &ad[r * n..(r + 1) * n];
                for i in 0..c1 {
                    let ri = row[i];
                    if ri == 0.0 {
                        continue;
                    }
                    let j0 = i.max(c0);
                    // Safety: this worker owns columns [c0, c1) of C's
                    // upper triangle; writes from other workers land in
                    // disjoint columns.
                    let crow = unsafe { shared.slice_mut(i * n + j0, i * n + c1) };
                    let rseg = &row[j0..c1];
                    let (mut j, end) = (0usize, rseg.len());
                    while j + 4 <= end {
                        crow[j] += ri * rseg[j];
                        crow[j + 1] += ri * rseg[j + 1];
                        crow[j + 2] += ri * rseg[j + 2];
                        crow[j + 3] += ri * rseg[j + 3];
                        j += 4;
                    }
                    while j < end {
                        crow[j] += ri * rseg[j];
                        j += 1;
                    }
                }
            }
        });
    }
    for i in 0..n {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        Matrix::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|k| a[(i, k)] * b[(k, j)]).sum()
        })
    }

    fn random(rng: &mut Rng, m: usize, n: usize) -> Matrix {
        Matrix::from_fn(m, n, |_, _| rng.normal())
    }

    #[test]
    fn matches_naive_on_odd_shapes() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (65, 64, 63), (100, 17, 130)] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let got = matmul(&a, &b);
            let want = naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-10, "({m},{k},{n})");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = random(&mut rng, 20, 20);
        assert!(matmul(&a, &Matrix::eye(20)).max_abs_diff(&a) < 1e-14);
        assert!(matmul(&Matrix::eye(20), &a).max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn bt_matches_explicit_transpose() {
        let mut rng = Rng::new(4);
        let a = random(&mut rng, 13, 9);
        let b = random(&mut rng, 21, 9);
        assert!(matmul_bt(&a, &b).max_abs_diff(&matmul(&a, &b.t())) < 1e-12);
    }

    #[test]
    fn ata_matches_explicit() {
        let mut rng = Rng::new(5);
        let a = random(&mut rng, 31, 8);
        assert!(ata(&a).max_abs_diff(&matmul(&a.t(), &a)) < 1e-12);
    }

    #[test]
    fn associativity_property() {
        let rng = Rng::new(6);
        crate::util::proptest::forall(
            "(AB)C == A(BC)",
            7,
            10,
            |r| {
                let m = 2 + r.below(12);
                let k = 2 + r.below(12);
                let n = 2 + r.below(12);
                let p = 2 + r.below(12);
                (random(r, m, k), random(r, k, n), random(r, n, p))
            },
            |(a, b, c)| {
                let left = matmul(&matmul(a, b), c);
                let right = matmul(a, &matmul(b, c));
                if left.max_abs_diff(&right) < 1e-9 {
                    Ok(())
                } else {
                    Err("associativity violated".into())
                }
            },
        );
        let _ = rng;
    }
}

//! Strassen matrix multiplication — Proposition 2.4 cites Strassen's
//! O(N^2.807) algorithm for materializing the full posterior covariance
//! `Sigma_c = U Q U'`.  Recursion with zero-padding to even dimensions and
//! a blocked-GEMM base case.

use super::gemm;
use super::matrix::Matrix;
use crate::util::threadpool;

/// Below this edge the O(N^3) blocked GEMM wins (crossover measured in
/// `benches/prop24_variance.rs`).
const BASE: usize = 128;

/// Above this edge a recursion level fans its seven products out through
/// the scoped pool (DESIGN.md §6).  Below it the sequential recursion is
/// used: nested levels already run inside pool workers, where `par_map`
/// degenerates to the inline serial loop, so only the outermost level
/// pays any coordination cost.
const PAR_EDGE: usize = 256;

/// `A * B` via Strassen's algorithm.
pub fn strassen(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "strassen dimension mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let dim = m.max(k).max(n);
    if dim <= BASE {
        return gemm::matmul(a, b);
    }
    // pad to next even size at each recursion level; simplest is to pad to
    // a power-of-two-ish even envelope once
    let p = dim.next_power_of_two();
    let ap = pad(a, p, p);
    let bp = pad(b, p, p);
    let cp = strassen_sq(&ap, &bp);
    cp.top_left(m, n)
}

fn pad(a: &Matrix, r: usize, c: usize) -> Matrix {
    let mut out = Matrix::zeros(r, c);
    for i in 0..a.rows() {
        out.row_mut(i)[..a.cols()].copy_from_slice(a.row(i));
    }
    out
}

/// Square power-of-two recursion.  The seven quadrant products are
/// independent; above `PAR_EDGE` they fan out through the pool (each
/// product recursing sequentially inside its worker — nested `par_map`
/// calls run inline).  The combination arithmetic is identical either
/// way, so the result does not depend on the thread count.
fn strassen_sq(a: &Matrix, b: &Matrix) -> Matrix {
    let n = a.rows();
    if n <= BASE {
        return gemm::matmul(a, b);
    }
    let h = n / 2;
    let (a11, a12, a21, a22) = split(a, h);
    let (b11, b12, b21, b22) = split(b, h);

    // grain 7 below the edge forces one worker, i.e. the in-order serial
    // loop through the same code path
    let grain = if n > PAR_EDGE { 1 } else { 7 };
    let product_ids: [usize; 7] = [0, 1, 2, 3, 4, 5, 6];
    let products = threadpool::par_map(&product_ids, grain, |&p| match p {
        0 => strassen_sq(&a11.add(&a22), &b11.add(&b22)),
        1 => strassen_sq(&a21.add(&a22), &b11),
        2 => strassen_sq(&a11, &b12.sub(&b22)),
        3 => strassen_sq(&a22, &b21.sub(&b11)),
        4 => strassen_sq(&a11.add(&a12), &b22),
        5 => strassen_sq(&a21.sub(&a11), &b11.add(&b12)),
        _ => strassen_sq(&a12.sub(&a22), &b21.add(&b22)),
    });
    let [m1, m2, m3, m4, m5, m6, m7] = match <[Matrix; 7]>::try_from(products) {
        Ok(ms) => ms,
        Err(_) => unreachable!("strassen always produces 7 products"),
    };

    let c11 = m1.add(&m4).sub(&m5).add(&m7);
    let c12 = m3.add(&m5);
    let c21 = m2.add(&m4);
    let c22 = m1.sub(&m2).add(&m3).add(&m6);

    join(&c11, &c12, &c21, &c22)
}

fn split(a: &Matrix, h: usize) -> (Matrix, Matrix, Matrix, Matrix) {
    let block = |r0: usize, c0: usize| Matrix::from_fn(h, h, |i, j| a[(r0 + i, c0 + j)]);
    (block(0, 0), block(0, h), block(h, 0), block(h, h))
}

fn join(c11: &Matrix, c12: &Matrix, c21: &Matrix, c22: &Matrix) -> Matrix {
    let h = c11.rows();
    Matrix::from_fn(2 * h, 2 * h, |i, j| match (i < h, j < h) {
        (true, true) => c11[(i, j)],
        (true, false) => c12[(i, j - h)],
        (false, true) => c21[(i - h, j)],
        (false, false) => c22[(i - h, j - h)],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random(rng: &mut Rng, m: usize, n: usize) -> Matrix {
        Matrix::from_fn(m, n, |_, _| rng.normal())
    }

    #[test]
    fn matches_gemm_small() {
        let mut rng = Rng::new(1);
        let a = random(&mut rng, 30, 30);
        let b = random(&mut rng, 30, 30);
        assert!(strassen(&a, &b).max_abs_diff(&gemm::matmul(&a, &b)) < 1e-10);
    }

    #[test]
    fn matches_gemm_above_base() {
        let mut rng = Rng::new(2);
        let n = BASE * 2 + 17; // force one recursion + padding
        let a = random(&mut rng, n, n);
        let b = random(&mut rng, n, n);
        assert!(strassen(&a, &b).max_abs_diff(&gemm::matmul(&a, &b)) < 1e-8);
    }

    #[test]
    fn rectangular_shapes() {
        let mut rng = Rng::new(3);
        let a = random(&mut rng, BASE + 40, BASE + 3);
        let b = random(&mut rng, BASE + 3, BASE + 90);
        assert!(strassen(&a, &b).max_abs_diff(&gemm::matmul(&a, &b)) < 1e-8);
    }
}

//! The naive O(N^3)-per-iterate baseline (paper §1.1): evaluate eq. (15)
//! by building `Sigma_y`, factorizing it, and forming the quadratic form —
//! exactly the procedure the spectral identities replace.  The Jacobian
//! uses the trace identity `d log|S|/dtheta = tr(S^{-1} dS/dtheta)` with
//! the O(N^3) products the paper describes.

use crate::linalg::{gemm, Cholesky, Matrix};
use crate::spectral::HyperParams;

/// Dense evaluator over a fixed `(K, y)` pair.  Every [`score`] /
/// [`score_grad`] call is O(N^3) — this is the baseline the Figure 1-3 and
/// speed-up benches compare against.
///
/// [`score`]: NaiveEvaluator::score
/// [`score_grad`]: NaiveEvaluator::score_grad
pub struct NaiveEvaluator {
    k: Matrix,
    y: Vec<f64>,
    yy: f64,
}

impl NaiveEvaluator {
    pub fn new(k: Matrix, y: Vec<f64>) -> Self {
        assert!(k.is_square());
        assert_eq!(k.rows(), y.len());
        let yy = y.iter().map(|v| v * v).sum();
        NaiveEvaluator { k, y, yy }
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// `W = (K + sigma2/lambda2 I)^{-1}` via Cholesky (O(N^3)).
    fn w_inverse(&self, hp: HyperParams) -> Matrix {
        let mut m = self.k.clone();
        m.add_diag(hp.sigma2 / hp.lambda2);
        Cholesky::new(&m)
            .expect("K + rI must be SPD for r > 0")
            .inverse()
    }

    /// `Sigma_y = sigma2 (K W + I)` (eq. 11).
    pub fn sigma_y(&self, hp: HyperParams) -> Matrix {
        let w = self.w_inverse(hp);
        let mut sy = gemm::matmul(&self.k, &w);
        sy.add_diag(1.0);
        sy.scale(hp.sigma2);
        sy.symmetrize(); // guard factorization against accumulation asymmetry
        sy
    }

    /// Eq. (15): `log|Sigma_y| + (mu_y - y)' Sigma_y^{-1} (mu_y - y)`.
    /// One O(N^3) inverse + one O(N^3) factorization, as in §1.1.
    pub fn score(&self, hp: HyperParams) -> f64 {
        let w = self.w_inverse(hp);
        let kw = gemm::matmul(&self.k, &w);
        let mut sy = kw.clone();
        sy.add_diag(1.0);
        sy.scale(hp.sigma2);
        sy.symmetrize();
        let ch = Cholesky::new(&sy).expect("Sigma_y must be SPD");
        // mu_y - y = (K W - I) y
        let mu = kw.matvec(&self.y);
        let r: Vec<f64> = mu.iter().zip(&self.y).map(|(m, yi)| m - yi).collect();
        ch.logdet() + ch.quad_form(&r)
    }

    /// Score and Jacobian via the dense trace identities.  Uses the
    /// eq. (16) form whose theta-dependence is explicit:
    /// `L = log|Sy| + sigma^-4 y'Sy y + 4 y'Sy^{-1} y - 4 y'y / sigma2`.
    pub fn score_grad(&self, hp: HyperParams) -> (f64, [f64; 2]) {
        let HyperParams { sigma2, lambda2 } = hp;
        let n = self.n();
        let w = self.w_inverse(hp);
        let kw = gemm::matmul(&self.k, &w);
        let mut sy = kw.clone();
        sy.add_diag(1.0);
        sy.scale(sigma2);
        sy.symmetrize();
        let ch = Cholesky::new(&sy).expect("Sigma_y must be SPD");
        let sy_inv = ch.inverse();

        // derivative of Sigma_y:
        //   dSy/dsigma2 = (K W + I) - (sigma2/lambda2) K W W
        //   dSy/dlambda2 = (sigma4/lambda4) K W W
        let kww = gemm::matmul(&kw, &w);
        let mut dsy_ds = kw.clone();
        dsy_ds.add_diag(1.0);
        {
            let coef = sigma2 / lambda2;
            let kww_d = kww.data();
            let out = dsy_ds.data_mut();
            for (o, &k) in out.iter_mut().zip(kww_d) {
                *o -= coef * k;
            }
        }
        let mut dsy_dl = kww.clone();
        dsy_dl.scale(sigma2 * sigma2 / (lambda2 * lambda2));

        // score (eq. 16 form)
        let sy_y = sy.matvec(&self.y);
        let y_sy_y: f64 = self.y.iter().zip(&sy_y).map(|(a, b)| a * b).sum();
        let syinv_y = sy_inv.matvec(&self.y);
        let y_syinv_y: f64 = self.y.iter().zip(&syinv_y).map(|(a, b)| a * b).sum();
        let s4 = sigma2 * sigma2;
        let score =
            ch.logdet() + y_sy_y / s4 + 4.0 * y_syinv_y - 4.0 * self.yy / sigma2;

        // gradient pieces shared by both components
        let grad_for = |dsy: &Matrix, is_sigma: bool| -> f64 {
            // tr(Sy^{-1} dSy)
            let mut tr = 0.0;
            for i in 0..n {
                tr += crate::linalg::dot(sy_inv.row(i), &dsy.col(i));
            }
            // y' dSy y / sigma4
            let dsy_y = dsy.matvec(&self.y);
            let y_dsy_y: f64 = self.y.iter().zip(&dsy_y).map(|(a, b)| a * b).sum();
            // -4 y' Sy^{-1} dSy Sy^{-1} y
            let t = dsy.matvec(&syinv_y);
            let quad: f64 = syinv_y.iter().zip(&t).map(|(a, b)| a * b).sum();
            let mut g = tr + y_dsy_y / s4 - 4.0 * quad;
            if is_sigma {
                g += -2.0 * y_sy_y / (s4 * sigma2) + 4.0 * self.yy / s4;
            }
            g
        };

        let gs = grad_for(&dsy_ds, true);
        let gl = grad_for(&dsy_dl, false);
        (score, [gs, gl])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelfn::{gram, Kernel};
    use crate::spectral::SpectralGp;
    use crate::util::proptest::{check_close, forall};
    use crate::util::rng::Rng;

    fn setup(n: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
        let y = rng.normal_vec(n);
        (x, y)
    }

    #[test]
    fn naive_score_matches_spectral() {
        forall(
            "naive == spectral score",
            41,
            8,
            |r| {
                let n = 10 + r.below(40);
                let seed = r.next_u64();
                let hp = HyperParams::new(r.uniform_in(0.1, 3.0), r.uniform_in(0.1, 3.0));
                (n, seed, hp)
            },
            |&(n, seed, hp)| {
                let (x, y) = setup(n, seed);
                let kern = Kernel::Rbf { xi2: 1.2 };
                let k = gram(kern, &x);
                let naive = NaiveEvaluator::new(k, y.clone());
                let gp = SpectralGp::fit(kern, x).unwrap();
                let es = gp.eigensystem(&y);
                check_close("score", naive.score(hp), es.score(hp), 1e-7, 1e-9)
            },
        );
    }

    #[test]
    fn naive_grad_matches_spectral() {
        forall(
            "naive grad == spectral grad",
            43,
            6,
            |r| {
                let n = 10 + r.below(30);
                let seed = r.next_u64();
                let hp = HyperParams::new(r.uniform_in(0.3, 2.0), r.uniform_in(0.3, 2.0));
                (n, seed, hp)
            },
            |&(n, seed, hp)| {
                let (x, y) = setup(n, seed);
                let kern = Kernel::Rbf { xi2: 1.0 };
                let k = gram(kern, &x);
                let naive = NaiveEvaluator::new(k, y.clone());
                let gp = SpectralGp::fit(kern, x).unwrap();
                let es = gp.eigensystem(&y);
                let (sc, g) = naive.score_grad(hp);
                check_close("score", sc, es.score(hp), 1e-7, 1e-9)?;
                let gs = es.grad(hp);
                check_close("dsigma2", g[0], gs[0], 1e-6, 1e-8)?;
                check_close("dlambda2", g[1], gs[1], 1e-6, 1e-8)
            },
        );
    }

    #[test]
    fn score_grad_score_consistent_with_score() {
        let (x, y) = setup(25, 7);
        let k = gram(Kernel::Rbf { xi2: 2.0 }, &x);
        let ev = NaiveEvaluator::new(k, y);
        let hp = HyperParams::new(0.8, 1.2);
        let (sc, _) = ev.score_grad(hp);
        assert!((sc - ev.score(hp)).abs() < 1e-8);
    }
}

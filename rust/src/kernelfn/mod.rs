//! Kernel functions and Gram-matrix construction (paper §1 eq. 3, §2.2).
//!
//! The [`Kernel`] enum covers the families the paper names (RBF with
//! bandwidth `xi^2`, polynomial with degree `l`, linear) plus Matérn 3/2
//! and 5/2 for the examples.  The rust builders here are the CPU fallback;
//! the PJRT `gram` artifact (Layer 1 `kernelmat.py`) computes the same
//! matrices through XLA and is cross-checked against these in integration
//! tests.

use crate::linalg::microkernel::{self, KernelBackend};
use crate::linalg::Matrix;
use crate::util::threadpool;

/// Minimum kernel evaluations a pool worker must have before `gram` /
/// `cross_gram` fan out (an RBF eval is ~20 ns; this keeps the spawn
/// cost well under 1% of each worker's share).
const PAR_GRAIN_EVALS: usize = 4096;

/// The search space of a kernel family's tunable hyperparameter `theta`
/// (see [`Kernel::with_theta`] / [`Kernel::theta_domain`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThetaDomain {
    /// A positive real (RBF bandwidth, Matérn length-scale): continuous
    /// line/bracket searches apply.
    Continuous,
    /// An integer >= 1 (polynomial degree): continuous probes round and
    /// alias — search must sweep the discrete values instead.
    Integer,
    /// No tunable theta (linear kernel).
    Fixed,
}

/// Capacity of the fixed-size theta vector: enough for ARD over the
/// feature dimensions any current caller uses, small enough that
/// [`ThetaVec`] stays `Copy` and allocation-free inside the O(N^2)
/// `gram` inner loops and the engine's cache keys.
pub const MAX_THETA_DIMS: usize = 8;

/// The canonical hyperparameter coordinate of the tuning engine: a small
/// fixed-capacity vector of theta components.  Scalar kernel families
/// are 1-component vectors; ARD families carry one component per feature
/// dimension.  Unused capacity is zero-filled so derived equality and
/// [`ThetaVec::bits`] are well-defined.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ThetaVec {
    len: usize,
    vals: [f64; MAX_THETA_DIMS],
}

impl ThetaVec {
    /// A 1-component vector (the scalar-theta compatibility embedding).
    pub fn scalar(t: f64) -> ThetaVec {
        let mut vals = [0.0; MAX_THETA_DIMS];
        vals[0] = t;
        ThetaVec { len: 1, vals }
    }

    /// `len` copies of `v`.  Panics unless `1 <= len <= MAX_THETA_DIMS`
    /// (callers validate user-supplied lengths first).
    pub fn splat(len: usize, v: f64) -> ThetaVec {
        assert!((1..=MAX_THETA_DIMS).contains(&len), "theta dims {len} out of 1..={MAX_THETA_DIMS}");
        let mut vals = [0.0; MAX_THETA_DIMS];
        vals[..len].fill(v);
        ThetaVec { len, vals }
    }

    /// Build from a slice; errors when the length is outside
    /// `1..=MAX_THETA_DIMS` (the wire/CLI validation path).
    pub fn from_slice(v: &[f64]) -> Result<ThetaVec, String> {
        if v.is_empty() || v.len() > MAX_THETA_DIMS {
            return Err(format!("theta has {} components (supported: 1..={MAX_THETA_DIMS})", v.len()));
        }
        let mut vals = [0.0; MAX_THETA_DIMS];
        vals[..v.len()].copy_from_slice(v);
        Ok(ThetaVec { len: v.len(), vals })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Component `i` (panics past `len`, like slice indexing).
    pub fn get(&self, i: usize) -> f64 {
        assert!(i < self.len, "theta component {i} out of 0..{}", self.len);
        self.vals[i]
    }

    pub fn set(&mut self, i: usize, v: f64) {
        assert!(i < self.len, "theta component {i} out of 0..{}", self.len);
        self.vals[i] = v;
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.vals[..self.len]
    }

    /// The concatenated per-component bit patterns — the engine's and the
    /// eigen-family cache's key.  `-0.0` is canonicalized to `+0.0` first
    /// so the two zero representations cannot key distinct cache entries
    /// for the same setup.
    pub fn bits(&self) -> ThetaVecBits {
        let mut bits = [0u64; MAX_THETA_DIMS];
        for (slot, &v) in bits.iter_mut().zip(&self.vals[..self.len]) {
            let canon = if v == 0.0 { 0.0 } else { v };
            *slot = canon.to_bits();
        }
        ThetaVecBits { len: self.len, bits }
    }
}

/// Hashable cache key derived from a [`ThetaVec`] (see [`ThetaVec::bits`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ThetaVecBits {
    len: usize,
    bits: [u64; MAX_THETA_DIMS],
}

impl ThetaVecBits {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Per-component search domains of a kernel family's theta vector.
/// `len == 0` means the family has no tunable theta at all (linear).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ThetaDomainVec {
    len: usize,
    doms: [ThetaDomain; MAX_THETA_DIMS],
}

impl ThetaDomainVec {
    /// The no-theta domain (linear kernel).
    pub fn fixed() -> ThetaDomainVec {
        ThetaDomainVec { len: 0, doms: [ThetaDomain::Fixed; MAX_THETA_DIMS] }
    }

    /// A 1-component domain (scalar families).
    pub fn scalar(d: ThetaDomain) -> ThetaDomainVec {
        ThetaDomainVec::uniform(1, d)
    }

    /// `len` copies of the same domain.  Panics unless
    /// `1 <= len <= MAX_THETA_DIMS`.
    pub fn uniform(len: usize, d: ThetaDomain) -> ThetaDomainVec {
        assert!((1..=MAX_THETA_DIMS).contains(&len), "theta dims {len} out of 1..={MAX_THETA_DIMS}");
        let mut doms = [ThetaDomain::Fixed; MAX_THETA_DIMS];
        doms[..len].fill(d);
        ThetaDomainVec { len, doms }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn get(&self, i: usize) -> ThetaDomain {
        assert!(i < self.len, "theta component {i} out of 0..{}", self.len);
        self.doms[i]
    }
}

/// A positive-definite kernel function `K(x, y)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// `exp(-||x - y||^2 / (2 xi2))`
    Rbf { xi2: f64 },
    /// ARD RBF `exp(-Σ_d (x_d - y_d)^2 / (2 xi2_d))`: one bandwidth per
    /// feature dimension.  `xi2.len()` must equal the feature count of
    /// the data it is evaluated on (the coordinator validates this at
    /// session creation).
    RbfArd { xi2: ThetaVec },
    /// `(<x, y> + 1)^degree`
    Polynomial { degree: u32 },
    /// `<x, y>`
    Linear,
    /// Matérn nu=3/2 with length-scale `ell`.
    Matern32 { ell: f64 },
    /// Matérn nu=5/2 with length-scale `ell`.
    Matern52 { ell: f64 },
}

impl Kernel {
    /// Evaluate on two feature vectors.
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        match *self {
            Kernel::Rbf { xi2 } => {
                let d2: f64 = x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum();
                (-d2 / (2.0 * xi2)).exp()
            }
            Kernel::RbfArd { xi2 } => {
                debug_assert_eq!(x.len(), xi2.len(), "ARD dims != feature dims");
                let xs = xi2.as_slice();
                let mut e = 0.0;
                for d in 0..x.len().min(xs.len()) {
                    let diff = x[d] - y[d];
                    e += diff * diff / (2.0 * xs[d]);
                }
                (-e).exp()
            }
            Kernel::Polynomial { degree } => {
                let ip: f64 = x.iter().zip(y).map(|(a, b)| a * b).sum();
                (ip + 1.0).powi(degree as i32)
            }
            Kernel::Linear => x.iter().zip(y).map(|(a, b)| a * b).sum(),
            Kernel::Matern32 { ell } => {
                let d = dist(x, y);
                let t = 3f64.sqrt() * d / ell;
                (1.0 + t) * (-t).exp()
            }
            Kernel::Matern52 { ell } => {
                let d = dist(x, y);
                let t = 5f64.sqrt() * d / ell;
                (1.0 + t + t * t / 3.0) * (-t).exp()
            }
        }
    }

    /// The `[family, theta]` encoding of the PJRT gram artifact, if this
    /// family is artifact-backed (RBF / polynomial / linear).
    pub fn artifact_code(&self) -> Option<[f64; 2]> {
        match *self {
            Kernel::Rbf { xi2 } => Some([0.0, xi2]),
            Kernel::Polynomial { degree } => Some([1.0, degree as f64]),
            Kernel::Linear => Some([2.0, 0.0]),
            _ => None,
        }
    }

    /// Replace the tunable kernel hyperparameter (Algorithm 1's `theta`).
    ///
    /// `Polynomial` is a **discrete** family: the continuous `theta` is
    /// rounded to the nearest integer degree (clamped to >= 1, non-finite
    /// inputs clamp to 1), so distinct continuous probes closer than 0.5
    /// alias to the *same* kernel.  A continuous line search over a
    /// polynomial theta therefore re-scores identical setups; use
    /// [`Kernel::theta_domain`] to pick a discrete sweep instead (the
    /// theta-plane engine in `optim::two_step` does this automatically).
    pub fn with_theta(&self, theta: f64) -> Kernel {
        match *self {
            Kernel::Rbf { .. } => Kernel::Rbf { xi2: theta },
            // scalar shim over the ARD family: broadcast to every dimension
            Kernel::RbfArd { xi2 } => Kernel::RbfArd { xi2: ThetaVec::splat(xi2.len(), theta) },
            Kernel::Polynomial { .. } => {
                let degree = if theta.is_finite() { theta.round().max(1.0) as u32 } else { 1 };
                Kernel::Polynomial { degree }
            }
            Kernel::Linear => Kernel::Linear,
            Kernel::Matern32 { .. } => Kernel::Matern32 { ell: theta },
            Kernel::Matern52 { .. } => Kernel::Matern52 { ell: theta },
        }
    }

    /// Vector counterpart of [`Kernel::with_theta`]: replace the whole
    /// theta vector.  Scalar families read component 0; `Polynomial`
    /// keeps its rounding/clamping guards.  `t.len()` must equal
    /// [`Kernel::theta_dims`] (callers validate; a mismatched ARD length
    /// panics via [`ThetaVec::get`] rather than silently truncating).
    pub fn with_theta_vec(&self, t: &ThetaVec) -> Kernel {
        match *self {
            Kernel::RbfArd { xi2 } => {
                assert_eq!(t.len(), xi2.len(), "theta dims != ARD dims");
                Kernel::RbfArd { xi2: *t }
            }
            Kernel::Linear => Kernel::Linear,
            _ => self.with_theta(t.get(0)),
        }
    }

    /// What kind of parameter Algorithm 1's outer search moves for this
    /// family — the family-awareness hook of the theta-plane engine.
    /// ARD families report the domain of a *single* component here; use
    /// [`Kernel::theta_vec_domain`] for the full per-component picture.
    pub fn theta_domain(&self) -> ThetaDomain {
        match *self {
            Kernel::Rbf { .. }
            | Kernel::RbfArd { .. }
            | Kernel::Matern32 { .. }
            | Kernel::Matern52 { .. } => ThetaDomain::Continuous,
            Kernel::Polynomial { .. } => ThetaDomain::Integer,
            Kernel::Linear => ThetaDomain::Fixed,
        }
    }

    /// Number of tunable theta components (0 for linear).
    pub fn theta_dims(&self) -> usize {
        match *self {
            Kernel::RbfArd { xi2 } => xi2.len(),
            Kernel::Linear => 0,
            _ => 1,
        }
    }

    /// Per-component search domains of the theta vector (empty for
    /// linear) — the vector counterpart of [`Kernel::theta_domain`].
    pub fn theta_vec_domain(&self) -> ThetaDomainVec {
        match *self {
            Kernel::RbfArd { xi2 } => ThetaDomainVec::uniform(xi2.len(), ThetaDomain::Continuous),
            Kernel::Linear => ThetaDomainVec::fixed(),
            _ => ThetaDomainVec::scalar(self.theta_domain()),
        }
    }

    /// The tunable hyperparameter value, if any.  ARD families are
    /// scalar-addressable only when they have exactly one dimension; use
    /// [`Kernel::theta_vec`] otherwise.
    pub fn theta(&self) -> Option<f64> {
        match *self {
            Kernel::Rbf { xi2 } => Some(xi2),
            Kernel::RbfArd { xi2 } if xi2.len() == 1 => Some(xi2.get(0)),
            Kernel::RbfArd { .. } => None,
            Kernel::Polynomial { degree } => Some(degree as f64),
            Kernel::Linear => None,
            Kernel::Matern32 { ell } => Some(ell),
            Kernel::Matern52 { ell } => Some(ell),
        }
    }

    /// The theta vector (scalar families as 1-component vectors; `None`
    /// for linear).
    pub fn theta_vec(&self) -> Option<ThetaVec> {
        match *self {
            Kernel::RbfArd { xi2 } => Some(xi2),
            Kernel::Linear => None,
            _ => self.theta().map(ThetaVec::scalar),
        }
    }
}

fn dist(x: &[f64], y: &[f64]) -> f64 {
    x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt()
}

/// Fast-path parameters for the RBF/ARD gram builders (DESIGN.md §14):
/// the per-dimension divisor that maps the inputs onto the isotropic
/// `xi2 = 1` problem (`None` = no rescale) and the exponent scale
/// `neg_inv = -1 / (2 xi2)`.  `None` overall = family not blocked
/// (Matérn/polynomial/linear keep the per-pair [`Kernel::eval`] path).
fn rbf_fast_params(kernel: &Kernel, p: usize) -> Option<(Option<Vec<f64>>, f64)> {
    match *kernel {
        Kernel::Rbf { xi2 } => Some((None, -1.0 / (2.0 * xi2))),
        // dividing by sqrt(xi2_d) up front is bitwise the rescaled-inputs
        // construction the ARD differential gate (verify/mod.rs) and the
        // ARD unit tests build by hand
        Kernel::RbfArd { xi2 } if xi2.len() == p => {
            Some((Some(xi2.as_slice().iter().map(|v| v.sqrt()).collect()), -0.5))
        }
        _ => None,
    }
}

/// One input matrix preprocessed for the RBF fast path: (rescaled)
/// row-major data, its feature-major transpose (one feature per
/// contiguous row — the broadcast-FMA axpy streams it), and per-row
/// squared norms via the `sq_chain` FMA fold (which bitwise matches the
/// row kernel's own self-inner-product, making the gram diagonal exactly
/// 1.0).
struct RbfSide {
    xd: Vec<f64>,
    xt: Vec<f64>,
    sq: Vec<f64>,
}

impl RbfSide {
    fn build(kb: KernelBackend, x: &Matrix, scale: Option<&[f64]>) -> RbfSide {
        let (rows, p) = (x.rows(), x.cols());
        if p == 0 {
            return RbfSide { xd: vec![], xt: vec![], sq: vec![0.0; rows] };
        }
        let mut xd = x.data().to_vec();
        if let Some(s) = scale {
            for row in xd.chunks_mut(p) {
                for (v, &sd) in row.iter_mut().zip(s) {
                    *v /= sd;
                }
            }
        }
        let mut xt = vec![0.0f64; p * rows];
        for (i, row) in xd.chunks(p).enumerate() {
            for (d, &v) in row.iter().enumerate() {
                xt[d * rows + i] = v;
            }
        }
        let sq = xd.chunks(p).map(|r| microkernel::sq_chain_with(kb, r)).collect();
        RbfSide { xd, xt, sq }
    }
}

/// Full Gram matrix `K[i, j] = K(x_i, x_j)` (eq. 3); exploits symmetry.
///
/// Row-block parallel (DESIGN.md §6): phase 1 fills each row's upper
/// triangle `j >= i` (workers own disjoint rows; the dynamic cursor in
/// `par_for` balances the triangular row costs), phase 2 mirrors the
/// strict upper triangle down (row `i` writes `j < i` reading `(j, i)`,
/// which phase 2 never writes).  Per-element arithmetic never depends on
/// the partition, so output is bit-identical across thread counts.
///
/// RBF and ARD grams take the blocked fast path (DESIGN.md §14): the
/// squared distance expands as `||x_i||^2 + ||x_j||^2 - 2 <x_i, x_j>`
/// with the inner products accumulated by rank-p broadcast-FMA axpy over
/// the transposed inputs and the exponential applied by the fixed
/// `exp_fixed` pass — bitwise identical across `GPML_KERNEL` backends,
/// with the diagonal exactly 1.0 (see `RbfSide`).  ARD rescales the
/// inputs by `1/sqrt(xi2_d)` up front and runs the isotropic path.
/// Other families keep the per-pair [`Kernel::eval`] loop.
pub fn gram(kernel: Kernel, x: &Matrix) -> Matrix {
    let n = x.rows();
    let mut k = Matrix::zeros(n, n);
    if n == 0 {
        return k;
    }
    let p = x.cols();
    let grain = (PAR_GRAIN_EVALS / n).max(1);
    let fast = rbf_fast_params(&kernel, p);
    // backend resolved once, on the calling thread (pool workers don't
    // inherit the scoped override)
    let kb = microkernel::default_kernel_backend();
    let shared = threadpool::SharedMut::new(k.data_mut());
    if let Some((scale, neg_inv)) = fast {
        let side = RbfSide::build(kb, x, scale.as_deref());
        threadpool::par_for(n, grain, |i| {
            // Safety: phase-1 worker `i` writes only row `i`.
            let row = unsafe { shared.slice_mut(i * n + i, (i + 1) * n) };
            let xi = &side.xd[i * p..(i + 1) * p];
            for (d, &xid) in xi.iter().enumerate() {
                microkernel::fma_axpy_with(kb, row, xid, &side.xt[d * n + i..(d + 1) * n]);
            }
            microkernel::rbf_finish_with(kb, row, side.sq[i], &side.sq[i..], neg_inv);
        });
    } else {
        threadpool::par_for(n, grain, |i| {
            // Safety: phase-1 worker `i` writes only row `i`.
            let row = unsafe { shared.slice_mut(i * n, (i + 1) * n) };
            let xi = x.row(i);
            for (j, slot) in row.iter_mut().enumerate().skip(i) {
                *slot = kernel.eval(xi, x.row(j));
            }
        });
    }
    threadpool::par_for(n, grain, |i| {
        // Safety: phase-2 worker `i` writes `(i, j)` strictly below the
        // diagonal and reads `(j, i)` strictly above it — the write and
        // read sets are disjoint across all workers.
        for j in 0..i {
            unsafe { shared.write(i * n + j, shared.read(j * n + i)) };
        }
    });
    k
}

/// Cross-Gram `K[i, j] = K(a_i, b_j)` for prediction (`k_x~` rows, eq. 4).
/// Row-block parallel like [`gram`] (disjoint output rows), with the
/// same RBF/ARD fast path; `cross_gram(k, x, x)` is bitwise equal to
/// `gram(k, x)` (the inner-product FMA chains commute per element).
pub fn cross_gram(kernel: Kernel, a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "feature dims differ");
    let (m, n) = (a.rows(), b.rows());
    let mut k = Matrix::zeros(m, n);
    if m == 0 || n == 0 {
        return k;
    }
    let p = a.cols();
    let rows_per_chunk = (PAR_GRAIN_EVALS / n).max(1);
    if let Some((scale, neg_inv)) = rbf_fast_params(&kernel, p) {
        let kb = microkernel::default_kernel_backend();
        let aside = RbfSide::build(kb, a, scale.as_deref());
        let bside = RbfSide::build(kb, b, scale.as_deref());
        threadpool::par_chunks_mut(k.data_mut(), rows_per_chunk * n, |ci, chunk| {
            let i0 = ci * rows_per_chunk;
            for (r, row) in chunk.chunks_mut(n).enumerate() {
                let i = i0 + r;
                let ai = &aside.xd[i * p..(i + 1) * p];
                for (d, &aid) in ai.iter().enumerate() {
                    microkernel::fma_axpy_with(kb, row, aid, &bside.xt[d * n..(d + 1) * n]);
                }
                microkernel::rbf_finish_with(kb, row, aside.sq[i], &bside.sq, neg_inv);
            }
        });
        return k;
    }
    threadpool::par_chunks_mut(k.data_mut(), rows_per_chunk * n, |ci, chunk| {
        let i0 = ci * rows_per_chunk;
        for (r, row) in chunk.chunks_mut(n).enumerate() {
            let ai = a.row(i0 + r);
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = kernel.eval(ai, b.row(j));
            }
        }
    });
    k
}

/// Parse `--kernel` CLI syntax: `rbf:1.5`, `rbf-ard:1.0,2.0,0.5`,
/// `poly:3`, `linear`, `matern32:0.8`, `matern52:1.2`.
pub fn parse_kernel(s: &str) -> Result<Kernel, String> {
    let (name, arg) = match s.split_once(':') {
        Some((n, a)) => (n, Some(a)),
        None => (s, None),
    };
    let num = |default: f64| -> Result<f64, String> {
        match arg {
            None => Ok(default),
            Some(a) => a.parse().map_err(|_| format!("bad kernel parameter '{a}'")),
        }
    };
    match name {
        "rbf" => Ok(Kernel::Rbf { xi2: num(1.0)? }),
        "rbf-ard" | "rbfard" => {
            let a = arg.ok_or_else(|| {
                "rbf-ard needs comma-separated bandwidths, e.g. rbf-ard:1.0,2.0".to_string()
            })?;
            let vals: Vec<f64> = a
                .split(',')
                .map(|p| p.trim().parse().map_err(|_| format!("bad kernel parameter '{p}'")))
                .collect::<Result<_, String>>()?;
            if vals.iter().any(|v| !(v.is_finite() && *v > 0.0)) {
                return Err(format!("rbf-ard bandwidths must be positive and finite, got '{a}'"));
            }
            Ok(Kernel::RbfArd { xi2: ThetaVec::from_slice(&vals)? })
        }
        "poly" | "polynomial" => Ok(Kernel::Polynomial { degree: num(2.0)? as u32 }),
        "linear" => Ok(Kernel::Linear),
        "matern32" => Ok(Kernel::Matern32 { ell: num(1.0)? }),
        "matern52" => Ok(Kernel::Matern52 { ell: num(1.0)? }),
        _ => Err(format!("unknown kernel '{name}' (rbf|rbf-ard|poly|linear|matern32|matern52)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::SymEigen;
    use crate::util::rng::Rng;

    fn random_x(rng: &mut Rng, n: usize, p: usize) -> Matrix {
        Matrix::from_fn(n, p, |_, _| rng.normal())
    }

    #[test]
    fn rbf_diagonal_is_one_and_symmetric() {
        let mut rng = Rng::new(1);
        let x = random_x(&mut rng, 20, 4);
        let k = gram(Kernel::Rbf { xi2: 2.0 }, &x);
        for i in 0..20 {
            assert!((k[(i, i)] - 1.0).abs() < 1e-14);
            for j in 0..20 {
                assert_eq!(k[(i, j)], k[(j, i)]);
                assert!(k[(i, j)] > 0.0 && k[(i, j)] <= 1.0);
            }
        }
    }

    #[test]
    fn rbf_gram_is_psd() {
        let mut rng = Rng::new(2);
        let x = random_x(&mut rng, 30, 3);
        let k = gram(Kernel::Rbf { xi2 : 1.0 }, &x);
        let eg = SymEigen::new(&k).unwrap();
        assert!(eg.values[0] > -1e-9, "min eigenvalue {}", eg.values[0]);
    }

    #[test]
    fn matern_gram_is_psd() {
        let mut rng = Rng::new(3);
        let x = random_x(&mut rng, 25, 2);
        for kern in [Kernel::Matern32 { ell: 0.7 }, Kernel::Matern52 { ell: 1.3 }] {
            let k = gram(kern, &x);
            let eg = SymEigen::new(&k).unwrap();
            assert!(eg.values[0] > -1e-9, "{kern:?}: min {}", eg.values[0]);
        }
    }

    #[test]
    fn polynomial_matches_formula() {
        let k = Kernel::Polynomial { degree: 3 };
        let v = k.eval(&[1.0, 2.0], &[0.5, -1.0]);
        assert!((v - (1.0 * 0.5 + 2.0 * (-1.0) + 1.0f64).powi(3)).abs() < 1e-14);
    }

    #[test]
    fn linear_matches_dot() {
        assert_eq!(Kernel::Linear.eval(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn cross_gram_consistent_with_gram() {
        let mut rng = Rng::new(4);
        let x = random_x(&mut rng, 12, 3);
        let kern = Kernel::Rbf { xi2: 1.5 };
        let full = gram(kern, &x);
        let cross = cross_gram(kern, &x, &x);
        assert!(full.max_abs_diff(&cross) < 1e-15);
    }

    #[test]
    fn matern_limits() {
        // at distance 0 both Matérn kernels are 1
        let x = [0.3, -0.2];
        assert!((Kernel::Matern32 { ell: 1.0 }.eval(&x, &x) - 1.0).abs() < 1e-15);
        assert!((Kernel::Matern52 { ell: 1.0 }.eval(&x, &x) - 1.0).abs() < 1e-15);
        // monotone decreasing in distance
        let k = Kernel::Matern52 { ell: 1.0 };
        let near = k.eval(&[0.0], &[0.1]);
        let far = k.eval(&[0.0], &[2.0]);
        assert!(near > far);
    }

    #[test]
    fn parse_kernel_syntax() {
        assert_eq!(parse_kernel("rbf:2.5").unwrap(), Kernel::Rbf { xi2: 2.5 });
        assert_eq!(parse_kernel("poly:3").unwrap(), Kernel::Polynomial { degree: 3 });
        assert_eq!(parse_kernel("linear").unwrap(), Kernel::Linear);
        assert_eq!(parse_kernel("matern32:0.5").unwrap(), Kernel::Matern32 { ell: 0.5 });
        assert!(parse_kernel("cubic").is_err());
        assert!(parse_kernel("rbf:abc").is_err());
    }

    #[test]
    fn artifact_codes() {
        assert_eq!(Kernel::Rbf { xi2: 1.5 }.artifact_code(), Some([0.0, 1.5]));
        assert_eq!(Kernel::Polynomial { degree: 2 }.artifact_code(), Some([1.0, 2.0]));
        assert_eq!(Kernel::Linear.artifact_code(), Some([2.0, 0.0]));
        assert_eq!(Kernel::Matern32 { ell: 1.0 }.artifact_code(), None);
    }

    #[test]
    fn with_theta_roundtrip() {
        let k = Kernel::Rbf { xi2: 1.0 }.with_theta(3.5);
        assert_eq!(k.theta(), Some(3.5));
    }

    #[test]
    fn with_theta_polynomial_rounds_and_guards() {
        let p = Kernel::Polynomial { degree: 2 };
        // continuous probes alias to the nearest integer degree
        assert_eq!(p.with_theta(2.9), Kernel::Polynomial { degree: 3 });
        assert_eq!(p.with_theta(3.2), Kernel::Polynomial { degree: 3 });
        // guarded: never below degree 1, non-finite clamps to 1
        assert_eq!(p.with_theta(0.1), Kernel::Polynomial { degree: 1 });
        assert_eq!(p.with_theta(-4.0), Kernel::Polynomial { degree: 1 });
        assert_eq!(p.with_theta(f64::NAN), Kernel::Polynomial { degree: 1 });
        assert_eq!(p.with_theta(f64::INFINITY), Kernel::Polynomial { degree: 1 });
    }

    #[test]
    fn theta_domains_per_family() {
        assert_eq!(Kernel::Rbf { xi2: 1.0 }.theta_domain(), ThetaDomain::Continuous);
        assert_eq!(Kernel::Matern32 { ell: 1.0 }.theta_domain(), ThetaDomain::Continuous);
        assert_eq!(Kernel::Matern52 { ell: 1.0 }.theta_domain(), ThetaDomain::Continuous);
        assert_eq!(Kernel::Polynomial { degree: 2 }.theta_domain(), ThetaDomain::Integer);
        assert_eq!(Kernel::Linear.theta_domain(), ThetaDomain::Fixed);
    }

    #[test]
    fn theta_vec_roundtrip_and_dims() {
        let tv = ThetaVec::from_slice(&[1.0, 2.0, 0.5]).unwrap();
        let k = Kernel::RbfArd { xi2: tv };
        assert_eq!(k.theta_dims(), 3);
        assert_eq!(k.theta_vec(), Some(tv));
        assert_eq!(k.theta(), None, "multi-dim ARD has no scalar theta");
        let dom = k.theta_vec_domain();
        assert_eq!(dom.len(), 3);
        for i in 0..3 {
            assert_eq!(dom.get(i), ThetaDomain::Continuous);
        }
        // scalar families embed as 1-vectors
        let r = Kernel::Rbf { xi2: 1.5 };
        assert_eq!(r.theta_dims(), 1);
        assert_eq!(r.theta_vec(), Some(ThetaVec::scalar(1.5)));
        assert_eq!(r.theta_vec_domain().len(), 1);
        assert_eq!(Kernel::Linear.theta_dims(), 0);
        assert_eq!(Kernel::Linear.theta_vec(), None);
        assert!(Kernel::Linear.theta_vec_domain().is_empty());
    }

    #[test]
    fn with_theta_vec_matches_scalar_shims() {
        let t2 = ThetaVec::from_slice(&[0.7, 3.0]).unwrap();
        let ard = Kernel::RbfArd { xi2: ThetaVec::splat(2, 1.0) };
        assert_eq!(ard.with_theta_vec(&t2), Kernel::RbfArd { xi2: t2 });
        // scalar broadcast over the ARD family
        assert_eq!(ard.with_theta(2.5), Kernel::RbfArd { xi2: ThetaVec::splat(2, 2.5) });
        // 1-component vectors reduce to with_theta exactly
        for k in [Kernel::Rbf { xi2: 1.0 }, Kernel::Matern32 { ell: 1.0 }] {
            assert_eq!(k.with_theta_vec(&ThetaVec::scalar(0.3)), k.with_theta(0.3));
        }
        assert_eq!(
            Kernel::Polynomial { degree: 2 }.with_theta_vec(&ThetaVec::scalar(3.4)),
            Kernel::Polynomial { degree: 3 }
        );
        assert_eq!(Kernel::Linear.with_theta_vec(&ThetaVec::scalar(9.0)), Kernel::Linear);
    }

    #[test]
    fn theta_vec_bits_canonicalize_negative_zero() {
        assert_ne!((-0.0f64).to_bits(), 0.0f64.to_bits(), "premise");
        assert_eq!(ThetaVec::scalar(-0.0).bits(), ThetaVec::scalar(0.0).bits());
        let a = ThetaVec::from_slice(&[1.0, -0.0]).unwrap();
        let b = ThetaVec::from_slice(&[1.0, 0.0]).unwrap();
        assert_eq!(a.bits(), b.bits());
        // distinct values still key distinct entries
        assert_ne!(ThetaVec::scalar(1.0).bits(), ThetaVec::scalar(2.0).bits());
        assert_ne!(a.bits(), ThetaVec::scalar(1.0).bits(), "length is part of the key");
    }

    #[test]
    fn ard_gram_equals_isotropic_gram_on_rescaled_inputs() {
        let mut rng = Rng::new(5);
        let x = random_x(&mut rng, 16, 3);
        let xi2 = [0.7, 1.6, 2.5];
        let ard = gram(Kernel::RbfArd { xi2: ThetaVec::from_slice(&xi2).unwrap() }, &x);
        let xs = Matrix::from_fn(16, 3, |i, j| x[(i, j)] / xi2[j].sqrt());
        let iso = gram(Kernel::Rbf { xi2: 1.0 }, &xs);
        assert!(ard.max_abs_diff(&iso) < 1e-12, "diff {}", ard.max_abs_diff(&iso));
    }

    #[test]
    fn ard_gram_is_psd_and_uniform_ard_matches_rbf() {
        let mut rng = Rng::new(6);
        let x = random_x(&mut rng, 20, 4);
        let ard = gram(Kernel::RbfArd { xi2: ThetaVec::splat(4, 2.0) }, &x);
        let eg = SymEigen::new(&ard).unwrap();
        assert!(eg.values[0] > -1e-9, "min eigenvalue {}", eg.values[0]);
        // equal bandwidths reduce to the isotropic kernel (same arithmetic
        // up to the division placement, so compare to tight tolerance)
        let iso = gram(Kernel::Rbf { xi2: 2.0 }, &x);
        assert!(ard.max_abs_diff(&iso) < 1e-13);
    }

    #[test]
    fn parse_rbf_ard_syntax() {
        assert_eq!(
            parse_kernel("rbf-ard:1.0,2.0,0.5").unwrap(),
            Kernel::RbfArd { xi2: ThetaVec::from_slice(&[1.0, 2.0, 0.5]).unwrap() }
        );
        assert!(parse_kernel("rbf-ard").is_err(), "bandwidths required");
        assert!(parse_kernel("rbf-ard:1.0,abc").is_err());
        assert!(parse_kernel("rbf-ard:1.0,-2.0").is_err(), "positive only");
        assert!(parse_kernel("rbf-ard:1,1,1,1,1,1,1,1,1").is_err(), "over capacity");
    }
}

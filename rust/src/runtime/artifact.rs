//! Artifact manifest: what `python/compile/aot.py` produced, which bucket
//! serves which dataset size, and zero-padding helpers.

use crate::util::json::{self, Json};

/// One compiled HLO-text artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    /// Layer-2 entry point: score | fused | batched_score | gram |
    /// posterior_var_diag.
    pub entry: String,
    /// Eigenvalue-vector bucket size.
    pub n: usize,
    /// Hyperparameter batch size (batched_score only).
    pub b: usize,
    /// Feature padding (gram only).
    pub p: usize,
}

/// The parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dtype: String,
    pub b_batch: usize,
    pub p_pad: usize,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let v = json::parse(text)?;
        let dtype = v
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or("manifest: missing dtype")?
            .to_string();
        let b_batch = v.get("b_batch").and_then(Json::as_usize).unwrap_or(0);
        let p_pad = v.get("p_pad").and_then(Json::as_usize).unwrap_or(0);
        let arts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or("manifest: missing artifacts array")?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            artifacts.push(ArtifactInfo {
                name: a
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or("artifact: missing name")?
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or("artifact: missing file")?
                    .to_string(),
                entry: a
                    .get("entry")
                    .and_then(Json::as_str)
                    .ok_or("artifact: missing entry")?
                    .to_string(),
                n: a.get("n").and_then(Json::as_usize).unwrap_or(0),
                b: a.get("b").and_then(Json::as_usize).unwrap_or(0),
                p: a.get("p").and_then(Json::as_usize).unwrap_or(0),
            });
        }
        Ok(Manifest { dtype, b_batch, p_pad, artifacts })
    }

    /// Smallest artifact of `entry` whose bucket holds `n` points.
    pub fn bucket_for(&self, entry: &str, n: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| a.entry == entry && a.n >= n)
            .min_by_key(|a| a.n)
    }

    /// All bucket sizes available for an entry (ascending).
    pub fn buckets(&self, entry: &str) -> Vec<usize> {
        let mut v: Vec<usize> =
            self.artifacts.iter().filter(|a| a.entry == entry).map(|a| a.n).collect();
        v.sort_unstable();
        v
    }
}

/// Zero-pad a vector to `len` (the neutrality of zero eigenvalues /
/// projections is property-tested on both the python and rust sides).
pub fn zero_pad(v: &[f64], len: usize) -> Vec<f64> {
    assert!(v.len() <= len, "cannot pad {} down to {}", v.len(), len);
    let mut out = Vec::with_capacity(len);
    out.extend_from_slice(v);
    out.resize(len, 0.0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "dtype": "f64", "b_batch": 64, "p_pad": 32,
      "artifacts": [
        {"name": "score_n32", "file": "score_n32.hlo.txt", "entry": "score", "n": 32},
        {"name": "score_n64", "file": "score_n64.hlo.txt", "entry": "score", "n": 64},
        {"name": "batched_b64_n32", "file": "b.hlo.txt", "entry": "batched_score", "n": 32, "b": 64},
        {"name": "gram_n32_p32", "file": "g.hlo.txt", "entry": "gram", "n": 32, "p": 32}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.dtype, "f64");
        assert_eq!(m.b_batch, 64);
        assert_eq!(m.artifacts.len(), 4);
        assert_eq!(m.artifacts[2].b, 64);
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.bucket_for("score", 10).unwrap().n, 32);
        assert_eq!(m.bucket_for("score", 32).unwrap().n, 32);
        assert_eq!(m.bucket_for("score", 33).unwrap().n, 64);
        assert!(m.bucket_for("score", 65).is_none());
        assert!(m.bucket_for("missing", 1).is_none());
        assert_eq!(m.buckets("score"), vec![32, 64]);
    }

    #[test]
    fn zero_pad_extends() {
        assert_eq!(zero_pad(&[1.0, 2.0], 4), vec![1.0, 2.0, 0.0, 0.0]);
        assert_eq!(zero_pad(&[], 2), vec![0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "cannot pad")]
    fn zero_pad_rejects_shrink() {
        zero_pad(&[1.0, 2.0, 3.0], 2);
    }

    #[test]
    fn rejects_malformed_manifest() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"dtype": "f64"}"#).is_err());
        assert!(Manifest::parse(r#"{"dtype": "f64", "artifacts": [{"file": "x"}]}"#).is_err());
    }
}

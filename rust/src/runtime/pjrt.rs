//! The real PJRT-backed runtime (`pjrt` feature).  Compiled executables
//! are cached per artifact; the [`PjrtEvaluator`] additionally pre-stages
//! the padded eigensystem as device buffers so each score evaluation only
//! uploads the (tiny) hyperparameter literal.
//!
//! Requires the external `xla` crate (not vendored in the offline image —
//! DESIGN.md §5).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::kernelfn::Kernel;
use crate::linalg::Matrix;
use crate::optim::Objective;
use crate::runtime::artifact::{zero_pad, ArtifactInfo, Manifest};
use crate::spectral::{EigenSystem, Evaluation, HyperParams};

/// Lazily-compiling artifact runtime over the CPU PJRT client.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    /// Executions performed (for perf accounting).
    pub dispatches: std::cell::Cell<usize>,
}

impl PjrtRuntime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
        })?;
        let manifest = Manifest::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            dispatches: std::cell::Cell::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Fetch (compiling on first use) the executable for an artifact.
    fn executable(&self, info: &ArtifactInfo) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&info.name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(info.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Force-compile every artifact of the given entries (warm start).
    pub fn warm(&self, entries: &[&str]) -> Result<usize> {
        let mut count = 0;
        let infos: Vec<ArtifactInfo> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| entries.contains(&a.entry.as_str()))
            .cloned()
            .collect();
        for info in infos {
            self.executable(&info)?;
            count += 1;
        }
        Ok(count)
    }

    fn run(&self, info: &ArtifactInfo, args: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self.executable(info)?;
        self.dispatches.set(self.dispatches.get() + 1);
        let out = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple1()?)
    }

    fn run_buffers(&self, info: &ArtifactInfo, args: &[&xla::PjRtBuffer]) -> Result<xla::Literal> {
        let exe = self.executable(info)?;
        self.dispatches.set(self.dispatches.get() + 1);
        let out = exe.execute_b::<&xla::PjRtBuffer>(args)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple1()?)
    }

    /// Stage a host vector on device.
    fn stage(&self, v: &[f64]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(v, &[v.len()], None)?)
    }

    fn stage_scalar(&self, v: f64) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
    }

    /// One-shot score evaluation (unstaged; prefer [`PjrtEvaluator`] in
    /// loops). Eq. (19) through the `score_n*` artifact.
    pub fn score(&self, es: &EigenSystem, hp: HyperParams) -> Result<f64> {
        let info = self
            .manifest
            .bucket_for("score", es.s.len())
            .ok_or_else(|| anyhow!("no score bucket >= {}", es.s.len()))?
            .clone();
        let out = self.run(
            &info,
            &[
                xla::Literal::vec1(&zero_pad(&es.s, info.n)),
                xla::Literal::vec1(&zero_pad(&es.y2t, info.n)),
                xla::Literal::vec1(&[hp.sigma2, hp.lambda2]),
                xla::Literal::scalar(es.n as f64),
                xla::Literal::scalar(es.yy),
            ],
        )?;
        Ok(out.to_vec::<f64>()?[0])
    }

    /// Build a Gram matrix through the `gram_n*` artifact (kernel families
    /// with an artifact encoding only). Output is the exact N x N block.
    pub fn gram(&self, x: &Matrix, kernel: Kernel) -> Result<Matrix> {
        let code = kernel
            .artifact_code()
            .ok_or_else(|| anyhow!("kernel {kernel:?} has no gram artifact"))?;
        let n = x.rows();
        let info = self
            .manifest
            .bucket_for("gram", n)
            .ok_or_else(|| anyhow!("no gram bucket >= {n}"))?
            .clone();
        if x.cols() > info.p {
            return Err(anyhow!(
                "feature dim {} exceeds artifact padding {}",
                x.cols(),
                info.p
            ));
        }
        // zero-pad rows and feature columns
        let mut flat = vec![0.0; info.n * info.p];
        for i in 0..n {
            flat[i * info.p..i * info.p + x.cols()].copy_from_slice(x.row(i));
        }
        let xpad = xla::Literal::vec1(&flat).reshape(&[info.n as i64, info.p as i64])?;
        let out = self.run(&info, &[xpad, xla::Literal::vec1(&code)])?;
        let full = out.to_vec::<f64>()?;
        Ok(Matrix::from_fn(n, n, |i, j| full[i * info.n + j]))
    }

    /// Prop. 2.4 posterior-variance diagonal through the `pvar_n*`
    /// artifact.  `u` is the eigenvector matrix, `s` the eigenvalues.
    pub fn posterior_var_diag(&self, u: &Matrix, s: &[f64], hp: HyperParams) -> Result<Vec<f64>> {
        let n = s.len();
        let info = self
            .manifest
            .bucket_for("posterior_var_diag", n)
            .ok_or_else(|| anyhow!("no pvar bucket >= {n}"))?
            .clone();
        let mut flat = vec![0.0; info.n * info.n];
        for i in 0..n {
            flat[i * info.n..i * info.n + n].copy_from_slice(u.row(i));
        }
        let upad = xla::Literal::vec1(&flat).reshape(&[info.n as i64, info.n as i64])?;
        let out = self.run(
            &info,
            &[
                upad,
                xla::Literal::vec1(&zero_pad(s, info.n)),
                xla::Literal::vec1(&[hp.sigma2, hp.lambda2]),
            ],
        )?;
        Ok(out.to_vec::<f64>()?[..n].to_vec())
    }

    /// Build a staged evaluator for repeated evaluations over one
    /// eigensystem (the tuning hot path).
    pub fn evaluator(&self, es: &EigenSystem) -> Result<PjrtEvaluator<'_>> {
        let score_info = self
            .manifest
            .bucket_for("score", es.s.len())
            .ok_or_else(|| anyhow!("no score bucket >= {}", es.s.len()))?
            .clone();
        let fused_info = self
            .manifest
            .bucket_for("fused", es.s.len())
            .ok_or_else(|| anyhow!("no fused bucket >= {}", es.s.len()))?
            .clone();
        let batched_info = self.manifest.bucket_for("batched_score", es.s.len()).cloned();
        let n_bucket = score_info.n;
        let s_pad = zero_pad(&es.s, n_bucket);
        let y2_pad = zero_pad(&es.y2t, n_bucket);
        Ok(PjrtEvaluator {
            rt: self,
            score_info,
            fused_info,
            batched_info,
            s_buf: self.stage(&s_pad)?,
            y2_buf: self.stage(&y2_pad)?,
            n_buf: self.stage_scalar(es.n as f64)?,
            yy_buf: self.stage_scalar(es.yy)?,
        })
    }
}

/// Staged per-eigensystem evaluator: eigenvalues / projections / closure
/// scalars live on device; each call uploads only the hyperparameters.
/// Implements [`Objective`], so every optimizer in [`crate::optim`] can
/// run against the AOT artifacts directly.
pub struct PjrtEvaluator<'r> {
    rt: &'r PjrtRuntime,
    score_info: ArtifactInfo,
    fused_info: ArtifactInfo,
    batched_info: Option<ArtifactInfo>,
    s_buf: xla::PjRtBuffer,
    y2_buf: xla::PjRtBuffer,
    n_buf: xla::PjRtBuffer,
    yy_buf: xla::PjRtBuffer,
}

impl<'r> PjrtEvaluator<'r> {
    /// Batch width of the batched-score artifact (the global-search
    /// wavefront size), if available.
    pub fn batch_width(&self) -> Option<usize> {
        self.batched_info.as_ref().map(|i| i.b)
    }

    /// Bucket the eigensystem was padded to.
    pub fn bucket(&self) -> usize {
        self.score_info.n
    }

    pub fn try_eval(&self, hp: HyperParams) -> Result<f64> {
        let hp_buf = self.rt.stage(&[hp.sigma2, hp.lambda2])?;
        let out = self.rt.run_buffers(
            &self.score_info,
            &[&self.s_buf, &self.y2_buf, &hp_buf, &self.n_buf, &self.yy_buf],
        )?;
        Ok(out.to_vec::<f64>()?[0])
    }

    pub fn try_eval_full(&self, hp: HyperParams) -> Result<Evaluation> {
        let hp_buf = self.rt.stage(&[hp.sigma2, hp.lambda2])?;
        let out = self.rt.run_buffers(
            &self.fused_info,
            &[&self.s_buf, &self.y2_buf, &hp_buf, &self.n_buf, &self.yy_buf],
        )?;
        let v = out.to_vec::<f64>()?;
        Ok(EigenSystem::evaluation_from_fused(&v))
    }

    /// Evaluate up to `b` points in one dispatch through the
    /// `batched_b*_n*` artifact; larger slices are chunked.
    pub fn try_eval_batch(&self, hps: &[HyperParams]) -> Result<Vec<f64>> {
        let Some(info) = &self.batched_info else {
            // no batched artifact for this bucket: scalar fallback
            return hps.iter().map(|&h| self.try_eval(h)).collect();
        };
        let b = info.b;
        let mut out = Vec::with_capacity(hps.len());
        for chunk in hps.chunks(b) {
            // pad the batch with copies of the first point
            let mut flat = Vec::with_capacity(b * 2);
            for hp in chunk {
                flat.push(hp.sigma2);
                flat.push(hp.lambda2);
            }
            for _ in chunk.len()..b {
                flat.push(chunk[0].sigma2);
                flat.push(chunk[0].lambda2);
            }
            let hps_buf = self.rt.client.buffer_from_host_buffer(&flat, &[b, 2], None)?;
            let res = self.rt.run_buffers(
                info,
                &[&self.s_buf, &self.y2_buf, &hps_buf, &self.n_buf, &self.yy_buf],
            )?;
            let v = res.to_vec::<f64>()?;
            out.extend_from_slice(&v[..chunk.len()]);
        }
        Ok(out)
    }
}

impl<'r> Objective for PjrtEvaluator<'r> {
    fn eval(&mut self, hp: HyperParams) -> f64 {
        self.try_eval(hp).expect("PJRT score dispatch failed")
    }
    fn eval_batch(&mut self, hps: &[HyperParams]) -> Vec<f64> {
        self.try_eval_batch(hps).expect("PJRT batched dispatch failed")
    }
    fn eval_full(&mut self, hp: HyperParams) -> Evaluation {
        self.try_eval_full(hp).expect("PJRT fused dispatch failed")
    }
}

//! API-compatible stand-in for the PJRT runtime, compiled unless both the
//! `pjrt` and `pjrt-xla` features are on (the default in the offline
//! image — DESIGN.md §5; `--features pjrt` alone is the stub-only build
//! CI's feature-matrix job exercises).
//!
//! [`PjrtRuntime::open`] always fails, and both types are uninhabited
//! (they carry an [`Infallible`] field), so no value can ever exist and
//! every other method is provably unreachable: callers — the coordinator,
//! benches, examples and integration tests — compile unchanged and
//! degrade to the pure-rust spectral evaluator at runtime.

use std::convert::Infallible;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::kernelfn::Kernel;
use crate::linalg::Matrix;
use crate::optim::Objective;
use crate::runtime::Manifest;
use crate::spectral::{EigenSystem, Evaluation, HyperParams};

const STUB: &str = "PjrtRuntime stub is uninhabited (pjrt feature disabled)";

/// Uninhabited stand-in for the artifact runtime.
pub struct PjrtRuntime {
    #[allow(dead_code)] // uninhabits the type; never read
    never: Infallible,
    /// Executions performed (API parity with the real runtime).
    pub dispatches: std::cell::Cell<usize>,
}

impl PjrtRuntime {
    /// Always fails: the build has no PJRT client.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        Err(anyhow!(
            "PJRT runtime unavailable: gpml was built without the real pjrt client \
             (artifact dir {}); rebuild with `--features pjrt-xla` and a vendored `xla` crate",
            dir.as_ref().display()
        ))
    }

    pub fn manifest(&self) -> &Manifest {
        unreachable!("{STUB}")
    }

    pub fn warm(&self, _entries: &[&str]) -> Result<usize> {
        unreachable!("{STUB}")
    }

    pub fn score(&self, _es: &EigenSystem, _hp: HyperParams) -> Result<f64> {
        unreachable!("{STUB}")
    }

    pub fn gram(&self, _x: &Matrix, _kernel: Kernel) -> Result<Matrix> {
        unreachable!("{STUB}")
    }

    pub fn posterior_var_diag(&self, _u: &Matrix, _s: &[f64], _hp: HyperParams) -> Result<Vec<f64>> {
        unreachable!("{STUB}")
    }

    pub fn evaluator(&self, _es: &EigenSystem) -> Result<PjrtEvaluator<'_>> {
        unreachable!("{STUB}")
    }
}

/// Uninhabited stand-in for the staged evaluator.
pub struct PjrtEvaluator<'r> {
    #[allow(dead_code)] // uninhabits the type; never read
    never: Infallible,
    _rt: std::marker::PhantomData<&'r PjrtRuntime>,
}

impl<'r> PjrtEvaluator<'r> {
    pub fn batch_width(&self) -> Option<usize> {
        unreachable!("{STUB}")
    }

    pub fn bucket(&self) -> usize {
        unreachable!("{STUB}")
    }

    pub fn try_eval(&self, _hp: HyperParams) -> Result<f64> {
        unreachable!("{STUB}")
    }

    pub fn try_eval_full(&self, _hp: HyperParams) -> Result<Evaluation> {
        unreachable!("{STUB}")
    }

    pub fn try_eval_batch(&self, _hps: &[HyperParams]) -> Result<Vec<f64>> {
        unreachable!("{STUB}")
    }
}

impl<'r> Objective for PjrtEvaluator<'r> {
    fn eval(&mut self, _hp: HyperParams) -> f64 {
        unreachable!("{STUB}")
    }
    fn eval_batch(&mut self, _hps: &[HyperParams]) -> Vec<f64> {
        unreachable!("{STUB}")
    }
    fn eval_full(&mut self, _hp: HyperParams) -> Evaluation {
        unreachable!("{STUB}")
    }
}

#[cfg(test)]
mod tests {
    use super::PjrtRuntime;

    #[test]
    fn open_reports_missing_feature() {
        let err = PjrtRuntime::open("artifacts").unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("pjrt"), "{text}");
        assert!(text.contains("artifacts"), "{text}");
    }
}

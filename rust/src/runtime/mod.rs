//! PJRT runtime layer: load the AOT HLO-text artifacts and execute them on
//! the CPU PJRT client — Python never runs here (DESIGN.md §2).
//!
//! The artifact manifest ([`artifact`]) is plain rust and always compiles.
//! The runtime itself has two implementations selected by cargo features:
//!
//! - `pjrt.rs` (`pjrt` **and** `pjrt-xla` on): the real client.  Pattern
//!   follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//!   -> `XlaComputation::from_proto` -> `client.compile` -> `execute`,
//!   with per-artifact executable caching and pre-staged device buffers.
//!   Requires the `xla` crate, which is not vendored in the offline image
//!   (DESIGN.md §5) — enabling `pjrt-xla` without it will not build.
//! - `stub.rs` (otherwise — including `--features pjrt` alone, the
//!   stub-only build CI's feature-matrix job compiles): the same public
//!   API where [`PjrtRuntime::open`] always fails, so the coordinator,
//!   benches and examples compile unchanged and degrade to the pure-rust
//!   evaluator.

pub mod artifact;

#[cfg(all(feature = "pjrt", feature = "pjrt-xla"))]
mod pjrt;
#[cfg(all(feature = "pjrt", feature = "pjrt-xla"))]
pub use pjrt::{PjrtEvaluator, PjrtRuntime};

#[cfg(not(all(feature = "pjrt", feature = "pjrt-xla")))]
mod stub;
#[cfg(not(all(feature = "pjrt", feature = "pjrt-xla")))]
pub use stub::{PjrtEvaluator, PjrtRuntime};

pub use artifact::{zero_pad, ArtifactInfo, Manifest};

use std::path::PathBuf;

/// Default artifact directory: `$GPML_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("GPML_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

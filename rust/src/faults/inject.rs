//! Deterministic fault injection (test-only; cargo feature
//! `fault-inject`).  Nothing in this module exists in a default build —
//! every call site is `#[cfg(feature = "fault-inject")]`-gated, so the
//! production binary carries zero injection branches.
//!
//! Injection is counter-scheduled, not random: arming a point with
//! `(every, limit)` makes every `every`-th traversal of that point fire,
//! at most `limit` times, regardless of thread interleaving — the chaos
//! suite gets a reproducible fault schedule without clocks or RNG state.
//! (Slow-client, oversized-request and mid-request-disconnect faults
//! need no server-side hook: the chaos tests drive those straight from
//! misbehaving client sockets.)

use std::sync::atomic::{AtomicU64, Ordering};

/// Server-side points where a fault can be made to fire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// The eigensolver call inside the degradation ladder reports
    /// `NoConvergence` instead of decomposing.
    EigenNoConvergence = 0,
    /// A pool worker panics after dequeuing a job (outside the per-job
    /// `catch_unwind`), exercising the supervisor respawn path.
    WorkerPanic = 1,
    /// Job dispatch stalls for [`slow_dispatch_ms`] before executing,
    /// exercising the per-request deadline.
    SlowDispatch = 2,
    /// A merge step of the divide-and-conquer tridiagonal solver
    /// reports `NoConvergence`, exercising the degradation ladder
    /// through the D&C path specifically (the clean attempt and each
    /// jitter rung traverse this point once per merge).
    DacMergeNoConvergence = 3,
}

const POINTS: usize = 4;

// Per-point schedule: fire on every `EVERY`-th traversal (0 = disarmed),
// at most `LIMIT` times; `SEEN`/`FIRED` are the traversal/fire counters.
#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
static EVERY: [AtomicU64; POINTS] = [ZERO; POINTS];
static LIMIT: [AtomicU64; POINTS] = [ZERO; POINTS];
static SEEN: [AtomicU64; POINTS] = [ZERO; POINTS];
static FIRED: [AtomicU64; POINTS] = [ZERO; POINTS];
static SLOW_MS: AtomicU64 = AtomicU64::new(0);

/// Arm `point`: every `every`-th traversal fires, at most `limit` times.
/// `every = 1` fires on the next `limit` traversals; `every = 10` models
/// a 10% fault rate.  Re-arming resets the point's counters.
pub fn arm(point: FaultPoint, every: u64, limit: u64) {
    let i = point as usize;
    SEEN[i].store(0, Ordering::SeqCst);
    FIRED[i].store(0, Ordering::SeqCst);
    LIMIT[i].store(limit, Ordering::SeqCst);
    EVERY[i].store(every, Ordering::SeqCst);
}

/// Disarm every point and zero all counters.
pub fn reset() {
    for i in 0..POINTS {
        EVERY[i].store(0, Ordering::SeqCst);
        LIMIT[i].store(0, Ordering::SeqCst);
        SEEN[i].store(0, Ordering::SeqCst);
        FIRED[i].store(0, Ordering::SeqCst);
    }
    SLOW_MS.store(0, Ordering::SeqCst);
}

/// Called by instrumented code at the injection point; true = inject.
pub fn fire(point: FaultPoint) -> bool {
    let i = point as usize;
    let every = EVERY[i].load(Ordering::SeqCst);
    if every == 0 {
        return false;
    }
    let seen = SEEN[i].fetch_add(1, Ordering::SeqCst) + 1;
    if seen % every != 0 {
        return false;
    }
    // claim one of the `limit` firings atomically
    loop {
        let fired = FIRED[i].load(Ordering::SeqCst);
        if fired >= LIMIT[i].load(Ordering::SeqCst) {
            return false;
        }
        if FIRED[i]
            .compare_exchange(fired, fired + 1, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            return true;
        }
    }
}

/// How many times `point` has fired since it was last armed.
pub fn fired(point: FaultPoint) -> u64 {
    FIRED[point as usize].load(Ordering::SeqCst)
}

/// How many times `point` has been traversed since it was last armed.
pub fn seen(point: FaultPoint) -> u64 {
    SEEN[point as usize].load(Ordering::SeqCst)
}

/// Stall duration for [`FaultPoint::SlowDispatch`] firings.
pub fn set_slow_dispatch_ms(ms: u64) {
    SLOW_MS.store(ms, Ordering::SeqCst);
}

pub fn slow_dispatch_ms() -> u64 {
    SLOW_MS.load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;

    // the schedule is process-global; serialize tests that touch it
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn schedule_is_counter_driven() {
        let _g = LOCK.lock().unwrap();
        reset();
        arm(FaultPoint::EigenNoConvergence, 3, 2);
        let fires: Vec<bool> =
            (0..9).map(|_| fire(FaultPoint::EigenNoConvergence)).collect();
        // fires on traversals 3 and 6; limit 2 stops traversal 9
        assert_eq!(
            fires,
            vec![false, false, true, false, false, true, false, false, false]
        );
        assert_eq!(fired(FaultPoint::EigenNoConvergence), 2);
        assert_eq!(seen(FaultPoint::EigenNoConvergence), 9);
        reset();
        assert!(!fire(FaultPoint::EigenNoConvergence));
    }

    #[test]
    fn points_are_independent() {
        let _g = LOCK.lock().unwrap();
        reset();
        arm(FaultPoint::WorkerPanic, 1, 1);
        assert!(!fire(FaultPoint::EigenNoConvergence));
        assert!(fire(FaultPoint::WorkerPanic));
        assert!(!fire(FaultPoint::WorkerPanic));
        reset();
    }
}

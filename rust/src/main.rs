//! `gpml` CLI — tune GP hyperparameters via the paper's O(N) spectral
//! identities, serve tuning jobs over TCP, or inspect the artifact
//! runtime.

use anyhow::{anyhow, Result};

use gpml::coordinator::{
    client::{Client, ClientOptions},
    server::{Server, ServerOptions},
    session::{SessionTuneRequest, ThetaTuneRequest},
    Backend, Coordinator, GlobalStrategy, ObjectiveKind, TuneRequest,
};
use gpml::optim::{RefineKind, ThetaSearch};
use gpml::data;
use gpml::kernelfn::{self, Kernel, ThetaVec};
use gpml::runtime::{default_artifact_dir, PjrtRuntime};
use gpml::spectral::{HyperParams, SpectralGp};
use gpml::util::cli::Args;

const USAGE: &str = "\
gpml — Efficient Marginal Likelihood Computation for GP Regression (Schirru et al., 2011)

USAGE:
  gpml tune   --data <csv> [--kernel rbf:2.0] [--backend rust|pjrt]
              [--strategy pso|grid] [--particles 64] [--iterations 25] [--grid 17]
              [--evidence] [--predict] [--threads N]
                                      tune (sigma2, lambda2) per y* column;
                                      --evidence swaps the paper's eq. 19 score
                                      for the classical GP evidence
  gpml synth  --n 256 --p 8 [--sigma2 0.05] [--lambda2 1.0] [--outputs 1]
              [--seed 42] --out <csv> generate a synthetic GP dataset
  gpml serve  [--addr 127.0.0.1:7070] [--no-pjrt] [--workers N]
              [--cache-sessions K] [--cache-bytes 1g]
              [--request-timeout 30000] [--max-queue 128]
              [--max-line-bytes 32m]
                                      run the tuning coordinator server;
                                      sessions cache the O(N^3) setup across
                                      requests (LRU, K entries / byte budget),
                                      N pool workers serve pure-rust jobs;
                                      requests past --request-timeout ms get
                                      a structured deadline error, load past
                                      --max-queue queued jobs is shed with
                                      overloaded + retry_after_ms, request
                                      lines are capped at --max-line-bytes
  gpml client --addr <host:port> --data <csv> [tune options]
              [--session] [--append <csv>] [--stats]
              [--retries 3] [--connect-timeout 10000] [--read-timeout 300000]
              [--tune-theta] [--theta-min 0.01] [--theta-max 100]
              [--theta-dims D] [--outer 20]
              [--theta-search wavefront|golden|nelder-mead|pso]
              [--wavefront 8] [--inner-grid 9] [--refine newton|none]
                                      submit a tuning job to a server;
                                      --session creates/reuses a server-side
                                      session first (warm requests skip the
                                      setup), --append streams extra
                                      observations into the session via
                                      update_session (rank-one refresh)
                                      before tuning, --stats prints cache
                                      statistics (incl. the theta_* family-
                                      cache counters), --tune-theta runs
                                      Algorithm 1 over the kernel theta
                                      through the server's eigen-family
                                      cache (parallel outer wavefronts;
                                      repeat sweeps are warm and bitwise
                                      identical; requires --session),
                                      --theta-dims D expands an rbf kernel
                                      to a D-lengthscale rbf-ard family
                                      swept by coordinate descent,
                                      --refine none skips the exact-Hessian
                                      Newton polish at the outer optimum
  gpml bench-gate --current <BENCH_x.json> --baseline <json> [--tolerance 1.25]
              [--write-baseline]      CI perf gate: fail if any series'
                                      median regresses past tolerance;
                                      --write-baseline instead rewrites the
                                      --baseline file from --current medians
  gpml info   [--artifacts <dir>]     list compiled artifacts and buckets
  gpml help                           this text

  --threads N (any command) sets the scoped-pool width for the O(N^3)
  setup and search wavefronts (DESIGN.md §6); 1 = exact serial, default =
  GPML_THREADS or all cores.

  GPML_KERNEL={auto,simd,scalar} picks the microkernel backend for the
  O(N^3) setup kernels (DESIGN.md §14); the backends are bitwise
  identical, and `simd` degrades to `scalar` off AVX2+FMA hardware.

  Protocol reference: docs/PROTOCOL.md.  Quickstart: README.md.
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    // pool width for every parallel substrate in this process
    // (DESIGN.md §6); per-request widths can still override via the
    // coordinator protocol's "threads" field
    match args.get_usize("threads", 0) {
        Ok(t) => gpml::util::threadpool::set_threads(t),
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    }
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "tune" => cmd_tune(&args),
        "synth" => cmd_synth(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "bench-gate" => cmd_bench_gate(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}'\n{USAGE}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_common(args: &Args) -> Result<(Kernel, Backend, GlobalStrategy, u64)> {
    let mut kernel = kernelfn::parse_kernel(args.get_or("kernel", "rbf:1.0"))
        .map_err(|e| anyhow!(e))?;
    // `--theta-dims D` expands an isotropic rbf into a D-lengthscale ARD
    // family (every lengthscale starts at the isotropic xi2); rbf-ard
    // kernels spell their dimension in the kernel string itself
    let theta_dims = args.get_usize("theta-dims", 0).map_err(|e| anyhow!(e))?;
    if theta_dims >= 1 {
        kernel = match kernel {
            Kernel::Rbf { xi2 } => Kernel::RbfArd {
                xi2: ThetaVec::from_slice(&vec![xi2; theta_dims]).map_err(|e| anyhow!(e))?,
            },
            Kernel::RbfArd { xi2 } if xi2.len() == theta_dims => Kernel::RbfArd { xi2 },
            other => {
                return Err(anyhow!(
                    "--theta-dims {theta_dims} expands an isotropic rbf kernel, got {other:?}"
                ))
            }
        };
    }
    let backend = match args.get_or("backend", "rust") {
        "rust" => Backend::Rust,
        "pjrt" => Backend::Pjrt,
        other => return Err(anyhow!("unknown backend '{other}'")),
    };
    let strategy = match args.get_or("strategy", "pso") {
        "grid" => GlobalStrategy::Grid {
            points_per_axis: args.get_usize("grid", 17).map_err(|e| anyhow!(e))?,
        },
        "pso" => GlobalStrategy::Pso {
            particles: args.get_usize("particles", 64).map_err(|e| anyhow!(e))?,
            iterations: args.get_usize("iterations", 25).map_err(|e| anyhow!(e))?,
        },
        other => return Err(anyhow!("unknown strategy '{other}'")),
    };
    let seed = args.get_usize("seed", 42).map_err(|e| anyhow!(e))? as u64;
    Ok((kernel, backend, strategy, seed))
}

fn load_request(args: &Args) -> Result<TuneRequest> {
    let path = args.get("data").ok_or_else(|| anyhow!("--data <csv> is required"))?;
    let ds = data::read_csv(path).map_err(|e| anyhow!(e))?;
    let (kernel, backend, strategy, seed) = parse_common(args)?;
    let mut req = TuneRequest::new(ds.x, ds.ys, kernel);
    req.backend = backend;
    req.strategy = strategy;
    req.seed = seed;
    // carried in the request so `gpml client` jobs pin the width on the
    // server side too
    req.threads = args.get_usize("threads", 0).map_err(|e| anyhow!(e))?;
    if args.flag("evidence") {
        req.objective = ObjectiveKind::Evidence;
    }
    Ok(req)
}

fn cmd_tune(args: &Args) -> Result<()> {
    let req = load_request(args)?;
    let n = req.x.rows();
    let mut coord = match req.backend {
        Backend::Pjrt => Coordinator::with_runtime(PjrtRuntime::open(
            args.get("artifacts").map(Into::into).unwrap_or_else(default_artifact_dir),
        )?),
        Backend::Rust => Coordinator::rust_only(),
    };
    println!(
        "tuning N={} P={} outputs={} kernel={:?} backend={:?}",
        n,
        req.x.cols(),
        req.ys.len(),
        req.kernel,
        req.backend
    );
    let res = coord.tune(&req)?;
    println!(
        "overhead: gram {:.3}s + eigendecomposition {:.3}s (cached: {})",
        res.gram_seconds, res.eigen_seconds, res.eigen_cached
    );
    println!("tuning:   {:.3}s for {} output(s)", res.tune_seconds, res.outputs.len());
    for (i, o) in res.outputs.iter().enumerate() {
        println!(
            "  y{i}: sigma2={:.6e} lambda2={:.6e} score={:.6} (global {} + newton {} evals, converged={})",
            o.hp.sigma2, o.hp.lambda2, o.score, o.global_evals, o.newton_evals, o.converged
        );
    }
    if args.flag("predict") {
        // in-sample fit quality, using the tuned hyperparameters
        let gp = SpectralGp::fit(req.kernel, req.x.clone())
            .map_err(|e| anyhow!("eigensolver: {e}"))?;
        for (i, (y, o)) in req.ys.iter().zip(&res.outputs).enumerate() {
            let hp = HyperParams::new(o.hp.sigma2, o.hp.lambda2);
            let mu = gp.posterior_mean_train(y, hp);
            println!("  y{i}: in-sample rmse = {:.6}", data::rmse(&mu, y));
        }
    }
    Ok(())
}

fn cmd_synth(args: &Args) -> Result<()> {
    let out = args.get("out").ok_or_else(|| anyhow!("--out <csv> is required"))?;
    let kernel = kernelfn::parse_kernel(args.get_or("kernel", "rbf:2.0")).map_err(|e| anyhow!(e))?;
    let spec = data::SyntheticSpec {
        n: args.get_usize("n", 256).map_err(|e| anyhow!(e))?,
        p: args.get_usize("p", 8).map_err(|e| anyhow!(e))?,
        kernel,
        sigma2: args.get_f64("sigma2", 0.05).map_err(|e| anyhow!(e))?,
        lambda2: args.get_f64("lambda2", 1.0).map_err(|e| anyhow!(e))?,
        seed: args.get_usize("seed", 42).map_err(|e| anyhow!(e))? as u64,
    };
    let outputs = args.get_usize("outputs", 1).map_err(|e| anyhow!(e))?;
    let ds = data::synthetic(spec, outputs);
    data::write_csv(out, &ds)?;
    println!("wrote {} rows x ({} features + {} outputs) to {out}", ds.n(), ds.p(), outputs);
    println!("true hyperparameters: sigma2={} lambda2={}", spec.sigma2, spec.lambda2);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7070").to_string();
    let no_pjrt = args.flag("no-pjrt");
    let opts = ServerOptions {
        workers: args.get_usize("workers", 0).map_err(|e| anyhow!(e))?,
        max_sessions: args
            .get_usize("cache-sessions", ServerOptions::DEFAULT_MAX_SESSIONS)
            .map_err(|e| anyhow!(e))?,
        max_bytes: args
            .get_bytes("cache-bytes", ServerOptions::DEFAULT_MAX_BYTES)
            .map_err(|e| anyhow!(e))?,
        request_timeout: std::time::Duration::from_millis(
            args.get_usize(
                "request-timeout",
                ServerOptions::DEFAULT_REQUEST_TIMEOUT.as_millis() as usize,
            )
            .map_err(|e| anyhow!(e))? as u64,
        ),
        max_queue: args
            .get_usize("max-queue", ServerOptions::DEFAULT_MAX_QUEUE)
            .map_err(|e| anyhow!(e))?,
        max_line_bytes: args
            .get_bytes("max-line-bytes", ServerOptions::DEFAULT_MAX_LINE_BYTES)
            .map_err(|e| anyhow!(e))?,
    };
    let artifacts: std::path::PathBuf =
        args.get("artifacts").map(Into::into).unwrap_or_else(default_artifact_dir);
    let server = Server::start_with(&addr, opts, move || {
        if no_pjrt {
            Coordinator::rust_only()
        } else {
            match PjrtRuntime::open(&artifacts) {
                Ok(rt) => {
                    eprintln!("serving with PJRT artifacts from {}", artifacts.display());
                    Coordinator::with_runtime(rt)
                }
                Err(e) => {
                    eprintln!("no artifacts ({e:#}); serving rust-only");
                    Coordinator::rust_only()
                }
            }
        }
    })?;
    println!("gpml coordinator listening on {}", server.addr);
    println!(
        "workers: {} | session cache: {} entries / {} bytes",
        server.workers(),
        opts.max_sessions,
        opts.max_bytes
    );
    println!(
        "deadline: {} ms | queue bound: {} jobs | line cap: {} bytes",
        opts.request_timeout.as_millis(),
        opts.max_queue,
        opts.max_line_bytes
    );
    println!(
        "protocol: newline-delimited JSON (docs/PROTOCOL.md); ops: ping | info | stats | tune \
         | tune_theta | create_session | update_session | drop_session | evaluate | predict \
         | shutdown"
    );
    // block forever: the acceptor thread owns the listener
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get("addr").ok_or_else(|| anyhow!("--addr <host:port> is required"))?;
    let defaults = ClientOptions::default();
    let read_ms = args
        .get_usize(
            "read-timeout",
            defaults.read_timeout.map(|d| d.as_millis() as usize).unwrap_or(0),
        )
        .map_err(|e| anyhow!(e))?;
    let copts = ClientOptions {
        retries: args.get_usize("retries", defaults.retries).map_err(|e| anyhow!(e))?,
        connect_timeout: std::time::Duration::from_millis(
            args.get_usize("connect-timeout", defaults.connect_timeout.as_millis() as usize)
                .map_err(|e| anyhow!(e))? as u64,
        ),
        // 0 = no read timeout (long tunes against a generous server)
        read_timeout: if read_ms == 0 {
            None
        } else {
            Some(std::time::Duration::from_millis(read_ms as u64))
        },
        ..defaults
    };
    let mut client = Client::connect_with(addr, copts)?;
    if args.flag("stats") {
        println!("{}", client.stats()?);
        return Ok(());
    }
    let req = load_request(args)?;
    if args.get("append").is_some() && !args.flag("session") {
        return Err(anyhow!("--append streams into a server-side session; add --session"));
    }
    if args.flag("session") {
        if req.backend == Backend::Pjrt {
            return Err(anyhow!(
                "--session runs on the server's pure-rust session path; drop --backend pjrt"
            ));
        }
        // explicit session: the server pays the setup at most once per
        // dataset; repeated invocations of this command are warm
        let created = client.create_session_full(&req.x, req.kernel, req.threads)?;
        eprintln!("session: {created}");
        let id = created
            .get("session_id")
            .and_then(gpml::util::json::Json::as_f64)
            .ok_or_else(|| anyhow!("malformed create_session response"))?
            as u64;
        let mut ys = req.ys.clone();
        if let Some(path) = args.get("append") {
            // streaming append: grow the session by rank-one refresh,
            // then tune against the concatenated outputs
            let extra = data::read_csv(path).map_err(|e| anyhow!(e))?;
            if extra.x.cols() != req.x.cols() {
                return Err(anyhow!(
                    "--append {path}: {} feature cols != {}",
                    extra.x.cols(),
                    req.x.cols()
                ));
            }
            if extra.ys.len() != ys.len() {
                return Err(anyhow!(
                    "--append {path}: {} output cols != {}",
                    extra.ys.len(),
                    ys.len()
                ));
            }
            let updated = client.update_session(id, &extra.x, req.threads)?;
            eprintln!("update: {updated}");
            for (y, extra_y) in ys.iter_mut().zip(&extra.ys) {
                y.extend_from_slice(extra_y);
            }
        }
        if args.flag("tune-theta") {
            // Algorithm 1 over the kernel theta, server-side: outer
            // candidates fan across the worker pool and every setup
            // lands in the eigen-family cache, so re-running this exact
            // command is warm (`setups_built: 0`)
            let mut treq = ThetaTuneRequest::new(id, ys);
            treq.theta_range = (
                args.get_f64("theta-min", treq.theta_range.0).map_err(|e| anyhow!(e))?,
                args.get_f64("theta-max", treq.theta_range.1).map_err(|e| anyhow!(e))?,
            );
            treq.outer_iters = args.get_usize("outer", treq.outer_iters).map_err(|e| anyhow!(e))?;
            treq.search = match args.get_or("theta-search", "wavefront") {
                "wavefront" => ThetaSearch::Wavefront {
                    width: args.get_usize("wavefront", 0).map_err(|e| anyhow!(e))?,
                },
                "golden" => ThetaSearch::Golden,
                "nelder-mead" => ThetaSearch::NelderMead,
                "pso" => ThetaSearch::Pso,
                other => {
                    return Err(anyhow!(
                        "unknown theta search '{other}' (wavefront|golden|nelder-mead|pso)"
                    ))
                }
            };
            treq.refine = match args.get_or("refine", "newton") {
                "newton" => RefineKind::Newton,
                "none" => RefineKind::None,
                other => return Err(anyhow!("unknown refine '{other}' (newton|none)")),
            };
            treq.inner_grid =
                args.get_usize("inner-grid", treq.inner_grid).map_err(|e| anyhow!(e))?;
            treq.objective = req.objective;
            treq.threads = req.threads;
            println!("{}", client.tune_theta(&treq)?);
            return Ok(());
        }
        let mut sreq = SessionTuneRequest::new(id, ys);
        sreq.strategy = req.strategy;
        sreq.objective = req.objective;
        sreq.seed = req.seed;
        sreq.threads = req.threads;
        println!("{}", client.tune_session(&sreq)?);
        return Ok(());
    }
    if args.flag("tune-theta") {
        return Err(anyhow!("--tune-theta sweeps a server-side session; add --session"));
    }
    let res = client.tune(&req)?;
    println!("{res}");
    Ok(())
}

fn cmd_bench_gate(args: &Args) -> Result<()> {
    let current_path =
        args.get("current").ok_or_else(|| anyhow!("--current <BENCH_x.json> is required"))?;
    let baseline_path =
        args.get("baseline").ok_or_else(|| anyhow!("--baseline <json> is required"))?;
    let tolerance = args.get_f64("tolerance", 1.25).map_err(|e| anyhow!(e))?;
    let read = |path: &str| -> Result<gpml::util::json::Json> {
        let text = std::fs::read_to_string(path).map_err(|e| anyhow!("reading {path}: {e}"))?;
        gpml::util::json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))
    };
    let current = read(current_path)?;
    if args.flag("write-baseline") {
        // re-baseline: replace the --baseline file with the medians the
        // current run measured (ns + per-series median_us; the envelope
        // semantics stay "fail past tolerance * these numbers")
        use gpml::util::json::Json;
        let bench = current.get("bench").and_then(Json::as_str).unwrap_or("bench");
        let ns = current
            .get("ns")
            .cloned()
            .ok_or_else(|| anyhow!("{current_path}: missing top-level \"ns\" array"))?;
        let series = current
            .get("series")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("{current_path}: missing top-level \"series\" object"))?;
        let mut pairs: Vec<(&str, Json)> = Vec::new();
        for (label, s) in series {
            let med = s
                .get("median_us")
                .cloned()
                .ok_or_else(|| anyhow!("{current_path}: series '{label}' missing median_us"))?;
            pairs.push((label.as_str(), Json::obj(vec![("median_us", med)])));
        }
        let count = pairs.len();
        let note = format!("written by `gpml bench-gate --write-baseline` from {current_path}");
        let out = Json::obj(vec![
            ("bench", Json::str(bench)),
            ("note", Json::str(&note)),
            ("ns", ns),
            ("series", Json::obj(pairs)),
        ]);
        std::fs::write(baseline_path, format!("{out}\n"))
            .map_err(|e| anyhow!("writing {baseline_path}: {e}"))?;
        println!("bench-gate: wrote baseline {baseline_path} ({count} series)");
        return Ok(());
    }
    let baseline = read(baseline_path)?;
    if let Some(note) = baseline.get("note").and_then(gpml::util::json::Json::as_str) {
        println!("baseline note: {note}");
    }
    println!("gate: {current_path} vs {baseline_path} (tolerance {tolerance}x)\n");
    let report =
        gpml::util::benchgate::compare(&current, &baseline, tolerance).map_err(|e| anyhow!(e))?;
    print!("{}", report.summary());
    if report.ok() {
        println!("\nbench-gate: OK — {} comparisons within {tolerance}x", report.rows.len());
        Ok(())
    } else {
        Err(anyhow!(
            "bench-gate: {} regression(s), {} missing series (tolerance {tolerance}x); \
             if intentional, re-baseline benches/baselines/ or apply the bench-override PR label",
            report.regressions().len(),
            report.missing.len()
        ))
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    println!(
        "kernel backend: {} (avx2+fma detected: {}; GPML_KERNEL to pin, DESIGN.md §14)",
        gpml::linalg::default_kernel_backend().as_str(),
        gpml::linalg::simd_available()
    );
    let dir: std::path::PathBuf =
        args.get("artifacts").map(Into::into).unwrap_or_else(default_artifact_dir);
    let rt = PjrtRuntime::open(&dir)?;
    let m = rt.manifest();
    println!("artifact dir: {} (dtype {})", dir.display(), m.dtype);
    println!("batch width B={}, feature pad P={}", m.b_batch, m.p_pad);
    for entry in ["score", "fused", "batched_score", "gram", "posterior_var_diag"] {
        let buckets = m.buckets(entry);
        println!("  {entry:<20} buckets: {buckets:?}");
    }
    println!("total artifacts: {}", m.artifacts.len());
    Ok(())
}

//! Dataset utilities: synthetic GP-regression generators (the paper's
//! simulation study uses synthetic data), CSV I/O, standardization, and
//! train/test splitting.

use crate::kernelfn::{self, Kernel};
use crate::linalg::{Cholesky, Matrix};
use crate::util::rng::Rng;

/// A regression dataset: inputs (N x P) and one or more output columns.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Matrix,
    pub ys: Vec<Vec<f64>>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows()
    }
    pub fn p(&self) -> usize {
        self.x.cols()
    }
    pub fn y(&self) -> &[f64] {
        &self.ys[0]
    }

    /// Split into (train, test) by a shuffled index set.
    pub fn split(&self, train_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        let n = self.n();
        let ntr = ((n as f64) * train_frac).round() as usize;
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let take = |ids: &[usize]| Dataset {
            x: Matrix::from_fn(ids.len(), self.p(), |i, j| self.x[(ids[i], j)]),
            ys: self
                .ys
                .iter()
                .map(|y| ids.iter().map(|&i| y[i]).collect())
                .collect(),
        };
        (take(&idx[..ntr]), take(&idx[ntr..]))
    }
}

/// Parameters of the synthetic generator.
#[derive(Clone, Copy, Debug)]
pub struct SyntheticSpec {
    pub n: usize,
    pub p: usize,
    pub kernel: Kernel,
    /// True coefficient-scale hyperparameter lambda^2 (eq. 6).
    pub lambda2: f64,
    /// True noise variance sigma^2 (eq. 4).
    pub sigma2: f64,
    pub seed: u64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec {
            n: 256,
            p: 8,
            kernel: Kernel::Rbf { xi2: 2.0 },
            lambda2: 1.0,
            sigma2: 0.05,
            seed: 42,
        }
    }
}

/// Draw a dataset from the paper's *generative model* (eqs. 4-6):
/// `c ~ N(0, lambda2 K^{-1})`, `y = K c + eps`, `eps ~ N(0, sigma2 I)`.
/// Sampling `K c` with `c ~ N(0, lambda2 K^{-1})` is equivalent to drawing
/// `f ~ N(0, lambda2 K)`, i.e. `f = sqrt(lambda2) L z` with `K = L L'`.
pub fn synthetic(spec: SyntheticSpec, outputs: usize) -> Dataset {
    let mut rng = Rng::new(spec.seed);
    let x = Matrix::from_fn(spec.n, spec.p, |_, _| rng.normal());
    let mut k = kernelfn::gram(spec.kernel, &x);
    k.add_diag(1e-8 * spec.n as f64); // jitter for the factorization
    let ch = Cholesky::new(&k).expect("jittered Gram must be SPD");
    let ys = (0..outputs)
        .map(|_| {
            let z = rng.normal_vec(spec.n);
            // f = sqrt(lambda2) L z
            let mut f = vec![0.0; spec.n];
            for i in 0..spec.n {
                let row = ch.l().row(i);
                f[i] = spec.lambda2.sqrt()
                    * row[..=i].iter().zip(&z[..=i]).map(|(a, b)| a * b).sum::<f64>();
            }
            // y = f + eps
            f.iter().map(|v| v + spec.sigma2.sqrt() * rng.normal()).collect()
        })
        .collect();
    Dataset { x, ys }
}

/// Standardize each feature column and each output to zero mean / unit
/// variance (in place); returns the per-column (mean, std) for features.
pub fn standardize(ds: &mut Dataset) -> Vec<(f64, f64)> {
    let (n, p) = (ds.n(), ds.p());
    let mut stats = Vec::with_capacity(p);
    for j in 0..p {
        let col: Vec<f64> = (0..n).map(|i| ds.x[(i, j)]).collect();
        let mean = col.iter().sum::<f64>() / n as f64;
        let var = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
        let std = var.sqrt().max(1e-12);
        for i in 0..n {
            ds.x[(i, j)] = (ds.x[(i, j)] - mean) / std;
        }
        stats.push((mean, std));
    }
    for y in &mut ds.ys {
        let mean = y.iter().sum::<f64>() / n as f64;
        let var = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
        let std = var.sqrt().max(1e-12);
        for v in y.iter_mut() {
            *v = (*v - mean) / std;
        }
    }
    stats
}

/// Root-mean-square error.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let s: f64 = pred.iter().zip(truth).map(|(a, b)| (a - b) * (a - b)).sum();
    (s / pred.len() as f64).sqrt()
}

/// Write a dataset as CSV (`x0,...,xP-1,y0[,y1...]`).
pub fn write_csv(path: &str, ds: &Dataset) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let header: Vec<String> = (0..ds.p())
        .map(|j| format!("x{j}"))
        .chain((0..ds.ys.len()).map(|j| format!("y{j}")))
        .collect();
    writeln!(f, "{}", header.join(","))?;
    for i in 0..ds.n() {
        let mut cells: Vec<String> = ds.x.row(i).iter().map(|v| format!("{v}")).collect();
        for y in &ds.ys {
            cells.push(format!("{}", y[i]));
        }
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

/// Read a CSV written by [`write_csv`] (or any headered numeric CSV where
/// output columns are named `y*`).
pub fn read_csv(path: &str) -> Result<Dataset, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let mut lines = text.lines();
    let header = lines.next().ok_or("empty csv")?;
    let cols: Vec<&str> = header.split(',').map(|s| s.trim()).collect();
    let y_cols: Vec<usize> = cols
        .iter()
        .enumerate()
        .filter(|(_, c)| c.starts_with('y'))
        .map(|(i, _)| i)
        .collect();
    if y_cols.is_empty() {
        return Err("csv has no y* columns".into());
    }
    let x_cols: Vec<usize> =
        (0..cols.len()).filter(|i| !y_cols.contains(i)).collect();
    let mut xs: Vec<f64> = Vec::new();
    let mut ys: Vec<Vec<f64>> = vec![Vec::new(); y_cols.len()];
    let mut n = 0usize;
    for (lineno, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let vals: Result<Vec<f64>, _> =
            line.split(',').map(|t| t.trim().parse::<f64>()).collect();
        let vals = vals.map_err(|e| format!("line {}: {e}", lineno + 2))?;
        if vals.len() != cols.len() {
            return Err(format!("line {}: {} fields, expected {}", lineno + 2, vals.len(), cols.len()));
        }
        for &i in &x_cols {
            xs.push(vals[i]);
        }
        for (k, &i) in y_cols.iter().enumerate() {
            ys[k].push(vals[i]);
        }
        n += 1;
    }
    Ok(Dataset { x: Matrix::from_vec(n, x_cols.len(), xs), ys })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spectral::{HyperParams, SpectralGp};

    #[test]
    fn synthetic_shapes_and_determinism() {
        let spec = SyntheticSpec { n: 50, p: 3, seed: 7, ..Default::default() };
        let a = synthetic(spec, 2);
        let b = synthetic(spec, 2);
        assert_eq!(a.n(), 50);
        assert_eq!(a.p(), 3);
        assert_eq!(a.ys.len(), 2);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.ys[0], b.ys[0]);
    }

    #[test]
    fn synthetic_likelihood_prefers_true_hyperparams_region() {
        // score at the generating hyperparameters should beat wildly wrong ones
        let spec = SyntheticSpec {
            n: 120,
            sigma2: 0.1,
            lambda2: 1.0,
            seed: 3,
            ..Default::default()
        };
        let ds = synthetic(spec, 1);
        let gp = SpectralGp::fit(spec.kernel, ds.x.clone()).unwrap();
        let es = gp.eigensystem(ds.y());
        let at_truth = es.score(HyperParams::new(0.1, 1.0));
        let far_off = es.score(HyperParams::new(100.0, 1e-3));
        assert!(at_truth < far_off, "{at_truth} !< {far_off}");
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut ds = synthetic(SyntheticSpec { n: 80, p: 2, seed: 9, ..Default::default() }, 1);
        standardize(&mut ds);
        for j in 0..2 {
            let col: Vec<f64> = (0..80).map(|i| ds.x[(i, j)]).collect();
            let mean = col.iter().sum::<f64>() / 80.0;
            let var = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 80.0;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn split_partitions_dataset() {
        let ds = synthetic(SyntheticSpec { n: 100, ..Default::default() }, 1);
        let mut rng = Rng::new(1);
        let (tr, te) = ds.split(0.8, &mut rng);
        assert_eq!(tr.n(), 80);
        assert_eq!(te.n(), 20);
        assert_eq!(tr.p(), ds.p());
    }

    #[test]
    fn csv_roundtrip() {
        let ds = synthetic(SyntheticSpec { n: 20, p: 2, seed: 5, ..Default::default() }, 2);
        let path = std::env::temp_dir().join("gpml_test_roundtrip.csv");
        let path = path.to_str().unwrap();
        write_csv(path, &ds).unwrap();
        let back = read_csv(path).unwrap();
        assert_eq!(back.n(), 20);
        assert_eq!(back.p(), 2);
        assert_eq!(back.ys.len(), 2);
        for i in 0..20 {
            assert!((back.ys[0][i] - ds.ys[0][i]).abs() < 1e-12);
            assert!((back.x[(i, 1)] - ds.x[(i, 1)]).abs() < 1e-12);
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn read_csv_rejects_malformed() {
        let dir = std::env::temp_dir();
        let p1 = dir.join("gpml_bad1.csv");
        std::fs::write(&p1, "x0,x1\n1,2\n").unwrap(); // no y column
        assert!(read_csv(p1.to_str().unwrap()).is_err());
        let p2 = dir.join("gpml_bad2.csv");
        std::fs::write(&p2, "x0,y0\n1,2\n3\n").unwrap(); // ragged row
        assert!(read_csv(p2.to_str().unwrap()).is_err());
        let _ = std::fs::remove_file(p1);
        let _ = std::fs::remove_file(p2);
    }

    #[test]
    fn rmse_basics() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }
}

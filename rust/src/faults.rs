//! Fault policy and the numeric degradation ladder (DESIGN.md §11).
//!
//! The paper's economics — one O(N^3) setup amortized over k* O(N)
//! iterates — only pay off in a server that *survives*: a non-convergent
//! QL iteration ([`crate::linalg::eigen::NoConvergence`]) or an
//! ill-conditioned Gram matrix at the small-lengthscale edge of a theta
//! sweep must degrade by policy, not panic.  This module centralizes
//! that policy:
//!
//! - [`FaultPolicy`] — the ladder's knobs (jitter base, rung count,
//!   positive-definiteness tolerance);
//! - [`hardened_eigen`] — the deterministic degradation ladder itself:
//!   clean decomposition → jitter-boosted retries (each rung scales the
//!   diagonal boost by 10x) → a Cholesky-backed fallback path → a clean
//!   structured [`FaultError`];
//! - [`FaultCounters`] — shared observable counters every degradation
//!   increments, surfaced through the wire `stats` op.
//!
//! The ladder is deterministic: the same input walks the same rungs and
//! produces the same [`SetupGrade`], so warm-cache bitwise identity is
//! preserved (a rescued setup is cached like any other — its grade is a
//! property of the decomposition, not of the request that triggered it).

#[cfg(feature = "fault-inject")]
pub mod inject;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::linalg::{matmul, norm2, Cholesky, Matrix, SymEigen};

/// Knobs of the numeric degradation ladder.
#[derive(Clone, Copy, Debug)]
pub struct FaultPolicy {
    /// Base diagonal jitter, as a fraction of the mean diagonal
    /// (`trace/N`).  Rung `r` adds `jitter_eps * 10^(r-1) * trace/N`.
    pub jitter_eps: f64,
    /// Jitter rungs to attempt before the Cholesky fallback.
    pub max_jitter_rungs: usize,
    /// Relative tolerance for the positive-semi-definiteness check: a
    /// decomposition whose most negative eigenvalue is below
    /// `-pd_tol * spectral scale` is treated as a failure (a kernel Gram
    /// matrix is PSD in exact arithmetic; a materially negative spectrum
    /// corrupts `log det` and every score built on it).
    pub pd_tol: f64,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        // 1e-10 * trace/N is far below the verify suite's 1e-7 relative
        // tolerance; four rungs top out at 1e-7 * trace/N, still a
        // perturbation the score tolerances absorb.
        FaultPolicy { jitter_eps: 1e-10, max_jitter_rungs: 4, pd_tol: 1e-8 }
    }
}

/// Shared fault/degradation counters.  One instance is shared by the
/// server (sheds, panics, respawns, deadlines) and the session store
/// (jitter retries, fallback refits); the wire `stats` op serializes a
/// [`snapshot`](FaultCounters::snapshot).
#[derive(Debug, Default)]
pub struct FaultCounters {
    /// Requests rejected by admission control (`overloaded` responses).
    pub sheds: AtomicU64,
    /// Jobs that panicked inside a worker (isolated by `catch_unwind`).
    pub panics: AtomicU64,
    /// Pool workers respawned after a panic escaped a job boundary.
    pub worker_respawns: AtomicU64,
    /// Jitter-boosted eigendecomposition retries (ladder rungs walked).
    pub jitter_retries: AtomicU64,
    /// Cholesky-backed fallback decompositions attempted, plus streaming
    /// updates refitted because the incremental path's eigensolve failed.
    pub fallback_refits: AtomicU64,
    /// Requests answered with a `deadline` error.
    pub deadline_expired: AtomicU64,
}

/// Point-in-time copy of [`FaultCounters`] (plain integers, for stats).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultSnapshot {
    pub sheds: u64,
    pub panics: u64,
    pub worker_respawns: u64,
    pub jitter_retries: u64,
    pub fallback_refits: u64,
    pub deadline_expired: u64,
}

impl FaultCounters {
    pub fn snapshot(&self) -> FaultSnapshot {
        FaultSnapshot {
            sheds: self.sheds.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            worker_respawns: self.worker_respawns.load(Ordering::Relaxed),
            jitter_retries: self.jitter_retries.load(Ordering::Relaxed),
            fallback_refits: self.fallback_refits.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
        }
    }

    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }
}

/// How a decomposition was obtained — clean, or via which ladder rung.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SetupGrade {
    /// First attempt succeeded (the overwhelmingly common case).
    Clean,
    /// Rescued by jitter rung `rung` (1-based): `jitter` was added to
    /// the diagonal before decomposing.
    Jittered { rung: usize, jitter: f64 },
    /// Rescued by the Cholesky-backed path at the maximum jitter.
    CholFallback { jitter: f64 },
}

impl SetupGrade {
    pub fn is_clean(&self) -> bool {
        matches!(self, SetupGrade::Clean)
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SetupGrade::Clean => "clean",
            SetupGrade::Jittered { .. } => "jittered",
            SetupGrade::CholFallback { .. } => "chol-fallback",
        }
    }
}

/// A decomposition that survived the ladder, tagged with how.
#[derive(Clone, Debug)]
pub struct HardenedEigen {
    pub eigen: SymEigen,
    pub grade: SetupGrade,
}

/// Every rung failed: the structured end of the ladder.  Carries what
/// was attempted so the error message (and logs) show the full walk.
#[derive(Debug)]
pub struct FaultError {
    /// Jitter rungs attempted (== `FaultPolicy::max_jitter_rungs` unless
    /// the ladder was configured shorter).
    pub rungs: usize,
    /// Largest diagonal jitter tried.
    pub max_jitter: f64,
    /// The final failure, after the Cholesky fallback also failed.
    pub cause: String,
}

impl std::fmt::Display for FaultError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "degradation ladder exhausted ({} jitter rungs, max jitter {:.3e}, \
             cholesky fallback): {}",
            self.rungs, self.max_jitter, self.cause
        )
    }
}

impl std::error::Error for FaultError {}

/// Decompose `k` through the degradation ladder:
///
/// 1. clean `SymEigen::new` + PSD check;
/// 2. up to [`FaultPolicy::max_jitter_rungs`] jitter-boosted retries,
///    rung `r` adding `jitter_eps * 10^(r-1) * trace/N` to the diagonal
///    (each counted in [`FaultCounters::jitter_retries`]);
/// 3. a Cholesky-backed decomposition of the max-jittered matrix
///    (counted in [`FaultCounters::fallback_refits`]);
/// 4. a structured [`FaultError`] recording the whole walk.
///
/// The ladder is deterministic — no randomness, no clocks — so repeated
/// calls on the same matrix take the same path.
pub fn hardened_eigen(
    k: &Matrix,
    policy: &FaultPolicy,
    counters: &FaultCounters,
) -> Result<HardenedEigen, FaultError> {
    let n = k.rows();
    let base = if n == 0 { 0.0 } else { (k.trace().abs() / n as f64).max(f64::MIN_POSITIVE) };

    let mut last_cause = match attempt(k, policy) {
        Ok(eigen) => return Ok(HardenedEigen { eigen, grade: SetupGrade::Clean }),
        Err(cause) => cause,
    };

    let mut jitter = 0.0;
    for rung in 1..=policy.max_jitter_rungs {
        jitter = policy.jitter_eps * 10f64.powi(rung as i32 - 1) * base;
        FaultCounters::bump(&counters.jitter_retries);
        let mut kj = k.clone();
        kj.add_diag(jitter);
        match attempt(&kj, policy) {
            Ok(eigen) => {
                return Ok(HardenedEigen { eigen, grade: SetupGrade::Jittered { rung, jitter } })
            }
            Err(cause) => last_cause = cause,
        }
    }

    // Cholesky-backed fallback at the maximum jitter: a different O(N^3)
    // algorithm with a different failure surface (pivot breakdown instead
    // of QL stagnation).
    if jitter == 0.0 {
        jitter = policy.jitter_eps * base;
    }
    FaultCounters::bump(&counters.fallback_refits);
    let mut kj = k.clone();
    kj.add_diag(jitter);
    match cholesky_eigen(&kj) {
        Ok(eigen) => Ok(HardenedEigen { eigen, grade: SetupGrade::CholFallback { jitter } }),
        Err(cause) => Err(FaultError {
            rungs: policy.max_jitter_rungs,
            max_jitter: jitter,
            cause: format!("{last_cause}; {cause}"),
        }),
    }
}

/// One ladder attempt: decompose (through the injection hook) and reject
/// non-finite or materially negative spectra.
fn attempt(k: &Matrix, policy: &FaultPolicy) -> Result<SymEigen, String> {
    let eigen = try_eigen(k).map_err(|e| e.to_string())?;
    check_psd(&eigen, policy)?;
    Ok(eigen)
}

/// `SymEigen::new` behind the fault-injection hook: under the
/// `fault-inject` feature an armed [`inject::FaultPoint::EigenNoConvergence`]
/// makes this return the same error a real QL stagnation would.
fn try_eigen(k: &Matrix) -> Result<SymEigen, crate::linalg::eigen::NoConvergence> {
    #[cfg(feature = "fault-inject")]
    if inject::fire(inject::FaultPoint::EigenNoConvergence) {
        return Err(crate::linalg::eigen::NoConvergence { eigenvalue_index: 0 });
    }
    SymEigen::new(k)
}

/// A kernel Gram matrix is PSD in exact arithmetic; eigenvalues below
/// `-pd_tol * scale` (or non-finite) mean the decomposition cannot back
/// the paper's `log det` identities.
fn check_psd(eigen: &SymEigen, policy: &FaultPolicy) -> Result<(), String> {
    // values are ascending (eigen.rs contract)
    let min = eigen.values.first().copied().unwrap_or(0.0);
    let max = eigen.values.last().copied().unwrap_or(0.0);
    if !min.is_finite() || !max.is_finite() {
        return Err("non-finite eigenvalues".to_string());
    }
    let scale = min.abs().max(max.abs()).max(f64::MIN_POSITIVE);
    if min < -policy.pd_tol * scale {
        return Err(format!("gram not positive semi-definite (min eigenvalue {min:.6e})"));
    }
    Ok(())
}

/// Cholesky-backed eigendecomposition of a positive-definite matrix:
/// factor `A = L L'`, decompose the *similar* matrix `M = L' L`
/// (same spectrum, and the two-sided similarity often conditions the QL
/// iteration better than `A` itself), then map eigenvectors back —
/// `A (L v) = L (L' L) v = s (L v)`, so `u = L v / |L v|`.
///
/// Fails (with a message naming the stage) when `A` is not positive
/// definite or the inner eigendecomposition itself fails — the ladder
/// reports both in its structured error.
pub fn cholesky_eigen(a: &Matrix) -> Result<SymEigen, String> {
    let ch = Cholesky::new(a).map_err(|e| format!("cholesky fallback: {e}"))?;
    let l = ch.l();
    let m = matmul(&l.t(), l);
    let eigen = try_eigen(&m).map_err(|e| format!("cholesky fallback eigen: {e}"))?;
    let n = a.rows();
    let mut vectors = Matrix::zeros(n, n);
    for j in 0..n {
        let v = eigen.vectors.col(j);
        let u = l.matvec(&v);
        let nrm = norm2(&u);
        let inv = if nrm > 0.0 { 1.0 / nrm } else { 0.0 };
        for i in 0..n {
            vectors[(i, j)] = u[i] * inv;
        }
    }
    Ok(SymEigen { values: eigen.values, vectors })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul_bt;

    /// Deterministic symmetric PSD test matrix `B B'` with bounded entries.
    fn psd(n: usize, seed: u64) -> Matrix {
        let mut state = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64 - 0.5
        };
        let b = Matrix::from_fn(n, n, |_, _| next());
        matmul_bt(&b, &b)
    }

    #[test]
    fn clean_matrix_takes_the_clean_rung() {
        let k = psd(12, 7);
        let counters = FaultCounters::default();
        let h = hardened_eigen(&k, &FaultPolicy::default(), &counters).unwrap();
        assert_eq!(h.grade, SetupGrade::Clean);
        let snap = counters.snapshot();
        assert_eq!((snap.jitter_retries, snap.fallback_refits), (0, 0));
        // identical to the direct decomposition, bit for bit
        let direct = SymEigen::new(&k).unwrap();
        assert_eq!(h.eigen.values, direct.values);
        assert_eq!(h.eigen.vectors.data(), direct.vectors.data());
    }

    #[test]
    fn markedly_non_pd_walks_every_rung_in_order() {
        // min eigenvalue pushed far below what any jitter rung repairs
        let mut k = psd(10, 3);
        let spread = SymEigen::new(&k).unwrap().values.last().copied().unwrap();
        k.add_diag(-0.5 * spread);
        let policy = FaultPolicy::default();
        let counters = FaultCounters::default();
        let err = hardened_eigen(&k, &policy, &counters).unwrap_err();
        assert_eq!(err.rungs, policy.max_jitter_rungs);
        let snap = counters.snapshot();
        assert_eq!(snap.jitter_retries, policy.max_jitter_rungs as u64);
        assert_eq!(snap.fallback_refits, 1);
        let msg = err.to_string();
        assert!(msg.contains("positive"), "cause names the PSD failure: {msg}");
        assert!(msg.contains("cholesky"), "cause names the fallback stage: {msg}");
    }

    #[test]
    fn slightly_non_pd_is_rescued_by_a_jitter_rung() {
        let n = 10;
        let mut k = psd(n, 5);
        let clean_min = SymEigen::new(&k).unwrap().values[0];
        let scale = SymEigen::new(&k).unwrap().values[n - 1];
        // plant a deficit a middle rung's jitter repairs: rung r adds
        // jitter_eps * 10^(r-1) * trace/n
        let policy = FaultPolicy::default();
        let trace_over_n = k.trace() / n as f64;
        let deficit = clean_min + 2.0 * policy.pd_tol * scale;
        k.add_diag(-deficit);
        let counters = FaultCounters::default();
        let h = hardened_eigen(&k, &policy, &counters).unwrap();
        match h.grade {
            SetupGrade::Jittered { rung, jitter } => {
                assert!((1..=policy.max_jitter_rungs).contains(&rung));
                assert!(jitter > 0.0 && jitter <= policy.jitter_eps * 1e4 * trace_over_n);
                assert_eq!(counters.snapshot().jitter_retries, rung as u64);
            }
            other => panic!("expected a jitter rescue, got {other:?}"),
        }
        // ladder result == direct decomposition of the jittered matrix
        let SetupGrade::Jittered { jitter, .. } = h.grade else { unreachable!() };
        let mut kj = k.clone();
        kj.add_diag(jitter);
        let direct = SymEigen::new(&kj).unwrap();
        assert_eq!(h.eigen.values, direct.values);
    }

    #[test]
    fn cholesky_eigen_matches_direct_decomposition() {
        let mut k = psd(16, 11);
        k.add_diag(1e-6 * k.trace() / 16.0);
        let ch = cholesky_eigen(&k).unwrap();
        let direct = SymEigen::new(&k).unwrap();
        for (a, b) in ch.values.iter().zip(&direct.values) {
            assert!((a - b).abs() <= 1e-9 * b.abs().max(1.0), "{a} vs {b}");
        }
        // same invariant subspaces: the reconstruction must match
        assert!(ch.reconstruct().max_abs_diff(&k) < 1e-8 * k.fro_norm().max(1.0));
        // and the vectors are orthonormal
        let utu = matmul(&ch.vectors.t(), &ch.vectors);
        assert!(utu.max_abs_diff(&Matrix::eye(16)) < 1e-8);
    }

    #[test]
    fn cholesky_eigen_rejects_non_pd() {
        let mut k = psd(8, 2);
        let top = SymEigen::new(&k).unwrap().values[7];
        k.add_diag(-0.5 * top);
        let err = cholesky_eigen(&k).unwrap_err();
        assert!(err.contains("cholesky"), "{err}");
    }

    #[test]
    fn ladder_is_deterministic() {
        let mut k = psd(9, 13);
        let clean_min = SymEigen::new(&k).unwrap().values[0];
        let scale = SymEigen::new(&k).unwrap().values[8];
        k.add_diag(-(clean_min + 2e-8 * scale));
        let policy = FaultPolicy::default();
        let c1 = FaultCounters::default();
        let c2 = FaultCounters::default();
        let a = hardened_eigen(&k, &policy, &c1).unwrap();
        let b = hardened_eigen(&k, &policy, &c2).unwrap();
        assert_eq!(a.grade, b.grade);
        assert_eq!(a.eigen.values, b.eigen.values);
        assert_eq!(a.eigen.vectors.data(), b.eigen.vectors.data());
        assert_eq!(c1.snapshot(), c2.snapshot());
    }
}

//! Integration: the session subsystem end-to-end over TCP — warm requests
//! perform zero O(N^3) work (asserted via the setup counter), eviction
//! respects the byte budget, and cached responses are bitwise identical
//! to cold ones.

use gpml::coordinator::client::Client;
use gpml::coordinator::protocol::{EvaluateRequest, PredictRequest};
use gpml::coordinator::server::{Server, ServerOptions};
use gpml::coordinator::session::SessionTuneRequest;
use gpml::coordinator::{Coordinator, GlobalStrategy, ObjectiveKind, TuneRequest};
use gpml::data::{synthetic, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::linalg::Matrix;
use gpml::spectral::HyperParams;

const KERNEL: Kernel = Kernel::Rbf { xi2: 2.0 };

fn dataset(n: usize, seed: u64) -> (Matrix, Vec<Vec<f64>>) {
    let ds = synthetic(SyntheticSpec { n, p: 2, seed, ..Default::default() }, 1);
    (ds.x, ds.ys)
}

fn grid_tune(id: u64, ys: Vec<Vec<f64>>) -> SessionTuneRequest {
    let mut req = SessionTuneRequest::new(id, ys);
    req.strategy = GlobalStrategy::Grid { points_per_axis: 7 };
    req.objective = ObjectiveKind::Evidence;
    req
}

#[test]
fn session_lifecycle_zero_setup_on_warm_requests() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let (x, ys) = dataset(40, 1);

    let created = client.create_session_full(&x, KERNEL, 0).unwrap();
    assert_eq!(created.get("cached").unwrap().as_bool(), Some(false));
    assert_eq!(created.get("n").unwrap().as_usize(), Some(40));
    let id = created.get("session_id").unwrap().as_f64().unwrap() as u64;

    // warm tune #1 and #2: the setup counter must not move
    let r1 = client.tune_session(&grid_tune(id, ys.clone())).unwrap();
    assert_eq!(r1.get("eigen_cached").unwrap().as_bool(), Some(true));
    assert_eq!(r1.get("gram_seconds").unwrap().as_f64(), Some(0.0));
    let r2 = client.tune_session(&grid_tune(id, ys.clone())).unwrap();
    assert_eq!(
        r1.get("outputs").unwrap().to_string(),
        r2.get("outputs").unwrap().to_string(),
        "identical warm requests give identical responses"
    );
    let stats = server.session_stats();
    assert_eq!(stats.setups, 1, "warm tunes performed zero gram/eigen work");

    // evaluate: O(N) closed forms against the cached eigenbasis
    let ev = client
        .evaluate(&EvaluateRequest {
            session_id: id,
            y: ys[0].clone(),
            hp: HyperParams::new(0.1, 1.0),
            objective: ObjectiveKind::Evidence,
        })
        .unwrap();
    assert!(ev.get("score").unwrap().as_f64().unwrap().is_finite());
    assert_eq!(ev.get("jac").unwrap().as_arr().unwrap().len(), 2);

    // predict at new inputs
    let xnew = Matrix::from_fn(5, 2, |i, j| (i + j) as f64 * 0.1);
    let pr = client
        .predict(&PredictRequest {
            session_id: id,
            y: ys[0].clone(),
            xnew,
            hp: HyperParams::new(0.1, 1.0),
        })
        .unwrap();
    assert_eq!(pr.get("mean").unwrap().as_arr().unwrap().len(), 5);
    for v in pr.get("var").unwrap().as_arr().unwrap() {
        assert!(v.as_f64().unwrap() >= 0.1 - 1e-12, "variance below noise floor");
    }
    assert_eq!(server.session_stats().setups, 1, "evaluate/predict are setup-free");

    // drop, then referencing the id is a clean error
    assert!(client.drop_session(id).unwrap());
    assert!(!client.drop_session(id).unwrap());
    let err = client.tune_session(&grid_tune(id, ys)).unwrap_err();
    assert!(err.to_string().contains("unknown session"), "{err}");
    server.stop();
}

#[test]
fn cold_and_warm_paths_bitwise_identical() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let (x, ys) = dataset(32, 9);
    let mut inline = TuneRequest::new(x.clone(), ys.clone(), KERNEL);
    inline.strategy = GlobalStrategy::Grid { points_per_axis: 7 };
    inline.objective = ObjectiveKind::Evidence;

    // cold: the inline tune pays the setup (and implicitly creates the
    // session for this fingerprint)
    let cold = client.tune(&inline).unwrap();
    assert_eq!(cold.get("eigen_cached").unwrap().as_bool(), Some(false));

    // the explicit create now hits the implicit session
    let created = client.create_session_full(&x, KERNEL, 0).unwrap();
    assert_eq!(created.get("cached").unwrap().as_bool(), Some(true));
    let id = created.get("session_id").unwrap().as_f64().unwrap() as u64;

    // warm session tune and warm inline tune: all three output blocks
    // must serialize identically
    let warm_session = client.tune_session(&grid_tune(id, ys)).unwrap();
    let warm_inline = client.tune(&inline).unwrap();
    let cold_outputs = cold.get("outputs").unwrap().to_string();
    assert_eq!(cold_outputs, warm_session.get("outputs").unwrap().to_string());
    assert_eq!(cold_outputs, warm_inline.get("outputs").unwrap().to_string());
    assert_eq!(server.session_stats().setups, 1);
    server.stop();
}

#[test]
fn concurrent_clients_mixed_sessions_share_setups() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let addr = server.addr.to_string();

    // pre-create 3 sessions (3 setups)
    let datasets: Vec<_> = (0..3).map(|s| dataset(30, 100 + s)).collect();
    let mut setup_client = Client::connect(&addr).unwrap();
    let ids: Vec<u64> =
        datasets.iter().map(|(x, _)| setup_client.create_session(x, KERNEL).unwrap()).collect();

    // 6 clients hammer the 3 sessions concurrently with mixed ops
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let addr = addr.clone();
            let id = ids[i % 3];
            let (_, ys) = datasets[i % 3].clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                for _ in 0..2 {
                    let res = client.tune_session(&grid_tune(id, ys.clone())).unwrap();
                    assert_eq!(res.get("ok").unwrap().as_bool(), Some(true));
                }
                let ev = client
                    .evaluate(&EvaluateRequest {
                        session_id: id,
                        y: ys[0].clone(),
                        hp: HyperParams::new(0.5, 1.0),
                        objective: ObjectiveKind::PaperScore,
                    })
                    .unwrap();
                assert!(ev.get("score").unwrap().as_f64().unwrap().is_finite());
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let stats = server.session_stats();
    assert_eq!(stats.setups, 3, "every request after creation hit the cached setups");
    assert_eq!(stats.sessions, 3);
    server.stop();
}

#[test]
fn racing_creates_of_one_dataset_compute_once() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let addr = server.addr.to_string();
    let (x, _) = dataset(48, 77);
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let addr = addr.clone();
            let x = x.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                client.create_session(&x, KERNEL).unwrap()
            })
        })
        .collect();
    let ids: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(ids.windows(2).all(|w| w[0] == w[1]), "one session for all racers: {ids:?}");
    assert_eq!(server.session_stats().setups, 1, "single-flight setup under a create race");
    server.stop();
}

#[test]
fn eviction_under_small_byte_budget() {
    // budget sized to hold exactly one n=32 session
    let one = gpml::spectral::SpectralGp::fit(KERNEL, dataset(32, 1).0).unwrap().setup_bytes();
    let opts = ServerOptions { max_bytes: one + one / 2, ..Default::default() };
    let server = Server::start_with("127.0.0.1:0", opts, Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();

    let (xa, ys_a) = dataset(32, 1);
    let (xb, _) = dataset(32, 2);
    let a = client.create_session(&xa, KERNEL).unwrap();
    let b = client.create_session(&xb, KERNEL).unwrap();
    assert_ne!(a, b);

    let stats = server.session_stats();
    assert_eq!(stats.evictions, 1, "creating B evicted A under the byte budget");
    assert_eq!(stats.sessions, 1);
    assert!(stats.bytes <= opts.max_bytes);

    // the evicted session errors cleanly...
    let err = client.tune_session(&grid_tune(a, ys_a.clone())).unwrap_err();
    assert!(err.to_string().contains("unknown session"), "{err}");
    // ...and re-creating it recomputes (the cache cannot hold both)
    let a2 = client.create_session(&xa, KERNEL).unwrap();
    assert!(client.tune_session(&grid_tune(a2, ys_a)).is_ok());
    assert_eq!(server.session_stats().setups, 3);

    // wire-level stats agree with the server-side snapshot
    let wire = client.stats().unwrap();
    assert_eq!(wire.get("setups").unwrap().as_usize(), Some(3));
    assert_eq!(wire.get("evictions").unwrap().as_usize(), Some(2));
    server.stop();
}

#[test]
fn stats_op_reports_budgets_and_counters() {
    let opts = ServerOptions { workers: 3, max_sessions: 5, max_bytes: 1 << 20 };
    let server = Server::start_with("127.0.0.1:0", opts, Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let v = client.stats().unwrap();
    assert_eq!(v.get("workers").unwrap().as_usize(), Some(3));
    assert_eq!(v.get("max_sessions").unwrap().as_usize(), Some(5));
    assert_eq!(v.get("max_bytes").unwrap().as_usize(), Some(1 << 20));
    assert_eq!(v.get("sessions").unwrap().as_usize(), Some(0));
    assert_eq!(v.get("bytes").unwrap().as_usize(), Some(0));
    server.stop();
}

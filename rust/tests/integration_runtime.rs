//! Integration: PJRT runtime vs the pure-rust spectral evaluator over the
//! real AOT artifacts.  Skips (with a message) if `make artifacts` has not
//! run.

mod common;

use gpml::kernelfn::Kernel;
use gpml::linalg::Matrix;
use gpml::spectral::HyperParams;
use gpml::util::rng::Rng;

const HPS: [(f64, f64); 4] = [(0.7, 1.3), (0.05, 4.0), (3.0, 0.2), (1.0, 1.0)];

#[test]
fn score_artifact_matches_rust_evaluator() {
    let Some(rt) = common::open_runtime() else { return };
    for &n in &[20usize, 32, 100, 500] {
        let (_, _, es) = common::small_system(n, n as u64);
        for &(s, l) in &HPS {
            let hp = HyperParams::new(s, l);
            let want = es.score(hp);
            let got = rt.score(&es, hp).unwrap();
            assert!(
                (got - want).abs() < 1e-8 * want.abs().max(1.0),
                "n={n} hp=({s},{l}): pjrt {got} vs rust {want}"
            );
        }
    }
}

#[test]
fn fused_artifact_matches_rust_evaluation() {
    let Some(rt) = common::open_runtime() else { return };
    let (_, _, es) = common::small_system(90, 7);
    let ev = rt.evaluator(&es).unwrap();
    for &(s, l) in &HPS {
        let hp = HyperParams::new(s, l);
        let got = ev.try_eval_full(hp).unwrap();
        let want = es.evaluate(hp);
        assert!((got.score - want.score).abs() < 1e-8 * want.score.abs().max(1.0));
        for i in 0..2 {
            assert!(
                (got.jac[i] - want.jac[i]).abs() < 1e-7 * want.jac[i].abs().max(1.0),
                "jac[{i}]: {} vs {}",
                got.jac[i],
                want.jac[i]
            );
            for j in 0..2 {
                assert!(
                    (got.hess[i][j] - want.hess[i][j]).abs()
                        < 1e-6 * want.hess[i][j].abs().max(1.0),
                    "hess[{i}][{j}]: {} vs {}",
                    got.hess[i][j],
                    want.hess[i][j]
                );
            }
        }
    }
}

#[test]
fn batched_artifact_matches_scalar_path() {
    let Some(rt) = common::open_runtime() else { return };
    let (_, _, es) = common::small_system(150, 9);
    let ev = rt.evaluator(&es).unwrap();
    let mut rng = Rng::new(11);
    // more points than one batch width to exercise chunking
    let b = ev.batch_width().unwrap_or(64);
    let hps: Vec<HyperParams> = (0..(b + b / 2))
        .map(|_| HyperParams::new(10f64.powf(rng.uniform_in(-2.0, 2.0)), 10f64.powf(rng.uniform_in(-2.0, 2.0))))
        .collect();
    let got = ev.try_eval_batch(&hps).unwrap();
    for (hp, g) in hps.iter().zip(&got) {
        let want = es.score(*hp);
        assert!(
            (g - want).abs() < 1e-8 * want.abs().max(1.0),
            "hp={hp:?}: batched {g} vs rust {want}"
        );
    }
}

#[test]
fn bucket_padding_is_neutral_across_buckets() {
    let Some(rt) = common::open_runtime() else { return };
    // n=33 lands in the 64-bucket; n=32 in the 32-bucket. Same data,
    // different padding path, same rust reference.
    for &n in &[31usize, 32, 33, 64, 65] {
        let (_, _, es) = common::small_system(n, 100 + n as u64);
        let hp = HyperParams::new(0.9, 1.7);
        let got = rt.score(&es, hp).unwrap();
        let want = es.score(hp);
        assert!(
            (got - want).abs() < 1e-8 * want.abs().max(1.0),
            "n={n}: {got} vs {want}"
        );
    }
}

#[test]
fn gram_artifact_matches_rust_kernels() {
    let Some(rt) = common::open_runtime() else { return };
    let mut rng = Rng::new(3);
    let x = Matrix::from_fn(70, 5, |_, _| rng.normal());
    for kernel in [
        Kernel::Rbf { xi2: 1.7 },
        Kernel::Polynomial { degree: 3 },
        Kernel::Linear,
    ] {
        let got = rt.gram(&x, kernel).unwrap();
        let want = gpml::kernelfn::gram(kernel, &x);
        assert!(
            got.max_abs_diff(&want) < 1e-9,
            "{kernel:?}: max diff {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn gram_artifact_rejects_oversized_features() {
    let Some(rt) = common::open_runtime() else { return };
    let x = Matrix::zeros(16, 64); // wider than P_PAD=32
    assert!(rt.gram(&x, Kernel::Linear).is_err());
}

#[test]
fn pvar_artifact_matches_rust_prop24() {
    let Some(rt) = common::open_runtime() else { return };
    let (gp, _, es) = common::small_system(60, 13);
    let hp = HyperParams::new(0.6, 1.8);
    let got = rt
        .posterior_var_diag(&gp.eigen().vectors, &es.s, hp)
        .unwrap();
    let want = gp.posterior_var_diag(hp);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-9 * w.abs().max(1.0), "i={i}: {g} vs {w}");
    }
}

#[test]
fn matern_kernel_has_no_artifact_and_errors_cleanly() {
    let Some(rt) = common::open_runtime() else { return };
    let x = Matrix::zeros(8, 2);
    assert!(rt.gram(&x, Kernel::Matern32 { ell: 1.0 }).is_err());
}

#[test]
fn manifest_covers_expected_buckets() {
    let Some(rt) = common::open_runtime() else { return };
    let m = rt.manifest();
    assert_eq!(m.dtype, "f64");
    for entry in ["score", "fused", "batched_score"] {
        let buckets = m.buckets(entry);
        assert!(buckets.contains(&32), "{entry}: {buckets:?}");
        assert!(buckets.contains(&8192), "{entry}: {buckets:?}");
    }
    assert!(!m.buckets("gram").is_empty());
    assert!(!m.buckets("posterior_var_diag").is_empty());
}

#[test]
fn warm_compiles_artifacts() {
    let Some(rt) = common::open_runtime() else { return };
    let count = rt.warm(&["score"]).unwrap();
    assert!(count >= 9, "expected the full score ladder, got {count}");
}

#[test]
fn dispatch_counter_increments() {
    let Some(rt) = common::open_runtime() else { return };
    let (_, _, es) = common::small_system(40, 17);
    let before = rt.dispatches.get();
    let _ = rt.score(&es, HyperParams::new(1.0, 1.0)).unwrap();
    let _ = rt.score(&es, HyperParams::new(2.0, 1.0)).unwrap();
    assert_eq!(rt.dispatches.get(), before + 2);
}

//! Integration gates for the sparse baselines (ISSUE 9): the SoR /
//! Nyström evaluators must behave like *approximations of the exact
//! model* — error shrinking along nested inducing ladders, cached and
//! recomputed spectra bitwise identical, the `SparseProvider` driving
//! the two-step engine deterministically, and a full-inducing sparse
//! tune landing on the exact tune's score.  The module-level unit tests
//! in `rust/src/sparse/` cover construction and single-point identities;
//! these tests exercise the cross-subsystem contracts.

use gpml::kernelfn::Kernel;
use gpml::linalg::Matrix;
use gpml::optim::{theta_tune, two_step_tune, EvidenceObjective, ThetaSearch, TwoStepOptions};
use gpml::sparse::{even_inducing, SparseGp, SparseMethod, SparseProvider};
use gpml::spectral::{HyperParams, SpectralGp};
use gpml::util::rng::Rng;
use gpml::verify::sparse_differential_suite;

fn dataset(n: usize, dims: usize, seed: u64) -> (Matrix, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x = Matrix::from_fn(n, dims, |_, _| rng.normal());
    let y = rng.normal_vec(n);
    (x, y)
}

/// Average |sparse score - exact score| over a few (sigma2, lambda2)
/// probes — single-probe errors can cross zero between m values, the
/// average is what the ladder property is stated over.
fn avg_err(sp: &SparseGp, exact: &gpml::spectral::EigenSystem, hps: &[HyperParams]) -> f64 {
    hps.iter().map(|&hp| (sp.score(hp) - exact.score(hp)).abs()).sum::<f64>() / hps.len() as f64
}

/// ISSUE-9 property: along a *nested* inducing ladder (shuffled prefix
/// sets, so each m is a superset of the previous) the approximation
/// error is non-increasing in m up to a 2x per-step slack — Nyström's
/// lifted eigenvectors are only approximately orthonormal, so strict
/// monotonicity is not a theorem there — and the m = N endpoint
/// recovers the exact score.
#[test]
fn error_shrinks_along_nested_inducing_ladders() {
    let n = 96;
    // narrow bandwidth => slow eigendecay => small-m error genuinely
    // large, so the ladder has room to fall
    let kern = Kernel::Rbf { xi2: 0.5 };
    let hps = [
        HyperParams::new(0.3, 1.2),
        HyperParams::new(1.0, 0.7),
        HyperParams::new(3.0, 0.4),
    ];
    for seed in [21u64, 22] {
        let (x, y) = dataset(n, 4, seed);
        let gp = SpectralGp::fit(kern, x.clone()).expect("exact eigensolve");
        let exact = gp.eigensystem(&y);
        let scale = hps.iter().map(|&hp| exact.score(hp).abs()).fold(1.0f64, f64::max);
        // nested prefixes of one shuffled permutation
        let mut perm: Vec<usize> = (0..n).collect();
        Rng::new(seed ^ 0xA5A5).shuffle(&mut perm);
        for method in [SparseMethod::Sor, SparseMethod::Nystrom] {
            let errs: Vec<f64> = [6usize, 12, 24, 48, 96]
                .iter()
                .map(|&m| {
                    let sp = SparseGp::new(method, kern, &x, &y, &perm[..m]).unwrap();
                    avg_err(&sp, &exact, &hps)
                })
                .collect();
            for (i, w) in errs.windows(2).enumerate() {
                assert!(
                    w[1] <= 2.0 * w[0] + 1e-7 * scale,
                    "{} seed {seed}: error rose at ladder step {i}: {:?}",
                    method.as_str(),
                    errs
                );
            }
            assert!(
                *errs.last().unwrap() <= errs[0] + 1e-9,
                "{} seed {seed}: m=N error {} above m=N/16 error {}",
                method.as_str(),
                errs.last().unwrap(),
                errs[0]
            );
            assert!(
                *errs.last().unwrap() < 1e-4 * scale,
                "{} seed {seed}: m=N must recover the exact score, err {}",
                method.as_str(),
                errs.last().unwrap()
            );
        }
    }
}

/// ISSUE-9 property: the cached-spectrum fast path is *bitwise* the
/// recompute-per-eval path at every rung and probe — caching is an
/// amortization, never a numeric fork (DESIGN.md §13).
#[test]
fn cached_spectrum_is_bitwise_the_recomputed_path() {
    let (x, y) = dataset(72, 3, 33);
    let kern = Kernel::Rbf { xi2: 1.5 };
    let hps = [
        HyperParams::new(0.2, 2.0),
        HyperParams::new(0.7, 1.3),
        HyperParams::new(1.0, 1.0),
        HyperParams::new(5.0, 0.3),
    ];
    for method in [SparseMethod::Sor, SparseMethod::Nystrom] {
        for m in [9usize, 24, 72] {
            let idx = even_inducing(72, m);
            let mut sp = SparseGp::new(method, kern, &x, &y, &idx).unwrap();
            let cached = sp.eigensystem().expect("cached spectrum").clone();
            for &hp in &hps {
                assert_eq!(
                    cached.score(hp).to_bits(),
                    sp.score(hp).to_bits(),
                    "{} m={m}: cached vs recomputed drift at hp={hp:?}",
                    method.as_str()
                );
            }
        }
    }
}

/// The two-step engine runs over a [`SparseProvider`] exactly as over
/// the exact provider: one O(N m^2) setup per outer eval, finite tuned
/// score, and run-to-run bitwise determinism.
#[test]
fn theta_tune_drives_a_sparse_provider_deterministically() {
    let (x, y) = dataset(48, 2, 44);
    let idx = even_inducing(48, 12);
    let opt = TwoStepOptions {
        theta_range: (0.1, 20.0),
        outer_iters: 10,
        inner_grid: 5,
        search: ThetaSearch::Wavefront { width: 0 },
        ..Default::default()
    };
    let run = || {
        let provider = SparseProvider::new(
            SparseMethod::Sor,
            Kernel::Rbf { xi2: 1.0 },
            x.clone(),
            y.clone(),
            idx.clone(),
        )
        .expect("valid provider");
        let r = theta_tune(&provider, &opt).expect("sparse tune");
        assert!(r.score.is_finite(), "tuned sparse score must be finite");
        assert!(r.outer_evals > 0 && r.outer_evals <= 10, "outer budget respected");
        // the engine builds exactly one sparse setup per outer eval —
        // same accounting contract as the exact provider
        assert_eq!(provider.setups_built(), r.outer_evals);
        r
    };
    let a = run();
    let b = run();
    assert_eq!(a.theta, b.theta, "sparse tune theta drift across runs");
    assert_eq!(a.score.to_bits(), b.score.to_bits(), "sparse tune score drift across runs");
    assert_eq!(a.hp, b.hp);
    assert_eq!(a.outer_evals, b.outer_evals);
}

/// With the full index set as inducing points the sparse model *is* the
/// exact model (up to jitter), so tuning through the sparse provider
/// must land on (essentially) the exact tune's score.
#[test]
fn full_inducing_sparse_tune_matches_exact_tune() {
    let n = 36;
    let (x, y) = dataset(n, 2, 55);
    let base = Kernel::Rbf { xi2: 1.0 };
    let opt = TwoStepOptions {
        theta_range: (0.1, 10.0),
        outer_iters: 12,
        inner_grid: 5,
        ..Default::default()
    };
    let exact = {
        let make = |theta: f64| {
            let gp = SpectralGp::fit(base.with_theta(theta), x.clone()).expect("exact fit");
            EvidenceObjective(gp.eigensystem(&y))
        };
        two_step_tune(make, opt)
    };
    let all: Vec<usize> = (0..n).collect();
    for method in [SparseMethod::Sor, SparseMethod::Nystrom] {
        let provider =
            SparseProvider::new(method, base, x.clone(), y.clone(), all.clone()).unwrap();
        let sparse = theta_tune(&provider, &opt).expect("full-inducing sparse tune");
        assert!(
            (sparse.score - exact.score).abs() <= 1e-4 * exact.score.abs().max(1.0),
            "{}: full-inducing tuned score {} vs exact {}",
            method.as_str(),
            sparse.score,
            exact.score
        );
    }
}

/// The oracle-grade sparse differential wall (verify::sparse_differential_suite)
/// is clean at integration sizes.
#[test]
fn sparse_differential_suite_is_clean() {
    let report = sparse_differential_suite(&[12, 20, 32], 0x9e37_79b9);
    assert!(report.ok(), "{}", report.summary());
    assert!(report.checks > 0);
}

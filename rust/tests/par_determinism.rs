//! Pool correctness and determinism gates (ISSUE 2 satellite, extended
//! by ISSUE 8 and ISSUE 10): width 1 must run the identical serial
//! arithmetic, pooled runs must agree with serial bit for bit, and the
//! `GPML_KERNEL=simd` and `scalar` microkernel backends must agree bit
//! for bit at every width — every fan-out partitions by fixed-shape
//! grains (a function of the problem size only, never the pool width)
//! whose per-element arithmetic is the canonical FMA-chain form both
//! backends implement (DESIGN.md §6, §12, §14).
//!
//! Thread widths are pinned per test via `threadpool::with_threads`,
//! eigensolvers via `with_solver` / `SymEigen::new_with`, and kernel
//! backends via `with_kernel_backend` — all thread-local — so these
//! tests are safe under the parallel libtest runner and independent of
//! the ambient GPML_THREADS / GPML_EIGEN / GPML_KERNEL values.

use gpml::kernelfn::{cross_gram, gram, Kernel};
use gpml::linalg::{
    gemm, microkernel, strassen, with_kernel_backend, with_solver, EigenSolver, KernelBackend,
    Matrix, SymEigen,
};
use gpml::optim::{self, Bounds, Objective};
use gpml::sparse::{even_inducing, SparseGp, SparseMethod};
use gpml::spectral::{EigenSystem, HyperParams, SpectralGp};
use gpml::util::rng::Rng;
use gpml::util::threadpool::with_threads;
use gpml::verify::{differential_suite, SuiteConfig};

fn random(rng: &mut Rng, m: usize, n: usize) -> Matrix {
    Matrix::from_fn(m, n, |_, _| rng.normal())
}

/// N large enough that every pooled site actually fans out at width 4
/// (the grain thresholds collapse smaller problems to serial).
const N_PAR: usize = 200;

#[test]
fn gram_width1_is_bitwise_the_canonical_fast_path_and_pooled_matches() {
    let mut rng = Rng::new(11);
    let p = 4;
    let x = random(&mut rng, N_PAR, p);
    let xi2 = 1.5;
    let kern = Kernel::Rbf { xi2 };
    // independent serial reference of the DESIGN.md §14 RBF fast path:
    // sq via the per-element FMA fold, inner products as ascending-d FMA
    // chains, d2 = fma(-2, t, sq_i + sq_j) clamped at 0, the fixed exp
    let sq: Vec<f64> = (0..N_PAR)
        .map(|i| x.row(i).iter().fold(0.0f64, |s, &v| v.mul_add(v, s)))
        .collect();
    let neg_inv = -1.0 / (2.0 * xi2);
    let mut want = Matrix::zeros(N_PAR, N_PAR);
    for i in 0..N_PAR {
        for j in i..N_PAR {
            let t = x
                .row(i)
                .iter()
                .zip(x.row(j))
                .fold(0.0f64, |acc, (&a, &b)| a.mul_add(b, acc));
            let d2 = (-2.0f64).mul_add(t, sq[i] + sq[j]);
            let d2 = if d2 > 0.0 { d2 } else { 0.0 };
            let v = microkernel::exp_fixed(d2 * neg_inv);
            want[(i, j)] = v;
            want[(j, i)] = v;
        }
    }
    let serial = with_threads(1, || gram(kern, &x));
    assert!(serial == want, "width-1 gram must be bit-identical to the canonical fast path");
    // the eval path (`Kernel::eval` per pair) must still agree closely
    for i in 0..N_PAR {
        for j in 0..N_PAR {
            let e = kern.eval(x.row(i), x.row(j));
            assert!((serial[(i, j)] - e).abs() <= 1e-14, "fast path drifts from eval at ({i},{j})");
        }
    }
    let pooled = with_threads(4, || gram(kern, &x));
    assert!(pooled == serial, "pooled gram must be bit-identical to serial");
    // and cross_gram(x, x) computes the same bits without the mirror phase
    let cross = with_threads(4, || cross_gram(kern, &x, &x));
    assert!(cross == serial, "cross_gram(x, x) must equal gram(x) bitwise");
}

#[test]
fn cross_gram_bitwise_across_widths() {
    let mut rng = Rng::new(12);
    let a = random(&mut rng, 150, 3);
    let b = random(&mut rng, 170, 3);
    let kern = Kernel::Matern52 { ell: 0.8 };
    let want = Matrix::from_fn(a.rows(), b.rows(), |i, j| kern.eval(a.row(i), b.row(j)));
    let serial = with_threads(1, || cross_gram(kern, &a, &b));
    assert!(serial == want, "width-1 cross_gram must match the pre-pool from_fn loop");
    let pooled = with_threads(4, || cross_gram(kern, &a, &b));
    assert!(pooled == serial);
}

#[test]
fn matmul_width1_is_bitwise_the_naive_fma_chain() {
    // the microkernel GEMM's canonical semantics (DESIGN.md §14): every
    // C element is a pure ascending-k mul_add chain — the packed 4x8
    // register tiling must never reorder a reduction
    fn naive_fma_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let k = a.cols();
        Matrix::from_fn(a.rows(), b.cols(), |i, j| {
            (0..k).fold(0.0f64, |acc, kk| a[(i, kk)].mul_add(b[(kk, j)], acc))
        })
    }
    let mut rng = Rng::new(13);
    let a = random(&mut rng, N_PAR, N_PAR);
    let b = random(&mut rng, N_PAR, N_PAR);
    let want = naive_fma_matmul(&a, &b);
    let serial = with_threads(1, || gemm::matmul(&a, &b));
    assert!(serial == want, "width-1 matmul must be bit-identical to the naive FMA chain");
    let pooled = with_threads(4, || gemm::matmul(&a, &b));
    assert!(pooled == serial, "pooled matmul must be bit-identical to serial");
}

#[test]
fn matmul_bt_and_ata_bitwise_across_widths() {
    let mut rng = Rng::new(14);
    let a = random(&mut rng, N_PAR, N_PAR);
    let b = random(&mut rng, N_PAR, N_PAR);
    let bt1 = with_threads(1, || gemm::matmul_bt(&a, &b));
    let bt4 = with_threads(4, || gemm::matmul_bt(&a, &b));
    assert!(bt1 == bt4, "pooled matmul_bt must be bit-identical to serial");
    // correctness against the reference product
    assert!(bt1.max_abs_diff(&gemm::matmul(&a, &b.t())) < 1e-9);

    // tall-skinny shape large enough for ata's column blocks to fan out
    let c = random(&mut rng, 3000, 400);
    let g1 = with_threads(1, || gemm::ata(&c));
    let g4 = with_threads(4, || gemm::ata(&c));
    assert!(g1 == g4, "pooled ata must be bit-identical to serial");
    assert!(g1.max_abs_diff(&gemm::matmul(&c.t(), &c)) < 1e-8);
}

#[test]
fn kernel_backends_bitwise_identical_for_gram_gemm_and_eigen_across_widths() {
    // ISSUE 10's headline gate: GPML_KERNEL=simd and =scalar must
    // produce identical bits for gram, GEMM, and the full SymEigen
    // pipeline at every pool width.  On hardware without AVX2+FMA the
    // Simd request resolves to the scalar path (same bits by
    // construction), so the gate degrades to a dispatch-plumbing check
    // rather than being skipped.
    let mut rng = Rng::new(21);
    let x = random(&mut rng, N_PAR, 4);
    let kern = Kernel::RbfArd {
        xi2: gpml::kernelfn::ThetaVec::from_slice(&[0.8, 1.5, 2.2, 0.6]).unwrap(),
    };
    let a = random(&mut rng, N_PAR, N_PAR);
    let b = random(&mut rng, N_PAR, N_PAR);
    let run = |backend: KernelBackend, width: usize| {
        with_threads(width, || {
            with_kernel_backend(backend, || {
                let g = gram(kern, &x);
                let m = gemm::matmul(&a, &b);
                // ambient solver: the gate holds under both GPML_EIGEN
                // legs of the CI matrix (tql2 is backend-independent
                // scalar code; tred2 and the D&C back-multiply route
                // through the microkernels)
                let e = SymEigen::new(&g).expect("eigensolver");
                (g, m, e)
            })
        })
    };
    let (g0, m0, e0) = run(KernelBackend::Scalar, 1);
    if microkernel::simd_available() {
        eprintln!("cross-backend gate: AVX2+FMA detected, simd leg is live");
    }
    for width in [1usize, 2, 4, 8] {
        for backend in [KernelBackend::Scalar, KernelBackend::Simd] {
            let (g, m, e) = run(backend, width);
            let tag = backend.as_str();
            assert!(g.data() == g0.data(), "gram drift: backend {tag}, width {width}");
            assert!(m.data() == m0.data(), "gemm drift: backend {tag}, width {width}");
            assert_eq!(e.values, e0.values, "eigenvalue drift: backend {tag}, width {width}");
            assert!(
                e.vectors.data() == e0.vectors.data(),
                "eigenvector drift: backend {tag}, width {width}"
            );
        }
    }
}

#[test]
fn strassen_bitwise_across_widths() {
    let mut rng = Rng::new(15);
    // above PAR_EDGE so the top level fans its seven quadrants out
    let n = 300;
    let a = random(&mut rng, n, n);
    let b = random(&mut rng, n, n);
    let s1 = with_threads(1, || strassen::strassen(&a, &b));
    let s4 = with_threads(4, || strassen::strassen(&a, &b));
    assert!(s1 == s4, "pooled strassen must be bit-identical to serial");
    assert!(s1.max_abs_diff(&gemm::matmul(&a, &b)) < 1e-8);
}

#[test]
fn eigendecomposition_bitwise_across_widths() {
    let mut rng = Rng::new(16);
    // above the eigensolver's fan-out threshold (steps i >= ~256 pool)
    let x = random(&mut rng, 400, 3);
    let k = gram(Kernel::Rbf { xi2: 1.5 }, &x);
    // the ambient-default solver (whichever GPML_EIGEN selected): since
    // ISSUE 8 the tred2 transform accumulation reduces fixed-shape
    // blocks, so the full solve — not just the tridiagonal phase — is
    // bit-identical across widths
    let e1 = with_threads(1, || SymEigen::new(&k).expect("serial eigensolver"));
    let e4 = with_threads(4, || SymEigen::new(&k).expect("pooled eigensolver"));
    assert_eq!(e1.values, e4.values, "eigenvalue drift across widths");
    assert!(
        e1.vectors.data() == e4.vectors.data(),
        "eigenvector drift {} across widths",
        e1.vectors.max_abs_diff(&e4.vectors)
    );
    // and the pooled decomposition still reconstructs the input
    assert!(e4.reconstruct().max_abs_diff(&k) < 1e-8);
}

#[test]
fn dac_eigendecomposition_bitwise_across_widths() {
    let mut rng = Rng::new(19);
    // N = 300: three recursion levels with odd splits (75/37/...), and
    // large enough for the secular/z-hat/GEMM fan-outs to engage
    let x = random(&mut rng, 300, 3);
    let k = gram(Kernel::Rbf { xi2: 1.2 }, &x);
    let e1 = with_threads(1, || SymEigen::new_with(&k, EigenSolver::Dac).unwrap());
    for width in [2usize, 4, 8] {
        let ew = with_threads(width, || SymEigen::new_with(&k, EigenSolver::Dac).unwrap());
        assert_eq!(e1.values, ew.values, "D&C eigenvalues drift at width {width}");
        assert!(
            e1.vectors.data() == ew.vectors.data(),
            "D&C eigenvectors drift at width {width}"
        );
    }
    // width 1 is the serial merge path by construction (the pool plan
    // collapses to the caller's thread); it must also be what a plain
    // un-pinned serial run produces
    let serial = with_threads(1, || SymEigen::new_with(&k, EigenSolver::Dac).unwrap());
    assert_eq!(serial.values, e1.values);
    assert!(serial.vectors.data() == e1.vectors.data());
}

#[test]
fn setup_tune_predict_roundtrip_bitwise_across_widths_through_dac() {
    // the full pipeline the solver sits under — gram -> tred2 -> D&C ->
    // EigenSystem -> grid search -> predict — pinned to D&C at every
    // pool width; any width-dependent partitioning anywhere in the
    // stack shows up here as a bit difference
    let run = |width: usize| {
        with_threads(width, || {
            with_solver(EigenSolver::Dac, || {
                let mut rng = Rng::new(77);
                let x = random(&mut rng, 260, 3);
                let y = rng.normal_vec(260);
                let gp = SpectralGp::fit(Kernel::Rbf { xi2: 1.2 }, x).unwrap();
                let mut es = gp.eigensystem(&y);
                let r = optim::grid_search(&mut es, Bounds::default(), 9, 32);
                let mut rq = Rng::new(78);
                let xq = random(&mut rq, 7, 3);
                let (mean, var) = gp.predict(&xq, &y, r.hp);
                (r.hp, r.score, mean, var)
            })
        })
    };
    let base = run(1);
    for width in [2usize, 4, 8] {
        let got = run(width);
        assert_eq!(base.0, got.0, "tuned hp drift at width {width}");
        assert_eq!(base.1, got.1, "tuned score drift at width {width}");
        assert_eq!(base.2, got.2, "predicted mean drift at width {width}");
        assert_eq!(base.3, got.3, "predicted variance drift at width {width}");
    }
}

#[test]
fn wavefront_eval_batch_bitwise_across_widths() {
    // synthetic O(N) state large enough for the wavefront grain to fan out
    let n = 2048;
    let mut rng = Rng::new(17);
    let s: Vec<f64> = (0..n).map(|i| (n - i) as f64 * rng.uniform_in(0.5, 1.0)).collect();
    let yt: Vec<f64> = rng.normal_vec(n);
    let yy = yt.iter().map(|v| v * v).sum();
    let es = EigenSystem::from_parts(
        s.iter().rev().copied().collect(),
        yt.iter().map(|v| v * v).collect(),
        n,
        yy,
    );
    let hps: Vec<HyperParams> = (0..64)
        .map(|i| HyperParams::new(0.1 + 0.05 * i as f64, 0.5 + 0.02 * i as f64))
        .collect();
    let mut es1 = es.clone();
    let mut es4 = es.clone();
    let serial = with_threads(1, || es1.eval_batch(&hps));
    let pooled = with_threads(4, || es4.eval_batch(&hps));
    assert_eq!(serial, pooled, "wavefront scores must be bit-identical across widths");
    // scalar loop is the ground truth for the batch
    let scalar: Vec<f64> = hps.iter().map(|&hp| es.score(hp)).collect();
    assert_eq!(serial, scalar);
}

#[test]
fn grid_search_result_bitwise_across_widths() {
    let n = 2048;
    let mut rng = Rng::new(18);
    let s: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.1, 10.0)).collect();
    let mut sorted = s.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let yt: Vec<f64> = rng.normal_vec(n);
    let yy = yt.iter().map(|v| v * v).sum();
    let es = EigenSystem::from_parts(sorted, yt.iter().map(|v| v * v).collect(), n, yy);
    let mut es1 = es.clone();
    let mut es4 = es.clone();
    let r1 = with_threads(1, || optim::grid_search(&mut es1, Bounds::default(), 17, 64));
    let r4 = with_threads(4, || optim::grid_search(&mut es4, Bounds::default(), 17, 64));
    assert_eq!(r1.hp, r4.hp);
    assert_eq!(r1.score, r4.score);
    assert_eq!(r1.evals, r4.evals);
}

#[test]
fn sparse_reduced_spectrum_bitwise_across_widths() {
    // ISSUE 9: the SoR pipeline fans out twice — the row-blocked
    // B = C L^{-T} solve (fixed-shape grain, a function of m only) and
    // the pooled ata — and Nyström leans on the pooled gram/eigen path;
    // at m = 96 the B-solve grain is ~14 rows/block, so N_PAR = 200 rows
    // genuinely split across workers at width 4+.
    let mut rng = Rng::new(20);
    let x = random(&mut rng, N_PAR, 4);
    let y = rng.normal_vec(N_PAR);
    let kern = Kernel::Rbf { xi2: 1.5 };
    let idx = even_inducing(N_PAR, 96);
    let hp = HyperParams::new(0.7, 1.3);
    for method in [SparseMethod::Sor, SparseMethod::Nystrom] {
        let tag = method.as_str();
        let run = |width: usize| {
            with_threads(width, || {
                let mut sp = SparseGp::new(method, kern, &x, &y, &idx).unwrap();
                let es = sp.eigensystem().unwrap().clone();
                let score = es.score(hp);
                (es, score)
            })
        };
        let base = run(1);
        for width in [2usize, 4, 8] {
            let got = run(width);
            assert_eq!(base.0.s, got.0.s, "{tag} eigenvalue drift at width {width}");
            assert_eq!(base.0.y2t, got.0.y2t, "{tag} projected-mass drift at width {width}");
            assert_eq!(base.1.to_bits(), got.1.to_bits(), "{tag} score drift at width {width}");
        }
    }
}

#[test]
fn verify_differential_suite_passes_under_the_pool() {
    // DESIGN.md §4's gate, executed with the pool engaged: the spectral
    // identities must survive the pooled gram/eigen/GEMM paths.
    let cfg = SuiteConfig {
        sizes: vec![8, 32, 128],
        datasets_per_size: 1,
        ..Default::default()
    };
    let pooled = with_threads(4, || differential_suite(&cfg));
    assert!(pooled.ok(), "{}", pooled.summary());
    let serial = with_threads(1, || differential_suite(&cfg));
    assert!(serial.ok(), "{}", serial.summary());
    assert_eq!(serial.cases, pooled.cases);
    assert_eq!(serial.checks, pooled.checks);
}

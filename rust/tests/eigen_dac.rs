//! Oracle-grade spectral test wall for the divide-and-conquer
//! eigensolver (ISSUE 8): seeded random symmetric matrices with
//! *planted* spectra are decomposed by both solvers and gated through
//! `verify::spectral_gate` — eigenvalues vs the QL oracle at rtol
//! 1e-12, eigenpair residuals, and orthogonality at 1e-10 — plus
//! planted-bug tests proving the gate has teeth.
//!
//! Sizes deliberately straddle the D&C leaf crossover (32) and force
//! odd splits; solvers are pinned per call via `with_solver` /
//! `SymEigen::new_with`, so the suite is independent of the ambient
//! `GPML_EIGEN` value (CI runs it under both).

use gpml::linalg::{with_solver, EigenSolver, Matrix, SymEigen};
use gpml::util::rng::Rng;
use gpml::verify::{spectral_gate, SpectralGateConfig};

/// Off-crossover, odd-split, and unit sizes from the ISSUE.
const SIZES: &[usize] = &[1, 2, 3, 8, 33, 128, 257];

/// A deterministic orthogonal basis: eigenvectors of a seeded random
/// symmetric matrix, taken from the QL path so the basis itself never
/// depends on the solver under test.
fn random_orthogonal(rng: &mut Rng, n: usize) -> Matrix {
    let b = Matrix::from_fn(n, n, |_, _| rng.normal());
    let mut s = b.add(&b.t());
    s.scale(0.5);
    SymEigen::new_with(&s, EigenSolver::Ql).unwrap().vectors
}

/// `Q diag(vals) Q'` with `vals` sorted ascending in place, so the
/// planted spectrum is directly comparable to solver output.
fn plant(q: &Matrix, vals: &mut Vec<f64>) -> Matrix {
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    SymEigen { values: vals.clone(), vectors: q.clone() }.reconstruct()
}

/// Run one planted-spectrum family through both solvers and the gate.
fn gate_family(name: &str, spectrum: impl Fn(usize) -> Vec<f64>) {
    let mut rng = Rng::new(0xDAC0 + name.len() as u64);
    let cfg = SpectralGateConfig::default();
    for &n in SIZES {
        let q = random_orthogonal(&mut rng, n);
        let mut vals = spectrum(n);
        assert_eq!(vals.len(), n, "family {name} produced a wrong-size spectrum");
        let a = plant(&q, &mut vals);
        // exercise the default-dispatch path, pinned to D&C
        let dac = with_solver(EigenSolver::Dac, || SymEigen::new(&a))
            .unwrap_or_else(|e| panic!("{name} n={n}: dac failed: {e}"));
        let ql = SymEigen::new_with(&a, EigenSolver::Ql)
            .unwrap_or_else(|e| panic!("{name} n={n}: ql oracle failed: {e}"));
        spectral_gate(&a, &dac, Some(&ql), &cfg)
            .unwrap_or_else(|e| panic!("{name} n={n} (dac vs ql oracle): {e}"));
        // the oracle itself must clear the residual/orthogonality bars
        spectral_gate(&a, &ql, None, &cfg)
            .unwrap_or_else(|e| panic!("{name} n={n} (ql self-check): {e}"));
        // and the planted spectrum must be recovered
        let scale = vals.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        for (j, (got, want)) in dac.values.iter().zip(&vals).enumerate() {
            assert!(
                (got - want).abs() <= 1e-10 * scale,
                "{name} n={n}: planted eigenvalue {j} not recovered: {got} vs {want}"
            );
        }
    }
}

#[test]
fn planted_tight_clusters() {
    // almost-degenerate cluster at 1 (gaps of a few ulps — the regime
    // where naive secular updates lose orthogonality), plus separated
    // anchors so deflation cannot trivialize the merge
    gate_family("tight-clusters", |n| {
        (0..n)
            .map(|i| match i % 8 {
                0 => 0.25,
                1 => 4.0 + 1e-13 * (i / 8) as f64,
                _ => 1.0 + 1e-14 * i as f64,
            })
            .collect()
    });
}

#[test]
fn planted_rank_deficient() {
    // half the spectrum exactly zero (the kernel Gram regime), the rest
    // spread over two decades
    gate_family("rank-deficient", |n| {
        (0..n)
            .map(|i| if i < n / 2 { 0.0 } else { 0.1 * (1 + i - n / 2) as f64 })
            .collect()
    });
}

#[test]
fn planted_geometric_decay() {
    // lambda_i = 1.25^-i: every scale from O(1) down to underflow-ish,
    // adjacent gaps shrinking geometrically
    gate_family("geometric-decay", |n| (0..n).map(|i| 1.25f64.powi(-(i as i32))).collect())
}

#[test]
fn planted_plus_minus_pairs() {
    // symmetric ±pairs (indefinite input — exercises the rho < 0 merge
    // flip); odd sizes add a zero
    gate_family("pm-pairs", |n| {
        let mut v = Vec::with_capacity(n);
        for i in 0..n / 2 {
            let mag = 1.0 + 0.5 * i as f64;
            v.push(mag);
            v.push(-mag);
        }
        if n % 2 == 1 {
            v.push(0.0);
        }
        v
    });
}

#[test]
fn planted_uniform_random() {
    gate_family("uniform-random", |n| {
        let mut r = Rng::new(0xF00D + n as u64);
        (0..n).map(|_| r.uniform_in(-5.0, 5.0)).collect()
    });
}

/// The gate must trip when a single secular root is wrong — the exact
/// failure mode a broken merge would produce.
#[test]
fn gate_trips_on_a_corrupted_secular_root() {
    let n = 64;
    let mut rng = Rng::new(0xBAD);
    let q = random_orthogonal(&mut rng, n);
    let mut vals: Vec<f64> = (0..n).map(|i| 1.0 + 0.05 * i as f64).collect();
    let a = plant(&q, &mut vals);
    let ql = SymEigen::new_with(&a, EigenSolver::Ql).unwrap();
    let dac = SymEigen::new_with(&a, EigenSolver::Dac).unwrap();
    let cfg = SpectralGateConfig::default();
    spectral_gate(&a, &dac, Some(&ql), &cfg).expect("clean decomposition must pass");

    // one mis-converged root, 1e-8 * scale off — far above solver noise,
    // far below anything a reconstruct-level smoke test would notice
    let scale = vals.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    let mut bad = dac.clone();
    bad.values[40] += 1e-8 * scale;
    assert!(
        spectral_gate(&a, &bad, Some(&ql), &cfg).is_err(),
        "corrupted secular root slipped through the gate"
    );

    // a denormalized eigenvector column (broken z-hat / W normalization)
    let mut bad = dac.clone();
    for r in 0..n {
        bad.vectors[(r, 17)] *= 1.0 + 1e-6;
    }
    assert!(
        spectral_gate(&a, &bad, Some(&ql), &cfg).is_err(),
        "denormalized eigenvector column slipped through the gate"
    );

    // swapped adjacent eigenvalues (a broken merge permutation)
    let mut bad = dac.clone();
    bad.values.swap(20, 21);
    assert!(
        spectral_gate(&a, &bad, Some(&ql), &cfg).is_err(),
        "non-ascending spectrum slipped through the gate"
    );
}

/// Unit sizes and already-tridiagonal inputs (the latent edge cases the
/// ISSUE calls out), through both solvers.
#[test]
fn unit_sizes_and_tridiagonal_inputs() {
    let cfg = SpectralGateConfig::default();
    for solver in [EigenSolver::Dac, EigenSolver::Ql] {
        // N = 0
        let a = Matrix::zeros(0, 0);
        let eg = SymEigen::new_with(&a, solver).unwrap();
        assert!(eg.values.is_empty());
        spectral_gate(&a, &eg, None, &cfg).unwrap();
        // N = 1, negative entry
        let a = Matrix::diag(&[-2.25]);
        let eg = SymEigen::new_with(&a, solver).unwrap();
        assert_eq!(eg.values, vec![-2.25]);
        spectral_gate(&a, &eg, None, &cfg).unwrap();
    }
    // already-tridiagonal inputs, including one decoupled exactly at the
    // D&C split point (beta = 0 merge) and one fully diagonal
    for &n in &[2usize, 3, 8, 33, 40, 64] {
        let mut tri = Matrix::from_fn(n, n, |i, j| {
            if i == j {
                (i as f64 * 0.9).cos() * 3.0
            } else if i.abs_diff(j) == 1 {
                0.7 + 0.02 * i.min(j) as f64
            } else {
                0.0
            }
        });
        if n == 40 {
            tri[(19, 20)] = 0.0;
            tri[(20, 19)] = 0.0;
        }
        let ql = SymEigen::new_with(&tri, EigenSolver::Ql).unwrap();
        let dac = SymEigen::new_with(&tri, EigenSolver::Dac).unwrap();
        let cfg = SpectralGateConfig::default();
        spectral_gate(&tri, &dac, Some(&ql), &cfg)
            .unwrap_or_else(|e| panic!("tridiagonal n={n}: {e}"));

        let diag = Matrix::diag(&(0..n).map(|i| (i % 5) as f64).collect::<Vec<_>>());
        let ql = SymEigen::new_with(&diag, EigenSolver::Ql).unwrap();
        let dac = SymEigen::new_with(&diag, EigenSolver::Dac).unwrap();
        spectral_gate(&diag, &dac, Some(&ql), &cfg)
            .unwrap_or_else(|e| panic!("diagonal n={n}: {e}"));
    }
}

/// Below the crossover, D&C dispatch *is* the QL path — bit for bit.
#[test]
fn below_crossover_solvers_are_bitwise_identical() {
    let mut rng = Rng::new(0x51CE);
    for &n in &[1usize, 8, 16, 31, 32] {
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.add(&b.t());
        a.scale(0.5);
        let dac = SymEigen::new_with(&a, EigenSolver::Dac).unwrap();
        let ql = SymEigen::new_with(&a, EigenSolver::Ql).unwrap();
        assert_eq!(dac.values, ql.values, "n={n}");
        assert_eq!(dac.vectors.data(), ql.vectors.data(), "n={n}");
    }
}

//! Integration: end-to-end tuning through the coordinator, PJRT backend vs
//! pure-rust backend, multi-output reuse, and Algorithm 1 on real GP data.

mod common;

use gpml::coordinator::{Backend, Coordinator, GlobalStrategy, ObjectiveKind, TuneRequest};
use gpml::data::{synthetic, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::optim::{self, two_step_tune, Bounds, TwoStepOptions};
use gpml::spectral::{HyperParams, SpectralGp};

fn request(n: usize, outputs: usize, seed: u64) -> TuneRequest {
    let spec = SyntheticSpec {
        n,
        p: 3,
        kernel: Kernel::Rbf { xi2: 2.0 },
        sigma2: 0.1,
        lambda2: 1.0,
        seed,
    };
    let ds = synthetic(spec, outputs);
    let mut req = TuneRequest::new(ds.x, ds.ys, spec.kernel);
    req.strategy = GlobalStrategy::Grid { points_per_axis: 9 };
    req
}

#[test]
fn pjrt_and_rust_backends_agree() {
    let Some(rt) = common::open_runtime() else { return };
    let mut coord = Coordinator::with_runtime(rt);
    let mut req = request(60, 1, 21);
    req.backend = Backend::Rust;
    let rust_res = coord.tune(&req).unwrap();
    req.backend = Backend::Pjrt;
    let pjrt_res = coord.tune(&req).unwrap();
    assert!(pjrt_res.eigen_cached, "second tune over same data reuses eigen");
    let (a, b) = (&rust_res.outputs[0], &pjrt_res.outputs[0]);
    // same deterministic optimizer over numerically identical objectives
    assert!(
        (a.hp.sigma2 - b.hp.sigma2).abs() < 1e-5 * a.hp.sigma2,
        "sigma2: rust {} vs pjrt {}",
        a.hp.sigma2,
        b.hp.sigma2
    );
    assert!(
        (a.hp.lambda2 - b.hp.lambda2).abs() < 1e-5 * a.hp.lambda2,
        "lambda2: rust {} vs pjrt {}",
        a.hp.lambda2,
        b.hp.lambda2
    );
    assert!((a.score - b.score).abs() < 1e-6 * a.score.abs().max(1.0));
}

#[test]
fn tuned_hyperparams_recover_generating_scale() {
    // With enough data, the evidence-tuned sigma2 should land near the
    // generating noise level (order of magnitude).  The paper score is
    // boundary-seeking by construction (see DESIGN.md), so the recovery
    // check uses the evidence objective.
    let mut coord = Coordinator::rust_only();
    let mut req = request(200, 1, 33);
    req.strategy = GlobalStrategy::Pso { particles: 32, iterations: 20 };
    req.objective = ObjectiveKind::Evidence;
    let res = coord.tune(&req).unwrap();
    let hp = res.outputs[0].hp;
    assert!(
        hp.sigma2 > 0.01 && hp.sigma2 < 1.0,
        "tuned sigma2 {} should be near generating 0.1",
        hp.sigma2
    );
}

#[test]
fn multi_output_pjrt_tuning() {
    let Some(rt) = common::open_runtime() else { return };
    let mut coord = Coordinator::with_runtime(rt);
    let mut req = request(50, 4, 55);
    req.backend = Backend::Pjrt;
    let res = coord.tune(&req).unwrap();
    assert_eq!(res.outputs.len(), 4);
    assert_eq!(coord.cache_misses, 1, "one decomposition for 4 outputs");
    for o in &res.outputs {
        assert!(o.score.is_finite());
        assert!(o.hp.feasible());
    }
}

#[test]
fn two_step_tunes_rbf_bandwidth_on_gp_data() {
    // Data generated with xi2 = 2.0; Algorithm 1's best probed bandwidth
    // must beat a bad fixed bandwidth tuned the same way.  The bad
    // bandwidth sits at the *upper* edge (xi2 = 50): under the paper's
    // eq. 19 objective the theta-profile is boundary-seeking toward
    // small bandwidths (K -> I gives a flat spectrum, the sigma2 -> 0
    // pathology of DESIGN.md reappears along theta), so the lower edge
    // is — counterintuitively — near-optimal for this objective and
    // differs from the golden-section probes only at noise level, which
    // made the original lower-edge comparison a coin flip.
    let spec = SyntheticSpec {
        n: 80,
        p: 2,
        kernel: Kernel::Rbf { xi2: 2.0 },
        sigma2: 0.05,
        lambda2: 1.0,
        seed: 77,
    };
    let ds = synthetic(spec, 1);
    let y = ds.y().to_vec();
    let x = ds.x.clone();

    let result = two_step_tune(
        |theta| {
            let gp = SpectralGp::fit(Kernel::Rbf { xi2: theta }, x.clone()).unwrap();
            gp.eigensystem(&y)
        },
        TwoStepOptions {
            theta_range: (0.05, 50.0),
            outer_iters: 12,
            inner_grid: 7,
            ..Default::default()
        },
    );
    // compare against the deliberately bad upper-edge bandwidth tuned
    // the same way (see the comment above for why not the lower edge)
    let gp_bad = SpectralGp::fit(Kernel::Rbf { xi2: 50.0 }, x.clone()).unwrap();
    let mut es_bad = gp_bad.eigensystem(&y);
    let bad = optim::grid_search(&mut es_bad, Bounds::default(), 9, 64);
    let bad_refined = optim::newton_refine(&mut es_bad, bad.hp, Bounds::default(), Default::default());
    assert!(
        result.score <= bad_refined.score + 1e-9,
        "two-step score {} should beat fixed-bad-bandwidth {}",
        result.score,
        bad_refined.score
    );
    assert!(result.theta > 0.05 && result.theta < 50.0);
    assert_eq!(result.outer_evals, 12);
}

#[test]
fn prediction_quality_after_tuning() {
    // Full pipeline: tune on train, predict on held-out test, beat the
    // predict-the-mean baseline by a wide margin.
    let spec = SyntheticSpec {
        n: 150,
        p: 2,
        kernel: Kernel::Rbf { xi2: 2.0 },
        sigma2: 0.01,
        lambda2: 1.0,
        seed: 99,
    };
    let ds = synthetic(spec, 1);
    let mut rng = gpml::util::rng::Rng::new(5);
    let (train, test) = ds.split(0.8, &mut rng);

    let mut coord = Coordinator::rust_only();
    let mut req = TuneRequest::new(train.x.clone(), train.ys.clone(), spec.kernel);
    req.strategy = GlobalStrategy::Pso { particles: 32, iterations: 20 };
    req.objective = ObjectiveKind::Evidence;
    let res = coord.tune(&req).unwrap();
    let hp = HyperParams::new(res.outputs[0].hp.sigma2, res.outputs[0].hp.lambda2);

    let gp = SpectralGp::fit(spec.kernel, train.x.clone()).unwrap();
    let pred = gp.predict_mean(&test.x, train.y(), hp);
    let rmse = gpml::data::rmse(&pred, test.y());
    let ymean = test.y().iter().sum::<f64>() / test.n() as f64;
    let base: Vec<f64> = vec![ymean; test.n()];
    let base_rmse = gpml::data::rmse(&base, test.y());
    assert!(
        rmse < 0.5 * base_rmse,
        "GP rmse {rmse} should easily beat mean-baseline {base_rmse}"
    );
    // predictive variance should be positive and finite
    for v in gp.predict_var(&test.x, hp) {
        assert!(v.is_finite() && v > 0.0);
    }
}

#![allow(dead_code)] // shared across multiple test binaries; each uses a subset
//! Shared helpers for integration tests: locate the artifact directory and
//! build small eigensystems.

use gpml::data::{synthetic, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::spectral::{EigenSystem, SpectralGp};

/// Artifact dir relative to the crate root (tests run from there).
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var_os("GPML_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Skip (return None) when artifacts have not been built.
pub fn open_runtime() -> Option<gpml::runtime::PjrtRuntime> {
    let dir = artifact_dir();
    match gpml::runtime::PjrtRuntime::open(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!(
                "SKIP: no artifacts at {} ({e:#}); run `make artifacts` first",
                dir.display()
            );
            None
        }
    }
}

/// A small RBF eigensystem plus the pieces needed to cross-check.
pub fn small_system(n: usize, seed: u64) -> (SpectralGp, Vec<f64>, EigenSystem) {
    let spec = SyntheticSpec {
        n,
        p: 3,
        kernel: Kernel::Rbf { xi2: 1.5 },
        sigma2: 0.1,
        lambda2: 1.0,
        seed,
    };
    let ds = synthetic(spec, 1);
    let gp = SpectralGp::fit(spec.kernel, ds.x.clone()).expect("eigensolver");
    let es = gp.eigensystem(ds.y());
    (gp, ds.ys.into_iter().next().unwrap(), es)
}

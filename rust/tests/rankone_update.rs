//! Differential gate for the streaming-update subsystem (ISSUE 4):
//! `SpectralGp::extend`-then-evaluate must match a from-scratch refit
//! within 1e-7 relative tolerance at N in {8, 32, 128} — for a single
//! append, a batched append, and an append past the fallback threshold —
//! under the scoped pool at width 1 (exact serial) and width 4.
//!
//! "Evaluate" here covers every downstream consumer of the
//! decomposition: the paper score / Jacobian / Hessian closed forms
//! (eqs. 19-28), the evidence objective, and the posterior predictive
//! mean + variance at held-out inputs.  All of these are invariant under
//! the eigenbasis rotations that can legitimately differ between the
//! incremental and the cold decomposition (degenerate eigenspaces), so
//! agreement is the right acceptance surface — eigenvector columns
//! themselves are compared only through these functionals.

use gpml::data::{synthetic, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::linalg::Matrix;
use gpml::spectral::{ExtendOutcome, ExtendPolicy, HyperParams, RefitReason, SpectralGp};
use gpml::util::rng::Rng;
use gpml::util::threadpool::with_threads;

const RTOL: f64 = 1e-7;
const KERNEL: Kernel = Kernel::Rbf { xi2: 2.0 };

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1.0)
}

fn hp_grid() -> Vec<HyperParams> {
    [(0.05, 0.5), (0.1, 1.0), (0.5, 2.0), (1.0, 1.0), (2.0, 0.3)]
        .iter()
        .map(|&(s, l)| HyperParams::new(s, l))
        .collect()
}

/// Full-dataset inputs split into a base prefix and an appended suffix.
fn split_dataset(n: usize, m: usize, seed: u64) -> (Matrix, Matrix, Vec<f64>) {
    let spec = SyntheticSpec { n: n + m, p: 3, seed, kernel: KERNEL, ..Default::default() };
    let ds = synthetic(spec, 1);
    let base = ds.x.top_left(n, 3);
    let extra = Matrix::from_fn(m, 3, |i, j| ds.x[(n + i, j)]);
    (base, extra, ds.ys[0].clone())
}

/// Every spectral functional the serving layer exposes, compared at RTOL.
fn assert_matches_refit(ext: &SpectralGp, refit: &SpectralGp, y: &[f64], ctx: &str) {
    assert_eq!(ext.n(), refit.n(), "{ctx}: sizes differ");
    let es_e = ext.eigensystem(y);
    let es_r = refit.eigensystem(y);
    for hp in hp_grid() {
        for (tag, a, b) in [
            ("paper", es_e.evaluate(hp), es_r.evaluate(hp)),
            ("evidence", es_e.evidence_evaluate(hp), es_r.evidence_evaluate(hp)),
        ] {
            assert!(
                rel(a.score, b.score) < RTOL,
                "{ctx} {tag} score @ {hp:?}: {} vs {}",
                a.score,
                b.score
            );
            for i in 0..2 {
                assert!(
                    rel(a.jac[i], b.jac[i]) < RTOL,
                    "{ctx} {tag} jac[{i}] @ {hp:?}: {} vs {}",
                    a.jac[i],
                    b.jac[i]
                );
                for j in 0..2 {
                    assert!(
                        rel(a.hess[i][j], b.hess[i][j]) < RTOL,
                        "{ctx} {tag} hess[{i}][{j}] @ {hp:?}: {} vs {}",
                        a.hess[i][j],
                        b.hess[i][j]
                    );
                }
            }
        }
    }
    // posterior predictive at held-out inputs
    let mut rng = Rng::new(0xFEED);
    let xnew = Matrix::from_fn(5, 3, |_, _| rng.normal());
    let hp = HyperParams::new(0.1, 1.0);
    let (mean_e, var_e) = ext.predict(&xnew, y, hp);
    let (mean_r, var_r) = refit.predict(&xnew, y, hp);
    for i in 0..5 {
        assert!(
            rel(mean_e[i], mean_r[i]) < RTOL,
            "{ctx} predict mean[{i}]: {} vs {}",
            mean_e[i],
            mean_r[i]
        );
        assert!(
            rel(var_e[i], var_r[i]) < RTOL,
            "{ctx} predict var[{i}]: {} vs {}",
            var_e[i],
            var_r[i]
        );
    }
}

fn run_extend_case(n: usize, m: usize, seed: u64, width: usize) {
    with_threads(width, || {
        let (base, extra, y) = split_dataset(n, m, seed);
        let full_x = {
            let mut data = base.data().to_vec();
            data.extend_from_slice(extra.data());
            Matrix::from_vec(n + m, 3, data)
        };
        let gp = SpectralGp::fit(KERNEL, base).unwrap();
        let (ext, outcome) = gp.extend(&extra).unwrap();
        assert_eq!(
            outcome,
            ExtendOutcome::Incremental,
            "N={n} m={m}: expected the incremental path"
        );
        let refit = SpectralGp::fit(KERNEL, full_x).unwrap();
        assert_matches_refit(&ext, &refit, &y, &format!("N={n} m={m} width={width}"));
    });
}

#[test]
fn single_append_matches_refit() {
    for &n in &[8usize, 32, 128] {
        for width in [1usize, 4] {
            run_extend_case(n, 1, 100 + n as u64, width);
        }
    }
}

#[test]
fn batched_append_matches_refit() {
    for &n in &[8usize, 32, 128] {
        for width in [1usize, 4] {
            run_extend_case(n, 5, 200 + n as u64, width);
        }
    }
}

#[test]
fn append_past_threshold_falls_back_and_matches() {
    for &n in &[8usize, 32, 128] {
        with_threads(4, || {
            let m = 6;
            let (base, extra, y) = split_dataset(n, m, 300 + n as u64);
            let full_x = {
                let mut data = base.data().to_vec();
                data.extend_from_slice(extra.data());
                Matrix::from_vec(n + m, 3, data)
            };
            let gp = SpectralGp::fit(KERNEL, base).unwrap();
            // 6 appends = 12 corrections > budget of 4: full refit path
            let policy = ExtendPolicy { max_updates: 4, ..Default::default() };
            let (ext, outcome) = gp.extend_with(&extra, policy).unwrap();
            assert_eq!(outcome, ExtendOutcome::Refit(RefitReason::UpdateBudget));
            assert_eq!(ext.updates(), 0, "a refit resets the correction budget");
            let refit = SpectralGp::fit(KERNEL, full_x).unwrap();
            assert_matches_refit(&ext, &refit, &y, &format!("N={n} fallback"));
        });
    }
}

#[test]
fn zero_ortho_tolerance_forces_conditioning_refit() {
    let (base, extra, _) = split_dataset(16, 1, 400);
    let gp = SpectralGp::fit(KERNEL, base).unwrap();
    let policy = ExtendPolicy { max_updates: 1000, ortho_tol: 0.0 };
    let (_, outcome) = gp.extend_with(&extra, policy).unwrap();
    assert_eq!(outcome, ExtendOutcome::Refit(RefitReason::Conditioning));
}

#[test]
fn chained_appends_stay_within_tolerance() {
    // stream 8 observations one at a time (16 corrections, inside the
    // default budget of 64) and gate the accumulated drift
    with_threads(4, || {
        let n = 32;
        let m = 8;
        let (base, extra, y) = split_dataset(n, m, 500);
        let full_x = {
            let mut data = base.data().to_vec();
            data.extend_from_slice(extra.data());
            Matrix::from_vec(n + m, 3, data)
        };
        let mut gp = SpectralGp::fit(KERNEL, base).unwrap();
        for t in 0..m {
            let row = Matrix::from_fn(1, 3, |_, j| extra[(t, j)]);
            let (next, outcome) = gp.extend(&row).unwrap();
            assert_eq!(outcome, ExtendOutcome::Incremental, "append {t}");
            gp = next;
        }
        assert_eq!(gp.updates(), 2 * m);
        let refit = SpectralGp::fit(KERNEL, full_x).unwrap();
        assert_matches_refit(&gp, &refit, &y, "chained");
    });
}

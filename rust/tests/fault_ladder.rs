//! Differential tests for the numeric degradation ladder (DESIGN.md §11):
//! a rescued setup must evaluate the paper's score/Jacobian/Hessian
//! (eqs. 19-28) indistinguishably from a clean decomposition of the same
//! (jittered) matrix, and the ladder must fail loudly — walking every
//! rung — when no jitter can repair the spectrum.

use gpml::faults::{cholesky_eigen, hardened_eigen, FaultCounters, FaultPolicy, SetupGrade};
use gpml::linalg::{matmul_bt, with_solver, EigenSolver, Matrix, SymEigen};
use gpml::spectral::{EigenSystem, HyperParams};

/// Deterministic symmetric PSD matrix `B B'` with bounded entries.
fn psd(n: usize, seed: u64) -> Matrix {
    let mut state = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
    let mut next = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        (state >> 11) as f64 / (1u64 << 53) as f64 - 0.5
    };
    let b = Matrix::from_fn(n, n, |_, _| next());
    matmul_bt(&b, &b)
}

/// Deterministic pseudo-observations.
fn outputs(n: usize, seed: u64) -> Vec<f64> {
    let mut state = seed.wrapping_mul(0x2545f4914f6cdd1d).wrapping_add(11);
    (0..n)
        .map(|_| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64 - 0.5
        })
        .collect()
}

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1e-300)
}

/// A jitter-rescued setup is *bitwise* the clean decomposition of the
/// jittered matrix: score, Jacobian and Hessian all match exactly.
#[test]
fn jitter_rescue_is_differentially_exact() {
    let n = 24;
    let mut k = psd(n, 41);
    let policy = FaultPolicy::default();
    let clean = SymEigen::new(&k).unwrap();
    let scale = clean.values.iter().fold(0f64, |m, v| m.max(v.abs()));
    // push the floor just past the PD tolerance: broken enough to reject,
    // small enough that a jitter rung repairs it
    let deficit = clean.values[0] + 2.0 * policy.pd_tol * scale;
    k.add_diag(-deficit);

    let counters = FaultCounters::default();
    let h = hardened_eigen(&k, &policy, &counters).unwrap();
    let SetupGrade::Jittered { rung, jitter } = h.grade else {
        panic!("expected a jitter rescue, got {:?}", h.grade);
    };
    assert!((1..=policy.max_jitter_rungs).contains(&rung));
    assert_eq!(counters.snapshot().jitter_retries, rung as u64);

    // reference: decompose the jittered matrix directly
    let mut kj = k.clone();
    kj.add_diag(jitter);
    let direct = SymEigen::new(&kj).unwrap();

    let y = outputs(n, 7);
    let rescued = EigenSystem::new(&h.eigen, &y);
    let reference = EigenSystem::new(&direct, &y);
    for &(s2, l2) in &[(0.05, 1.0), (0.5, 0.2), (2.0, 4.0)] {
        let hp = HyperParams::new(s2, l2);
        let a = rescued.evaluate(hp);
        let b = reference.evaluate(hp);
        assert_eq!(a.score, b.score, "score at ({s2}, {l2})");
        assert_eq!(a.jac, b.jac, "jacobian at ({s2}, {l2})");
        assert_eq!(a.hess, b.hess, "hessian at ({s2}, {l2})");
    }
}

/// The Cholesky-backed fallback reproduces score/Jacobian/Hessian of the
/// direct symmetric eigensolver within the verification tolerances
/// (DESIGN.md §4 uses 1e-7 relative; the similarity transform costs a
/// little precision, so 1e-6 here).
#[test]
fn cholesky_backed_evaluation_matches_direct() {
    let n = 32;
    let mut k = psd(n, 13);
    k.add_diag(0.5); // comfortably PD so both routes succeed

    let via_chol = cholesky_eigen(&k).unwrap();
    let direct = SymEigen::new(&k).unwrap();
    for (a, b) in via_chol.values.iter().zip(direct.values.iter()) {
        assert!(rel(*a, *b) < 1e-9, "eigenvalue mismatch: {a} vs {b}");
    }

    let y = outputs(n, 3);
    let es_chol = EigenSystem::new(&via_chol, &y);
    let es_direct = EigenSystem::new(&direct, &y);
    for &(s2, l2) in &[(0.05, 1.0), (0.5, 0.2), (2.0, 4.0), (1e-3, 10.0)] {
        let hp = HyperParams::new(s2, l2);
        let a = es_chol.evaluate(hp);
        let b = es_direct.evaluate(hp);
        assert!(rel(a.score, b.score) < 1e-6, "score at ({s2}, {l2}): {} vs {}", a.score, b.score);
        for d in 0..2 {
            assert!(
                rel(a.jac[d], b.jac[d]) < 1e-6,
                "jac[{d}] at ({s2}, {l2}): {} vs {}",
                a.jac[d],
                b.jac[d]
            );
            for e in 0..2 {
                assert!(
                    rel(a.hess[d][e], b.hess[d][e]) < 1e-6,
                    "hess[{d}][{e}] at ({s2}, {l2}): {} vs {}",
                    a.hess[d][e],
                    b.hess[d][e]
                );
            }
        }
    }
}

/// An irreparably indefinite matrix walks *every* rung in order — all
/// jitter retries, then the Cholesky fallback — and the structured error
/// plus the counters record the whole walk, identically on every run.
#[test]
fn planted_non_pd_walks_every_rung_and_reports() {
    let policy = FaultPolicy::default();
    let mut k = psd(16, 29);
    let spread = SymEigen::new(&k).unwrap().values.last().copied().unwrap();
    k.add_diag(-0.5 * spread); // far beyond any jitter rung's reach

    let run = |k: &Matrix| {
        let counters = FaultCounters::default();
        let err = hardened_eigen(k, &policy, &counters).unwrap_err();
        (err.to_string(), counters.snapshot())
    };
    let (msg, snap) = run(&k);
    assert_eq!(snap.jitter_retries, policy.max_jitter_rungs as u64);
    assert_eq!(snap.fallback_refits, 1);
    assert!(msg.contains("cholesky"), "error names the fallback stage: {msg}");
    assert!(
        msg.contains(&policy.max_jitter_rungs.to_string()),
        "error counts the rungs walked: {msg}"
    );

    // deterministic: the second walk is the first, bit for bit
    let (msg2, snap2) = run(&k);
    assert_eq!(msg, msg2);
    assert_eq!(snap, snap2);
}

/// Degenerate sizes stay structured: an empty matrix either decomposes
/// cleanly or fails with the ladder error — it must not panic.
#[test]
fn zero_dimensional_matrix_does_not_panic() {
    let k = Matrix::zeros(0, 0);
    let counters = FaultCounters::default();
    let _ = hardened_eigen(&k, &FaultPolicy::default(), &counters);
}

/// The ladder routes through whichever solver is ambient: a clean walk
/// under D&C is bitwise the direct D&C decomposition, and its
/// score/Jacobian/Hessian agree with the QL oracle's within the
/// differential tolerances.
#[test]
fn clean_ladder_through_dac_matches_the_ql_oracle() {
    let n = 48; // above the D&C crossover: the solve traverses a merge
    let k = psd(n, 51);
    let counters = FaultCounters::default();
    let h = with_solver(EigenSolver::Dac, || {
        hardened_eigen(&k, &FaultPolicy::default(), &counters)
    })
    .unwrap();
    assert_eq!(h.grade, SetupGrade::Clean);
    let direct = SymEigen::new_with(&k, EigenSolver::Dac).unwrap();
    assert_eq!(h.eigen.values, direct.values);
    assert_eq!(h.eigen.vectors.data(), direct.vectors.data());

    let ql = SymEigen::new_with(&k, EigenSolver::Ql).unwrap();
    let y = outputs(n, 9);
    let es_dac = EigenSystem::new(&h.eigen, &y);
    let es_ql = EigenSystem::new(&ql, &y);
    for &(s2, l2) in &[(0.05, 1.0), (0.5, 0.2), (2.0, 4.0)] {
        let hp = HyperParams::new(s2, l2);
        let a = es_dac.evaluate(hp);
        let b = es_ql.evaluate(hp);
        assert!(rel(a.score, b.score) < 1e-9, "score at ({s2}, {l2})");
        for d in 0..2 {
            // absolute-with-floor: jacobian components may sit near zero
            let diff = (a.jac[d] - b.jac[d]).abs();
            let bar = 1e-9 * (1.0 + a.jac[d].abs().max(b.jac[d].abs()));
            assert!(diff < bar, "jac[{d}] at ({s2}, {l2}): {} vs {}", a.jac[d], b.jac[d]);
        }
    }
}

/// End-to-end ladder walks driven by the D&C merge injection point
/// (`--features fault-inject`): the clean attempt dies inside the new
/// solver, and the ladder degrades exactly as it would for a real QL
/// stagnation — jitter rungs first, Cholesky fallback after, structured
/// error at the very end.
#[cfg(feature = "fault-inject")]
mod dac_merge_injection {
    use super::*;
    use gpml::faults::inject::{self, FaultPoint};

    /// Injection state is process-global; serialize the tests that arm it.
    static INJECT_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    /// n > CROSSOVER so every solve traverses exactly one merge — each
    /// ladder attempt consumes exactly one scheduled firing.
    const N: usize = 48;

    #[test]
    fn merge_failure_walks_one_jitter_rung_and_is_differentially_exact() {
        let _g = INJECT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        inject::reset();
        let k = psd(N, 61);
        let policy = FaultPolicy::default();
        let counters = FaultCounters::default();
        inject::arm(FaultPoint::DacMergeNoConvergence, 1, 1);
        let h = with_solver(EigenSolver::Dac, || hardened_eigen(&k, &policy, &counters));
        inject::reset();
        let h = h.unwrap();
        let SetupGrade::Jittered { rung, jitter } = h.grade else {
            panic!("expected a jitter rescue, got {:?}", h.grade);
        };
        assert_eq!(rung, 1, "first rung must rescue once the injection budget is spent");
        assert_eq!(counters.snapshot().jitter_retries, 1);
        // bitwise the direct D&C decomposition of the jittered matrix
        let mut kj = k.clone();
        kj.add_diag(jitter);
        let direct = SymEigen::new_with(&kj, EigenSolver::Dac).unwrap();
        assert_eq!(h.eigen.values, direct.values);
        assert_eq!(h.eigen.vectors.data(), direct.vectors.data());
    }

    #[test]
    fn merge_failures_exhaust_jitter_and_land_on_the_cholesky_fallback() {
        let _g = INJECT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        inject::reset();
        let k = psd(N, 67);
        let policy = FaultPolicy::default();
        let counters = FaultCounters::default();
        // clean attempt + all four jitter rungs fail; the Cholesky
        // fallback's inner eigensolve is the sixth traversal and succeeds
        inject::arm(FaultPoint::DacMergeNoConvergence, 1, 1 + policy.max_jitter_rungs as u64);
        let h = with_solver(EigenSolver::Dac, || hardened_eigen(&k, &policy, &counters));
        inject::reset();
        let h = h.unwrap();
        assert!(
            matches!(h.grade, SetupGrade::CholFallback { .. }),
            "expected the Cholesky fallback, got {:?}",
            h.grade
        );
        let snap = counters.snapshot();
        assert_eq!(snap.jitter_retries, policy.max_jitter_rungs as u64);
        assert_eq!(snap.fallback_refits, 1);
        // the fallback result is still a usable decomposition
        assert!(h.eigen.reconstruct().max_abs_diff(&k) < 1e-6 * (1.0 + k.fro_norm()));
    }

    #[test]
    fn merge_failures_all_the_way_down_exhaust_the_ladder() {
        let _g = INJECT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        inject::reset();
        let k = psd(N, 71);
        let policy = FaultPolicy::default();
        let counters = FaultCounters::default();
        // one more firing than the fallback needs: every rung dies
        inject::arm(FaultPoint::DacMergeNoConvergence, 1, 2 + policy.max_jitter_rungs as u64);
        let err = with_solver(EigenSolver::Dac, || hardened_eigen(&k, &policy, &counters));
        inject::reset();
        let err = err.unwrap_err();
        assert_eq!(err.rungs, policy.max_jitter_rungs);
        let msg = err.to_string();
        assert!(msg.contains("cholesky"), "error names the fallback stage: {msg}");
        let snap = counters.snapshot();
        assert_eq!(snap.jitter_retries, policy.max_jitter_rungs as u64);
        assert_eq!(snap.fallback_refits, 1);
    }
}

//! The differential-verification gate (ISSUE 1 acceptance): the paper's
//! Propositions 2.1–2.3 hold — spectral O(N) score, Jacobian and Hessian
//! match the naive O(N^3) evaluator and finite differences — for
//! N in {8, 32, 128} across the feasible hyperparameter grid, including
//! the near-boundary sigma2 -> 0+ region, at 1e-7 relative tolerance
//! (conditioning-aware where f64 itself loses digits; see
//! `gpml::verify`'s module docs for the exact tolerance model).
//!
//! This file is the permanent regression gate: any refactor of
//! `spectral`, `naive` or `linalg` that breaks an identity fails
//! `cargo test` here with a per-quantity report.

use gpml::verify::{ard_differential_suite, differential_suite, random_triples_suite, SuiteConfig};

#[test]
fn spectral_identities_hold_across_the_grid() {
    let cfg = SuiteConfig::default();
    assert_eq!(cfg.sizes, vec![8, 32, 128], "acceptance sizes");
    assert_eq!(cfg.rtol, 1e-7, "acceptance tolerance");
    let report = differential_suite(&cfg);
    assert!(report.ok(), "{}", report.summary());
    // 3 sizes x 2 datasets x 2 kernels x 32 grid points
    assert_eq!(report.cases, 3 * 2 * 2 * 32);
    assert!(
        report.checks >= 10 * report.cases,
        "suite shrank: only {} checks over {} cases",
        report.checks,
        report.cases
    );
}

#[test]
fn identities_hold_at_the_sigma2_boundary() {
    // Dedicated sweep of eq. (13)'s near-boundary region: tiny sigma2
    // against a spread of lambda2, where the seed's score rewrite
    // (`g = (b^2+4a^2)/(sigma2 a b)`, `- 4 y'y / sigma2`) sees its
    // heaviest cancellation.
    let cfg = SuiteConfig {
        sizes: vec![8, 32, 128],
        datasets_per_size: 1,
        sigma2_grid: vec![1e-10, 1e-8, 1e-7, 1e-6, 1e-5],
        lambda2_grid: vec![1e-2, 1.0, 1e2],
        seed: 0xB0DA_5EED,
        ..Default::default()
    };
    let report = differential_suite(&cfg);
    assert!(report.ok(), "{}", report.summary());
}

#[test]
fn two_hundred_random_triples() {
    // >= 200 random (kernel, y, hyperparameter) triples asserting
    // naive <-> spectral score/Jacobian agreement, Hessian-vs-fd
    // agreement, and Hessian symmetry (ISSUE 1 test-coverage satellite).
    let report = random_triples_suite(200, 0xC0FFEE);
    assert!(report.ok(), "{}", report.summary());
    assert_eq!(report.cases, 200);
    assert!(report.checks >= 200 * 10, "{} checks", report.checks);
}

#[test]
fn ard_grams_and_score_slopes_match_the_isotropic_rescaling() {
    // PR 6 vector-theta acceptance: the ARD gram equals the isotropic
    // gram on rescaled inputs, the eq. 19 score agrees through both
    // constructions, and the score's finite-difference slope along each
    // theta component matches — at every size the main suite covers.
    let report = ard_differential_suite(&[8, 32, 128], 0xA4D_0001);
    assert!(report.ok(), "{}", report.summary());
    assert_eq!(report.cases, 3);
    // per size: gram identity + score agreement + 3 component slopes
    assert_eq!(report.checks, 3 * 5);
}

#[test]
fn suite_is_deterministic_per_seed() {
    // The gate must be reproducible: a failure report's seed re-runs to
    // the identical case list.
    let cfg = SuiteConfig {
        sizes: vec![8],
        datasets_per_size: 1,
        ..Default::default()
    };
    let a = differential_suite(&cfg);
    let b = differential_suite(&cfg);
    assert_eq!(a.cases, b.cases);
    assert_eq!(a.checks, b.checks);
    assert_eq!(a.discrepancies.len(), b.discrepancies.len());
}

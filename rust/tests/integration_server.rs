//! Integration: the TCP coordinator server end-to-end, including the PJRT
//! worker when artifacts are present.

mod common;

use gpml::coordinator::client::Client;
use gpml::coordinator::server::Server;
use gpml::coordinator::{Backend, Coordinator, GlobalStrategy, TuneRequest};
use gpml::data::{synthetic, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::util::json::Json;

fn small_request(seed: u64) -> TuneRequest {
    let ds = synthetic(SyntheticSpec { n: 40, p: 2, seed, ..Default::default() }, 1);
    let mut req = TuneRequest::new(ds.x, ds.ys, Kernel::Rbf { xi2: 2.0 });
    req.strategy = GlobalStrategy::Grid { points_per_axis: 7 };
    req
}

#[test]
fn server_rust_backend_end_to_end() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    assert!(client.ping().unwrap());
    let res = client.tune(&small_request(1)).unwrap();
    let out = &res.get("outputs").unwrap().as_arr().unwrap()[0];
    assert!(out.get("score").unwrap().as_f64().unwrap().is_finite());
    assert!(out.get("sigma2").unwrap().as_f64().unwrap() > 0.0);
    server.stop();
}

#[test]
fn server_pjrt_backend_end_to_end() {
    // build the coordinator on the worker thread with a PJRT runtime if
    // artifacts exist; otherwise this degrades to rust-only and the pjrt
    // request errors cleanly.
    let dir = common::artifact_dir();
    let have_artifacts = dir.join("manifest.json").exists();
    let server = Server::start("127.0.0.1:0", move || {
        match gpml::runtime::PjrtRuntime::open(&dir) {
            Ok(rt) => Coordinator::with_runtime(rt),
            Err(_) => Coordinator::rust_only(),
        }
    })
    .unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let mut req = small_request(2);
    req.backend = Backend::Pjrt;
    let result = client.tune(&req);
    if have_artifacts {
        let res = result.unwrap();
        assert_eq!(res.get("backend").unwrap().as_str(), Some("pjrt"));
    } else {
        assert!(result.is_err());
    }
    server.stop();
}

#[test]
fn info_reports_cache_counters() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let req = small_request(3);
    client.tune(&req).unwrap();
    client.tune(&req).unwrap();
    let info = client.info().unwrap();
    assert_eq!(info.get("cache_misses").and_then(Json::as_f64), Some(1.0));
    assert_eq!(info.get("cache_hits").and_then(Json::as_f64), Some(1.0));
    server.stop();
}

#[test]
fn multiple_sequential_clients() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    for seed in 0..3 {
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        let res = client.tune(&small_request(seed)).unwrap();
        assert_eq!(res.get("ok").unwrap().as_bool(), Some(true));
    }
    server.stop();
}

//! Integration: the theta-plane tuning engine (ISSUE 5, extended by the
//! PR 6 vector-theta refactor) — warm/cold differential identity through
//! the eigen-family cache (scalar and 2-D ARD), the wavefront-vs-golden
//! property sweep, Newton inner-refinement properties, cross-width
//! determinism, and the `tune_theta` wire op end to end.

use gpml::coordinator::client::Client;
use gpml::coordinator::server::Server;
use gpml::coordinator::session::{tune_theta, SessionStore, ThetaTuneRequest};
use gpml::coordinator::{Coordinator, ObjectiveKind};
use gpml::data::{synthetic, SyntheticSpec};
use gpml::kernelfn::{Kernel, ThetaVec};
use gpml::optim::{theta_tune, FnProvider, RefineKind, ThetaSearch, TwoStepOptions};
use gpml::spectral::SpectralGp;
use gpml::util::json::Json;

fn dataset(n: usize, seed: u64, kernel: Kernel) -> (gpml::linalg::Matrix, Vec<Vec<f64>>) {
    let ds = synthetic(SyntheticSpec { n, p: 3, seed, kernel, ..Default::default() }, 1);
    (ds.x, ds.ys)
}

fn sweep_request(id: u64, ys: Vec<Vec<f64>>) -> ThetaTuneRequest {
    let mut req = ThetaTuneRequest::new(id, ys);
    req.theta_range = (0.2, 10.0);
    req.outer_iters = 14;
    req.inner_grid = 5;
    req.objective = ObjectiveKind::Evidence;
    req
}

/// ISSUE-5 differential test: a warm (family-cached) `tune_theta` must
/// return bitwise-identical `(theta, hp, score)` to the cold sweep that
/// populated the cache, at every size.
#[test]
fn warm_tune_theta_is_bitwise_cold_across_sizes() {
    for &n in &[8usize, 32, 128] {
        let kernel = Kernel::Rbf { xi2: 2.0 };
        let (x, ys) = dataset(n, 100 + n as u64, kernel);
        let store = SessionStore::new(8, usize::MAX);
        let (sess, _) = store.create(kernel, x).unwrap();
        let req = sweep_request(sess.id, ys);

        let cold = tune_theta(&store, &req).unwrap();
        assert!(cold.setups_built > 0, "N={n}: cold sweep must build");
        let setups = store.stats().setups;

        let warm = tune_theta(&store, &req).unwrap();
        assert_eq!(warm.setups_built, 0, "N={n}: warm sweep must not build");
        assert_eq!(store.stats().setups, setups, "N={n}: setups stay flat");

        assert_eq!(cold.outputs.len(), warm.outputs.len());
        for (a, b) in cold.outputs.iter().zip(&warm.outputs) {
            assert_eq!(a.theta.bits(), b.theta.bits(), "N={n}: theta");
            assert_eq!(a.hp.sigma2.to_bits(), b.hp.sigma2.to_bits(), "N={n}: sigma2");
            assert_eq!(a.hp.lambda2.to_bits(), b.hp.lambda2.to_bits(), "N={n}: lambda2");
            assert_eq!(a.score.to_bits(), b.score.to_bits(), "N={n}: score");
            assert_eq!(a.newton_iters, b.newton_iters, "N={n}: newton iters");
            assert_eq!(a.newton_evals, b.newton_evals, "N={n}: newton evals");
        }
    }
}

/// The candidate set is fixed by the request, never by the pool width,
/// and every setup is built on the pinned serial path: widths 1 and 4
/// must agree bitwise for both search strategies (the engine analogue
/// of the par_determinism gates; golden is the single-candidate-wave
/// case where an unpinned build would parallelize the eigensolver).
#[test]
fn tune_theta_is_bitwise_identical_across_pool_widths() {
    let kernel = Kernel::Rbf { xi2: 2.0 };
    let (x, ys) = dataset(48, 7, kernel);
    for search in [ThetaSearch::Wavefront { width: 0 }, ThetaSearch::Golden] {
        let run = |threads: usize| {
            let store = SessionStore::new(8, usize::MAX);
            let (sess, _) = store.create(kernel, x.clone()).unwrap();
            let mut req = sweep_request(sess.id, ys.clone());
            req.search = search;
            req.threads = threads;
            tune_theta(&store, &req).unwrap()
        };
        let serial = run(1);
        let pooled = run(4);
        for (a, b) in serial.outputs.iter().zip(&pooled.outputs) {
            assert_eq!(a.theta.bits(), b.theta.bits(), "{search:?}");
            assert_eq!(a.hp, b.hp, "{search:?}");
            assert_eq!(a.score.to_bits(), b.score.to_bits(), "{search:?}");
            assert_eq!(a.outer_evals, b.outer_evals, "{search:?}");
            assert_eq!(a.distinct_thetas, b.distinct_thetas, "{search:?}");
            assert_eq!(a.newton_iters, b.newton_iters, "{search:?}");
            assert_eq!(a.newton_evals, b.newton_evals, "{search:?}");
        }
    }
}

/// ISSUE-5 property sweep: on random synthetic datasets the parallel
/// wavefront outer search finds a score <= the serial golden-section
/// result (up to float-noise slack — both converge the bracket to the
/// same 1e-4-decade tolerance).
#[test]
fn wavefront_beats_or_matches_golden_on_random_datasets() {
    for seed in 0..6u64 {
        let kernel = Kernel::Rbf { xi2: 1.0 + seed as f64 * 0.7 };
        let (x, ys) = dataset(32, 900 + seed, kernel);
        let y = ys[0].clone();
        let make = |theta: f64| {
            let gp = SpectralGp::fit(kernel.with_theta(theta), x.clone()).unwrap();
            gpml::optim::EvidenceObjective(gp.eigensystem(&y))
        };
        let base = TwoStepOptions {
            theta_range: (0.1, 20.0),
            inner_grid: 5,
            ..Default::default()
        };
        let golden = theta_tune(
            &FnProvider::new(&make),
            &TwoStepOptions { outer_iters: 18, search: ThetaSearch::Golden, ..base },
        )
        .unwrap();
        let wave = theta_tune(
            &FnProvider::new(&make),
            &TwoStepOptions {
                outer_iters: 48,
                search: ThetaSearch::Wavefront { width: 0 },
                ..base
            },
        )
        .unwrap();
        assert!(
            wave.score <= golden.score + 1e-6 * golden.score.abs().max(1.0),
            "seed {seed}: wavefront {} should not lose to golden {}",
            wave.score,
            golden.score
        );
        assert!(wave.outer_evals <= 48, "seed {seed}: budget respected");
    }
}

/// Polynomial is a discrete family: the engine sweeps integer degrees
/// (one setup each — no golden-section aliasing), and the winning theta
/// is an exact integer.
#[test]
fn polynomial_family_sweeps_discrete_degrees() {
    let kernel = Kernel::Polynomial { degree: 3 };
    let (x, ys) = dataset(24, 31, kernel);
    let store = SessionStore::new(8, usize::MAX);
    let (sess, _) = store.create(kernel, x).unwrap();
    let mut req = sweep_request(sess.id, ys);
    req.theta_range = (1.0, 5.0);
    // golden would alias probes; the family-aware engine must ignore the
    // requested continuous search for an Integer domain
    req.search = ThetaSearch::Golden;

    let res = tune_theta(&store, &req).unwrap();
    let out = &res.outputs[0];
    assert_eq!(out.theta.get(0).fract(), 0.0, "discrete family returns an integer degree");
    assert!((1.0..=5.0).contains(&out.theta.get(0)));
    assert_eq!(out.distinct_thetas, 5, "degrees 1..=5 each probed once");
    // degree 3 == the base session's kernel, served by the base setup
    assert_eq!(out.outer_evals, 4, "4 new setups; the base degree was free");

    // warm re-sweep: zero builds, identical bits
    let warm = tune_theta(&store, &req).unwrap();
    assert_eq!(warm.setups_built, 0);
    assert_eq!(warm.outputs[0].theta.bits(), out.theta.bits());
    assert_eq!(warm.outputs[0].score.to_bits(), out.score.to_bits());
}

/// Multi-output jobs share the family across outputs: output 2's probes
/// hit the decompositions output 1 built.
#[test]
fn multi_output_sweep_shares_family_setups() {
    let kernel = Kernel::Rbf { xi2: 2.0 };
    let ds = synthetic(SyntheticSpec { n: 24, p: 3, seed: 55, kernel, ..Default::default() }, 3);
    let store = SessionStore::new(8, usize::MAX);
    let (sess, _) = store.create(kernel, ds.x).unwrap();
    let req = sweep_request(sess.id, ds.ys);
    let res = tune_theta(&store, &req).unwrap();
    assert_eq!(res.outputs.len(), 3);
    assert!(res.outputs[0].outer_evals > 0, "first output builds the family");
    assert_eq!(res.outputs[1].outer_evals, 0, "second output rides the cache");
    assert_eq!(res.outputs[2].outer_evals, 0);
    assert_eq!(res.setups_built, res.outputs[0].outer_evals);
}

#[test]
fn tune_theta_over_the_wire_with_warm_stats() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let kernel = Kernel::Rbf { xi2: 2.0 };
    let (x, ys) = dataset(32, 71, kernel);
    let id = client.create_session(&x, kernel).unwrap();

    let req = sweep_request(id, ys);
    let cold = client.tune_theta(&req).unwrap();
    assert_eq!(cold.get("ok").and_then(Json::as_bool), Some(true));
    assert!(cold.get("setups_built").and_then(Json::as_usize).unwrap() > 0);
    let outs = cold.get("outputs").unwrap().as_arr().unwrap();
    assert!(outs[0].get("theta").unwrap().as_f64().unwrap() > 0.0);

    let stats = client.stats().unwrap();
    let setups_cold = stats.get("setups").and_then(Json::as_usize).unwrap();
    let hits_cold = stats.get("theta_hits").and_then(Json::as_usize).unwrap();
    assert!(stats.get("theta_entries").and_then(Json::as_usize).unwrap() > 0);

    // warm: setups flat, theta_hits rising, bitwise-identical outputs
    let warm = client.tune_theta(&req).unwrap();
    assert_eq!(warm.get("setups_built").and_then(Json::as_usize), Some(0));
    assert_eq!(
        warm.get("outputs").unwrap().to_string(),
        cold.get("outputs").unwrap().to_string(),
        "warm wire response must be bitwise identical"
    );
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("setups").and_then(Json::as_usize), Some(setups_cold));
    assert!(stats.get("theta_hits").and_then(Json::as_usize).unwrap() > hits_cold);
    server.stop();
}

#[test]
fn tune_theta_wire_error_shapes() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let kernel = Kernel::Rbf { xi2: 2.0 };
    let (x, ys) = dataset(12, 73, kernel);
    let id = client.create_session(&x, kernel).unwrap();

    // unknown session
    let v = client.raw(r#"{"op":"tune_theta","session_id":999,"ys":[[1,2]]}"#).unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
    assert!(v.get("error").and_then(Json::as_str).unwrap().contains("unknown session"));

    // parse-level strictness travels as an error response, not a hang
    let v = client
        .raw(&format!(
            r#"{{"op":"tune_theta","session_id":{id},"ys":[[1]],"theta_min":5,"theta_max":1}}"#
        ))
        .unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
    let v = client
        .raw(&format!(r#"{{"op":"tune_theta","session_id":{id},"ys":[[1]],"search":"magic"}}"#))
        .unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));

    // wrong output length
    let mut bad = ys.clone();
    bad[0].pop();
    let req = ThetaTuneRequest::new(id, bad);
    assert!(client.tune_theta(&req).is_err());

    // a family with no theta
    let lin_id = client.create_session(&x, Kernel::Linear).unwrap();
    let req = ThetaTuneRequest::new(lin_id, ys);
    let err = client.tune_theta(&req).unwrap_err();
    assert!(err.to_string().contains("no tunable theta"), "{err}");
    server.stop();
}

/// Concurrent wire sweeps over the same family single-flight their
/// setups: the total built never exceeds the distinct candidate count.
#[test]
fn concurrent_wire_sweeps_share_the_family() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let addr = server.addr.to_string();
    let kernel = Kernel::Rbf { xi2: 2.0 };
    let (x, ys) = dataset(24, 77, kernel);
    let mut client = Client::connect(&addr).unwrap();
    let id = client.create_session(&x, kernel).unwrap();

    let handles: Vec<_> = (0..3)
        .map(|_| {
            let addr = addr.clone();
            let ys = ys.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                let req = sweep_request(id, ys);
                let res = client.tune_theta(&req).unwrap();
                res.get("outputs").unwrap().to_string()
            })
        })
        .collect();
    let outs: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(outs.windows(2).all(|w| w[0] == w[1]), "concurrent sweeps agree bitwise");

    let stats = server.session_stats();
    let distinct = {
        let warm = tune_theta(server.store().as_ref(), &sweep_request(id, ys)).unwrap();
        warm.outputs[0].distinct_thetas as u64
    };
    // 1 base setup + at most one build per distinct theta, despite 3
    // concurrent sweeps racing over the same candidates
    assert!(
        stats.setups <= 1 + distinct,
        "setups {} exceed 1 + distinct thetas {distinct}",
        stats.setups
    );
    server.stop();
}

/// PR 6 acceptance: a 2-D ARD sweep is warm/cold bitwise-differential —
/// the warm re-sweep builds **zero** setups and returns byte-identical
/// outputs (vector theta, hp, score, Newton counters).
#[test]
fn warm_ard_sweep_is_bitwise_cold_with_zero_builds() {
    let kernel = Kernel::RbfArd { xi2: ThetaVec::splat(2, 2.0) };
    let ds = synthetic(SyntheticSpec { n: 24, p: 2, seed: 91, kernel, ..Default::default() }, 1);
    let store = SessionStore::new(8, usize::MAX);
    let (sess, _) = store.create(kernel, ds.x).unwrap();
    let mut req = sweep_request(sess.id, ds.ys);
    req.theta_ranges = vec![(0.2, 10.0), (0.2, 10.0)];
    req.outer_iters = 10;

    let cold = tune_theta(&store, &req).unwrap();
    assert!(cold.setups_built > 0, "cold ARD sweep must build");
    let out = &cold.outputs[0];
    assert_eq!(out.theta.len(), 2, "2-D family returns a 2-component theta");

    let warm = tune_theta(&store, &req).unwrap();
    assert_eq!(warm.setups_built, 0, "warm ARD re-sweep builds zero setups");
    let w = &warm.outputs[0];
    assert_eq!(w.theta.bits(), out.theta.bits());
    assert_eq!(w.hp.sigma2.to_bits(), out.hp.sigma2.to_bits());
    assert_eq!(w.hp.lambda2.to_bits(), out.hp.lambda2.to_bits());
    assert_eq!(w.score.to_bits(), out.score.to_bits());
    assert_eq!(w.newton_iters, out.newton_iters);
    assert_eq!(w.newton_evals, out.newton_evals);
}

/// The ARD wire path end to end: array `theta_min`/`theta_max` travel
/// through `tune_theta`, the response theta comes back as an array, and
/// the warm re-request is byte-identical with zero builds.
#[test]
fn ard_tune_theta_over_the_wire_returns_vector_theta() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let kernel = Kernel::RbfArd { xi2: ThetaVec::splat(2, 2.0) };
    let ds = synthetic(SyntheticSpec { n: 16, p: 2, seed: 19, kernel, ..Default::default() }, 1);
    let id = client.create_session(&ds.x, kernel).unwrap();

    let mut req = sweep_request(id, ds.ys);
    req.theta_ranges = vec![(0.2, 10.0), (0.2, 10.0)];
    req.outer_iters = 6;
    let cold = client.tune_theta(&req).unwrap();
    assert_eq!(cold.get("ok").and_then(Json::as_bool), Some(true), "{cold}");
    let outs = cold.get("outputs").unwrap().as_arr().unwrap();
    let theta = outs[0].get("theta").unwrap().as_arr().unwrap();
    assert_eq!(theta.len(), 2, "ARD theta travels as an array");
    assert!(theta.iter().all(|t| t.as_f64().unwrap() > 0.0));

    let warm = client.tune_theta(&req).unwrap();
    assert_eq!(warm.get("setups_built").and_then(Json::as_usize), Some(0));
    assert_eq!(
        warm.get("outputs").unwrap().to_string(),
        cold.get("outputs").unwrap().to_string(),
        "warm ARD wire response must be byte-identical"
    );
    server.stop();
}

/// An ARD kernel whose lengthscale count disagrees with the data's
/// feature columns is rejected at session creation, not deep inside the
/// gram kernel.
#[test]
fn ard_session_requires_matching_feature_dims() {
    let iso = Kernel::Rbf { xi2: 1.0 };
    let ds = synthetic(SyntheticSpec { n: 8, p: 3, seed: 1, kernel: iso, ..Default::default() }, 1);
    let store = SessionStore::new(8, usize::MAX);
    let err = store.create(Kernel::RbfArd { xi2: ThetaVec::splat(2, 1.0) }, ds.x).unwrap_err();
    assert!(err.to_string().contains("lengthscales"), "{err}");
}

/// ISSUE-6 property sweep: the exact-Hessian Newton inner refinement
/// must not lose to the grid-only inner loop — over 50 random
/// (dataset, kernel) triples the refined score is <= the
/// wavefront-without-Newton score (tiny slack: the two runs may settle
/// on outer candidates refined to different inner optima).
#[test]
fn newton_refinement_never_loses_to_grid_only_on_random_triples() {
    for seed in 0..50u64 {
        let kernel = match seed % 3 {
            0 => Kernel::Rbf { xi2: 0.5 + seed as f64 * 0.1 },
            1 => Kernel::Matern32 { ell: 0.5 + seed as f64 * 0.05 },
            _ => Kernel::Matern52 { ell: 0.4 + seed as f64 * 0.04 },
        };
        let (x, ys) = dataset(16, 5000 + seed, kernel);
        let y = ys[0].clone();
        let make = |theta: f64| {
            let gp = SpectralGp::fit(kernel.with_theta(theta), x.clone()).unwrap();
            gpml::optim::EvidenceObjective(gp.eigensystem(&y))
        };
        let base = TwoStepOptions {
            theta_range: (0.2, 10.0),
            outer_iters: 16,
            search: ThetaSearch::Wavefront { width: 0 },
            inner_grid: 5,
            ..Default::default()
        };
        let refined = theta_tune(&FnProvider::new(&make), &base).unwrap();
        let grid_only = theta_tune(
            &FnProvider::new(&make),
            &TwoStepOptions { refine: RefineKind::None, ..base },
        )
        .unwrap();
        assert!(refined.newton_evals > 0, "seed {seed}: Newton must have run");
        assert_eq!(grid_only.newton_evals, 0, "seed {seed}: grid-only skips Newton");
        assert_eq!(grid_only.newton_iters, 0, "seed {seed}");
        assert!(
            refined.score <= grid_only.score + 1e-4 * grid_only.score.abs().max(1.0),
            "seed {seed}: refined {} must not lose to grid-only {}",
            refined.score,
            grid_only.score
        );
    }
}

/// Regression (ISSUE-6): `outer_evals` counts distinct setups built for
/// the sweep — Newton's O(N) inner re-evaluations are reported in the
/// separate `newton_evals` counter and never inflate it.  The discrete
/// polynomial family fixes the candidate set independently of inner
/// scores, so refine on/off must report identical `outer_evals`.
#[test]
fn outer_evals_count_setups_not_newton_reevaluations() {
    let kernel = Kernel::Polynomial { degree: 2 };
    let ds = synthetic(SyntheticSpec { n: 20, p: 3, seed: 83, kernel, ..Default::default() }, 1);
    let run = |refine: RefineKind| {
        let store = SessionStore::new(8, usize::MAX);
        let (sess, _) = store.create(kernel, ds.x.clone()).unwrap();
        let mut req = sweep_request(sess.id, ds.ys.clone());
        req.theta_range = (1.0, 6.0);
        req.refine = refine;
        tune_theta(&store, &req).unwrap()
    };
    let refined = run(RefineKind::Newton);
    let grid = run(RefineKind::None);
    let (a, b) = (&refined.outputs[0], &grid.outputs[0]);
    assert!(a.newton_evals > 0, "Newton evaluations are accounted somewhere");
    assert_eq!(b.newton_evals, 0);
    assert_eq!(a.outer_evals, b.outer_evals, "outer_evals must not absorb Newton's evals");
    assert_eq!(a.distinct_thetas, b.distinct_thetas);
    assert_eq!(refined.setups_built, a.outer_evals, "outer_evals == setups built this sweep");
}

/// ISSUE-6 satellite: the Nelder-Mead and PSO comparison backends land
/// on the wavefront's optimum (within termination slack) on random
/// datasets, inside the same probe budget.
#[test]
fn nelder_mead_and_pso_match_the_wavefront_on_random_datasets() {
    for seed in 0..4u64 {
        let kernel = Kernel::Rbf { xi2: 1.0 + seed as f64 * 0.6 };
        let (x, ys) = dataset(20, 7000 + seed, kernel);
        let y = ys[0].clone();
        let make = |theta: f64| {
            let gp = SpectralGp::fit(kernel.with_theta(theta), x.clone()).unwrap();
            gpml::optim::EvidenceObjective(gp.eigensystem(&y))
        };
        let base = TwoStepOptions {
            theta_range: (0.1, 20.0),
            outer_iters: 40,
            inner_grid: 5,
            ..Default::default()
        };
        let wave = theta_tune(
            &FnProvider::new(&make),
            &TwoStepOptions { search: ThetaSearch::Wavefront { width: 0 }, ..base },
        )
        .unwrap();
        for search in [ThetaSearch::NelderMead, ThetaSearch::Pso] {
            let r =
                theta_tune(&FnProvider::new(&make), &TwoStepOptions { search, ..base }).unwrap();
            assert!(
                r.score <= wave.score + 1e-2 * wave.score.abs().max(1.0),
                "seed {seed} {search:?}: {} vs wavefront {}",
                r.score,
                wave.score
            );
            assert!(r.outer_evals <= 40, "seed {seed} {search:?}: budget respected");
        }
    }
}

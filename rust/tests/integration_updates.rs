//! Integration: the streaming `update_session` op end-to-end over TCP —
//! fingerprint evolution, byte-ledger growth, the `updates` stats
//! counter, shape/liveness errors, and update-then-evaluate agreeing
//! with a cold session of the full dataset.

use gpml::coordinator::client::Client;
use gpml::coordinator::protocol::EvaluateRequest;
use gpml::coordinator::server::{Server, ServerOptions};
use gpml::coordinator::session::SessionTuneRequest;
use gpml::coordinator::{Coordinator, GlobalStrategy, ObjectiveKind};
use gpml::data::{synthetic, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::linalg::Matrix;
use gpml::spectral::HyperParams;

const KERNEL: Kernel = Kernel::Rbf { xi2: 2.0 };

/// Full dataset split into a served base and a streamed tail.
fn streamed(n: usize, m: usize, seed: u64) -> (Matrix, Matrix, Matrix, Vec<f64>) {
    let spec = SyntheticSpec { n: n + m, p: 2, seed, kernel: KERNEL, ..Default::default() };
    let ds = synthetic(spec, 1);
    let base = ds.x.top_left(n, 2);
    let extra = Matrix::from_fn(m, 2, |i, j| ds.x[(n + i, j)]);
    (ds.x, base, extra, ds.ys[0].clone())
}

#[test]
fn update_lifecycle_over_the_wire() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let (full_x, base, extra, y_full) = streamed(24, 2, 1);

    let id = client.create_session(&base, KERNEL).unwrap();
    let res = client.update_session(id, &extra, 0).unwrap();
    assert_eq!(res.get("session_id").unwrap().as_usize(), Some(id as usize));
    assert_eq!(res.get("n").unwrap().as_usize(), Some(26));
    assert_eq!(res.get("incremental").unwrap().as_bool(), Some(true));
    assert_eq!(res.get("updates_applied").unwrap().as_usize(), Some(4));
    assert!(res.get("refit_reason").is_none());
    assert!(res.get("update_seconds").unwrap().as_f64().unwrap() >= 0.0);

    // the old y length is now rejected with the grown N in the message
    let err = client
        .evaluate(&EvaluateRequest {
            session_id: id,
            y: y_full[..24].to_vec(),
            hp: HyperParams::new(0.1, 1.0),
            objective: ObjectiveKind::Evidence,
        })
        .unwrap_err();
    assert!(err.to_string().contains("26"), "{err}");

    // full-length outputs evaluate fine
    let ev = client
        .evaluate(&EvaluateRequest {
            session_id: id,
            y: y_full.clone(),
            hp: HyperParams::new(0.1, 1.0),
            objective: ObjectiveKind::Evidence,
        })
        .unwrap();
    assert!(ev.get("score").unwrap().as_f64().unwrap().is_finite());

    // fingerprint evolution: creating the full dataset hits the grown
    // session (same id, no new setup)
    let created = client.create_session_full(&full_x, KERNEL, 0).unwrap();
    assert_eq!(created.get("cached").unwrap().as_bool(), Some(true));
    assert_eq!(created.get("session_id").unwrap().as_usize(), Some(id as usize));

    // observability: exactly one setup, one update
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("setups").unwrap().as_usize(), Some(1));
    assert_eq!(stats.get("updates").unwrap().as_usize(), Some(1));
    server.stop();
}

#[test]
fn update_then_tune_matches_cold_session_of_full_dataset() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let (full_x, base, extra, y_full) = streamed(32, 3, 7);

    // streamed session
    let warm_id = client.create_session(&base, KERNEL).unwrap();
    client.update_session(warm_id, &extra, 0).unwrap();

    // cold reference on a second server (its own O(N^3) decomposition)
    let cold_server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut cold_client = Client::connect(&cold_server.addr.to_string()).unwrap();
    let cold_id = cold_client.create_session(&full_x, KERNEL).unwrap();

    let tune = |client: &mut Client, id: u64| {
        let mut req = SessionTuneRequest::new(id, vec![y_full.clone()]);
        req.strategy = GlobalStrategy::Grid { points_per_axis: 7 };
        req.objective = ObjectiveKind::Evidence;
        client.tune_session(&req).unwrap()
    };
    let warm = tune(&mut client, warm_id);
    let cold = tune(&mut cold_client, cold_id);
    let get = |v: &gpml::util::json::Json, key: &str| {
        v.get("outputs").unwrap().as_arr().unwrap()[0].get(key).unwrap().as_f64().unwrap()
    };
    for key in ["sigma2", "lambda2", "score"] {
        let (a, b) = (get(&warm, key), get(&cold, key));
        let rel = (a - b).abs() / a.abs().max(b.abs()).max(1.0);
        // the two optimizers walk near-identical objectives; allow for a
        // near-tie branch flipping one Newton step
        assert!(rel < 1e-5, "{key}: streamed {a} vs cold {b}");
    }
    cold_server.stop();
    server.stop();
}

#[test]
fn update_errors_are_clean() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let (_, base, extra, _) = streamed(12, 1, 9);

    // unknown session
    let err = client.update_session(404, &extra, 0).unwrap_err();
    assert!(err.to_string().contains("unknown session"), "{err}");

    let id = client.create_session(&base, KERNEL).unwrap();
    // wrong feature count
    let wrong = Matrix::from_fn(1, 3, |_, _| 0.5);
    let err = client.update_session(id, &wrong, 0).unwrap_err();
    assert!(err.to_string().contains("cols"), "{err}");
    // dropped sessions are gone
    client.drop_session(id).unwrap();
    let err = client.update_session(id, &extra, 0).unwrap_err();
    assert!(err.to_string().contains("unknown session"), "{err}");
    assert_eq!(client.stats().unwrap().get("updates").unwrap().as_usize(), Some(0));
    server.stop();
}

#[test]
fn oversized_batch_falls_back_to_refit_on_the_wire() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();
    let (_, base, _, _) = streamed(16, 1, 11);
    let id = client.create_session(&base, KERNEL).unwrap();

    // 40 appended rows = 80 corrections > the default budget of 64
    let spec = SyntheticSpec { n: 40, p: 2, seed: 12, kernel: KERNEL, ..Default::default() };
    let res = client.update_session(id, &synthetic(spec, 1).x, 0).unwrap();
    assert_eq!(res.get("incremental").unwrap().as_bool(), Some(false));
    assert_eq!(res.get("refit_reason").unwrap().as_str(), Some("update-budget"));
    assert_eq!(res.get("n").unwrap().as_usize(), Some(56));
    assert_eq!(res.get("updates_applied").unwrap().as_usize(), Some(0));

    let stats = client.stats().unwrap();
    assert_eq!(stats.get("updates").unwrap().as_usize(), Some(1));
    assert_eq!(stats.get("setups").unwrap().as_usize(), Some(2), "fallback counted as a setup");
    server.stop();
}

#[test]
fn concurrent_wire_updates_serialize_per_session() {
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let addr = server.addr.to_string();
    let (_, base, _, _) = streamed(20, 1, 13);
    let mut setup_client = Client::connect(&addr).unwrap();
    let id = setup_client.create_session(&base, KERNEL).unwrap();

    let handles: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                let row = Matrix::from_fn(1, 2, |_, j| (i * 2 + j) as f64 * 0.25);
                client
                    .update_session(id, &row, 0)
                    .unwrap()
                    .get("n")
                    .unwrap()
                    .as_usize()
                    .unwrap()
            })
        })
        .collect();
    let mut ns: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    ns.sort_unstable();
    assert_eq!(ns, vec![21, 22, 23, 24], "each racer saw the previous append");
    assert_eq!(server.session_stats().updates, 4);
    server.stop();
}

#[test]
fn update_respects_byte_budget_for_other_sessions() {
    // budget sized so the two base sessions fit, and so does the grown A
    // alone — but grown A + B does not: growing A must evict B, never A
    let one = gpml::spectral::SpectralGp::fit(KERNEL, streamed(24, 0, 1).1).unwrap().setup_bytes();
    let opts = ServerOptions { max_bytes: 4 * one, ..Default::default() };
    let server = Server::start_with("127.0.0.1:0", opts, Coordinator::rust_only).unwrap();
    let mut client = Client::connect(&server.addr.to_string()).unwrap();

    let (_, base_a, extra_a, _) = streamed(24, 20, 21);
    let (_, base_b, _, _) = streamed(24, 0, 22);
    let a = client.create_session(&base_a, KERNEL).unwrap();
    let b = client.create_session(&base_b, KERNEL).unwrap();

    // grow A well past one budget unit (44 rows total)
    let res = client.update_session(a, &extra_a, 0).unwrap();
    assert_eq!(res.get("n").unwrap().as_usize(), Some(44));
    let stats = server.session_stats();
    assert!(stats.bytes <= opts.max_bytes, "byte budget holds after growth");
    // A (the updated session) survives; B was the eviction victim
    assert!(server.store().get(a).is_some());
    assert!(server.store().get(b).is_none());
    assert!(stats.evictions >= 1);
    server.stop();
}

//! Chaos suite (requires `--features fault-inject`): seeded, counter-
//! scheduled faults fire against a live server while healthy traffic on
//! neighboring connections must come back **bitwise identical** to its
//! pre-fault baseline (DESIGN.md §11).
//!
//! The injection points are process-global, so every test serializes on
//! one mutex and resets the schedule on entry and exit.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Mutex;
use std::time::Duration;

use gpml::coordinator::client::{Client, ClientError, ClientOptions};
use gpml::coordinator::protocol::EvaluateRequest;
use gpml::coordinator::server::{Server, ServerOptions};
use gpml::coordinator::{Coordinator, ObjectiveKind};
use gpml::faults::inject::{self, FaultPoint};
use gpml::faults::FaultPolicy;
use gpml::kernelfn::Kernel;
use gpml::linalg::Matrix;
use gpml::spectral::HyperParams;

static LOCK: Mutex<()> = Mutex::new(());

/// Serialize on the global injection state and guarantee a clean
/// schedule before and after each test (even on panic).
struct InjectionSession<'a> {
    _guard: std::sync::MutexGuard<'a, ()>,
}

impl<'a> InjectionSession<'a> {
    fn begin() -> InjectionSession<'a> {
        let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        inject::reset();
        InjectionSession { _guard: guard }
    }
}

impl Drop for InjectionSession<'_> {
    fn drop(&mut self) {
        inject::reset();
    }
}

/// Deterministic inputs matrix.
fn inputs(n: usize, p: usize, seed: u64) -> Matrix {
    let mut state = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
    Matrix::from_fn(n, p, |_, _| {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        (state >> 11) as f64 / (1u64 << 53) as f64 - 0.5
    })
}

/// Deterministic outputs.
fn outputs(n: usize, seed: u64) -> Vec<f64> {
    let mut state = seed.wrapping_mul(0x2545f4914f6cdd1d).wrapping_add(9);
    (0..n)
        .map(|_| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64 - 0.5
        })
        .collect()
}

const KERNEL: Kernel = Kernel::Rbf { xi2: 2.0 };

fn eval_req(id: u64, n: usize) -> EvaluateRequest {
    EvaluateRequest {
        session_id: id,
        y: outputs(n, 5),
        hp: HyperParams::new(0.1, 1.3),
        objective: ObjectiveKind::PaperScore,
    }
}

/// No-retry client options so tests observe sheds and errors directly.
fn direct_options() -> ClientOptions {
    ClientOptions { retries: 0, ..ClientOptions::default() }
}

/// Healthy traffic replayed around seeded faults on *neighboring*
/// connections is bitwise identical to its pre-fault baseline, and no
/// worker is permanently lost.
#[test]
fn healthy_traffic_is_bitwise_stable_while_neighbors_fault() {
    let session = InjectionSession::begin();
    let n = 24;
    let opts = ServerOptions {
        workers: 2,
        // short enough that the slow-loris connection expires inside the
        // test; all healthy ops here are sub-millisecond
        request_timeout: Duration::from_millis(500),
        max_line_bytes: 1 << 20,
        ..Default::default()
    };
    let server = Server::start_with("127.0.0.1:0", opts, Coordinator::rust_only).unwrap();
    let addr = server.addr.to_string();

    // --- healthy baseline ---
    let mut healthy = Client::connect_with(&addr, direct_options()).unwrap();
    let x = inputs(n, 3, 42);
    let id = healthy.create_session(&x, KERNEL).unwrap();
    let baseline_eval = healthy.evaluate(&eval_req(id, n)).unwrap().to_string();

    // --- fault 1: a worker panic on a neighboring connection ---
    inject::arm(FaultPoint::WorkerPanic, 1, 1);
    {
        let mut victim = Client::connect_with(&addr, direct_options()).unwrap();
        let v = victim.raw(r#"{"op":"ping"}"#).unwrap();
        assert_eq!(v.get("ok").and_then(|o| o.as_bool()), Some(false), "job died: {v}");
    }
    assert_eq!(inject::fired(FaultPoint::WorkerPanic), 1);

    // --- fault 2: eigensolver non-convergence exhausts the ladder on a
    // *different* dataset (clean + every jitter rung + cholesky inner) ---
    let rungs = FaultPolicy::default().max_jitter_rungs as u64;
    inject::arm(FaultPoint::EigenNoConvergence, 1, rungs + 2);
    {
        let mut victim = Client::connect_with(&addr, direct_options()).unwrap();
        let err = victim.create_session(&inputs(n, 3, 777), KERNEL).unwrap_err();
        match err {
            ClientError::Server { message } => {
                assert!(message.contains("ladder exhausted"), "structured ladder error: {message}")
            }
            other => panic!("expected a structured server error, got {other:?}"),
        }
    }
    assert_eq!(inject::fired(FaultPoint::EigenNoConvergence), rungs + 2);

    // --- fault 3: an oversized request line ---
    {
        let mut victim = Client::connect_with(&addr, direct_options()).unwrap();
        let big = format!(r#"{{"op":"ping","pad":"{}"}}"#, "x".repeat(2 << 20));
        let v = victim.raw(&big).unwrap();
        assert!(
            v.get("error").and_then(|e| e.as_str()).unwrap_or("").contains("exceeds"),
            "oversized line is rejected: {v}"
        );
    }

    // --- fault 4: a slow-loris holding half a request line ---
    {
        let mut loris = TcpStream::connect(server.addr).unwrap();
        loris.write_all(br#"{"op":"pi"#).unwrap(); // half a line, then stall
        let mut resp = String::new();
        let mut reader = BufReader::new(loris.try_clone().unwrap());
        reader.read_line(&mut resp).unwrap(); // server expires the stall
        assert!(resp.contains("deadline"), "slow-loris answered + closed: {resp}");
    }

    // --- fault 5: a mid-request disconnect ---
    {
        let mut rude = TcpStream::connect(server.addr).unwrap();
        rude.write_all(br#"{"op":"stats","#).unwrap();
        rude.shutdown(Shutdown::Both).unwrap();
    }

    // --- healthy traffic replays bitwise identically ---
    let replay = healthy.evaluate(&eval_req(id, n)).unwrap().to_string();
    assert_eq!(baseline_eval, replay, "same connection, same bits");
    let mut fresh = Client::connect_with(&addr, direct_options()).unwrap();
    let id2 = fresh.create_session(&x, KERNEL).unwrap();
    assert_eq!(id2, id, "fingerprint-cached session survived the faults");
    let replay_fresh = fresh.evaluate(&eval_req(id2, n)).unwrap().to_string();
    assert_eq!(baseline_eval, replay_fresh, "fresh connection, same bits");

    // --- the pool is whole: both workers answer, and the counters saw
    // every fault ---
    let stats = fresh.stats().unwrap();
    let faults = server.session_stats().faults;
    assert!(faults.worker_respawns >= 1, "panicked worker respawned: {faults:?}");
    assert!(faults.jitter_retries >= rungs, "ladder rungs recorded: {faults:?}");
    assert!(faults.fallback_refits >= 1, "cholesky fallback recorded: {faults:?}");
    assert!(faults.deadline_expired >= 1, "slow-loris expiry recorded: {faults:?}");
    let wire_respawns = stats.get("worker_respawns").and_then(|v| v.as_usize());
    assert_eq!(wire_respawns, Some(faults.worker_respawns as usize));
    drop(session);
    server.stop();
}

/// Panicking every worker in the pool respawns every worker: the pool
/// self-heals to full strength and keeps serving concurrent load.
#[test]
fn pool_self_heals_after_every_worker_panics() {
    let session = InjectionSession::begin();
    let opts = ServerOptions { workers: 2, ..Default::default() };
    let server = Server::start_with("127.0.0.1:0", opts, Coordinator::rust_only).unwrap();
    let addr = server.addr.to_string();

    inject::arm(FaultPoint::WorkerPanic, 1, 2);
    for _ in 0..2 {
        let mut victim = Client::connect_with(&addr, direct_options()).unwrap();
        let v = victim.raw(r#"{"op":"ping"}"#).unwrap();
        assert_eq!(v.get("ok").and_then(|o| o.as_bool()), Some(false));
    }
    assert_eq!(inject::fired(FaultPoint::WorkerPanic), 2);

    // both workers died once; both must be back — serve concurrent jobs
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect_with(&addr, direct_options()).unwrap();
                let id = c.create_session(&inputs(16, 2, 100 + i), KERNEL).unwrap();
                c.evaluate(&eval_req(id, 16)).unwrap();
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(server.session_stats().faults.worker_respawns, 2);
    drop(session);
    server.stop();
}

/// A stalled dispatch trips the per-request deadline: the client gets a
/// typed `Deadline`, the counter moves, and the worker recovers.
#[test]
fn slow_dispatch_trips_the_deadline() {
    let session = InjectionSession::begin();
    let opts = ServerOptions {
        workers: 1,
        request_timeout: Duration::from_millis(100),
        ..Default::default()
    };
    let server = Server::start_with("127.0.0.1:0", opts, Coordinator::rust_only).unwrap();
    let addr = server.addr.to_string();

    inject::set_slow_dispatch_ms(400);
    inject::arm(FaultPoint::SlowDispatch, 1, 1);
    let mut client = Client::connect_with(&addr, direct_options()).unwrap();
    let err = client.stats().unwrap_err();
    match err {
        ClientError::Deadline { timeout_ms } => assert!(timeout_ms >= 100),
        other => panic!("expected Deadline, got {other:?}"),
    }
    assert!(server.session_stats().faults.deadline_expired >= 1);

    // once the stalled job drains, the same connection serves again
    let mut ok = false;
    for _ in 0..100 {
        let pong = client.raw(r#"{"op":"ping"}"#);
        if pong.map(|v| v.get("ok").and_then(|o| o.as_bool()) == Some(true)).unwrap_or(false) {
            ok = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(ok, "worker never recovered from the stalled dispatch");
    drop(session);
    server.stop();
}

/// An overloaded server sheds with `overloaded` + `retry_after_ms`; the
/// typed client surfaces it after its retry budget, and the shed is
/// counted.
#[test]
fn overload_sheds_and_the_typed_client_reports_it() {
    let session = InjectionSession::begin();
    let opts = ServerOptions { workers: 1, max_queue: 0, ..Default::default() };
    let server = Server::start_with("127.0.0.1:0", opts, Coordinator::rust_only).unwrap();
    let copts = ClientOptions {
        retries: 2,
        backoff_base: Duration::from_millis(5),
        backoff_cap: Duration::from_millis(10),
        ..ClientOptions::default()
    };
    let mut client = Client::connect_with(&server.addr.to_string(), copts).unwrap();
    match client.stats().unwrap_err() {
        ClientError::Overloaded { retry_after_ms } => assert!(retry_after_ms >= 100),
        other => panic!("expected Overloaded, got {other:?}"),
    }
    // 1 initial + 2 retries, all shed
    assert!(server.session_stats().faults.sheds >= 3);
    drop(session);
    server.stop();
}

/// Single-flight under an exhausted ladder: concurrent creates of the
/// same dataset all fail fast — the failed builder's drop-guard wakes
/// the waiters instead of leaving them blocked on the condvar — and a
/// later create (injection disarmed) succeeds cleanly.
#[test]
fn failed_setup_wakes_single_flight_waiters_under_injection() {
    let session = InjectionSession::begin();
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let addr = server.addr.to_string();
    let n = 20;

    // every eigensolve fails until reset: the ladder exhausts for every
    // builder, however many race
    inject::arm(FaultPoint::EigenNoConvergence, 1, u64::MAX);
    let (tx, rx) = std::sync::mpsc::channel();
    let handles: Vec<_> = (0..3)
        .map(|_| {
            let addr = addr.clone();
            let tx = tx.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect_with(&addr, direct_options()).unwrap();
                let res = c.create_session(&inputs(n, 2, 1234), KERNEL);
                tx.send(res.is_err()).unwrap();
            })
        })
        .collect();
    for _ in 0..3 {
        let errored = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("a single-flight waiter hung on a failed builder");
        assert!(errored, "creates must fail while injection exhausts the ladder");
    }
    for h in handles {
        h.join().unwrap();
    }

    inject::reset();
    let mut c = Client::connect_with(&addr, direct_options()).unwrap();
    let id = c.create_session(&inputs(n, 2, 1234), KERNEL).unwrap();
    c.evaluate(&eval_req(id, n)).unwrap();
    drop(session);
    server.stop();
}

/// A failed incremental eigensolve inside `update_session` degrades to a
/// ladder refit and reports `refit_reason: "eigen-failure"` on the wire.
#[test]
fn update_falls_back_to_ladder_refit_on_eigen_failure() {
    let session = InjectionSession::begin();
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect_with(&server.addr.to_string(), direct_options()).unwrap();
    let n = 18;
    let id = client.create_session(&inputs(n, 2, 55), KERNEL).unwrap();

    // exactly one failure: the extend's eigensolve dies, the ladder's
    // from-scratch refit (next traversal, injection exhausted) succeeds
    inject::arm(FaultPoint::EigenNoConvergence, 1, 1);
    let v = client.update_session(id, &inputs(2, 2, 56), 0).unwrap();
    assert_eq!(v.get("incremental").and_then(|b| b.as_bool()), Some(false), "{v}");
    assert_eq!(
        v.get("refit_reason").and_then(|r| r.as_str()),
        Some("eigen-failure"),
        "ladder refit is attributed: {v}"
    );
    assert_eq!(v.get("n").and_then(|x| x.as_usize()), Some(n + 2), "{v}");
    assert!(server.session_stats().faults.fallback_refits >= 1);

    // the refitted session evaluates normally
    client.evaluate(&eval_req(id, n + 2)).unwrap();
    drop(session);
    server.stop();
}

/// The streaming eigen-failure path driven *through the D&C solver*:
/// the extend's eigensolve dies, and the ladder refit's clean attempt
/// then dies inside the divide-and-conquer merge step, so the rung-1
/// jitter retry serves the refit.  The wire response still reports
/// `refit_reason: "eigen-failure"` and the counters record the deeper
/// walk.  (Assumes the default solver — the chaos CI job does not set
/// `GPML_EIGEN`, and a session above the crossover traverses a merge
/// on every decomposition.)
#[test]
fn update_ladder_refit_degrades_through_the_dac_merge() {
    let session = InjectionSession::begin();
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect_with(&server.addr.to_string(), direct_options()).unwrap();
    // above the D&C leaf crossover (32), so the ladder's from-scratch
    // refit of the extended session traverses the merge injection point
    let n = 40;
    let id = client.create_session(&inputs(n, 2, 57), KERNEL).unwrap();

    // one extend failure + one merge failure: the incremental path dies,
    // the refit's clean attempt dies in the merge, jitter rung 1 rescues
    inject::arm(FaultPoint::EigenNoConvergence, 1, 1);
    inject::arm(FaultPoint::DacMergeNoConvergence, 1, 1);
    let v = client.update_session(id, &inputs(2, 2, 58), 0).unwrap();
    assert_eq!(v.get("incremental").and_then(|b| b.as_bool()), Some(false), "{v}");
    assert_eq!(
        v.get("refit_reason").and_then(|r| r.as_str()),
        Some("eigen-failure"),
        "ladder refit is attributed: {v}"
    );
    assert_eq!(v.get("n").and_then(|x| x.as_usize()), Some(n + 2), "{v}");
    let faults = server.session_stats().faults;
    assert!(faults.fallback_refits >= 1, "refit recorded: {faults:?}");
    assert!(faults.jitter_retries >= 1, "the merge failure forced a jitter rung: {faults:?}");

    // the rescued session evaluates normally
    client.evaluate(&eval_req(id, n + 2)).unwrap();
    drop(session);
    server.stop();
}

/// Healthy-path determinism guard for the counters themselves: with no
/// faults armed, serving traffic moves none of the fault counters.
#[test]
fn healthy_traffic_leaves_fault_counters_at_zero() {
    let session = InjectionSession::begin();
    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).unwrap();
    let mut client = Client::connect_with(&server.addr.to_string(), direct_options()).unwrap();
    let id = client.create_session(&inputs(20, 2, 9), KERNEL).unwrap();
    client.evaluate(&eval_req(id, 20)).unwrap();
    client.update_session(id, &inputs(1, 2, 10), 0).unwrap();
    let snap = server.session_stats().faults;
    assert_eq!(snap, gpml::faults::FaultSnapshot::default(), "clean serve: {snap:?}");
    drop(session);
    server.stop();
}

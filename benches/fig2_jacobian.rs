//! Figure 2: evaluation time of the Jacobian (eqs. 20-21) vs N.
//!
//! Paper result: tau_J(N) ~= 44.54 + 0.086 N [us] — the slope is ~2x the
//! score slope because two derivative sums are accumulated.  The rust
//! series times `EigenSystem::grad`; there is deliberately no
//! Jacobian-only PJRT artifact (the fused artifact returns
//! score+Jacobian+Hessian in one dispatch — see fig3), so the PJRT column
//! here reports that fused dispatch as an upper bound.  Alongside the
//! stdout table the run writes `BENCH_fig2_jacobian.json` for the
//! cross-PR perf trajectory.

mod bench_common;

use bench_common::*;
use gpml::spectral::HyperParams;
use gpml::util::timing::{measure_block_stats, Stats, Table};

fn main() {
    println!("== Figure 2: Jacobian evaluation time vs N ==");
    let rt = open_runtime();
    let hp = HyperParams::new(0.7, 1.3);

    let mut table = Table::new(&["N", "rust us/eval", "pjrt(fused) us/eval"]);
    let (mut ns, mut rust_us) = (vec![], vec![]);
    let mut rust_stats: Vec<Stats> = vec![];
    let mut score_stats: Vec<Stats> = vec![];

    for &n in &PAPER_SWEEP {
        let es = synthetic_eigensystem(n, 10 + n as u64);
        let st_rust = measure_block_stats(50, rust_iters(n), 7, || {
            std::hint::black_box(es.grad(hp));
        });
        let t_rust = st_rust.median_us;
        let t_pjrt = rt.as_ref().map(|rt| {
            let ev = rt.evaluator(&es).expect("evaluator");
            measure_block_stats(20, pjrt_iters(n), 3, || {
                std::hint::black_box(ev.try_eval_full(hp).expect("pjrt fused"));
            })
            .median_us
        });
        ns.push(n as f64);
        rust_us.push(t_rust);
        rust_stats.push(st_rust);
        table.row(&[
            n.to_string(),
            format!("{t_rust:.2}"),
            t_pjrt.map(|t| format!("{t:.2}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    table.print();

    print_fit("rust", &ns, &rust_us, "tau_J(N) ~= 44.54 + 0.086 N [us]");

    // shape check the paper calls out: Jacobian slope ~ 2x score slope
    let score_us: Vec<f64> = PAPER_SWEEP
        .iter()
        .map(|&n| {
            let es = synthetic_eigensystem(n, n as u64);
            let st = measure_block_stats(50, rust_iters(n), 7, || {
                std::hint::black_box(es.score(hp));
            });
            let t = st.median_us;
            score_stats.push(st);
            t
        })
        .collect();
    let (_, b_score, _) = gpml::util::timing::linear_fit(&ns, &score_us);
    let (_, b_jac, _) = gpml::util::timing::linear_fit(&ns, &rust_us);
    println!(
        "\nslope ratio jacobian/score: measured {:.2} (paper: 0.086/0.05 = 1.72)",
        b_jac / b_score
    );

    let payload = bench_json(
        "fig2_jacobian",
        &PAPER_SWEEP,
        &[
            Series { label: "rust_jacobian", stats: &rust_stats },
            Series { label: "rust_score", stats: &score_stats },
        ],
        vec![(
            "slope_ratio_jacobian_over_score",
            gpml::util::json::Json::Num(b_jac / b_score),
        )],
    );
    write_bench_json("fig2_jacobian", &payload);
}

//! Ablation for Algorithm 1 (§2.2): what the two-step structure buys.
//!
//! Strategies compared at fixed total work:
//!   A. Algorithm 1 — eigendecomposition per *outer* theta step, O(N)
//!      inner loop (the paper's proposal).
//!   B. decompose-per-iterate — what a naive joint optimizer pays: every
//!      single (theta, sigma2, lambda2) evaluation triggers a fresh
//!      O(N^3) factorization.  Measured for one iterate, extrapolated.

mod bench_common;

use std::time::Instant;

use gpml::data::{synthetic, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::optim::{two_step_tune, EvidenceObjective, TwoStepOptions};
use gpml::spectral::SpectralGp;
use gpml::util::timing::Table;

fn main() {
    println!("== ablation: Algorithm 1 vs decompose-per-iterate ==");
    let mut table = Table::new(&[
        "N",
        "outer evals",
        "inner evals",
        "algo1 total s",
        "per-iterate est. s",
        "advantage",
    ]);

    for &n in &[128usize, 256, 512] {
        let spec = SyntheticSpec {
            n,
            p: 3,
            kernel: Kernel::Rbf { xi2: 2.0 },
            sigma2: 0.05,
            lambda2: 1.0,
            seed: 31,
        };
        let ds = synthetic(spec, 1);
        let y = ds.y().to_vec();
        let x = ds.x;

        // one decomposition cost at this N (for the extrapolation)
        let t = Instant::now();
        let gp0 = SpectralGp::fit(Kernel::Rbf { xi2: 1.0 }, x.clone()).unwrap();
        let t_decomp = t.elapsed().as_secs_f64();
        drop(gp0);

        let t = Instant::now();
        let result = two_step_tune(
            |theta| {
                let gp = SpectralGp::fit(Kernel::Rbf { xi2: theta }, x.clone()).unwrap();
                EvidenceObjective(gp.eigensystem(&y))
            },
            TwoStepOptions {
                theta_range: (0.05, 50.0),
                outer_iters: 10,
                inner_grid: 7,
                ..Default::default()
            },
        );
        let algo1_total = t.elapsed().as_secs_f64();

        // strategy B pays t_decomp for EVERY inner evaluation
        let total_evals = result.inner_evals;
        let per_iterate = t_decomp * total_evals as f64;
        table.row(&[
            n.to_string(),
            result.outer_evals.to_string(),
            result.inner_evals.to_string(),
            format!("{algo1_total:.2}"),
            format!("{per_iterate:.1}"),
            format!("{:.0}x", per_iterate / algo1_total),
        ]);
    }
    table.print();
    println!("\nreading: the inner loop runs hundreds of evaluations per outer theta");
    println!("step; Algorithm 1 pays one O(N^3) decomposition per outer step instead");
    println!("of one per evaluation — the advantage column is the paper's point.");
}

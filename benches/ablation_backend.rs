//! Ablation: evaluation-backend choices in the coordinator.
//!
//!   rust        — pure-rust O(N) loop (no dispatch overhead)
//!   pjrt-cold   — PJRT score with literals re-uploaded per call
//!   pjrt-staged — PJRT score with the eigensystem pre-staged on device
//!   pjrt-batch  — batched artifact, per-point cost at B=64
//!
//! This justifies the coordinator's routing policy (DESIGN.md): batched
//! PJRT for global-search wavefronts, rust scalar for Newton steps.

mod bench_common;

use bench_common::*;
use gpml::spectral::HyperParams;
use gpml::util::timing::{measure_block, Table};

fn main() {
    println!("== ablation: evaluation backend per-point cost (us) ==");
    let Some(rt) = open_runtime() else {
        println!("PJRT artifacts required for this ablation; run `make artifacts`.");
        return;
    };
    let hp = HyperParams::new(0.7, 1.3);

    let mut table = Table::new(&["N", "rust", "pjrt-cold", "pjrt-staged", "pjrt-batch(B=64)"]);
    for &n in &[32usize, 256, 1024, 4096, 8192] {
        let es = synthetic_eigensystem(n, n as u64);
        let ev = rt.evaluator(&es).expect("evaluator");
        let b = ev.batch_width().unwrap_or(64);
        let hps: Vec<HyperParams> = (0..b)
            .map(|i| HyperParams::new(0.5 + 0.01 * i as f64, 1.0 + 0.01 * i as f64))
            .collect();

        let t_rust = measure_block(50, rust_iters(n), || {
            std::hint::black_box(es.score(hp));
        });
        let t_cold = measure_block(10, 100, || {
            std::hint::black_box(rt.score(&es, hp).expect("score"));
        });
        let t_staged = measure_block(20, pjrt_iters(n), || {
            std::hint::black_box(ev.try_eval(hp).expect("staged"));
        });
        let t_batch = measure_block(5, 50, || {
            std::hint::black_box(ev.try_eval_batch(&hps).expect("batch"));
        }) / b as f64;

        table.row(&[
            n.to_string(),
            format!("{t_rust:.2}"),
            format!("{t_cold:.2}"),
            format!("{t_staged:.2}"),
            format!("{t_batch:.2}"),
        ]);
    }
    table.print();
    println!("\nreading: staging removes the per-call upload of the padded eigen-");
    println!("vectors; batching amortizes the dispatch overhead (the paper's ~42 us");
    println!("intercept) across the whole PSO/grid wavefront.");
}

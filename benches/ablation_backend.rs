//! Ablation: scalar vs simd microkernel backend (DESIGN.md §14).
//!
//! Times the three GEMM-shaped setup kernels the microkernel layer
//! serves — `gram` (RBF Gram construction), `matmul` (blocked GEMM) and
//! `tridiagonalize` (the tred2 Householder sweep feeding both
//! eigensolvers) — serially under each pinned `GPML_KERNEL` backend.
//! The two backends are bitwise identical by construction (the
//! par_determinism suite gates that); this bench shows what the AVX2+FMA
//! path buys on top, and `gpml bench-gate` holds each series inside the
//! BENCH_ablation.json envelope.  On hardware without AVX2+FMA the
//! `*_simd` series silently resolve to the scalar path (`simd_available`
//! is recorded in the payload), so the ratio sits at ~1x and the gate's
//! loose envelopes still pass.
//!
//! Writes `BENCH_ablation.json` next to the stdout table.
//!
//! Options (after `cargo bench --bench ablation_backend --`):
//!   --sizes 256,1024,4096   sweep override
//!   --max-n 1024            cap the sweep (CI smoke uses this)
//!   --iters 3               timed repetitions per point

mod bench_common;

use bench_common::*;
use gpml::kernelfn::{gram, Kernel};
use gpml::linalg::{eigen, gemm, simd_available, with_kernel_backend, KernelBackend, Matrix};
use gpml::util::cli::Args;
use gpml::util::json::Json;
use gpml::util::rng::Rng;
use gpml::util::threadpool;
use gpml::util::timing::{measure, Stats, Table};

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let default_sizes = [256usize, 1024, 4096];
    let mut sizes = args.get_usize_list("sizes", &default_sizes).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    match args.get_usize("max-n", usize::MAX) {
        Ok(cap) => sizes.retain(|&n| n <= cap),
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
    if sizes.is_empty() {
        eprintln!("empty sweep after --sizes/--max-n filtering");
        std::process::exit(2);
    }
    let iters = args.get_usize("iters", 0).unwrap_or(0);

    println!(
        "== ablation: scalar vs simd microkernel backend, serial (avx2+fma detected: {}) ==",
        simd_available()
    );

    let mut table = Table::new(&[
        "N",
        "gram scalar ms",
        "gram simd ms",
        "gemm scalar ms",
        "gemm simd ms",
        "tred2 scalar ms",
        "tred2 simd ms",
        "gram x",
        "gemm x",
        "tred2 x",
    ]);
    let mut gram_sc: Vec<Stats> = vec![];
    let mut gram_sv: Vec<Stats> = vec![];
    let mut gemm_sc: Vec<Stats> = vec![];
    let mut gemm_sv: Vec<Stats> = vec![];
    let mut tred_sc: Vec<Stats> = vec![];
    let mut tred_sv: Vec<Stats> = vec![];

    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let x = Matrix::from_fn(n, 4, |_, _| rng.normal());
        let kern = Kernel::Rbf { xi2: 1.5 };
        let k = gram(kern, &x);
        let reps = if iters > 0 {
            iters
        } else if n <= 1024 {
            3
        } else {
            2
        };

        // Serial (width 1) isolates the per-element kernel cost from the
        // pool's stripe scheduling; setup_overhead.rs covers pooled.
        let timed = |backend: KernelBackend, f: &dyn Fn()| {
            threadpool::with_threads(1, || with_kernel_backend(backend, || measure(0, reps, f)))
        };
        let st_gram_sc = timed(KernelBackend::Scalar, &|| {
            std::hint::black_box(gram(kern, &x));
        });
        let st_gram_sv = timed(KernelBackend::Simd, &|| {
            std::hint::black_box(gram(kern, &x));
        });
        let st_gemm_sc = timed(KernelBackend::Scalar, &|| {
            std::hint::black_box(gemm::matmul(&k, &k));
        });
        let st_gemm_sv = timed(KernelBackend::Simd, &|| {
            std::hint::black_box(gemm::matmul(&k, &k));
        });
        let st_tred_sc = timed(KernelBackend::Scalar, &|| {
            std::hint::black_box(eigen::tridiagonalize(&k));
        });
        let st_tred_sv = timed(KernelBackend::Simd, &|| {
            std::hint::black_box(eigen::tridiagonalize(&k));
        });

        table.row(&[
            n.to_string(),
            format!("{:.1}", st_gram_sc.median_us / 1e3),
            format!("{:.1}", st_gram_sv.median_us / 1e3),
            format!("{:.1}", st_gemm_sc.median_us / 1e3),
            format!("{:.1}", st_gemm_sv.median_us / 1e3),
            format!("{:.1}", st_tred_sc.median_us / 1e3),
            format!("{:.1}", st_tred_sv.median_us / 1e3),
            format!("{:.2}x", st_gram_sc.median_us / st_gram_sv.median_us),
            format!("{:.2}x", st_gemm_sc.median_us / st_gemm_sv.median_us),
            format!("{:.2}x", st_tred_sc.median_us / st_tred_sv.median_us),
        ]);
        gram_sc.push(st_gram_sc);
        gram_sv.push(st_gram_sv);
        gemm_sc.push(st_gemm_sc);
        gemm_sv.push(st_gemm_sv);
        tred_sc.push(st_tred_sc);
        tred_sv.push(st_tred_sv);
    }
    table.print();

    let last = sizes.len() - 1;
    let gram_x = gram_sc[last].median_us / gram_sv[last].median_us;
    let gemm_x = gemm_sc[last].median_us / gemm_sv[last].median_us;
    let tred_x = tred_sc[last].median_us / tred_sv[last].median_us;
    println!(
        "\n@ N={}: simd over scalar — gram {gram_x:.2}x, gemm {gemm_x:.2}x, tred2 {tred_x:.2}x",
        sizes[last]
    );
    println!("reading: the register-tiled GEMM and the vectorized exp pass carry the");
    println!("Gram/GEMM wins; tred2 is matvec/rank-2 bound so its headroom is memory,");
    println!("not lanes (DESIGN.md §14).");

    let payload = bench_json(
        "ablation",
        &sizes,
        &[
            Series { label: "gram_scalar", stats: &gram_sc },
            Series { label: "gram_simd", stats: &gram_sv },
            Series { label: "gemm_scalar", stats: &gemm_sc },
            Series { label: "gemm_simd", stats: &gemm_sv },
            Series { label: "tred2_scalar", stats: &tred_sc },
            Series { label: "tred2_simd", stats: &tred_sv },
        ],
        vec![
            ("simd_available", Json::Bool(simd_available())),
            (
                "simd_over_scalar_at_max_n",
                Json::obj(vec![
                    ("n", Json::Num(sizes[last] as f64)),
                    ("gram", Json::Num(gram_x)),
                    ("gemm", Json::Num(gemm_x)),
                    ("tred2", Json::Num(tred_x)),
                ]),
            ),
        ],
    );
    write_bench_json("ablation", &payload);
}
